"""AOT pipeline correctness: lowering produces valid HLO text and a
manifest the Rust runtime can consume.

Uses a throwaway output directory and a trimmed shard registry so the
test stays fast; full-artifact generation is exercised by `make
artifacts` + the Rust runtime_integration tests.
"""

import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import aot
from compile import model as M

jax.config.update("jax_platform_name", "cpu")


def _small_shard():
    return M.LayerShard(hidden=64, heads=4, ffn=256, seq=16, batch=1, mp=2)


def test_to_hlo_text_produces_parseable_module():
    shard = _small_shard()
    fwd, _ = M.make_fwd(shard)
    lowered = jax.jit(fwd).lower(*M.example_args(shard))
    text = aot.to_hlo_text(lowered)
    # HLO text structural markers the xla crate's parser needs
    assert text.startswith("HloModule")
    assert "ENTRY" in text
    assert "ROOT" in text
    # returns a tuple (return_tuple=True)
    assert "tuple(" in text.replace(") ", "(") or "tuple" in text


def test_hlo_text_roundtrips_numerics():
    """The lowered module must compute the same values as eager JAX."""
    from jax._src.lib import xla_client as xc

    shard = _small_shard()
    fwd, names = M.make_fwd(shard)
    args = [
        jax.random.normal(jax.random.PRNGKey(i), s.shape)
        for i, s in enumerate(M.example_args(shard))
    ]
    want = fwd(*args)[0]

    lowered = jax.jit(fwd).lower(*M.example_args(shard))
    text = aot.to_hlo_text(lowered)
    # recompile the text through the same client the rust side uses
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(lowered.compiler_ir("stablehlo")),
        use_tuple_args=False,
        return_tuple=True,
    )
    assert comp.as_hlo_text() == text
    got = jax.jit(fwd)(*args)[0]
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)


def test_lower_all_writes_manifest_and_files(tmp_path, monkeypatch):
    # trim the registry: one small shard + one matmul size
    monkeypatch.setattr(
        aot, "SHARDS", {"layer_tiny_mp2": _small_shard()}
    )
    monkeypatch.setattr(aot, "MATMUL_SIZES", (64,))
    monkeypatch.setattr(aot, "ATTN_SHAPES", {"attn_tiny": (4, 16, 16)})
    out = str(tmp_path / "artifacts")
    manifest = aot.lower_all(out, verbose=False)

    names = {a["name"] for a in manifest["artifacts"]}
    assert names == {"layer_tiny_mp2_fwd", "layer_tiny_mp2_bwd", "matmul_64", "attn_tiny"}
    # every artifact file exists and is HLO text
    for a in manifest["artifacts"]:
        path = os.path.join(out, a["path"])
        assert os.path.exists(path), a["path"]
        with open(path) as f:
            assert f.read(9) == "HloModule"
        assert a["flops"] > 0
        for arg in a["args"]:
            assert all(d > 0 for d in arg["shape"])
    # manifest parses as strict JSON (the rust side's hand-rolled parser)
    with open(os.path.join(out, "manifest.json")) as f:
        json.load(f)


def test_manifest_flops_match_shard_accounting(tmp_path, monkeypatch):
    shard = _small_shard()
    monkeypatch.setattr(aot, "SHARDS", {"layer_tiny_mp2": shard})
    monkeypatch.setattr(aot, "MATMUL_SIZES", ())
    monkeypatch.setattr(aot, "ATTN_SHAPES", {})
    out = str(tmp_path / "artifacts")
    manifest = aot.lower_all(out, verbose=False)
    by_name = {a["name"]: a for a in manifest["artifacts"]}
    assert by_name["layer_tiny_mp2_fwd"]["flops"] == shard.flops_fwd()
    assert by_name["layer_tiny_mp2_bwd"]["flops"] == 3 * shard.flops_fwd()


@pytest.mark.parametrize("mp", [1, 2, 4])
def test_registered_shards_cover_eval_mp_degrees(mp):
    assert f"layer_h1024_mp{mp}" in aot.SHARDS
    shard = aot.SHARDS[f"layer_h1024_mp{mp}"]
    assert shard.mp == mp
    assert shard.heads % mp == 0
