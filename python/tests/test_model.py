"""L2 correctness: Megatron-sharded transformer layer graphs.

Checks (1) shape correctness for every AOT shard, (2) the tensor-MP
invariant — summing the partial outputs of all mp shards (with the weight
partition laid out like Megatron's column/row split) equals the mp=1 layer
up to residual bookkeeping, (3) grads exist and are finite for fwd+bwd.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model as M

jax.config.update("jax_platform_name", "cpu")

SMALL = M.LayerShard(hidden=64, heads=4, ffn=256, seq=16, batch=2, mp=1)


def test_param_shapes_consistent():
    shard = SMALL
    p = shard.init_params(jax.random.PRNGKey(0))
    for name, shape in shard.param_shapes().items():
        assert p[name].shape == shape, name


@pytest.mark.parametrize("mp", [1, 2, 4])
def test_layer_fwd_shapes(mp):
    shard = M.LayerShard(hidden=64, heads=4, ffn=256, seq=16, batch=2, mp=mp)
    params = shard.init_params(jax.random.PRNGKey(0))
    x = jax.random.normal(jax.random.PRNGKey(1), (shard.tokens, shard.hidden))
    y = M.layer_fwd(params, x, shard)
    assert y.shape == (shard.tokens, shard.hidden)
    assert bool(jnp.all(jnp.isfinite(y)))


def _split_params(params, shard_full, mp):
    """Megatron split of full-layer params into mp shard param sets.

    Column-parallel (w_qkv as (h, 3, heads, d) on the head axis; w_fc1 on
    the output axis); row-parallel (w_proj on the input axis, w_fc2 on the
    input axis). LayerNorm params are replicated.
    """
    h = shard_full.hidden
    heads, d = shard_full.heads, shard_full.head_dim
    qkv = params["w_qkv"].reshape(h, 3, heads, d)
    shards = []
    for r in range(mp):
        lh = heads // mp
        sl = slice(r * lh, (r + 1) * lh)
        p = {
            "ln1_g": params["ln1_g"],
            "ln1_b": params["ln1_b"],
            "ln2_g": params["ln2_g"],
            "ln2_b": params["ln2_b"],
            "w_qkv": qkv[:, :, sl, :].reshape(h, 3 * lh * d),
            "w_proj": params["w_proj"].reshape(heads, d, h)[sl].reshape(lh * d, h),
            "w_fc1": params["w_fc1"][:, r * (shard_full.ffn // mp):(r + 1) * (shard_full.ffn // mp)],
            "w_fc2": params["w_fc2"][r * (shard_full.ffn // mp):(r + 1) * (shard_full.ffn // mp), :],
        }
        shards.append(p)
    return shards


@pytest.mark.parametrize("mp", [2, 4])
def test_tensor_parallel_partial_sums_equal_full_layer(mp):
    """The MP invariant the paper's model-parallelism modeling rests on:
    all-reducing the mp shards' partial attn/mlp outputs reproduces the
    unsharded layer output."""
    full = M.LayerShard(hidden=64, heads=4, ffn=256, seq=8, batch=1, mp=1)
    params = full.init_params(jax.random.PRNGKey(0))
    x = jax.random.normal(jax.random.PRNGKey(1), (full.tokens, full.hidden))
    want = M.layer_fwd(params, x, full)

    np.testing.assert_allclose(
        _reconstruct(params, x, full, mp), want, rtol=2e-4, atol=2e-4
    )


def _reconstruct(params, x, full, mp):
    """Run the sharded layers and combine with explicit all-reduce points,
    mirroring what a Megatron rank pair actually communicates."""
    shard = M.LayerShard(
        hidden=full.hidden, heads=full.heads, ffn=full.ffn,
        seq=full.seq, batch=full.batch, mp=mp,
    )
    shard_params = _split_params(params, full, mp)

    # Recompute with the internal structure of layer_fwd, but with the two
    # all-reduce (sum over ranks) insertions:
    from compile.kernels import attention_vjp, layernorm, matmul_vjp

    t = x.shape[0]
    lh, d = shard.local_heads, shard.head_dim

    attn_parts = []
    for p in shard_params:
        y = layernorm(x, p["ln1_g"], p["ln1_b"])
        qkv = matmul_vjp(y, p["w_qkv"]).reshape(shard.batch, shard.seq, 3, lh, d)
        q = qkv[:, :, 0].transpose(0, 2, 1, 3).reshape(shard.batch * lh, shard.seq, d)
        k = qkv[:, :, 1].transpose(0, 2, 1, 3).reshape(shard.batch * lh, shard.seq, d)
        v = qkv[:, :, 2].transpose(0, 2, 1, 3).reshape(shard.batch * lh, shard.seq, d)
        ctx = attention_vjp(q, k, v)
        ctx = ctx.reshape(shard.batch, lh, shard.seq, d).transpose(0, 2, 1, 3).reshape(t, lh * d)
        attn_parts.append(matmul_vjp(ctx, p["w_proj"]))
    x2 = x + sum(attn_parts)  # all-reduce #1

    mlp_parts = []
    for p in shard_params:
        y = layernorm(x2, p["ln2_g"], p["ln2_b"])
        y = jax.nn.gelu(matmul_vjp(y, p["w_fc1"]))
        mlp_parts.append(matmul_vjp(y, p["w_fc2"]))
    return x2 + sum(mlp_parts)  # all-reduce #2


def test_fwdbwd_grads_finite():
    shard = SMALL
    fn, names = M.make_fwdbwd(shard)
    args = [
        jax.random.normal(jax.random.PRNGKey(i), s.shape)
        for i, s in enumerate(M.example_args(shard))
    ]
    outs = fn(*args)
    assert len(outs) == 1 + len(names) + 1  # loss + dparams + dx
    for o in outs:
        assert bool(jnp.all(jnp.isfinite(o)))


def test_flops_fwd_scales_linearly_with_tokens():
    a = M.LayerShard(hidden=64, heads=4, ffn=256, seq=16, batch=1, mp=1)
    b = M.LayerShard(hidden=64, heads=4, ffn=256, seq=16, batch=2, mp=1)
    # attention term is quadratic in seq but linear in batch
    assert b.flops_fwd() == 2 * a.flops_fwd()


def test_flops_fwd_shrinks_with_mp():
    full = M.LayerShard(hidden=64, heads=4, ffn=256, seq=16, batch=1, mp=1)
    half = M.LayerShard(hidden=64, heads=4, ffn=256, seq=16, batch=1, mp=2)
    assert abs(half.flops_fwd() * 2 - full.flops_fwd()) / full.flops_fwd() < 1e-9
