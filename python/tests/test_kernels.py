"""L1 correctness: Pallas kernels vs pure-jnp oracles (ref.py).

Hypothesis sweeps shapes/dtypes; every property asserts allclose against
the reference implementation — the core correctness signal for the AOT
artifacts the Rust profiler times.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import (
    attention,
    attention_ref,
    attention_vjp,
    layernorm,
    layernorm_ref,
    matmul,
    matmul_ref,
    matmul_vjp,
)

jax.config.update("jax_platform_name", "cpu")


def _rand(key, shape, dtype=jnp.float32):
    return jax.random.normal(key, shape, dtype)


# ---------------------------------------------------------------- matmul --


@settings(max_examples=25, deadline=None)
@given(
    m=st.sampled_from([1, 8, 32, 128, 160, 256]),
    k=st.sampled_from([16, 64, 128, 512, 768]),
    n=st.sampled_from([8, 32, 128, 256]),
    seed=st.integers(0, 2**31 - 1),
)
def test_matmul_matches_ref(m, k, n, seed):
    kx, kw = jax.random.split(jax.random.PRNGKey(seed))
    x, w = _rand(kx, (m, k)), _rand(kw, (k, n))
    # tolerance: accumulation order differs between the tiled kernel and
    # the reference, so k-proportional float error is expected
    np.testing.assert_allclose(
        matmul(x, w), matmul_ref(x, w), rtol=1e-4, atol=1e-4
    )


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_matmul_dtypes(dtype):
    kx, kw = jax.random.split(jax.random.PRNGKey(0))
    x = _rand(kx, (64, 128)).astype(dtype)
    w = _rand(kw, (128, 64)).astype(dtype)
    got = matmul(x, w).astype(jnp.float32)
    want = matmul_ref(x.astype(jnp.float32), w.astype(jnp.float32))
    tol = 1e-5 if dtype == jnp.float32 else 5e-2
    np.testing.assert_allclose(got, want, rtol=tol, atol=tol)


@pytest.mark.parametrize(
    "bm,bn,bk", [(32, 32, 64), (128, 128, 128), (64, 128, 256)]
)
def test_matmul_block_shape_invariance(bm, bn, bk):
    """Result must not depend on the HBM<->VMEM schedule (BlockSpec)."""
    kx, kw = jax.random.split(jax.random.PRNGKey(7))
    x, w = _rand(kx, (128, 256)), _rand(kw, (256, 128))
    np.testing.assert_allclose(
        matmul(x, w, bm=bm, bn=bn, bk=bk),
        matmul_ref(x, w),
        rtol=1e-4,
        atol=1e-4,
    )


def test_matmul_vjp_grads_match_ref_grads():
    kx, kw = jax.random.split(jax.random.PRNGKey(3))
    x, w = _rand(kx, (64, 128)), _rand(kw, (128, 32))

    def loss_pallas(x, w):
        return jnp.sum(matmul_vjp(x, w) ** 2)

    def loss_ref(x, w):
        return jnp.sum(matmul_ref(x, w) ** 2)

    gx, gw = jax.grad(loss_pallas, argnums=(0, 1))(x, w)
    rx, rw = jax.grad(loss_ref, argnums=(0, 1))(x, w)
    np.testing.assert_allclose(gx, rx, rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(gw, rw, rtol=1e-4, atol=1e-4)


# ------------------------------------------------------------- attention --


@settings(max_examples=15, deadline=None)
@given(
    bh=st.sampled_from([1, 4, 8]),
    seq=st.sampled_from([16, 64, 128]),
    d=st.sampled_from([16, 64]),
    seed=st.integers(0, 2**31 - 1),
)
def test_attention_matches_ref(bh, seq, d, seed):
    kq, kk, kv = jax.random.split(jax.random.PRNGKey(seed), 3)
    q, k, v = _rand(kq, (bh, seq, d)), _rand(kk, (bh, seq, d)), _rand(kv, (bh, seq, d))
    np.testing.assert_allclose(
        attention(q, k, v), attention_ref(q, k, v), rtol=1e-5, atol=1e-5
    )


def test_attention_softmax_rows_are_convex_combination():
    """Output rows must lie inside the convex hull of v rows: max |o| <=
    max |v| — a softmax-weights invariant independent of the reference."""
    kq, kk, kv = jax.random.split(jax.random.PRNGKey(11), 3)
    q, k, v = _rand(kq, (4, 64, 32)), _rand(kk, (4, 64, 32)), _rand(kv, (4, 64, 32))
    o = attention(q, k, v)
    assert jnp.max(jnp.abs(o)) <= jnp.max(jnp.abs(v)) + 1e-5


def test_attention_scale_invariance_of_uniform_v():
    """If v is constant across seq, attention returns exactly that constant
    regardless of q/k (softmax weights sum to 1)."""
    kq, kk = jax.random.split(jax.random.PRNGKey(5))
    q, k = _rand(kq, (2, 32, 16)), _rand(kk, (2, 32, 16))
    v = jnp.broadcast_to(jnp.arange(16, dtype=jnp.float32), (2, 32, 16))
    np.testing.assert_allclose(
        attention(q, k, v), v, rtol=1e-5, atol=1e-5
    )


def test_attention_vjp_grads_match_ref_grads():
    kq, kk, kv = jax.random.split(jax.random.PRNGKey(13), 3)
    q, k, v = _rand(kq, (2, 32, 16)), _rand(kk, (2, 32, 16)), _rand(kv, (2, 32, 16))

    def lp(q, k, v):
        return jnp.sum(attention_vjp(q, k, v) ** 2)

    def lr(q, k, v):
        return jnp.sum(attention_ref(q, k, v) ** 2)

    gp = jax.grad(lp, argnums=(0, 1, 2))(q, k, v)
    gr = jax.grad(lr, argnums=(0, 1, 2))(q, k, v)
    for a, b in zip(gp, gr):
        np.testing.assert_allclose(a, b, rtol=1e-4, atol=1e-4)


# ------------------------------------------------------------- layernorm --


@settings(max_examples=15, deadline=None)
@given(
    rows=st.sampled_from([1, 8, 64, 128, 192]),
    hidden=st.sampled_from([64, 256, 768]),
    seed=st.integers(0, 2**31 - 1),
)
def test_layernorm_matches_ref(rows, hidden, seed):
    kx, kg, kb = jax.random.split(jax.random.PRNGKey(seed), 3)
    x = _rand(kx, (rows, hidden))
    g = _rand(kg, (hidden,))
    b = _rand(kb, (hidden,))
    np.testing.assert_allclose(
        layernorm(x, g, b), layernorm_ref(x, g, b), rtol=1e-4, atol=1e-4
    )


def test_layernorm_output_is_normalized():
    x = _rand(jax.random.PRNGKey(1), (32, 512)) * 10 + 3
    y = layernorm(x, jnp.ones(512), jnp.zeros(512))
    np.testing.assert_allclose(jnp.mean(y, axis=-1), 0.0, atol=1e-4)
    np.testing.assert_allclose(jnp.std(y, axis=-1), 1.0, atol=1e-3)
