"""L1 Pallas kernel: row-wise LayerNorm.

Grid over row blocks; each step normalizes a (bm, hidden) tile in VMEM.
Small compared to the matmuls but present in every transformer event, so it
is profiled as its own computation event by the Rust side.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _layernorm_kernel(x_ref, g_ref, b_ref, o_ref, *, eps: float):
    x = x_ref[...].astype(jnp.float32)
    mean = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.mean(jnp.square(x - mean), axis=-1, keepdims=True)
    y = (x - mean) * jax.lax.rsqrt(var + eps)
    o_ref[...] = (y * g_ref[...] + b_ref[...]).astype(o_ref.dtype)


def layernorm(
    x: jax.Array, gamma: jax.Array, beta: jax.Array, *, eps: float = 1e-5
) -> jax.Array:
    """LayerNorm over the last axis of a (rows, hidden) input."""
    rows, hidden = x.shape
    bm = rows if rows <= 128 else next(
        c for c in range(128, 0, -1) if rows % c == 0
    )
    import functools

    return pl.pallas_call(
        functools.partial(_layernorm_kernel, eps=eps),
        grid=(rows // bm,),
        in_specs=[
            pl.BlockSpec((bm, hidden), lambda i: (i, 0)),
            pl.BlockSpec((hidden,), lambda i: (0,)),
            pl.BlockSpec((hidden,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((bm, hidden), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((rows, hidden), x.dtype),
        interpret=True,
    )(x, gamma, beta)


@jax.custom_vjp
def layernorm_vjp(x: jax.Array, gamma: jax.Array, beta: jax.Array) -> jax.Array:
    """Differentiable LayerNorm: forward runs the Pallas kernel, backward
    uses the closed-form LayerNorm gradient."""
    return layernorm(x, gamma, beta)


def _ln_fwd(x, gamma, beta):
    return layernorm(x, gamma, beta), (x, gamma)


def _ln_bwd(res, dy, *, eps: float = 1e-5):
    x, gamma = res
    mean = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.mean(jnp.square(x - mean), axis=-1, keepdims=True)
    inv = jax.lax.rsqrt(var + eps)
    xhat = (x - mean) * inv
    dg = jnp.sum(dy * xhat, axis=0)
    db = jnp.sum(dy, axis=0)
    dyg = dy * gamma
    dx = inv * (
        dyg
        - jnp.mean(dyg, axis=-1, keepdims=True)
        - xhat * jnp.mean(dyg * xhat, axis=-1, keepdims=True)
    )
    return dx, dg, db


layernorm_vjp.defvjp(_ln_fwd, _ln_bwd)
