"""Pure-jnp oracles for every L1 Pallas kernel.

These are the correctness ground truth: pytest (python/tests/) sweeps
shapes/dtypes with hypothesis and asserts the Pallas kernels match these
within float tolerance.
"""

from __future__ import annotations

import math

import jax
import jax.numpy as jnp


def matmul_ref(x: jax.Array, w: jax.Array) -> jax.Array:
    return jnp.matmul(x, w)


def attention_ref(q: jax.Array, k: jax.Array, v: jax.Array) -> jax.Array:
    """softmax(q k^T / sqrt(d)) v over (bh, seq, d) operands."""
    d = q.shape[-1]
    s = jnp.einsum("bid,bjd->bij", q, k) / math.sqrt(d)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bij,bjd->bid", p, v)


def layernorm_ref(
    x: jax.Array, gamma: jax.Array, beta: jax.Array, *, eps: float = 1e-5
) -> jax.Array:
    mean = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.var(x, axis=-1, keepdims=True)
    return (x - mean) / jnp.sqrt(var + eps) * gamma + beta
