"""L1 Pallas kernel: fused attention core (softmax(q k^T / sqrt(d)) v).

One grid cell per (batch * head): the full (seq, head_dim) q/k/v tiles for
that head live in VMEM together with the (seq, seq) score tile — for the
paper's profiling shapes (seq <= 512, head_dim <= 128) that is
(3*512*128 + 512*512) * 4B ~= 1.8 MiB, inside the VMEM budget, so no
FlashAttention-style streaming is needed. Softmax is computed in the
numerically-stable max-subtracted form, accumulating in f32.

`interpret=True` (CPU PJRT cannot run Mosaic custom-calls).
"""

from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _attention_kernel(q_ref, k_ref, v_ref, o_ref, *, scale: float):
    q = q_ref[0]  # (seq, d)
    k = k_ref[0]
    v = v_ref[0]
    s = jnp.dot(q, k.T, preferred_element_type=jnp.float32) * scale
    s = s - jnp.max(s, axis=-1, keepdims=True)
    p = jnp.exp(s)
    p = p / jnp.sum(p, axis=-1, keepdims=True)
    o_ref[0] = jnp.dot(p, v, preferred_element_type=jnp.float32).astype(
        o_ref.dtype
    )


def attention(q: jax.Array, k: jax.Array, v: jax.Array) -> jax.Array:
    """Fused attention core.

    Args:
      q, k, v: (bh, seq, head_dim) — batch and head axes pre-flattened.
    Returns:
      (bh, seq, head_dim) attention output.
    """
    bh, seq, d = q.shape
    assert k.shape == (bh, seq, d) and v.shape == (bh, seq, d)
    scale = 1.0 / math.sqrt(d)
    return pl.pallas_call(
        functools.partial(_attention_kernel, scale=scale),
        grid=(bh,),
        in_specs=[
            pl.BlockSpec((1, seq, d), lambda i: (i, 0, 0)),
            pl.BlockSpec((1, seq, d), lambda i: (i, 0, 0)),
            pl.BlockSpec((1, seq, d), lambda i: (i, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, seq, d), lambda i: (i, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((bh, seq, d), q.dtype),
        interpret=True,
    )(q, k, v)


@jax.custom_vjp
def attention_vjp(q: jax.Array, k: jax.Array, v: jax.Array) -> jax.Array:
    """Differentiable fused attention: forward runs the Pallas kernel, the
    backward recomputes probabilities and derives grads with standard
    softmax-attention calculus (matmuls dominate either way)."""
    return attention(q, k, v)


def _attn_fwd(q, k, v):
    return attention(q, k, v), (q, k, v)


def _attn_bwd(res, g):
    q, k, v = res
    d = q.shape[-1]
    scale = 1.0 / math.sqrt(d)
    s = jnp.einsum("bid,bjd->bij", q, k) * scale
    s = s - jnp.max(s, axis=-1, keepdims=True)
    p = jnp.exp(s)
    p = p / jnp.sum(p, axis=-1, keepdims=True)
    dv = jnp.einsum("bij,bid->bjd", p, g)
    dp = jnp.einsum("bid,bjd->bij", g, v)
    # softmax jacobian: dS = P * (dP - sum(dP * P))
    ds = p * (dp - jnp.sum(dp * p, axis=-1, keepdims=True))
    dq = jnp.einsum("bij,bjd->bid", ds, k) * scale
    dk = jnp.einsum("bij,bid->bjd", ds, q) * scale
    return dq, dk, dv


attention_vjp.defvjp(_attn_fwd, _attn_bwd)
