"""L1 Pallas kernel: tiled matmul for the transformer hot path.

Hardware adaptation (paper events are CUDA/A40 kernels -> we target TPU
structure, see DESIGN.md #Hardware-Adaptation): the matmul is tiled over a
(M/bm, N/bn, K/bk) grid so each step holds an x-tile, a w-tile and an
accumulator tile in VMEM; tiles are MXU-aligned (multiples of 128 where the
problem allows). `interpret=True` everywhere: the CPU PJRT client cannot run
Mosaic custom-calls, so correctness is validated through the interpret path
and real-TPU efficiency is *estimated* from the block shapes (DESIGN.md
#Perf).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _matmul_kernel(x_ref, w_ref, o_ref, *, n_k: int):
    """Compute one (bm, bn) output tile; k is the innermost grid axis.

    The output block is revisited for every k step (its index_map ignores
    k), so it doubles as the VMEM accumulator — zeroed at k == 0 and
    accumulated into afterwards, the classic Pallas reduction idiom.
    """
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    # f32 accumulation regardless of input dtype (MXU-style accumulate).
    o_ref[...] += jnp.dot(
        x_ref[...], w_ref[...], preferred_element_type=jnp.float32
    ).astype(o_ref.dtype)


def _pick_block(dim: int, target: int) -> int:
    """Largest divisor of `dim` that is <= target (keeps tiles MXU-friendly
    for power-of-two transformer dims while accepting ragged test shapes)."""
    if dim <= target:
        return dim
    for cand in range(target, 0, -1):
        if dim % cand == 0:
            return cand
    return dim


def matmul(
    x: jax.Array,
    w: jax.Array,
    *,
    bm: int | None = None,
    bn: int | None = None,
    bk: int | None = None,
) -> jax.Array:
    """Tiled Pallas matmul: (m, k) @ (k, n) -> (m, n).

    Default blocks are 128x128 output tiles with a 512-deep k slab: VMEM
    footprint = bm*bk + bk*bn + bm*bn floats = (128*512*2 + 128*128)*4B
    ~= 576 KiB << 16 MiB, and both MXU operand dims are 128-aligned for
    power-of-two transformer shapes.
    """
    m, k = x.shape
    k2, n = w.shape
    assert k == k2, f"contraction mismatch {x.shape} @ {w.shape}"
    bm = bm or _pick_block(m, 128)
    bn = bn or _pick_block(n, 128)
    bk = bk or _pick_block(k, 512)
    assert m % bm == 0 and n % bn == 0 and k % bk == 0, (
        f"blocks ({bm},{bn},{bk}) must divide problem ({m},{n},{k})"
    )
    n_k = k // bk
    grid = (m // bm, n // bn, n_k)
    return pl.pallas_call(
        functools.partial(_matmul_kernel, n_k=n_k),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), x.dtype),
        interpret=True,
    )(x, w)


@jax.custom_vjp
def matmul_vjp(x: jax.Array, w: jax.Array) -> jax.Array:
    """Differentiable wrapper: the forward AND both backward matmuls run
    the Pallas kernel, so AOT bwd artifacts exercise L1 as well."""
    return matmul(x, w)


def _matmul_fwd(x, w):
    return matmul(x, w), (x, w)


def _matmul_bwd(res, g):
    x, w = res
    dx = matmul(g, w.T)
    dw = matmul(x.T, g)
    return dx, dw


matmul_vjp.defvjp(_matmul_fwd, _matmul_bwd)
