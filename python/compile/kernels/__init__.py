# L1: Pallas kernels for the paper's compute hot-spots (all interpret=True).
from .attention import attention, attention_vjp  # noqa: F401
from .layernorm import layernorm, layernorm_vjp  # noqa: F401
from .matmul import matmul, matmul_vjp  # noqa: F401
from .ref import attention_ref, layernorm_ref, matmul_ref  # noqa: F401
