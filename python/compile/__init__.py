# Build-time-only package: authors and AOT-lowers the compute events that
# the Rust profiler times through PJRT. Never imported at simulation time.
