"""AOT lowering: JAX (L2, calling Pallas L1) -> HLO *text* artifacts.

HLO text — NOT `lowered.compiler_ir('hlo')`/`.serialize()` — is the
interchange format: jax >= 0.5 emits HloModuleProto with 64-bit instruction
ids which xla_extension 0.5.1 (what the published `xla` 0.1.6 crate links)
rejects; the text parser reassigns ids and round-trips cleanly. See
/opt/xla-example/README.md.

Outputs, under --out-dir (default ../artifacts):
  <name>.hlo.txt           one module per (event, shape) pair
  manifest.json            index the Rust profiler reads: for every artifact
                           its arg shapes, flop count, and event identity

Run via `make artifacts` (no-op when inputs are unchanged).
"""

from __future__ import annotations

import argparse
import json
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model as M
from .kernels import attention_vjp, matmul_vjp


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


# ---------------------------------------------------------------------------
# Artifact definitions.
#
# The profiling shapes mirror the per-rank shards the paper profiles on its
# 2-node slice: one transformer layer of each benchmark model family at
# every tensor-MP degree used in the evaluation. seq/batch are the paper's
# micro-batch granularity (seq 128 keeps CPU-PJRT timing runs fast; the
# Rust cost model scales by FLOPs to the full sequence length).
# ---------------------------------------------------------------------------

SHARDS: dict[str, M.LayerShard] = {}


def _register_shards() -> None:
    # (family, hidden, heads, ffn): BERT-Large / GPT-2-345M share h=1024;
    # T5-Large uses h=1024 ffn=4096 too but we also emit a 768 variant to
    # give the calibration a second size point.
    for name, (h, heads, ffn) in {
        "h1024": (1024, 16, 4096),
        "h768": (768, 12, 3072),
    }.items():
        for mp in (1, 2, 4):
            if heads % mp:
                continue
            SHARDS[f"layer_{name}_mp{mp}"] = M.LayerShard(
                hidden=h, heads=heads, ffn=ffn, seq=128, batch=1, mp=mp
            )


_register_shards()

# Micro events used for the cost-model efficiency curve: square matmuls of
# increasing size and one attention core.
MATMUL_SIZES = (128, 256, 512, 1024)
ATTN_SHAPES = {"attn_bh16_s128_d64": (16, 128, 64)}


def lower_all(out_dir: str, *, verbose: bool = True) -> dict:
    os.makedirs(out_dir, exist_ok=True)
    manifest: dict = {"artifacts": []}

    def emit(name: str, lowered, args, *, flops: int, kind: str, meta: dict):
        text = to_hlo_text(lowered)
        path = f"{name}.hlo.txt"
        with open(os.path.join(out_dir, path), "w") as f:
            f.write(text)
        manifest["artifacts"].append(
            {
                "name": name,
                "path": path,
                "kind": kind,
                "flops": flops,
                "args": [
                    {"shape": list(a.shape), "dtype": str(a.dtype)}
                    for a in args
                ],
                **meta,
            }
        )
        if verbose:
            print(f"  wrote {path} ({len(text)} chars, {flops/1e9:.2f} GFLOP)")

    # Transformer layer shards: fwd and fwd+bwd.
    for name, shard in SHARDS.items():
        args = M.example_args(shard)
        fwd, _ = M.make_fwd(shard)
        emit(
            f"{name}_fwd",
            jax.jit(fwd).lower(*args),
            args,
            flops=shard.flops_fwd(),
            kind="layer_fwd",
            meta={
                "hidden": shard.hidden,
                "heads": shard.heads,
                "ffn": shard.ffn,
                "seq": shard.seq,
                "batch": shard.batch,
                "mp": shard.mp,
            },
        )
        fwdbwd, _ = M.make_fwdbwd(shard)
        emit(
            f"{name}_bwd",
            jax.jit(fwdbwd).lower(*args),
            args,
            flops=3 * shard.flops_fwd(),  # fwd + ~2x fwd for bwd
            kind="layer_bwd",
            meta={
                "hidden": shard.hidden,
                "heads": shard.heads,
                "ffn": shard.ffn,
                "seq": shard.seq,
                "batch": shard.batch,
                "mp": shard.mp,
            },
        )

    # Calibration micro-events.
    for n in MATMUL_SIZES:
        spec = jax.ShapeDtypeStruct((n, n), jnp.float32)

        def mm(x, w):
            return (matmul_vjp(x, w),)

        emit(
            f"matmul_{n}",
            jax.jit(mm).lower(spec, spec),
            [spec, spec],
            flops=2 * n * n * n,
            kind="matmul",
            meta={"n": n},
        )
    for name, (bh, s, d) in ATTN_SHAPES.items():
        spec = jax.ShapeDtypeStruct((bh, s, d), jnp.float32)

        def at(q, k, v):
            return (attention_vjp(q, k, v),)

        emit(
            name,
            jax.jit(at).lower(spec, spec, spec),
            [spec, spec, spec],
            flops=2 * bh * s * s * d * 2,
            kind="attention",
            meta={"bh": bh, "seq": s, "d": d},
        )

    with open(os.path.join(out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1)
    return manifest


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--quiet", action="store_true")
    args = ap.parse_args()
    manifest = lower_all(args.out_dir, verbose=not args.quiet)
    print(
        f"wrote {len(manifest['artifacts'])} artifacts + manifest.json "
        f"to {args.out_dir}"
    )


if __name__ == "__main__":
    main()
