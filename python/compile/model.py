"""L2: JAX compute graphs for DistSim's *computation events*.

The paper's events are the per-device operators of a Megatron-partitioned
transformer layer. This module builds exactly those graphs — a tensor-model-
parallel shard of one transformer layer, forward and forward+backward — by
calling the L1 Pallas kernels, so the AOT artifacts the Rust profiler times
contain the same kernels the paper would have profiled with CUPTI.

Megatron sharding of a layer with MP size `mp`:
  attention: qkv projection is column-parallel (heads/mp heads per rank),
    output projection row-parallel (h/mp -> h, partial sums all-reduced);
  MLP: h -> 4h/mp column-parallel, gelu, 4h/mp -> h row-parallel (partial
    sums all-reduced).
The all-reduces are *communication* events modeled in Rust (comm/); here we
compute the per-rank compute shard only, which is what a compute event is.
"""

from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp

from .kernels import attention_vjp, layernorm_vjp as layernorm, matmul_vjp


@dataclass(frozen=True)
class LayerShard:
    """A tensor-parallel shard of one transformer layer."""

    hidden: int
    heads: int
    ffn: int
    seq: int
    batch: int
    mp: int  # tensor model parallelism degree

    @property
    def head_dim(self) -> int:
        return self.hidden // self.heads

    @property
    def local_heads(self) -> int:
        assert self.heads % self.mp == 0
        return self.heads // self.mp

    @property
    def local_qkv(self) -> int:
        return 3 * self.hidden // self.mp

    @property
    def local_ffn(self) -> int:
        assert self.ffn % self.mp == 0
        return self.ffn // self.mp

    @property
    def tokens(self) -> int:
        return self.seq * self.batch

    def param_shapes(self) -> dict[str, tuple[int, ...]]:
        h, lf, lq = self.hidden, self.local_ffn, self.local_qkv
        return {
            "ln1_g": (h,),
            "ln1_b": (h,),
            "w_qkv": (h, lq),
            "w_proj": (self.local_heads * self.head_dim, h),
            "ln2_g": (h,),
            "ln2_b": (h,),
            "w_fc1": (h, lf),
            "w_fc2": (lf, h),
        }

    def init_params(self, key: jax.Array) -> dict[str, jax.Array]:
        shapes = self.param_shapes()
        keys = jax.random.split(key, len(shapes))
        out = {}
        for (name, shape), k in zip(sorted(shapes.items()), keys):
            scale = 0.02 if len(shape) > 1 else (1.0 if name.endswith("_g") else 0.0)
            if len(shape) > 1:
                out[name] = jax.random.normal(k, shape, jnp.float32) * scale
            else:
                out[name] = jnp.full(shape, scale, jnp.float32)
        return out

    def flops_fwd(self) -> int:
        """MACs*2 for the per-rank shard forward (matches rust/src/model)."""
        t = self.tokens
        h, d = self.hidden, self.head_dim
        lh, lf = self.local_heads, self.local_ffn
        qkv = 2 * t * h * (3 * h // self.mp)
        scores = 2 * lh * self.batch * self.seq * self.seq * d * 2  # qk^T + pv
        proj = 2 * t * (lh * d) * h
        mlp = 2 * t * h * lf * 2
        return qkv + scores + proj + mlp


def layer_fwd(params: dict[str, jax.Array], x: jax.Array, shard: LayerShard) -> jax.Array:
    """Per-rank forward of one Megatron-sharded transformer layer.

    x: (tokens, hidden) activation (tokens = batch*seq).
    Returns the rank's *partial* layer output (pre-all-reduce residual adds
    are kept local; the all-reduce is a comm event handled in Rust).
    """
    t, h = x.shape
    assert h == shard.hidden and t == shard.tokens
    lh, d = shard.local_heads, shard.head_dim

    y = layernorm(x, params["ln1_g"], params["ln1_b"])
    qkv = matmul_vjp(y, params["w_qkv"])  # (t, 3*h/mp)
    qkv = qkv.reshape(shard.batch, shard.seq, 3, lh, d)
    q = qkv[:, :, 0].transpose(0, 2, 1, 3).reshape(shard.batch * lh, shard.seq, d)
    k = qkv[:, :, 1].transpose(0, 2, 1, 3).reshape(shard.batch * lh, shard.seq, d)
    v = qkv[:, :, 2].transpose(0, 2, 1, 3).reshape(shard.batch * lh, shard.seq, d)
    ctx = attention_vjp(q, k, v)  # (b*lh, s, d)
    ctx = (
        ctx.reshape(shard.batch, lh, shard.seq, d)
        .transpose(0, 2, 1, 3)
        .reshape(t, lh * d)
    )
    attn_out = matmul_vjp(ctx, params["w_proj"])  # (t, h) partial sum
    x = x + attn_out  # residual (local partial; AR is a comm event)

    y = layernorm(x, params["ln2_g"], params["ln2_b"])
    y = matmul_vjp(y, params["w_fc1"])
    y = jax.nn.gelu(y)
    mlp_out = matmul_vjp(y, params["w_fc2"])  # (t, h) partial sum
    return x + mlp_out


def layer_loss(params: dict[str, jax.Array], x: jax.Array, shard: LayerShard) -> jax.Array:
    """Scalar reduction so grad() gives the full bwd graph."""
    return jnp.sum(layer_fwd(params, x, shard) ** 2)


def make_fwd(shard: LayerShard):
    """fn(params..., x) -> (out,) for AOT lowering (flat args, tuple out)."""
    names = sorted(shard.param_shapes())

    def fn(*args):
        params = dict(zip(names, args[:-1]))
        x = args[-1]
        return (layer_fwd(params, x, shard),)

    return fn, names


def make_fwdbwd(shard: LayerShard):
    """fn(params..., x) -> (loss, dparams..., dx) for AOT lowering."""
    names = sorted(shard.param_shapes())

    def fn(*args):
        params = dict(zip(names, args[:-1]))
        x = args[-1]
        loss, grads = jax.value_and_grad(layer_loss, argnums=(0, 1))(
            params, x, shard
        )
        dparams, dx = grads
        return (loss, *[dparams[n] for n in names], dx)

    return fn, names


def example_args(shard: LayerShard) -> list[jax.ShapeDtypeStruct]:
    shapes = shard.param_shapes()
    args = [
        jax.ShapeDtypeStruct(shapes[n], jnp.float32) for n in sorted(shapes)
    ]
    args.append(
        jax.ShapeDtypeStruct((shard.tokens, shard.hidden), jnp.float32)
    )
    return args
