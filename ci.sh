#!/usr/bin/env bash
# Repo-local CI: exactly what .github/workflows/ci.yml runs, for offline
# environments. All dependencies are path-local (rust/vendor/), so
# --offline needs no registry.
set -euo pipefail
cd "$(dirname "$0")"

cargo build --release --offline
cargo bench --no-run --offline
cargo test -q --offline
if cargo fmt --version >/dev/null 2>&1; then
    cargo fmt --check
else
    echo "cargo fmt unavailable; skipping format check"
fi

# rustdoc must build clean: the module docs are the navigable overview
RUSTDOCFLAGS="-D warnings" cargo doc --no-deps --offline

# docs drift: every op the service dispatcher accepts must be documented
# in docs/FORMATS.md. (tests/docs_drift.rs checks the same from the const
# itself; this grep catches drift even when the test file is edited.)
OPS=$(sed -n 's/^pub const OPS: \[&str; [0-9]*\] = \[\(.*\)\];$/\1/p' \
    rust/src/service/protocol.rs | tr -d '" ')
test -n "$OPS" || { echo "could not extract OPS from protocol.rs" >&2; exit 1; }
for op in $(printf '%s' "$OPS" | tr ',' ' '); do
    grep -q "\`$op\`" docs/FORMATS.md || {
        echo "docs drift: op '$op' missing from docs/FORMATS.md" >&2
        exit 1
    }
done
echo "docs-drift check passed"

# smoke: one what-if request piped through the service daemon must come
# back as a well-formed ok-response line
SMOKE_REQ='{"id":"smoke","op":"sweep","model":"bert-large","cluster":{"preset":"a40","nodes":1,"gpus_per_node":4},"sweep":{"global_batch":4,"profile_iters":1}}'
SMOKE_OUT=$(printf '%s\n' "$SMOKE_REQ" | ./target/release/distsim serve --stdio --workers 2)
printf '%s' "$SMOKE_OUT" | grep -q '"ok":true' || {
    echo "service smoke test failed: $SMOKE_OUT" >&2
    exit 1
}
if command -v python3 >/dev/null 2>&1; then
    printf '%s' "$SMOKE_OUT" | python3 -c 'import json,sys; json.loads(sys.stdin.read())'
fi
echo "service smoke test passed"

# scenario smoke: the same sweep under a straggler + elastic-resize
# scenario must answer with per-candidate scenario throughputs and a
# robustness attribution block (the unhappy-path what-if path end-to-end)
SCN_REQ='{"id":"scn-smoke","op":"sweep","model":"bert-large","cluster":{"preset":"a40","nodes":1,"gpus_per_node":4},"sweep":{"global_batch":4,"profile_iters":1,"scenario":{"stragglers":[{"device":0,"factor":1.5}],"resize":{"dp_delta":1,"reshard_us":500}}}}'
SCN_OUT=$(printf '%s\n' "$SCN_REQ" | ./target/release/distsim serve --stdio --workers 2)
printf '%s' "$SCN_OUT" | grep -q '"ok":true' || {
    echo "scenario smoke test failed: $SCN_OUT" >&2
    exit 1
}
for field in '"robustness"' '"scenario_throughput"' '"regret"'; do
    printf '%s' "$SCN_OUT" | grep -q "$field" || {
        echo "scenario smoke: missing $field in $SCN_OUT" >&2
        exit 1
    }
done
echo "scenario smoke test passed"

# metrics smoke: a sweep followed by a `metrics` op must return the
# telemetry registry in both exposition forms, and the Prometheus text
# must round-trip against the structured JSON (values agree)
MET_REQS='{"id":"m-sweep","op":"sweep","model":"bert-large","cluster":{"preset":"a40","nodes":1,"gpus_per_node":4},"sweep":{"global_batch":4,"profile_iters":1}}
{"id":"m","op":"metrics"}'
MET_OUT=$(printf '%s\n' "$MET_REQS" | ./target/release/distsim serve --stdio --workers 2 2>/dev/null)
MET_LINE=$(printf '%s\n' "$MET_OUT" | grep '"op":"metrics"') || {
    echo "metrics smoke: no metrics response in $MET_OUT" >&2
    exit 1
}
for field in '"prometheus"' 'distsim_requests_total' '"sweeps_total"' '"queue_wait_us"' '"deterministic":false'; do
    printf '%s' "$MET_LINE" | grep -q "$field" || {
        echo "metrics smoke: missing $field in $MET_LINE" >&2
        exit 1
    }
done
if command -v python3 >/dev/null 2>&1; then
    printf '%s' "$MET_LINE" | python3 -c '
import json, sys
r = json.loads(sys.stdin.read())["result"]
m, prom = r["metrics"], r["prometheus"]
flat = dict(m["counters"])
flat.update(m["gauges"])
samples = {}
for line in prom.splitlines():
    if line.startswith("#") or not line.strip():
        continue
    name, value = line.rsplit(" ", 1)
    samples[name] = float(value)
for name, value in flat.items():
    assert samples["distsim_" + name] == float(value), (name, value, samples)
for name, h in m["histograms"].items():
    assert samples["distsim_" + name + "_count"] == float(h["count"]), name
    assert samples["distsim_" + name + "_sum"] == float(h["sum_us"]), name
    inf = [b["count"] for b in h["buckets"] if b["le"] == "+Inf"][0]
    assert inf == h["count"], "last cumulative bucket equals count"
assert flat["sweeps_total"] == 1 and flat["requests_total"] == 2
print("prometheus/JSON round-trip consistent:", len(samples), "samples")
'
fi
echo "metrics smoke test passed"

# memory smoke: a capacity-capped sweep must discard infeasible
# candidates as deterministic oom placeholders at the head of the
# pipeline and still crown a feasible winner (the per-rank memory
# model end-to-end)
MEM_REQ='{"id":"mem-smoke","op":"sweep","model":"bert-large","cluster":{"preset":"a40","nodes":1,"gpus_per_node":4,"capacity_bytes":3000000000},"sweep":{"global_batch":4,"profile_iters":1,"recompute_axis":true,"zero_axis":true}}'
MEM_OUT=$(printf '%s\n' "$MEM_REQ" | ./target/release/distsim serve --stdio --workers 2)
printf '%s' "$MEM_OUT" | grep -q '"ok":true' || {
    echo "memory smoke test failed: $MEM_OUT" >&2
    exit 1
}
for field in '"reason":"oom"' '"memory_pruned"' '"peak_bytes"' '"best"'; do
    printf '%s' "$MEM_OUT" | grep -q "$field" || {
        echo "memory smoke: missing $field in $MEM_OUT" >&2
        exit 1
    }
done
echo "memory smoke test passed"

# plan smoke: two identical sweeps through one daemon session must ride
# the compiled-plan cache — the second is a full plan hit — and answer
# with byte-identical response lines (the DESIGN.md §11 contract).
# --workers 1 keeps the compiles/hits accounting deterministic.
PLAN_REQ='{"id":"plan-smoke","op":"sweep","model":"bert-large","cluster":{"preset":"a40","nodes":1,"gpus_per_node":4},"sweep":{"global_batch":4,"profile_iters":1,"prune":true}}'
PLAN_REQS="$PLAN_REQ
$PLAN_REQ
{\"id\":\"plan-stats\",\"op\":\"stats\"}"
PLAN_OUT=$(printf '%s\n' "$PLAN_REQS" | ./target/release/distsim serve --stdio --workers 1 2>/dev/null)
PLAN_LINES=$(printf '%s\n' "$PLAN_OUT" | grep -c '"id":"plan-smoke"')
test "$PLAN_LINES" = 2 || {
    echo "plan smoke: expected 2 sweep responses, got $PLAN_LINES: $PLAN_OUT" >&2
    exit 1
}
FIRST=$(printf '%s\n' "$PLAN_OUT" | grep '"id":"plan-smoke"' | sed -n 1p)
SECOND=$(printf '%s\n' "$PLAN_OUT" | grep '"id":"plan-smoke"' | sed -n 2p)
test "$FIRST" = "$SECOND" || {
    echo "plan smoke: plan-hit response not byte-identical to the compile response" >&2
    echo "first:  $FIRST" >&2
    echo "second: $SECOND" >&2
    exit 1
}
STATS_LINE=$(printf '%s\n' "$PLAN_OUT" | grep '"id":"plan-stats"')
printf '%s' "$STATS_LINE" | grep -q '"plans":{"compiles":1,"hits":1,"partial":0}' || {
    echo "plan smoke: stats must report one compile and one full hit: $STATS_LINE" >&2
    exit 1
}
echo "plan smoke test passed"
