#!/usr/bin/env bash
# Repo-local CI: exactly what .github/workflows/ci.yml runs, for offline
# environments. All dependencies are path-local (rust/vendor/), so
# --offline needs no registry.
set -euo pipefail
cd "$(dirname "$0")"

cargo build --release --offline
cargo bench --no-run --offline
cargo test -q --offline
if cargo fmt --version >/dev/null 2>&1; then
    cargo fmt --check
else
    echo "cargo fmt unavailable; skipping format check"
fi
