# Allow `pytest python/tests/` from the repo root: the build-time package
# lives under python/ (it is not installed; it never runs at sim time).
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "python"))
