//! Bench: ground-truth engine throughput (instructions/second), plus the
//! seed-path vs indexed+scratch comparison at paper scale.
//!
//! The DES engine is the other L3 hot path (§Perf target: >= 1 M
//! events/s): every Fig.-8/9/10 "actual" data point is an engine run, and
//! Table 3's direct-run costing executes the whole grid.
//!
//! The sweep scenarios reproduce ISSUE 2's claim at the paper's
//! large-scale-from-two-node-profiles shape (§5.5): 16-, 64- and 256-rank
//! GPT-style iterations, comparing
//!
//! * **seed path** — fresh engine state per iteration plus the seed's
//!   naive rescan/clone/sort Timeline queries (`testutil::naive`), vs
//! * **indexed path** — `ExecScratch` reuse plus the columnar Timeline's
//!   O(1)/borrowed-slice queries,
//!
//! with asserted value equivalence (the two paths must sum to bit-equal
//! metric totals). Results are printed and written machine-readably to
//! `BENCH_engine.json` for CI trend tracking.

use std::time::Instant;

use distsim::cluster::ClusterSpec;
use distsim::config::{Json, RunConfig};
use distsim::engine::{ExecScratch, GroundTruth};
use distsim::strategy::Strategy;
use distsim::testutil::naive;

fn cluster_for(world: usize) -> ClusterSpec {
    if world > 16 {
        ClusterSpec::a100_pod(world.div_ceil(8))
    } else {
        ClusterSpec::a40_cluster(4, 4)
    }
}

fn bench_one(model: &str, s: &str, micro_batches: usize) {
    let strategy = Strategy::parse(s).unwrap();
    let mut cfg = RunConfig::new(model, strategy, cluster_for(strategy.world_size()));
    cfg.micro_batches = micro_batches;
    let gt = GroundTruth::prepare(&cfg).unwrap();
    let instrs = gt.prog.total_instrs();

    // warmup + measure (scratch path: the post-ISSUE-2 default)
    let mut scratch = ExecScratch::new();
    let warm = gt.run_iteration_with_scratch(0, &mut scratch);
    scratch.recycle(warm);
    let reps = 20;
    let t0 = Instant::now();
    for i in 0..reps {
        let tl = gt.run_iteration_with_scratch(i, &mut scratch);
        scratch.recycle(tl);
    }
    let secs = t0.elapsed().as_secs_f64() / reps as f64;
    println!(
        "{model:<12} {s:<8} m={micro_batches:<3} {instrs:>7} instrs  \
         {:>9.1} us/iter  {:>8.2} M instr/s",
        secs * 1e6,
        instrs as f64 / secs / 1e6
    );
}

struct SweepScenario {
    model: &'static str,
    strategy: &'static str,
    micro_batches: usize,
    reps: u64,
}

struct SweepResult {
    ranks: usize,
    scenario: SweepScenario,
    instrs: usize,
    seed_iters_per_sec: f64,
    indexed_iters_per_sec: f64,
}

impl SweepResult {
    fn speedup(&self) -> f64 {
        self.indexed_iters_per_sec / self.seed_iters_per_sec
    }
}

/// The per-iteration metric reads a sweep performs: batch time plus every
/// device's busy total. Summing them gives a single checksum the two
/// paths must agree on bit-exactly.
fn seed_metrics_checksum(tl: &distsim::timeline::Timeline) -> f64 {
    let mut acc = naive::batch_time_us(tl);
    for d in 0..tl.n_devices {
        acc += naive::busy_us(tl, d);
    }
    acc
}

fn indexed_metrics_checksum(tl: &distsim::timeline::Timeline) -> f64 {
    let mut acc = tl.batch_time_us();
    for d in 0..tl.n_devices {
        acc += tl.busy_us(d);
    }
    acc
}

fn bench_sweep_scenario(sc: SweepScenario) -> SweepResult {
    let strategy = Strategy::parse(sc.strategy).unwrap();
    let ranks = strategy.world_size();
    let mut cfg = RunConfig::new(sc.model, strategy, cluster_for(ranks));
    cfg.micro_batches = sc.micro_batches;
    let gt = GroundTruth::prepare(&cfg).unwrap();
    let instrs = gt.prog.total_instrs();

    // warmup both paths and assert span-level equivalence up front
    let mut scratch = ExecScratch::new();
    let fresh = gt.run_iteration(0);
    let reused = gt.run_iteration_with_scratch(0, &mut scratch);
    assert_eq!(fresh.spans(), reused.spans(), "{}: paths diverge", sc.strategy);
    scratch.recycle(reused);

    // seed path: fresh engine allocations + naive rescan queries
    let t0 = Instant::now();
    let mut seed_sum = 0.0;
    for i in 0..sc.reps {
        let tl = gt.run_iteration(i);
        seed_sum += seed_metrics_checksum(&tl);
    }
    let seed_secs = t0.elapsed().as_secs_f64();

    // indexed path: scratch reuse + O(1)/borrowed-slice queries
    let t1 = Instant::now();
    let mut indexed_sum = 0.0;
    for i in 0..sc.reps {
        let tl = gt.run_iteration_with_scratch(i, &mut scratch);
        indexed_sum += indexed_metrics_checksum(&tl);
        scratch.recycle(tl);
    }
    let indexed_secs = t1.elapsed().as_secs_f64();

    assert_eq!(
        seed_sum, indexed_sum,
        "{}: metric values must be bit-identical across paths",
        sc.strategy
    );

    let reps = sc.reps as f64;
    SweepResult {
        ranks,
        scenario: sc,
        instrs,
        seed_iters_per_sec: reps / seed_secs,
        indexed_iters_per_sec: reps / indexed_secs,
    }
}

fn write_bench_json(results: &[SweepResult]) -> std::io::Result<()> {
    let scenarios: Vec<Json> = results
        .iter()
        .map(|r| {
            Json::obj(vec![
                ("ranks", Json::num(r.ranks as f64)),
                ("model", Json::str(r.scenario.model)),
                ("strategy", Json::str(r.scenario.strategy)),
                ("micro_batches", Json::num(r.scenario.micro_batches as f64)),
                ("reps", Json::num(r.scenario.reps as f64)),
                ("instrs_per_iter", Json::num(r.instrs as f64)),
                ("seed_iters_per_sec", Json::num(r.seed_iters_per_sec)),
                ("indexed_iters_per_sec", Json::num(r.indexed_iters_per_sec)),
                ("speedup", Json::num(r.speedup())),
            ])
        })
        .collect();
    let doc = Json::obj(vec![
        ("bench", Json::str("engine_throughput")),
        ("scenarios", Json::Arr(scenarios)),
    ]);
    std::fs::write("BENCH_engine.json", doc.to_string())
}

fn main() {
    println!("# bench engine: DES throughput\n");
    bench_one("bert-large", "1M1P1D", 1);
    bench_one("bert-large", "2M2P2D", 4);
    bench_one("bert-large", "2M4P2D", 8);
    bench_one("bert-large", "1M4P4D", 16);
    bench_one("t5", "2M4P2D", 16);
    bench_one("gpt-145b", "8M16P1D", 16);

    println!("\n# bench engine: seed path vs indexed+scratch (GPT-style sweep scenarios)\n");
    let results: Vec<SweepResult> = [
        SweepScenario { model: "bert-large", strategy: "2M4P2D", micro_batches: 8, reps: 20 },
        SweepScenario { model: "gpt-145b", strategy: "4M8P2D", micro_batches: 8, reps: 6 },
        SweepScenario { model: "gpt-145b", strategy: "8M16P2D", micro_batches: 16, reps: 3 },
    ]
    .into_iter()
    .map(bench_sweep_scenario)
    .collect();

    println!(
        "{:<6} {:<12} {:<8} {:>10} {:>14} {:>14} {:>9}",
        "ranks", "model", "strat", "instrs", "seed it/s", "indexed it/s", "speedup"
    );
    for r in &results {
        println!(
            "{:<6} {:<12} {:<8} {:>10} {:>14.2} {:>14.2} {:>8.2}x",
            r.ranks,
            r.scenario.model,
            r.scenario.strategy,
            r.instrs,
            r.seed_iters_per_sec,
            r.indexed_iters_per_sec,
            r.speedup()
        );
    }

    // write the artifact before asserting the win, so one noisy run
    // still leaves its numbers behind for CI trend tracking
    write_bench_json(&results).expect("write BENCH_engine.json");
    println!("\nwrote BENCH_engine.json");

    for r in &results {
        assert!(
            r.speedup() > 1.0,
            "{} ranks: indexed+scratch path must beat the seed path ({}x)",
            r.ranks,
            r.speedup()
        );
    }
}
