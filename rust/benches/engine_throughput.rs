//! Bench: ground-truth engine throughput (instructions/second).
//!
//! The DES engine is the other L3 hot path (§Perf target: >= 1 M
//! events/s): every Fig.-8/9/10 "actual" data point is an engine run, and
//! Table 3's direct-run costing executes the whole grid.

use std::time::Instant;

use distsim::cluster::ClusterSpec;
use distsim::config::RunConfig;
use distsim::engine::GroundTruth;
use distsim::strategy::Strategy;

fn bench_one(model: &str, s: &str, micro_batches: usize) {
    let strategy = Strategy::parse(s).unwrap();
    let cluster = if strategy.world_size() > 16 {
        ClusterSpec::a100_pod(strategy.world_size().div_ceil(8))
    } else {
        ClusterSpec::a40_cluster(4, 4)
    };
    let mut cfg = RunConfig::new(model, strategy, cluster);
    cfg.micro_batches = micro_batches;
    let gt = GroundTruth::prepare(&cfg).unwrap();
    let instrs = gt.prog.total_instrs();

    // warmup + measure
    let _ = gt.run_iteration(0);
    let reps = 20;
    let t0 = Instant::now();
    for i in 0..reps {
        let _ = gt.run_iteration(i);
    }
    let secs = t0.elapsed().as_secs_f64() / reps as f64;
    println!(
        "{model:<12} {s:<8} m={micro_batches:<3} {instrs:>7} instrs  {:>9.1} us/iter  {:>8.2} M instr/s",
        secs * 1e6,
        instrs as f64 / secs / 1e6
    );
}

fn main() {
    println!("# bench engine: DES throughput\n");
    bench_one("bert-large", "1M1P1D", 1);
    bench_one("bert-large", "2M2P2D", 4);
    bench_one("bert-large", "2M4P2D", 8);
    bench_one("bert-large", "1M4P4D", 16);
    bench_one("t5", "2M4P2D", 16);
    bench_one("gpt-145b", "8M16P1D", 16);
}
