//! Bench: cold sweep vs a warm compiled-plan sweep (ISSUE 10) on a
//! 64-rank mixed fleet.
//!
//! The cold path re-plans from scratch — candidate enumeration, memory
//! verdicts, analytical bounds, event interning — on every sweep; the
//! warm path compiles a [`SweepPlan`] once and re-launches it, paying
//! only execution. Both use fresh `ProfileCache`s per rep so the delta
//! is the planning phase, not profile-measurement sharing. The winners
//! are asserted bit-equal (the DESIGN.md §11 byte-identity contract)
//! and relaunching the plan on the identical request must be a full
//! hit. Emits a machine-readable `BENCH_plan.json` line (see
//! docs/FORMATS.md §3).

use std::sync::Arc;
use std::time::Instant;

use distsim::cluster::ClusterSpec;
use distsim::config::Json;
use distsim::cost::CostBook;
use distsim::model::zoo;
use distsim::search::{ProfileCache, SearchEngine, SweepConfig, SweepPlan, SweepReport};

fn fnv1a64(bytes: &[u8]) -> u64 {
    let mut h = 0xcbf29ce484222325u64;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x100000001b3);
    }
    h
}

/// Canonical digest of the winning candidate (same recipe as the
/// placement bench): bit-equal checksums mean bit-equal winners.
fn best_checksum(rep: &SweepReport) -> String {
    let mut s = String::new();
    if let Some(b) = rep.best() {
        s.push_str(&format!(
            "{}/{}/{}/mbs{}x{}/tp{:016x}",
            b.strategy.notation(),
            b.schedule.name(),
            b.placement.name(),
            b.micro_batch_size,
            b.micro_batches,
            b.throughput.to_bits()
        ));
        if let Some(t) = rep.winning_table() {
            s.push_str(&format!("/table{t:?}"));
        }
    }
    format!("{:016x}", fnv1a64(s.as_bytes()))
}

fn main() {
    let reps = 3;
    let model = zoo::bert_large();
    let cluster = ClusterSpec::mixed_a40_a10(8, 8);
    let ranks = cluster.total_devices();
    let book = CostBook::default();
    let cfg = SweepConfig {
        global_batch: 16,
        profile_iters: 1,
        threads: std::thread::available_parallelism()
            .map(|n| n.get())
            .unwrap_or(4)
            .min(8),
        placement_axis: true,
        prune: true,
        ..SweepConfig::default()
    };

    println!("# {ranks}-rank mixed fleet, cold vs warm compiled plan ({reps} reps)");

    // cold: every rep re-plans from scratch
    let mut cold_wall = f64::INFINITY;
    let mut cold_checksum = String::new();
    for _ in 0..reps {
        let t0 = Instant::now();
        let rep = SearchEngine::with_book(
            &model,
            &cluster,
            book.clone(),
            cfg.clone(),
            Arc::new(ProfileCache::new()),
        )
        .sweep();
        cold_wall = cold_wall.min(t0.elapsed().as_secs_f64());
        cold_checksum = best_checksum(&rep);
    }

    // warm: compile once, every rep sweeps through the shared plan
    let t0 = Instant::now();
    let plan = Arc::new(SweepPlan::compile(&model, &cluster, &book, &cfg));
    let compile_wall = t0.elapsed().as_secs_f64();
    let mut warm_wall = f64::INFINITY;
    let mut warm_checksum = String::new();
    for _ in 0..reps {
        let t0 = Instant::now();
        let rep = SearchEngine::with_book(
            &model,
            &cluster,
            book.clone(),
            cfg.clone(),
            Arc::new(ProfileCache::new()),
        )
        .with_plan(plan.clone())
        .sweep();
        warm_wall = warm_wall.min(t0.elapsed().as_secs_f64());
        warm_checksum = best_checksum(&rep);
    }

    let identical = cold_checksum == warm_checksum;
    assert!(
        identical,
        "plan-cached sweep crowned a different winner than the cold sweep \
         (cold {cold_checksum}, warm {warm_checksum})"
    );
    let (_, reuse) = plan.launch(&model, &cluster, &book, &cfg, None);
    assert!(
        reuse.full_hit(),
        "relaunching the plan on the identical request must reuse every \
         component: {reuse:?}"
    );

    let speedup = cold_wall / warm_wall;
    println!(
        "cold: {cold_wall:.3} s   warm: {warm_wall:.3} s   speedup {speedup:.2}x \
         (one-time compile {compile_wall:.3} s, {} candidates, {} events, \
         checksum {cold_checksum})",
        plan.candidate_count(),
        plan.event_count()
    );

    println!(
        "BENCH_plan.json {}",
        Json::obj(vec![
            ("bench", Json::str("plan_reuse")),
            ("ranks", Json::num(ranks as f64)),
            ("model", Json::str("bert-large")),
            ("candidates", Json::num(plan.candidate_count() as f64)),
            ("events", Json::num(plan.event_count() as f64)),
            ("cold_seconds", Json::num(cold_wall)),
            ("warm_seconds", Json::num(warm_wall)),
            ("speedup", Json::num(speedup)),
            ("compile_seconds", Json::num(compile_wall)),
            ("full_hit", Json::Bool(reuse.full_hit())),
            ("best_checksum", Json::str(&cold_checksum)),
            ("identical", Json::Bool(identical)),
        ])
    );
}
