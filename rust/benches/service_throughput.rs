//! Bench: what-if service throughput — concurrent requests over one shared
//! profile cache vs the same request stream served serially.
//!
//! Two scenarios:
//!
//! * **single stream** — a mixed NDJSON session (distinct sweeps +
//!   repeats) through the in-process service core at worker counts 1 / N,
//!   asserting the response streams are byte-identical (the service
//!   determinism contract) and reporting requests/second plus the cache's
//!   cross-request dedup.
//! * **saturation** — the same dialogue fanned out over 8 concurrent TCP
//!   connections at worker counts 1 / N, asserting every *connection's*
//!   stream is byte-identical across worker counts (the per-connection
//!   determinism contract of ISSUE 6) and reporting aggregate
//!   requests/second under multi-tenant load.
//!
//! Emits a machine-readable BENCH_service.json line like the engine bench.

use std::collections::BTreeMap;
use std::io::{BufRead, BufReader, Cursor, Write};
use std::net::{TcpListener, TcpStream};
use std::time::Instant;

use distsim::config::Json;
use distsim::service::{serve_ndjson, serve_tcp, ServeOpts};

fn request(id: &str, model: &str, batch: usize) -> String {
    format!(
        r#"{{"id":"{id}","op":"sweep","model":"{model}","cluster":{{"preset":"a10","nodes":4,"gpus_per_node":4}},"sweep":{{"global_batch":{batch},"profile_iters":1}}}}"#
    )
}

const SHAPES: [(&str, usize); 3] = [("bert-large", 16), ("bert-exlarge", 16), ("bert-large", 32)];

fn session() -> String {
    // 12 requests: 3 distinct shapes x 4 repeats each, interleaved — the
    // shape of a real what-if dialogue (ask, tweak, re-ask)
    (0..12)
        .map(|i| {
            let (m, b) = SHAPES[i % SHAPES.len()];
            request(&format!("r{i}"), m, b)
        })
        .collect::<Vec<_>>()
        .join("\n")
}

fn run(workers: usize, input: &str) -> (String, f64) {
    let mut out = Vec::new();
    let opts = ServeOpts {
        workers,
        cache_dir: None,
        ..ServeOpts::default()
    };
    let t0 = Instant::now();
    serve_ndjson(Cursor::new(input.to_string()), &mut out, &opts);
    (String::from_utf8(out).unwrap(), t0.elapsed().as_secs_f64())
}

const SAT_CONNS: usize = 8;
const SAT_REQS_PER_CONN: usize = 6;

/// Fan the dialogue out over `SAT_CONNS` concurrent TCP connections and
/// collect each connection's response stream. Returns (per-connection
/// streams, wall seconds).
fn run_saturation(workers: usize) -> (BTreeMap<String, Vec<String>>, f64) {
    let listener = TcpListener::bind("127.0.0.1:0").expect("bind");
    let addr = listener.local_addr().expect("addr");
    let opts = ServeOpts {
        workers,
        ..ServeOpts::default()
    };
    let daemon = std::thread::spawn(move || serve_tcp(listener, &opts).expect("serve_tcp"));

    let t0 = Instant::now();
    let mut handles = Vec::new();
    for c in 0..SAT_CONNS {
        handles.push(std::thread::spawn(move || {
            let mut stream = TcpStream::connect(addr).expect("connect");
            for i in 0..SAT_REQS_PER_CONN {
                // each connection walks the shapes in its own order, with
                // an in-connection repeat so per-conn cache re-scoping is
                // exercised too
                let (m, b) = SHAPES[(c + i) % SHAPES.len()];
                writeln!(stream, "{}", request(&format!("c{c}-r{i}"), m, b)).expect("send");
            }
            stream.flush().expect("flush");
            let reader = BufReader::new(stream.try_clone().expect("clone"));
            let lines: Vec<String> = reader
                .lines()
                .take(SAT_REQS_PER_CONN)
                .map(|l| l.expect("read"))
                .collect();
            assert_eq!(lines.len(), SAT_REQS_PER_CONN, "short stream on conn {c}");
            (format!("c{c}"), lines)
        }));
    }
    let mut by_conn = BTreeMap::new();
    for h in handles {
        let (tag, lines) = h.join().expect("client");
        by_conn.insert(tag, lines);
    }
    let wall = t0.elapsed().as_secs_f64();

    let mut ctl = TcpStream::connect(addr).expect("connect ctl");
    writeln!(ctl, r#"{{"op":"shutdown"}}"#).expect("send shutdown");
    ctl.flush().expect("flush");
    daemon.join().expect("daemon");
    (by_conn, wall)
}

fn main() {
    let input = session();
    let n_requests = input.lines().count();
    let parallel_workers = std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(4)
        .min(8);

    println!("# bench service: {n_requests} what-if requests, 3 distinct shapes\n");
    let (serial_out, serial_wall) = run(1, &input);
    let (parallel_out, parallel_wall) = run(parallel_workers, &input);

    assert_eq!(
        serial_out, parallel_out,
        "service responses must be bit-identical for any worker count"
    );

    // pull cache accounting from the first and last responses
    let first = Json::parse(serial_out.lines().next().unwrap()).unwrap();
    let last = Json::parse(serial_out.lines().last().unwrap()).unwrap();
    let misses = |j: &Json| {
        j.get("result")
            .and_then(|r| r.get("cache"))
            .and_then(|c| c.get("misses"))
            .and_then(Json::as_usize)
            .unwrap_or(0)
    };
    println!("1 worker:          {serial_wall:.3} s  ({:.1} req/s)", n_requests as f64 / serial_wall);
    println!(
        "{parallel_workers} workers:         {parallel_wall:.3} s  ({:.1} req/s)",
        n_requests as f64 / parallel_wall
    );
    println!(
        "wall-clock improvement: {:.2}x   responses identical: true",
        serial_wall / parallel_wall
    );
    println!(
        "cross-request dedup: first request {} misses, last request {} misses",
        misses(&first),
        misses(&last)
    );
    assert_eq!(misses(&last), 0, "repeats must be full cache hits");

    // multi-connection saturation: same worker counts, 8 concurrent
    // tenants, per-connection byte-identity
    let sat_requests = SAT_CONNS * SAT_REQS_PER_CONN;
    println!(
        "\n# saturation: {SAT_CONNS} TCP connections x {SAT_REQS_PER_CONN} requests\n"
    );
    let (sat_serial, sat_serial_wall) = run_saturation(1);
    let (sat_parallel, sat_parallel_wall) = run_saturation(parallel_workers);
    assert_eq!(
        sat_serial, sat_parallel,
        "every connection's stream must be bit-identical for any worker count"
    );
    println!(
        "1 worker:          {sat_serial_wall:.3} s  ({:.1} req/s aggregate)",
        sat_requests as f64 / sat_serial_wall
    );
    println!(
        "{parallel_workers} workers:         {sat_parallel_wall:.3} s  ({:.1} req/s aggregate)",
        sat_requests as f64 / sat_parallel_wall
    );
    println!(
        "wall-clock improvement: {:.2}x   per-connection streams identical: true",
        sat_serial_wall / sat_parallel_wall
    );

    println!(
        "BENCH_service.json {}",
        Json::obj(vec![
            ("requests", Json::num(n_requests as f64)),
            ("serial_seconds", Json::num(serial_wall)),
            ("parallel_seconds", Json::num(parallel_wall)),
            ("workers", Json::num(parallel_workers as f64)),
            (
                "speedup",
                Json::num(serial_wall / parallel_wall)
            ),
            ("identical", Json::Bool(true)),
            (
                "saturation",
                Json::obj(vec![
                    ("connections", Json::num(SAT_CONNS as f64)),
                    ("requests", Json::num(sat_requests as f64)),
                    ("serial_seconds", Json::num(sat_serial_wall)),
                    ("parallel_seconds", Json::num(sat_parallel_wall)),
                    ("speedup", Json::num(sat_serial_wall / sat_parallel_wall)),
                    ("per_connection_identical", Json::Bool(true)),
                ])
            ),
        ])
    );
}
