//! Bench: what-if service throughput — concurrent requests over one shared
//! profile cache vs the same request stream served serially.
//!
//! Feeds a mixed NDJSON session (distinct sweeps + repeats) through the
//! in-process service core at worker counts 1 / N, asserts the response
//! streams are byte-identical (the service determinism contract), and
//! reports requests/second plus the cache's cross-request dedup. Emits a
//! machine-readable BENCH_service.json line like the engine bench.

use std::io::Cursor;
use std::time::Instant;

use distsim::config::Json;
use distsim::service::{serve_ndjson, ServeOpts};

fn request(id: usize, model: &str, batch: usize) -> String {
    format!(
        r#"{{"id":"r{id}","op":"sweep","model":"{model}","cluster":{{"preset":"a10","nodes":4,"gpus_per_node":4}},"sweep":{{"global_batch":{batch},"profile_iters":1}}}}"#
    )
}

fn session() -> String {
    // 12 requests: 3 distinct shapes x 4 repeats each, interleaved — the
    // shape of a real what-if dialogue (ask, tweak, re-ask)
    let shapes = [("bert-large", 16), ("bert-exlarge", 16), ("bert-large", 32)];
    (0..12)
        .map(|i| {
            let (m, b) = shapes[i % shapes.len()];
            request(i, m, b)
        })
        .collect::<Vec<_>>()
        .join("\n")
}

fn run(workers: usize, input: &str) -> (String, f64) {
    let mut out = Vec::new();
    let opts = ServeOpts {
        workers,
        cache_dir: None,
        ..ServeOpts::default()
    };
    let t0 = Instant::now();
    serve_ndjson(Cursor::new(input.to_string()), &mut out, &opts);
    (String::from_utf8(out).unwrap(), t0.elapsed().as_secs_f64())
}

fn main() {
    let input = session();
    let n_requests = input.lines().count();
    let parallel_workers = std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(4)
        .min(8);

    println!("# bench service: {n_requests} what-if requests, 3 distinct shapes\n");
    let (serial_out, serial_wall) = run(1, &input);
    let (parallel_out, parallel_wall) = run(parallel_workers, &input);

    assert_eq!(
        serial_out, parallel_out,
        "service responses must be bit-identical for any worker count"
    );

    // pull cache accounting from the first and last responses
    let first = Json::parse(serial_out.lines().next().unwrap()).unwrap();
    let last = Json::parse(serial_out.lines().last().unwrap()).unwrap();
    let misses = |j: &Json| {
        j.get("result")
            .and_then(|r| r.get("cache"))
            .and_then(|c| c.get("misses"))
            .and_then(Json::as_usize)
            .unwrap_or(0)
    };
    println!("1 worker:          {serial_wall:.3} s  ({:.1} req/s)", n_requests as f64 / serial_wall);
    println!(
        "{parallel_workers} workers:         {parallel_wall:.3} s  ({:.1} req/s)",
        n_requests as f64 / parallel_wall
    );
    println!(
        "wall-clock improvement: {:.2}x   responses identical: true",
        serial_wall / parallel_wall
    );
    println!(
        "cross-request dedup: first request {} misses, last request {} misses",
        misses(&first),
        misses(&last)
    );
    assert_eq!(misses(&last), 0, "repeats must be full cache hits");

    println!(
        "BENCH_service.json {}",
        Json::obj(vec![
            ("requests", Json::num(n_requests as f64)),
            ("serial_seconds", Json::num(serial_wall)),
            ("parallel_seconds", Json::num(parallel_wall)),
            ("workers", Json::num(parallel_workers as f64)),
            (
                "speedup",
                Json::num(serial_wall / parallel_wall)
            ),
            ("identical", Json::Bool(true)),
        ])
    );
}
