//! Bench: what-if service throughput — concurrent requests over one shared
//! profile cache vs the same request stream served serially.
//!
//! Two scenarios:
//!
//! * **single stream** — a mixed NDJSON session (distinct sweeps +
//!   repeats) through the in-process service core at worker counts 1 / N,
//!   asserting the response streams are byte-identical (the service
//!   determinism contract) and reporting requests/second plus the cache's
//!   cross-request dedup.
//! * **saturation** — the same dialogue fanned out over 8 concurrent TCP
//!   connections at worker counts 1 / N, asserting every *connection's*
//!   stream is byte-identical across worker counts (the per-connection
//!   determinism contract of ISSUE 6) and reporting aggregate
//!   requests/second under multi-tenant load.
//! * **telemetry overhead** — the single-stream dialogue bare vs fully
//!   instrumented (`sweep.trace: true` everywhere, `--trace-dir`, debug
//!   logger at a warn threshold), min-of-reps, asserting the tax stays
//!   under 3% (plus timer slack) and that the deterministic portion of
//!   the instrumented stream is byte-identical to the bare one (the
//!   out-of-band timing rule, DESIGN.md §9).
//!
//! Emits a machine-readable BENCH_service.json line like the engine bench.

use std::collections::BTreeMap;
use std::io::{BufRead, BufReader, Cursor, Write};
use std::net::{TcpListener, TcpStream};
use std::time::Instant;

use distsim::config::Json;
use distsim::service::{serve_ndjson, serve_tcp, ServeOpts};
use distsim::telemetry::LogLevel;

fn request(id: &str, model: &str, batch: usize) -> String {
    format!(
        r#"{{"id":"{id}","op":"sweep","model":"{model}","cluster":{{"preset":"a10","nodes":4,"gpus_per_node":4}},"sweep":{{"global_batch":{batch},"profile_iters":1}}}}"#
    )
}

const SHAPES: [(&str, usize); 3] = [("bert-large", 16), ("bert-exlarge", 16), ("bert-large", 32)];

fn session() -> String {
    // 12 requests: 3 distinct shapes x 4 repeats each, interleaved — the
    // shape of a real what-if dialogue (ask, tweak, re-ask)
    (0..12)
        .map(|i| {
            let (m, b) = SHAPES[i % SHAPES.len()];
            request(&format!("r{i}"), m, b)
        })
        .collect::<Vec<_>>()
        .join("\n")
}

fn run(workers: usize, input: &str) -> (String, f64) {
    let mut out = Vec::new();
    let opts = ServeOpts {
        workers,
        cache_dir: None,
        ..ServeOpts::default()
    };
    let t0 = Instant::now();
    serve_ndjson(Cursor::new(input.to_string()), &mut out, &opts);
    (String::from_utf8(out).unwrap(), t0.elapsed().as_secs_f64())
}

const SAT_CONNS: usize = 8;
const SAT_REQS_PER_CONN: usize = 6;

/// Fan the dialogue out over `SAT_CONNS` concurrent TCP connections and
/// collect each connection's response stream. Returns (per-connection
/// streams, wall seconds).
fn run_saturation(workers: usize) -> (BTreeMap<String, Vec<String>>, f64) {
    let listener = TcpListener::bind("127.0.0.1:0").expect("bind");
    let addr = listener.local_addr().expect("addr");
    let opts = ServeOpts {
        workers,
        ..ServeOpts::default()
    };
    let daemon = std::thread::spawn(move || serve_tcp(listener, &opts).expect("serve_tcp"));

    let t0 = Instant::now();
    let mut handles = Vec::new();
    for c in 0..SAT_CONNS {
        handles.push(std::thread::spawn(move || {
            let mut stream = TcpStream::connect(addr).expect("connect");
            for i in 0..SAT_REQS_PER_CONN {
                // each connection walks the shapes in its own order, with
                // an in-connection repeat so per-conn cache re-scoping is
                // exercised too
                let (m, b) = SHAPES[(c + i) % SHAPES.len()];
                writeln!(stream, "{}", request(&format!("c{c}-r{i}"), m, b)).expect("send");
            }
            stream.flush().expect("flush");
            let reader = BufReader::new(stream.try_clone().expect("clone"));
            let lines: Vec<String> = reader
                .lines()
                .take(SAT_REQS_PER_CONN)
                .map(|l| l.expect("read"))
                .collect();
            assert_eq!(lines.len(), SAT_REQS_PER_CONN, "short stream on conn {c}");
            (format!("c{c}"), lines)
        }));
    }
    let mut by_conn = BTreeMap::new();
    for h in handles {
        let (tag, lines) = h.join().expect("client");
        by_conn.insert(tag, lines);
    }
    let wall = t0.elapsed().as_secs_f64();

    let mut ctl = TcpStream::connect(addr).expect("connect ctl");
    writeln!(ctl, r#"{{"op":"shutdown"}}"#).expect("send shutdown");
    ctl.flush().expect("flush");
    daemon.join().expect("daemon");
    (by_conn, wall)
}

/// Run the dialogue `reps` times under `opts`, returning the fastest
/// wall time and the (identical-across-reps) response stream.
fn timed_best(input: &str, opts: &ServeOpts, reps: usize) -> (String, f64) {
    let mut best = f64::INFINITY;
    let mut stream = String::new();
    for _ in 0..reps {
        let mut out = Vec::new();
        let t0 = Instant::now();
        serve_ndjson(Cursor::new(input.to_string()), &mut out, opts);
        best = best.min(t0.elapsed().as_secs_f64());
        stream = String::from_utf8(out).unwrap();
    }
    (stream, best)
}

/// Strip the gated `trace` block from every response line, leaving the
/// deterministic payload for byte-comparison against an untraced run.
fn strip_trace(stream: &str) -> String {
    stream
        .lines()
        .map(|line| {
            let j = Json::parse(line).expect("response parses");
            let Some(result) = j.get("result").and_then(Json::as_obj) else {
                return line.to_string();
            };
            if !result.contains_key("trace") {
                return line.to_string();
            }
            let kept: Vec<(&str, Json)> = result
                .iter()
                .filter(|(k, _)| k.as_str() != "trace")
                .map(|(k, v)| (k.as_str(), v.clone()))
                .collect();
            Json::obj(vec![
                ("id", j.get("id").cloned().unwrap_or(Json::Null)),
                ("ok", j.get("ok").cloned().unwrap_or(Json::Null)),
                ("result", Json::obj(kept)),
            ])
            .to_string()
        })
        .collect::<Vec<_>>()
        .join("
")
}

const TELEMETRY_REPS: usize = 3;
const TELEMETRY_OVERHEAD_BOUND: f64 = 1.03;
const TELEMETRY_SLACK_SECONDS: f64 = 0.05;

fn main() {
    let input = session();
    let n_requests = input.lines().count();
    let parallel_workers = std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(4)
        .min(8);

    println!("# bench service: {n_requests} what-if requests, 3 distinct shapes\n");
    let (serial_out, serial_wall) = run(1, &input);
    let (parallel_out, parallel_wall) = run(parallel_workers, &input);

    assert_eq!(
        serial_out, parallel_out,
        "service responses must be bit-identical for any worker count"
    );

    // pull cache accounting from the first and last responses
    let first = Json::parse(serial_out.lines().next().unwrap()).unwrap();
    let last = Json::parse(serial_out.lines().last().unwrap()).unwrap();
    let misses = |j: &Json| {
        j.get("result")
            .and_then(|r| r.get("cache"))
            .and_then(|c| c.get("misses"))
            .and_then(Json::as_usize)
            .unwrap_or(0)
    };
    println!("1 worker:          {serial_wall:.3} s  ({:.1} req/s)", n_requests as f64 / serial_wall);
    println!(
        "{parallel_workers} workers:         {parallel_wall:.3} s  ({:.1} req/s)",
        n_requests as f64 / parallel_wall
    );
    println!(
        "wall-clock improvement: {:.2}x   responses identical: true",
        serial_wall / parallel_wall
    );
    println!(
        "cross-request dedup: first request {} misses, last request {} misses",
        misses(&first),
        misses(&last)
    );
    assert_eq!(misses(&last), 0, "repeats must be full cache hits");

    // multi-connection saturation: same worker counts, 8 concurrent
    // tenants, per-connection byte-identity
    let sat_requests = SAT_CONNS * SAT_REQS_PER_CONN;
    println!(
        "\n# saturation: {SAT_CONNS} TCP connections x {SAT_REQS_PER_CONN} requests\n"
    );
    let (sat_serial, sat_serial_wall) = run_saturation(1);
    let (sat_parallel, sat_parallel_wall) = run_saturation(parallel_workers);
    assert_eq!(
        sat_serial, sat_parallel,
        "every connection's stream must be bit-identical for any worker count"
    );
    println!(
        "1 worker:          {sat_serial_wall:.3} s  ({:.1} req/s aggregate)",
        sat_requests as f64 / sat_serial_wall
    );
    println!(
        "{parallel_workers} workers:         {sat_parallel_wall:.3} s  ({:.1} req/s aggregate)",
        sat_requests as f64 / sat_parallel_wall
    );
    println!(
        "wall-clock improvement: {:.2}x   per-connection streams identical: true",
        sat_serial_wall / sat_parallel_wall
    );

    // telemetry overhead: the same dialogue bare vs fully instrumented
    let trace_dir = std::env::temp_dir().join(format!(
        "distsim_bench_traces_{}",
        std::process::id()
    ));
    let _ = std::fs::remove_dir_all(&trace_dir);
    let traced_input = input.replace(
        r#""profile_iters":1"#,
        r#""profile_iters":1,"trace":true"#,
    );
    println!("
# telemetry: bare vs instrumented (trace blocks + --trace-dir + logger)
");
    let (off_stream, off_seconds) = timed_best(
        &input,
        &ServeOpts {
            workers: parallel_workers,
            ..ServeOpts::default()
        },
        TELEMETRY_REPS,
    );
    let (on_stream, on_seconds) = timed_best(
        &traced_input,
        &ServeOpts {
            workers: parallel_workers,
            trace_dir: Some(trace_dir.clone()),
            // warn threshold: the logger's level check runs on every
            // event site but nothing prints into the timing
            log_level: LogLevel::Warn,
            ..ServeOpts::default()
        },
        TELEMETRY_REPS,
    );
    let _ = std::fs::remove_dir_all(&trace_dir);
    let telemetry_identical = strip_trace(&on_stream) == off_stream;
    assert!(
        telemetry_identical,
        "instrumented responses minus their trace blocks must be          byte-identical to the bare stream"
    );
    let overhead_ratio = on_seconds / off_seconds;
    let within_bound =
        on_seconds <= off_seconds * TELEMETRY_OVERHEAD_BOUND + TELEMETRY_SLACK_SECONDS;
    println!("telemetry off:     {off_seconds:.3} s (best of {TELEMETRY_REPS})");
    println!("telemetry on:      {on_seconds:.3} s (best of {TELEMETRY_REPS})");
    println!(
        "overhead: {overhead_ratio:.3}x   within {TELEMETRY_OVERHEAD_BOUND:.2}x bound:          {within_bound}   deterministic bytes identical: {telemetry_identical}"
    );
    assert!(
        within_bound,
        "telemetry overhead {overhead_ratio:.3}x exceeds the          {TELEMETRY_OVERHEAD_BOUND:.2}x budget ({off_seconds:.3}s -> {on_seconds:.3}s)"
    );

    println!(
        "BENCH_service.json {}",
        Json::obj(vec![
            ("requests", Json::num(n_requests as f64)),
            ("serial_seconds", Json::num(serial_wall)),
            ("parallel_seconds", Json::num(parallel_wall)),
            ("workers", Json::num(parallel_workers as f64)),
            (
                "speedup",
                Json::num(serial_wall / parallel_wall)
            ),
            ("identical", Json::Bool(true)),
            (
                "saturation",
                Json::obj(vec![
                    ("connections", Json::num(SAT_CONNS as f64)),
                    ("requests", Json::num(sat_requests as f64)),
                    ("serial_seconds", Json::num(sat_serial_wall)),
                    ("parallel_seconds", Json::num(sat_parallel_wall)),
                    ("speedup", Json::num(sat_serial_wall / sat_parallel_wall)),
                    ("per_connection_identical", Json::Bool(true)),
                ])
            ),
            (
                "telemetry",
                Json::obj(vec![
                    ("off_seconds", Json::num(off_seconds)),
                    ("on_seconds", Json::num(on_seconds)),
                    ("overhead_ratio", Json::num(overhead_ratio)),
                    ("within_bound", Json::Bool(within_bound)),
                    ("identical", Json::Bool(telemetry_identical)),
                ])
            ),
        ])
    );
}
