//! Bench: Fig. 12 / Table 2 / Table 3 regeneration + sweep-engine timing.
//!
//! Runs the full §6 grid sweep (15 candidates, profile + simulate) two
//! ways and reports the wall-clock ratio:
//!
//! * **serial seed path** — one worker, no profile cache: every candidate
//!   re-profiles its own event set (the historical free-function search);
//! * **engine path** — all available workers sharing one `ProfileCache`.
//!
//! Values are asserted bit-identical between the two paths (the cache
//! returns exactly what a fresh measurement would), so the ratio is pure
//! infrastructure win. The paper's reference: 0.14 s simulate time for
//! the whole search.

use std::time::Instant;

use distsim::cluster::ClusterSpec;
use distsim::cost::CostModel;
use distsim::model::zoo;
use distsim::search::{SearchEngine, SweepConfig, SweepReport};

fn sweep(model: &distsim::model::ModelSpec, cluster: &ClusterSpec, cfg: SweepConfig) -> (SweepReport, f64) {
    let cost = CostModel::default();
    let engine = SearchEngine::new(model, cluster, &cost, cfg);
    let t0 = Instant::now();
    let report = engine.sweep();
    (report, t0.elapsed().as_secs_f64())
}

fn main() {
    let model = zoo::bert_ex_large();
    let cluster = ClusterSpec::a10_cluster(4, 4);
    let base = SweepConfig {
        global_batch: 16,
        jitter_sigma: 0.02,
        profile_iters: 50,
        ..SweepConfig::default()
    };

    let serial_cfg = SweepConfig {
        threads: 1,
        use_cache: false,
        ..base.clone()
    };
    let (serial, serial_wall) = sweep(&model, &cluster, serial_cfg);
    let (engine, engine_wall) = sweep(&model, &cluster, base);

    println!("# bench fig12: BERT-exLarge grid search on 16 A10\n");
    let mut sorted = engine.candidates.clone();
    sorted.sort_by(|a, b| b.throughput.total_cmp(&a.throughput));
    for c in &sorted {
        println!(
            "{:10} {:>12}",
            c.strategy.notation(),
            if c.evaluated() {
                format!("{:.3} it/s", c.throughput)
            } else {
                "unreachable".into()
            }
        );
    }
    println!(
        "\nspeedup best/worst: {:.2}x  (paper: 7.37x; winner pipeline-heavy, loser 16M)",
        engine.speedup().unwrap_or(f64::NAN)
    );

    // the two paths must agree bit-for-bit on every candidate — enforced,
    // not just printed: the wall-clock ratio is meaningless otherwise
    let identical = serial
        .candidates
        .iter()
        .zip(&engine.candidates)
        .all(|(a, b)| a == b);
    assert!(
        identical && serial.candidates.len() == engine.candidates.len(),
        "serial and engine sweeps diverged"
    );
    println!("\nserial seed path:  {serial_wall:.3} s wall (1 thread, no cache)");
    println!(
        "engine path:       {engine_wall:.3} s wall ({} threads, cache {} hits / {} misses)",
        engine.threads_used, engine.cache.hits, engine.cache.misses
    );
    println!(
        "wall-clock improvement: {:.2}x   values identical: {identical}",
        serial_wall / engine_wall
    );
    println!(
        "profiling cost: serial {:.2} gpu-s vs deduped {:.2} gpu-s ({} unique events)",
        serial.profile.gpu_seconds, engine.profile.gpu_seconds, engine.profile.events_profiled
    );

    // per-candidate simulate-only timing (hot path for §Perf)
    let t0 = Instant::now();
    let n = 10;
    for _ in 0..n {
        let cfg = SweepConfig {
            global_batch: 16,
            jitter_sigma: 0.0,
            profile_iters: 1,
            ..SweepConfig::default()
        };
        let _ = sweep(&model, &cluster, cfg);
    }
    println!(
        "\nminimal-profile sweep: {:.1} ms per full 15-candidate sweep",
        t0.elapsed().as_secs_f64() * 1e3 / n as f64
    );
}
