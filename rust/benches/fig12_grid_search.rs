//! Bench: Fig. 12 / Table 2 / Table 3 regeneration + search timing.
//!
//! Times the full §6 grid search (15 candidates, profile + simulate) and
//! prints the Fig.-12 throughput series plus the Table-3 cost accounting.
//! The paper's reference: 0.14 s simulate time for the whole search.

use std::time::Instant;

use distsim::cluster::ClusterSpec;
use distsim::cost::CostModel;
use distsim::model::zoo;
use distsim::search::grid_search;

fn main() {
    let model = zoo::bert_ex_large();
    let cluster = ClusterSpec::a10_cluster(4, 4);

    let t0 = Instant::now();
    let report = grid_search(&model, &cluster, &CostModel::default(), 16, 0.02, 50);
    let wall = t0.elapsed().as_secs_f64();

    println!("# bench fig12: BERT-exLarge grid search on 16 A10\n");
    let mut sorted = report.candidates.clone();
    sorted.sort_by(|a, b| b.throughput.partial_cmp(&a.throughput).unwrap());
    for c in &sorted {
        println!(
            "{:10} {:>12}",
            c.strategy.notation(),
            if c.reachable {
                format!("{:.3} it/s", c.throughput)
            } else {
                "unreachable".into()
            }
        );
    }
    println!(
        "\nspeedup best/worst: {:.2}x  (paper: 7.37x; winner pipeline-heavy, loser 16M)",
        report.speedup()
    );
    println!(
        "search wall time {:.3} s (simulate {:.3} s, paper: 0.14 s); profiling {:.2} gpu-s",
        wall, report.simulate_seconds, report.profile.gpu_seconds
    );

    // per-candidate simulate-only timing (hot path for §Perf)
    let t0 = Instant::now();
    let n = 10;
    for _ in 0..n {
        let _ = grid_search(&model, &cluster, &CostModel::default(), 16, 0.0, 1);
    }
    println!(
        "minimal-profile search: {:.1} ms per full 15-candidate sweep",
        t0.elapsed().as_secs_f64() * 1e3 / n as f64
    );
}
