//! Bench: Fig. 8 regeneration + simulator hot-path timing.
//!
//! `cargo bench --offline` (harness = false: no criterion in the offline
//! vendor set). For every (model, strategy) cell of Fig. 8 this measures
//! the cost of (a) DistSim's full pipeline — event generation, 2-node
//! profiling, hierarchical modeling — and (b) one ground-truth engine
//! iteration, then prints the accuracy row. The simulation path is the L3
//! hot path the §Perf pass optimizes.

use std::time::Instant;

use distsim::cluster::ClusterSpec;
use distsim::config::RunConfig;
use distsim::strategy::Strategy;
use distsim::util::stats;

fn time_us<T>(reps: usize, mut f: impl FnMut() -> T) -> (f64, T) {
    let mut out = f(); // warmup
    let mut samples = Vec::with_capacity(reps);
    for _ in 0..reps {
        let t0 = Instant::now();
        out = f();
        samples.push(t0.elapsed().as_secs_f64() * 1e6);
    }
    (stats::median(&samples), out)
}

fn main() -> anyhow::Result<()> {
    println!("# bench fig8: DistSim pipeline vs engine, per configuration\n");
    println!(
        "{:<12} {:<8} {:>14} {:>14} {:>14} {:>10}",
        "model", "strategy", "simulate (us)", "profile (us)", "engine (us)", "err %"
    );
    let mut sim_times = Vec::new();
    for model in ["bert-large", "gpt2-345m", "t5"] {
        for s in ["1M1P4D", "2M2P2D", "1M4P2D", "2M2P4D", "2M4P2D", "4M2P2D"] {
            let mut cfg = RunConfig::new(
                model,
                Strategy::parse(s)?,
                ClusterSpec::a40_cluster(4, 4),
            );
            cfg.profile_iters = 20;
            let gt = distsim::engine::GroundTruth::prepare(&cfg)?;

            // profiling cost (event measurement on the 2-node slice)
            let (profile_us, mut db) = time_us(3, || {
                let mut db = distsim::events::EventDb::new();
                distsim::engine::build_programs(&gt.part, &gt.sched, &cfg.cluster, &mut db);
                distsim::profile::profile_events(
                    &mut db,
                    &cfg.cluster,
                    &distsim::cost::CostBook::default(),
                    cfg.jitter_sigma,
                    cfg.profile_iters,
                    1,
                );
                db
            });

            // pure modeling cost (the paper's "simulate time")
            let ds = distsim::distsim::DistSim::new(&gt.part, &gt.sched, &cfg.cluster);
            let (sim_us, predicted) = time_us(10, || ds.predict(&mut db));

            // one engine iteration (the "real cluster")
            let (engine_us, actual) = time_us(3, || gt.run_iteration(0));

            let err = distsim::metrics::batch_time_error_pct(&predicted, &actual);
            println!(
                "{:<12} {:<8} {:>14.0} {:>14.0} {:>14.0} {:>9.2}%",
                model, s, sim_us, profile_us, engine_us, err
            );
            sim_times.push(sim_us);
        }
    }
    println!(
        "\nsimulate median {:.0} us, max {:.0} us  (paper Table 3: simulation <1% of cost)",
        stats::median(&sim_times),
        stats::max(&sim_times)
    );
    Ok(())
}
