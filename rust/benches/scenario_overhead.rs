//! Bench: scenario engine overhead (ISSUE 7).
//!
//! Three contracts, asserted in-bench:
//!
//! * the **empty scenario is bit-identical** to running without one,
//!   span for span;
//! * its overhead on the DES hot loop is **~zero** — every scenario hook
//!   is gated on `is_empty()` before any per-span work, so attaching an
//!   empty spec must not slow the executor measurably;
//! * a **scenario-scored sweep** answers with a robustness block at a
//!   small constant-factor cost over the nominal sweep (two extra
//!   analytical walks per candidate plus three cache-warm re-walks of
//!   the winner — never a second profiling pass).
//!
//! Emits a machine-readable `BENCH_scenario.json` line (docs/FORMATS.md §3).

use std::sync::Arc;
use std::time::Instant;

use distsim::cluster::ClusterSpec;
use distsim::config::{Json, RunConfig};
use distsim::cost::CostModel;
use distsim::engine::GroundTruth;
use distsim::model::zoo;
use distsim::scenario::{ScenarioSpec, Straggler};
use distsim::search::{SearchEngine, SweepConfig};
use distsim::strategy::Strategy;

/// Min-of-trials wall time of `iters` engine iterations.
fn engine_seconds(gt: &GroundTruth, iters: usize, trials: usize) -> f64 {
    let mut best = f64::INFINITY;
    for _ in 0..trials {
        let t0 = Instant::now();
        let mean = gt.mean_batch_time_us(iters);
        assert!(mean > 0.0);
        best = best.min(t0.elapsed().as_secs_f64());
    }
    best
}

fn main() {
    let cfg = {
        let mut c = RunConfig::new(
            "bert-large",
            Strategy::new(2, 2, 2),
            ClusterSpec::a40_cluster(2, 4),
        );
        c.micro_batches = 4;
        c.micro_batch_size = 2;
        c
    };
    let straggle = ScenarioSpec {
        stragglers: vec![Straggler {
            device: 0,
            factor: 1.5,
        }],
        ..ScenarioSpec::default()
    };

    let plain = GroundTruth::prepare(&cfg).expect("prepare");
    let empty = GroundTruth::prepare(&cfg)
        .expect("prepare")
        .with_scenario(Arc::new(ScenarioSpec::default()));
    let straggled = GroundTruth::prepare(&cfg)
        .expect("prepare")
        .with_scenario(Arc::new(straggle.clone()));

    // contract 1: empty scenario is bit-identical, span for span
    for iter in 0..3 {
        let a = plain.run_iteration(iter);
        let b = empty.run_iteration(iter);
        assert_eq!(a.len(), b.len(), "iteration {iter}: span count differs");
        let identical = a.spans().iter().zip(b.spans()).all(|(x, y)| {
            x.device == y.device
                && x.start.to_bits() == y.start.to_bits()
                && x.end.to_bits() == y.end.to_bits()
        });
        assert!(identical, "iteration {iter}: empty scenario moved a span");
    }

    // contract 2: ~zero hot-loop overhead for the empty spec
    let (iters, trials) = (30, 3);
    engine_seconds(&plain, 2, 1); // warm up allocators and caches
    let none_s = engine_seconds(&plain, iters, trials);
    let empty_s = engine_seconds(&empty, iters, trials);
    let straggled_s = engine_seconds(&straggled, iters, trials);
    let overhead = empty_s / none_s;
    assert!(
        overhead < 1.25,
        "empty-scenario overhead x{overhead:.3} (none {none_s:.4}s, empty {empty_s:.4}s) \
         — the is_empty() gate is not short-circuiting the hot loop"
    );
    println!(
        "engine: {iters} iters  none {none_s:.4}s  empty {empty_s:.4}s (x{overhead:.3})  \
         straggler {straggled_s:.4}s"
    );

    // contract 3: the scenario-scored sweep answers with robustness at a
    // bounded constant factor over the nominal sweep
    let model = zoo::bert_large();
    let cluster = ClusterSpec::a40_cluster(1, 4);
    let cost = CostModel::default();
    let base = SweepConfig {
        global_batch: 8,
        profile_iters: 1,
        threads: 1,
        ..SweepConfig::default()
    };
    let t0 = Instant::now();
    let nominal = SearchEngine::new(&model, &cluster, &cost, base.clone()).sweep();
    let nominal_s = t0.elapsed().as_secs_f64();
    let t0 = Instant::now();
    let robust = SearchEngine::new(
        &model,
        &cluster,
        &cost,
        SweepConfig {
            scenario: straggle,
            ..base
        },
    )
    .sweep();
    let scenario_s = t0.elapsed().as_secs_f64();
    assert!(nominal.robustness.is_none(), "nominal sweep grew a robustness block");
    let rb = robust
        .robustness
        .expect("scenario sweep must attribute robustness");
    assert!(rb.straggler_slowdown > 1.0, "straggler not attributed");
    let sweep_ratio = scenario_s / nominal_s;
    assert!(
        sweep_ratio < 10.0,
        "scenario sweep x{sweep_ratio:.2} over nominal — scoring should be \
         walk-bound, not profile-bound"
    );
    println!(
        "sweep: nominal {nominal_s:.3}s  scenario {scenario_s:.3}s (x{sweep_ratio:.2})  \
         regret {:.4}",
        rb.regret
    );

    println!(
        "BENCH_scenario.json {}",
        Json::obj(vec![
            ("bench", Json::str("scenario_overhead")),
            ("engine_iters", Json::num(iters as f64)),
            ("none_seconds", Json::num(none_s)),
            ("empty_seconds", Json::num(empty_s)),
            ("straggler_seconds", Json::num(straggled_s)),
            ("empty_overhead_ratio", Json::num(overhead)),
            ("identical", Json::Bool(true)),
            ("sweep_nominal_seconds", Json::num(nominal_s)),
            ("sweep_scenario_seconds", Json::num(scenario_s)),
            ("sweep_ratio", Json::num(sweep_ratio)),
            ("straggler_slowdown", Json::num(rb.straggler_slowdown)),
            ("regret", Json::num(rb.regret)),
        ])
    );
}
