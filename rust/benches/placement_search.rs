//! Bench: flat placement sweep vs the staged candidate pipeline
//! (ISSUE 5) on mixed-SKU fleets.
//!
//! Two scenarios (16 and 64 ranks of alternating A40/A10 nodes) run a
//! **flat** sweep — the named placement axis, everything evaluated — and
//! a **staged** sweep — the placement optimizer's `Placement::Table`
//! candidates on top of the axis, with adaptive epoch-scheduled pruning.
//! For each scenario the staged sweep runs at 1 worker thread and at N,
//! and the best-candidate checksum is asserted bit-equal (the pipeline's
//! thread-count determinism contract); the shipped 16-rank scenario also
//! asserts the optimizer strictly beats every named placement. A third,
//! capacity-capped scenario (ISSUE 9) measures the memory feasibility
//! stage: candidates the cap makes infeasible are discarded at the head
//! of the pipeline and the avoided profiling cost is reported. Emits a
//! machine-readable `BENCH_placement.json` line (see docs/FORMATS.md §3).

use std::time::Instant;

use distsim::cluster::{ClusterSpec, PlacementPolicy};
use distsim::config::Json;
use distsim::cost::CostModel;
use distsim::model::zoo;
use distsim::search::{SearchEngine, SweepConfig, SweepReport};

fn fnv1a64(bytes: &[u8]) -> u64 {
    let mut h = 0xcbf29ce484222325u64;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x100000001b3);
    }
    h
}

/// Canonical digest of the winning candidate: strategy, schedule,
/// placement, micro-batching, the throughput's exact bits, and the
/// deployed table (when the optimizer won). Bit-equal checksums mean
/// bit-equal winners.
fn best_checksum(rep: &SweepReport) -> String {
    let mut s = String::new();
    if let Some(b) = rep.best() {
        s.push_str(&format!(
            "{}/{}/{}/mbs{}x{}/tp{:016x}",
            b.strategy.notation(),
            b.schedule.name(),
            b.placement.name(),
            b.micro_batch_size,
            b.micro_batches,
            b.throughput.to_bits()
        ));
        if let Some(t) = rep.winning_table() {
            s.push_str(&format!("/table{t:?}"));
        }
    }
    format!("{:016x}", fnv1a64(s.as_bytes()))
}

fn run(cluster: &ClusterSpec, cfg: SweepConfig) -> (SweepReport, f64) {
    let model = zoo::bert_large();
    let cost = CostModel::default();
    let t0 = Instant::now();
    let rep = SearchEngine::new(&model, cluster, &cost, cfg).sweep();
    (rep, t0.elapsed().as_secs_f64())
}

fn best_named(rep: &SweepReport) -> f64 {
    rep.candidates
        .iter()
        .filter(|c| c.placement != PlacementPolicy::Optimized && c.evaluated())
        .map(|c| c.throughput)
        .fold(0.0, f64::max)
}

fn best_optimized(rep: &SweepReport) -> f64 {
    rep.candidates
        .iter()
        .filter(|c| c.placement == PlacementPolicy::Optimized && c.evaluated())
        .map(|c| c.throughput)
        .fold(0.0, f64::max)
}

fn main() {
    let parallel = std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(4)
        .min(8);
    let mut scenarios = Vec::new();

    for (nodes, gpn, batch, strict) in [(4usize, 4usize, 16usize, true), (8, 8, 16, false)] {
        let cluster = ClusterSpec::mixed_a40_a10(nodes, gpn);
        let ranks = cluster.total_devices();
        let flat_cfg = SweepConfig {
            global_batch: batch,
            profile_iters: 1,
            threads: parallel,
            placement_axis: true,
            ..SweepConfig::default()
        };
        let staged_cfg = SweepConfig {
            placement_opt: true,
            beam: 4,
            prune: true,
            prune_epochs: 4,
            ..flat_cfg.clone()
        };

        println!("# {ranks}-rank mixed fleet ({nodes} nodes x {gpn})");
        let (flat, flat_wall) = run(&cluster, flat_cfg);
        let (staged, staged_wall) = run(&cluster, staged_cfg.clone());
        let (staged_1t, _) = run(
            &cluster,
            SweepConfig {
                threads: 1,
                ..staged_cfg
            },
        );

        // thread-count bit-identity of the staged pipeline's winner
        let checksum = best_checksum(&staged);
        let identical = checksum == best_checksum(&staged_1t);
        assert!(
            identical,
            "{ranks}-rank staged sweep: best candidate differs across thread counts"
        );

        // the optimizer never loses to the named placements; in the
        // shipped 16-rank scenario it strictly beats all three
        let named = best_named(&staged);
        let optimized = best_optimized(&staged);
        assert!(
            optimized >= named,
            "{ranks}-rank: optimizer best {optimized} lost to named best {named}"
        );
        if strict {
            assert!(
                optimized > named,
                "16-rank scenario: optimizer ({optimized}) must strictly beat \
                 every named placement ({named})"
            );
        }

        println!(
            "flat:   {:4} candidates evaluated in {flat_wall:.3} s (best {:.4} it/s)",
            flat.pruning.evaluated,
            flat.best().map(|b| b.throughput).unwrap_or(0.0)
        );
        println!(
            "staged: {:4} generated, {} bound-pruned, {} epoch-repruned, {} evaluated \
             in {staged_wall:.3} s (best {:.4} it/s, {:.2} gpu-s avoided)",
            staged.pruning.generated,
            staged.pruning.bound_pruned,
            staged.pruning.epoch_repruned,
            staged.pruning.evaluated,
            staged.best().map(|b| b.throughput).unwrap_or(0.0),
            staged.pruning.gpu_seconds_avoided
        );
        println!(
            "optimizer: best table beats named placements by {:.3}x  (checksum {checksum})\n",
            if named > 0.0 { optimized / named } else { f64::NAN }
        );

        scenarios.push(Json::obj(vec![
            ("ranks", Json::num(ranks as f64)),
            ("model", Json::str("bert-large")),
            ("flat_seconds", Json::num(flat_wall)),
            ("staged_seconds", Json::num(staged_wall)),
            ("flat_evaluated", Json::num(flat.pruning.evaluated as f64)),
            ("staged_generated", Json::num(staged.pruning.generated as f64)),
            ("staged_evaluated", Json::num(staged.pruning.evaluated as f64)),
            (
                "bound_pruned",
                Json::num(staged.pruning.bound_pruned as f64),
            ),
            (
                "epoch_repruned",
                Json::num(staged.pruning.epoch_repruned as f64),
            ),
            (
                "gpu_seconds_avoided",
                Json::num(staged.pruning.gpu_seconds_avoided),
            ),
            (
                "optimizer_speedup_vs_named",
                Json::num(if named > 0.0 { optimized / named } else { 0.0 }),
            ),
            ("best_checksum", Json::str(&checksum)),
            ("identical", Json::Bool(identical)),
        ]));
    }

    // Feasibility scenario (ISSUE 9): a capacity-capped mixed fleet
    // where the memory stage discards infeasible candidates at the
    // head of the pipeline, before any profiling is spent on them.
    {
        let cluster = ClusterSpec::mixed_a40_a10(2, 4).with_uniform_capacity(3_000_000_000);
        let ranks = cluster.total_devices();
        let mem_cfg = SweepConfig {
            global_batch: 16,
            profile_iters: 1,
            threads: parallel,
            micro_batch_axis: true,
            recompute_axis: true,
            zero_axis: true,
            prune: true,
            ..SweepConfig::default()
        };
        println!("# {ranks}-rank capacity-capped fleet (3.0 GB/rank)");
        let (capped, capped_wall) = run(&cluster, mem_cfg.clone());
        let (capped_1t, _) = run(
            &cluster,
            SweepConfig {
                threads: 1,
                ..mem_cfg
            },
        );

        let checksum = best_checksum(&capped);
        let identical = checksum == best_checksum(&capped_1t);
        assert!(
            identical,
            "capacity-capped sweep: best candidate differs across thread counts"
        );
        assert!(
            capped.pruning.memory_pruned > 0,
            "the 3.0 GB cap must make some candidate infeasible"
        );
        let best = capped
            .best()
            .expect("a feasible winner must exist under the 3.0 GB cap");
        assert!(
            best.fits && best.peak_bytes <= 3_000_000_000,
            "the winner must fit its cap (peak {} bytes)",
            best.peak_bytes
        );

        println!(
            "memory: {} generated, {} memory-pruned (oom), {} evaluated in \
             {capped_wall:.3} s ({:.2} gpu-s avoided by the memory stage, \
             {:.2} by the bound)\n",
            capped.pruning.generated,
            capped.pruning.memory_pruned,
            capped.pruning.evaluated,
            capped.pruning.memory_gpu_seconds_avoided,
            capped.pruning.gpu_seconds_avoided
        );

        scenarios.push(Json::obj(vec![
            ("ranks", Json::num(ranks as f64)),
            ("model", Json::str("bert-large")),
            ("staged_seconds", Json::num(capped_wall)),
            (
                "staged_generated",
                Json::num(capped.pruning.generated as f64),
            ),
            (
                "staged_evaluated",
                Json::num(capped.pruning.evaluated as f64),
            ),
            (
                "memory_pruned",
                Json::num(capped.pruning.memory_pruned as f64),
            ),
            (
                "memory_gpu_seconds_avoided",
                Json::num(capped.pruning.memory_gpu_seconds_avoided),
            ),
            (
                "bound_pruned",
                Json::num(capped.pruning.bound_pruned as f64),
            ),
            (
                "gpu_seconds_avoided",
                Json::num(capped.pruning.gpu_seconds_avoided),
            ),
            ("best_checksum", Json::str(&checksum)),
            ("identical", Json::Bool(identical)),
        ]));
    }

    println!(
        "BENCH_placement.json {}",
        Json::obj(vec![
            ("bench", Json::str("placement_search")),
            ("scenarios", Json::Arr(scenarios)),
        ])
    );
}
