//! The paper's core abstraction (§3.2, §4.1): **events**.
//!
//! An event is an equivalence class of identical work — "the same
//! computation and communication performed by different devices can be
//! gathered into one event and need to be profiled only once". Identity is
//! (operator name, parameters, input shape, **device kind**) for
//! computation events, plus an intra-/inter-node attribute for
//! communication events (§4.1).
//!
//! The device kind (the SKU name, e.g. `"A40"`) generalizes the paper's
//! homogeneous setting to mixed fleets: a layer's forward pass on an A40
//! and the same shapes on an A10 are *different* events with different
//! measured costs, so a profile cached for one SKU can never serve a
//! lookup for another (ISSUE 4). Communication events carry no kind —
//! their cost is a property of the link fabric, which the cluster
//! fingerprint already pins.
//!
//! [`EventDb`] interns event descriptors to dense [`EventId`]s; profiling
//! (profile/) fills in elapsed times; hierarchical modeling (distsim/)
//! composes timelines out of ids without re-profiling duplicates — that
//! dedup is exactly the paper's Table-3 cost saving.

use std::collections::HashMap;

use crate::cluster::LinkClass;
use crate::config::Json;
use crate::cost::OpClass;

/// Dense handle for an interned event.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct EventId(pub u32);

/// A computation event: one operator on one device kind.
#[derive(Debug, Clone, PartialEq, Eq, Hash)]
pub struct CompEvent {
    /// Operator name + parameter digest, e.g. "layer_fwd/h1024/mp2".
    pub name: String,
    pub class: OpClass,
    /// Per-device FLOPs of the operator.
    pub flops: u64,
    /// Per-device bytes touched (activations + weights read/written).
    pub bytes: u64,
    /// Device-kind (SKU) name the operator runs on, e.g. "A40" — part of
    /// the event identity (an A40 profile must never price an A10 rank).
    pub kind: String,
}

impl CompEvent {
    /// The same operator re-targeted to another device kind (program
    /// builders stamp the partition's template descriptor per rank).
    pub fn for_kind(&self, kind: &str) -> CompEvent {
        let mut e = self.clone();
        e.kind = kind.to_string();
        e
    }
}

/// A communication event (§4.2 families).
#[derive(Debug, Clone, PartialEq, Eq, Hash)]
pub enum CommEvent {
    /// Point-to-point activation transfer.
    P2p { bytes: u64, link: LinkClass },
    /// Ring all-reduce over a group.
    AllReduce {
        bytes: u64,
        group: usize,
        link: LinkClass,
    },
}

/// Any event.
#[derive(Debug, Clone, PartialEq, Eq, Hash)]
pub enum Event {
    Comp(CompEvent),
    Comm(CommEvent),
}

impl Event {
    pub fn name(&self) -> String {
        match self {
            Event::Comp(c) => format!("{}@{}", c.name, c.kind),
            Event::Comm(CommEvent::P2p { bytes, link }) => {
                format!("p2p/{bytes}B/{link:?}")
            }
            Event::Comm(CommEvent::AllReduce { bytes, group, link }) => {
                format!("allreduce/{bytes}B/x{group}/{link:?}")
            }
        }
    }

    pub fn is_comm(&self) -> bool {
        matches!(self, Event::Comm(_))
    }

    /// Canonical JSON form of the descriptor, used as the profile-cache
    /// snapshot key. `u64` fields travel as strings so values above 2^53
    /// survive the `f64`-backed JSON number type; objects serialize with
    /// sorted keys, so the string form is a stable identity.
    pub fn to_json(&self) -> Json {
        match self {
            Event::Comp(c) => Json::obj(vec![
                ("type", Json::str("comp")),
                ("name", Json::str(&c.name)),
                ("class", Json::str(c.class.name())),
                ("flops", Json::str(c.flops.to_string())),
                ("bytes", Json::str(c.bytes.to_string())),
                ("kind", Json::str(&c.kind)),
            ]),
            Event::Comm(CommEvent::P2p { bytes, link }) => Json::obj(vec![
                ("type", Json::str("p2p")),
                ("bytes", Json::str(bytes.to_string())),
                ("link", Json::str(link.name())),
            ]),
            Event::Comm(CommEvent::AllReduce { bytes, group, link }) => Json::obj(vec![
                ("type", Json::str("allreduce")),
                ("bytes", Json::str(bytes.to_string())),
                ("group", Json::num(*group as f64)),
                ("link", Json::str(link.name())),
            ]),
        }
    }

    /// The canonical string identity of this descriptor (sorted-key JSON).
    pub fn key(&self) -> String {
        self.to_json().to_string()
    }

    pub fn from_json(j: &Json) -> anyhow::Result<Event> {
        fn str_field<'a>(j: &'a Json, k: &str) -> anyhow::Result<&'a str> {
            j.get(k)
                .and_then(Json::as_str)
                .ok_or_else(|| anyhow::anyhow!("event missing string field '{k}'"))
        }
        fn u64_field(j: &Json, k: &str) -> anyhow::Result<u64> {
            str_field(j, k)?
                .parse()
                .map_err(|_| anyhow::anyhow!("event field '{k}' is not a u64"))
        }
        match str_field(j, "type")? {
            "comp" => Ok(Event::Comp(CompEvent {
                name: str_field(j, "name")?.to_string(),
                class: OpClass::parse(str_field(j, "class")?)?,
                flops: u64_field(j, "flops")?,
                bytes: u64_field(j, "bytes")?,
                kind: str_field(j, "kind")?.to_string(),
            })),
            "p2p" => Ok(Event::Comm(CommEvent::P2p {
                bytes: u64_field(j, "bytes")?,
                link: LinkClass::parse(str_field(j, "link")?)?,
            })),
            "allreduce" => Ok(Event::Comm(CommEvent::AllReduce {
                bytes: u64_field(j, "bytes")?,
                group: j
                    .get("group")
                    .and_then(Json::as_usize)
                    .ok_or_else(|| anyhow::anyhow!("allreduce event missing group"))?,
                link: LinkClass::parse(str_field(j, "link")?)?,
            })),
            other => anyhow::bail!("unknown event type '{other}'"),
        }
    }
}

/// Interning table + profiled elapsed times.
#[derive(Debug, Default, Clone)]
pub struct EventDb {
    events: Vec<Event>,
    index: HashMap<Event, EventId>,
    /// Profiled mean elapsed time per event (us); NaN = not yet profiled.
    elapsed_us: Vec<f64>,
}

impl EventDb {
    pub fn new() -> Self {
        Self::default()
    }

    /// Intern an event, returning its id (dedup point — §3.2 observation 1).
    pub fn intern(&mut self, e: Event) -> EventId {
        if let Some(&id) = self.index.get(&e) {
            return id;
        }
        let id = EventId(self.events.len() as u32);
        self.index.insert(e.clone(), id);
        self.events.push(e);
        self.elapsed_us.push(f64::NAN);
        id
    }

    pub fn get(&self, id: EventId) -> &Event {
        &self.events[id.0 as usize]
    }

    pub fn len(&self) -> usize {
        self.events.len()
    }

    pub fn is_empty(&self) -> bool {
        self.events.is_empty()
    }

    pub fn set_elapsed(&mut self, id: EventId, us: f64) {
        self.elapsed_us[id.0 as usize] = us;
    }

    /// Profiled elapsed time; panics if the event was never profiled
    /// (modeling must not silently invent costs).
    pub fn elapsed(&self, id: EventId) -> f64 {
        let t = self.elapsed_us[id.0 as usize];
        assert!(
            !t.is_nan(),
            "event {:?} ({}) used before profiling",
            id,
            self.get(id).name()
        );
        t
    }

    pub fn is_profiled(&self, id: EventId) -> bool {
        !self.elapsed_us[id.0 as usize].is_nan()
    }

    pub fn ids(&self) -> impl Iterator<Item = EventId> + '_ {
        (0..self.events.len() as u32).map(EventId)
    }

    /// Unprofiled ids (what the profiler still has to measure).
    pub fn unprofiled(&self) -> Vec<EventId> {
        self.ids().filter(|&id| !self.is_profiled(id)).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn comp(name: &str, flops: u64) -> Event {
        Event::Comp(CompEvent {
            name: name.into(),
            class: OpClass::Matmul,
            flops,
            bytes: flops / 100,
            kind: "A40".into(),
        })
    }

    #[test]
    fn interning_dedups_identical_events() {
        let mut db = EventDb::new();
        let a = db.intern(comp("layer_fwd/h1024/mp2", 1 << 30));
        let b = db.intern(comp("layer_fwd/h1024/mp2", 1 << 30));
        assert_eq!(a, b);
        assert_eq!(db.len(), 1);
    }

    #[test]
    fn different_shapes_are_different_events() {
        let mut db = EventDb::new();
        let a = db.intern(comp("layer_fwd", 1 << 30));
        let b = db.intern(comp("layer_fwd", 1 << 31));
        assert_ne!(a, b);
        assert_eq!(db.len(), 2);
    }

    #[test]
    fn intra_vs_inter_node_comm_distinct() {
        // §4.1: the supplementary attribute distinguishes comm events.
        let mut db = EventDb::new();
        let a = db.intern(Event::Comm(CommEvent::P2p {
            bytes: 1 << 20,
            link: LinkClass::Intra,
        }));
        let b = db.intern(Event::Comm(CommEvent::P2p {
            bytes: 1 << 20,
            link: LinkClass::Inter,
        }));
        assert_ne!(a, b);
    }

    #[test]
    fn elapsed_roundtrip_and_unprofiled_tracking() {
        let mut db = EventDb::new();
        let a = db.intern(comp("x", 1));
        let b = db.intern(comp("y", 2));
        assert_eq!(db.unprofiled(), vec![a, b]);
        db.set_elapsed(a, 12.5);
        assert_eq!(db.elapsed(a), 12.5);
        assert_eq!(db.unprofiled(), vec![b]);
    }

    #[test]
    #[should_panic(expected = "used before profiling")]
    fn elapsed_panics_if_unprofiled() {
        let mut db = EventDb::new();
        let a = db.intern(comp("x", 1));
        let _ = db.elapsed(a);
    }

    #[test]
    fn event_json_roundtrips_every_family() {
        let events = [
            comp("xfmr_fwd/h1024/mp2/b4s128", (1u64 << 60) + 3),
            Event::Comm(CommEvent::P2p {
                bytes: u64::MAX,
                link: LinkClass::Intra,
            }),
            Event::Comm(CommEvent::AllReduce {
                bytes: 1 << 26,
                group: 16,
                link: LinkClass::Inter,
            }),
        ];
        for e in events {
            let j = Json::parse(&e.to_json().to_string()).unwrap();
            assert_eq!(Event::from_json(&j).unwrap(), e);
        }
    }

    #[test]
    fn event_key_distinguishes_descriptors() {
        let a = comp("x", 1).key();
        let b = comp("x", 2).key();
        let c = comp("y", 1).key();
        assert_ne!(a, b);
        assert_ne!(a, c);
        assert_eq!(a, comp("x", 1).key());
    }

    #[test]
    fn device_kind_separates_otherwise_identical_comp_events() {
        // ISSUE 4: the same shapes on different SKUs are different events
        let Event::Comp(on_a40) = comp("xfmr_fwd/h1024/mp2/b4s128", 1 << 30) else {
            unreachable!()
        };
        let on_a10 = on_a40.for_kind("A10");
        assert_ne!(Event::Comp(on_a40.clone()), Event::Comp(on_a10.clone()));
        assert_ne!(Event::Comp(on_a40.clone()).key(), Event::Comp(on_a10.clone()).key());
        let mut db = EventDb::new();
        let a = db.intern(Event::Comp(on_a40));
        let b = db.intern(Event::Comp(on_a10));
        assert_ne!(a, b);
        assert_eq!(db.len(), 2);
        // and from_json refuses kind-less comp events (v1 snapshots)
        let v1 = r#"{"bytes":"8","class":"matmul","flops":"8","name":"x","type":"comp"}"#;
        assert!(Event::from_json(&Json::parse(v1).unwrap()).is_err());
    }

    #[test]
    fn event_from_json_rejects_garbage() {
        for src in [
            r#"{"type":"warp"}"#,
            r#"{"type":"comp","name":"x"}"#,
            r#"{"type":"p2p","bytes":"xyz","link":"intra"}"#,
            r#"{"type":"allreduce","bytes":"4","link":"orbital","group":2}"#,
        ] {
            let j = Json::parse(src).unwrap();
            assert!(Event::from_json(&j).is_err(), "{src}");
        }
    }
}
