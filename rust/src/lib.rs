//! # DistSim — event-based performance model of hybrid distributed DNN training
//!
//! A reproduction of *DistSim: A performance model of large-scale hybrid
//! distributed DNN training* (Lu et al., ACM CF '23) as a three-layer
//! Rust + JAX + Pallas system:
//!
//! * **Layer 3 (this crate)** — the paper's contribution: event generation
//!   and dedup ([`events`]), two-node profiling ([`profile`]), hierarchical
//!   MP→PP→DP timeline modeling ([`distsim`]), plus every substrate it
//!   needs: a model zoo ([`model`]), a Megatron-style partitioner
//!   ([`partition`]), pipeline schedules ([`schedule`]), communication laws
//!   ([`comm`]), a calibrated device cost model ([`cost`]), a ground-truth
//!   discrete-event cluster engine ([`engine`]) standing in for the paper's
//!   16-GPU testbed, analytical & Daydream-style baselines ([`baseline`]),
//!   the auto-parallel strategy search ([`search`]), and a long-lived
//!   what-if sweep service ([`service`]) answering concurrent strategy
//!   queries over a disk-persistent shared profile cache, observed by an
//!   in-process telemetry layer ([`telemetry`]: metrics registry,
//!   per-request lifecycle tracing, structured logging). Beyond the
//!   paper's homogeneous testbeds, clusters can mix device SKUs
//!   ([`cluster`]: named device kinds + rank→device placement maps) with
//!   per-kind cost models ([`cost::CostBook`]) and a placement axis in
//!   the sweep, and sweeps can run under deterministic unhappy-path
//!   scenarios ([`scenario`]: stragglers, link degradation, failures,
//!   elastic resize) — see `docs/FORMATS.md` for every externally visible
//!   byte format (service protocol, cache snapshots, bench output).
//! * **Layer 2 (python/compile/model.py)** — JAX transformer-layer event
//!   graphs, AOT-lowered to HLO text artifacts.
//! * **Layer 1 (python/compile/kernels/)** — Pallas matmul/attention/
//!   layernorm kernels (interpret mode) inside those graphs.
//!
//! The [`runtime`] module loads the AOT artifacts through PJRT-CPU so the
//! profiler can anchor the cost model to *measured* compute — python never
//! runs at simulation time.

pub mod baseline;
pub mod cluster;
pub mod comm;
pub mod config;
pub mod cost;
pub mod distsim;
pub mod engine;
pub mod events;
pub mod exp;
pub mod memory;
pub mod metrics;
pub mod model;
pub mod partition;
pub mod profile;
pub mod runtime;
pub mod scenario;
pub mod schedule;
pub mod search;
pub mod service;
pub mod strategy;
pub mod telemetry;
pub mod timeline;
pub mod util;

pub mod testutil;
