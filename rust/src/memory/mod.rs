//! Per-rank peak-memory accounting (ISSUE 9).
//!
//! The sweep's candidate space is honest only if every candidate it ranks
//! can actually be deployed: at large model scales the binding constraint
//! is device memory, not throughput. This module prices, for every rank
//! of a `(strategy, micro-batch, schedule)` point, the peak bytes of the
//! four training-state families —
//!
//! * **weights** — the rank's parameter shard, fp32;
//! * **gradients** — one fp32 gradient per local parameter (held across
//!   the backward regardless of DP degree);
//! * **optimizer state** — Adam's two fp32 moments (8 bytes/param),
//!   divided across the DP group under ZeRO stage 1;
//! * **activations** — the live forward activations awaiting their
//!   backward: per in-flight micro-batch
//!   ([`PipelineSchedule::max_in_flight`]), one `(mbs·seq, hidden)` fp32
//!   tensor per resident layer — or just the stage-boundary tensor under
//!   full recomputation.
//!
//! — and gates them against the per-SKU
//! [`capacity_bytes`](crate::cluster::DeviceSpec::capacity_bytes).
//! Capacities are strictly opt-in: a rank on a capacity-less SKU never
//! fails, and a capacity-less fleet never prunes, keeping every response
//! byte-identical to pre-memory builds.
//!
//! Deliberate approximations (DESIGN.md §10): activations are not divided
//! by the tensor-MP degree (Megatron's sequence-parallel-free layout
//! keeps full activations on every MP rank for most of the layer body);
//! temporary workspace, fragmentation and the embedding-lookup footprint
//! are absorbed into whatever headroom the operator left between
//! `capacity_bytes` and the physical HBM size. The model is therefore
//! *monotone and comparable across candidates* rather than
//! allocator-exact, which is what a pruning stage needs.

use crate::cluster::ClusterSpec;
use crate::partition::Partition;
use crate::schedule::PipelineSchedule;

/// Activation-recomputation policy: one point of the sweep's
/// `recompute_axis`. `Full` re-runs every layer's forward inside the
/// backward (only stage-boundary activations stay resident), trading
/// activation memory for recomputed FLOPs — see
/// [`crate::partition::partition_opts`].
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub enum Recompute {
    #[default]
    None,
    Full,
}

impl Recompute {
    /// The deterministic axis order the sweep enumerates, baseline first.
    pub const AXIS: [Recompute; 2] = [Recompute::None, Recompute::Full];

    /// Canonical serialization name.
    pub fn name(&self) -> &'static str {
        match self {
            Recompute::None => "none",
            Recompute::Full => "full",
        }
    }

    pub fn parse(name: &str) -> anyhow::Result<Recompute> {
        match name {
            "none" => Ok(Recompute::None),
            "full" => Ok(Recompute::Full),
            other => anyhow::bail!("unknown recompute policy '{other}' (none|full)"),
        }
    }
}

impl std::fmt::Display for Recompute {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "{}", self.name())
    }
}

/// One pipeline stage's per-rank residency, by family.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct StageBytes {
    pub weights: u64,
    pub grads: u64,
    pub optimizer: u64,
    pub activations: u64,
}

impl StageBytes {
    pub fn total(&self) -> u64 {
        self.weights + self.grads + self.optimizer + self.activations
    }
}

/// The per-rank verdict of one candidate on one fleet.
#[derive(Debug, Clone, PartialEq)]
pub struct MemoryReport {
    /// The worst rank's residency — what the sweep surfaces as
    /// `peak_bytes`.
    pub peak_bytes: u64,
    /// Lowest rank attaining the peak.
    pub peak_rank: usize,
    /// That rank's pipeline stage.
    pub peak_stage: usize,
    /// The peak stage's family breakdown.
    pub breakdown: StageBytes,
    /// Does every rank with a declared capacity fit?
    pub fits: bool,
    /// Ranks whose SKU declares a capacity their residency exceeds,
    /// ascending.
    pub oom_ranks: Vec<usize>,
}

/// Price one stage's per-rank residency under the candidate's axes. The
/// result depends only on the stage (every `(mp, dp)` lane of a stage
/// holds the same shard sizes); capacities are applied per rank by
/// [`assess`].
pub fn stage_bytes(
    part: &Partition,
    sched: &PipelineSchedule,
    stage: usize,
    recompute: Recompute,
    zero_stage: u8,
) -> StageBytes {
    let params = part.stages[stage].params_per_rank;
    let weights = params * 4;
    let grads = params * 4;
    let optimizer = {
        let full = params * 8; // Adam: two fp32 moments
        let dp = part.strategy.dp as u64;
        if zero_stage >= 1 && dp > 1 {
            full.div_ceil(dp)
        } else {
            full
        }
    };
    // one (mbs·seq, hidden) fp32 tensor per resident layer output, per
    // in-flight micro-batch; full recompute keeps only the stage input
    let act_mb = (part.micro_batch_size * part.seq) as u64 * part.hidden as u64 * 4;
    let resident_layers = match recompute {
        Recompute::None => part.stages[stage].layers.len() as u64,
        Recompute::Full => 1,
    };
    let in_flight = sched.max_in_flight(stage) as u64;
    StageBytes {
        weights,
        grads,
        optimizer,
        activations: act_mb * resident_layers * in_flight,
    }
}

/// Assess every rank of the partition's strategy against the fleet's
/// declared capacities. The rank→SKU map goes through the cluster's
/// placement, so two placements of one strategy can differ in
/// feasibility on a mixed fleet.
pub fn assess(
    part: &Partition,
    sched: &PipelineSchedule,
    cluster: &ClusterSpec,
    recompute: Recompute,
    zero_stage: u8,
) -> MemoryReport {
    let strategy = part.strategy;
    let per_stage: Vec<StageBytes> = (0..strategy.pp)
        .map(|s| stage_bytes(part, sched, s, recompute, zero_stage))
        .collect();
    let mut peak_bytes = 0u64;
    let mut peak_rank = 0usize;
    let mut peak_stage = 0usize;
    let mut oom_ranks = Vec::new();
    for rank in 0..strategy.world_size() {
        let stage = strategy.coords(rank).pp;
        let bytes = per_stage[stage].total();
        if bytes > peak_bytes {
            peak_bytes = bytes;
            peak_rank = rank;
            peak_stage = stage;
        }
        let kind = cluster.kind_of_rank(rank);
        if let Some(cap) = cluster.capacity_of_kind(kind) {
            if bytes > cap {
                oom_ranks.push(rank);
            }
        }
    }
    MemoryReport {
        peak_bytes,
        peak_rank,
        peak_stage,
        breakdown: per_stage[peak_stage],
        fits: oom_ranks.is_empty(),
        oom_ranks,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::zoo;
    use crate::partition::partition_opts;
    use crate::schedule::SchedKind;
    use crate::strategy::Strategy;

    fn report(
        mp: usize,
        pp: usize,
        dp: usize,
        mbs: usize,
        micro_batches: usize,
        recompute: Recompute,
        zero_stage: u8,
        cluster: &ClusterSpec,
    ) -> MemoryReport {
        let m = zoo::bert_large();
        let s = Strategy::new(mp, pp, dp);
        let part = partition_opts(&m, &s, cluster, mbs, recompute, zero_stage);
        let sched = SchedKind::Dapple.build(pp, micro_batches);
        assess(&part, &sched, cluster, recompute, zero_stage)
    }

    #[test]
    fn capacity_less_fleets_never_fail() {
        let c = ClusterSpec::a40_cluster(4, 4);
        let r = report(1, 1, 16, 4, 1, Recompute::None, 0, &c);
        assert!(r.fits);
        assert!(r.oom_ranks.is_empty());
        assert!(r.peak_bytes > 0);
    }

    #[test]
    fn breakdown_matches_the_formulas() {
        let c = ClusterSpec::a40_cluster(4, 4);
        let m = zoo::bert_large();
        let s = Strategy::new(2, 2, 4);
        let part = partition_opts(&m, &s, &c, 2, Recompute::None, 0);
        let sched = SchedKind::Dapple.build(2, 2);
        let sb = stage_bytes(&part, &sched, 0, Recompute::None, 0);
        let params = part.stages[0].params_per_rank;
        assert_eq!(sb.weights, params * 4);
        assert_eq!(sb.grads, params * 4);
        assert_eq!(sb.optimizer, params * 8);
        let act_mb = (2 * m.seq * m.hidden) as u64 * 4;
        let layers = part.stages[0].layers.len() as u64;
        assert_eq!(
            sb.activations,
            act_mb * layers * sched.max_in_flight(0) as u64
        );
        assert_eq!(
            sb.total(),
            sb.weights + sb.grads + sb.optimizer + sb.activations
        );
    }

    #[test]
    fn zero_stage_divides_optimizer_bytes_by_dp() {
        let c = ClusterSpec::a40_cluster(4, 4);
        let base = report(1, 2, 4, 2, 2, Recompute::None, 0, &c);
        let zero = report(1, 2, 4, 2, 2, Recompute::None, 1, &c);
        assert_eq!(zero.breakdown.optimizer, base.breakdown.optimizer.div_ceil(4));
        // and dp=1 is a no-op
        let solo = report(1, 2, 1, 2, 2, Recompute::None, 0, &c);
        let solo_z = report(1, 2, 1, 2, 2, Recompute::None, 1, &c);
        assert_eq!(solo.peak_bytes, solo_z.peak_bytes);
    }

    #[test]
    fn recompute_keeps_only_the_stage_boundary_activation() {
        let c = ClusterSpec::a40_cluster(4, 4);
        let base = report(1, 2, 4, 2, 2, Recompute::None, 0, &c);
        let rc = report(1, 2, 4, 2, 2, Recompute::Full, 0, &c);
        let layers = base.breakdown.activations / rc.breakdown.activations;
        assert!(layers > 1, "bert-large stages hold many layers");
        assert_eq!(rc.breakdown.weights, base.breakdown.weights);
        assert!(rc.peak_bytes < base.peak_bytes);
    }

    #[test]
    fn tight_capacity_flags_every_rank_of_the_fat_stage() {
        // cap the fleet just under the dp-only residency: every rank OOMs
        let c = ClusterSpec::a40_cluster(4, 4);
        let probe = report(1, 1, 16, 4, 1, Recompute::None, 0, &c);
        let capped = c.with_uniform_capacity(probe.peak_bytes - 1);
        let r = report(1, 1, 16, 4, 1, Recompute::None, 0, &capped);
        assert!(!r.fits);
        assert_eq!(r.oom_ranks, (0..16).collect::<Vec<_>>());
        // one byte more and everything fits again
        let roomy = c.with_uniform_capacity(probe.peak_bytes);
        assert!(report(1, 1, 16, 4, 1, Recompute::None, 0, &roomy).fits);
    }

    #[test]
    fn peak_rank_is_the_lowest_rank_of_the_heaviest_stage() {
        let c = ClusterSpec::a40_cluster(4, 4);
        // pp=2, Dapple, 2 micro-batches: stage 0 keeps 2 micro-batches
        // in flight to stage 1's one — strictly heavier activations
        let r = report(2, 2, 4, 2, 2, Recompute::None, 0, &c);
        assert_eq!(r.peak_stage, 0);
        assert_eq!(r.peak_rank, 0);
    }
}
