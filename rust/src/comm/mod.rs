//! Communication cost laws (paper §4.2).
//!
//! Two families of communication events:
//!  * **point-to-point** (pipeline activation transfers) — priced as
//!    latency + bytes/bw; profiling-wise the paper adopts dPRO's rule that
//!    the true transfer time is `min(send_side, recv_side)` because the
//!    later caller gates the rendezvous (queuing time must be excluded).
//!  * **ring all-reduce** (MP partial-sum gathers, DP gradient sync) — the
//!    Baidu ring law: each device transfers `2(N-1)/N * P` bytes, i.e.
//!    time = 2(N-1)/N * P / bus_bw + 2(N-1) * latency.
//!
//! The paper profiles all-reduce directly up to 8 devices and extrapolates
//! beyond with this law (measured effect on iteration time < 2%); we mirror
//! that in `profile/`.
//!
//! These laws price *links*, not compute, so they are device-kind
//! agnostic: in a mixed-SKU fleet (ISSUE 4) communication events carry no
//! SKU identity and a measurement transfers across kinds — but the
//! functions here take **physical device indices**, so callers with a
//! non-linear rank→device placement must map ranks through
//! [`ClusterSpec::rank_to_device`] first (the engine's base-cost pass and
//! the hierarchical model both do).

use crate::cluster::{ClusterSpec, LinkClass};
use crate::util::TimeUs;

/// Time for a point-to-point transfer of `bytes` over `class`.
pub fn p2p_time_us(cluster: &ClusterSpec, class: LinkClass, bytes: u64) -> TimeUs {
    let bw = cluster.bw_gbs(class) * 1e3; // bytes/us
    cluster.lat_us(class) + bytes as f64 / bw
}

/// Ring all-reduce time for `bytes` across `n` devices over `class`.
///
/// 2(N-1) steps, each moving P/N bytes per device; every step pays the
/// link latency once (ring neighbours synchronize per step).
pub fn allreduce_time_us(
    cluster: &ClusterSpec,
    class: LinkClass,
    n: usize,
    bytes: u64,
) -> TimeUs {
    if n <= 1 {
        return 0.0;
    }
    let bw = cluster.bw_gbs(class) * 1e3;
    let steps = 2 * (n - 1);
    let chunk = bytes as f64 / n as f64;
    steps as f64 * (cluster.lat_us(class) + chunk / bw)
}

/// The paper's §4.2 extrapolation: profile an `n_profiled`-device ring and
/// predict an `n_target`-device ring of the same payload. Derived from the
/// per-device transfer volume 2(N-1)P/N — converges as N grows, so the
/// correction factor is near 1 for large rings.
pub fn extrapolate_allreduce(
    measured_us: TimeUs,
    n_profiled: usize,
    n_target: usize,
) -> TimeUs {
    if n_profiled <= 1 || n_target <= 1 {
        return if n_target <= 1 { 0.0 } else { measured_us };
    }
    let vol = |n: usize| 2.0 * (n as f64 - 1.0) / n as f64;
    measured_us * vol(n_target) / vol(n_profiled)
}

/// All-reduce over a concrete rank placement: NCCL-style algorithm choice
/// between a flat ring over the bottleneck link and a hierarchical
/// reduce-scatter-intra / ring-inter / broadcast-intra scheme — whichever
/// is faster on this fabric. Used by the ground-truth engine for every
/// collective; the profiler extrapolates toward it with the ring law.
pub fn hierarchical_allreduce_time_us(
    cluster: &ClusterSpec,
    ranks: &[usize],
    bytes: u64,
) -> TimeUs {
    let n = ranks.len();
    if n <= 1 {
        return 0.0;
    }
    let nodes: std::collections::BTreeSet<usize> =
        ranks.iter().map(|&r| cluster.node_of(r)).collect();
    if nodes.len() == 1 {
        return allreduce_time_us(cluster, LinkClass::Intra, n, bytes);
    }
    let flat = allreduce_time_us(cluster, LinkClass::Inter, n, bytes);
    let per_node = n / nodes.len();
    let intra = if per_node > 1 {
        allreduce_time_us(cluster, LinkClass::Intra, per_node, bytes)
    } else {
        0.0
    };
    let inter = allreduce_time_us(cluster, LinkClass::Inter, nodes.len(), bytes);
    // reduce-scatter (≈ half of AR) + leader ring + broadcast (≈ half)
    let hier = intra * 0.5 + inter + intra * 0.5;
    flat.min(hier)
}

/// Synthetic placement for an all-reduce *event* (group size + link class,
/// no concrete ranks): pack one node for intra, spread evenly over
/// min(nodes, group) nodes for inter — matching how Megatron-ordered MP/DP
/// groups actually land on the cluster. Lets the profiler price a target
/// group it cannot physically assemble on its 2-node slice.
pub fn synthetic_group(cluster: &ClusterSpec, group: usize, class: LinkClass) -> Vec<usize> {
    match class {
        LinkClass::Intra => (0..group).collect(),
        LinkClass::Inter => {
            let nodes_used = cluster.nodes.min(group).max(2);
            let per = group.div_ceil(nodes_used);
            (0..group)
                .map(|i| (i / per) * cluster.gpus_per_node + (i % per))
                .collect()
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cluster() -> ClusterSpec {
        ClusterSpec::a40_cluster(4, 4)
    }

    #[test]
    fn p2p_linear_in_bytes() {
        let c = cluster();
        let t1 = p2p_time_us(&c, LinkClass::Intra, 1 << 20);
        let t2 = p2p_time_us(&c, LinkClass::Intra, 2 << 20);
        assert!(t2 > t1);
        assert!(((t2 - c.intra_lat_us) / (t1 - c.intra_lat_us) - 2.0).abs() < 1e-9);
    }

    #[test]
    fn inter_node_slower_than_intra() {
        let c = cluster();
        assert!(
            p2p_time_us(&c, LinkClass::Inter, 1 << 20)
                > p2p_time_us(&c, LinkClass::Intra, 1 << 20)
        );
    }

    #[test]
    fn allreduce_trivial_group_is_free() {
        let c = cluster();
        assert_eq!(allreduce_time_us(&c, LinkClass::Intra, 1, 1 << 30), 0.0);
    }

    #[test]
    fn allreduce_volume_converges_with_n() {
        // 2(N-1)/N -> 2: doubling N beyond 8 barely moves the time.
        let c = cluster();
        let t8 = allreduce_time_us(&c, LinkClass::Inter, 8, 1 << 26);
        let t64 = allreduce_time_us(&c, LinkClass::Inter, 64, 1 << 26);
        // bandwidth term converges; latency term grows linearly with steps
        let bw_only_8 = t8 - 14.0 * c.inter_lat_us;
        let bw_only_64 = t64 - 126.0 * c.inter_lat_us;
        let ratio = bw_only_64 / bw_only_8;
        assert!(
            (1.0..1.15).contains(&ratio),
            "volume ratio {ratio} should be 2*(63/64)/(2*7/8) ~= 1.125"
        );
    }

    #[test]
    fn extrapolation_matches_law_modulo_latency() {
        // §4.2 check (<2% iteration impact): extrapolating an 8-ring to 16
        // must land close to the directly-computed 16-ring for payloads
        // where bandwidth dominates.
        let c = cluster();
        let bytes = 1u64 << 28; // 256 MiB: bandwidth dominated
        let t8 = allreduce_time_us(&c, LinkClass::Inter, 8, bytes);
        let t16 = allreduce_time_us(&c, LinkClass::Inter, 16, bytes);
        let pred = extrapolate_allreduce(t8, 8, 16);
        let err = ((pred - t16) / t16).abs();
        assert!(err < 0.02, "extrapolation error {err}");
    }

    #[test]
    fn extrapolation_identity() {
        assert_eq!(extrapolate_allreduce(123.0, 8, 8), 123.0);
        assert_eq!(extrapolate_allreduce(123.0, 8, 1), 0.0);
    }

    #[test]
    fn multi_node_allreduce_never_beats_both_algorithms() {
        // the engine picks min(flat, hierarchical): on PCIe-ish A40 nodes
        // (intra only 2x inter) flat can win; on NVLink A100 pods the
        // hierarchical scheme must win outright.
        let c = cluster();
        let ranks: Vec<usize> = (0..16).collect(); // 4 nodes x 4
        let bytes = 1u64 << 28;
        let t = hierarchical_allreduce_time_us(&c, &ranks, bytes);
        let flat = allreduce_time_us(&c, LinkClass::Inter, 16, bytes);
        assert!(t <= flat, "{t} > flat {flat}");

        let pod = ClusterSpec::a100_pod(2);
        let ranks16: Vec<usize> = (0..16).collect(); // 2 nodes x 8
        let t = hierarchical_allreduce_time_us(&pod, &ranks16, bytes);
        let flat = allreduce_time_us(&pod, LinkClass::Inter, 16, bytes);
        assert!(t < flat, "NVLink pod: hier {t} should beat flat {flat}");
    }

    #[test]
    fn synthetic_group_matches_real_megatron_placements() {
        let c = cluster();
        // 16-way DP on 4x4: every rank, 4 per node
        let g = synthetic_group(&c, 16, LinkClass::Inter);
        let nodes: Vec<usize> = g.iter().map(|&r| c.node_of(r)).collect();
        assert_eq!(nodes, (0..4).flat_map(|n| [n; 4]).collect::<Vec<_>>());
        // 4-way inter group: one member per node
        let g = synthetic_group(&c, 4, LinkClass::Inter);
        let nodes: std::collections::BTreeSet<usize> =
            g.iter().map(|&r| c.node_of(r)).collect();
        assert_eq!(nodes.len(), 4);
        // intra group stays on node 0
        let g = synthetic_group(&c, 4, LinkClass::Intra);
        assert!(g.iter().all(|&r| c.node_of(r) == 0));
    }

    #[test]
    fn hierarchical_single_node_equals_flat_intra() {
        let c = cluster();
        let ranks = [0, 1, 2, 3];
        assert_eq!(
            hierarchical_allreduce_time_us(&c, &ranks, 1 << 20),
            allreduce_time_us(&c, LinkClass::Intra, 4, 1 << 20)
        );
    }
}
