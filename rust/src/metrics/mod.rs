//! Accuracy metrics — the paper's three evaluation lenses.
//!
//! * **Batch-time error** (§5.2 / Fig. 8): relative error of predicted vs
//!   actual iteration time.
//! * **Per-GPU activity error** (§5.3 / Fig. 9): average timestamp bias of
//!   each device's computation events against the actual timeline,
//!   normalized by the iteration time.
//! * **Per-stage error** (§5.4 / Fig. 10): per (stage, micro-batch, phase,
//!   GPU) start/finish timestamp differences — median over repeated actual
//!   runs.
//!
//! Both timelines are aligned to their first span (the paper uses the
//! first stage's start as the global standard time) before comparison —
//! done by subtracting each timeline's cached [`Timeline::start_us`]
//! in place, never by cloning a shifted copy (§Perf: the sweep compares
//! hundreds of timelines; whole-timeline clones dominated this path).

use std::collections::BTreeMap;

use crate::schedule::Phase;
use crate::timeline::Timeline;
use crate::util::{rel_err_pct, stats};

/// Relative batch-time error in percent.
pub fn batch_time_error_pct(pred: &Timeline, truth: &Timeline) -> f64 {
    rel_err_pct(pred.batch_time_us(), truth.batch_time_us())
}

/// Per-device activity error (percent of batch time), one entry per device.
///
/// Aligns each device's computation spans by order (both sides emit them
/// in program order) and averages |Δstart| and |Δend|, normalized by the
/// actual batch time.
pub fn per_gpu_activity_error_pct(pred: &Timeline, truth: &Timeline) -> Vec<f64> {
    assert_eq!(pred.n_devices, truth.n_devices, "device count mismatch");
    let p0 = pred.start_us();
    let t0 = truth.start_us();
    let bt = truth.batch_time_us();
    (0..truth.n_devices)
        .map(|d| {
            let ps = pred.device_comp_spans(d);
            let ts = truth.device_comp_spans(d);
            assert_eq!(
                ps.len(),
                ts.len(),
                "device {d}: span count mismatch ({} vs {})",
                ps.len(),
                ts.len()
            );
            if ts.is_empty() || bt == 0.0 {
                return 0.0;
            }
            let biases: Vec<f64> = ps
                .iter()
                .zip(ts)
                .flat_map(|(a, b)| {
                    [
                        ((a.start - p0) - (b.start - t0)).abs(),
                        ((a.end - p0) - (b.end - t0)).abs(),
                    ]
                })
                .collect();
            stats::mean(&biases) / bt * 100.0
        })
        .collect()
}

/// Per-device batch time: each device's latest span end relative to the
/// timeline start (0 for a device with no spans). Under an unhappy-path
/// scenario the straggling ranks finish late — these are the numbers the
/// robustness attribution ranks (ISSUE 7).
pub fn rank_batch_times_us(t: &Timeline) -> Vec<f64> {
    let t0 = t.start_us();
    (0..t.n_devices)
        .map(|d| {
            t.device_spans(d)
                .iter()
                .map(|s| s.end - t0)
                .fold(0.0f64, f64::max)
        })
        .collect()
}

/// The slowest rank's batch time — what a scenario's straggler actually
/// costs end-to-end (collective barriers make it gate the iteration).
pub fn worst_rank_batch_time_us(t: &Timeline) -> f64 {
    rank_batch_times_us(t).into_iter().fold(0.0f64, f64::max)
}

/// Nearest-rank percentile (p in [0, 100]) of a value set; 0.0 when
/// empty. Used for the p99 rank batch time in scenario reporting.
pub fn percentile(values: &[f64], p: f64) -> f64 {
    if values.is_empty() {
        return 0.0;
    }
    let mut v = values.to_vec();
    v.sort_by(f64::total_cmp);
    let idx = ((p / 100.0 * v.len() as f64).ceil() as usize).clamp(1, v.len()) - 1;
    v[idx]
}

/// p99 over [`rank_batch_times_us`] — the tail-rank batch time.
pub fn p99_rank_batch_time_us(t: &Timeline) -> f64 {
    percentile(&rank_batch_times_us(t), 99.0)
}

/// Key for one pipeline-stage execution on one device.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct StageKey {
    pub device: usize,
    pub mb: u32,
    pub phase_fwd: bool,
}

/// Per-stage timestamps: for each (device, micro-batch, phase), the start
/// of the first and end of the last computation span of that task, in the
/// timeline's own aligned clock (first span = 0).
///
/// Returns a `BTreeMap` so iteration order is deterministic — fig10 and
/// table output are stable across runs and usable in golden tests.
pub fn stage_timestamps(t: &Timeline) -> BTreeMap<StageKey, (f64, f64)> {
    let t0 = t.start_us();
    let mut out: BTreeMap<StageKey, (f64, f64)> = BTreeMap::new();
    for d in 0..t.n_devices {
        for s in t.device_comp_spans(d) {
            let key = StageKey {
                device: d,
                mb: s.tag.mb,
                phase_fwd: s.tag.phase == Phase::Fwd,
            };
            let e = out.entry(key).or_insert((f64::INFINITY, f64::NEG_INFINITY));
            e.0 = e.0.min(s.start - t0);
            e.1 = e.1.max(s.end - t0);
        }
    }
    out
}

/// Per-stage error (§5.4): for every (device, mb, phase), the mean of
/// |Δstart| and |Δend| between prediction and one actual run, as percent
/// of the actual batch time. Callers aggregate the per-run values into
/// medians across repeated runs (Fig. 10). Deterministically ordered.
pub fn per_stage_error_pct(pred: &Timeline, truth: &Timeline) -> BTreeMap<StageKey, f64> {
    let p = stage_timestamps(pred);
    let t = stage_timestamps(truth);
    let bt = truth.batch_time_us();
    let mut out = BTreeMap::new();
    for (key, (ts, te)) in &t {
        let Some((ps, pe)) = p.get(key) else { continue };
        let err = ((ps - ts).abs() + (pe - te).abs()) / 2.0 / bt * 100.0;
        out.insert(*key, err);
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::timeline::{Span, SpanKind, Tag};

    fn mk(device: usize, start: f64, end: f64, mb: u32, fwd: bool) -> Span {
        Span {
            device,
            start,
            end,
            tag: Tag {
                stage: 0,
                mb,
                phase: if fwd { Phase::Fwd } else { Phase::Bwd },
                layer: 0,
                kind: SpanKind::Comp,
                idx: 0,
            },
        }
    }

    fn tl(spans: Vec<Span>, n: usize) -> Timeline {
        let mut t = Timeline::new(n);
        for s in spans {
            t.push(s);
        }
        t.finalize();
        t
    }

    #[test]
    fn batch_time_error_pct_basic() {
        let a = tl(vec![mk(0, 0.0, 104.0, 0, true)], 1);
        let b = tl(vec![mk(0, 0.0, 100.0, 0, true)], 1);
        assert!((batch_time_error_pct(&a, &b) - 4.0).abs() < 1e-9);
    }

    #[test]
    fn identical_timelines_have_zero_activity_error() {
        let a = tl(
            vec![mk(0, 0.0, 10.0, 0, true), mk(0, 12.0, 30.0, 1, true)],
            1,
        );
        let errs = per_gpu_activity_error_pct(&a, &a.clone());
        assert!(errs.iter().all(|&e| e == 0.0));
    }

    #[test]
    fn shifted_spans_produce_expected_error() {
        // truth: [0,100]; pred: same span but second event shifted +5
        let truth = tl(
            vec![mk(0, 0.0, 50.0, 0, true), mk(0, 50.0, 100.0, 1, true)],
            1,
        );
        let pred = tl(
            vec![mk(0, 0.0, 50.0, 0, true), mk(0, 55.0, 105.0, 1, true)],
            1,
        );
        let errs = per_gpu_activity_error_pct(&pred, &truth);
        // biases: 0,0 for first; 5,5 for second -> mean 2.5 over bt 100
        assert!((errs[0] - 2.5).abs() < 1e-9, "{errs:?}");
    }

    #[test]
    fn normalization_removes_global_offsets() {
        let truth = tl(vec![mk(0, 0.0, 10.0, 0, true)], 1);
        let pred = tl(vec![mk(0, 1000.0, 1010.0, 0, true)], 1);
        let errs = per_gpu_activity_error_pct(&pred, &truth);
        assert_eq!(errs[0], 0.0);
    }

    #[test]
    fn stage_timestamps_group_by_task() {
        let t = tl(
            vec![
                mk(0, 0.0, 10.0, 0, true),
                mk(0, 10.0, 20.0, 0, true), // second layer, same task
                mk(0, 20.0, 40.0, 0, false),
            ],
            1,
        );
        let m = stage_timestamps(&t);
        assert_eq!(
            m[&StageKey {
                device: 0,
                mb: 0,
                phase_fwd: true
            }],
            (0.0, 20.0)
        );
        assert_eq!(m.len(), 2);
    }

    #[test]
    fn stage_timestamps_iterate_in_key_order() {
        let t = tl(
            vec![
                mk(1, 0.0, 10.0, 1, true),
                mk(0, 0.0, 10.0, 0, false),
                mk(0, 0.0, 10.0, 0, true),
            ],
            2,
        );
        let keys: Vec<StageKey> = stage_timestamps(&t).into_keys().collect();
        let mut sorted = keys.clone();
        sorted.sort();
        assert_eq!(keys, sorted, "BTreeMap must iterate in key order");
        assert_eq!(keys.len(), 3);
    }

    #[test]
    fn rank_batch_times_and_worst_rank() {
        // device 0 finishes at 40, device 1 at 100, device 2 idle
        let t = tl(
            vec![
                mk(0, 0.0, 10.0, 0, true),
                mk(0, 20.0, 40.0, 0, false),
                mk(1, 0.0, 100.0, 0, true),
            ],
            3,
        );
        assert_eq!(rank_batch_times_us(&t), vec![40.0, 100.0, 0.0]);
        assert_eq!(worst_rank_batch_time_us(&t), 100.0);
        assert_eq!(p99_rank_batch_time_us(&t), 100.0);
    }

    #[test]
    fn rank_batch_times_align_to_timeline_start() {
        // global offset must not inflate per-rank times
        let t = tl(
            vec![mk(0, 1000.0, 1010.0, 0, true), mk(1, 1000.0, 1050.0, 0, true)],
            2,
        );
        assert_eq!(rank_batch_times_us(&t), vec![10.0, 50.0]);
    }

    #[test]
    fn percentile_nearest_rank() {
        let xs = vec![10.0, 20.0, 30.0, 40.0];
        assert_eq!(percentile(&xs, 0.0), 10.0);
        assert_eq!(percentile(&xs, 50.0), 20.0);
        assert_eq!(percentile(&xs, 75.0), 30.0);
        assert_eq!(percentile(&xs, 99.0), 40.0);
        assert_eq!(percentile(&xs, 100.0), 40.0);
        assert_eq!(percentile(&[], 50.0), 0.0);
        // unsorted input is handled
        assert_eq!(percentile(&[3.0, 1.0, 2.0], 99.0), 3.0);
    }

    #[test]
    fn per_stage_error_zero_for_identical() {
        let t = tl(
            vec![mk(0, 0.0, 10.0, 0, true), mk(0, 20.0, 40.0, 0, false)],
            1,
        );
        let e = per_stage_error_pct(&t, &t.clone());
        assert!(e.values().all(|&v| v == 0.0));
    }
}
