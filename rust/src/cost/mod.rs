//! Device cost model: maps an operator's (FLOPs, bytes) to a latency on a
//! [`DeviceSpec`].
//!
//! This is where the paper's "why not analytical" gap lives: real kernels
//! do NOT run at peak FLOPs — efficiency depends on operator size and kind
//! (paper §2.3 measures a 26.1% average error for the peak-rate heuristic).
//! The ground-truth engine and the event profiler both price operators
//! through [`CostModel::op_latency_us`], which applies a size-dependent
//! efficiency curve plus launch overhead; the *analytical baseline*
//! (`baseline/analytical.rs`) deliberately prices at peak efficiency with
//! no overheads, reproducing the paper's Fig. 3 gap.
//!
//! The curve's absolute scale can be recalibrated from measured PJRT
//! executions of the AOT artifacts (`profile/calibrate.rs`).
//!
//! **Heterogeneous fleets (ISSUE 4).** A mixed-SKU cluster prices the same
//! operator differently per device kind twice over: the [`DeviceSpec`]
//! differs (peak FLOPs, bandwidth, launch overhead), and the efficiency
//! curve itself may differ (an A100's tensor cores saturate differently
//! than an A10's). [`CostBook`] is the per-device-kind registry: a base
//! [`CostModel`] plus named per-SKU overrides, resolved by the kind name a
//! computation event carries. Every pricing site (ground-truth engine,
//! profiler, sweep engine, service) consumes a `CostBook`; a bare
//! `CostModel` lifts via [`CostBook::uniform`].

use crate::cluster::DeviceSpec;
use crate::config::Json;
use crate::util::TimeUs;

/// Operator classes with distinct efficiency behaviour.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum OpClass {
    /// Dense matmul-dominated (qkv/proj/mlp/attention): tensor-core bound.
    Matmul,
    /// Elementwise / normalization: bandwidth bound.
    Memory,
    /// Embedding gather: bandwidth bound with poor locality.
    Gather,
}

impl OpClass {
    /// Canonical serialization name (profile-cache snapshots).
    pub fn name(&self) -> &'static str {
        match self {
            OpClass::Matmul => "matmul",
            OpClass::Memory => "memory",
            OpClass::Gather => "gather",
        }
    }

    pub fn parse(name: &str) -> anyhow::Result<OpClass> {
        match name {
            "matmul" => Ok(OpClass::Matmul),
            "memory" => Ok(OpClass::Memory),
            "gather" => Ok(OpClass::Gather),
            other => anyhow::bail!("unknown op class '{other}'"),
        }
    }
}

/// Tunable efficiency-curve parameters.
#[derive(Debug, Clone, PartialEq)]
pub struct CostModel {
    /// Peak fraction reached by very large matmuls (0..1).
    pub eff_max: f64,
    /// Peak fraction for tiny matmuls (0..1).
    pub eff_min: f64,
    /// FLOP count at which the curve reaches half-way between min and max.
    pub eff_knee_flops: f64,
    /// Fraction of peak memory bandwidth achieved by memory-bound ops.
    pub membw_frac: f64,
    /// Global multiplier applied to every latency (calibration hook).
    pub scale: f64,
}

impl Default for CostModel {
    fn default() -> Self {
        // Defaults follow common ML-perf lore for Ampere-class parts:
        // big GEMMs hit ~60% of tensor peak, small ones a few percent;
        // memory-bound ops reach ~75% of HBM bandwidth.
        CostModel {
            eff_max: 0.62,
            eff_min: 0.04,
            eff_knee_flops: 2.0e9,
            membw_frac: 0.75,
            scale: 1.0,
        }
    }
}

impl CostModel {
    /// Smooth size-dependent matmul efficiency in (0, eff_max].
    pub fn matmul_efficiency(&self, flops: f64) -> f64 {
        // logistic in log-space around the knee
        let x = (flops.max(1.0) / self.eff_knee_flops).ln();
        let sig = 1.0 / (1.0 + (-0.7 * x).exp());
        self.eff_min + (self.eff_max - self.eff_min) * sig
    }

    /// Latency (us) of one operator on `dev`.
    ///
    /// compute-bound term: flops / (peak * eff); memory term: bytes /
    /// (membw * frac). The op takes the max (roofline), plus launch
    /// overhead.
    pub fn op_latency_us(
        &self,
        dev: &DeviceSpec,
        class: OpClass,
        flops: u64,
        bytes: u64,
    ) -> TimeUs {
        let peak_flops_us = dev.peak_tflops * 1e6; // FLOP per us
        let membw_us = dev.mem_bw_gbs * 1e3; // bytes per us
        let t = match class {
            OpClass::Matmul => {
                let eff = self.matmul_efficiency(flops as f64);
                let compute = flops as f64 / (peak_flops_us * eff);
                let memory = bytes as f64 / (membw_us * self.membw_frac);
                compute.max(memory)
            }
            OpClass::Memory => bytes as f64 / (membw_us * self.membw_frac),
            OpClass::Gather => bytes as f64 / (membw_us * self.membw_frac * 0.4),
        };
        (t + dev.launch_overhead_us) * self.scale
    }

    /// What the *analytical baseline* would predict (paper §2.3): peak
    /// rate, no launch overhead, no efficiency loss.
    pub fn analytical_latency_us(
        &self,
        dev: &DeviceSpec,
        flops: u64,
        bytes: u64,
    ) -> TimeUs {
        let compute = flops as f64 / (dev.peak_tflops * 1e6);
        let memory = bytes as f64 / (dev.mem_bw_gbs * 1e3);
        compute.max(memory)
    }

    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("eff_max", Json::num(self.eff_max)),
            ("eff_min", Json::num(self.eff_min)),
            ("eff_knee_flops", Json::num(self.eff_knee_flops)),
            ("membw_frac", Json::num(self.membw_frac)),
            ("scale", Json::num(self.scale)),
        ])
    }

    pub fn from_json(j: &Json) -> Self {
        let d = CostModel::default();
        CostModel {
            eff_max: j.get("eff_max").and_then(Json::as_f64).unwrap_or(d.eff_max),
            eff_min: j.get("eff_min").and_then(Json::as_f64).unwrap_or(d.eff_min),
            eff_knee_flops: j
                .get("eff_knee_flops")
                .and_then(Json::as_f64)
                .unwrap_or(d.eff_knee_flops),
            membw_frac: j
                .get("membw_frac")
                .and_then(Json::as_f64)
                .unwrap_or(d.membw_frac),
            scale: j.get("scale").and_then(Json::as_f64).unwrap_or(d.scale),
        }
    }
}

/// Per-device-kind cost-model registry: `base` prices every SKU without an
/// override; `per_kind` maps SKU names (see
/// [`DeviceSpec::name`]) to their own curves. Kept
/// name-sorted so the canonical JSON — and therefore the profile-cache
/// fingerprint — is independent of insertion order.
#[derive(Debug, Clone, PartialEq, Default)]
pub struct CostBook {
    pub base: CostModel,
    /// (SKU name, override), sorted by name.
    pub per_kind: Vec<(String, CostModel)>,
}

impl From<CostModel> for CostBook {
    fn from(base: CostModel) -> Self {
        CostBook::uniform(base)
    }
}

impl CostBook {
    /// One model for every kind (the homogeneous / pre-heterogeneity case).
    pub fn uniform(base: CostModel) -> Self {
        CostBook {
            base,
            per_kind: Vec::new(),
        }
    }

    /// Add (or replace) a per-SKU override, keeping name order.
    pub fn with_kind(mut self, name: impl Into<String>, model: CostModel) -> Self {
        let name = name.into();
        match self.per_kind.binary_search_by(|(n, _)| n.as_str().cmp(&name)) {
            Ok(i) => self.per_kind[i].1 = model,
            Err(i) => self.per_kind.insert(i, (name, model)),
        }
        self
    }

    /// The model pricing a SKU: its override, else the base model.
    pub fn for_kind(&self, kind: &str) -> &CostModel {
        match self.per_kind.binary_search_by(|(n, _)| n.as_str().cmp(kind)) {
            Ok(i) => &self.per_kind[i].1,
            Err(_) => &self.base,
        }
    }

    /// No per-SKU overrides: every kind prices through `base`.
    pub fn is_uniform(&self) -> bool {
        self.per_kind.is_empty()
    }

    /// Canonical JSON: the base model's fields flat (byte-identical to a
    /// bare [`CostModel`] when uniform) plus a `per_kind` object when
    /// overrides exist.
    pub fn to_json(&self) -> Json {
        let mut j = self.base.to_json();
        if !self.per_kind.is_empty() {
            if let Json::Obj(map) = &mut j {
                map.insert(
                    "per_kind".to_string(),
                    Json::Obj(
                        self.per_kind
                            .iter()
                            .map(|(n, m)| (n.clone(), m.to_json()))
                            .collect(),
                    ),
                );
            }
        }
        j
    }

    /// Lenient parse, mirroring [`CostModel::from_json`]: missing fields
    /// default, unknown keys are ignored (the service's strict validation
    /// lives in `service::protocol`).
    pub fn from_json(j: &Json) -> Self {
        let mut book = CostBook::uniform(CostModel::from_json(j));
        if let Some(per) = j.get("per_kind").and_then(Json::as_obj) {
            for (name, m) in per {
                book = book.with_kind(name.clone(), CostModel::from_json(m));
            }
        }
        book
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn a40() -> DeviceSpec {
        DeviceSpec::a40()
    }

    #[test]
    fn efficiency_is_monotone_and_bounded() {
        let cm = CostModel::default();
        let mut last = 0.0;
        for exp in 0..16 {
            let e = cm.matmul_efficiency(10f64.powi(exp));
            assert!(e >= last, "non-monotone at 1e{exp}");
            assert!(e > 0.0 && e <= cm.eff_max + 1e-12);
            last = e;
        }
    }

    #[test]
    fn big_matmul_slower_than_analytical() {
        // the realistic model must always predict >= the peak heuristic
        let cm = CostModel::default();
        let d = a40();
        for flops in [1e6 as u64, 1e9 as u64, 1e12 as u64] {
            let real = cm.op_latency_us(&d, OpClass::Matmul, flops, 1024);
            let ideal = cm.analytical_latency_us(&d, flops, 1024);
            assert!(real > ideal, "flops={flops}");
        }
    }

    #[test]
    fn analytical_gap_is_tens_of_percent_for_layer_sized_ops() {
        // Fig. 3's premise: the heuristic underestimates real time by
        // a large margin at transformer-layer scale.
        let cm = CostModel::default();
        let d = a40();
        let flops = 3_288_334_336u64; // one BERT-Large layer fwd @ seq 128
        let real = cm.op_latency_us(&d, OpClass::Matmul, flops, 25 << 20);
        let ideal = cm.analytical_latency_us(&d, flops, 25 << 20);
        let gap = (real - ideal) / real;
        assert!(
            (0.15..0.75).contains(&gap),
            "gap {gap} outside the plausible Fig.3 band"
        );
    }

    #[test]
    fn launch_overhead_dominates_tiny_ops() {
        let cm = CostModel::default();
        let d = a40();
        let t = cm.op_latency_us(&d, OpClass::Memory, 0, 64);
        assert!(t >= d.launch_overhead_us);
    }

    #[test]
    fn memory_class_is_bandwidth_priced() {
        let cm = CostModel::default();
        let d = a40();
        let t1 = cm.op_latency_us(&d, OpClass::Memory, 0, 1 << 20) - d.launch_overhead_us;
        let t2 = cm.op_latency_us(&d, OpClass::Memory, 0, 2 << 20) - d.launch_overhead_us;
        // Doubling bytes exactly doubles the bandwidth term (net of launch)
        assert!((t2 / t1 - 2.0).abs() < 1e-9, "t1={t1} t2={t2}");
    }

    #[test]
    fn scale_calibration_multiplies() {
        let mut cm = CostModel::default();
        let d = a40();
        let base = cm.op_latency_us(&d, OpClass::Matmul, 1 << 30, 1 << 20);
        cm.scale = 2.0;
        let scaled = cm.op_latency_us(&d, OpClass::Matmul, 1 << 30, 1 << 20);
        assert!((scaled / base - 2.0).abs() < 1e-9);
    }

    #[test]
    fn json_roundtrip() {
        let mut cm = CostModel::default();
        cm.scale = 1.25;
        let j = Json::parse(&cm.to_json().to_string()).unwrap();
        assert_eq!(CostModel::from_json(&j), cm);
    }

    #[test]
    fn book_resolves_overrides_by_kind_name() {
        let mut slow = CostModel::default();
        slow.scale = 2.0;
        let book = CostBook::default().with_kind("A10", slow.clone());
        assert_eq!(book.for_kind("A10"), &slow);
        assert_eq!(book.for_kind("A40"), &book.base);
        assert!(!book.is_uniform());
        assert!(CostBook::default().is_uniform());
        // the same op prices differently per SKU through the book
        let d = a40();
        let base_t = book
            .for_kind("A40")
            .op_latency_us(&d, OpClass::Matmul, 1 << 30, 1 << 20);
        let slow_t = book
            .for_kind("A10")
            .op_latency_us(&d, OpClass::Matmul, 1 << 30, 1 << 20);
        assert!((slow_t / base_t - 2.0).abs() < 1e-9);
    }

    #[test]
    fn book_with_kind_replaces_and_sorts() {
        let mut a = CostModel::default();
        a.scale = 2.0;
        let mut b = CostModel::default();
        b.scale = 3.0;
        let book = CostBook::default()
            .with_kind("Z", a.clone())
            .with_kind("A", a.clone())
            .with_kind("Z", b.clone());
        assert_eq!(book.per_kind.len(), 2);
        assert_eq!(book.per_kind[0].0, "A");
        assert_eq!(book.for_kind("Z"), &b);
    }

    #[test]
    fn book_json_roundtrip_and_uniform_compat() {
        // uniform book JSON == bare CostModel JSON (fingerprint stability)
        let mut cm = CostModel::default();
        cm.scale = 1.25;
        assert_eq!(
            CostBook::uniform(cm.clone()).to_json().to_string(),
            cm.to_json().to_string()
        );
        // roundtrip with overrides
        let mut slow = CostModel::default();
        slow.scale = 1.5;
        let book = CostBook::uniform(cm).with_kind("A10", slow);
        let j = Json::parse(&book.to_json().to_string()).unwrap();
        assert_eq!(CostBook::from_json(&j), book);
    }
}
