//! Deterministic PRNG: xoshiro256++ seeded via SplitMix64.
//!
//! The vendored offline crate set has no `rand`, so the simulator carries
//! its own generator. Only needs to be statistically decent (jitter, clock
//! skew, property-test case generation), not cryptographic.

/// xoshiro256++ generator.
#[derive(Debug, Clone)]
pub struct Rng {
    s: [u64; 4],
}

impl Rng {
    /// Seed via SplitMix64 so any u64 (including 0) gives a good state.
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let mut next_sm = || {
            sm = sm.wrapping_add(0x9E3779B97F4A7C15);
            let mut z = sm;
            z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
            z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
            z ^ (z >> 31)
        };
        Rng {
            s: [next_sm(), next_sm(), next_sm(), next_sm()],
        }
    }

    /// Derive an independent stream (e.g. one per simulated device).
    ///
    /// Collision-freedom (audited for the scenario engine, ISSUE 7):
    /// the salt is mixed by multiplication with an **odd** constant,
    /// which is invertible mod 2^64 — so for a fixed generator state,
    /// distinct salts always produce distinct child seeds. Forks taken
    /// at different times (the engine's skew/per-rank/collective/scenario
    /// forks) each consume one master draw first, so even an equal salt
    /// meets a different state; (scenario, rank) fork pairs are therefore
    /// distinct both across ranks (distinct salts, same state) and
    /// against every pre-existing fork (distinct states). Pinned by
    /// `fork_salts_are_injective_for_fixed_state` and
    /// `sequential_forks_with_equal_salt_differ` below.
    pub fn fork(&mut self, salt: u64) -> Rng {
        Rng::new(self.next_u64() ^ salt.wrapping_mul(0x9E3779B97F4A7C15))
    }

    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[0]
            .wrapping_add(self.s[3])
            .rotate_left(23)
            .wrapping_add(self.s[0]);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform in [0, 1).
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform in [lo, hi).
    pub fn range_f64(&mut self, lo: f64, hi: f64) -> f64 {
        lo + self.f64() * (hi - lo)
    }

    /// Uniform integer in [0, n).
    pub fn below(&mut self, n: u64) -> u64 {
        // Lemire-style rejection-free for our (non-adversarial) needs.
        ((self.next_u64() as u128 * n as u128) >> 64) as u64
    }

    /// Standard normal via Box–Muller.
    pub fn normal(&mut self) -> f64 {
        let u1 = self.f64().max(1e-18);
        let u2 = self.f64();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }

    /// Normal with mean/std.
    pub fn normal_ms(&mut self, mean: f64, std: f64) -> f64 {
        mean + std * self.normal()
    }

    /// Multiplicative jitter factor: max(1 + N(0, sigma), floor).
    pub fn jitter(&mut self, sigma: f64) -> f64 {
        (1.0 + self.normal() * sigma).max(0.2)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_from_seed() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        assert_ne!(a.next_u64(), b.next_u64());
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Rng::new(7);
        for _ in 0..10_000 {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn uniform_mean_near_half() {
        let mut r = Rng::new(11);
        let n = 100_000;
        let mean: f64 = (0..n).map(|_| r.f64()).sum::<f64>() / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean={mean}");
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(13);
        let n = 100_000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean).powi(2)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.02, "mean={mean}");
        assert!((var - 1.0).abs() < 0.05, "var={var}");
    }

    #[test]
    fn below_is_in_range_and_covers() {
        let mut r = Rng::new(17);
        let mut seen = [false; 8];
        for _ in 0..1000 {
            let v = r.below(8) as usize;
            assert!(v < 8);
            seen[v] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn jitter_bounded_below() {
        let mut r = Rng::new(19);
        for _ in 0..10_000 {
            assert!(r.jitter(0.5) >= 0.2);
        }
    }

    #[test]
    fn fork_salts_are_injective_for_fixed_state() {
        // distinct salts from the SAME state must give distinct streams:
        // the odd multiplier is invertible mod 2^64, so salt mixing is a
        // bijection on the child seed. Exercise rank-style salts and the
        // scenario-style xor-of-hash salts against each other.
        let base = Rng::new(42);
        let salts: Vec<u64> = (1..=64u64)
            .chain([0xC10C, 0xA11, 0xDEAD_BEEF ^ 1, 0xDEAD_BEEF ^ 2])
            .collect();
        let mut seen = std::collections::HashSet::new();
        for &s in &salts {
            let mut child = base.clone().fork(s);
            let sig = (child.next_u64(), child.next_u64());
            assert!(seen.insert(sig), "salt {s:#x} collided");
        }
    }

    #[test]
    fn sequential_forks_with_equal_salt_differ() {
        // forks taken at different times consume a master draw each, so
        // the same salt never reproduces a stream (the engine's scenario
        // forks come after the skew/rank/collective forks and cannot
        // alias them even if the salts collide)
        let mut master = Rng::new(7);
        let mut a = master.fork(0xC10C);
        let mut b = master.fork(0xC10C);
        assert_ne!(a.next_u64(), b.next_u64());
    }
}
