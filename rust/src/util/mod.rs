//! Small shared utilities: a deterministic PRNG (no `rand` offline), basic
//! statistics, and time formatting. Everything downstream (the ground-truth
//! engine's jitter, the profiler's averaging, the property-test harness)
//! draws randomness from [`Rng`] so runs are reproducible from a seed.

pub mod rng;
pub mod stats;

pub use rng::Rng;

/// Simulation time in microseconds. All layers (cost model, comm laws,
/// engine, timelines) agree on this unit.
pub type TimeUs = f64;

/// Format a microsecond duration human-readably.
pub fn fmt_us(t: TimeUs) -> String {
    if t >= 1e6 {
        format!("{:.3} s", t / 1e6)
    } else if t >= 1e3 {
        format!("{:.3} ms", t / 1e3)
    } else {
        format!("{t:.1} us")
    }
}

/// Relative error |a - b| / b (b is ground truth), in percent.
pub fn rel_err_pct(pred: f64, truth: f64) -> f64 {
    if truth == 0.0 {
        return if pred == 0.0 { 0.0 } else { f64::INFINITY };
    }
    ((pred - truth) / truth).abs() * 100.0
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fmt_us_units() {
        assert_eq!(fmt_us(1.5), "1.5 us");
        assert_eq!(fmt_us(1500.0), "1.500 ms");
        assert_eq!(fmt_us(2_500_000.0), "2.500 s");
    }

    #[test]
    fn rel_err_basic() {
        assert!((rel_err_pct(104.0, 100.0) - 4.0).abs() < 1e-12);
        assert!((rel_err_pct(96.0, 100.0) - 4.0).abs() < 1e-12);
        assert_eq!(rel_err_pct(0.0, 0.0), 0.0);
        assert!(rel_err_pct(1.0, 0.0).is_infinite());
    }
}
