//! Summary statistics used by the metrics layer and the bench harness.

/// Arithmetic mean; 0 for empty input.
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    xs.iter().sum::<f64>() / xs.len() as f64
}

/// Population standard deviation; 0 for < 2 samples.
pub fn std_dev(xs: &[f64]) -> f64 {
    if xs.len() < 2 {
        return 0.0;
    }
    let m = mean(xs);
    (xs.iter().map(|x| (x - m).powi(2)).sum::<f64>() / xs.len() as f64).sqrt()
}

/// Median (by sorting a copy); 0 for empty input.
pub fn median(xs: &[f64]) -> f64 {
    percentile(xs, 50.0)
}

/// Linear-interpolated percentile in [0, 100]; 0 for empty input.
pub fn percentile(xs: &[f64], p: f64) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    let mut v: Vec<f64> = xs.to_vec();
    v.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let rank = (p / 100.0) * (v.len() - 1) as f64;
    let lo = rank.floor() as usize;
    let hi = rank.ceil() as usize;
    if lo == hi {
        v[lo]
    } else {
        let w = rank - lo as f64;
        v[lo] * (1.0 - w) + v[hi] * w
    }
}

pub fn min(xs: &[f64]) -> f64 {
    xs.iter().copied().fold(f64::INFINITY, f64::min)
}

pub fn max(xs: &[f64]) -> f64 {
    xs.iter().copied().fold(f64::NEG_INFINITY, f64::max)
}

/// Geometric mean; panics on non-positive input.
pub fn geomean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    let s: f64 = xs
        .iter()
        .map(|&x| {
            assert!(x > 0.0, "geomean needs positive values, got {x}");
            x.ln()
        })
        .sum();
    (s / xs.len() as f64).exp()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mean_median() {
        let xs = [1.0, 2.0, 3.0, 4.0];
        assert_eq!(mean(&xs), 2.5);
        assert_eq!(median(&xs), 2.5);
        assert_eq!(median(&[1.0, 2.0, 9.0]), 2.0);
    }

    #[test]
    fn percentile_interpolates() {
        let xs = [0.0, 10.0];
        assert_eq!(percentile(&xs, 0.0), 0.0);
        assert_eq!(percentile(&xs, 100.0), 10.0);
        assert_eq!(percentile(&xs, 50.0), 5.0);
    }

    #[test]
    fn empty_inputs() {
        assert_eq!(mean(&[]), 0.0);
        assert_eq!(median(&[]), 0.0);
        assert_eq!(std_dev(&[]), 0.0);
        assert_eq!(geomean(&[]), 0.0);
    }

    #[test]
    fn std_dev_constant_is_zero() {
        assert_eq!(std_dev(&[3.0, 3.0, 3.0]), 0.0);
    }

    #[test]
    fn geomean_of_powers() {
        assert!((geomean(&[1.0, 4.0]) - 2.0).abs() < 1e-12);
    }

    #[test]
    fn min_max() {
        let xs = [3.0, -1.0, 7.0];
        assert_eq!(min(&xs), -1.0);
        assert_eq!(max(&xs), 7.0);
    }
}
