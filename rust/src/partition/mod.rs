//! Megatron-style model partitioner.
//!
//! Mirrors the role of "the model partition function in current distributed
//! training frameworks" the paper takes over (§4.1): given a model and a
//! hybrid strategy it produces, per pipeline stage, the per-rank shard of
//! work — compute events for every layer (tensor-MP sharded), the MP
//! all-reduce communication events inside layers, the inter-stage
//! activation transfer, and the DP gradient all-reduce payload.
//!
//! Both the ground-truth engine and DistSim's modeling consume this one
//! partition, exactly like the real framework deploys the same sub-models
//! that DistSim parses.

use crate::cluster::ClusterSpec;
use crate::cost::OpClass;
use crate::events::{CommEvent, CompEvent};
use crate::memory::Recompute;
use crate::model::{Layer, ModelSpec};
use crate::strategy::Strategy;

/// Per-layer, per-rank work under the strategy.
#[derive(Debug, Clone)]
pub struct LayerWork {
    /// Index into `ModelSpec::layers`.
    pub layer_idx: usize,
    /// Forward compute event for one micro-batch on one rank.
    pub fwd: CompEvent,
    /// Backward compute event (~2x forward FLOPs).
    pub bwd: CompEvent,
    /// The tensor-MP all-reduce inside this layer (None when mp == 1 or
    /// the layer is not tensor-sharded).
    pub mp_allreduce: Option<CommEvent>,
    /// How many MP all-reduces per forward pass (Megatron: 2 — attention
    /// proj + MLP fc2) and per backward pass (2 more).
    pub ar_count_fwd: usize,
    pub ar_count_bwd: usize,
    /// Parameters held by one rank for this layer.
    pub params_per_rank: u64,
}

/// One pipeline stage's per-rank work.
#[derive(Debug, Clone)]
pub struct StageWork {
    pub stage: usize,
    pub layers: Vec<LayerWork>,
    /// Activation bytes sent to the next stage per micro-batch (0 for the
    /// last stage).
    pub act_bytes: u64,
    pub params_per_rank: u64,
}

/// The full partition of a model under a strategy.
#[derive(Debug, Clone)]
pub struct Partition {
    pub strategy: Strategy,
    pub stages: Vec<StageWork>,
    /// Micro-batch size (sequences) used to size the events.
    pub micro_batch_size: usize,
    pub seq: usize,
    pub hidden: usize,
    /// Gradient bytes each rank all-reduces across its DP group.
    pub grad_bytes_per_rank: Vec<u64>,
}

/// Contiguously assign `n_layers` model layers to `pp` stages, balancing
/// counts (earlier stages get the remainder, matching Megatron's default).
pub fn stage_ranges(n_layers: usize, pp: usize) -> Vec<std::ops::Range<usize>> {
    assert!(pp >= 1 && pp <= n_layers.max(1), "pp {pp} > layers {n_layers}");
    let base = n_layers / pp;
    let extra = n_layers % pp;
    let mut out = Vec::with_capacity(pp);
    let mut start = 0;
    for s in 0..pp {
        let len = base + usize::from(s < extra);
        out.push(start..start + len);
        start += len;
    }
    out
}

fn layer_comp_events(
    layer: &Layer,
    layer_idx: usize,
    mbs: usize,
    seq: usize,
    mp: usize,
    kind: &str,
) -> (CompEvent, CompEvent, u64) {
    let tokens = (mbs * seq) as u64;
    let comp = |name: String, class: OpClass, flops: u64, bytes: u64| CompEvent {
        name,
        class,
        flops,
        bytes,
        kind: kind.to_string(),
    };
    match layer {
        Layer::Embedding { vocab, hidden } => {
            let bytes = tokens * *hidden as u64 * 4 * 2;
            let params = (*vocab * *hidden) as u64 / mp as u64;
            (
                comp(
                    format!("embed/v{vocab}h{hidden}/mp{mp}/b{mbs}s{seq}"),
                    OpClass::Gather,
                    tokens * *hidden as u64 / mp as u64,
                    bytes / mp as u64,
                ),
                comp(
                    format!("embed_bwd/v{vocab}h{hidden}/mp{mp}/b{mbs}s{seq}"),
                    OpClass::Gather,
                    tokens * *hidden as u64 / mp as u64,
                    bytes / mp as u64,
                ),
                params,
            )
        }
        Layer::Transformer(t) => {
            let flops = t.flops_fwd_mp(mbs, seq, mp);
            // bytes: weights read + activations read/written (rough but
            // consistent; the profiler measures actual times anyway)
            let bytes = t.params() * 4 / mp as u64
                + tokens * t.hidden as u64 * 4 * 8 / mp as u64;
            let _ = layer_idx;
            (
                comp(
                    format!(
                        "xfmr_fwd/h{}f{}a{}/mp{}/b{}s{}",
                        t.hidden, t.ffn, t.heads, mp, mbs, seq
                    ),
                    OpClass::Matmul,
                    flops,
                    bytes,
                ),
                comp(
                    format!(
                        "xfmr_bwd/h{}f{}a{}/mp{}/b{}s{}",
                        t.hidden, t.ffn, t.heads, mp, mbs, seq
                    ),
                    OpClass::Matmul,
                    2 * flops,
                    2 * bytes,
                ),
                t.params() / mp as u64,
            )
        }
        Layer::Head { vocab, hidden } => {
            let flops = 2 * tokens * (*hidden as u64) * (*vocab as u64) / mp as u64;
            let bytes = (*vocab * *hidden) as u64 * 4 / mp as u64;
            (
                comp(
                    format!("head/v{vocab}h{hidden}/mp{mp}/b{mbs}s{seq}"),
                    OpClass::Matmul,
                    flops,
                    bytes,
                ),
                comp(
                    format!("head_bwd/v{vocab}h{hidden}/mp{mp}/b{mbs}s{seq}"),
                    OpClass::Matmul,
                    2 * flops,
                    2 * bytes,
                ),
                (*vocab * *hidden) as u64 / mp as u64,
            )
        }
    }
}

/// Partition `model` under `strategy` for micro-batches of `mbs` sequences
/// (the historical entry point: no recomputation, no optimizer sharding).
pub fn partition(
    model: &ModelSpec,
    strategy: &Strategy,
    cluster: &ClusterSpec,
    mbs: usize,
) -> Partition {
    partition_opts(model, strategy, cluster, mbs, Recompute::None, 0)
}

/// [`partition`] with the memory-trading axes applied:
///
/// * `recompute == Full` folds each layer's forward work into its
///   backward event (flops, bytes, and the recomputed forward's MP
///   all-reduces) — the classic activation-checkpointing trade. The
///   merged event carries a distinct name (`…+rc`), so it interns, caches
///   and prices separately from the plain backward.
/// * `zero_stage == 1` shards optimizer state across the DP group; each
///   rank then re-gathers updated parameters after the step, which this
///   model folds into the existing DP collective as extra payload
///   (`grad_bytes_per_rank` grows by the parameter bytes).
///
/// Both the ground-truth engine and the analytical model consume the
/// partition, so one transformation covers every prediction path.
pub fn partition_opts(
    model: &ModelSpec,
    strategy: &Strategy,
    cluster: &ClusterSpec,
    mbs: usize,
    recompute: Recompute,
    zero_stage: u8,
) -> Partition {
    let pp = strategy.pp;
    let mp = strategy.mp;
    assert!(
        model.heads % mp == 0,
        "mp {mp} does not divide {} heads",
        model.heads
    );
    let ranges = stage_ranges(model.layers.len(), pp);

    // MP group link class, resolved through the placement map from the
    // stage-0 / dp-0 representative group. The named placements (linear /
    // fast-first / interleaved) map equal-stride rank groups to
    // translation-equivalent device sets, so one class covers every lane;
    // a hand-crafted Placement::Table can break that symmetry, in which
    // case other lanes' MP all-reduces are approximated at this class
    // (the ground-truth engine always prices each group's real devices —
    // see DESIGN.md §6).
    let mp_link = cluster.rank_group_link_class(&strategy.mp_group(0));

    let tokens = (mbs * model.seq) as u64;
    let act_bytes = tokens * model.hidden as u64 * 4;

    let mut stages = Vec::with_capacity(pp);
    for (s, range) in ranges.iter().enumerate() {
        let mut layers = Vec::with_capacity(range.len());
        let mut stage_params = 0u64;
        for li in range.clone() {
            let layer = &model.layers[li];
            // events are templated on kind 0; program builders re-stamp the
            // kind per rank (heterogeneous fleets intern one event per SKU)
            let (fwd, bwd, params) =
                layer_comp_events(layer, li, mbs, model.seq, mp, &cluster.device.name);
            // full recomputation: the backward re-runs this layer's
            // forward before differentiating it — merge the forward into
            // the backward event under a distinct name so the combined
            // kernel is profiled/priced as its own entity
            let bwd = if recompute == Recompute::Full {
                CompEvent {
                    name: format!("{}+rc", bwd.name),
                    flops: bwd.flops + fwd.flops,
                    bytes: bwd.bytes + fwd.bytes,
                    ..bwd
                }
            } else {
                bwd
            };
            let is_sharded = mp > 1;
            let mp_allreduce = if is_sharded {
                Some(CommEvent::AllReduce {
                    bytes: act_bytes,
                    group: mp,
                    link: mp_link,
                })
            } else {
                None
            };
            let (arf, mut arb) = match layer {
                Layer::Transformer(_) if is_sharded => (2, 2),
                _ if is_sharded => (1, 1),
                _ => (0, 0),
            };
            // the recomputed forward repeats its MP all-reduces inside
            // the backward phase
            if recompute == Recompute::Full {
                arb += arf;
            }
            stage_params += params;
            layers.push(LayerWork {
                layer_idx: li,
                fwd,
                bwd,
                mp_allreduce,
                ar_count_fwd: arf,
                ar_count_bwd: arb,
                params_per_rank: params,
            });
        }
        stages.push(StageWork {
            stage: s,
            layers,
            act_bytes: if s + 1 < pp { act_bytes } else { 0 },
            params_per_rank: stage_params,
        });
    }

    let grad_bytes_per_rank = stages
        .iter()
        .map(|st| {
            if strategy.dp > 1 {
                // ZeRO-1 re-gathers the sharded optimizer's updated
                // parameters after the step; fold that payload into the
                // DP collective (same ring, same link class)
                let gather = if zero_stage >= 1 {
                    st.params_per_rank * 4
                } else {
                    0
                };
                st.params_per_rank * 4 + gather
            } else {
                0
            }
        })
        .collect();

    Partition {
        strategy: *strategy,
        stages,
        micro_batch_size: mbs,
        seq: model.seq,
        hidden: model.hidden,
        grad_bytes_per_rank,
    }
}

impl Partition {
    /// Total FLOPs one rank of `stage` computes for one micro-batch fwd.
    pub fn stage_fwd_flops(&self, stage: usize) -> u64 {
        self.stages[stage].layers.iter().map(|l| l.fwd.flops).sum()
    }

    /// Max parameters any rank holds (deployability check).
    pub fn max_params_per_rank(&self) -> u64 {
        self.stages
            .iter()
            .map(|s| s.params_per_rank)
            .max()
            .unwrap_or(0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::zoo;

    fn setup(mp: usize, pp: usize, dp: usize) -> (ModelSpec, Strategy, ClusterSpec) {
        (
            zoo::bert_large(),
            Strategy::new(mp, pp, dp),
            ClusterSpec::a40_cluster(4, 4),
        )
    }

    #[test]
    fn stage_ranges_cover_all_layers_contiguously() {
        for (n, pp) in [(26, 4), (26, 1), (10, 3), (7, 7)] {
            let rs = stage_ranges(n, pp);
            assert_eq!(rs.len(), pp);
            assert_eq!(rs[0].start, 0);
            assert_eq!(rs.last().unwrap().end, n);
            for w in rs.windows(2) {
                assert_eq!(w[0].end, w[1].start);
            }
            // balanced: lengths differ by at most 1
            let lens: Vec<usize> = rs.iter().map(|r| r.len()).collect();
            assert!(lens.iter().max().unwrap() - lens.iter().min().unwrap() <= 1);
        }
    }

    #[test]
    fn partition_conserves_parameters() {
        let (m, s, c) = setup(2, 4, 2);
        let p = partition(&m, &s, &c, 4);
        let per_rank_total: u64 = p.stages.iter().map(|st| st.params_per_rank).sum();
        // all stages together, times mp ranks, give the full model
        assert_eq!(per_rank_total * s.mp as u64, m.total_params());
    }

    #[test]
    fn partition_conserves_flops() {
        let (m, s, c) = setup(4, 2, 2);
        let mbs = 4;
        let p = partition(&m, &s, &c, mbs);
        let sharded: u64 = (0..s.pp).map(|st| p.stage_fwd_flops(st)).sum();
        assert_eq!(sharded * s.mp as u64, m.flops_fwd(mbs));
    }

    #[test]
    fn mp1_has_no_allreduce_events() {
        let (m, s, c) = setup(1, 2, 2);
        let p = partition(&m, &s, &c, 4);
        for st in &p.stages {
            for l in &st.layers {
                assert!(l.mp_allreduce.is_none());
                assert_eq!(l.ar_count_fwd, 0);
            }
        }
    }

    #[test]
    fn mp2_transformer_layers_have_two_fwd_allreduces() {
        let (m, s, c) = setup(2, 1, 1);
        let p = partition(&m, &s, &c, 4);
        let xfmr = p.stages[0]
            .layers
            .iter()
            .find(|l| l.fwd.name.starts_with("xfmr"))
            .unwrap();
        assert_eq!(xfmr.ar_count_fwd, 2);
        assert_eq!(xfmr.ar_count_bwd, 2);
        assert!(xfmr.mp_allreduce.is_some());
    }

    #[test]
    fn last_stage_sends_no_activation() {
        let (m, s, c) = setup(1, 4, 1);
        let p = partition(&m, &s, &c, 4);
        assert!(p.stages[..3].iter().all(|st| st.act_bytes > 0));
        assert_eq!(p.stages[3].act_bytes, 0);
    }

    #[test]
    fn identical_layers_produce_identical_event_names() {
        // the dedup premise: all 24 BERT blocks map to one event name
        let (m, s, c) = setup(2, 1, 1);
        let p = partition(&m, &s, &c, 4);
        let names: std::collections::HashSet<String> = p.stages[0]
            .layers
            .iter()
            .filter(|l| l.fwd.name.starts_with("xfmr"))
            .map(|l| l.fwd.name.clone())
            .collect();
        assert_eq!(names.len(), 1);
    }

    #[test]
    fn bwd_is_twice_fwd_flops_for_transformer() {
        let (m, s, c) = setup(2, 2, 1);
        let p = partition(&m, &s, &c, 4);
        for st in &p.stages {
            for l in &st.layers {
                if l.fwd.name.starts_with("xfmr") {
                    assert_eq!(l.bwd.flops, 2 * l.fwd.flops);
                }
            }
        }
    }

    #[test]
    fn grad_bytes_zero_without_dp() {
        let (m, s, c) = setup(2, 2, 1);
        let p = partition(&m, &s, &c, 4);
        assert!(p.grad_bytes_per_rank.iter().all(|&b| b == 0));
        let (m2, s2, c2) = setup(2, 2, 2);
        let p2 = partition(&m2, &s2, &c2, 4);
        assert!(p2.grad_bytes_per_rank.iter().all(|&b| b > 0));
    }

    #[test]
    fn recompute_full_folds_fwd_into_bwd() {
        let (m, s, c) = setup(2, 2, 1);
        let plain = partition(&m, &s, &c, 4);
        let rc = partition_opts(&m, &s, &c, 4, Recompute::Full, 0);
        for (a, b) in plain.stages.iter().zip(&rc.stages) {
            for (la, lb) in a.layers.iter().zip(&b.layers) {
                // the forward pass itself is untouched
                assert_eq!(la.fwd, lb.fwd);
                // the backward grows by exactly the recomputed forward
                assert_eq!(lb.bwd.flops, la.bwd.flops + la.fwd.flops);
                assert_eq!(lb.bwd.bytes, la.bwd.bytes + la.fwd.bytes);
                assert_eq!(lb.bwd.name, format!("{}+rc", la.bwd.name));
                assert_eq!(lb.ar_count_bwd, la.ar_count_bwd + la.ar_count_fwd);
            }
        }
    }

    #[test]
    fn zero_stage_grows_the_dp_collective_iff_dp_gt_1() {
        let (m, s, c) = setup(1, 2, 2);
        let plain = partition(&m, &s, &c, 4);
        let zero = partition_opts(&m, &s, &c, 4, Recompute::None, 1);
        for (a, b) in plain
            .grad_bytes_per_rank
            .iter()
            .zip(&zero.grad_bytes_per_rank)
        {
            assert_eq!(*b, 2 * a, "gather payload equals the grad payload");
        }
        // without DP there is no optimizer shard to gather back
        let (m1, s1, c1) = setup(1, 2, 1);
        let z1 = partition_opts(&m1, &s1, &c1, 4, Recompute::None, 1);
        assert!(z1.grad_bytes_per_rank.iter().all(|&b| b == 0));
    }

    #[test]
    #[should_panic(expected = "does not divide")]
    fn rejects_mp_not_dividing_heads() {
        let (m, _, c) = setup(1, 1, 1);
        let s = Strategy::new(3, 1, 1);
        partition(&m, &s, &c, 4);
    }
}
