//! DistSim's hierarchical modeling (paper §4.3): compose profiled events
//! into the full-cluster timeline, level by level.
//!
//! * **Model-parallelism modeling** — each layer maps to a *composed
//!   event*: its per-rank compute event plus the Megatron MP all-reduces,
//!   replicated across the MP group ([`stage_items`]).
//! * **Pipeline-parallelism modeling** — Algorithm 1: walk the pipeline
//!   schedule, always expanding the first stage whose data dependency is
//!   satisfied, inserting the composed events plus the inter-stage
//!   point-to-point event, tracking per-stage device availability.
//! * **Data-parallelism modeling** — replicate the event-list across DP
//!   replicas and append the gradient all-reduce event per stage.
//!
//! **Heterogeneous fleets (ISSUE 4).** On a mixed-SKU cluster the same
//! layer costs different profiled times per device kind, so composition
//! generalizes in two ways: (1) a composed item's duration within an MP
//! group is the **max over the group members' kinds** — the per-layer
//! all-reduce barriers make the slowest SKU gate every step, exactly as
//! the ground-truth engine's collective barriers do; (2) the Algorithm-1
//! walk runs **once per DP replica**, because placement can give each
//! replica a different SKU profile, and the per-stage gradient all-reduce
//! then starts at the *latest* replica's availability (a barrier across
//! the DP group). On a homogeneous cluster every group has one kind and
//! every replica walks identically, so the output is bit-identical to the
//! pre-heterogeneity model.
//!
//! The output is a [`Timeline`] with the *same tags* as the ground-truth
//! engine emits, so the metrics layer aligns spans one-to-one. DistSim
//! never executes the per-rank programs — it only ever touches profiled
//! event means, which is the point of the paper.

use crate::cluster::ClusterSpec;
use crate::events::{CommEvent, Event, EventDb, EventId};
use crate::partition::Partition;
use crate::scenario::{Degrade, ScenarioSpec};
use crate::schedule::{Phase, PipelineSchedule};
use crate::strategy::RankCoords;
use crate::timeline::{Span, SpanKind, Tag, Timeline};
use crate::util::TimeUs;

/// One element of a composed event (the paper's "event list" inside a
/// composed-event): a compute event or an MP all-reduce, with enough
/// identity to emit engine-compatible tags. MP all-reduce items carry no
/// event id of their own — the ring's link class depends on the *group*
/// (which ranks, through which placement), so the pipeline walk resolves
/// each lane's all-reduce event exactly per (stage, replica) group (see
/// DESIGN.md §6: this replaced the representative-group approximation).
#[derive(Debug, Clone, Copy)]
pub enum Item {
    Comp { event: EventId, layer: u32 },
    MpAr { layer: u32, idx: u32 },
}

/// Model-parallelism modeling: the composed event-list of one stage for
/// one phase, targeting one device kind (`kind` is the SKU name stamped
/// into the compute events — heterogeneous stages compose one list per
/// kind present). Layers run in order (reversed for backward), each
/// compute event followed by its MP all-reduces.
pub fn stage_items(
    part: &Partition,
    db: &mut EventDb,
    stage: usize,
    phase: Phase,
    kind: &str,
) -> Vec<Item> {
    let work = &part.stages[stage];
    let mut items = Vec::new();
    let layers: Vec<&crate::partition::LayerWork> = match phase {
        Phase::Fwd => work.layers.iter().collect(),
        Phase::Bwd => work.layers.iter().rev().collect(),
    };
    for lw in layers {
        let (comp, ar_count) = match phase {
            Phase::Fwd => (&lw.fwd, lw.ar_count_fwd),
            Phase::Bwd => (&lw.bwd, lw.ar_count_bwd),
        };
        items.push(Item::Comp {
            event: db.intern(Event::Comp(comp.for_kind(kind))),
            layer: lw.layer_idx as u32,
        });
        if lw.mp_allreduce.is_some() {
            for k in 0..ar_count {
                items.push(Item::MpAr {
                    layer: lw.layer_idx as u32,
                    idx: k as u32,
                });
            }
        }
    }
    items
}

/// The full DistSim prediction for one configuration.
pub struct DistSim<'a> {
    pub part: &'a Partition,
    pub sched: &'a PipelineSchedule,
    pub cluster: &'a ClusterSpec,
}

impl<'a> DistSim<'a> {
    pub fn new(
        part: &'a Partition,
        sched: &'a PipelineSchedule,
        cluster: &'a ClusterSpec,
    ) -> Self {
        DistSim {
            part,
            sched,
            cluster,
        }
    }

    /// Hierarchical modeling: MP composition → Algorithm-1 pipeline walk
    /// (per DP replica) → DP expansion. `db` must contain profiled times
    /// for every event the partition references on every device kind in
    /// use (run `profile::profile_events` after `engine::build_programs`,
    /// which interns the full per-kind set).
    pub fn predict(&self, db: &mut EventDb) -> Timeline {
        self.predict_with(db, None)
    }

    /// The analytical degradation-aware walk (ISSUE 7): the same
    /// hierarchical model with every composed duration scaled by a
    /// scenario's time-weighted effective factors — compute by the
    /// slowest degraded MP-group member, transfers and all-reduces by
    /// their link class's effective bandwidth/latency multipliers. The
    /// `None` path is the exact pre-scenario walk (every adjustment is
    /// behind `if let Some`), which keeps sweep responses bit-identical
    /// without a scenario.
    pub fn predict_degraded(&self, db: &mut EventDb, deg: &Degrade) -> Timeline {
        self.predict_with(db, Some(deg))
    }

    fn predict_with(&self, db: &mut EventDb, deg: Option<&Degrade>) -> Timeline {
        let strategy = self.part.strategy;
        let pp = strategy.pp;
        let dpn = strategy.dp;
        let rank_dev = self.cluster.rank_to_device();
        let kind_of_rank =
            |rank: usize| self.cluster.device_kind(rank_dev[rank]);

        // -- model parallelism modeling: composed event lists ------------
        // kinds present per stage (across every mp x dp lane), ascending
        let stage_kinds: Vec<Vec<usize>> = (0..pp)
            .map(|s| {
                let mut ks: Vec<usize> = (0..strategy.mp)
                    .flat_map(|m| {
                        (0..dpn).map(move |d| (m, d))
                    })
                    .map(|(m, d)| {
                        kind_of_rank(strategy.rank_of(RankCoords { mp: m, pp: s, dp: d }))
                    })
                    .collect();
                ks.sort_unstable();
                ks.dedup();
                ks
            })
            .collect();
        // composed items per (stage, kind-slot), aligned with stage_kinds
        let items_for = |db: &mut EventDb, phase: Phase| -> Vec<Vec<Vec<Item>>> {
            (0..pp)
                .map(|s| {
                    stage_kinds[s]
                        .iter()
                        .map(|&k| {
                            stage_items(self.part, db, s, phase, self.cluster.kind_name(k))
                        })
                        .collect()
                })
                .collect()
        };
        let fwd_items = items_for(db, Phase::Fwd);
        let bwd_items = items_for(db, Phase::Bwd);

        // MP all-reduce events, exact per (stage, replica) group: each
        // lane's ring resolves its own link class through the placement
        // map. Under the named placements every lane's group is
        // translation-equivalent (one class covers the stage), but a
        // hand-crafted Placement::Table can put sibling lanes on
        // different classes — the engine prices each group's real
        // devices, so the model must too (DESIGN.md §6).
        let mp_ar_ev: Vec<Vec<Option<EventId>>> = (0..pp)
            .map(|s| {
                (0..dpn)
                    .map(|d| -> Option<EventId> {
                        let tmpl = self.part.stages[s]
                            .layers
                            .iter()
                            .find_map(|lw| lw.mp_allreduce.as_ref())?;
                        // one template covers the stage (the partitioner
                        // gives every layer the same payload) — enforced,
                        // mirroring engine::build_programs
                        debug_assert!(
                            self.part.stages[s]
                                .layers
                                .iter()
                                .filter_map(|lw| lw.mp_allreduce.as_ref())
                                .all(|a| a == tmpl),
                            "per-layer MP all-reduce templates diverged within a stage"
                        );
                        let CommEvent::AllReduce { bytes, group, .. } = tmpl else {
                            return None;
                        };
                        let members: Vec<usize> = (0..strategy.mp)
                            .map(|m| {
                                rank_dev[strategy
                                    .rank_of(RankCoords { mp: m, pp: s, dp: d })]
                            })
                            .collect();
                        Some(db.intern(Event::Comm(CommEvent::AllReduce {
                            bytes: *bytes,
                            group: *group,
                            link: self.cluster.group_link_class(&members),
                        })))
                    })
                    .collect()
            })
            .collect();

        // inter-stage p2p events (boundary s -> s+1), per DP replica: each
        // replica's mp-0 lane resolves its own link class through the
        // placement map — under a scattered placement replica k's hop can
        // cross nodes where replica 0's does not, and the engine prices
        // each rank pair individually, so the model must too
        let p2p_fwd: Vec<Vec<Option<EventId>>> = (0..dpn)
            .map(|d| {
                (0..pp)
                    .map(|s| {
                        if s + 1 < pp {
                            let a = strategy.rank_of(RankCoords { mp: 0, pp: s, dp: d });
                            let b =
                                strategy.rank_of(RankCoords { mp: 0, pp: s + 1, dp: d });
                            Some(db.intern(Event::Comm(CommEvent::P2p {
                                bytes: self.part.stages[s].act_bytes,
                                link: self.cluster.link_class(rank_dev[a], rank_dev[b]),
                            })))
                        } else {
                            None
                        }
                    })
                    .collect()
            })
            .collect();

        // scenario degradation of one communication duration: resolve the
        // event's link class and apply the effective bandwidth/latency
        // multipliers (identity without a degrade)
        let degrade_link = |db: &EventDb, ev: EventId, dur: TimeUs| -> TimeUs {
            match deg {
                None => dur,
                Some(dg) => {
                    let link = match db.get(ev) {
                        Event::Comm(CommEvent::P2p { link, .. })
                        | Event::Comm(CommEvent::AllReduce { link, .. }) => *link,
                        Event::Comp(_) => return dur,
                    };
                    dg.link_dur(link, dur, self.cluster.lat_us(link))
                }
            }
        };

        // -- pipeline parallelism modeling (Algorithm 1), per DP replica --
        let m = self.sched.micro_batches;
        // spans per (replica, logical stage); replicated over MP at the end
        let mut stage_spans: Vec<Vec<Vec<(TimeUs, TimeUs, Tag)>>> =
            vec![vec![Vec::new(); pp]; dpn];
        let mut free_all = vec![vec![0.0f64; pp]; dpn];

        for d in 0..dpn {
            // this replica's per-stage kind subset (over its MP group) and
            // sender-side launch overheads (mp-0 representative)
            let lane_kinds: Vec<Vec<usize>> = (0..pp)
                .map(|s| {
                    let mut ks: Vec<usize> = (0..strategy.mp)
                        .map(|mp| {
                            kind_of_rank(strategy.rank_of(RankCoords { mp, pp: s, dp: d }))
                        })
                        .collect();
                    ks.sort_unstable();
                    ks.dedup();
                    ks
                })
                .collect();
            let launch: Vec<f64> = (0..pp)
                .map(|s| {
                    let r = strategy.rank_of(RankCoords { mp: 0, pp: s, dp: d });
                    self.cluster.kind_spec(kind_of_rank(r)).launch_overhead_us
                })
                .collect();
            // composed item duration: compute is the max over the lane's
            // kinds — the MP all-reduce barriers make the slowest member
            // gate each step — and all-reduces price this lane's own
            // group (exact link class through the placement map)
            let lane_dur = |db: &EventDb, items: &[Vec<Item>], s: usize, i: usize| {
                match items[0][i] {
                    Item::MpAr { .. } => {
                        let ev = mp_ar_ev[s][d].expect("mp > 1 lane composes an all-reduce");
                        degrade_link(db, ev, db.elapsed(ev))
                    }
                    Item::Comp { .. } => match deg {
                        // happy path: the max over the lane's kinds
                        None => lane_kinds[s]
                            .iter()
                            .map(|k| {
                                let slot = stage_kinds[s]
                                    .iter()
                                    .position(|sk| sk == k)
                                    .expect("lane kind enumerated per stage");
                                let Item::Comp { event, .. } = items[slot][i] else {
                                    unreachable!("kind slots share one item layout")
                                };
                                db.elapsed(event)
                            })
                            .fold(f64::NEG_INFINITY, f64::max),
                        // degraded: the max over the lane's *members* —
                        // a straggler slows its own device's copy, and
                        // the MP barrier makes the slowest member gate
                        Some(dg) => (0..strategy.mp)
                            .map(|mp| {
                                let rank =
                                    strategy.rank_of(RankCoords { mp, pp: s, dp: d });
                                let slot = stage_kinds[s]
                                    .iter()
                                    .position(|sk| *sk == kind_of_rank(rank))
                                    .expect("lane kind enumerated per stage");
                                let Item::Comp { event, .. } = items[slot][i] else {
                                    unreachable!("kind slots share one item layout")
                                };
                                db.elapsed(event) * dg.comp_factor(rank_dev[rank])
                            })
                            .fold(f64::NEG_INFINITY, f64::max),
                    },
                }
            };

            let mut queue_pos = vec![0usize; pp];
            let free = &mut free_all[d];
            let mut done_f = vec![vec![None::<TimeUs>; m]; pp];
            let mut done_b = vec![vec![None::<TimeUs>; m]; pp];

            let total: usize = self.sched.stage_tasks.iter().map(Vec::len).sum();
            let mut processed = 0usize;
            while processed < total {
                let mut advanced = false;
                for s in 0..pp {
                    let pos = queue_pos[s];
                    if pos >= self.sched.stage_tasks[s].len() {
                        continue;
                    }
                    let task = self.sched.stage_tasks[s][pos];
                    let (mb, phase) = (task.mb, task.phase);
                    // first_available: data dependency satisfied?
                    let upstream_done = match phase {
                        Phase::Fwd if s > 0 => done_f[s - 1][mb],
                        Phase::Bwd if s + 1 < pp => done_b[s + 1][mb],
                        _ => Some(0.0),
                    };
                    let Some(dep_done) = upstream_done else {
                        continue;
                    };

                    let mut cur = free[s];
                    // inter-stage transfer (a p2p communication event);
                    // the sender pays its own SKU's launch overhead
                    let (recv_ev, sender) = match phase {
                        Phase::Fwd if s > 0 => (p2p_fwd[d][s - 1], Some(s - 1)),
                        Phase::Bwd if s + 1 < pp => (p2p_fwd[d][s], Some(s + 1)),
                        _ => (None, None),
                    };
                    if let Some(ev) = recv_ev {
                        let send_post = dep_done + launch[sender.unwrap()];
                        let start = cur.max(send_post);
                        let dur = degrade_link(db, ev, db.elapsed(ev));
                        stage_spans[d][s].push((
                            start,
                            start + dur,
                            Tag {
                                stage: s as u32,
                                mb: mb as u32,
                                phase,
                                layer: u32::MAX,
                                kind: SpanKind::P2p,
                                idx: 0,
                            },
                        ));
                        cur = start + dur;
                    }

                    // composed events of this stage
                    let items = match phase {
                        Phase::Fwd => &fwd_items[s],
                        Phase::Bwd => &bwd_items[s],
                    };
                    for (i, item) in items[0].iter().enumerate() {
                        let tag = match *item {
                            Item::Comp { layer, .. } => Tag {
                                stage: s as u32,
                                mb: mb as u32,
                                phase,
                                layer,
                                kind: SpanKind::Comp,
                                idx: 0,
                            },
                            Item::MpAr { layer, idx, .. } => Tag {
                                stage: s as u32,
                                mb: mb as u32,
                                phase,
                                layer,
                                kind: SpanKind::MpAllReduce,
                                idx,
                            },
                        };
                        let dur = lane_dur(db, items, s, i);
                        stage_spans[d][s].push((cur, cur + dur, tag));
                        cur += dur;
                    }

                    match phase {
                        Phase::Fwd => done_f[s][mb] = Some(cur),
                        Phase::Bwd => done_b[s][mb] = Some(cur),
                    }
                    // sender-side launch overhead for the outgoing transfer
                    let sends = matches!(phase, Phase::Fwd if s + 1 < pp)
                        || matches!(phase, Phase::Bwd if s > 0);
                    if sends {
                        cur += launch[s];
                    }
                    free[s] = cur;
                    queue_pos[s] += 1;
                    processed += 1;
                    advanced = true;
                }
                assert!(
                    advanced,
                    "pipeline modeling stuck: schedule has an unsatisfiable dependency"
                );
            }
        }

        // -- data parallelism modeling: expansion + gradient all-reduce --
        // one event per (stage, mp lane), each lane's DP group resolving
        // its *own* link class through the placement map. Under the named
        // placements sibling lanes are translation-equivalent (the events
        // intern to one id); a hand-crafted Placement::Table can give
        // lanes different classes, and each is priced exactly — matching
        // the engine, which always prices each group's real devices.
        let grad_ar: Vec<Vec<Option<EventId>>> = (0..pp)
            .map(|s| {
                (0..strategy.mp)
                    .map(|m| {
                        if strategy.dp > 1 {
                            let group = strategy.dp_group(
                                strategy.rank_of(RankCoords { mp: m, pp: s, dp: 0 }),
                            );
                            let group_devs: Vec<usize> =
                                group.iter().map(|&r| rank_dev[r]).collect();
                            Some(db.intern(Event::Comm(CommEvent::AllReduce {
                                bytes: self.part.grad_bytes_per_rank[s],
                                group: strategy.dp,
                                link: self.cluster.group_link_class(&group_devs),
                            })))
                        } else {
                            None
                        }
                    })
                    .collect()
            })
            .collect();
        // the gradient all-reduce is a barrier across replicas: it starts
        // when the *last* replica's stage becomes free
        let ar_start: Vec<TimeUs> = (0..pp)
            .map(|s| {
                (0..dpn)
                    .map(|d| free_all[d][s])
                    .fold(f64::NEG_INFINITY, f64::max)
            })
            .collect();

        let per_lane: usize = stage_spans
            .iter()
            .map(|per_d| per_d.iter().map(Vec::len).sum::<usize>())
            .sum();
        let grad_spans = grad_ar
            .iter()
            .map(|per_m| per_m.iter().filter(|g| g.is_some()).count())
            .sum::<usize>()
            * dpn;
        let mut timeline = Timeline::with_capacity(
            strategy.world_size(),
            strategy.mp * per_lane + grad_spans,
        );
        for dp in 0..dpn {
            for s in 0..pp {
                for mp in 0..strategy.mp {
                    let device = strategy.rank_of(RankCoords { mp, pp: s, dp });
                    for &(start, end, tag) in &stage_spans[dp][s] {
                        timeline.push(Span {
                            device,
                            start,
                            end,
                            tag,
                        });
                    }
                    if let Some(ev) = grad_ar[s][mp] {
                        let dur = degrade_link(db, ev, db.elapsed(ev));
                        timeline.push(Span {
                            device,
                            start: ar_start[s],
                            end: ar_start[s] + dur,
                            tag: Tag {
                                stage: s as u32,
                                mb: 0,
                                phase: Phase::Bwd,
                                layer: u32::MAX,
                                kind: SpanKind::GradAllReduce,
                                idx: 0,
                            },
                        });
                    }
                }
            }
        }
        timeline.finalize();
        timeline
    }

    /// Predicted iteration (batch) time in microseconds.
    pub fn predict_batch_time_us(&self, db: &mut EventDb) -> f64 {
        self.predict(db).batch_time_us()
    }

    /// Two-pass scenario prediction: the nominal walk fixes the horizon,
    /// the scenario's episodes are time-weighted over it
    /// ([`ScenarioSpec::degrade_over`]), and a second walk applies the
    /// effective factors. Returns `(nominal_us, degraded_us)`; resize and
    /// failure accounting compose on top
    /// ([`ScenarioSpec::compose_batch_us`]). With an identity degrade the
    /// second walk is skipped and both numbers are bit-identical.
    pub fn predict_batch_time_us_scenario(
        &self,
        db: &mut EventDb,
        spec: &ScenarioSpec,
    ) -> (f64, f64) {
        let nominal = self.predict_batch_time_us(db);
        let deg = spec.degrade_over(self.cluster.total_devices(), nominal);
        if deg.is_identity() {
            return (nominal, nominal);
        }
        let degraded = self.predict_degraded(db, &deg).batch_time_us();
        (nominal, degraded)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cost::CostBook;
    use crate::model::zoo;
    use crate::partition::partition;
    use crate::profile::profile_events;
    use crate::schedule;
    use crate::strategy::Strategy;

    /// Profile (noise-free) + predict for one strategy on `cluster`.
    fn predict_on(
        mp: usize,
        pp: usize,
        dp: usize,
        m: usize,
        c: &ClusterSpec,
    ) -> Timeline {
        let model = zoo::bert_large();
        let s = Strategy::new(mp, pp, dp);
        let part = partition(&model, &s, c, 4);
        let sched = schedule::dapple(pp, m);
        let mut db = EventDb::new();
        let ds = DistSim::new(&part, &sched, c);
        // build_programs interns the full per-rank (per-kind) event set;
        // profiling then covers everything predict() touches
        crate::engine::build_programs(&part, &sched, c, &mut db);
        profile_events(&mut db, c, &CostBook::default(), 0.0, 1, 99);
        ds.predict(&mut db)
    }

    fn predict(mp: usize, pp: usize, dp: usize, m: usize) -> Timeline {
        predict_on(mp, pp, dp, m, &ClusterSpec::a40_cluster(4, 4))
    }

    #[test]
    fn predicts_positive_batch_time_for_hybrid_shapes() {
        for (mp, pp, dp, m) in [(1, 1, 1, 1), (2, 2, 2, 4), (1, 4, 1, 8), (4, 1, 2, 2)] {
            let t = predict(mp, pp, dp, m);
            assert!(t.batch_time_us() > 0.0);
            assert_eq!(
                t.n_devices,
                mp * pp * dp,
                "timeline covers the whole world"
            );
        }
    }

    #[test]
    fn mp_replicas_have_identical_spans() {
        let t = predict(2, 2, 1, 2);
        // devices 0,1 are the MP pair of stage 0
        let a = t.device_spans(0);
        let b = t.device_spans(1);
        assert_eq!(a.len(), b.len());
        for (x, y) in a.iter().zip(b) {
            assert_eq!(x.start, y.start);
            assert_eq!(x.tag, y.tag);
        }
    }

    #[test]
    fn dp_replicas_have_identical_spans() {
        let t = predict(1, 2, 2, 2);
        let a = t.device_spans(0); // (pp0, dp0)
        let b = t.device_spans(2); // (pp0, dp1)
        assert_eq!(a.len(), b.len());
        for (x, y) in a.iter().zip(b) {
            assert_eq!(x.start, y.start);
        }
    }

    #[test]
    fn pipeline_stages_are_causally_ordered() {
        let t = predict(1, 4, 1, 4);
        // F(mb=0) completion at stage s must precede F start at stage s+1
        for s in 0..3usize {
            let up: Vec<_> = t
                .device_comp_spans(s)
                .into_iter()
                .filter(|sp| sp.tag.mb == 0 && sp.tag.phase == Phase::Fwd)
                .collect();
            let down: Vec<_> = t
                .device_comp_spans(s + 1)
                .into_iter()
                .filter(|sp| sp.tag.mb == 0 && sp.tag.phase == Phase::Fwd)
                .collect();
            let up_end = up.iter().map(|x| x.end).fold(f64::NEG_INFINITY, f64::max);
            let down_start = down.iter().map(|x| x.start).fold(f64::INFINITY, f64::min);
            assert!(down_start >= up_end, "stage {s} causality");
        }
    }

    #[test]
    fn grad_allreduce_present_iff_dp() {
        let t1 = predict(1, 2, 1, 2);
        assert!(!t1
            .spans()
            .iter()
            .any(|s| s.tag.kind == SpanKind::GradAllReduce));
        let t2 = predict(1, 2, 2, 2);
        assert!(t2
            .spans()
            .iter()
            .any(|s| s.tag.kind == SpanKind::GradAllReduce));
    }

    #[test]
    fn identity_degrade_is_bit_identical_to_predict() {
        let model = zoo::bert_large();
        let s = Strategy::new(2, 2, 2);
        let c = ClusterSpec::a40_cluster(4, 4);
        let part = partition(&model, &s, &c, 4);
        let sched = schedule::dapple(2, 4);
        let mut db = EventDb::new();
        crate::engine::build_programs(&part, &sched, &c, &mut db);
        profile_events(&mut db, &c, &CostBook::default(), 0.0, 1, 99);
        let ds = DistSim::new(&part, &sched, &c);
        let plain = ds.predict(&mut db);
        let deg = crate::scenario::ScenarioSpec::default()
            .degrade_over(c.total_devices(), 1000.0);
        let degraded = ds.predict_degraded(&mut db, &deg);
        assert_eq!(plain.len(), degraded.len());
        for (a, b) in plain.spans().iter().zip(degraded.spans()) {
            assert_eq!(a, b);
        }
        // the two-pass scenario path agrees too
        let (nom, deg_us) =
            ds.predict_batch_time_us_scenario(&mut db, &crate::scenario::ScenarioSpec::default());
        assert_eq!(nom, deg_us);
        assert_eq!(nom, plain.batch_time_us());
    }

    #[test]
    fn degraded_walk_is_slower_under_stragglers_and_link_episodes() {
        use crate::scenario::{LinkEpisode, ScenarioSpec, Straggler};
        let model = zoo::bert_large();
        let s = Strategy::new(1, 2, 2);
        let c = ClusterSpec::a40_cluster(4, 4);
        let part = partition(&model, &s, &c, 4);
        let sched = schedule::dapple(2, 4);
        let mut db = EventDb::new();
        crate::engine::build_programs(&part, &sched, &c, &mut db);
        profile_events(&mut db, &c, &CostBook::default(), 0.0, 1, 99);
        let ds = DistSim::new(&part, &sched, &c);
        let nominal = ds.predict_batch_time_us(&mut db);
        let strag = ScenarioSpec {
            stragglers: vec![Straggler { device: 0, factor: 1.5 }],
            ..ScenarioSpec::default()
        };
        let (_, strag_us) = ds.predict_batch_time_us_scenario(&mut db, &strag);
        assert!(strag_us > nominal, "straggler {strag_us} !> {nominal}");
        let link = ScenarioSpec {
            link_episodes: vec![LinkEpisode {
                link: crate::cluster::LinkClass::Intra,
                bw_factor: 3.0,
                lat_factor: 2.0,
                start_us: 0.0,
                end_us: f64::MAX,
            }],
            ..ScenarioSpec::default()
        };
        let (_, link_us) = ds.predict_batch_time_us_scenario(&mut db, &link);
        assert!(link_us > nominal, "link episode {link_us} !> {nominal}");
    }

    #[test]
    fn mixed_fleet_prediction_sits_between_homogeneous_bounds() {
        // A40+A10 mixed cluster: predicted batch time must be slower than
        // the all-A40 fleet, no slower than the all-A10 fleet (the slowest
        // SKU gates, it never accelerates), and strictly different from
        // the fast homogeneous baseline — the tentpole claim of ISSUE 4.
        let fast = ClusterSpec::a40_cluster(2, 4);
        let mut slow = ClusterSpec::a40_cluster(2, 4);
        slow.device = crate::cluster::DeviceSpec::a10();
        let mixed = ClusterSpec::mixed_a40_a10(2, 4);
        for (mp, pp, dp, m) in [(1, 4, 2, 4), (2, 2, 2, 4), (1, 8, 1, 8)] {
            let tf = predict_on(mp, pp, dp, m, &fast).batch_time_us();
            let ts = predict_on(mp, pp, dp, m, &slow).batch_time_us();
            let tm = predict_on(mp, pp, dp, m, &mixed).batch_time_us();
            assert!(tm > tf * 1.001, "{mp}M{pp}P{dp}D: mixed {tm} !> fast {tf}");
            assert!(tm <= ts * 1.001, "{mp}M{pp}P{dp}D: mixed {tm} !<= slow {ts}");
        }
    }

    #[test]
    fn placement_changes_mixed_fleet_predictions() {
        use crate::cluster::Placement;
        // 1M4P1D on a 2x4 mixed cluster: fast-first packs every stage onto
        // A40s (ranks 0-3 -> node 0); interleaved alternates SKUs, so the
        // pipeline is gated by A10 stages — the predictions must differ,
        // and fast-first must win
        let base = ClusterSpec::mixed_a40_a10(2, 4);
        let ff = predict_on(1, 4, 1, 8, &base.with_placement(Placement::FastFirst))
            .batch_time_us();
        let il = predict_on(1, 4, 1, 8, &base.with_placement(Placement::Interleaved))
            .batch_time_us();
        assert!(
            ff < il * 0.999,
            "fast-first ({ff}) should beat interleaved ({il}) for a 4-stage pipeline"
        );
        // and fast-first on the mixed fleet matches the all-A40 prediction
        // (all four ranks land on A40 silicon, same links)
        let all_fast = predict_on(1, 4, 1, 8, &ClusterSpec::a40_cluster(2, 4))
            .batch_time_us();
        assert_eq!(ff, all_fast, "fast-first == homogeneous-fast placement");
    }
}
