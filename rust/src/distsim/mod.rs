//! DistSim's hierarchical modeling (paper §4.3): compose profiled events
//! into the full-cluster timeline, level by level.
//!
//! * **Model-parallelism modeling** — each layer maps to a *composed
//!   event*: its per-rank compute event plus the Megatron MP all-reduces,
//!   replicated across the MP group ([`stage_items`]).
//! * **Pipeline-parallelism modeling** — Algorithm 1: walk the pipeline
//!   schedule, always expanding the first stage whose data dependency is
//!   satisfied, inserting the composed events plus the inter-stage
//!   point-to-point event, tracking per-stage device availability.
//! * **Data-parallelism modeling** — replicate the event-list across DP
//!   replicas and append the gradient all-reduce event per stage.
//!
//! The output is a [`Timeline`] with the *same tags* as the ground-truth
//! engine emits, so the metrics layer aligns spans one-to-one. DistSim
//! never executes the per-rank programs — it only ever touches profiled
//! event means, which is the point of the paper.

use crate::cluster::ClusterSpec;
use crate::events::{CommEvent, Event, EventDb, EventId};
use crate::partition::Partition;
use crate::schedule::{Phase, PipelineSchedule};
use crate::strategy::RankCoords;
use crate::timeline::{Span, SpanKind, Tag, Timeline};
use crate::util::TimeUs;

/// One element of a composed event (the paper's "event list" inside a
/// composed-event): a compute event or an MP all-reduce, with enough
/// identity to emit engine-compatible tags.
#[derive(Debug, Clone, Copy)]
pub enum Item {
    Comp { event: EventId, layer: u32 },
    MpAr { event: EventId, layer: u32, idx: u32 },
}

/// Model-parallelism modeling: the composed event-list of one stage for
/// one phase. Layers run in order (reversed for backward), each compute
/// event followed by its MP all-reduces.
pub fn stage_items(
    part: &Partition,
    db: &mut EventDb,
    stage: usize,
    phase: Phase,
) -> Vec<Item> {
    let work = &part.stages[stage];
    let mut items = Vec::new();
    let layers: Vec<&crate::partition::LayerWork> = match phase {
        Phase::Fwd => work.layers.iter().collect(),
        Phase::Bwd => work.layers.iter().rev().collect(),
    };
    for lw in layers {
        let (comp, ar_count) = match phase {
            Phase::Fwd => (&lw.fwd, lw.ar_count_fwd),
            Phase::Bwd => (&lw.bwd, lw.ar_count_bwd),
        };
        items.push(Item::Comp {
            event: db.intern(Event::Comp(comp.clone())),
            layer: lw.layer_idx as u32,
        });
        if let Some(ar) = &lw.mp_allreduce {
            let ev = db.intern(Event::Comm(ar.clone()));
            for k in 0..ar_count {
                items.push(Item::MpAr {
                    event: ev,
                    layer: lw.layer_idx as u32,
                    idx: k as u32,
                });
            }
        }
    }
    items
}

/// The full DistSim prediction for one configuration.
pub struct DistSim<'a> {
    pub part: &'a Partition,
    pub sched: &'a PipelineSchedule,
    pub cluster: &'a ClusterSpec,
}

impl<'a> DistSim<'a> {
    pub fn new(
        part: &'a Partition,
        sched: &'a PipelineSchedule,
        cluster: &'a ClusterSpec,
    ) -> Self {
        DistSim {
            part,
            sched,
            cluster,
        }
    }

    /// Hierarchical modeling: MP composition → Algorithm-1 pipeline walk →
    /// DP expansion. `db` must contain profiled times for every event the
    /// partition references (run `profile::profile_events` first).
    pub fn predict(&self, db: &mut EventDb) -> Timeline {
        let strategy = self.part.strategy;
        let pp = strategy.pp;
        let launch = self.cluster.device.launch_overhead_us;

        // -- model parallelism modeling: composed event lists ------------
        let fwd_items: Vec<Vec<Item>> = (0..pp)
            .map(|s| stage_items(self.part, db, s, Phase::Fwd))
            .collect();
        let bwd_items: Vec<Vec<Item>> = (0..pp)
            .map(|s| stage_items(self.part, db, s, Phase::Bwd))
            .collect();

        // inter-stage p2p events (boundary s -> s+1); link class from the
        // representative dp-0 lane (homogeneous layout)
        let p2p_fwd: Vec<Option<EventId>> = (0..pp)
            .map(|s| {
                if s + 1 < pp {
                    let a = strategy.rank_of(RankCoords { mp: 0, pp: s, dp: 0 });
                    let b = strategy.rank_of(RankCoords { mp: 0, pp: s + 1, dp: 0 });
                    Some(db.intern(Event::Comm(CommEvent::P2p {
                        bytes: self.part.stages[s].act_bytes,
                        link: self.cluster.link_class(a, b),
                    })))
                } else {
                    None
                }
            })
            .collect();

        // -- pipeline parallelism modeling (Algorithm 1) ------------------
        let m = self.sched.micro_batches;
        let mut queue_pos = vec![0usize; pp];
        let mut free = vec![0.0f64; pp];
        let mut done_f = vec![vec![None::<TimeUs>; m]; pp];
        let mut done_b = vec![vec![None::<TimeUs>; m]; pp];
        // spans per logical stage (replicated over MP and DP at the end)
        let mut stage_spans: Vec<Vec<(TimeUs, TimeUs, Tag)>> = vec![Vec::new(); pp];

        let total: usize = self.sched.stage_tasks.iter().map(Vec::len).sum();
        let mut processed = 0usize;
        while processed < total {
            let mut advanced = false;
            for s in 0..pp {
                let pos = queue_pos[s];
                if pos >= self.sched.stage_tasks[s].len() {
                    continue;
                }
                let task = self.sched.stage_tasks[s][pos];
                let (mb, phase) = (task.mb, task.phase);
                // first_available: data dependency satisfied?
                let upstream_done = match phase {
                    Phase::Fwd if s > 0 => done_f[s - 1][mb],
                    Phase::Bwd if s + 1 < pp => done_b[s + 1][mb],
                    _ => Some(0.0),
                };
                let Some(dep_done) = upstream_done else {
                    continue;
                };

                let mut cur = free[s];
                // inter-stage transfer (a p2p communication event)
                let recv_ev = match phase {
                    Phase::Fwd if s > 0 => p2p_fwd[s - 1],
                    Phase::Bwd if s + 1 < pp => p2p_fwd[s],
                    _ => None,
                };
                if let Some(ev) = recv_ev {
                    let send_post = dep_done + launch;
                    let start = cur.max(send_post);
                    let dur = db.elapsed(ev);
                    stage_spans[s].push((
                        start,
                        start + dur,
                        Tag {
                            stage: s as u32,
                            mb: mb as u32,
                            phase,
                            layer: u32::MAX,
                            kind: SpanKind::P2p,
                            idx: 0,
                        },
                    ));
                    cur = start + dur;
                }

                // composed events of this stage
                let items = match phase {
                    Phase::Fwd => &fwd_items[s],
                    Phase::Bwd => &bwd_items[s],
                };
                for item in items {
                    let (ev, tag) = match *item {
                        Item::Comp { event, layer } => (
                            event,
                            Tag {
                                stage: s as u32,
                                mb: mb as u32,
                                phase,
                                layer,
                                kind: SpanKind::Comp,
                                idx: 0,
                            },
                        ),
                        Item::MpAr { event, layer, idx } => (
                            event,
                            Tag {
                                stage: s as u32,
                                mb: mb as u32,
                                phase,
                                layer,
                                kind: SpanKind::MpAllReduce,
                                idx,
                            },
                        ),
                    };
                    let dur = db.elapsed(ev);
                    stage_spans[s].push((cur, cur + dur, tag));
                    cur += dur;
                }

                match phase {
                    Phase::Fwd => done_f[s][mb] = Some(cur),
                    Phase::Bwd => done_b[s][mb] = Some(cur),
                }
                // sender-side launch overhead for the outgoing transfer
                let sends = matches!(phase, Phase::Fwd if s + 1 < pp)
                    || matches!(phase, Phase::Bwd if s > 0);
                if sends {
                    cur += launch;
                }
                free[s] = cur;
                queue_pos[s] += 1;
                processed += 1;
                advanced = true;
            }
            assert!(
                advanced,
                "pipeline modeling stuck: schedule has an unsatisfiable dependency"
            );
        }

        // -- data parallelism modeling: expansion + gradient all-reduce --
        let grad_ar: Vec<Option<EventId>> = (0..pp)
            .map(|s| {
                if strategy.dp > 1 {
                    let group = strategy.dp_group(
                        strategy.rank_of(RankCoords { mp: 0, pp: s, dp: 0 }),
                    );
                    Some(db.intern(Event::Comm(CommEvent::AllReduce {
                        bytes: self.part.grad_bytes_per_rank[s],
                        group: strategy.dp,
                        link: self.cluster.group_link_class(&group),
                    })))
                } else {
                    None
                }
            })
            .collect();

        let per_lane: usize = stage_spans.iter().map(Vec::len).sum();
        let grad_lanes = grad_ar.iter().filter(|g| g.is_some()).count();
        let mut timeline = Timeline::with_capacity(
            strategy.world_size(),
            strategy.mp * strategy.dp * (per_lane + grad_lanes),
        );
        for dp in 0..strategy.dp {
            for s in 0..pp {
                for mp in 0..strategy.mp {
                    let device = strategy.rank_of(RankCoords { mp, pp: s, dp });
                    for &(start, end, tag) in &stage_spans[s] {
                        timeline.push(Span {
                            device,
                            start,
                            end,
                            tag,
                        });
                    }
                    if let Some(ev) = grad_ar[s] {
                        let dur = db.elapsed(ev);
                        timeline.push(Span {
                            device,
                            start: free[s],
                            end: free[s] + dur,
                            tag: Tag {
                                stage: s as u32,
                                mb: 0,
                                phase: Phase::Bwd,
                                layer: u32::MAX,
                                kind: SpanKind::GradAllReduce,
                                idx: 0,
                            },
                        });
                    }
                }
            }
        }
        timeline.finalize();
        timeline
    }

    /// Predicted iteration (batch) time in microseconds.
    pub fn predict_batch_time_us(&self, db: &mut EventDb) -> f64 {
        self.predict(db).batch_time_us()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cost::CostModel;
    use crate::model::zoo;
    use crate::partition::partition;
    use crate::profile::profile_events;
    use crate::schedule;
    use crate::strategy::Strategy;

    /// Profile (noise-free) + predict for one strategy.
    fn predict(mp: usize, pp: usize, dp: usize, m: usize) -> Timeline {
        let model = zoo::bert_large();
        let s = Strategy::new(mp, pp, dp);
        let c = ClusterSpec::a40_cluster(4, 4);
        let part = partition(&model, &s, &c, 4);
        let sched = schedule::dapple(pp, m);
        let mut db = EventDb::new();
        // intern exactly what the model needs, then profile
        let ds = DistSim::new(&part, &sched, &c);
        // build event set by a dry predict requires profiled times; intern
        // via stage_items + comm events first:
        for stage in 0..pp {
            stage_items(&part, &mut db, stage, Phase::Fwd);
            stage_items(&part, &mut db, stage, Phase::Bwd);
        }
        // p2p + grad AR events are interned lazily in predict; intern the
        // same keys here by calling the same constructors through a probe
        // profile loop:
        crate::engine::build_programs(&part, &sched, &c, &mut db);
        profile_events(&mut db, &c, &CostModel::default(), 0.0, 1, 99);
        ds.predict(&mut db)
    }

    #[test]
    fn predicts_positive_batch_time_for_hybrid_shapes() {
        for (mp, pp, dp, m) in [(1, 1, 1, 1), (2, 2, 2, 4), (1, 4, 1, 8), (4, 1, 2, 2)] {
            let t = predict(mp, pp, dp, m);
            assert!(t.batch_time_us() > 0.0);
            assert_eq!(
                t.n_devices,
                mp * pp * dp,
                "timeline covers the whole world"
            );
        }
    }

    #[test]
    fn mp_replicas_have_identical_spans() {
        let t = predict(2, 2, 1, 2);
        // devices 0,1 are the MP pair of stage 0
        let a = t.device_spans(0);
        let b = t.device_spans(1);
        assert_eq!(a.len(), b.len());
        for (x, y) in a.iter().zip(b) {
            assert_eq!(x.start, y.start);
            assert_eq!(x.tag, y.tag);
        }
    }

    #[test]
    fn dp_replicas_have_identical_spans() {
        let t = predict(1, 2, 2, 2);
        let a = t.device_spans(0); // (pp0, dp0)
        let b = t.device_spans(2); // (pp0, dp1)
        assert_eq!(a.len(), b.len());
        for (x, y) in a.iter().zip(b) {
            assert_eq!(x.start, y.start);
        }
    }

    #[test]
    fn pipeline_stages_are_causally_ordered() {
        let t = predict(1, 4, 1, 4);
        // F(mb=0) completion at stage s must precede F start at stage s+1
        for s in 0..3usize {
            let up: Vec<_> = t
                .device_comp_spans(s)
                .into_iter()
                .filter(|sp| sp.tag.mb == 0 && sp.tag.phase == Phase::Fwd)
                .collect();
            let down: Vec<_> = t
                .device_comp_spans(s + 1)
                .into_iter()
                .filter(|sp| sp.tag.mb == 0 && sp.tag.phase == Phase::Fwd)
                .collect();
            let up_end = up.iter().map(|x| x.end).fold(f64::NEG_INFINITY, f64::max);
            let down_start = down.iter().map(|x| x.start).fold(f64::INFINITY, f64::min);
            assert!(down_start >= up_end, "stage {s} causality");
        }
    }

    #[test]
    fn grad_allreduce_present_iff_dp() {
        let t1 = predict(1, 2, 1, 2);
        assert!(!t1
            .spans()
            .iter()
            .any(|s| s.tag.kind == SpanKind::GradAllReduce));
        let t2 = predict(1, 2, 2, 2);
        assert!(t2
            .spans()
            .iter()
            .any(|s| s.tag.kind == SpanKind::GradAllReduce));
    }
}
