//! Ablation experiments for DistSim's design choices (DESIGN.md):
//!
//! * `allreduce` — §4.2's claim that extrapolating >8-GPU all-reduces from
//!   an 8-GPU profile changes iteration-time prediction by < 2%.
//! * `noise` — how ground-truth jitter drives DistSim's error (§5.2
//!   attributes residual error to profiling fluctuation).
//! * `hierarchy` — hierarchical modeling vs the Daydream-style sequential
//!   replay, per strategy family (the Table-1 capability gap, quantified).

use crate::baseline::daydream::daydream_batch_time_us;
use crate::cluster::ClusterSpec;
use crate::comm;
use crate::config::RunConfig;
use crate::cost::CostBook;
use crate::distsim::DistSim;
use crate::engine::GroundTruth;
use crate::events::{CommEvent, Event, EventDb};
use crate::profile::profile_events;
use crate::strategy::Strategy;
use crate::util::rel_err_pct;

/// Ablation 1: all-reduce extrapolation error on the full iteration.
pub struct AllReduceAblation {
    pub strategy: String,
    /// batch time with profiled-then-extrapolated ARs (normal DistSim)
    pub extrapolated_ms: f64,
    /// batch time with exactly-priced ARs (oracle)
    pub exact_ms: f64,
    pub delta_pct: f64,
}

pub fn allreduce(profile_iters: usize) -> anyhow::Result<Vec<AllReduceAblation>> {
    let mut out = Vec::new();
    // 16-way DP has a 16-rank gradient ring: the extrapolation case
    for (mp, pp, dp) in [(1, 1, 16), (2, 1, 8), (1, 2, 8)] {
        let cfg = RunConfig::new(
            "bert-large",
            Strategy::new(mp, pp, dp),
            ClusterSpec::a40_cluster(4, 4),
        );
        let gt = GroundTruth::prepare(&cfg)?;

        // normal path (profiler caps rings at 8 and extrapolates)
        let mut db = EventDb::new();
        crate::engine::build_programs(&gt.part, &gt.sched, &cfg.cluster, &mut db);
        profile_events(&mut db, &cfg.cluster, &CostBook::default(), 0.0, profile_iters, 3);
        let ds = DistSim::new(&gt.part, &gt.sched, &cfg.cluster);
        let extrapolated = ds.predict_batch_time_us(&mut db);

        // paper-method path: flat 2(N-1)P/N ring-law extrapolation from an
        // 8-device measurement (what §4.2 does), vs the oracle placement
        let mut db_flat = db.clone();
        let mut db_exact = db.clone();
        for id in db_flat.ids().collect::<Vec<_>>() {
            if let Event::Comm(CommEvent::AllReduce { bytes, group, link }) =
                db_flat.get(id).clone()
            {
                let members = comm::synthetic_group(&cfg.cluster, group, link);
                let exact =
                    comm::hierarchical_allreduce_time_us(&cfg.cluster, &members, bytes);
                db_exact.set_elapsed(id, exact);
                if group > 8 {
                    // measured on an 8-ring straddling 2 nodes, then the
                    // paper's flat-volume extrapolation
                    let slice8 = comm::synthetic_group(&cfg.cluster, 8, link);
                    let m8 = comm::hierarchical_allreduce_time_us(
                        &cfg.cluster,
                        &slice8,
                        bytes,
                    );
                    db_flat.set_elapsed(id, comm::extrapolate_allreduce(m8, 8, group));
                }
            }
        }
        let flat = ds.predict_batch_time_us(&mut db_flat);
        let exact = ds.predict_batch_time_us(&mut db_exact);
        let _ = extrapolated;
        out.push(AllReduceAblation {
            strategy: cfg.strategy.notation(),
            extrapolated_ms: flat / 1e3,
            exact_ms: exact / 1e3,
            delta_pct: rel_err_pct(flat, exact),
        });
    }
    Ok(out)
}

/// Ablation 2: DistSim's batch-time error as ground-truth jitter grows.
pub struct NoiseAblation {
    pub jitter_sigma: f64,
    pub error_pct: f64,
}

pub fn noise(gt_iters: usize, profile_iters: usize) -> anyhow::Result<Vec<NoiseAblation>> {
    let mut out = Vec::new();
    for sigma in [0.0, 0.01, 0.02, 0.05, 0.10] {
        let mut cfg = RunConfig::new(
            "bert-large",
            Strategy::new(2, 2, 2),
            ClusterSpec::a40_cluster(4, 4),
        );
        cfg.jitter_sigma = sigma;
        cfg.profile_iters = profile_iters;
        let run = crate::exp::eval_cfg(&cfg)?;
        let actual = run.gt.mean_batch_time_us(gt_iters);
        let pred = run.predicted.batch_time_us();
        out.push(NoiseAblation {
            jitter_sigma: sigma,
            error_pct: rel_err_pct(pred, actual),
        });
    }
    Ok(out)
}

/// Ablation 3: hierarchical modeling vs Daydream-style sequential replay.
pub struct HierarchyAblation {
    pub strategy: String,
    pub distsim_err_pct: f64,
    pub daydream_err_pct: f64,
}

pub fn hierarchy(gt_iters: usize, profile_iters: usize) -> anyhow::Result<Vec<HierarchyAblation>> {
    let mut out = Vec::new();
    for (mp, pp, dp) in [(1, 1, 4), (1, 4, 1), (4, 1, 1), (2, 2, 2)] {
        let mut cfg = RunConfig::new(
            "bert-large",
            Strategy::new(mp, pp, dp),
            ClusterSpec::a40_cluster(4, 4),
        );
        cfg.profile_iters = profile_iters;
        let run = crate::exp::eval_cfg(&cfg)?;
        let actual = run.gt.mean_batch_time_us(gt_iters);
        let distsim_pred = run.predicted.batch_time_us();

        let mut db = EventDb::new();
        crate::engine::build_programs(&run.gt.part, &run.gt.sched, &cfg.cluster, &mut db);
        profile_events(&mut db, &cfg.cluster, &CostBook::default(), 0.0, profile_iters, 3);
        let daydream_pred =
            daydream_batch_time_us(&run.gt.part, &run.gt.sched, &cfg.cluster, &mut db);

        out.push(HierarchyAblation {
            strategy: cfg.strategy.notation(),
            distsim_err_pct: rel_err_pct(distsim_pred, actual),
            daydream_err_pct: rel_err_pct(daydream_pred, actual),
        });
    }
    Ok(out)
}

pub fn print_allreduce(rows: &[AllReduceAblation]) {
    let table: Vec<Vec<String>> = rows
        .iter()
        .map(|r| {
            vec![
                r.strategy.clone(),
                format!("{:.2}", r.extrapolated_ms),
                format!("{:.2}", r.exact_ms),
                format!("{:.2}%", r.delta_pct),
            ]
        })
        .collect();
    crate::exp::print_table(
        "Ablation — all-reduce ring extrapolation (>8 GPUs)",
        &["strategy", "extrapolated (ms)", "exact (ms)", "delta"],
        &table,
    );
    println!("\n(paper §4.2: effect on iteration time < 2%)");
}

pub fn print_noise(rows: &[NoiseAblation]) {
    let table: Vec<Vec<String>> = rows
        .iter()
        .map(|r| vec![format!("{:.2}", r.jitter_sigma), format!("{:.2}%", r.error_pct)])
        .collect();
    crate::exp::print_table(
        "Ablation — ground-truth jitter vs DistSim error (Bert 2M2P2D)",
        &["jitter sigma", "batch-time error"],
        &table,
    );
}

pub fn print_hierarchy(rows: &[HierarchyAblation]) {
    let table: Vec<Vec<String>> = rows
        .iter()
        .map(|r| {
            vec![
                r.strategy.clone(),
                format!("{:.2}%", r.distsim_err_pct),
                format!("{:.2}%", r.daydream_err_pct),
            ]
        })
        .collect();
    crate::exp::print_table(
        "Ablation — hierarchical modeling vs sequential replay (Daydream-style)",
        &["strategy", "DistSim error", "Daydream error"],
        &table,
    );
    println!("\n(sequential replay is fine for xD-only, wrong once P/M > 1 — Table 1)");
}

/// Ablation 4: pipeline-schedule comparison (paper Fig. 2's motivation,
/// quantified): bubble ratio and batch time for naive vs GPipe vs Dapple
/// across pipeline depths, modeled by DistSim and verified on the engine.
pub struct ScheduleAblation {
    pub pp: usize,
    pub schedule: String,
    pub batch_ms: f64,
    pub bubble_ratio: f64,
    pub engine_batch_ms: f64,
}

pub fn schedules(profile_iters: usize) -> anyhow::Result<Vec<ScheduleAblation>> {
    let mut out = Vec::new();
    for pp in [2usize, 4, 8] {
        for sched in ["naive", "gpipe", "dapple"] {
            let mut cfg = RunConfig::new(
                "bert-large",
                Strategy::new(1, pp, 1),
                ClusterSpec::a40_cluster(4, 4),
            );
            // fixed total work: 16 sequences per batch
            if sched == "naive" {
                cfg.micro_batches = 1;
                cfg.micro_batch_size = 16;
            } else {
                cfg.micro_batches = 8;
                cfg.micro_batch_size = 2;
            }
            cfg.schedule = sched.to_string();
            cfg.profile_iters = profile_iters;
            let run = crate::exp::eval_cfg(&cfg)?;
            out.push(ScheduleAblation {
                pp,
                schedule: sched.to_string(),
                batch_ms: run.predicted.batch_time_us() / 1e3,
                bubble_ratio: crate::timeline::analysis::bubble_ratio(&run.predicted),
                engine_batch_ms: run.gt.run_iteration(0).batch_time_us() / 1e3,
            });
        }
    }
    Ok(out)
}

pub fn print_schedules(rows: &[ScheduleAblation]) {
    let table: Vec<Vec<String>> = rows
        .iter()
        .map(|r| {
            vec![
                r.pp.to_string(),
                r.schedule.clone(),
                format!("{:.2}", r.batch_ms),
                format!("{:.1}%", r.bubble_ratio * 100.0),
                format!("{:.2}", r.engine_batch_ms),
            ]
        })
        .collect();
    crate::exp::print_table(
        "Ablation — pipeline schedules (Bert, 16 seqs/batch, 1M xP 1D)",
        &["PP", "schedule", "DistSim (ms)", "bubble", "engine (ms)"],
        &table,
    );
    println!("\n(micro-batching cuts the naive pipeline's bubble, paper Fig. 2)");
}
