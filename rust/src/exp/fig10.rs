//! Fig. 10: per-stage timestamp accuracy for Bert with MP=2, PP=4,
//! micro-batch count 4 — 32 forward/backward stage executions, 4 per GPU.
//! The error per (stage task, GPU) is the median over repeated actual
//! runs; the paper's largest median error is 1.71%, MP partner GPUs match,
//! and the first stage's error is ~0 (it defines the time origin).

use std::collections::BTreeMap;

use crate::cluster::ClusterSpec;
use crate::config::RunConfig;
use crate::metrics::{per_stage_error_pct, StageKey};
use crate::strategy::Strategy;
use crate::util::stats;

pub struct Fig10Cell {
    pub key: StageKey,
    pub median_err_pct: f64,
}

pub fn run(actual_runs: usize, profile_iters: usize) -> anyhow::Result<Vec<Fig10Cell>> {
    let mut cfg = RunConfig::new(
        "bert-large",
        Strategy::new(2, 4, 1),
        ClusterSpec::a40_cluster(4, 4),
    );
    cfg.micro_batches = 4;
    cfg.profile_iters = profile_iters;
    let run = super::eval_cfg(&cfg)?;

    // accumulate per-key errors over `actual_runs` independent real runs
    // (BTreeMap: per_stage_error_pct iterates in key order, so the cell
    // list is identical across runs and usable in golden tests; one
    // scratch serves every engine run)
    let mut acc: BTreeMap<StageKey, Vec<f64>> = BTreeMap::new();
    let mut scratch = crate::engine::ExecScratch::new();
    for i in 0..actual_runs {
        let actual = run.gt.run_iteration_with_scratch(i as u64, &mut scratch);
        for (key, err) in per_stage_error_pct(&run.predicted, &actual) {
            acc.entry(key).or_default().push(err);
        }
        scratch.recycle(actual);
    }
    let mut cells: Vec<Fig10Cell> = acc
        .into_iter()
        .map(|(key, errs)| Fig10Cell {
            key,
            median_err_pct: stats::median(&errs),
        })
        .collect();
    cells.sort_by_key(|c| (c.key.mb, !c.key.phase_fwd, c.key.device));
    Ok(cells)
}

pub fn print(cells: &[Fig10Cell]) {
    let table: Vec<Vec<String>> = cells
        .iter()
        .map(|c| {
            vec![
                format!(
                    "{}{}",
                    if c.key.phase_fwd { "F" } else { "B" },
                    c.key.mb
                ),
                format!("GPU{}", c.key.device),
                format!("{:.3}%", c.median_err_pct),
            ]
        })
        .collect();
    super::print_table(
        "Fig. 10 — per-stage median error (Bert 2M4P, 4 micro-batches)",
        &["stage task", "GPU", "median error"],
        &table,
    );
    let all: Vec<f64> = cells.iter().map(|c| c.median_err_pct).collect();
    println!(
        "\nlargest median error {:.3}%   (paper: 1.71%)",
        stats::max(&all)
    );

    // MP-partner similarity check (paper: "the error distribution for
    // every two GPUs is generally the same")
    let mut by_pair: BTreeMap<(usize, u32, bool), Vec<f64>> = BTreeMap::new();
    for c in cells {
        by_pair
            .entry((c.key.device / 2, c.key.mb, c.key.phase_fwd))
            .or_default()
            .push(c.median_err_pct);
    }
    let diffs: Vec<f64> = by_pair
        .values()
        .filter(|v| v.len() == 2)
        .map(|v| (v[0] - v[1]).abs())
        .collect();
    println!(
        "MP-partner mean |Δ| = {:.4}% (paper: pairs indistinguishable)",
        stats::mean(&diffs)
    );
}
