//! Fig. 8: DistSim's batch-time (iteration time) accuracy vs actual, on
//! Bert-Large, GPT-2-345M and T5 across hybrid strategies. The paper
//! reports < 4% error everywhere (3.51% max).

use crate::cluster::ClusterSpec;
use crate::config::RunConfig;
use crate::util::{rel_err_pct, stats};

pub struct Fig8Row {
    pub model: String,
    pub strategy: String,
    pub gpus: usize,
    pub actual_ms: f64,
    pub predicted_ms: f64,
    pub error_pct: f64,
}

pub fn run(gt_iters: usize, profile_iters: usize) -> anyhow::Result<Vec<Fig8Row>> {
    let mut rows = Vec::new();
    for model in ["bert-large", "gpt2-345m", "t5"] {
        for (strategy, gpus) in super::eval_strategies() {
            let mut cfg = RunConfig::new(model, strategy, ClusterSpec::a40_cluster(4, 4));
            cfg.profile_iters = profile_iters;
            let run = super::eval_cfg(&cfg)?;
            let actual = run.gt.mean_batch_time_us(gt_iters);
            let pred = run.predicted.batch_time_us();
            rows.push(Fig8Row {
                model: model.to_string(),
                strategy: strategy.notation(),
                gpus,
                actual_ms: actual / 1e3,
                predicted_ms: pred / 1e3,
                error_pct: rel_err_pct(pred, actual),
            });
        }
    }
    Ok(rows)
}

pub fn print(rows: &[Fig8Row]) {
    let table: Vec<Vec<String>> = rows
        .iter()
        .map(|r| {
            vec![
                r.model.clone(),
                r.strategy.clone(),
                r.gpus.to_string(),
                format!("{:.2}", r.actual_ms),
                format!("{:.2}", r.predicted_ms),
                format!("{:.2}%", r.error_pct),
            ]
        })
        .collect();
    super::print_table(
        "Fig. 8 — DistSim batch-time accuracy",
        &["model", "strategy", "GPUs", "actual (ms)", "DistSim (ms)", "error"],
        &table,
    );
    let errs: Vec<f64> = rows.iter().map(|r| r.error_pct).collect();
    println!(
        "\nmax error {:.2}%  avg error {:.2}%   (paper: < 4%, 3.51% max)",
        stats::max(&errs),
        stats::mean(&errs)
    );
}
