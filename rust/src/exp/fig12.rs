//! Fig. 12 + Table 2: auto parallel-strategy grid search for BERT-exLarge
//! (48 layers) on 4 nodes x 4 A10 GPUs at global batch 16, then verify the
//! ranking on the "actual" cluster (ground-truth engine).
//!
//! Paper: best = DP2/PP8 at 2.94 it/s; 7.37x over the worst (16-way MP);
//! the actual measurement agrees (Table 2).

use crate::cluster::ClusterSpec;
use crate::cost::CostModel;
use crate::model::zoo;
use crate::search::{grid_search, measure_actual, SearchReport};

pub struct Fig12Result {
    pub report: SearchReport,
    /// (strategy notation, DistSim it/s, actual it/s) for best/2nd/worst
    pub table2: Vec<(String, f64, f64)>,
    pub speedup_distsim: f64,
    pub speedup_actual: f64,
}

pub const GLOBAL_BATCH: usize = 16;

pub fn run(profile_iters: usize, verify_iters: usize) -> anyhow::Result<Fig12Result> {
    let model = zoo::bert_ex_large();
    let cluster = ClusterSpec::a10_cluster(4, 4);
    let report = grid_search(
        &model,
        &cluster,
        &CostModel::default(),
        GLOBAL_BATCH,
        0.02,
        profile_iters,
    );

    let mut table2 = Vec::new();
    let pick = |c: Option<&crate::search::Candidate>, what: &str| {
        c.cloned()
            .ok_or_else(|| anyhow::anyhow!("grid search found no {what} candidate"))
    };
    let picks = [
        pick(report.best(), "best")?,
        pick(report.second_best(), "second-best")?,
        pick(report.worst(), "worst")?,
    ];
    for cand in &picks {
        let actual = measure_actual("bert-exlarge", cand, &cluster, GLOBAL_BATCH, verify_iters)?;
        table2.push((cand.strategy.notation(), cand.throughput, actual));
    }
    let speedup_actual = table2[0].2 / table2[2].2;
    Ok(Fig12Result {
        speedup_distsim: report
            .speedup()
            .ok_or_else(|| anyhow::anyhow!("speedup undefined: no reachable candidates"))?,
        report,
        table2,
        speedup_actual,
    })
}

pub fn print(res: &Fig12Result) {
    let mut rows: Vec<Vec<String>> = res
        .report
        .candidates
        .iter()
        .map(|c| {
            vec![
                c.strategy.notation(),
                if c.reachable {
                    format!("{:.3}", c.throughput)
                } else {
                    "0 (unreachable)".to_string()
                },
            ]
        })
        .collect();
    rows.sort();
    super::print_table(
        "Fig. 12 — BERT-exLarge grid search on 16 A10 GPUs (it/s, global batch 16)",
        &["strategy", "DistSim throughput"],
        &rows,
    );

    let t2: Vec<Vec<String>> = res
        .table2
        .iter()
        .zip(["best", "second-best", "worst"])
        .map(|((s, d, a), label)| {
            vec![
                label.to_string(),
                s.clone(),
                format!("{d:.3}"),
                format!("{a:.3}"),
            ]
        })
        .collect();
    super::print_table(
        "Table 2 — search vs actual measurement",
        &["rank", "strategy", "DistSim (it/s)", "actual (it/s)"],
        &t2,
    );
    println!(
        "\nspeedup best/worst: DistSim {:.3}x, actual {:.3}x   (paper: 7.379x / 7.488x)",
        res.speedup_distsim, res.speedup_actual
    );
}
