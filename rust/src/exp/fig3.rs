//! Fig. 3: iteration-time gap between the analytical model and actual
//! profiling, Bert-Large, 4–16 GPUs. The paper measures up to 40.4% error,
//! 26.1% average — the motivation for profiling-based modeling.

use crate::baseline::analytical::analytical_from_gt;
use crate::cluster::ClusterSpec;
use crate::config::RunConfig;
use crate::engine::GroundTruth;
use crate::util::{rel_err_pct, stats};

pub struct Fig3Row {
    pub strategy: String,
    pub gpus: usize,
    pub actual_ms: f64,
    pub analytical_ms: f64,
    pub error_pct: f64,
}

pub fn run(iters: usize) -> anyhow::Result<Vec<Fig3Row>> {
    let mut rows = Vec::new();
    for (strategy, gpus) in super::eval_strategies() {
        let cluster = ClusterSpec::a40_cluster(4, 4);
        let cfg = RunConfig::new("bert-large", strategy, cluster);
        let gt = GroundTruth::prepare(&cfg)?;
        let actual = gt.mean_batch_time_us(iters);
        let est = analytical_from_gt(&gt);
        rows.push(Fig3Row {
            strategy: strategy.notation(),
            gpus,
            actual_ms: actual / 1e3,
            analytical_ms: est / 1e3,
            error_pct: rel_err_pct(est, actual),
        });
    }
    Ok(rows)
}

pub fn print(rows: &[Fig3Row]) {
    let table: Vec<Vec<String>> = rows
        .iter()
        .map(|r| {
            vec![
                r.strategy.clone(),
                r.gpus.to_string(),
                format!("{:.2}", r.actual_ms),
                format!("{:.2}", r.analytical_ms),
                format!("{:.1}%", r.error_pct),
            ]
        })
        .collect();
    super::print_table(
        "Fig. 3 — analytical model vs actual (Bert-Large)",
        &["strategy", "GPUs", "actual (ms)", "analytical (ms)", "error"],
        &table,
    );
    let errs: Vec<f64> = rows.iter().map(|r| r.error_pct).collect();
    println!(
        "\nmax error {:.1}%  avg error {:.1}%   (paper: 40.4% max, 26.1% avg)",
        stats::max(&errs),
        stats::mean(&errs)
    );
}
