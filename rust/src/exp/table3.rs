//! Table 3: the cost of strategy search — DistSim's profiling GPU-time +
//! simulation wall-time vs directly running every candidate on the real
//! cluster. The paper measures DistSim at 0.1296x of the direct cost, with
//! simulation itself < 1% of the total.

use crate::cluster::ClusterSpec;
use crate::config::RunConfig;
use crate::cost::CostModel;
use crate::engine::GroundTruth;
use crate::model::zoo;
use crate::search::{grid, grid_search};

pub struct Table3 {
    pub simulate_seconds: f64,
    pub profiling_gpu_seconds: f64,
    pub direct_gpu_seconds: f64,
    pub relative: f64,
    /// Unique events actually measured (after cross-candidate dedup).
    pub events_profiled: usize,
    /// Event lookups the sweep's shared [`crate::search::ProfileCache`]
    /// answered without re-profiling — the dedup Table 3's saving rests on.
    pub cache_hits: usize,
}

/// `iters` — iterations the direct run profiles per strategy (paper: 100).
pub fn run(profile_iters: usize, iters: usize) -> anyhow::Result<Table3> {
    let model = zoo::bert_ex_large();
    let cluster = ClusterSpec::a10_cluster(4, 4);

    // DistSim path: 2-node profiling + simulation
    let report = grid_search(
        &model,
        &cluster,
        &CostModel::default(),
        super::fig12::GLOBAL_BATCH,
        0.02,
        profile_iters,
    );

    // Direct path: run every *reachable* strategy on all 16 GPUs
    let mut direct_gpu_seconds = 0.0;
    for cand in report.candidates.iter().filter(|c| c.reachable) {
        let per_replica = super::fig12::GLOBAL_BATCH / cand.strategy.dp;
        let (mbs, m) = if cand.strategy.pp > 1 {
            (1, per_replica)
        } else {
            (per_replica, 1)
        };
        let mut cfg = RunConfig::new("bert-exlarge", cand.strategy, cluster.clone());
        cfg.micro_batch_size = mbs;
        cfg.micro_batches = m;
        let gt = GroundTruth::prepare(&cfg)?;
        direct_gpu_seconds += gt.direct_profiling_gpu_seconds(iters);
    }
    let _ = grid(16);

    Ok(Table3 {
        simulate_seconds: report.simulate_seconds,
        profiling_gpu_seconds: report.profile.gpu_seconds * iters as f64
            / profile_iters.max(1) as f64,
        direct_gpu_seconds,
        relative: 0.0,
        events_profiled: report.profile.events_profiled,
        cache_hits: report.profile.cache_hits,
    }
    .finish())
}

impl Table3 {
    fn finish(mut self) -> Self {
        self.relative = self.profiling_gpu_seconds / self.direct_gpu_seconds;
        self
    }
}

pub fn print(t: &Table3) {
    super::print_table(
        "Table 3 — search cost: DistSim vs direct run",
        &["", "simulate (s)", "profiling (gpu x s)", "relative"],
        &[
            vec![
                "DistSim".into(),
                format!("{:.3}", t.simulate_seconds),
                format!("{:.2}", t.profiling_gpu_seconds),
                format!("{:.4}x", t.relative),
            ],
            vec![
                "direct run".into(),
                "-".into(),
                format!("{:.2}", t.direct_gpu_seconds),
                "1x".into(),
            ],
        ],
    );
    println!(
        "\nevent dedup across candidates: {} unique events measured, {} cache hits",
        t.events_profiled, t.cache_hits
    );
    println!("(paper: 0.14 s simulate, 49.18 vs 380.35 gpu x s = 0.1296x)");
}
