//! Fig. 9: per-GPU activity accuracy — the average bias of every
//! computation event's begin/end timestamps per device, vs the actual
//! timeline. The paper reports < 5% (4.19% max), with higher errors for
//! deeper pipeline parallelism.

use crate::cluster::ClusterSpec;
use crate::config::RunConfig;
use crate::metrics::per_gpu_activity_error_pct;
use crate::util::stats;

pub struct Fig9Row {
    pub model: String,
    pub strategy: String,
    /// one error per GPU (the paper's per-bar values)
    pub per_gpu_pct: Vec<f64>,
}

pub fn run(profile_iters: usize) -> anyhow::Result<Vec<Fig9Row>> {
    let mut rows = Vec::new();
    for model in ["bert-large", "gpt2-345m", "t5"] {
        for (strategy, _gpus) in super::eval_strategies() {
            let mut cfg = RunConfig::new(model, strategy, ClusterSpec::a40_cluster(4, 4));
            cfg.profile_iters = profile_iters;
            let run = super::eval_cfg(&cfg)?;
            let actual = run.gt.run_iteration(0);
            let errs = per_gpu_activity_error_pct(&run.predicted, &actual);
            rows.push(Fig9Row {
                model: model.to_string(),
                strategy: strategy.notation(),
                per_gpu_pct: errs,
            });
        }
    }
    Ok(rows)
}

pub fn print(rows: &[Fig9Row]) {
    let table: Vec<Vec<String>> = rows
        .iter()
        .map(|r| {
            vec![
                r.model.clone(),
                r.strategy.clone(),
                format!("{:.2}%", stats::mean(&r.per_gpu_pct)),
                format!("{:.2}%", stats::max(&r.per_gpu_pct)),
                r.per_gpu_pct
                    .iter()
                    .map(|e| format!("{e:.1}"))
                    .collect::<Vec<_>>()
                    .join(","),
            ]
        })
        .collect();
    super::print_table(
        "Fig. 9 — per-GPU activity accuracy",
        &["model", "strategy", "mean", "max", "per-GPU errors (%)"],
        &table,
    );
    let all: Vec<f64> = rows.iter().flat_map(|r| r.per_gpu_pct.clone()).collect();
    println!(
        "\nglobal max {:.2}%  global mean {:.2}%   (paper: < 5%, 4.19% max)",
        stats::max(&all),
        stats::mean(&all)
    );
}
