//! Experiment drivers: one function per figure/table of the paper's
//! evaluation (see DESIGN.md experiment index). Each prints the same
//! rows/series the paper reports and returns structured results for the
//! bench harness and EXPERIMENTS.md.

pub mod ablate;
pub mod fig10;
pub mod fig11;
pub mod fig12;
pub mod fig3;
pub mod fig8;
pub mod fig9;
pub mod table3;

use crate::cluster::ClusterSpec;
use crate::config::RunConfig;
use crate::cost::CostBook;
use crate::distsim::DistSim;
use crate::engine::GroundTruth;
use crate::events::EventDb;
use crate::profile::{profile_events, ProfileReport};
use crate::strategy::Strategy;
use crate::timeline::Timeline;

/// The strategy grid used by §5.2/§5.3 for a given GPU budget — mirrors
/// the paper's x-axes (Figs. 8/9): 4-, 8- and 16-GPU hybrid settings.
pub fn eval_strategies() -> Vec<(Strategy, usize)> {
    vec![
        // (strategy, total GPUs)
        (Strategy::new(1, 2, 2), 4),
        (Strategy::new(2, 2, 1), 4),
        (Strategy::new(1, 1, 4), 4),
        (Strategy::new(2, 2, 2), 8),
        (Strategy::new(1, 4, 2), 8),
        (Strategy::new(2, 1, 4), 8),
        (Strategy::new(2, 2, 4), 16),
        (Strategy::new(2, 4, 2), 16),
        (Strategy::new(4, 2, 2), 16),
    ]
}

/// A prediction + ground-truth pair for one configuration.
pub struct EvalRun {
    pub cfg: RunConfig,
    pub gt: GroundTruth,
    pub predicted: Timeline,
    pub profile: ProfileReport,
}

/// Run the full DistSim pipeline (partition → 2-node profile → hierarchical
/// model) and prepare the ground truth for one configuration.
pub fn eval_one(model: &str, strategy: Strategy, cluster: ClusterSpec) -> anyhow::Result<EvalRun> {
    let cfg = RunConfig::new(model, strategy, cluster);
    eval_cfg(&cfg)
}

pub fn eval_cfg(cfg: &RunConfig) -> anyhow::Result<EvalRun> {
    let gt = GroundTruth::prepare(cfg)?;
    // DistSim path: independent event db, profiled on the 2-node slice
    let mut db = EventDb::new();
    crate::engine::build_programs(&gt.part, &gt.sched, &cfg.cluster, &mut db);
    let profile = profile_events(
        &mut db,
        &cfg.cluster,
        &CostBook::default(),
        cfg.jitter_sigma,
        cfg.profile_iters,
        cfg.seed.wrapping_mul(0x5EED).wrapping_add(1),
    );
    let ds = DistSim::new(&gt.part, &gt.sched, &cfg.cluster);
    let predicted = ds.predict(&mut db);
    Ok(EvalRun {
        cfg: cfg.clone(),
        gt,
        predicted,
        profile,
    })
}

/// Markdown-ish table printer used by all experiment drivers.
pub fn print_table(title: &str, headers: &[&str], rows: &[Vec<String>]) {
    println!("\n## {title}\n");
    println!("| {} |", headers.join(" | "));
    println!("|{}|", headers.iter().map(|_| "---").collect::<Vec<_>>().join("|"));
    for row in rows {
        println!("| {} |", row.join(" | "));
    }
}
