//! Fig. 11: large-scale generalization — model a 145-billion-parameter GPT
//! on 128 GPUs with the Megatron-LM "8M16P1D" configuration and compare
//! *normalized* throughput scaling (relative to batch size 1) against the
//! series Megatron-LM reports (SC'21 Fig. 17).
//!
//! As in the paper, absolute numbers are not comparable (different
//! hardware); the claim is that the throughput-vs-batch-size *shape*
//! matches.

use crate::cluster::ClusterSpec;
use crate::config::RunConfig;
use crate::strategy::Strategy;

/// Batch sizes (in micro-batches of 1 sequence) swept, matching the
/// geometric x-axis of Megatron's figure.
pub const BATCHES: [usize; 7] = [1, 2, 4, 8, 16, 32, 64];

/// Normalized throughput Megatron-LM SC'21 reports for its 145B/8-way-TP/
/// 16-stage configuration (their Fig. 17 analysis shows measured scaling
/// tracking the pipeline-bubble amortization law T(b)/T(1) = 16 b/(b+15)
/// closely): the reference series is that law, which is what the paper's
/// Fig. 11 compares against after normalizing to batch 1.
pub const MEGATRON_REPORTED: [f64; 7] = [1.0, 1.88, 3.37, 5.57, 8.26, 10.89, 12.96];

pub struct Fig11Row {
    pub batch: usize,
    pub batch_time_ms: f64,
    pub normalized: f64,
    pub megatron: f64,
}

pub fn run(profile_iters: usize) -> anyhow::Result<Vec<Fig11Row>> {
    let cluster = ClusterSpec::a100_pod(16); // 16 nodes x 8 = 128 GPUs
    let strategy = Strategy::new(8, 16, 1);
    let mut rows = Vec::new();
    let mut base_throughput = None;
    for (i, &batch) in BATCHES.iter().enumerate() {
        let mut cfg = RunConfig::new("gpt-145b", strategy, cluster.clone());
        cfg.micro_batch_size = 1;
        cfg.micro_batches = batch;
        cfg.profile_iters = profile_iters;
        let run = super::eval_cfg(&cfg)?;
        let t = run.predicted.batch_time_us();
        let throughput = batch as f64 / t; // sequences per us
        let base = *base_throughput.get_or_insert(throughput);
        rows.push(Fig11Row {
            batch,
            batch_time_ms: t / 1e3,
            normalized: throughput / base,
            megatron: MEGATRON_REPORTED[i],
        });
    }
    Ok(rows)
}

pub fn print(rows: &[Fig11Row]) {
    let table: Vec<Vec<String>> = rows
        .iter()
        .map(|r| {
            vec![
                r.batch.to_string(),
                format!("{:.1}", r.batch_time_ms),
                format!("{:.2}x", r.normalized),
                format!("{:.2}x", r.megatron),
                format!(
                    "{:.1}%",
                    ((r.normalized - r.megatron) / r.megatron * 100.0).abs()
                ),
            ]
        })
        .collect();
    super::print_table(
        "Fig. 11 — GPT-145B, 128 GPUs (8M16P1D): normalized throughput",
        &["batch", "DistSim batch time (ms)", "DistSim", "Megatron-LM", "gap"],
        &table,
    );
    println!("\n(paper claim: the increment rate matches Megatron-LM's report)");
}
