//! Per-request lifecycle tracing for the what-if daemon.
//!
//! Every job carries a [`RequestTrace`]. Disabled (the default) it is a
//! `None` — recording is a no-op and no clock is ever read. Enabled, it
//! captures named wall-clock spans relative to the admission instant:
//! `queue` (admission → worker pickup), `sweep` (the whole engine run),
//! the engine's pipeline stages (`source`, `memory`, `bound`,
//! `prune_epoch`, `evaluate` — one `evaluate` span per candidate
//! batch), and `write`
//! (response serialization; Chrome-trace files only, since a response
//! cannot contain the span of its own serialization).
//!
//! Two surfaces, both out-of-band with respect to the determinism
//! contract (DESIGN.md §9):
//!
//! * [`RequestTrace::to_json`] — the opt-in `trace` response block
//!   (`sweep.trace: true`), durations quantized to [`TRACE_QUANTUM_US`]
//!   and flagged `"deterministic": false`.
//! * [`RequestTrace::to_chrome_json`] — a Chrome-trace JSON document
//!   (unquantized), written under `--trace-dir` via the same
//!   [`crate::timeline::chrome`] envelope as the simulated timelines.

use std::sync::{Arc, Mutex};
use std::time::Instant;

use crate::config::json::Json;
use crate::search::cache::lock_recover;
use crate::timeline::chrome;

/// Span names a [`RequestTrace`] can record. The docs-drift test pins
/// each of these against FORMATS.md. `write` only ever appears in
/// Chrome-trace files: the response's `trace` block is serialized before
/// the write span is recorded.
pub const TRACE_PHASES: [&str; 8] = [
    "queue",
    "sweep",
    "source",
    "memory",
    "bound",
    "prune_epoch",
    "evaluate",
    "write",
];

/// Quantum (µs) applied to the span fields of the `trace` response
/// block: starts and durations are rounded to the nearest multiple.
pub const TRACE_QUANTUM_US: u64 = 100;

/// One recorded span, microseconds relative to the trace epoch.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct TraceSpan {
    pub name: &'static str,
    pub start_us: u64,
    pub dur_us: u64,
}

#[derive(Debug)]
struct TraceInner {
    epoch: Instant,
    spans: Mutex<Vec<TraceSpan>>,
}

/// A shared, clonable span recorder; `Default` is the disabled no-op.
#[derive(Debug, Clone, Default)]
pub struct RequestTrace {
    inner: Option<Arc<TraceInner>>,
}

impl RequestTrace {
    /// An enabled trace whose epoch is now (the admission instant).
    pub fn enabled() -> Self {
        RequestTrace {
            inner: Some(Arc::new(TraceInner {
                epoch: Instant::now(),
                spans: Mutex::new(Vec::new()),
            })),
        }
    }

    /// The disabled no-op recorder (same as `Default`).
    pub fn disabled() -> Self {
        RequestTrace::default()
    }

    pub fn is_enabled(&self) -> bool {
        self.inner.is_some()
    }

    /// Start a span now; it is recorded when the returned timer drops.
    /// On a disabled trace this reads no clock and records nothing.
    pub fn start(&self, name: &'static str) -> SpanTimer {
        SpanTimer {
            inner: self
                .inner
                .as_ref()
                .map(|i| (Arc::clone(i), name, Instant::now())),
        }
    }

    /// Record a span running from the trace epoch until now — used for
    /// the `queue` span, whose start *is* the admission instant.
    pub fn span_since_epoch(&self, name: &'static str) {
        if let Some(inner) = &self.inner {
            let dur = Instant::now().saturating_duration_since(inner.epoch);
            push_span(inner, name, 0, dur.as_micros() as u64);
        }
    }

    /// All recorded spans, ordered by start time (name breaks ties).
    pub fn spans(&self) -> Vec<TraceSpan> {
        let Some(inner) = &self.inner else {
            return Vec::new();
        };
        let mut spans = lock_recover(&inner.spans).clone();
        spans.sort_by(|a, b| (a.start_us, a.name).cmp(&(b.start_us, b.name)));
        spans
    }

    /// The opt-in `trace` response block: spans quantized to
    /// [`TRACE_QUANTUM_US`] and explicitly marked non-deterministic.
    pub fn to_json(&self) -> Json {
        let q = |us: u64| {
            let half = TRACE_QUANTUM_US / 2;
            ((us + half) / TRACE_QUANTUM_US * TRACE_QUANTUM_US) as f64
        };
        let spans = self
            .spans()
            .into_iter()
            .map(|s| {
                Json::obj(vec![
                    ("name", Json::str(s.name)),
                    ("start_us", Json::num(q(s.start_us))),
                    ("dur_us", Json::num(q(s.dur_us))),
                ])
            })
            .collect();
        Json::obj(vec![
            ("deterministic", Json::Bool(false)),
            ("quantum_us", Json::num(TRACE_QUANTUM_US as f64)),
            ("spans", Json::Arr(spans)),
        ])
    }

    /// A Chrome-trace JSON document of this request's own lifecycle
    /// (unquantized), openable in the same viewer as the simulated
    /// timelines. `label` names the single track (usually the request id).
    pub fn to_chrome_json(&self, label: &str) -> String {
        let mut events = vec![Json::obj(vec![
            ("name", Json::str("thread_name")),
            ("ph", Json::str("M")),
            ("pid", Json::num(0.0)),
            ("tid", Json::num(0.0)),
            (
                "args",
                Json::obj(vec![("name", Json::str(format!("request {label}")))]),
            ),
        ])];
        for s in self.spans() {
            events.push(Json::obj(vec![
                ("name", Json::str(s.name)),
                ("cat", Json::str("daemon")),
                ("ph", Json::str("X")),
                ("ts", Json::num(s.start_us as f64)),
                ("dur", Json::num(s.dur_us as f64)),
                ("pid", Json::num(0.0)),
                ("tid", Json::num(0.0)),
            ]));
        }
        chrome::finish(events)
    }
}

fn push_span(inner: &TraceInner, name: &'static str, start_us: u64, dur_us: u64) {
    lock_recover(&inner.spans).push(TraceSpan {
        name,
        start_us,
        dur_us,
    });
}

/// RAII span timer from [`RequestTrace::start`]; records on drop.
#[derive(Debug)]
pub struct SpanTimer {
    inner: Option<(Arc<TraceInner>, &'static str, Instant)>,
}

impl Drop for SpanTimer {
    fn drop(&mut self) {
        if let Some((inner, name, t0)) = self.inner.take() {
            let start = t0.saturating_duration_since(inner.epoch).as_micros() as u64;
            let dur = Instant::now().saturating_duration_since(t0).as_micros() as u64;
            push_span(&inner, name, start, dur);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn disabled_trace_records_nothing() {
        let t = RequestTrace::disabled();
        let timer = t.start("sweep");
        drop(timer);
        t.span_since_epoch("queue");
        assert!(!t.is_enabled());
        assert!(t.spans().is_empty());
    }

    #[test]
    fn enabled_trace_records_named_spans() {
        let t = RequestTrace::enabled();
        t.span_since_epoch("queue");
        let timer = t.start("sweep");
        drop(timer);
        let names: Vec<&str> = t.spans().iter().map(|s| s.name).collect();
        assert_eq!(names.len(), 2);
        assert!(names.contains(&"queue"));
        assert!(names.contains(&"sweep"));
        for name in &names {
            assert!(TRACE_PHASES.contains(name), "unknown phase {name}");
        }
    }

    #[test]
    fn trace_block_is_marked_non_deterministic_and_quantized() {
        let t = RequestTrace::enabled();
        t.span_since_epoch("queue");
        let block = t.to_json();
        assert_eq!(block.get("deterministic").and_then(Json::as_bool), Some(false));
        assert_eq!(
            block.get("quantum_us").and_then(Json::as_u64),
            Some(TRACE_QUANTUM_US)
        );
        let spans = match block.get("spans") {
            Some(Json::Arr(v)) => v,
            other => panic!("spans not an array: {other:?}"),
        };
        for s in spans {
            let start = s.get("start_us").and_then(Json::as_u64).unwrap();
            let dur = s.get("dur_us").and_then(Json::as_u64).unwrap();
            assert_eq!(start % TRACE_QUANTUM_US, 0);
            assert_eq!(dur % TRACE_QUANTUM_US, 0);
        }
    }

    #[test]
    fn chrome_export_is_valid_json_with_trace_events() {
        let t = RequestTrace::enabled();
        let timer = t.start("sweep");
        drop(timer);
        let doc = Json::parse(&t.to_chrome_json("req-1")).expect("valid chrome json");
        let events = match doc.get("traceEvents") {
            Some(Json::Arr(v)) => v,
            other => panic!("traceEvents missing: {other:?}"),
        };
        assert!(events.len() >= 2, "metadata + at least one span");
    }
}
