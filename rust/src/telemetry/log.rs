//! Structured leveled logging: one JSON object per line on stderr.
//!
//! Replaces the daemon's ad-hoc `eprintln!("warning: ...")` prose. Every
//! event is a single-line JSON object with a stable schema:
//!
//! ```json
//! {"event":"snapshot_ignored","level":"warn","path":"...","ts_ms":1700000000000}
//! ```
//!
//! `level` and `event` are always present; `ts_ms` (wall-clock Unix
//! milliseconds) is always present and, like everything on stderr, is
//! out-of-band with respect to the determinism contract (DESIGN.md §9).
//! Remaining keys are event-specific. Key order is sorted (the JSON
//! substrate sorts object keys). The event vocabulary is [`LOG_EVENTS`];
//! the docs-drift test pins it against FORMATS.md.

use std::time::{SystemTime, UNIX_EPOCH};

use crate::config::json::Json;

/// Every `event` name the daemon and CLI emit, pinned by docs drift.
pub const LOG_EVENTS: [&str; 10] = [
    "accept_failed",
    "cache_dir_error",
    "listening",
    "request_done",
    "response_dropped",
    "served",
    "snapshot_ignored",
    "snapshot_saved",
    "snapshot_write_failed",
    "trace_write_failed",
];

/// Severity, most to least severe. `--log-level` picks the threshold;
/// events above it are suppressed. Default `info`.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Default)]
pub enum LogLevel {
    Error,
    Warn,
    #[default]
    Info,
    Debug,
}

impl LogLevel {
    pub fn name(&self) -> &'static str {
        match self {
            LogLevel::Error => "error",
            LogLevel::Warn => "warn",
            LogLevel::Info => "info",
            LogLevel::Debug => "debug",
        }
    }

    /// Parse a `--log-level` value.
    pub fn parse(s: &str) -> Result<LogLevel, String> {
        match s {
            "error" => Ok(LogLevel::Error),
            "warn" => Ok(LogLevel::Warn),
            "info" => Ok(LogLevel::Info),
            "debug" => Ok(LogLevel::Debug),
            other => Err(format!(
                "unknown log level '{other}' (expected error|warn|info|debug)"
            )),
        }
    }
}

/// A cheap, copyable handle: a severity threshold over stderr.
#[derive(Debug, Clone, Copy, Default)]
pub struct Logger {
    level: LogLevel,
}

impl Logger {
    pub fn new(level: LogLevel) -> Self {
        Logger { level }
    }

    pub fn enabled(&self, level: LogLevel) -> bool {
        level <= self.level
    }

    /// Emit one structured event line on stderr (if `level` passes the
    /// threshold). `fields` are event-specific key/value pairs.
    pub fn event(&self, level: LogLevel, event: &'static str, fields: &[(&str, Json)]) {
        if !self.enabled(level) {
            return;
        }
        let ts_ms = SystemTime::now()
            .duration_since(UNIX_EPOCH)
            .map(|d| d.as_millis() as u64)
            .unwrap_or(0);
        let mut pairs = vec![
            ("level", Json::str(level.name())),
            ("event", Json::str(event)),
            ("ts_ms", Json::num(ts_ms as f64)),
        ];
        for (k, v) in fields {
            pairs.push((*k, v.clone()));
        }
        eprintln!("{}", Json::obj(pairs));
    }

    pub fn error(&self, event: &'static str, fields: &[(&str, Json)]) {
        self.event(LogLevel::Error, event, fields);
    }

    pub fn warn(&self, event: &'static str, fields: &[(&str, Json)]) {
        self.event(LogLevel::Warn, event, fields);
    }

    pub fn info(&self, event: &'static str, fields: &[(&str, Json)]) {
        self.event(LogLevel::Info, event, fields);
    }

    pub fn debug(&self, event: &'static str, fields: &[(&str, Json)]) {
        self.event(LogLevel::Debug, event, fields);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn levels_order_most_severe_first() {
        assert!(LogLevel::Error < LogLevel::Warn);
        assert!(LogLevel::Warn < LogLevel::Info);
        assert!(LogLevel::Info < LogLevel::Debug);
    }

    #[test]
    fn threshold_gates_events() {
        let warn_only = Logger::new(LogLevel::Warn);
        assert!(warn_only.enabled(LogLevel::Error));
        assert!(warn_only.enabled(LogLevel::Warn));
        assert!(!warn_only.enabled(LogLevel::Info));
        assert!(!warn_only.enabled(LogLevel::Debug));
    }

    #[test]
    fn parse_round_trips_names() {
        for level in [
            LogLevel::Error,
            LogLevel::Warn,
            LogLevel::Info,
            LogLevel::Debug,
        ] {
            assert_eq!(LogLevel::parse(level.name()), Ok(level));
        }
        assert!(LogLevel::parse("verbose").is_err());
    }

    #[test]
    fn log_events_are_sorted_and_unique() {
        let mut sorted = LOG_EVENTS.to_vec();
        sorted.sort_unstable();
        sorted.dedup();
        assert_eq!(sorted, LOG_EVENTS.to_vec());
    }
}
