//! In-process observability for the what-if daemon.
//!
//! Three pieces, all designed around one rule — **timing is out-of-band**
//! (DESIGN.md §9). Deterministic response payloads never carry wall-clock
//! data; everything here surfaces through explicitly non-deterministic
//! channels (the `metrics` op, the opt-in `trace` block, `--trace-dir`
//! files, stderr):
//!
//! * [`registry`] — a lock-cheap metrics registry ([`ServiceMetrics`]):
//!   label-free atomic counters, gauges, and fixed-bucket histograms,
//!   exposed by the NDJSON `metrics` op in structured-JSON and
//!   Prometheus text forms.
//! * [`trace`] — per-request lifecycle tracing ([`RequestTrace`]):
//!   admission → queue → pipeline stages → write spans, surfaced as an
//!   opt-in quantized response block and as Chrome-trace files of the
//!   daemon itself.
//! * [`log`] — a structured leveled [`Logger`] (`--log-level`): one JSON
//!   event per line on stderr with a stable schema, replacing ad-hoc
//!   `eprintln!` prose.

pub mod log;
pub mod registry;
pub mod trace;

pub use log::{LogLevel, Logger, LOG_EVENTS};
pub use registry::{ServiceMetrics, HISTOGRAM_BOUNDS_US, PROMETHEUS_PREFIX};
pub use trace::{RequestTrace, SpanTimer, TraceSpan, TRACE_PHASES, TRACE_QUANTUM_US};
