//! Lock-cheap metrics registry for the what-if daemon.
//!
//! A fixed, label-free set of named counters, gauges, and fixed-bucket
//! histograms, every one a plain atomic — no locks, no allocation on the
//! hot path, `Ordering::Relaxed` everywhere (the registry is diagnostic,
//! like the `stats` op, and sits outside the byte-identity determinism
//! contract; see DESIGN.md §9).
//!
//! Two exposition forms, both produced from the same snapshot pass:
//!
//! * [`ServiceMetrics::export_json`] — a structured [`Json`] object
//!   (`counters` / `gauges` / `histograms`), key order deterministic
//!   (the JSON substrate sorts object keys).
//! * [`ServiceMetrics::export_prometheus`] — the Prometheus text
//!   exposition format, one `# TYPE` comment plus samples per metric,
//!   in the fixed declaration order of [`ServiceMetrics::names`].
//!
//! Metric names are bare (`queue_depth`); the Prometheus form prefixes
//! every family with `distsim_`. Histogram buckets carry the standard
//! cumulative `le` label — the only label anywhere in the registry.

use std::sync::atomic::{AtomicU64, Ordering};

use crate::config::json::Json;
use crate::service::protocol::ErrorKind;

/// Upper bounds (µs, inclusive) of the shared histogram buckets; an
/// implicit `+Inf` bucket follows. Log-spaced from 100µs to 60s.
pub const HISTOGRAM_BOUNDS_US: [u64; 7] = [
    100, 1_000, 10_000, 100_000, 1_000_000, 10_000_000, 60_000_000,
];

const BUCKETS: usize = HISTOGRAM_BOUNDS_US.len() + 1;

/// Prometheus metric-family prefix used by [`ServiceMetrics::export_prometheus`].
pub const PROMETHEUS_PREFIX: &str = "distsim_";

/// Per-[`ErrorKind`] counter names, aligned with [`ErrorKind::ALL`].
const ERROR_METRIC_NAMES: [&str; 7] = [
    "errors_bad_json_total",
    "errors_bad_request_total",
    "errors_deadline_total",
    "errors_internal_total",
    "errors_cli_total",
    "errors_unavailable_total",
    "errors_cancelled_total",
];

/// A monotonically increasing integer counter.
#[derive(Debug, Default)]
pub struct Counter(AtomicU64);

impl Counter {
    pub fn inc(&self) {
        self.add(1);
    }
    pub fn add(&self, n: u64) {
        self.0.fetch_add(n, Ordering::Relaxed);
    }
    pub fn get(&self) -> u64 {
        self.0.load(Ordering::Relaxed)
    }
}

/// A set-to-latest (or ratcheting max) integer gauge.
#[derive(Debug, Default)]
pub struct Gauge(AtomicU64);

impl Gauge {
    pub fn set(&self, v: u64) {
        self.0.store(v, Ordering::Relaxed);
    }
    /// Ratchet the gauge up to `v` if `v` exceeds the current value.
    pub fn record_max(&self, v: u64) {
        self.0.fetch_max(v, Ordering::Relaxed);
    }
    pub fn get(&self) -> u64 {
        self.0.load(Ordering::Relaxed)
    }
}

/// A monotonically increasing float counter (GPU-seconds and friends),
/// stored as integer micro-units so it stays a single atomic.
#[derive(Debug, Default)]
pub struct FloatCounter(AtomicU64);

impl FloatCounter {
    pub fn add(&self, v: f64) {
        if v > 0.0 {
            self.0.fetch_add((v * 1e6).round() as u64, Ordering::Relaxed);
        }
    }
    pub fn get(&self) -> f64 {
        self.0.load(Ordering::Relaxed) as f64 / 1e6
    }
}

/// A fixed-bucket latency histogram over [`HISTOGRAM_BOUNDS_US`].
#[derive(Debug, Default)]
pub struct Histogram {
    buckets: [AtomicU64; BUCKETS],
    count: AtomicU64,
    sum_us: AtomicU64,
}

impl Histogram {
    pub fn observe_us(&self, us: u64) {
        let idx = HISTOGRAM_BOUNDS_US
            .iter()
            .position(|&b| us <= b)
            .unwrap_or(BUCKETS - 1);
        self.buckets[idx].fetch_add(1, Ordering::Relaxed);
        self.count.fetch_add(1, Ordering::Relaxed);
        self.sum_us.fetch_add(us, Ordering::Relaxed);
    }

    /// Cumulative (Prometheus-style) bucket counts, then total count and
    /// summed microseconds.
    fn snapshot(&self) -> ([u64; BUCKETS], u64, u64) {
        let mut cum = [0u64; BUCKETS];
        let mut running = 0u64;
        for (i, b) in self.buckets.iter().enumerate() {
            running += b.load(Ordering::Relaxed);
            cum[i] = running;
        }
        (
            cum,
            self.count.load(Ordering::Relaxed),
            self.sum_us.load(Ordering::Relaxed),
        )
    }
}

/// Upper-bound label (`le`) for bucket `i`, Prometheus-style.
fn bucket_le(i: usize) -> String {
    if i < HISTOGRAM_BOUNDS_US.len() {
        HISTOGRAM_BOUNDS_US[i].to_string()
    } else {
        "+Inf".to_string()
    }
}

/// The daemon's full metric set. One instance per serve call, shared by
/// the reader, worker, and writer threads through `Shared`.
#[derive(Debug, Default)]
pub struct ServiceMetrics {
    // -- counters (monotonic, deterministic given a request schedule) --
    pub requests_total: Counter,
    pub sweeps_total: Counter,
    pub shed_queue_full_total: Counter,
    pub shed_shutdown_total: Counter,
    pub cancel_cancelled_queued_total: Counter,
    pub cancel_cancelling_total: Counter,
    pub cancel_not_found_total: Counter,
    errors: [Counter; 7],
    pub cache_hits_total: Counter,
    pub cache_misses_total: Counter,
    pub cache_gpu_seconds: FloatCounter,
    pub pruning_generated_total: Counter,
    pub pruning_memory_pruned_total: Counter,
    pub pruning_bound_pruned_total: Counter,
    pub pruning_epoch_repruned_total: Counter,
    pub pruning_evaluated_total: Counter,
    pub pruning_gpu_seconds_avoided: FloatCounter,
    // plan-cache accounting (ISSUE 10): set at exposition from the plan
    // cache's own monotonic counters — the same source the `stats` op's
    // `plans` block reads — so the two always reconcile. Every plan
    // resolve increments exactly one of the three, so compiles + hits +
    // partial equals the number of plan-cached sweeps.
    pub plan_compiles_total: Gauge,
    pub plan_hits_total: Gauge,
    pub plan_partial_reuse_total: Gauge,
    pub scenario_sweeps_total: Gauge,
    pub scenario_episodes_total: Gauge,
    pub traces_written_total: Counter,
    // -- gauges ------------------------------------------------------
    pub queue_depth: Gauge,
    pub queue_high_water: Gauge,
    pub caches: Gauge,
    pub cache_events: Gauge,
    // -- histograms (wall-clock; never deterministic) ----------------
    pub queue_wait_us: Histogram,
    pub sweep_duration_us: Histogram,
    /// Wall-clock of plan compilation (full compiles and the rebuilt
    /// portion of partial reuses; full hits compile nothing and observe
    /// nothing).
    pub plan_compile_us: Histogram,
}

impl ServiceMetrics {
    pub fn new() -> Self {
        Self::default()
    }

    /// The per-kind error counter for `kind`.
    pub fn error_counter(&self, kind: ErrorKind) -> &Counter {
        let idx = ErrorKind::ALL
            .iter()
            .position(|k| *k == kind)
            .expect("every ErrorKind appears in ALL");
        &self.errors[idx]
    }

    /// Counter samples `(name, value)` in fixed declaration order.
    /// Scenario totals are sampled here even though they are stored as
    /// set-at-exposition gauges — their source of truth is the cache
    /// registry's monotonic counters, so they expose as counters.
    fn counter_samples(&self) -> Vec<(&'static str, f64)> {
        let mut v: Vec<(&'static str, f64)> = vec![
            ("requests_total", self.requests_total.get() as f64),
            ("sweeps_total", self.sweeps_total.get() as f64),
            (
                "shed_queue_full_total",
                self.shed_queue_full_total.get() as f64,
            ),
            ("shed_shutdown_total", self.shed_shutdown_total.get() as f64),
            (
                "cancel_cancelled_queued_total",
                self.cancel_cancelled_queued_total.get() as f64,
            ),
            (
                "cancel_cancelling_total",
                self.cancel_cancelling_total.get() as f64,
            ),
            (
                "cancel_not_found_total",
                self.cancel_not_found_total.get() as f64,
            ),
        ];
        for (i, name) in ERROR_METRIC_NAMES.iter().enumerate() {
            v.push((name, self.errors[i].get() as f64));
        }
        v.extend([
            ("cache_hits_total", self.cache_hits_total.get() as f64),
            ("cache_misses_total", self.cache_misses_total.get() as f64),
            ("cache_gpu_seconds", self.cache_gpu_seconds.get()),
            (
                "pruning_generated_total",
                self.pruning_generated_total.get() as f64,
            ),
            (
                "pruning_memory_pruned_total",
                self.pruning_memory_pruned_total.get() as f64,
            ),
            (
                "pruning_bound_pruned_total",
                self.pruning_bound_pruned_total.get() as f64,
            ),
            (
                "pruning_epoch_repruned_total",
                self.pruning_epoch_repruned_total.get() as f64,
            ),
            (
                "pruning_evaluated_total",
                self.pruning_evaluated_total.get() as f64,
            ),
            (
                "pruning_gpu_seconds_avoided",
                self.pruning_gpu_seconds_avoided.get(),
            ),
            (
                "plan_compiles_total",
                self.plan_compiles_total.get() as f64,
            ),
            ("plan_hits_total", self.plan_hits_total.get() as f64),
            (
                "plan_partial_reuse_total",
                self.plan_partial_reuse_total.get() as f64,
            ),
            (
                "scenario_sweeps_total",
                self.scenario_sweeps_total.get() as f64,
            ),
            (
                "scenario_episodes_total",
                self.scenario_episodes_total.get() as f64,
            ),
            (
                "traces_written_total",
                self.traces_written_total.get() as f64,
            ),
        ]);
        v
    }

    fn gauge_samples(&self) -> Vec<(&'static str, f64)> {
        vec![
            ("queue_depth", self.queue_depth.get() as f64),
            ("queue_high_water", self.queue_high_water.get() as f64),
            ("caches", self.caches.get() as f64),
            ("cache_events", self.cache_events.get() as f64),
        ]
    }

    fn histogram_samples(&self) -> Vec<(&'static str, &Histogram)> {
        vec![
            ("queue_wait_us", &self.queue_wait_us),
            ("sweep_duration_us", &self.sweep_duration_us),
            ("plan_compile_us", &self.plan_compile_us),
        ]
    }

    /// Every metric family name, in exposition order. The docs-drift
    /// test pins each of these against FORMATS.md.
    pub fn names(&self) -> Vec<&'static str> {
        self.counter_samples()
            .iter()
            .map(|(n, _)| *n)
            .chain(self.gauge_samples().iter().map(|(n, _)| *n))
            .chain(self.histogram_samples().iter().map(|(n, _)| *n))
            .collect()
    }

    /// Structured-JSON exposition form.
    pub fn export_json(&self) -> Json {
        let counters = Json::Obj(
            self.counter_samples()
                .into_iter()
                .map(|(n, v)| (n.to_string(), Json::num(v)))
                .collect(),
        );
        let gauges = Json::Obj(
            self.gauge_samples()
                .into_iter()
                .map(|(n, v)| (n.to_string(), Json::num(v)))
                .collect(),
        );
        let histograms = Json::Obj(
            self.histogram_samples()
                .into_iter()
                .map(|(n, h)| {
                    let (cum, count, sum_us) = h.snapshot();
                    let buckets = Json::Arr(
                        cum.iter()
                            .enumerate()
                            .map(|(i, c)| {
                                Json::obj(vec![
                                    ("le", Json::str(bucket_le(i))),
                                    ("count", Json::num(*c as f64)),
                                ])
                            })
                            .collect(),
                    );
                    (
                        n.to_string(),
                        Json::obj(vec![
                            ("count", Json::num(count as f64)),
                            ("sum_us", Json::num(sum_us as f64)),
                            ("buckets", buckets),
                        ]),
                    )
                })
                .collect(),
        );
        Json::obj(vec![
            ("counters", counters),
            ("gauges", gauges),
            ("histograms", histograms),
        ])
    }

    /// Prometheus text exposition form (one string, newline-separated).
    pub fn export_prometheus(&self) -> String {
        let mut out = String::new();
        let fmt_num = |v: f64| Json::num(v).to_string();
        for (name, value) in self.counter_samples() {
            out.push_str(&format!(
                "# TYPE {p}{name} counter\n{p}{name} {}\n",
                fmt_num(value),
                p = PROMETHEUS_PREFIX,
            ));
        }
        for (name, value) in self.gauge_samples() {
            out.push_str(&format!(
                "# TYPE {p}{name} gauge\n{p}{name} {}\n",
                fmt_num(value),
                p = PROMETHEUS_PREFIX,
            ));
        }
        for (name, h) in self.histogram_samples() {
            let (cum, count, sum_us) = h.snapshot();
            out.push_str(&format!(
                "# TYPE {p}{name} histogram\n",
                p = PROMETHEUS_PREFIX
            ));
            for (i, c) in cum.iter().enumerate() {
                out.push_str(&format!(
                    "{p}{name}_bucket{{le=\"{}\"}} {c}\n",
                    bucket_le(i),
                    p = PROMETHEUS_PREFIX,
                ));
            }
            out.push_str(&format!(
                "{p}{name}_sum {sum_us}\n{p}{name}_count {count}\n",
                p = PROMETHEUS_PREFIX
            ));
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn error_metric_names_align_with_error_kinds() {
        for (i, kind) in ErrorKind::ALL.iter().enumerate() {
            let expected = format!("errors_{}_total", kind.name());
            assert_eq!(ERROR_METRIC_NAMES[i], expected, "index {i}");
        }
    }

    #[test]
    fn histogram_buckets_are_cumulative_and_cover_overflow() {
        let h = Histogram::default();
        h.observe_us(50); // le 100
        h.observe_us(500); // le 1000
        h.observe_us(999_999_999); // +Inf
        let (cum, count, sum) = h.snapshot();
        assert_eq!(count, 3);
        assert_eq!(sum, 50 + 500 + 999_999_999);
        assert_eq!(cum[0], 1);
        assert_eq!(cum[1], 2);
        assert_eq!(cum[BUCKETS - 1], 3, "last bucket counts everything");
    }

    #[test]
    fn exposition_forms_cover_every_name() {
        let m = ServiceMetrics::new();
        m.requests_total.inc();
        m.queue_depth.set(3);
        m.queue_wait_us.observe_us(1234);
        let json = m.export_json().to_string();
        let prom = m.export_prometheus();
        for name in m.names() {
            assert!(json.contains(&format!("\"{name}\"")), "json lacks {name}");
            assert!(
                prom.contains(&format!("{PROMETHEUS_PREFIX}{name}")),
                "prometheus lacks {name}"
            );
        }
        // the text form parses line-by-line: every non-comment line is
        // `name[{le="..."}] value`
        for line in prom.lines() {
            if line.starts_with('#') {
                continue;
            }
            let (_, value) = line.rsplit_once(' ').expect("sample line");
            value.parse::<f64>().expect("numeric sample value");
        }
    }

    #[test]
    fn float_counter_round_trips_micro_units() {
        let c = FloatCounter::default();
        c.add(1.25);
        c.add(0.75);
        assert!((c.get() - 2.0).abs() < 1e-9);
    }
}
