//! Cluster topology: nodes x devices, intra-/inter-node links, device
//! presets matching the paper's testbeds (A40 x 16 over 4 nodes for §5,
//! A10 x 16 for §6, and a 128-GPU pod for §5.5) — and, beyond the paper,
//! **heterogeneous mixed-SKU fleets** (ISSUE 4).
//!
//! Heterogeneity is two orthogonal tables:
//!
//! * **Device kinds** — `device` is kind 0; [`ClusterSpec::extra_kinds`]
//!   adds named SKUs (kind 1..), and [`ClusterSpec::kind_of_device`] maps
//!   every physical device slot to a kind. Empty = homogeneous (all
//!   kind 0), byte-identical JSON to the pre-heterogeneity format.
//! * **Placement** — a rank→device map ([`Placement`]): `Linear`
//!   (identity, the homogeneous default), `FastFirst` (ranks fill the
//!   fastest SKUs first), `Interleaved` (ranks deal round-robin across
//!   SKUs), or an explicit permutation `Table`. The strategy sweep
//!   enumerates named policies as a search axis
//!   ([`crate::search::SweepConfig::placement_axis`]).
//!
//! Placement permutes *which rank runs on which device*; it never changes
//! any profiled event cost (those depend on the device kind, carried in
//! the event descriptor — see [`crate::events`]). The profile-cache
//! fingerprint therefore excludes it ([`crate::search::fingerprint`]).

use crate::config::Json;

/// A GPU-like accelerator's headline characteristics. These anchor the
/// cost model (`cost/`); the calibration pass can rescale them to measured
/// PJRT numbers. The `name` doubles as the **device-kind identity** in
/// heterogeneous clusters: computation events carry it, and the per-kind
/// cost registry ([`crate::cost::CostBook`]) resolves overrides by it.
#[derive(Debug, Clone, PartialEq)]
pub struct DeviceSpec {
    pub name: String,
    /// Dense fp32-accumulate tensor throughput, TFLOP/s.
    pub peak_tflops: f64,
    /// HBM bandwidth, GB/s.
    pub mem_bw_gbs: f64,
    /// Fixed kernel-launch overhead per operator, us.
    pub launch_overhead_us: f64,
    /// Device memory, GiB (for deployability checks).
    pub mem_gib: f64,
    /// Usable training-state budget, bytes. `None` (the default) keeps
    /// the pre-memory behaviour: the per-rank accounting never prunes,
    /// and every serialization stays byte-identical to the old format.
    /// Deliberately separate from `mem_gib`: capacities opt *in* to
    /// feasibility pruning (and are usually set below the headline HBM
    /// size to leave allocator/framework headroom).
    pub capacity_bytes: Option<u64>,
}

impl DeviceSpec {
    /// NVIDIA A40: 149.7 TF/s bf16 tensor (with fp32 acc), 696 GB/s GDDR6.
    pub fn a40() -> Self {
        DeviceSpec {
            name: "A40".into(),
            peak_tflops: 149.7,
            mem_bw_gbs: 696.0,
            launch_overhead_us: 8.0,
            mem_gib: 48.0,
            capacity_bytes: None,
        }
    }

    /// NVIDIA A10: 125 TF/s tensor, 600 GB/s.
    pub fn a10() -> Self {
        DeviceSpec {
            name: "A10".into(),
            peak_tflops: 125.0,
            mem_bw_gbs: 600.0,
            launch_overhead_us: 8.0,
            mem_gib: 24.0,
            capacity_bytes: None,
        }
    }

    /// A100-80G SXM: 312 TF/s tensor, 2039 GB/s (for the 128-GPU pod).
    pub fn a100() -> Self {
        DeviceSpec {
            name: "A100".into(),
            peak_tflops: 312.0,
            mem_bw_gbs: 2039.0,
            launch_overhead_us: 6.0,
            mem_gib: 80.0,
            capacity_bytes: None,
        }
    }

    /// Canonical JSON. `capacity_bytes` is emitted only when set, so a
    /// capacity-less device serializes byte-identically to the pre-memory
    /// format (and capacity-less cache fingerprints stay unchanged).
    pub fn to_json(&self) -> Json {
        let mut fields = vec![
            ("name", Json::str(&self.name)),
            ("peak_tflops", Json::num(self.peak_tflops)),
            ("mem_bw_gbs", Json::num(self.mem_bw_gbs)),
            ("launch_overhead_us", Json::num(self.launch_overhead_us)),
            ("mem_gib", Json::num(self.mem_gib)),
        ];
        if let Some(cap) = self.capacity_bytes {
            fields.push(("capacity_bytes", Json::num(cap as f64)));
        }
        Json::obj(fields)
    }

    pub fn from_json(j: &Json) -> anyhow::Result<Self> {
        // capacity gates feasibility pruning, so a mistyped value must
        // fail loudly rather than silently disable (or enable) pruning
        let capacity_bytes = match j.get("capacity_bytes") {
            None => None,
            Some(v) => {
                // as_u64 is a saturating cast, so vet the raw number
                let f = v.as_f64().unwrap_or(-1.0);
                anyhow::ensure!(
                    f > 0.0 && f.fract() == 0.0 && f <= (1u64 << 53) as f64,
                    "capacity_bytes must be a positive integer byte count"
                );
                Some(f as u64)
            }
        };
        Ok(DeviceSpec {
            name: j
                .get("name")
                .and_then(Json::as_str)
                .ok_or_else(|| anyhow::anyhow!("device missing name"))?
                .to_string(),
            peak_tflops: j.get("peak_tflops").and_then(Json::as_f64).unwrap_or(100.0),
            mem_bw_gbs: j.get("mem_bw_gbs").and_then(Json::as_f64).unwrap_or(600.0),
            launch_overhead_us: j
                .get("launch_overhead_us")
                .and_then(Json::as_f64)
                .unwrap_or(8.0),
            mem_gib: j.get("mem_gib").and_then(Json::as_f64).unwrap_or(24.0),
            capacity_bytes,
        })
    }
}

/// Link class between two devices.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum LinkClass {
    /// Same node: NVLink / PCIe-P2P.
    Intra,
    /// Across nodes: IB / Ethernet.
    Inter,
}

impl LinkClass {
    /// Canonical serialization name (profile-cache snapshots).
    pub fn name(&self) -> &'static str {
        match self {
            LinkClass::Intra => "intra",
            LinkClass::Inter => "inter",
        }
    }

    pub fn parse(name: &str) -> anyhow::Result<LinkClass> {
        match name {
            "intra" => Ok(LinkClass::Intra),
            "inter" => Ok(LinkClass::Inter),
            other => anyhow::bail!("unknown link class '{other}'"),
        }
    }
}

/// Rank→device placement map (see the module docs). `Linear` is the
/// homogeneous identity; the named policies are the deterministic
/// placements the sweep's placement axis enumerates; `Table` is an
/// explicit permutation for hand-crafted layouts.
#[derive(Debug, Clone, PartialEq)]
pub enum Placement {
    /// rank == device (the pre-heterogeneity behaviour).
    Linear,
    /// Ranks fill devices fastest-SKU-first (stable by device index
    /// within a kind): low ranks — and with Megatron's MP-fastest rank
    /// order, the early pipeline stages — land on the fastest silicon.
    FastFirst,
    /// Ranks deal round-robin across SKUs (fastest kind first, stable by
    /// device index within a kind): every contiguous rank group mixes
    /// SKUs, the adversarial layout for MP groups.
    Interleaved,
    /// Explicit rank→device permutation; `table[rank] = device`.
    Table(Vec<usize>),
}

impl Placement {
    /// Canonical serialization name.
    pub fn name(&self) -> &'static str {
        match self {
            Placement::Linear => "linear",
            Placement::FastFirst => "fast_first",
            Placement::Interleaved => "interleaved",
            Placement::Table(_) => "table",
        }
    }

    /// Parse a named policy (`linear` / `fast_first` / `interleaved`;
    /// hyphens accepted for CLI friendliness). `Table` only arrives as a
    /// JSON array, never by name.
    pub fn parse(name: &str) -> anyhow::Result<Placement> {
        match name.replace('-', "_").as_str() {
            "linear" => Ok(Placement::Linear),
            "fast_first" => Ok(Placement::FastFirst),
            "interleaved" => Ok(Placement::Interleaved),
            other => {
                anyhow::bail!("unknown placement '{other}' (linear|fast_first|interleaved)")
            }
        }
    }

    /// JSON form: a policy name string, or the raw table as an array.
    pub fn to_json(&self) -> Json {
        match self {
            Placement::Table(t) => {
                Json::Arr(t.iter().map(|&d| Json::num(d as f64)).collect())
            }
            named => Json::str(named.name()),
        }
    }

    pub fn from_json(j: &Json) -> anyhow::Result<Placement> {
        if let Some(name) = j.as_str() {
            return Placement::parse(name);
        }
        if let Some(arr) = j.as_arr() {
            let table = arr
                .iter()
                .map(|v| {
                    v.as_usize()
                        .ok_or_else(|| anyhow::anyhow!("placement table entries must be numbers"))
                })
                .collect::<anyhow::Result<Vec<usize>>>()?;
            return Ok(Placement::Table(table));
        }
        anyhow::bail!("placement must be a policy name or a rank->device array")
    }
}

/// One point on the strategy sweep's placement axis: keep the cluster's
/// own placement, or override it with a named policy. `Copy`, so candidate
/// specs stay `Copy`; an explicit [`Placement::Table`] can only arrive via
/// the cluster spec itself.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub enum PlacementPolicy {
    /// Evaluate under the cluster spec's own placement (the baseline —
    /// and the only point when the axis is off).
    Cluster,
    FastFirst,
    Interleaved,
    /// A [`Placement::Table`] chosen by the sweep's placement optimizer.
    /// The concrete table lives in the sweep's table pool
    /// (`search::CandidateSpec::table` indexes it); this policy only
    /// names the candidate's provenance in reports.
    Optimized,
}

impl PlacementPolicy {
    /// The deterministic axis the sweep enumerates for heterogeneous
    /// clusters, baseline first (ties resolve toward it).
    pub const AXIS: [PlacementPolicy; 3] = [
        PlacementPolicy::Cluster,
        PlacementPolicy::FastFirst,
        PlacementPolicy::Interleaved,
    ];

    /// Canonical serialization name.
    pub fn name(&self) -> &'static str {
        match self {
            PlacementPolicy::Cluster => "cluster",
            PlacementPolicy::FastFirst => "fast_first",
            PlacementPolicy::Interleaved => "interleaved",
            PlacementPolicy::Optimized => "optimized",
        }
    }

    /// The placement override this policy applies, if any. `Optimized`
    /// resolves through the sweep's table pool, not through this enum.
    pub fn placement(&self) -> Option<Placement> {
        match self {
            PlacementPolicy::Cluster | PlacementPolicy::Optimized => None,
            PlacementPolicy::FastFirst => Some(Placement::FastFirst),
            PlacementPolicy::Interleaved => Some(Placement::Interleaved),
        }
    }
}

impl std::fmt::Display for PlacementPolicy {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "{}", self.name())
    }
}

/// Cluster: devices across nodes with a flat two-level network (the
/// paper's intra/inter-node distinction). Homogeneous by default; see the
/// module docs for the mixed-SKU extension.
#[derive(Debug, Clone, PartialEq)]
pub struct ClusterSpec {
    pub nodes: usize,
    pub gpus_per_node: usize,
    /// Device kind 0 — the whole fleet in a homogeneous cluster.
    pub device: DeviceSpec,
    /// Named device kinds 1.. (empty = homogeneous).
    pub extra_kinds: Vec<DeviceSpec>,
    /// `kind_of_device[d]` = kind index of physical device slot `d`.
    /// Empty = every device is kind 0; otherwise one entry per device.
    pub kind_of_device: Vec<usize>,
    /// Rank→device placement map ([`Placement::Linear`] by default).
    pub placement: Placement,
    /// Intra-node per-direction bandwidth, GB/s (NVLink-ish).
    pub intra_bw_gbs: f64,
    /// Inter-node per-NIC bandwidth, GB/s (IB-ish).
    pub inter_bw_gbs: f64,
    /// One-way latencies, us.
    pub intra_lat_us: f64,
    pub inter_lat_us: f64,
}

impl ClusterSpec {
    /// The paper's §5 testbed: 4 nodes x 4 A40, PCIe gen4 intra (A40 has
    /// NVLink pairs but the cluster fabric is PCIe), 100 Gb IB inter.
    pub fn a40_cluster(nodes: usize, gpus_per_node: usize) -> Self {
        ClusterSpec {
            nodes,
            gpus_per_node,
            device: DeviceSpec::a40(),
            extra_kinds: Vec::new(),
            kind_of_device: Vec::new(),
            placement: Placement::Linear,
            intra_bw_gbs: 24.0,
            inter_bw_gbs: 12.0,
            intra_lat_us: 6.0,
            inter_lat_us: 18.0,
        }
    }

    /// The paper's §6 testbed: 4 nodes x 4 A10.
    pub fn a10_cluster(nodes: usize, gpus_per_node: usize) -> Self {
        ClusterSpec {
            device: DeviceSpec::a10(),
            intra_bw_gbs: 20.0,
            ..ClusterSpec::a40_cluster(nodes, gpus_per_node)
        }
    }

    /// A Megatron-style A100 pod for §5.5: 8 GPUs/node, NVLink intra,
    /// 8x200Gb HDR inter.
    pub fn a100_pod(nodes: usize) -> Self {
        ClusterSpec {
            device: DeviceSpec::a100(),
            intra_bw_gbs: 300.0,
            inter_bw_gbs: 100.0,
            intra_lat_us: 3.0,
            inter_lat_us: 10.0,
            ..ClusterSpec::a40_cluster(nodes, 8)
        }
    }

    /// A mixed-SKU fleet on the §5 fabric: even-index nodes carry A40s
    /// (kind 0), odd-index nodes carry A10s (kind 1). The smallest
    /// realistic heterogeneous scenario — a cluster grown in two
    /// procurement rounds.
    pub fn mixed_a40_a10(nodes: usize, gpus_per_node: usize) -> Self {
        assert!(
            nodes >= 2,
            "a mixed a40-a10 fleet needs >= 2 nodes (got {nodes}); \
             with one node every device would be an A40"
        );
        let mut c = ClusterSpec::a40_cluster(nodes, gpus_per_node);
        c.extra_kinds = vec![DeviceSpec::a10()];
        let kinds: Vec<usize> = (0..c.total_devices()).map(|d| c.node_of(d) % 2).collect();
        c.kind_of_device = kinds;
        c
    }

    /// Same topology with a different rank→device placement.
    pub fn with_placement(&self, placement: Placement) -> Self {
        ClusterSpec {
            placement,
            ..self.clone()
        }
    }

    /// Structural invariants of the kind and placement tables. Called by
    /// [`ClusterSpec::from_json`]; builders uphold them by construction.
    pub fn validate(&self) -> anyhow::Result<()> {
        let n = self.total_devices();
        // kind names are the SKU identity events carry: two kinds sharing
        // a name would conflate in the cache and price the wrong silicon
        for (i, a) in self.extra_kinds.iter().enumerate() {
            anyhow::ensure!(
                a.name != self.device.name
                    && self.extra_kinds[i + 1..].iter().all(|b| b.name != a.name),
                "duplicate device-kind name '{}': kind names must be unique",
                a.name
            );
        }
        if !self.kind_of_device.is_empty() {
            anyhow::ensure!(
                self.kind_of_device.len() == n,
                "kind_of_device has {} entries for {} devices",
                self.kind_of_device.len(),
                n
            );
            for (d, &k) in self.kind_of_device.iter().enumerate() {
                anyhow::ensure!(
                    k < self.kind_count(),
                    "device {d} maps to kind {k}, but only {} kinds exist",
                    self.kind_count()
                );
            }
        }
        if let Placement::Table(t) = &self.placement {
            anyhow::ensure!(
                t.len() == n,
                "placement table has {} entries for {} devices",
                t.len(),
                n
            );
            let mut seen = vec![false; n];
            for (r, &d) in t.iter().enumerate() {
                anyhow::ensure!(d < n, "rank {r} placed on device {d} of {n}");
                anyhow::ensure!(
                    !std::mem::replace(&mut seen[d], true),
                    "placement table maps two ranks to device {d}"
                );
            }
        }
        Ok(())
    }

    pub fn total_devices(&self) -> usize {
        self.nodes * self.gpus_per_node
    }

    // -- device kinds -----------------------------------------------------

    /// Number of named device kinds (kind 0 = `device`).
    pub fn kind_count(&self) -> usize {
        1 + self.extra_kinds.len()
    }

    /// The [`DeviceSpec`] of a kind index.
    pub fn kind_spec(&self, kind: usize) -> &DeviceSpec {
        if kind == 0 {
            &self.device
        } else {
            &self.extra_kinds[kind - 1]
        }
    }

    /// A kind's SKU name (the identity computation events carry).
    pub fn kind_name(&self, kind: usize) -> &str {
        &self.kind_spec(kind).name
    }

    /// Kind index of a physical device slot.
    pub fn device_kind(&self, device: usize) -> usize {
        self.kind_of_device.get(device).copied().unwrap_or(0)
    }

    /// Resolve a SKU name back to its spec (profilers price computation
    /// events on the kind the event was generated for).
    pub fn kind_by_name(&self, name: &str) -> Option<&DeviceSpec> {
        std::iter::once(&self.device)
            .chain(self.extra_kinds.iter())
            .find(|k| k.name == name)
    }

    /// Does more than one SKU actually appear in the fleet? (A fleet whose
    /// every device maps to the same kind — even a non-zero one — is
    /// homogeneous: all placements price identically there.)
    pub fn is_heterogeneous(&self) -> bool {
        self.kinds_in_use().len() > 1
    }

    /// Kind indices with at least one device, ascending.
    pub fn kinds_in_use(&self) -> Vec<usize> {
        if self.kind_of_device.is_empty() {
            return vec![0];
        }
        let mut v = self.kind_of_device.clone();
        v.sort_unstable();
        v.dedup();
        v
    }

    /// Highest-peak SKU in the fleet — what the analytical lower bound
    /// prices compute at (optimistic on purpose, so the pruning bound
    /// stays a true upper bound on throughput for any placement).
    pub fn fastest_spec(&self) -> &DeviceSpec {
        self.kinds_in_use()
            .into_iter()
            .map(|k| self.kind_spec(k))
            .max_by(|a, b| a.peak_tflops.total_cmp(&b.peak_tflops))
            .expect("at least one kind in use")
    }

    /// Smallest device memory in the fleet, GiB — deployability must hold
    /// on every rank, so the tightest SKU gates.
    pub fn min_mem_gib(&self) -> f64 {
        self.kinds_in_use()
            .into_iter()
            .map(|k| self.kind_spec(k).mem_gib)
            .fold(f64::INFINITY, f64::min)
    }

    /// Does any in-use SKU declare an explicit training-state capacity?
    /// This is the opt-in switch of the per-rank memory accounting
    /// ([`crate::memory`]): capacity-less clusters never feasibility-prune
    /// and keep every response byte-identical to pre-memory builds.
    pub fn has_capacity(&self) -> bool {
        self.kinds_in_use()
            .into_iter()
            .any(|k| self.kind_spec(k).capacity_bytes.is_some())
    }

    /// A kind's explicit capacity, if declared.
    pub fn capacity_of_kind(&self, kind: usize) -> Option<u64> {
        self.kind_spec(kind).capacity_bytes
    }

    /// The same fleet with every kind capped at `bytes` (test and preset
    /// convenience — real fleets usually cap per SKU via the spec JSON).
    pub fn with_uniform_capacity(&self, bytes: u64) -> Self {
        let mut c = self.clone();
        c.device.capacity_bytes = Some(bytes);
        for k in &mut c.extra_kinds {
            k.capacity_bytes = Some(bytes);
        }
        c
    }

    /// The same fleet with every explicit capacity cap removed. Capacity
    /// gates only the per-rank memory stage — never the candidate space,
    /// the analytical bounds or the event set — so the plan compiler
    /// ([`crate::search::SweepPlan`]) fingerprints those components
    /// against this capacity-stripped form, letting a capacity delta
    /// invalidate nothing but the memory verdicts.
    pub fn sans_capacity(&self) -> Self {
        let mut c = self.clone();
        c.device.capacity_bytes = None;
        for k in &mut c.extra_kinds {
            k.capacity_bytes = None;
        }
        c
    }

    // -- placement --------------------------------------------------------

    /// The placement-equivalence class of a physical device slot:
    /// `(node, kind)`. Two devices of the same class are interchangeable
    /// under *any* placement — swapping them changes neither any rank's
    /// SKU nor any link class (links depend only on node membership) —
    /// so performance is a function of the rank→class map alone. The
    /// placement optimizer searches over class assignments, not raw
    /// device permutations (see DESIGN.md §7).
    pub fn device_class(&self, device: usize) -> (usize, usize) {
        (self.node_of(device), self.device_kind(device))
    }

    /// Device slots grouped by `(node, kind)` class: classes ascending,
    /// slots ascending within each class. The shape of the placement
    /// optimizer's search space.
    pub fn device_classes(&self) -> Vec<((usize, usize), Vec<usize>)> {
        let mut out: Vec<((usize, usize), Vec<usize>)> = Vec::new();
        for d in 0..self.total_devices() {
            let class = self.device_class(d);
            match out.binary_search_by(|(c, _)| c.cmp(&class)) {
                Ok(i) => out[i].1.push(d),
                Err(i) => out.insert(i, (class, vec![d])),
            }
        }
        out
    }

    /// Canonicalize a rank→device table: keep every rank's `(node, kind)`
    /// class but re-assign, in rank order, the smallest still-unused
    /// device slot of that class. The result is performance-equivalent to
    /// the input (see [`ClusterSpec::device_class`]) and is the unique
    /// representative of its equivalence class, so two tables canonicalize
    /// equal iff they induce the same rank→class map.
    pub fn canonicalize_table(&self, table: &[usize]) -> Vec<usize> {
        let mut classes = self.device_classes();
        // reverse each slot list so pop() yields ascending device indices
        for (_, slots) in &mut classes {
            slots.reverse();
        }
        table
            .iter()
            .map(|&d| {
                let class = self.device_class(d);
                let i = classes
                    .binary_search_by(|(c, _)| c.cmp(&class))
                    .expect("device class enumerated");
                classes[i].1.pop().expect("class capacity respected")
            })
            .collect()
    }

    /// The resolved rank→device table under the current [`Placement`].
    /// O(n log n); hot paths (program building, engine base costs) call
    /// this once and index.
    pub fn rank_to_device(&self) -> Vec<usize> {
        let n = self.total_devices();
        match &self.placement {
            Placement::Linear => (0..n).collect(),
            Placement::Table(t) => t.clone(),
            Placement::FastFirst => {
                let mut devs: Vec<usize> = (0..n).collect();
                devs.sort_by(|&a, &b| {
                    let pa = self.kind_spec(self.device_kind(a)).peak_tflops;
                    let pb = self.kind_spec(self.device_kind(b)).peak_tflops;
                    pb.total_cmp(&pa).then(a.cmp(&b))
                });
                devs
            }
            Placement::Interleaved => {
                // bucket devices by kind (fastest kind first, device index
                // order within), then deal one device per bucket per round
                let mut kinds = self.kinds_in_use();
                kinds.sort_by(|&a, &b| {
                    self.kind_spec(b)
                        .peak_tflops
                        .total_cmp(&self.kind_spec(a).peak_tflops)
                        .then(a.cmp(&b))
                });
                let mut buckets: Vec<Vec<usize>> = kinds
                    .iter()
                    .map(|&k| (0..n).filter(|&d| self.device_kind(d) == k).collect())
                    .collect();
                for b in &mut buckets {
                    b.reverse(); // pop() yields ascending device index
                }
                let mut out = Vec::with_capacity(n);
                while out.len() < n {
                    for b in &mut buckets {
                        if let Some(d) = b.pop() {
                            out.push(d);
                        }
                    }
                }
                out
            }
        }
    }

    /// The physical device a strategy rank runs on (one-off lookup; batch
    /// callers use [`ClusterSpec::rank_to_device`]).
    pub fn device_of_rank(&self, rank: usize) -> usize {
        match &self.placement {
            Placement::Linear => rank,
            Placement::Table(t) => t[rank],
            _ => self.rank_to_device()[rank],
        }
    }

    /// Kind index of the SKU a rank runs on.
    pub fn kind_of_rank(&self, rank: usize) -> usize {
        self.device_kind(self.device_of_rank(rank))
    }

    /// The [`DeviceSpec`] a rank runs on.
    pub fn spec_of_rank(&self, rank: usize) -> &DeviceSpec {
        self.kind_spec(self.kind_of_rank(rank))
    }

    // -- topology ---------------------------------------------------------

    /// Which node a global device index lives on.
    pub fn node_of(&self, device: usize) -> usize {
        device / self.gpus_per_node
    }

    /// Link class between two global *device* indices.
    pub fn link_class(&self, a: usize, b: usize) -> LinkClass {
        if self.node_of(a) == self.node_of(b) {
            LinkClass::Intra
        } else {
            LinkClass::Inter
        }
    }

    /// Link class between two *ranks*, through the placement map.
    pub fn rank_link_class(&self, a: usize, b: usize) -> LinkClass {
        self.link_class(self.device_of_rank(a), self.device_of_rank(b))
    }

    pub fn bw_gbs(&self, class: LinkClass) -> f64 {
        match class {
            LinkClass::Intra => self.intra_bw_gbs,
            LinkClass::Inter => self.inter_bw_gbs,
        }
    }

    pub fn lat_us(&self, class: LinkClass) -> f64 {
        match class {
            LinkClass::Intra => self.intra_lat_us,
            LinkClass::Inter => self.inter_lat_us,
        }
    }

    /// Link class of a communication *group* of device indices: inter-node
    /// as soon as any pair of members crosses nodes (the slowest hop gates
    /// a ring).
    pub fn group_link_class(&self, devices: &[usize]) -> LinkClass {
        let first = self.node_of(devices[0]);
        if devices.iter().all(|&d| self.node_of(d) == first) {
            LinkClass::Intra
        } else {
            LinkClass::Inter
        }
    }

    /// [`ClusterSpec::group_link_class`] over *ranks*, through placement.
    pub fn rank_group_link_class(&self, ranks: &[usize]) -> LinkClass {
        if matches!(self.placement, Placement::Linear) {
            return self.group_link_class(ranks);
        }
        // resolve the placement table once, not per member (FastFirst /
        // Interleaved resolution sorts the whole fleet)
        let table = self.rank_to_device();
        let devices: Vec<usize> = ranks.iter().map(|&r| table[r]).collect();
        self.group_link_class(&devices)
    }

    /// Does one rank's share of the model fit in device memory? Gated by
    /// the smallest SKU in the fleet — the search driver marks
    /// configurations as unreachable (paper Fig. 12 draws those as 0).
    pub fn fits(&self, params_per_rank: u64) -> bool {
        // params + grads + Adam moments = 4x, fp32 = 4 bytes, plus ~25%
        // activation headroom.
        let need = params_per_rank as f64 * 4.0 * 4.0 * 1.25;
        need <= self.min_mem_gib() * (1u64 << 30) as f64
    }

    /// Canonical JSON. Heterogeneity fields are emitted only when
    /// non-default, so a homogeneous cluster's JSON is byte-identical to
    /// the pre-heterogeneity format (see docs/FORMATS.md).
    pub fn to_json(&self) -> Json {
        let mut fields = vec![
            ("nodes", Json::num(self.nodes as f64)),
            ("gpus_per_node", Json::num(self.gpus_per_node as f64)),
            ("device", self.device.to_json()),
            ("intra_bw_gbs", Json::num(self.intra_bw_gbs)),
            ("inter_bw_gbs", Json::num(self.inter_bw_gbs)),
            ("intra_lat_us", Json::num(self.intra_lat_us)),
            ("inter_lat_us", Json::num(self.inter_lat_us)),
        ];
        if !self.extra_kinds.is_empty() {
            fields.push((
                "extra_kinds",
                Json::Arr(self.extra_kinds.iter().map(DeviceSpec::to_json).collect()),
            ));
        }
        if !self.kind_of_device.is_empty() {
            fields.push((
                "kind_of_device",
                Json::Arr(
                    self.kind_of_device
                        .iter()
                        .map(|&k| Json::num(k as f64))
                        .collect(),
                ),
            ));
        }
        if self.placement != Placement::Linear {
            fields.push(("placement", self.placement.to_json()));
        }
        Json::obj(fields)
    }

    pub fn from_json(j: &Json) -> anyhow::Result<Self> {
        let c = ClusterSpec {
            nodes: j
                .get("nodes")
                .and_then(Json::as_usize)
                .ok_or_else(|| anyhow::anyhow!("cluster missing nodes"))?,
            gpus_per_node: j
                .get("gpus_per_node")
                .and_then(Json::as_usize)
                .ok_or_else(|| anyhow::anyhow!("cluster missing gpus_per_node"))?,
            device: DeviceSpec::from_json(
                j.get("device")
                    .ok_or_else(|| anyhow::anyhow!("cluster missing device"))?,
            )?,
            extra_kinds: match j.get("extra_kinds").and_then(Json::as_arr) {
                Some(arr) => arr
                    .iter()
                    .map(DeviceSpec::from_json)
                    .collect::<anyhow::Result<Vec<_>>>()?,
                None => Vec::new(),
            },
            kind_of_device: match j.get("kind_of_device").and_then(Json::as_arr) {
                Some(arr) => arr
                    .iter()
                    .map(|v| {
                        v.as_usize().ok_or_else(|| {
                            anyhow::anyhow!("kind_of_device entries must be numbers")
                        })
                    })
                    .collect::<anyhow::Result<Vec<_>>>()?,
                None => Vec::new(),
            },
            placement: match j.get("placement") {
                Some(p) => Placement::from_json(p)?,
                None => Placement::Linear,
            },
            intra_bw_gbs: j.get("intra_bw_gbs").and_then(Json::as_f64).unwrap_or(24.0),
            inter_bw_gbs: j.get("inter_bw_gbs").and_then(Json::as_f64).unwrap_or(12.0),
            intra_lat_us: j.get("intra_lat_us").and_then(Json::as_f64).unwrap_or(6.0),
            inter_lat_us: j.get("inter_lat_us").and_then(Json::as_f64).unwrap_or(18.0),
        };
        c.validate()?;
        Ok(c)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn node_mapping() {
        let c = ClusterSpec::a40_cluster(4, 4);
        assert_eq!(c.total_devices(), 16);
        assert_eq!(c.node_of(0), 0);
        assert_eq!(c.node_of(3), 0);
        assert_eq!(c.node_of(4), 1);
        assert_eq!(c.node_of(15), 3);
    }

    #[test]
    fn link_classes() {
        let c = ClusterSpec::a40_cluster(4, 4);
        assert_eq!(c.link_class(0, 3), LinkClass::Intra);
        assert_eq!(c.link_class(0, 4), LinkClass::Inter);
        assert_eq!(c.group_link_class(&[0, 1, 2, 3]), LinkClass::Intra);
        assert_eq!(c.group_link_class(&[0, 1, 4]), LinkClass::Inter);
    }

    #[test]
    fn intra_is_faster_than_inter_in_presets() {
        for c in [
            ClusterSpec::a40_cluster(4, 4),
            ClusterSpec::a10_cluster(4, 4),
            ClusterSpec::a100_pod(16),
            ClusterSpec::mixed_a40_a10(4, 4),
        ] {
            assert!(c.intra_bw_gbs > c.inter_bw_gbs);
            assert!(c.intra_lat_us < c.inter_lat_us);
        }
    }

    #[test]
    fn fits_rejects_whole_145b_on_one_a100() {
        let c = ClusterSpec::a100_pod(16);
        let m = crate::model::zoo::gpt_145b();
        assert!(!c.fits(m.total_params()));
        // but a 128-way shard fits
        assert!(c.fits(m.total_params() / 128));
    }

    #[test]
    fn json_roundtrip() {
        let c = ClusterSpec::a10_cluster(4, 4);
        let j = Json::parse(&c.to_json().to_string()).unwrap();
        assert_eq!(ClusterSpec::from_json(&j).unwrap(), c);
    }

    #[test]
    fn homogeneous_json_has_no_heterogeneity_fields() {
        // byte-compatibility: the old format is the homogeneous format
        let text = ClusterSpec::a40_cluster(4, 4).to_json().to_string();
        for key in ["extra_kinds", "kind_of_device", "placement"] {
            assert!(!text.contains(key), "unexpected '{key}' in {text}");
        }
    }

    #[test]
    fn mixed_preset_alternates_kinds_by_node() {
        let c = ClusterSpec::mixed_a40_a10(4, 4);
        assert!(c.is_heterogeneous());
        assert_eq!(c.kind_count(), 2);
        assert_eq!(c.kinds_in_use(), vec![0, 1]);
        for d in 0..c.total_devices() {
            assert_eq!(c.device_kind(d), c.node_of(d) % 2);
        }
        assert_eq!(c.kind_name(0), "A40");
        assert_eq!(c.kind_name(1), "A10");
        assert_eq!(c.fastest_spec().name, "A40");
        assert_eq!(c.min_mem_gib(), DeviceSpec::a10().mem_gib);
        assert!(c.kind_by_name("A10").is_some());
        assert!(c.kind_by_name("H100").is_none());
    }

    #[test]
    fn heterogeneous_json_roundtrips_all_placements() {
        let base = ClusterSpec::mixed_a40_a10(2, 4);
        for p in [
            Placement::Linear,
            Placement::FastFirst,
            Placement::Interleaved,
            Placement::Table(vec![7, 6, 5, 4, 3, 2, 1, 0]),
        ] {
            let c = base.with_placement(p);
            let j = Json::parse(&c.to_json().to_string()).unwrap();
            assert_eq!(ClusterSpec::from_json(&j).unwrap(), c, "{:?}", c.placement);
        }
    }

    #[test]
    fn placement_resolution_is_a_permutation() {
        let c = ClusterSpec::mixed_a40_a10(2, 4);
        for p in [Placement::Linear, Placement::FastFirst, Placement::Interleaved] {
            let map = c.with_placement(p.clone()).rank_to_device();
            let mut sorted = map.clone();
            sorted.sort_unstable();
            assert_eq!(sorted, (0..8).collect::<Vec<_>>(), "{p:?}: {map:?}");
        }
    }

    #[test]
    fn fast_first_packs_fast_devices_into_low_ranks() {
        // 2x4 mixed: node 0 = A40 (devices 0-3), node 1 = A10 (devices 4-7)
        let c = ClusterSpec::mixed_a40_a10(2, 4).with_placement(Placement::FastFirst);
        assert_eq!(c.rank_to_device(), vec![0, 1, 2, 3, 4, 5, 6, 7]);
        for r in 0..4 {
            assert_eq!(c.spec_of_rank(r).name, "A40", "rank {r}");
        }
        for r in 4..8 {
            assert_eq!(c.spec_of_rank(r).name, "A10", "rank {r}");
        }
        // flip the kind layout: A10s on node 0 -> fast-first reorders
        let mut flipped = ClusterSpec::mixed_a40_a10(2, 4);
        let flipped_kinds: Vec<usize> = (0..8).map(|d| 1 - flipped.node_of(d) % 2).collect();
        flipped.kind_of_device = flipped_kinds;
        let map = flipped.with_placement(Placement::FastFirst).rank_to_device();
        assert_eq!(map, vec![4, 5, 6, 7, 0, 1, 2, 3]);
    }

    #[test]
    fn interleaved_alternates_kinds() {
        let c = ClusterSpec::mixed_a40_a10(2, 4).with_placement(Placement::Interleaved);
        let kinds: Vec<usize> = (0..8).map(|r| c.kind_of_rank(r)).collect();
        assert_eq!(kinds, vec![0, 1, 0, 1, 0, 1, 0, 1]);
        assert_eq!(c.rank_to_device(), vec![0, 4, 1, 5, 2, 6, 3, 7]);
    }

    #[test]
    fn rank_link_class_follows_placement() {
        let c = ClusterSpec::mixed_a40_a10(2, 4);
        // linear: ranks 0 and 1 share node 0
        assert_eq!(c.rank_link_class(0, 1), LinkClass::Intra);
        // interleaved: rank 1 sits on device 4 (node 1)
        let i = c.with_placement(Placement::Interleaved);
        assert_eq!(i.rank_link_class(0, 1), LinkClass::Inter);
        assert_eq!(i.rank_group_link_class(&[0, 1]), LinkClass::Inter);
        assert_eq!(i.rank_group_link_class(&[0, 2]), LinkClass::Intra);
    }

    #[test]
    fn validate_rejects_malformed_tables() {
        let base = ClusterSpec::mixed_a40_a10(2, 4);
        let mut short = base.clone();
        short.kind_of_device = vec![0, 1];
        assert!(short.validate().is_err());
        let mut bad_kind = base.clone();
        bad_kind.kind_of_device = vec![0, 0, 0, 0, 0, 0, 0, 9];
        assert!(bad_kind.validate().is_err());
        let dup = base.with_placement(Placement::Table(vec![0; 8]));
        assert!(dup.validate().is_err());
        let short_table = base.with_placement(Placement::Table(vec![0, 1]));
        assert!(short_table.validate().is_err());
        let ok = base.with_placement(Placement::Table((0..8).rev().collect()));
        ok.validate().unwrap();
    }

    #[test]
    fn validate_rejects_duplicate_kind_names() {
        // two kinds sharing a name would conflate in the event cache and
        // silently price the wrong silicon
        let mut c = ClusterSpec::mixed_a40_a10(2, 4);
        let mut throttled = DeviceSpec::a40();
        throttled.peak_tflops = 37.0;
        c.extra_kinds.push(throttled);
        assert!(c.validate().unwrap_err().to_string().contains("duplicate"));
        let mut twice = ClusterSpec::mixed_a40_a10(2, 4);
        twice.extra_kinds.push(DeviceSpec::a10());
        assert!(twice.validate().is_err());
        ClusterSpec::mixed_a40_a10(2, 4).validate().unwrap();
    }

    #[test]
    fn device_classes_partition_the_fleet() {
        let c = ClusterSpec::mixed_a40_a10(2, 4);
        let classes = c.device_classes();
        // node 0 = A40 (kind 0), node 1 = A10 (kind 1)
        assert_eq!(
            classes,
            vec![((0, 0), vec![0, 1, 2, 3]), ((1, 1), vec![4, 5, 6, 7])]
        );
        // homogeneous: one class per node
        let h = ClusterSpec::a40_cluster(2, 2);
        assert_eq!(
            h.device_classes(),
            vec![((0, 0), vec![0, 1]), ((1, 0), vec![2, 3])]
        );
    }

    #[test]
    fn canonicalize_table_is_idempotent_and_class_preserving() {
        let c = ClusterSpec::mixed_a40_a10(2, 4);
        let table = vec![3, 7, 1, 5, 2, 6, 0, 4];
        let canon = c.canonicalize_table(&table);
        // class-preserving: every rank keeps its (node, kind)
        for (r, (&d, &cd)) in table.iter().zip(&canon).enumerate() {
            assert_eq!(c.device_class(d), c.device_class(cd), "rank {r}");
        }
        // permutation
        let mut sorted = canon.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..8).collect::<Vec<_>>());
        // idempotent, and device order within a class is ascending by rank
        assert_eq!(c.canonicalize_table(&canon), canon);
        assert_eq!(canon, vec![0, 4, 1, 5, 2, 6, 3, 7]);
        // two tables with the same rank→class map canonicalize equal
        let other = vec![1, 4, 0, 6, 3, 5, 2, 7];
        assert_eq!(c.canonicalize_table(&other), canon);
    }

    #[test]
    fn capacity_json_roundtrips_and_is_absent_by_default() {
        // capacity-less specs serialize byte-identically to pre-memory
        let plain = ClusterSpec::a40_cluster(2, 4);
        assert!(!plain.has_capacity());
        assert!(!plain.to_json().to_string().contains("capacity_bytes"));
        // capped specs round-trip and flip the opt-in switch
        let capped = plain.with_uniform_capacity(3_000_000_000);
        assert!(capped.has_capacity());
        assert_eq!(capped.capacity_of_kind(0), Some(3_000_000_000));
        let j = Json::parse(&capped.to_json().to_string()).unwrap();
        assert_eq!(ClusterSpec::from_json(&j).unwrap(), capped);
        // mixed fleets cap every kind
        let mixed = ClusterSpec::mixed_a40_a10(2, 4).with_uniform_capacity(1 << 30);
        assert_eq!(mixed.capacity_of_kind(0), Some(1 << 30));
        assert_eq!(mixed.capacity_of_kind(1), Some(1 << 30));
    }

    #[test]
    fn capacity_must_be_a_positive_integer() {
        for bad in [r#""48GiB""#, "0", "-5", "1.5"] {
            let text = format!(
                r#"{{"name":"A40","peak_tflops":149.7,"mem_bw_gbs":696,"launch_overhead_us":8,"mem_gib":48,"capacity_bytes":{bad}}}"#
            );
            let j = Json::parse(&text).unwrap();
            assert!(DeviceSpec::from_json(&j).is_err(), "accepted {bad}");
        }
    }

    #[test]
    fn placement_parse_accepts_hyphens() {
        assert_eq!(Placement::parse("fast-first").unwrap(), Placement::FastFirst);
        assert_eq!(Placement::parse("interleaved").unwrap(), Placement::Interleaved);
        assert!(Placement::parse("random").is_err());
    }
}
