//! Cluster topology: nodes x devices, intra-/inter-node links, and device
//! presets matching the paper's testbeds (A40 x 16 over 4 nodes for §5,
//! A10 x 16 for §6, and a 128-GPU pod for §5.5).

use crate::config::Json;
use crate::strategy::Strategy;

/// A GPU-like accelerator's headline characteristics. These anchor the
/// cost model (`cost/`); the calibration pass can rescale them to measured
/// PJRT numbers.
#[derive(Debug, Clone, PartialEq)]
pub struct DeviceSpec {
    pub name: String,
    /// Dense fp32-accumulate tensor throughput, TFLOP/s.
    pub peak_tflops: f64,
    /// HBM bandwidth, GB/s.
    pub mem_bw_gbs: f64,
    /// Fixed kernel-launch overhead per operator, us.
    pub launch_overhead_us: f64,
    /// Device memory, GiB (for deployability checks).
    pub mem_gib: f64,
}

impl DeviceSpec {
    /// NVIDIA A40: 149.7 TF/s bf16 tensor (with fp32 acc), 696 GB/s GDDR6.
    pub fn a40() -> Self {
        DeviceSpec {
            name: "A40".into(),
            peak_tflops: 149.7,
            mem_bw_gbs: 696.0,
            launch_overhead_us: 8.0,
            mem_gib: 48.0,
        }
    }

    /// NVIDIA A10: 125 TF/s tensor, 600 GB/s.
    pub fn a10() -> Self {
        DeviceSpec {
            name: "A10".into(),
            peak_tflops: 125.0,
            mem_bw_gbs: 600.0,
            launch_overhead_us: 8.0,
            mem_gib: 24.0,
        }
    }

    /// A100-80G SXM: 312 TF/s tensor, 2039 GB/s (for the 128-GPU pod).
    pub fn a100() -> Self {
        DeviceSpec {
            name: "A100".into(),
            peak_tflops: 312.0,
            mem_bw_gbs: 2039.0,
            launch_overhead_us: 6.0,
            mem_gib: 80.0,
        }
    }

    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("name", Json::str(&self.name)),
            ("peak_tflops", Json::num(self.peak_tflops)),
            ("mem_bw_gbs", Json::num(self.mem_bw_gbs)),
            ("launch_overhead_us", Json::num(self.launch_overhead_us)),
            ("mem_gib", Json::num(self.mem_gib)),
        ])
    }

    pub fn from_json(j: &Json) -> anyhow::Result<Self> {
        Ok(DeviceSpec {
            name: j
                .get("name")
                .and_then(Json::as_str)
                .ok_or_else(|| anyhow::anyhow!("device missing name"))?
                .to_string(),
            peak_tflops: j.get("peak_tflops").and_then(Json::as_f64).unwrap_or(100.0),
            mem_bw_gbs: j.get("mem_bw_gbs").and_then(Json::as_f64).unwrap_or(600.0),
            launch_overhead_us: j
                .get("launch_overhead_us")
                .and_then(Json::as_f64)
                .unwrap_or(8.0),
            mem_gib: j.get("mem_gib").and_then(Json::as_f64).unwrap_or(24.0),
        })
    }
}

/// Link class between two devices.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum LinkClass {
    /// Same node: NVLink / PCIe-P2P.
    Intra,
    /// Across nodes: IB / Ethernet.
    Inter,
}

impl LinkClass {
    /// Canonical serialization name (profile-cache snapshots).
    pub fn name(&self) -> &'static str {
        match self {
            LinkClass::Intra => "intra",
            LinkClass::Inter => "inter",
        }
    }

    pub fn parse(name: &str) -> anyhow::Result<LinkClass> {
        match name {
            "intra" => Ok(LinkClass::Intra),
            "inter" => Ok(LinkClass::Inter),
            other => anyhow::bail!("unknown link class '{other}'"),
        }
    }
}

/// Cluster: homogeneous devices, flat two-level network (the paper's
/// setting: "clusters with homogeneous devices and no network hierarchy"
/// beyond the intra/inter-node distinction its comm events carry).
#[derive(Debug, Clone, PartialEq)]
pub struct ClusterSpec {
    pub nodes: usize,
    pub gpus_per_node: usize,
    pub device: DeviceSpec,
    /// Intra-node per-direction bandwidth, GB/s (NVLink-ish).
    pub intra_bw_gbs: f64,
    /// Inter-node per-NIC bandwidth, GB/s (IB-ish).
    pub inter_bw_gbs: f64,
    /// One-way latencies, us.
    pub intra_lat_us: f64,
    pub inter_lat_us: f64,
}

impl ClusterSpec {
    /// The paper's §5 testbed: 4 nodes x 4 A40, PCIe gen4 intra (A40 has
    /// NVLink pairs but the cluster fabric is PCIe), 100 Gb IB inter.
    pub fn a40_cluster(nodes: usize, gpus_per_node: usize) -> Self {
        ClusterSpec {
            nodes,
            gpus_per_node,
            device: DeviceSpec::a40(),
            intra_bw_gbs: 24.0,
            inter_bw_gbs: 12.0,
            intra_lat_us: 6.0,
            inter_lat_us: 18.0,
        }
    }

    /// The paper's §6 testbed: 4 nodes x 4 A10.
    pub fn a10_cluster(nodes: usize, gpus_per_node: usize) -> Self {
        ClusterSpec {
            nodes,
            gpus_per_node,
            device: DeviceSpec::a10(),
            intra_bw_gbs: 20.0,
            inter_bw_gbs: 12.0,
            intra_lat_us: 6.0,
            inter_lat_us: 18.0,
        }
    }

    /// A Megatron-style A100 pod for §5.5: 8 GPUs/node, NVLink intra,
    /// 8x200Gb HDR inter.
    pub fn a100_pod(nodes: usize) -> Self {
        ClusterSpec {
            nodes,
            gpus_per_node: 8,
            device: DeviceSpec::a100(),
            intra_bw_gbs: 300.0,
            inter_bw_gbs: 100.0,
            intra_lat_us: 3.0,
            inter_lat_us: 10.0,
        }
    }

    pub fn total_devices(&self) -> usize {
        self.nodes * self.gpus_per_node
    }

    /// Which node a global device index lives on.
    pub fn node_of(&self, device: usize) -> usize {
        device / self.gpus_per_node
    }

    /// Link class between two global device indices.
    pub fn link_class(&self, a: usize, b: usize) -> LinkClass {
        if self.node_of(a) == self.node_of(b) {
            LinkClass::Intra
        } else {
            LinkClass::Inter
        }
    }

    pub fn bw_gbs(&self, class: LinkClass) -> f64 {
        match class {
            LinkClass::Intra => self.intra_bw_gbs,
            LinkClass::Inter => self.inter_bw_gbs,
        }
    }

    pub fn lat_us(&self, class: LinkClass) -> f64 {
        match class {
            LinkClass::Intra => self.intra_lat_us,
            LinkClass::Inter => self.inter_lat_us,
        }
    }

    /// Link class of a communication *group*: inter-node as soon as any
    /// pair of members crosses nodes (the slowest hop gates a ring).
    pub fn group_link_class(&self, ranks: &[usize]) -> LinkClass {
        let first = self.node_of(ranks[0]);
        if ranks.iter().all(|&r| self.node_of(r) == first) {
            LinkClass::Intra
        } else {
            LinkClass::Inter
        }
    }

    /// Does one rank's share of the model fit in device memory? Used by
    /// the search driver to mark configurations as unreachable (paper
    /// Fig. 12 draws those as 0).
    pub fn fits(&self, params_per_rank: u64) -> bool {
        // params + grads + Adam moments = 4x, fp32 = 4 bytes, plus ~25%
        // activation headroom.
        let need = params_per_rank as f64 * 4.0 * 4.0 * 1.25;
        need <= self.device.mem_gib * (1u64 << 30) as f64
    }

    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("nodes", Json::num(self.nodes as f64)),
            ("gpus_per_node", Json::num(self.gpus_per_node as f64)),
            ("device", self.device.to_json()),
            ("intra_bw_gbs", Json::num(self.intra_bw_gbs)),
            ("inter_bw_gbs", Json::num(self.inter_bw_gbs)),
            ("intra_lat_us", Json::num(self.intra_lat_us)),
            ("inter_lat_us", Json::num(self.inter_lat_us)),
        ])
    }

    pub fn from_json(j: &Json) -> anyhow::Result<Self> {
        Ok(ClusterSpec {
            nodes: j
                .get("nodes")
                .and_then(Json::as_usize)
                .ok_or_else(|| anyhow::anyhow!("cluster missing nodes"))?,
            gpus_per_node: j
                .get("gpus_per_node")
                .and_then(Json::as_usize)
                .ok_or_else(|| anyhow::anyhow!("cluster missing gpus_per_node"))?,
            device: DeviceSpec::from_json(
                j.get("device")
                    .ok_or_else(|| anyhow::anyhow!("cluster missing device"))?,
            )?,
            intra_bw_gbs: j.get("intra_bw_gbs").and_then(Json::as_f64).unwrap_or(24.0),
            inter_bw_gbs: j.get("inter_bw_gbs").and_then(Json::as_f64).unwrap_or(12.0),
            intra_lat_us: j.get("intra_lat_us").and_then(Json::as_f64).unwrap_or(6.0),
            inter_lat_us: j.get("inter_lat_us").and_then(Json::as_f64).unwrap_or(18.0),
        })
    }

    /// Map a strategy rank onto a physical device index (identity in this
    /// homogeneous flat layout: rank == device). Kept as an explicit hook
    /// so heterogeneous mappings can slot in.
    pub fn device_of_rank(&self, _strategy: &Strategy, rank: usize) -> usize {
        rank
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn node_mapping() {
        let c = ClusterSpec::a40_cluster(4, 4);
        assert_eq!(c.total_devices(), 16);
        assert_eq!(c.node_of(0), 0);
        assert_eq!(c.node_of(3), 0);
        assert_eq!(c.node_of(4), 1);
        assert_eq!(c.node_of(15), 3);
    }

    #[test]
    fn link_classes() {
        let c = ClusterSpec::a40_cluster(4, 4);
        assert_eq!(c.link_class(0, 3), LinkClass::Intra);
        assert_eq!(c.link_class(0, 4), LinkClass::Inter);
        assert_eq!(c.group_link_class(&[0, 1, 2, 3]), LinkClass::Intra);
        assert_eq!(c.group_link_class(&[0, 1, 4]), LinkClass::Inter);
    }

    #[test]
    fn intra_is_faster_than_inter_in_presets() {
        for c in [
            ClusterSpec::a40_cluster(4, 4),
            ClusterSpec::a10_cluster(4, 4),
            ClusterSpec::a100_pod(16),
        ] {
            assert!(c.intra_bw_gbs > c.inter_bw_gbs);
            assert!(c.intra_lat_us < c.inter_lat_us);
        }
    }

    #[test]
    fn fits_rejects_whole_145b_on_one_a100() {
        let c = ClusterSpec::a100_pod(16);
        let m = crate::model::zoo::gpt_145b();
        assert!(!c.fits(m.total_params()));
        // but a 128-way shard fits
        assert!(c.fits(m.total_params() / 128));
    }

    #[test]
    fn json_roundtrip() {
        let c = ClusterSpec::a10_cluster(4, 4);
        let j = Json::parse(&c.to_json().to_string()).unwrap();
        assert_eq!(ClusterSpec::from_json(&j).unwrap(), c);
    }
}
