//! DNN model descriptions: the paper's benchmark workloads as layer graphs
//! with exact parameter / FLOP / activation-size accounting.
//!
//! Models are sequences of [`Layer`]s (embedding, transformer blocks, head),
//! which is the granularity the paper's partitioner works at: pipeline
//! stages are contiguous layer ranges, tensor-MP splits inside a layer, DP
//! replicates the whole thing.

pub mod zoo;

pub use zoo::{by_name, model_names};

pub const BYTES_PER_PARAM: u64 = 4; // fp32 training state (paper testbed)

/// One layer of a model.
#[derive(Debug, Clone, PartialEq)]
pub enum Layer {
    /// Token + position embedding lookup.
    Embedding { vocab: usize, hidden: usize },
    /// A standard pre-LN transformer block.
    Transformer(TransformerLayer),
    /// LM head / pooler projection back to vocab.
    Head { vocab: usize, hidden: usize },
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct TransformerLayer {
    pub hidden: usize,
    pub heads: usize,
    pub ffn: usize,
}

/// A whole model plus its training sequence length.
#[derive(Debug, Clone, PartialEq)]
pub struct ModelSpec {
    pub name: String,
    pub layers: Vec<Layer>,
    pub seq: usize,
    pub heads: usize,
    pub hidden: usize,
}

impl TransformerLayer {
    /// Parameters of the full (unsharded) block, incl. LN and biases.
    pub fn params(&self) -> u64 {
        let h = self.hidden as u64;
        let f = self.ffn as u64;
        let qkv = h * 3 * h + 3 * h;
        let proj = h * h + h;
        let mlp = h * f + f + f * h + h;
        let ln = 4 * h;
        qkv + proj + mlp + ln
    }

    /// Forward FLOPs for the full block at (batch, seq) — 2*MACs.
    pub fn flops_fwd(&self, batch: usize, seq: usize) -> u64 {
        let t = (batch * seq) as u64;
        let h = self.hidden as u64;
        let f = self.ffn as u64;
        let d = (self.hidden / self.heads) as u64;
        let lh = self.heads as u64;
        let qkv = 2 * t * h * 3 * h;
        let scores = 2 * lh * (batch as u64) * (seq as u64).pow(2) * d * 2;
        let proj = 2 * t * h * h;
        let mlp = 2 * t * h * f * 2;
        qkv + scores + proj + mlp
    }

    /// Per-rank forward FLOPs under tensor-MP degree `mp` (Megatron split:
    /// the attention-score term scales with local heads, matmuls with the
    /// sharded output/input dim).
    pub fn flops_fwd_mp(&self, batch: usize, seq: usize, mp: usize) -> u64 {
        self.flops_fwd(batch, seq) / mp as u64
    }

    /// Activation bytes leaving the block: (batch*seq, hidden) fp32.
    pub fn activation_bytes(&self, batch: usize, seq: usize) -> u64 {
        (batch * seq * self.hidden) as u64 * 4
    }
}

impl Layer {
    pub fn params(&self) -> u64 {
        match self {
            Layer::Embedding { vocab, hidden } => (vocab * hidden) as u64,
            Layer::Transformer(t) => t.params(),
            Layer::Head { vocab, hidden } => (vocab * hidden) as u64,
        }
    }

    /// Full-layer forward FLOPs at (batch, seq).
    pub fn flops_fwd(&self, batch: usize, seq: usize) -> u64 {
        let t = (batch * seq) as u64;
        match self {
            // embedding lookup is bandwidth-bound; count the gather reads
            Layer::Embedding { hidden, .. } => t * *hidden as u64,
            Layer::Transformer(l) => l.flops_fwd(batch, seq),
            Layer::Head { vocab, hidden } => 2 * t * (*hidden as u64) * (*vocab as u64),
        }
    }

    /// Bytes of activation this layer outputs per (batch, seq).
    pub fn activation_bytes(&self, batch: usize, seq: usize, hidden: usize) -> u64 {
        let _ = self;
        (batch * seq * hidden) as u64 * 4
    }
}

impl ModelSpec {
    pub fn total_params(&self) -> u64 {
        self.layers.iter().map(|l| l.params()).sum()
    }

    pub fn num_transformer_layers(&self) -> usize {
        self.layers
            .iter()
            .filter(|l| matches!(l, Layer::Transformer(_)))
            .count()
    }

    /// Full-model forward FLOPs for one micro-batch.
    pub fn flops_fwd(&self, batch: usize) -> u64 {
        self.layers
            .iter()
            .map(|l| l.flops_fwd(batch, self.seq))
            .sum()
    }

    /// Gradient bytes all-reduced by data parallelism (all parameters).
    pub fn grad_bytes(&self) -> u64 {
        self.total_params() * BYTES_PER_PARAM
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn block(h: usize, heads: usize, f: usize) -> TransformerLayer {
        TransformerLayer {
            hidden: h,
            heads,
            ffn: f,
        }
    }

    #[test]
    fn bert_large_param_count_matches_paper() {
        // Paper intro: Bert-Large ~= 0.34 B params.
        let m = zoo::bert_large();
        let p = m.total_params() as f64 / 1e9;
        assert!((0.30..0.37).contains(&p), "bert-large params = {p} B");
    }

    #[test]
    fn gpt2_345m_param_count() {
        let m = zoo::gpt2_345m();
        let p = m.total_params() as f64 / 1e6;
        assert!((330.0..430.0).contains(&p), "gpt-2 params = {p} M");
    }

    #[test]
    fn gpt_145b_param_count() {
        // §5.5: 145-billion-parameter GPT (Megatron configuration).
        let m = zoo::gpt_145b();
        let p = m.total_params() as f64 / 1e9;
        assert!((135.0..155.0).contains(&p), "gpt-145b params = {p} B");
    }

    #[test]
    fn transformer_flops_quadratic_in_seq_attention_term() {
        let l = block(64, 4, 256);
        let f1 = l.flops_fwd(1, 64);
        let f2 = l.flops_fwd(1, 128);
        // doubling seq more than doubles FLOPs (attention term quadratic)
        assert!(f2 > 2 * f1);
        // but batch is exactly linear
        assert_eq!(l.flops_fwd(2, 64), 2 * f1);
    }

    #[test]
    fn mp_shard_flops_divide_evenly() {
        let l = block(1024, 16, 4096);
        let full = l.flops_fwd(4, 128);
        for mp in [1, 2, 4, 8, 16] {
            assert_eq!(l.flops_fwd_mp(4, 128, mp) * mp as u64, full);
        }
    }

    #[test]
    fn grad_bytes_is_4x_params() {
        let m = zoo::bert_large();
        assert_eq!(m.grad_bytes(), m.total_params() * 4);
    }

    #[test]
    fn zoo_models_have_consistent_heads() {
        for name in zoo::model_names() {
            let m = zoo::by_name(name).unwrap();
            for l in &m.layers {
                if let Layer::Transformer(t) = l {
                    assert_eq!(t.hidden, m.hidden, "{name}");
                    assert_eq!(t.heads, m.heads, "{name}");
                    assert_eq!(t.hidden % t.heads, 0, "{name}");
                }
            }
        }
    }

    #[test]
    fn by_name_unknown_is_none() {
        assert!(zoo::by_name("resnet-50").is_none());
    }
}
