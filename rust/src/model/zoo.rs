//! The paper's benchmark models (§5.1, §5.5, §6).
//!
//! | model        | layers | hidden | heads | ffn   | seq  | params |
//! |--------------|--------|--------|-------|-------|------|--------|
//! | BERT-Large   | 24     | 1024   | 16    | 4096  | 512  | ~0.34B |
//! | GPT-2-345M   | 24     | 1024   | 16    | 4096  | 1024 | ~0.35B |
//! | T5 (large)   | 24+24  | 1024   | 16    | 4096  | 512  | ~0.77B |
//! | BERT-exLarge | 48     | 1024   | 16    | 4096  | 512  | ~0.64B |
//! | GPT-145B     | 80     | 12288  | 96    | 49152 | 2048 | ~145B  |
//!
//! T5's encoder-decoder structure is flattened into a 48-block stack for
//! partitioning purposes (the paper's partitioner does the same: stages are
//! contiguous layer ranges across the enc/dec boundary). GPT-145B follows
//! Megatron-LM SC'21's 8-way-MP x 16-stage configuration.

use super::{Layer, ModelSpec, TransformerLayer};

fn transformer_stack(
    name: &str,
    n_layers: usize,
    hidden: usize,
    heads: usize,
    ffn: usize,
    seq: usize,
    vocab: usize,
) -> ModelSpec {
    let mut layers = Vec::with_capacity(n_layers + 2);
    layers.push(Layer::Embedding { vocab, hidden });
    for _ in 0..n_layers {
        layers.push(Layer::Transformer(TransformerLayer {
            hidden,
            heads,
            ffn,
        }));
    }
    layers.push(Layer::Head { vocab, hidden });
    ModelSpec {
        name: name.to_string(),
        layers,
        seq,
        heads,
        hidden,
    }
}

/// BERT-Large (Devlin et al.): 24 x (1024, 16 heads, 4096 ffn).
pub fn bert_large() -> ModelSpec {
    transformer_stack("bert-large", 24, 1024, 16, 4096, 512, 30522)
}

/// GPT-2-345M (Radford et al.): 24 x (1024, 16 heads, 4096 ffn).
pub fn gpt2_345m() -> ModelSpec {
    transformer_stack("gpt2-345m", 24, 1024, 16, 4096, 1024, 50257)
}

/// T5 (Raffel et al.), large-ish: 24 encoder + 24 decoder blocks flattened.
pub fn t5() -> ModelSpec {
    transformer_stack("t5", 48, 1024, 16, 4096, 512, 32128)
}

/// BERT-exLarge (paper §6): the unseen 48-layer BERT variant used for the
/// auto-strategy search on 16 A10 GPUs.
pub fn bert_ex_large() -> ModelSpec {
    transformer_stack("bert-exlarge", 48, 1024, 16, 4096, 512, 30522)
}

/// GPT-145B (paper §5.5 / Megatron-LM SC'21): 80 x (12288, 96 heads).
pub fn gpt_145b() -> ModelSpec {
    transformer_stack("gpt-145b", 80, 12288, 96, 49152, 2048, 51200)
}

/// Look a model up by CLI name.
pub fn by_name(name: &str) -> Option<ModelSpec> {
    match name.to_ascii_lowercase().as_str() {
        "bert-large" | "bert_large" | "bert" => Some(bert_large()),
        "gpt2-345m" | "gpt2" | "gpt-2-345m" => Some(gpt2_345m()),
        "t5" => Some(t5()),
        "bert-exlarge" | "bert_exlarge" | "bert-ex-large" => Some(bert_ex_large()),
        "gpt-145b" | "gpt145b" => Some(gpt_145b()),
        _ => None,
    }
}

/// All zoo names (stable order, for CLI help and sweep drivers).
pub fn model_names() -> &'static [&'static str] {
    &["bert-large", "gpt2-345m", "t5", "bert-exlarge", "gpt-145b"]
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn zoo_lookup_aliases() {
        assert_eq!(by_name("BERT").unwrap().name, "bert-large");
        assert_eq!(by_name("gpt2").unwrap().name, "gpt2-345m");
        assert!(by_name("nope").is_none());
    }

    #[test]
    fn layer_counts() {
        assert_eq!(bert_large().num_transformer_layers(), 24);
        assert_eq!(t5().num_transformer_layers(), 48);
        assert_eq!(bert_ex_large().num_transformer_layers(), 48);
        assert_eq!(gpt_145b().num_transformer_layers(), 80);
    }

    #[test]
    fn every_model_has_embedding_and_head() {
        for name in model_names() {
            let m = by_name(name).unwrap();
            assert!(matches!(m.layers.first(), Some(Layer::Embedding { .. })));
            assert!(matches!(m.layers.last(), Some(Layer::Head { .. })));
        }
    }
}
