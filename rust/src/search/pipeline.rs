//! The staged candidate pipeline (ISSUE 5): candidate **sources** →
//! **pruner** → evaluator/cache.
//!
//! The sweep used to be a monolithic `SweepConfig → enumerate → evaluate`
//! loop. This module factors the space construction into composable
//! source stages and adds the two layers that make placement search
//! tractable:
//!
//! * **Candidate sources** — [`build_space`] composes the strategy grid,
//!   the micro-batch and schedule axes, the named-placement axis, and the
//!   [`PlacementOptimizer`]'s `Placement::Table` candidates into one
//!   deterministic, index-addressed [`CandidateSpace`]. A
//!   `max_candidates` budget truncates this order, so a budgeted sweep is
//!   a prefix of the full one.
//! * **Placement optimizer** — searches rank→device permutations. The key
//!   reduction: a device's placement-relevant identity is its `(node,
//!   kind)` class ([`ClusterSpec::device_class`]) — swapping two devices
//!   of one class changes neither any rank's SKU nor any link class — so
//!   the space is rank→class assignments, not raw permutations
//!   ([`ClusterSpec::canonicalize_table`] picks the unique
//!   representative). Identically-composed *nodes* are interchangeable as
//!   wholes, so a fresh node of a composition is only ever entered via
//!   its first fresh representative. When the reduced space is small
//!   (≤ [`PLACEMENT_EXHAUSTIVE_LIMIT`]) it is enumerated completely —
//!   together with the pruning bound's soundness this makes the optimizer
//!   *exact* on small fleets; larger fleets fall back to a deterministic
//!   beam search guided by a per-rank cost heuristic, with the survivors
//!   ranked by the exact placement-aware analytical bound.
//! * **Pruner** — [`EpochPlan`] schedules adaptive re-pruning at fixed
//!   candidate-index epochs: evaluation proceeds in bound-descending
//!   order (branch-and-bound style), and after every `chunk`-sized epoch
//!   the incumbent (best simulated throughput so far) re-prunes the
//!   remaining candidates. Because epoch boundaries are fixed counts of
//!   the deterministic evaluation order — never wall-clock or thread
//!   interleaving — the pruned set is bit-identical for any worker count,
//!   preserving the engine's determinism contract. With
//!   `prune_epochs = 1` this degenerates to the historical single
//!   up-front incumbent.
//!
//! [`PruneStats`] carries the accounting the CLI, service responses and
//! `BENCH_placement.json` surface, mirroring the Table-3 cache
//! accounting.

use std::collections::{BTreeSet, HashMap};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;

use crate::baseline::analytical::analytical_batch_time_us;
use crate::cluster::{ClusterSpec, Placement, PlacementPolicy};
use crate::cost::CostModel;
use crate::memory::Recompute;
use crate::model::ModelSpec;
use crate::partition::partition;
use crate::schedule::SchedKind;
use crate::strategy::{RankCoords, Strategy};

use super::engine::{CandidateSpec, SweepConfig};
use super::{grid, widened_grid};

/// Sentinel for "this candidate deploys no optimizer table"
/// ([`CandidateSpec::table`]).
pub const NO_TABLE: u32 = u32::MAX;

/// Exhaustive-enumeration ceiling for the symmetry-reduced placement
/// space: at or below this many canonical tables the optimizer emits
/// every one of them (exact search — the pruning bound then guarantees
/// the true optimum is never discarded); above it, beam search caps the
/// candidate count at [`SweepConfig::beam`].
pub const PLACEMENT_EXHAUSTIVE_LIMIT: usize = 128;

/// Constructive tables the beam regime always seeds alongside the beam
/// survivors: the three named placements plus the lane-alternating and
/// weight-greedy anchors.
const ANCHOR_TABLES: usize = 5;

/// Cooperative cancellation flag for an in-flight sweep (ISSUE 6).
///
/// Cloned into every evaluation worker; the sweep checks it at
/// candidate-evaluation boundaries — at the top of every pruning epoch
/// and before each individual candidate — and stops dispatching new work
/// once it fires. A candidate whose evaluation has *started* runs to
/// completion (evaluation never observes the flag mid-candidate), so
/// cancellation can never produce a torn measurement or a torn cache
/// entry; everything the cancelled sweep did measure stays valid in the
/// shared [`ProfileCache`](super::ProfileCache).
///
/// Cancellation is inherently wall-clock (like `budget.deadline_ms`):
/// which candidate boundary observes the flag depends on timing, so a
/// cancelled sweep's partial report is *not* covered by the bit-identity
/// contract. Callers that care about determinism simply never cancel.
#[derive(Debug, Clone, Default)]
pub struct CancelToken {
    fired: Arc<AtomicBool>,
}

impl CancelToken {
    /// A fresh, un-fired token.
    pub fn new() -> Self {
        Self::default()
    }

    /// Fire the token. Idempotent; safe from any thread.
    pub fn cancel(&self) {
        self.fired.store(true, Ordering::SeqCst);
    }

    /// Has [`cancel`](Self::cancel) been called?
    pub fn is_cancelled(&self) -> bool {
        self.fired.load(Ordering::SeqCst)
    }
}

/// Accounting of the pruning layer — what the `distsim search` accounting
/// block, the service's `pruning` response object and
/// `BENCH_placement.json` report. Deterministic (a pure function of the
/// candidate set and the simulated throughputs).
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct PruneStats {
    /// Candidates the sources generated (= `SweepReport::candidates` len).
    pub generated: usize,
    /// Pruned by the memory-feasibility stage at the head of the pipeline
    /// (ISSUE 9): some rank's peak residency exceeds its SKU's declared
    /// `capacity_bytes`. Free — no profiling, no simulation — and
    /// independent of `SweepConfig::prune` (feasibility is a hard
    /// constraint, not a performance heuristic).
    pub memory_pruned: usize,
    /// Pruned by the initial incumbent (the analytically-best candidate,
    /// evaluated first).
    pub bound_pruned: usize,
    /// Pruned by an improved incumbent at a later epoch boundary.
    pub epoch_repruned: usize,
    /// Candidates that went through the evaluator (everything not pruned,
    /// including invalid/unreachable ones — those are cheap).
    pub evaluated: usize,
    /// Profiling cost the pruned candidates' events would have added: a
    /// deterministic noise-free estimate (the profiler's cost laws, never
    /// an actual measurement) of every event only pruned candidates
    /// reference, each counted once like the cache dedup. 0 on cache-off
    /// sweeps, whose evaluated event set is untracked. Includes
    /// `memory_gpu_seconds_avoided`.
    pub gpu_seconds_avoided: f64,
    /// The memory stage's share of `gpu_seconds_avoided`: events shared
    /// between a memory-pruned and a bound-pruned candidate are credited
    /// here (the memory stage runs first).
    pub memory_gpu_seconds_avoided: f64,
}

/// The sweep's candidate space: index-addressed specs plus the placement
/// optimizer's table pool (`CandidateSpec::table` indexes into `tables`).
#[derive(Debug, Clone, Default)]
pub struct CandidateSpace {
    pub specs: Vec<CandidateSpec>,
    pub tables: Vec<Vec<usize>>,
    /// Per-spec analytical bound the optimizer already computed while
    /// ranking tables (`None` for non-optimizer candidates) — the pruning
    /// pass reuses it instead of re-deriving the identical number.
    pub seed_bounds: Vec<Option<f64>>,
}

// ---------------------------------------------------------------------------
// candidate sources

/// Source stage 1+2: strategies × (micro-batching × schedule) points, in
/// the deterministic order the engine has always used.
fn strategy_points(cluster: &ClusterSpec, cfg: &SweepConfig) -> Vec<CandidateSpec> {
    let devices = cluster.total_devices();
    let strategies = if cfg.widened {
        widened_grid(devices)
    } else {
        grid(devices)
    };
    let mut specs = Vec::new();
    for s in strategies {
        let base = CandidateSpec::default_for(s, cfg.global_batch);
        specs.push(base);
        if s.pp <= 1 || base.micro_batch_size == 0 {
            continue;
        }
        let per_replica = cfg.global_batch / s.dp;
        let push_mb_grid = |specs: &mut Vec<CandidateSpec>, schedule: SchedKind| {
            if !cfg.micro_batch_axis {
                return;
            }
            for mbs in 2..=per_replica {
                // with the schedule axis on, the single-micro-batch point
                // of EVERY grid is the Naive schedule (one micro-batch
                // degenerates them all to the same sequential F/B); keep
                // only the Naive-labeled copy
                if per_replica % mbs == 0 && !(cfg.schedule_axis && mbs == per_replica) {
                    specs.push(CandidateSpec {
                        micro_batch_size: mbs,
                        micro_batches: per_replica / mbs,
                        schedule,
                        ..base
                    });
                }
            }
        };
        push_mb_grid(&mut specs, SchedKind::Dapple);
        // with one micro-batch per replica every schedule degenerates to
        // the same sequential F/B — the Dapple base already covers it, so
        // the schedule axis only applies when per_replica > 1
        if cfg.schedule_axis && per_replica > 1 {
            specs.push(CandidateSpec {
                micro_batch_size: 1,
                micro_batches: per_replica,
                schedule: SchedKind::GPipe,
                ..base
            });
            push_mb_grid(&mut specs, SchedKind::GPipe);
            // naive: the whole replica batch as one micro-batch
            specs.push(CandidateSpec {
                micro_batch_size: per_replica,
                micro_batches: 1,
                schedule: SchedKind::Naive,
                ..base
            });
        }
    }
    specs
}

/// Source stage 2b: the memory axes — each point replicated across the
/// enabled recompute/ZeRO grids, point-major with the `(none, 0)`
/// baseline first, so axis-off sweeps are order-preserved sub-sequences.
/// Degenerate variants are skipped: the `micro_batch_size == 0` sentinel
/// is unreachable under every axis value, and `zero_stage: 1` with
/// `dp == 1` simulates and prices bit-identically to stage 0 (nothing to
/// shard, no extra gather), so only dp>1 points grow ZeRO variants.
fn replicate_over_memory_axes(
    specs: Vec<CandidateSpec>,
    cfg: &SweepConfig,
) -> Vec<CandidateSpec> {
    if !cfg.recompute_axis && !cfg.zero_axis {
        return specs;
    }
    let mut out = Vec::with_capacity(specs.len() * 4);
    for base in specs {
        out.push(base);
        if base.micro_batch_size == 0 {
            continue;
        }
        if cfg.recompute_axis {
            out.push(CandidateSpec {
                recompute: Recompute::Full,
                ..base
            });
        }
        if cfg.zero_axis && base.strategy.dp > 1 {
            out.push(CandidateSpec {
                zero_stage: 1,
                ..base
            });
            if cfg.recompute_axis {
                out.push(CandidateSpec {
                    recompute: Recompute::Full,
                    zero_stage: 1,
                    ..base
                });
            }
        }
    }
    out
}

/// Source stage 3: the named-placement axis — each point replicated
/// across [`PlacementPolicy::AXIS`], baseline first (spec-major order
/// keeps a budgeted sweep a prefix of the unbudgeted one).
fn replicate_over_placements(specs: Vec<CandidateSpec>) -> Vec<CandidateSpec> {
    specs
        .into_iter()
        .flat_map(|base| {
            PlacementPolicy::AXIS
                .into_iter()
                .map(move |placement| CandidateSpec { placement, ..base })
        })
        .collect()
}

/// Compose the full candidate space for one sweep. Order: the
/// strategy/schedule/micro-batch points (× the memory axes when on, ×
/// the named-placement axis when on), then the placement optimizer's
/// `Placement::Table` candidates — per strategy in enumeration order,
/// bound-descending within a strategy. The optimizer searches placements
/// at the `(recompute: none, zero_stage: 0)` baseline only: its table
/// ranking is memory-point-independent in relative order, and the named
/// axes already cover the cross products.
pub fn build_space(model: &ModelSpec, cluster: &ClusterSpec, cfg: &SweepConfig) -> CandidateSpace {
    build_space_seeded(model, cluster, cfg, None)
}

/// [`build_space`] with an optionally pre-computed canonical-table
/// enumeration: the enumeration is a pure function of the cluster's
/// device-class structure, so the plan compiler's
/// [`TableMemo`](super::plan::TableMemo) hands one in and repeated
/// requests against the same fleet skip the symmetry-reduced DFS (and
/// its per-table canonicalization) entirely. `None` as the *inner* value
/// means the memoized enumeration overflowed the exhaustive limit — the
/// beam regime, exactly as a fresh enumeration would have chosen.
pub fn build_space_seeded(
    model: &ModelSpec,
    cluster: &ClusterSpec,
    cfg: &SweepConfig,
    precomputed: Option<&Option<Vec<Vec<usize>>>>,
) -> CandidateSpace {
    let mut specs = replicate_over_memory_axes(strategy_points(cluster, cfg), cfg);
    // named axis and optimizer are both no-ops on homogeneous clusters,
    // where every placement prices identically
    if cfg.placement_axis && cluster.is_heterogeneous() {
        specs = replicate_over_placements(specs);
    }
    let mut tables = Vec::new();
    let mut seed_bounds: Vec<Option<f64>> = vec![None; specs.len()];
    if cfg.placement_opt && cluster.is_heterogeneous() {
        let opt = PlacementOptimizer::new(model, cluster, cfg);
        // the canonical enumeration is strategy-independent: run it once
        // (or take the memoized copy), and intern tables so strategies
        // sharing a table share one pool entry (candidates still carry
        // their own spec each)
        let canonical = match precomputed {
            Some(memoized) => memoized.clone(),
            None => enumerate_canonical_tables(cluster, PLACEMENT_EXHAUSTIVE_LIMIT),
        };
        let mut interned: HashMap<Vec<usize>, u32> = HashMap::new();
        let devices = cluster.total_devices();
        let strategies = if cfg.widened {
            widened_grid(devices)
        } else {
            grid(devices)
        };
        for s in strategies {
            opt.emit(
                s,
                canonical.as_deref(),
                &mut specs,
                &mut tables,
                &mut seed_bounds,
                &mut interned,
            );
        }
    }
    if cfg.max_candidates > 0 {
        specs.truncate(cfg.max_candidates);
        seed_bounds.truncate(cfg.max_candidates);
    }
    CandidateSpace {
        specs,
        tables,
        seed_bounds,
    }
}

// ---------------------------------------------------------------------------
// the placement optimizer

/// Searches `Placement::Table` permutations for each strategy (module
/// docs describe the canonicalization/symmetry/beam scheme).
pub struct PlacementOptimizer<'a> {
    model: &'a ModelSpec,
    cluster: &'a ClusterSpec,
    cfg: &'a SweepConfig,
}

impl<'a> PlacementOptimizer<'a> {
    pub fn new(model: &'a ModelSpec, cluster: &'a ClusterSpec, cfg: &'a SweepConfig) -> Self {
        PlacementOptimizer {
            model,
            cluster,
            cfg,
        }
    }

    /// Append this strategy's table candidates to the space. Exhaustive
    /// when the symmetry-reduced space fits
    /// [`PLACEMENT_EXHAUSTIVE_LIMIT`] (`canonical` is the pre-computed,
    /// strategy-independent enumeration); beam-capped otherwise (the beam
    /// set is seeded with the three named placements' tables so the
    /// optimizer never does worse than the named axis). Tables land in the
    /// sweep-wide pool through `interned`, so strategies sharing a table
    /// share one pool entry.
    pub fn emit(
        &self,
        strategy: Strategy,
        canonical: Option<&[Vec<usize>]>,
        specs: &mut Vec<CandidateSpec>,
        tables: &mut Vec<Vec<usize>>,
        seed_bounds: &mut Vec<Option<f64>>,
        interned: &mut HashMap<Vec<usize>, u32>,
    ) {
        let base = CandidateSpec::default_for(strategy, self.cfg.global_batch);
        if base.micro_batch_size == 0
            || !strategy.is_valid_for(
                self.model.heads,
                self.model.num_transformer_layers(),
                strategy.world_size(),
            )
        {
            return;
        }
        // beam survivors + deterministic constructive anchors: the three
        // named placements (so the optimizer never does worse than the
        // named axis), a lane-alternating table (balances SKUs across DP
        // replicas — the beam's greedy per-rank score is replica-blind)
        // and a weight-greedy table (heaviest stages onto fastest SKUs)
        let beam_set: Vec<Vec<usize>> = if canonical.is_none() {
            let mut set: BTreeSet<Vec<usize>> = self
                .beam_tables(strategy, base.micro_batch_size)
                .into_iter()
                .collect();
            for p in [
                self.cluster.placement.clone(),
                Placement::FastFirst,
                Placement::Interleaved,
            ] {
                let t = self.cluster.with_placement(p).rank_to_device();
                set.insert(self.cluster.canonicalize_table(&t));
            }
            set.insert(
                self.cluster
                    .canonicalize_table(&self.alternating_table(strategy)),
            );
            set.insert(
                self.cluster
                    .canonicalize_table(&self.weight_greedy_table(strategy)),
            );
            set.into_iter().collect()
        } else {
            Vec::new()
        };
        let cand: &[Vec<usize>] = canonical.unwrap_or(&beam_set);
        // rank by the exact placement-aware analytical bound, best first
        // (ties break toward the lexicographically smaller table — a pure
        // function of the inputs, so the emitted order is deterministic)
        let mut scored: Vec<(f64, &Vec<usize>)> = cand
            .iter()
            .map(|t| (self.table_bound(strategy, &base, t), t))
            .collect();
        scored.sort_by(|a, b| b.0.total_cmp(&a.0).then(a.1.cmp(b.1)));
        let keep = if canonical.is_some() {
            scored.len() // exhaustive regime: emit every canonical table
        } else {
            scored.len().min(self.cfg.beam.max(1) + ANCHOR_TABLES)
        };
        for (bound, t) in scored.into_iter().take(keep) {
            let idx = match interned.get(t) {
                Some(&idx) => idx,
                None => {
                    let idx = tables.len() as u32;
                    tables.push(t.clone());
                    interned.insert(t.clone(), idx);
                    idx
                }
            };
            specs.push(CandidateSpec {
                placement: PlacementPolicy::Optimized,
                table: idx,
                ..base
            });
            seed_bounds.push(Some(bound));
        }
    }

    /// The exact analytical throughput bound of one (strategy, table)
    /// point — the score the optimizer ranks tables by, and the same
    /// bound the pruner later uses (so ranking and pruning agree).
    fn table_bound(&self, strategy: Strategy, base: &CandidateSpec, table: &[usize]) -> f64 {
        let c = self
            .cluster
            .with_placement(Placement::Table(table.to_vec()));
        let part = partition(self.model, &strategy, &c, base.micro_batch_size);
        if !c.fits(part.max_params_per_rank()) {
            return 0.0;
        }
        let sched = base.schedule.build(strategy.pp, base.micro_batches);
        let us = analytical_batch_time_us(self.model, &part, &sched, &c);
        if us > 0.0 {
            1e6 / us
        } else {
            0.0
        }
    }

    /// Deterministic beam search over rank→class assignments for one
    /// strategy. States expand rank by rank; the per-rank heuristic
    /// charges the rank's stage compute at its class's SKU plus
    /// inter-node penalties for the MP group and the inter-stage hop.
    /// Ties break on the lexicographically smaller partial assignment.
    fn beam_tables(&self, strategy: Strategy, mbs: usize) -> Vec<Vec<usize>> {
        let cluster = self.cluster;
        let classes = cluster.device_classes();
        let sizes: Vec<usize> = classes.iter().map(|(_, slots)| slots.len()).collect();
        let n = cluster.total_devices();
        let beam = self.cfg.beam.max(1);

        // per-(stage, kind) ideal compute and per-stage comm penalties
        let part = partition(self.model, &strategy, cluster, mbs);
        let cm = CostModel::default();
        let kinds = cluster.kind_count();
        let w: Vec<Vec<f64>> = (0..strategy.pp)
            .map(|s| {
                (0..kinds)
                    .map(|k| {
                        let spec = cluster.kind_spec(k);
                        part.stages[s]
                            .layers
                            .iter()
                            .map(|lw| {
                                cm.analytical_latency_us(spec, lw.fwd.flops, lw.fwd.bytes)
                                    + cm.analytical_latency_us(spec, lw.bwd.flops, lw.bwd.bytes)
                            })
                            .sum()
                    })
                    .collect()
            })
            .collect();
        let inv_bw = |link: crate::cluster::LinkClass| 1.0 / (cluster.bw_gbs(link) * 1e3);
        let bw_gap =
            inv_bw(crate::cluster::LinkClass::Inter) - inv_bw(crate::cluster::LinkClass::Intra);
        let ar_penalty: Vec<f64> = (0..strategy.pp)
            .map(|s| {
                part.stages[s]
                    .layers
                    .iter()
                    .map(|lw| match &lw.mp_allreduce {
                        Some(crate::events::CommEvent::AllReduce { bytes, .. }) => {
                            let m = strategy.mp as f64;
                            (lw.ar_count_fwd + lw.ar_count_bwd) as f64
                                * 2.0
                                * (m - 1.0)
                                / m
                                * *bytes as f64
                                * bw_gap
                        }
                        _ => 0.0,
                    })
                    .sum()
            })
            .collect();
        let p2p_penalty: Vec<f64> = (0..strategy.pp)
            .map(|s| 2.0 * part.stages[s].act_bytes as f64 * bw_gap)
            .collect();
        let compositions: Vec<Vec<(usize, usize)>> = (0..cluster.nodes)
            .map(|nd| node_composition(cluster, nd))
            .collect();

        struct State {
            assign: Vec<u8>,
            used: Vec<usize>,
            score: f64,
        }
        let mut front = vec![State {
            assign: Vec::new(),
            used: vec![0; classes.len()],
            score: 0.0,
        }];
        for r in 0..n {
            let coords = strategy.coords(r);
            let stage = coords.pp;
            let mut next: Vec<State> = Vec::new();
            for st in &front {
                for (ci, ((node, kind), _)) in classes.iter().enumerate() {
                    if st.used[ci] >= sizes[ci] {
                        continue;
                    }
                    if fresh_node_symmetry_skip(&classes, &st.used, &compositions, *node) {
                        continue;
                    }
                    let mut score = st.score + w[stage][*kind];
                    // MP barrier: a group member on another node turns the
                    // per-layer all-reduces inter-node
                    let crosses_mp = (0..coords.mp).any(|m| {
                        let peer = strategy.rank_of(RankCoords { mp: m, ..coords });
                        classes[st.assign[peer] as usize].0 .0 != *node
                    });
                    if crosses_mp {
                        score += ar_penalty[stage];
                    }
                    // inter-stage hop from the pipeline predecessor
                    if stage > 0 {
                        let pred = strategy.rank_of(RankCoords {
                            pp: stage - 1,
                            ..coords
                        });
                        if pred < st.assign.len()
                            && classes[st.assign[pred] as usize].0 .0 != *node
                        {
                            score += p2p_penalty[stage - 1];
                        }
                    }
                    let mut assign = st.assign.clone();
                    assign.push(ci as u8);
                    let mut used = st.used.clone();
                    used[ci] += 1;
                    next.push(State {
                        assign,
                        used,
                        score,
                    });
                }
            }
            next.sort_by(|a, b| a.score.total_cmp(&b.score).then(a.assign.cmp(&b.assign)));
            next.truncate(beam);
            front = next;
        }
        front
            .into_iter()
            .map(|st| assignment_to_table(&classes, &st.assign))
            .collect()
    }

    /// Fill one (stage, replica) lane's `mp` ranks from the classes in
    /// `preference` order (first class with free slots wins, slot indices
    /// ascending). Shared by the constructive table builders.
    fn fill_lane(
        &self,
        strategy: Strategy,
        s: usize,
        d: usize,
        preference: &[usize],
        classes: &[((usize, usize), Vec<usize>)],
        next_free: &mut [usize],
        table: &mut [usize],
    ) {
        for m in 0..strategy.mp {
            let rank = strategy.rank_of(RankCoords { mp: m, pp: s, dp: d });
            let ci = preference
                .iter()
                .copied()
                .find(|&ci| next_free[ci] < classes[ci].1.len())
                .expect("class capacities cover the world");
            table[rank] = classes[ci].1[next_free[ci]];
            next_free[ci] += 1;
        }
    }

    /// Kind ranking (fastest first) and, per kind, that kind's class
    /// indices (node ascending).
    fn kind_classes(
        &self,
        classes: &[((usize, usize), Vec<usize>)],
    ) -> Vec<(usize, Vec<usize>)> {
        let mut kinds = self.cluster.kinds_in_use();
        kinds.sort_by(|&a, &b| {
            self.cluster
                .kind_spec(b)
                .peak_tflops
                .total_cmp(&self.cluster.kind_spec(a).peak_tflops)
                .then(a.cmp(&b))
        });
        kinds
            .into_iter()
            .map(|k| {
                let cis: Vec<usize> = classes
                    .iter()
                    .enumerate()
                    .filter(|(_, ((_, ck), _))| *ck == k)
                    .map(|(ci, _)| ci)
                    .collect();
                (k, cis)
            })
            .collect()
    }

    /// Constructive anchor 1: deal SKUs across (stage, replica) lanes
    /// round-robin — lane (s, d) prefers the `(s + d) % kinds`-fastest
    /// kind — so every DP replica gets a balanced SKU mix (the DP-barrier
    /// gradient all-reduce waits for the slowest replica, so an all-slow
    /// replica paces the whole batch).
    fn alternating_table(&self, strategy: Strategy) -> Vec<usize> {
        let classes = self.cluster.device_classes();
        let by_kind = self.kind_classes(&classes);
        let n = self.cluster.total_devices();
        let mut table = vec![0usize; n];
        let mut next_free = vec![0usize; classes.len()];
        for d in 0..strategy.dp {
            for s in 0..strategy.pp {
                let start = (s + d) % by_kind.len();
                let preference: Vec<usize> = (0..by_kind.len())
                    .flat_map(|o| by_kind[(start + o) % by_kind.len()].1.clone())
                    .collect();
                self.fill_lane(
                    strategy, s, d, &preference, &classes, &mut next_free, &mut table,
                );
            }
        }
        table
    }

    /// Constructive anchor 2: lanes sorted by descending stage FLOPs take
    /// the fastest remaining SKUs — every replica's heavy stages (the
    /// head, remainder-layer stages) land on fast silicon first.
    fn weight_greedy_table(&self, strategy: Strategy) -> Vec<usize> {
        let classes = self.cluster.device_classes();
        let by_kind = self.kind_classes(&classes);
        let part = partition(
            self.model,
            &strategy,
            self.cluster,
            CandidateSpec::default_for(strategy, self.cfg.global_batch)
                .micro_batch_size
                .max(1),
        );
        let weight = |s: usize| -> u64 {
            part.stages[s]
                .layers
                .iter()
                .map(|lw| lw.fwd.flops + lw.bwd.flops)
                .sum()
        };
        let mut lanes: Vec<(usize, usize)> = (0..strategy.pp)
            .flat_map(|s| (0..strategy.dp).map(move |d| (s, d)))
            .collect();
        lanes.sort_by(|a, b| weight(b.0).cmp(&weight(a.0)).then(a.cmp(b)));
        let preference: Vec<usize> = by_kind.iter().flat_map(|(_, cis)| cis.clone()).collect();
        let n = self.cluster.total_devices();
        let mut table = vec![0usize; n];
        let mut next_free = vec![0usize; classes.len()];
        for (s, d) in lanes {
            self.fill_lane(
                strategy, s, d, &preference, &classes, &mut next_free, &mut table,
            );
        }
        table
    }

}

/// Identical-node symmetry breaking, shared by the exhaustive DFS and the
/// beam search (one rule, one implementation — the two regimes must agree
/// on which placements are symmetric duplicates): entering a completely
/// fresh node is only allowed via the first fresh node of its composition.
fn fresh_node_symmetry_skip(
    classes: &[((usize, usize), Vec<usize>)],
    used: &[usize],
    compositions: &[Vec<(usize, usize)>],
    node: usize,
) -> bool {
    let node_fresh = |n: usize| {
        classes
            .iter()
            .enumerate()
            .filter(|(_, ((cn, _), _))| *cn == n)
            .all(|(ci, _)| used[ci] == 0)
    };
    node_fresh(node)
        && (0..node).any(|n2| node_fresh(n2) && compositions[n2] == compositions[node])
}

/// A node's kind composition: sorted (kind, count) pairs. Two nodes with
/// equal compositions are interchangeable as wholes.
fn node_composition(cluster: &ClusterSpec, node: usize) -> Vec<(usize, usize)> {
    let mut counts: Vec<(usize, usize)> = Vec::new();
    for d in 0..cluster.total_devices() {
        if cluster.node_of(d) != node {
            continue;
        }
        let k = cluster.device_kind(d);
        match counts.binary_search_by(|(ck, _)| ck.cmp(&k)) {
            Ok(i) => counts[i].1 += 1,
            Err(i) => counts.insert(i, (k, 1)),
        }
    }
    counts
}

/// Turn a rank→class assignment into its canonical rank→device table
/// (smallest unused slot of the class, in rank order).
fn assignment_to_table(classes: &[((usize, usize), Vec<usize>)], assign: &[u8]) -> Vec<usize> {
    let mut next = vec![0usize; classes.len()];
    assign
        .iter()
        .map(|&ci| {
            let ci = ci as usize;
            let slot = classes[ci].1[next[ci]];
            next[ci] += 1;
            slot
        })
        .collect()
}

/// Enumerate every canonical rank→device table of the fleet, with
/// identical-node symmetry breaking, in deterministic (class-index
/// lexicographic) order. Returns `None` as soon as more than `limit`
/// tables exist — the caller then falls back to beam search.
pub fn enumerate_canonical_tables(
    cluster: &ClusterSpec,
    limit: usize,
) -> Option<Vec<Vec<usize>>> {
    let classes = cluster.device_classes();
    let sizes: Vec<usize> = classes.iter().map(|(_, slots)| slots.len()).collect();
    let n = cluster.total_devices();
    let compositions: Vec<Vec<(usize, usize)>> = (0..cluster.nodes)
        .map(|nd| node_composition(cluster, nd))
        .collect();

    let mut out: Vec<Vec<usize>> = Vec::new();
    let mut assign: Vec<u8> = Vec::with_capacity(n);
    let mut used = vec![0usize; classes.len()];

    fn dfs(
        rank: usize,
        n: usize,
        classes: &[((usize, usize), Vec<usize>)],
        sizes: &[usize],
        compositions: &[Vec<(usize, usize)>],
        assign: &mut Vec<u8>,
        used: &mut Vec<usize>,
        out: &mut Vec<Vec<usize>>,
        limit: usize,
    ) -> bool {
        if rank == n {
            if out.len() >= limit {
                return false; // space too large: abort enumeration
            }
            out.push(assignment_to_table(classes, assign));
            return true;
        }
        for ci in 0..classes.len() {
            if used[ci] >= sizes[ci] {
                continue;
            }
            let node = classes[ci].0 .0;
            if fresh_node_symmetry_skip(classes, used, compositions, node) {
                continue;
            }
            assign.push(ci as u8);
            used[ci] += 1;
            let ok = dfs(
                rank + 1,
                n,
                classes,
                sizes,
                compositions,
                assign,
                used,
                out,
                limit,
            );
            used[ci] -= 1;
            assign.pop();
            if !ok {
                return false;
            }
        }
        true
    }

    if dfs(
        0,
        n,
        &classes,
        &sizes,
        &compositions,
        &mut assign,
        &mut used,
        &mut out,
        limit,
    ) {
        Some(out)
    } else {
        None
    }
}

// ---------------------------------------------------------------------------
// the adaptive pruner

/// The deterministic epoch schedule of one pruned sweep: evaluation
/// proceeds over `order` (bound-descending when pruning); the first epoch
/// evaluates exactly one candidate (the analytically-best — the incumbent
/// seed, reproducing the historical behaviour), and every later epoch
/// evaluates up to `chunk` not-yet-pruned candidates. Between epochs the
/// caller re-prunes against the improved incumbent. Epoch boundaries are
/// fixed candidate counts, so the schedule — and therefore the pruned set
/// — is independent of worker count.
#[derive(Debug)]
pub struct EpochPlan {
    pub order: Vec<usize>,
    pub chunk: usize,
    seeded: bool,
    cursor: usize,
}

impl EpochPlan {
    /// Build the plan: `order` is bound-descending (ties toward the lower
    /// spec index) when pruning, the natural spec order otherwise.
    pub fn new(bounds: &[f64], prune: bool, epochs: usize) -> EpochPlan {
        let n = bounds.len();
        let order: Vec<usize> = if prune {
            let mut idx: Vec<usize> = (0..n).collect();
            idx.sort_by(|&a, &b| bounds[b].total_cmp(&bounds[a]).then(a.cmp(&b)));
            idx
        } else {
            (0..n).collect()
        };
        let epochs = epochs.max(1);
        let chunk = if prune {
            n.saturating_sub(1).div_ceil(epochs).max(1)
        } else {
            n.max(1)
        };
        EpochPlan {
            order,
            chunk,
            seeded: !prune,
            cursor: 0,
        }
    }

    /// The next epoch's evaluation set (skipping pruned indices), or an
    /// empty vector when the order is exhausted.
    pub fn next_epoch(&mut self, pruned: &[bool]) -> Vec<usize> {
        let take = if self.seeded { self.chunk } else { 1 };
        self.seeded = true;
        let mut chunk = Vec::new();
        while self.cursor < self.order.len() && chunk.len() < take {
            let i = self.order[self.cursor];
            self.cursor += 1;
            if !pruned[i] {
                chunk.push(i);
            }
        }
        chunk
    }

    pub fn exhausted(&self) -> bool {
        self.cursor >= self.order.len()
    }

    /// Indices not yet handed to an epoch — the set a re-prune may touch
    /// (already-evaluated candidates are behind the cursor and immutable).
    pub fn remaining(&self) -> &[usize] {
        &self.order[self.cursor..]
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::zoo;

    #[test]
    fn canonical_enumeration_counts_the_symmetry_reduced_space() {
        // mixed 2x4: 8 ranks over two 4-slot classes -> C(8,4) = 70
        let c = ClusterSpec::mixed_a40_a10(2, 4);
        let all = enumerate_canonical_tables(&c, 128).expect("70 <= 128");
        assert_eq!(all.len(), 70);
        // every table is canonical, unique, and a permutation
        let set: BTreeSet<&Vec<usize>> = all.iter().collect();
        assert_eq!(set.len(), 70);
        for t in &all {
            assert_eq!(c.canonicalize_table(t), *t, "not canonical: {t:?}");
            let mut s = t.clone();
            s.sort_unstable();
            assert_eq!(s, (0..8).collect::<Vec<_>>());
        }
        // the named placements' tables are all in the set
        for p in [Placement::Linear, Placement::FastFirst, Placement::Interleaved] {
            let t = c.with_placement(p.clone()).rank_to_device();
            let canon = c.canonicalize_table(&t);
            assert!(all.contains(&canon), "{p:?} missing from the canonical set");
        }
        // and a tight limit aborts instead of truncating
        assert!(enumerate_canonical_tables(&c, 69).is_none());
    }

    #[test]
    fn identical_nodes_are_entered_via_their_first_representative() {
        // 2 identical all-A40 nodes: the only rank->class choice that
        // matters is "how many ranks on the first-touched node", so the
        // space collapses from C(4,2)=6 raw class assignments to 3
        let c = ClusterSpec::a40_cluster(2, 2);
        let all = enumerate_canonical_tables(&c, 128).unwrap();
        assert_eq!(all.len(), 3, "{all:?}");
    }

    #[test]
    fn epoch_plan_reproduces_the_single_incumbent_scheme() {
        let bounds = vec![1.0, 5.0, 3.0, 5.0, 0.0];
        let mut plan = EpochPlan::new(&bounds, true, 1);
        // bound-descending, ties toward the lower index
        assert_eq!(plan.order, vec![1, 3, 2, 0, 4]);
        let pruned = vec![false; 5];
        assert_eq!(plan.next_epoch(&pruned), vec![1], "seed epoch");
        // one epoch: everything else in one chunk
        assert_eq!(plan.next_epoch(&pruned), vec![3, 2, 0, 4]);
        assert!(plan.exhausted());
    }

    #[test]
    fn epoch_plan_chunks_and_skips_pruned() {
        let bounds = vec![4.0, 3.0, 2.0, 1.0, 0.5];
        let mut plan = EpochPlan::new(&bounds, true, 2);
        assert_eq!(plan.chunk, 2);
        let mut pruned = vec![false; 5];
        assert_eq!(plan.next_epoch(&pruned), vec![0]);
        pruned[2] = true; // re-pruned between epochs
        assert_eq!(plan.next_epoch(&pruned), vec![1, 3]);
        assert_eq!(plan.next_epoch(&pruned), vec![4]);
        assert!(plan.exhausted());
        assert_eq!(plan.next_epoch(&pruned), Vec::<usize>::new());
    }

    #[test]
    fn optimizer_emits_bound_ranked_tables_for_a_mixed_fleet() {
        let model = zoo::bert_large();
        let cluster = ClusterSpec::mixed_a40_a10(2, 4);
        let cfg = SweepConfig {
            global_batch: 8,
            placement_opt: true,
            ..SweepConfig::default()
        };
        let space = build_space(&model, &cluster, &cfg);
        assert!(!space.tables.is_empty());
        let opt: Vec<&CandidateSpec> = space
            .specs
            .iter()
            .filter(|s| s.placement == PlacementPolicy::Optimized)
            .collect();
        assert!(!opt.is_empty());
        for s in &opt {
            let t = &space.tables[s.table as usize];
            assert_eq!(cluster.canonicalize_table(t), **t);
        }
        // exhaustive regime on this fleet: every strategy with tables
        // carries the full 70-table canonical set
        let per_strategy = opt
            .iter()
            .filter(|s| s.strategy == Strategy::new(1, 2, 4))
            .count();
        assert_eq!(per_strategy, 70);
        // homogeneous clusters skip the optimizer entirely
        let h = build_space(&model, &ClusterSpec::a40_cluster(2, 4), &cfg);
        assert!(h.tables.is_empty());
    }

    #[test]
    fn memory_axes_expand_points_defaults_first() {
        let model = zoo::bert_large();
        let cluster = ClusterSpec::a40_cluster(4, 4);
        let base = build_space(&model, &cluster, &SweepConfig::default()).specs;
        let cfg = SweepConfig {
            recompute_axis: true,
            zero_axis: true,
            ..SweepConfig::default()
        };
        let grown = build_space(&model, &cluster, &cfg).specs;
        assert!(grown.len() > base.len());
        // axis-off points survive, in order, as the (none, 0) sub-sequence
        let defaults: Vec<&CandidateSpec> = grown
            .iter()
            .filter(|s| s.recompute == Recompute::None && s.zero_stage == 0)
            .collect();
        assert_eq!(defaults.len(), base.len());
        for (a, b) in defaults.iter().zip(&base) {
            assert_eq!(**a, *b);
        }
        // ZeRO variants only where there is a DP group to shard across
        for s in &grown {
            if s.zero_stage == 1 {
                assert!(s.strategy.dp > 1, "{s:?}");
            }
        }
        assert!(grown
            .iter()
            .any(|s| s.recompute == Recompute::Full && s.zero_stage == 1));
        // single-axis runs expand too, without the cross product
        let rc_only = build_space(
            &model,
            &cluster,
            &SweepConfig {
                recompute_axis: true,
                ..SweepConfig::default()
            },
        )
        .specs;
        assert!(rc_only.len() > base.len());
        assert!(rc_only.iter().all(|s| s.zero_stage == 0));
    }
}
