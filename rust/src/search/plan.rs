//! Compiled sweep plans (ISSUE 10): split **planning** from **execution**.
//!
//! Every sweep used to re-derive the candidate space, the canonical
//! placement tables, the per-candidate memory verdicts, the analytical
//! bounds and the interned event set from scratch — even when a request
//! differed from the previous one by a single delta (a cost-book edit, a
//! new capacity cap). Borrowing the Program/CostModel/Launcher split from
//! zosimos and DistIR's compile-once IR, a [`SweepPlan`] captures those
//! planning stages once and replays them:
//!
//! * [`SweepPlan::compile`] runs the candidate sources (via the
//!   device-class-memoized table pool, see [`TableMemo`]), the analytical
//!   bound stage, the memory stage and the per-candidate event interning,
//!   and **tags every component with the fingerprint of exactly the
//!   inputs it reads**:
//!   - the candidate list + table pool + event set depend on the request
//!     *shape* (model, capacity-stripped cluster — placement included —
//!     and the space-defining sweep axes);
//!   - the bound vector additionally carries the cost-book fingerprint
//!     (conservative: the bound layer prices at ideal peak rates, so a
//!     book edit re-runs only this cheap stage);
//!   - the memory verdicts additionally carry the per-kind capacity list
//!     and the `memory` flag;
//!   - the scenario salt marks the plan's evaluation context (it gates no
//!     planning component — scenarios perturb only the analytical
//!     re-walk — but a full *plan hit* is only declared when it matches).
//! * [`SweepPlan::launch`] compares the tags a new request produces
//!   against the plan's and rebuilds **only** the mismatched components:
//!   an identical request is a 100% hit (every component reused, zero
//!   candidate-space/bound/memory recomputation); a cost-book edit keeps
//!   the candidate list, memory verdicts and event set; a capacity edit
//!   re-runs only the memory stage; a topology edit recompiles.
//!
//! **Byte-identity.** A plan never enters a [`SweepReport`]: the engine
//! consumes the plan's components through the same staged pipeline
//! (`SearchEngine::with_plan`), and each component is — by the tag
//! discipline above — bit-identical to what the cold path would have
//! recomputed. Plan reuse therefore changes *cost*, never *bytes*;
//! `tests/plan_reuse.rs` pins serialized-response equality.
//!
//! [`SweepReport`]: super::SweepReport

use std::collections::HashMap;
use std::sync::{Arc, Mutex};

use crate::cluster::ClusterSpec;
use crate::cost::CostBook;
use crate::events::{Event, EventDb};
use crate::memory;
use crate::model::ModelSpec;
use crate::partition::partition_opts;

use super::cache::{fnv1a64, lock_recover, ProfileCache};
use super::engine::{SearchEngine, SweepConfig};
use super::pipeline::{self, CandidateSpace, PLACEMENT_EXHAUSTIVE_LIMIT};

/// Which of a plan's components a [`SweepPlan::launch`] (or
/// [`SweepPlan::reuse_against`]) could reuse for a request.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct PlanReuse {
    /// Candidate list + canonical table pool + seed bounds reused.
    pub space: bool,
    /// Per-candidate analytical bound vector reused.
    pub bounds: bool,
    /// Per-candidate memory verdicts reused.
    pub memory: bool,
    /// Interned per-candidate event set reused.
    pub events: bool,
    /// The scenario salt matched (no component hangs off it — scenarios
    /// only perturb evaluation — but a full hit requires it).
    pub scenario: bool,
}

impl PlanReuse {
    /// Every component reused and the scenario salt matched: the request
    /// is a 100% plan hit.
    pub fn full_hit(&self) -> bool {
        self.space && self.bounds && self.memory && self.events && self.scenario
    }

    /// At least one component reused (a delta request that kept some of
    /// the plan alive).
    pub fn any(&self) -> bool {
        self.space || self.bounds || self.memory || self.events
    }
}

/// The memory stage's per-candidate output, index-aligned with the
/// plan's candidate list. Empty (`active: false`) when the request keeps
/// per-rank accounting off ([`SearchEngine::memory_active`]).
#[derive(Debug, Clone, Default)]
pub struct MemoryVerdicts {
    pub active: bool,
    /// Worst rank's peak residency per candidate (0 for invalid specs,
    /// which the memory stage skips).
    pub peak_bytes: Vec<u64>,
    /// Whether every rank fits its SKU's declared capacity.
    pub fits: Vec<bool>,
}

/// The plan-wide interned event set: every distinct event descriptor any
/// valid candidate references, plus each candidate's id list in its
/// deterministic interning order. Replaces the per-sweep re-interning
/// the pruning-cost accounting used to pay.
#[derive(Debug, Clone, Default)]
pub struct PlanEvents {
    /// Distinct descriptors, in first-reference order.
    pub events: Vec<Event>,
    /// Canonical key string per event (index-aligned with `events`).
    pub keys: Vec<String>,
    /// Per-candidate indices into `events`/`keys`, in the candidate's own
    /// `EventDb` interning order (empty for invalid/non-deployable specs).
    pub per_candidate: Vec<Vec<u32>>,
}

/// The component tags one request produces (all FNV-1a of canonical
/// serializations).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
struct PlanTags {
    shape: u64,
    bounds: u64,
    memory: u64,
    scenario: u64,
}

impl PlanTags {
    fn of(model: &ModelSpec, cluster: &ClusterSpec, book: &CostBook, cfg: &SweepConfig) -> Self {
        let shape = SweepPlan::shape_fingerprint(model, cluster, cfg);
        let bounds = fnv1a64(format!("{shape:016x}|book={}", book.to_json()).as_bytes());
        let caps: Vec<Option<u64>> = cluster
            .kinds_in_use()
            .into_iter()
            .map(|k| cluster.capacity_of_kind(k))
            .collect();
        let memory =
            fnv1a64(format!("{shape:016x}|caps={caps:?}|mem={}", cfg.memory).as_bytes());
        let scenario = fnv1a64(format!("scn={}", cfg.scenario.to_json()).as_bytes());
        PlanTags {
            shape,
            bounds,
            memory,
            scenario,
        }
    }
}

/// A compiled sweep: the planning stages' outputs, each tagged with the
/// fingerprint of the inputs it was derived from (module docs).
#[derive(Debug, Clone)]
pub struct SweepPlan {
    shape: u64,
    bounds_tag: u64,
    memory_tag: u64,
    scenario_tag: u64,
    space: Arc<CandidateSpace>,
    bounds: Arc<Vec<f64>>,
    memory: Arc<MemoryVerdicts>,
    events: Arc<PlanEvents>,
}

impl SweepPlan {
    /// The request-shape fingerprint: everything the candidate space and
    /// event set are a function of — the model, the capacity-stripped
    /// cluster (topology, device kinds, placement), and the
    /// space-defining sweep axes. Capacity caps, cost books, scenarios
    /// and the profiling protocol are deliberately excluded: deltas in
    /// those must land on the *same* plan slot so `launch` can reuse the
    /// untouched components.
    pub fn shape_fingerprint(model: &ModelSpec, cluster: &ClusterSpec, cfg: &SweepConfig) -> u64 {
        let desc = format!(
            "distsim-plan-shape/v1|model={model:?}|cluster={}|gb={}|wid={}|mba={}|sa={}|pa={}|po={}|beam={}|ra={}|za={}|maxc={}",
            cluster.sans_capacity().to_json(),
            cfg.global_batch,
            cfg.widened,
            cfg.micro_batch_axis,
            cfg.schedule_axis,
            cfg.placement_axis,
            cfg.placement_opt,
            cfg.beam,
            cfg.recompute_axis,
            cfg.zero_axis,
            cfg.max_candidates,
        );
        fnv1a64(desc.as_bytes())
    }

    /// Compile a request into a plan (no memoized table pool).
    pub fn compile(
        model: &ModelSpec,
        cluster: &ClusterSpec,
        book: &CostBook,
        cfg: &SweepConfig,
    ) -> SweepPlan {
        Self::compile_memo(model, cluster, book, cfg, None)
    }

    /// Compile with a shared [`TableMemo`], so repeated requests against
    /// the same fleet skip the canonical-table enumeration entirely.
    pub fn compile_memo(
        model: &ModelSpec,
        cluster: &ClusterSpec,
        book: &CostBook,
        cfg: &SweepConfig,
        memo: Option<&TableMemo>,
    ) -> SweepPlan {
        let tags = PlanTags::of(model, cluster, book, cfg);
        let space = Arc::new(build_space_for(model, cluster, cfg, memo));
        let eng = scratch_engine(model, cluster, book, cfg);
        let bounds = Arc::new(compute_bounds(&eng, &space));
        let memory = Arc::new(compute_memory(&eng, &space));
        let events = Arc::new(compute_events(&eng, &space));
        SweepPlan {
            shape: tags.shape,
            bounds_tag: tags.bounds,
            memory_tag: tags.memory,
            scenario_tag: tags.scenario,
            space,
            bounds,
            memory,
            events,
        }
    }

    /// Which components a request could reuse, without rebuilding
    /// anything. A component whose inputs' fingerprint matches its tag is
    /// reusable; a shape mismatch invalidates every per-candidate
    /// component (they are indexed by the candidate list).
    pub fn reuse_against(
        &self,
        model: &ModelSpec,
        cluster: &ClusterSpec,
        book: &CostBook,
        cfg: &SweepConfig,
    ) -> PlanReuse {
        let tags = PlanTags::of(model, cluster, book, cfg);
        let space = tags.shape == self.shape;
        PlanReuse {
            space,
            bounds: space && tags.bounds == self.bounds_tag,
            memory: space && tags.memory == self.memory_tag,
            events: space, // the event set reads exactly the shape inputs
            scenario: tags.scenario == self.scenario_tag,
        }
    }

    /// Launch the plan against a (possibly delta-carrying) request:
    /// reuse every component whose tag still matches, rebuild only the
    /// rest, and return the refreshed plan (tagged for the new request)
    /// plus what was reused. An identical request returns a clone sharing
    /// every component (`PlanReuse::full_hit`).
    pub fn launch(
        &self,
        model: &ModelSpec,
        cluster: &ClusterSpec,
        book: &CostBook,
        cfg: &SweepConfig,
        memo: Option<&TableMemo>,
    ) -> (SweepPlan, PlanReuse) {
        let tags = PlanTags::of(model, cluster, book, cfg);
        let reuse = self.reuse_against(model, cluster, book, cfg);
        let space = if reuse.space {
            self.space.clone()
        } else {
            Arc::new(build_space_for(model, cluster, cfg, memo))
        };
        let eng = scratch_engine(model, cluster, book, cfg);
        let bounds = if reuse.bounds {
            self.bounds.clone()
        } else {
            Arc::new(compute_bounds(&eng, &space))
        };
        let memory = if reuse.memory {
            self.memory.clone()
        } else {
            Arc::new(compute_memory(&eng, &space))
        };
        let events = if reuse.events {
            self.events.clone()
        } else {
            Arc::new(compute_events(&eng, &space))
        };
        (
            SweepPlan {
                shape: tags.shape,
                bounds_tag: tags.bounds,
                memory_tag: tags.memory,
                scenario_tag: tags.scenario,
                space,
                bounds,
                memory,
                events,
            },
            reuse,
        )
    }

    /// The request-shape fingerprint this plan was compiled for.
    pub fn shape(&self) -> u64 {
        self.shape
    }

    /// The compiled candidate space (specs + table pool + seed bounds).
    pub fn space(&self) -> &CandidateSpace {
        &self.space
    }

    /// Shared handle on the candidate space (for pointer-identity
    /// assertions in tests).
    pub fn space_arc(&self) -> &Arc<CandidateSpace> {
        &self.space
    }

    pub fn candidate_count(&self) -> usize {
        self.space.specs.len()
    }

    /// Distinct events any valid candidate references.
    pub fn event_count(&self) -> usize {
        self.events.events.len()
    }

    /// The bound vector, if it is index-aligned with a space of `n`
    /// candidates (defensive: an engine handed a mismatched plan falls
    /// back to recomputing).
    pub(super) fn bounds_for(&self, n: usize) -> Option<&[f64]> {
        (self.bounds.len() == n).then(|| self.bounds.as_slice())
    }

    /// The memory verdicts, if active and index-aligned.
    pub(super) fn memory_for(&self, n: usize) -> Option<&MemoryVerdicts> {
        (self.memory.active && self.memory.peak_bytes.len() == n).then(|| &*self.memory)
    }

    /// The interned event set, if index-aligned.
    pub(super) fn events_for(&self, n: usize) -> Option<&PlanEvents> {
        (self.events.per_candidate.len() == n).then(|| &*self.events)
    }
}

/// Device-class-keyed memo of the canonical placement-table enumeration
/// (the satellite fix of ISSUE 10): [`pipeline::build_space`] used to
/// re-run [`pipeline::enumerate_canonical_tables`] — a symmetry-reduced
/// DFS plus one `canonicalize_table` per emitted table — for **every**
/// request against the same fleet. The enumeration is a pure function of
/// the cluster's `(node, kind)` class structure, so one memo entry per
/// class signature serves every request shape on that fleet. `None`
/// entries (space larger than [`PLACEMENT_EXHAUSTIVE_LIMIT`]) are
/// memoized too: the aborted DFS that discovers the overflow is itself
/// worth skipping.
#[derive(Debug, Default)]
pub struct TableMemo {
    map: Mutex<HashMap<String, Arc<Option<Vec<Vec<usize>>>>>>,
}

impl TableMemo {
    pub fn new() -> Self {
        Self::default()
    }

    /// The canonical table enumeration for this fleet, computed at most
    /// once per device-class signature.
    pub fn canonical_for(&self, cluster: &ClusterSpec) -> Arc<Option<Vec<Vec<usize>>>> {
        let sig = format!("{:?}", cluster.device_classes());
        let mut map = lock_recover(&self.map);
        map.entry(sig)
            .or_insert_with(|| {
                Arc::new(pipeline::enumerate_canonical_tables(
                    cluster,
                    PLACEMENT_EXHAUSTIVE_LIMIT,
                ))
            })
            .clone()
    }

    /// Distinct fleets memoized so far.
    pub fn len(&self) -> usize {
        lock_recover(&self.map).len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

/// Build the candidate space, routing the canonical-table enumeration
/// through the memo when one is supplied (homogeneous fleets and
/// optimizer-off sweeps never touch it).
fn build_space_for(
    model: &ModelSpec,
    cluster: &ClusterSpec,
    cfg: &SweepConfig,
    memo: Option<&TableMemo>,
) -> CandidateSpace {
    match memo {
        Some(m) if cfg.placement_opt && cluster.is_heterogeneous() => {
            let canonical = m.canonical_for(cluster);
            pipeline::build_space_seeded(model, cluster, cfg, Some(&canonical))
        }
        _ => pipeline::build_space(model, cluster, cfg),
    }
}

/// A throwaway engine used only for its candidate-scoped helpers
/// (`valid`/`cluster_for`/`bound_with`/`memory_active`); its cache is
/// never touched during compilation.
fn scratch_engine<'a>(
    model: &'a ModelSpec,
    cluster: &'a ClusterSpec,
    book: &CostBook,
    cfg: &SweepConfig,
) -> SearchEngine<'a> {
    SearchEngine::with_book(
        model,
        cluster,
        book.clone(),
        cfg.clone(),
        Arc::new(ProfileCache::new()),
    )
}

/// The bound stage, for every candidate (memory-independent: the sweep
/// consults the vector only for candidates the memory stage kept, so
/// capacity deltas never touch it). Identical numbers to the cold path:
/// the optimizer's seed bound where one exists, the placement-aware
/// analytical bound otherwise.
fn compute_bounds(eng: &SearchEngine<'_>, space: &CandidateSpace) -> Vec<f64> {
    space
        .specs
        .iter()
        .enumerate()
        .map(|(i, spec)| match space.seed_bounds[i] {
            Some(b) => b,
            None => eng.bound_with(spec, &space.tables),
        })
        .collect()
}

/// The memory stage: per-candidate `(peak_bytes, fits)` verdicts,
/// skipping invalid specs exactly as the sweep's own stage does.
fn compute_memory(eng: &SearchEngine<'_>, space: &CandidateSpace) -> MemoryVerdicts {
    if !eng.memory_active() {
        return MemoryVerdicts::default();
    }
    let n = space.specs.len();
    let mut out = MemoryVerdicts {
        active: true,
        peak_bytes: vec![0; n],
        fits: vec![true; n],
    };
    for (i, spec) in space.specs.iter().enumerate() {
        if !eng.valid(spec) {
            continue;
        }
        let cluster = eng.cluster_for(spec, &space.tables);
        let part = partition_opts(
            eng.model(),
            &spec.strategy,
            &cluster,
            spec.micro_batch_size,
            spec.recompute,
            spec.zero_stage,
        );
        let sched = spec.schedule.build(spec.strategy.pp, spec.micro_batches);
        let mem = memory::assess(&part, &sched, &cluster, spec.recompute, spec.zero_stage);
        out.peak_bytes[i] = mem.peak_bytes;
        out.fits[i] = mem.fits;
    }
    out
}

/// Intern every valid candidate's events into the plan-wide set —
/// deliberately *without* the SKU-capacity (`cluster.fits`) gate, because
/// the cold path's pruning-cost accounting interns events for any valid
/// pruned candidate, fitting or not. Per-candidate id lists keep each
/// candidate's own `EventDb` interning order, so replaying them visits
/// keys in exactly the order the cold path's re-interning would — the
/// accounting stays bit-identical.
fn compute_events(eng: &SearchEngine<'_>, space: &CandidateSpace) -> PlanEvents {
    let mut out = PlanEvents::default();
    let mut index: HashMap<String, u32> = HashMap::new();
    for spec in &space.specs {
        let mut ids: Vec<u32> = Vec::new();
        if eng.valid(spec) {
            let cluster = eng.cluster_for(spec, &space.tables);
            let part = partition_opts(
                eng.model(),
                &spec.strategy,
                &cluster,
                spec.micro_batch_size,
                spec.recompute,
                spec.zero_stage,
            );
            let sched = spec.schedule.build(spec.strategy.pp, spec.micro_batches);
            let mut db = EventDb::new();
            crate::engine::build_programs(&part, &sched, &cluster, &mut db);
            for id in db.ids() {
                let key = db.get(id).key();
                let plan_id = match index.get(&key) {
                    Some(&p) => p,
                    None => {
                        let p = out.events.len() as u32;
                        out.events.push(db.get(id).clone());
                        out.keys.push(key.clone());
                        index.insert(key, p);
                        p
                    }
                };
                ids.push(plan_id);
            }
        }
        out.per_candidate.push(ids);
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::zoo;

    fn mixed_cfg() -> SweepConfig {
        SweepConfig {
            global_batch: 8,
            placement_opt: true,
            prune: true,
            ..SweepConfig::default()
        }
    }

    #[test]
    fn identical_request_is_a_full_hit_sharing_every_component() {
        let model = zoo::bert_large();
        let cluster = ClusterSpec::mixed_a40_a10(2, 4);
        let book = CostBook::default();
        let cfg = mixed_cfg();
        let plan = SweepPlan::compile(&model, &cluster, &book, &cfg);
        let (again, reuse) = plan.launch(&model, &cluster, &book, &cfg, None);
        assert!(reuse.full_hit(), "{reuse:?}");
        assert!(Arc::ptr_eq(&plan.space, &again.space));
        assert!(Arc::ptr_eq(&plan.bounds, &again.bounds));
        assert!(Arc::ptr_eq(&plan.memory, &again.memory));
        assert!(Arc::ptr_eq(&plan.events, &again.events));
    }

    #[test]
    fn cost_book_delta_reprices_bounds_and_keeps_the_rest() {
        let model = zoo::bert_large();
        let cluster = ClusterSpec::mixed_a40_a10(2, 4);
        let cfg = mixed_cfg();
        let plan = SweepPlan::compile(&model, &cluster, &CostBook::default(), &cfg);
        let mut edited = CostBook::default();
        edited.base.eff_max *= 0.9;
        let (next, reuse) = plan.launch(&model, &cluster, &edited, &cfg, None);
        assert!(reuse.space && reuse.events && reuse.memory && !reuse.bounds);
        assert!(Arc::ptr_eq(&plan.space, &next.space));
        assert!(Arc::ptr_eq(&plan.events, &next.events));
        // the bound layer prices at ideal peak rates (book-independent),
        // so the conservatively recomputed vector is value-identical
        assert_eq!(*plan.bounds, *next.bounds);
        assert!(!Arc::ptr_eq(&plan.bounds, &next.bounds));
    }

    #[test]
    fn capacity_delta_reruns_only_the_memory_stage() {
        let model = zoo::bert_large();
        let cluster = ClusterSpec::mixed_a40_a10(2, 4);
        let book = CostBook::default();
        let cfg = mixed_cfg();
        let plan = SweepPlan::compile(&model, &cluster, &book, &cfg);
        assert!(!plan.memory.active, "no capacity, no memory flag");
        let capped = cluster.with_uniform_capacity(3_000_000_000);
        let (next, reuse) = plan.launch(&model, &capped, &book, &cfg, None);
        assert!(reuse.space && reuse.bounds && reuse.events && !reuse.memory);
        assert!(Arc::ptr_eq(&plan.space, &next.space));
        assert!(next.memory.active);
        assert_eq!(next.memory.peak_bytes.len(), next.candidate_count());
    }

    #[test]
    fn topology_delta_recompiles_everything() {
        let model = zoo::bert_large();
        let book = CostBook::default();
        let cfg = mixed_cfg();
        let plan = SweepPlan::compile(&model, &ClusterSpec::mixed_a40_a10(2, 4), &book, &cfg);
        let grown = ClusterSpec::mixed_a40_a10(4, 4);
        let reuse = plan.reuse_against(&model, &grown, &book, &cfg);
        assert!(!reuse.any(), "{reuse:?}");
    }

    #[test]
    fn scenario_delta_reuses_components_but_is_not_a_full_hit() {
        let model = zoo::bert_large();
        let cluster = ClusterSpec::mixed_a40_a10(2, 4);
        let book = CostBook::default();
        let cfg = mixed_cfg();
        let plan = SweepPlan::compile(&model, &cluster, &book, &cfg);
        let mut salted = cfg.clone();
        salted.scenario = crate::scenario::ScenarioSpec {
            stragglers: vec![crate::scenario::Straggler {
                device: 0,
                factor: 1.5,
            }],
            ..Default::default()
        };
        let reuse = plan.reuse_against(&model, &cluster, &book, &salted);
        assert!(reuse.space && reuse.bounds && reuse.memory && reuse.events);
        assert!(!reuse.scenario && !reuse.full_hit());
    }

    #[test]
    fn table_memo_computes_each_fleet_once() {
        let memo = TableMemo::new();
        let mixed = ClusterSpec::mixed_a40_a10(2, 4);
        let a = memo.canonical_for(&mixed);
        let b = memo.canonical_for(&mixed);
        assert!(Arc::ptr_eq(&a, &b));
        assert_eq!(a.as_ref().as_ref().map(Vec::len), Some(70));
        assert_eq!(memo.len(), 1);
        // a different fleet is a different entry
        let _ = memo.canonical_for(&ClusterSpec::a40_cluster(2, 2));
        assert_eq!(memo.len(), 2);
        // and a memoized compile produces the same space as a cold one
        let model = zoo::bert_large();
        let book = CostBook::default();
        let cfg = mixed_cfg();
        let cold = SweepPlan::compile(&model, &mixed, &book, &cfg);
        let warm = SweepPlan::compile_memo(&model, &mixed, &book, &cfg, Some(&memo));
        assert_eq!(cold.space.specs, warm.space.specs);
        assert_eq!(cold.space.tables, warm.space.tables);
        assert_eq!(*cold.bounds, *warm.bounds);
    }
}
