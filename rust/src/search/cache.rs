//! Shared, thread-safe profile cache for strategy sweeps.
//!
//! **Cache key.** An interned [`Event`] descriptor *is* the key. For
//! computation events the descriptor name encodes the model layer kind,
//! the tensor-MP shard shape (`.../mp{mp}/...`) and the micro-batch size
//! (`.../b{mbs}s{seq}`), and the descriptor additionally carries the
//! **device kind** (SKU name) the event runs on — an event profiled on an
//! A40 can never serve a lookup for an A100 (ISSUE 4); for communication
//! events the payload bytes, group size and intra/inter link class are
//! the identity (paper §4.1). Two
//! sweep candidates that shard a layer the same way therefore hash to the
//! same key and the second one reuses the first's measured cost instead of
//! re-running the profiling micro-program — the cross-candidate
//! generalization of the paper's §3.2 within-candidate dedup, and the
//! saving Table 3 accounts in GPU-seconds.
//!
//! **Determinism.** [`profile_single`] depends only on the descriptor and
//! the (jitter, iters, seed) protocol, never on arrival order, so a cache
//! hit returns bit-identical values to a fresh measurement. Each entry is
//! an `Arc<OnceLock<..>>`: when two workers race on the same un-profiled
//! event, exactly one runs the measurement and the other blocks on the
//! cell, which keeps the *unique-event* GPU-second accounting exact (no
//! double-billing) regardless of thread count.

use std::collections::{HashMap, HashSet};
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, Mutex, MutexGuard, OnceLock, PoisonError};

use crate::cluster::{ClusterSpec, Placement};
use crate::config::Json;
use crate::cost::CostBook;
use crate::events::{Event, EventDb};
use crate::profile::{profile_single, ProfileReport, ProfiledEvent};

/// Lock a mutex, recovering from poisoning (ISSUE 6).
///
/// Every mutex in the cache/service layer guards an **append-only**
/// structure (entry maps that only gain measured cells, counters that only
/// grow, queues whose elements are owned values): a panic that unwinds
/// while the guard is held can abandon the holder's *intent* but can never
/// leave the guarded data half-mutated in a way later readers would
/// misinterpret. Recovering the poisoned guard is therefore safe — and
/// necessary: the daemon catches sweep panics with `catch_unwind`, and a
/// single poisoned `.lock().unwrap()` would otherwise wedge every
/// subsequent request (the poisoned-lock daemon crash of ISSUE 6).
pub(crate) fn lock_recover<T: ?Sized>(m: &Mutex<T>) -> MutexGuard<'_, T> {
    m.lock().unwrap_or_else(PoisonError::into_inner)
}

/// Shared cache of profiled event costs.
///
/// Entries are keyed by event descriptor only, so a cache is only valid
/// for **one** profiling protocol (jitter, iters, seed). The first lookup
/// pins the protocol; later lookups under a different one panic rather
/// than silently returning measurements taken under other settings.
#[derive(Debug, Default)]
pub struct ProfileCache {
    entries: Mutex<HashMap<Event, Arc<OnceLock<ProfiledEvent>>>>,
    /// (jitter_sigma bits, iters, seed) of the first lookup.
    protocol: OnceLock<(u64, usize, u64)>,
    hits: AtomicUsize,
    misses: AtomicUsize,
}

/// Deterministic summary of cache activity.
///
/// `misses` equals the number of unique events measured (each `OnceLock`
/// initializes exactly once) and `hits = lookups - misses`; both are
/// independent of thread interleaving, as is `gpu_seconds` (summed in
/// sorted key order).
#[derive(Debug, Clone, Default, PartialEq)]
pub struct CacheStats {
    pub hits: usize,
    pub misses: usize,
    pub unique_events: usize,
    /// GPU-seconds burned measuring the unique events (each once).
    pub gpu_seconds: f64,
    /// Unique events that needed ring-law extrapolation.
    pub extrapolated: usize,
}

impl CacheStats {
    /// Lookups served overall (hits + misses).
    pub fn lookups(&self) -> usize {
        self.hits + self.misses
    }

    /// Fraction of lookups served from cache, in [0, 1].
    pub fn hit_rate(&self) -> f64 {
        if self.lookups() == 0 {
            0.0
        } else {
            self.hits as f64 / self.lookups() as f64
        }
    }
}

/// One event's traffic within a single sweep, in canonical-key form.
///
/// `gpu_seconds`/`extrapolated` are the deterministic cost of measuring the
/// event once under the sweep's protocol; `lookups` is how many of the
/// sweep's candidates touched it. All three depend only on the sweep's own
/// candidate set, never on what other sweeps share the cache.
#[derive(Debug, Clone, PartialEq)]
pub struct EventUse {
    /// Canonical descriptor identity ([`Event::key`]).
    pub key: String,
    /// GPU-seconds one measurement of this event costs.
    pub gpu_seconds: f64,
    /// Whether the measurement needed ring-law extrapolation.
    pub extrapolated: bool,
    /// Cache lookups this sweep issued for the event.
    pub lookups: usize,
}

/// Per-sweep record of profile-cache traffic.
///
/// Workers on any thread record into it; [`LookupLog::into_uses`] drains to
/// a key-sorted vector, so the result is bit-identical for any evaluation
/// order — the sweep-level analogue of the cache's sorted-key stats.
///
/// The per-lookup cost is one hash of the already-interned [`Event`] plus
/// a counter bump under a short lock; canonical-JSON key serialization is
/// deferred to the one-time drain, keeping the hot (warm-cache) sweep
/// path allocation-free.
#[derive(Debug, Default)]
pub struct LookupLog {
    entries: Mutex<HashMap<Event, (ProfiledEvent, usize)>>,
}

impl LookupLog {
    pub fn record(&self, event: &Event, p: &ProfiledEvent) {
        let mut map = lock_recover(&self.entries);
        if let Some(e) = map.get_mut(event) {
            e.1 += 1;
        } else {
            map.insert(event.clone(), (*p, 1));
        }
    }

    /// Drain into deterministic (key-sorted) order. `iters` is the
    /// sweep's profiling protocol (GPU-second scaling).
    pub fn into_uses(self, iters: usize) -> Vec<EventUse> {
        let mut v: Vec<EventUse> = self
            .entries
            .into_inner()
            .unwrap_or_else(PoisonError::into_inner)
            .into_iter()
            .map(|(ev, (p, lookups))| EventUse {
                key: ev.key(),
                gpu_seconds: p.gpu_seconds(iters),
                extrapolated: p.extrapolated,
                lookups,
            })
            .collect();
        v.sort_by(|a, b| a.key.cmp(&b.key));
        v
    }
}

/// Deterministic "as-if-serial" cache accounting: charge a sweep only for
/// events absent from `prior` (descriptors already measured — by a loaded
/// snapshot or by earlier requests in a service's admission order); every
/// other lookup is a hit. Unlike raw `OnceLock` winner-counting, this is a
/// pure function of `(uses, prior)`, so concurrent sweeps sharing one cache
/// still report bit-identical stats.
pub fn stats_against(uses: &[EventUse], prior: &HashSet<String>) -> CacheStats {
    let mut stats = CacheStats::default();
    let mut lookups = 0usize;
    for u in uses {
        lookups += u.lookups;
        if !prior.contains(&u.key) {
            stats.misses += 1;
            stats.unique_events += 1;
            stats.gpu_seconds += u.gpu_seconds;
            stats.extrapolated += usize::from(u.extrapolated);
        }
    }
    stats.hits = lookups - stats.misses;
    stats
}

/// On-disk snapshot format version (see docs/FORMATS.md §2). Version 2
/// added the device kind to computation-event descriptors and replaced the
/// flat cost model with the per-kind [`CostBook`]; version-1 files are
/// rejected with a versioned error rather than silently serving costs
/// whose SKU identity is unknown.
pub const SNAPSHOT_VERSION: usize = 2;

pub(crate) fn fnv1a64(bytes: &[u8]) -> u64 {
    let mut h = 0xcbf29ce484222325u64;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x100000001b3);
    }
    h
}

fn protocol_json(jitter_sigma: f64, iters: usize, seed: u64) -> Json {
    Json::obj(vec![
        ("jitter_sigma", Json::num(jitter_sigma)),
        ("iters", Json::num(iters as f64)),
        // seeds travel as strings: u64 values above 2^53 would not survive
        // the f64-backed JSON number type
        ("seed", Json::str(seed.to_string())),
    ])
}

fn protocol_from_json(j: &Json) -> anyhow::Result<(f64, usize, u64)> {
    let jitter = j
        .get("jitter_sigma")
        .and_then(Json::as_f64)
        .ok_or_else(|| anyhow::anyhow!("snapshot protocol missing jitter_sigma"))?;
    let iters = j
        .get("iters")
        .and_then(Json::as_usize)
        .ok_or_else(|| anyhow::anyhow!("snapshot protocol missing iters"))?;
    let seed = j
        .get("seed")
        .and_then(Json::as_str)
        .and_then(|s| s.parse().ok())
        .ok_or_else(|| anyhow::anyhow!("snapshot protocol missing seed"))?;
    Ok((jitter, iters, seed))
}

/// Identity of a profile cache: hash of the canonical JSON of (cluster,
/// cost book, profiling protocol). Two sweeps may share measurements iff
/// their fingerprints agree — the same condition under which
/// [`profile_single`] is guaranteed to return identical values.
///
/// The cluster enters *without its placement*: placement permutes which
/// rank runs on which device but never changes any event's measured cost
/// (device kinds travel in the event descriptors), so sweeps that differ
/// only in placement — in particular every point of a placement-axis
/// sweep — share one cache. Device kinds, the kind→device table and the
/// per-kind cost overrides all stay in the fingerprint: an A40-fleet
/// snapshot can never serve an A100 fleet.
pub fn fingerprint(
    cluster: &ClusterSpec,
    cost: &CostBook,
    jitter_sigma: f64,
    iters: usize,
    seed: u64,
) -> String {
    let desc = Json::obj(vec![
        ("cluster", cluster.with_placement(Placement::Linear).to_json()),
        ("cost", cost.to_json()),
        ("protocol", protocol_json(jitter_sigma, iters, seed)),
    ])
    .to_string();
    format!("{:016x}", fnv1a64(desc.as_bytes()))
}

/// A cache restored from a JSON snapshot, plus what the snapshot claimed.
#[derive(Debug)]
pub struct CacheSnapshot {
    /// Fingerprint recomputed from the stored cluster/cost/protocol.
    pub fingerprint: String,
    pub cluster: ClusterSpec,
    pub cost: CostBook,
    /// (jitter_sigma, iters, seed) the entries were measured under.
    pub protocol: (f64, usize, u64),
    pub cache: ProfileCache,
    /// Canonical keys of every restored entry — the "already measured"
    /// prior for as-if-serial accounting.
    pub keys: HashSet<String>,
}

impl ProfileCache {
    pub fn new() -> Self {
        Self::default()
    }

    /// Number of descriptors with a measured (or restored) value.
    pub fn measured_len(&self) -> usize {
        let map = lock_recover(&self.entries);
        map.values().filter(|c| c.get().is_some()).count()
    }

    /// Serialize every measured entry to a versioned JSON snapshot keyed by
    /// the (cluster, cost, protocol) fingerprint. Entries sort by canonical
    /// event key, so equal caches produce byte-identical snapshots.
    ///
    /// Panics if the cache was filled under a *different* protocol than the
    /// one passed — persisting measurements under the wrong identity would
    /// poison every future run that trusts the fingerprint.
    pub fn save_json(
        &self,
        cluster: &ClusterSpec,
        cost: &CostBook,
        jitter_sigma: f64,
        iters: usize,
        seed: u64,
    ) -> Json {
        if let Some(&pinned) = self.protocol.get() {
            assert_eq!(
                pinned,
                (jitter_sigma.to_bits(), iters, seed),
                "ProfileCache snapshot requested under a different profiling protocol"
            );
        }
        let map = lock_recover(&self.entries);
        let mut entries: Vec<(String, Json)> = map
            .iter()
            .filter_map(|(ev, cell)| {
                cell.get().map(|p| {
                    let j = Json::obj(vec![
                        ("event", ev.to_json()),
                        ("mean_us", Json::num(p.mean_us)),
                        ("devices", Json::num(p.devices as f64)),
                        ("extrapolated", Json::Bool(p.extrapolated)),
                    ]);
                    (ev.key(), j)
                })
            })
            .collect();
        entries.sort_by(|a, b| a.0.cmp(&b.0));
        Json::obj(vec![
            ("kind", Json::str("distsim-profile-cache")),
            ("version", Json::num(SNAPSHOT_VERSION as f64)),
            (
                "fingerprint",
                Json::str(fingerprint(cluster, cost, jitter_sigma, iters, seed)),
            ),
            ("cluster", cluster.to_json()),
            ("cost", cost.to_json()),
            ("protocol", protocol_json(jitter_sigma, iters, seed)),
            (
                "entries",
                Json::Arr(entries.into_iter().map(|(_, j)| j).collect()),
            ),
        ])
    }

    /// Restore a cache from a [`ProfileCache::save_json`] snapshot.
    ///
    /// The fingerprint is recomputed from the stored cluster/cost/protocol
    /// and must match the stored one (a mismatch means a corrupted or
    /// hand-edited file). Whether the snapshot applies to a *given* sweep
    /// is the caller's check: compare [`CacheSnapshot::fingerprint`] with
    /// [`fingerprint`] of the sweep's own parameters.
    pub fn load_json(j: &Json) -> anyhow::Result<CacheSnapshot> {
        anyhow::ensure!(
            j.get("kind").and_then(Json::as_str) == Some("distsim-profile-cache"),
            "not a profile-cache snapshot"
        );
        match j.get("version").and_then(Json::as_usize) {
            Some(SNAPSHOT_VERSION) => {}
            Some(v) if v < SNAPSHOT_VERSION => anyhow::bail!(
                "snapshot version {v} predates per-device-kind cache keys \
                 (expected {SNAPSHOT_VERSION}): its entries cannot be trusted across \
                 SKUs — delete the file or re-profile"
            ),
            Some(v) => anyhow::bail!(
                "unsupported snapshot version {v} (this build reads {SNAPSHOT_VERSION})"
            ),
            None => anyhow::bail!("snapshot missing version"),
        }
        let cluster = ClusterSpec::from_json(
            j.get("cluster")
                .ok_or_else(|| anyhow::anyhow!("snapshot missing cluster"))?,
        )?;
        let cost = CostBook::from_json(
            j.get("cost")
                .ok_or_else(|| anyhow::anyhow!("snapshot missing cost"))?,
        );
        let (jitter, iters, seed) = protocol_from_json(
            j.get("protocol")
                .ok_or_else(|| anyhow::anyhow!("snapshot missing protocol"))?,
        )?;
        let fp = fingerprint(&cluster, &cost, jitter, iters, seed);
        let stored = j
            .get("fingerprint")
            .and_then(Json::as_str)
            .ok_or_else(|| anyhow::anyhow!("snapshot missing fingerprint"))?;
        anyhow::ensure!(
            fp == stored,
            "snapshot fingerprint {stored} does not match its own contents ({fp})"
        );
        let cache = ProfileCache::new();
        cache
            .protocol
            .set((jitter.to_bits(), iters, seed))
            .expect("fresh cache");
        let mut keys = HashSet::new();
        {
            let mut map = lock_recover(&cache.entries);
            for e in j
                .get("entries")
                .and_then(Json::as_arr)
                .ok_or_else(|| anyhow::anyhow!("snapshot missing entries"))?
            {
                let ev = Event::from_json(
                    e.get("event")
                        .ok_or_else(|| anyhow::anyhow!("snapshot entry missing event"))?,
                )?;
                let p = ProfiledEvent {
                    mean_us: e
                        .get("mean_us")
                        .and_then(Json::as_f64)
                        .ok_or_else(|| anyhow::anyhow!("snapshot entry missing mean_us"))?,
                    devices: e.get("devices").and_then(Json::as_usize).unwrap_or(1),
                    extrapolated: e
                        .get("extrapolated")
                        .and_then(Json::as_bool)
                        .unwrap_or(false),
                };
                keys.insert(ev.key());
                let cell: Arc<OnceLock<ProfiledEvent>> = Arc::default();
                cell.set(p).expect("fresh cell");
                map.insert(ev, cell);
            }
        }
        Ok(CacheSnapshot {
            fingerprint: fp,
            cluster,
            cost,
            protocol: (jitter, iters, seed),
            cache,
            keys,
        })
    }

    /// Look up the cost of `db`'s event `id`, measuring it on a miss.
    ///
    /// Concurrent misses on the same event serialize on the entry's
    /// `OnceLock`; only the winner runs [`profile_single`].
    #[allow(clippy::too_many_arguments)]
    pub fn get_or_profile(
        &self,
        db: &EventDb,
        id: crate::events::EventId,
        cluster: &ClusterSpec,
        cost: &CostBook,
        jitter_sigma: f64,
        iters: usize,
        seed: u64,
    ) -> ProfiledEvent {
        let proto = (jitter_sigma.to_bits(), iters, seed);
        let pinned = *self.protocol.get_or_init(|| proto);
        assert_eq!(
            pinned, proto,
            "ProfileCache reused under a different profiling protocol \
             (jitter/iters/seed); use one cache per protocol"
        );
        let key = db.get(id).clone();
        let cell = {
            let mut map = lock_recover(&self.entries);
            map.entry(key).or_default().clone()
        };
        let mut measured = false;
        let out = *cell.get_or_init(|| {
            measured = true;
            profile_single(db, id, cluster, cost, jitter_sigma, iters, seed)
        });
        if measured {
            self.misses.fetch_add(1, Ordering::Relaxed);
        } else {
            self.hits.fetch_add(1, Ordering::Relaxed);
        }
        out
    }

    /// Fill in every unprofiled event of `db` through the cache, returning
    /// how many lookups this candidate resolved from cache vs fresh.
    #[allow(clippy::too_many_arguments)]
    pub fn profile_into(
        &self,
        db: &mut EventDb,
        cluster: &ClusterSpec,
        cost: &CostBook,
        jitter_sigma: f64,
        iters: usize,
        seed: u64,
    ) -> usize {
        self.profile_into_logged(db, cluster, cost, jitter_sigma, iters, seed, None)
    }

    /// [`ProfileCache::profile_into`], additionally recording each lookup
    /// into a per-sweep [`LookupLog`] for deterministic accounting.
    #[allow(clippy::too_many_arguments)]
    pub fn profile_into_logged(
        &self,
        db: &mut EventDb,
        cluster: &ClusterSpec,
        cost: &CostBook,
        jitter_sigma: f64,
        iters: usize,
        seed: u64,
        log: Option<&LookupLog>,
    ) -> usize {
        let ids = db.unprofiled();
        let n = ids.len();
        for id in ids {
            let p = self.get_or_profile(db, id, cluster, cost, jitter_sigma, iters, seed);
            db.set_elapsed(id, p.mean_us);
            if let Some(log) = log {
                log.record(db.get(id), &p);
            }
        }
        n
    }

    /// Snapshot of the cache's deterministic totals. `iters` must match
    /// the profiling protocol used to fill the cache (GPU-second scaling).
    pub fn stats(&self, iters: usize) -> CacheStats {
        let map = lock_recover(&self.entries);
        // sort by event name so the f64 sum is bit-stable across runs
        // (HashMap iteration order is not)
        let mut profiled: Vec<(String, ProfiledEvent)> = map
            .iter()
            .filter_map(|(ev, cell)| cell.get().map(|p| (ev.name(), *p)))
            .collect();
        profiled.sort_by(|a, b| a.0.cmp(&b.0));
        let mut stats = CacheStats {
            hits: self.hits.load(Ordering::Relaxed),
            misses: self.misses.load(Ordering::Relaxed),
            unique_events: profiled.len(),
            ..CacheStats::default()
        };
        for (_, p) in &profiled {
            stats.gpu_seconds += p.gpu_seconds(iters);
            stats.extrapolated += usize::from(p.extrapolated);
        }
        stats
    }

    /// The cache's totals in legacy [`ProfileReport`] form (what
    /// `SearchReport::profile` carries).
    pub fn report(&self, iters: usize) -> ProfileReport {
        let s = self.stats(iters);
        ProfileReport {
            gpu_seconds: s.gpu_seconds,
            events_profiled: s.unique_events,
            extrapolated: s.extrapolated,
            cache_hits: s.hits,
        }
    }

    /// Test-only fault injection: panic while *holding* the entries lock,
    /// genuinely poisoning it the way a panicking sweep caught by the
    /// daemon's `catch_unwind` would. Exists so the poisoned-lock recovery
    /// path (ISSUE 6) can be exercised end-to-end without depending on a
    /// data-dependent panic inside the evaluator.
    #[doc(hidden)]
    pub fn panic_holding_entries_lock(&self) -> ! {
        let _guard = lock_recover(&self.entries);
        panic!("injected panic while holding the profile-cache entries lock");
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cost::OpClass;
    use crate::events::CompEvent;

    fn comp(name: &str, flops: u64) -> Event {
        comp_on(name, flops, "A40")
    }

    fn comp_on(name: &str, flops: u64, kind: &str) -> Event {
        Event::Comp(CompEvent {
            name: name.into(),
            class: OpClass::Matmul,
            flops,
            bytes: flops / 64,
            kind: kind.into(),
        })
    }

    #[test]
    fn second_lookup_hits_and_matches_fresh_measurement() {
        let cluster = ClusterSpec::a40_cluster(4, 4);
        let cost = CostBook::default();
        let cache = ProfileCache::new();

        let mut db1 = EventDb::new();
        let a1 = db1.intern(comp("xfmr_fwd/h1024/mp2/b4s128", 1 << 30));
        let fresh = profile_single(&db1, a1, &cluster, &cost, 0.0, 2, 7);
        let first = cache.get_or_profile(&db1, a1, &cluster, &cost, 0.0, 2, 7);
        assert_eq!(first.mean_us, fresh.mean_us);

        // a different db interning the same descriptor must hit
        let mut db2 = EventDb::new();
        let a2 = db2.intern(comp("xfmr_fwd/h1024/mp2/b4s128", 1 << 30));
        let second = cache.get_or_profile(&db2, a2, &cluster, &cost, 0.0, 2, 7);
        assert_eq!(second.mean_us, first.mean_us);

        let s = cache.stats(2);
        assert_eq!((s.hits, s.misses, s.unique_events), (1, 1, 1));
        assert!(s.gpu_seconds > 0.0);
    }

    #[test]
    fn distinct_shard_shapes_do_not_collide() {
        let cluster = ClusterSpec::a40_cluster(4, 4);
        let cost = CostBook::default();
        let cache = ProfileCache::new();
        let mut db = EventDb::new();
        let a = db.intern(comp("xfmr_fwd/h1024/mp1/b4s128", 1 << 30));
        let b = db.intern(comp("xfmr_fwd/h1024/mp2/b4s128", 1 << 29));
        cache.get_or_profile(&db, a, &cluster, &cost, 0.0, 1, 7);
        cache.get_or_profile(&db, b, &cluster, &cost, 0.0, 1, 7);
        let s = cache.stats(1);
        assert_eq!((s.hits, s.misses, s.unique_events), (0, 2, 2));
    }

    #[test]
    fn profile_into_fills_db_and_counts_lookups() {
        let cluster = ClusterSpec::a40_cluster(4, 4);
        let cost = CostBook::default();
        let cache = ProfileCache::new();
        let mut db = EventDb::new();
        let a = db.intern(comp("a", 1 << 28));
        let b = db.intern(comp("b", 1 << 29));
        let n = cache.profile_into(&mut db, &cluster, &cost, 0.0, 1, 7);
        assert_eq!(n, 2);
        assert!(db.is_profiled(a) && db.is_profiled(b));
        assert_eq!(cache.profile_into(&mut db, &cluster, &cost, 0.0, 1, 7), 0);
    }

    #[test]
    #[should_panic(expected = "different profiling protocol")]
    fn protocol_mismatch_is_rejected() {
        let cluster = ClusterSpec::a40_cluster(4, 4);
        let cost = CostBook::default();
        let cache = ProfileCache::new();
        let mut db = EventDb::new();
        let a = db.intern(comp("a", 1 << 28));
        cache.get_or_profile(&db, a, &cluster, &cost, 0.0, 1, 7);
        cache.get_or_profile(&db, a, &cluster, &cost, 0.0, 2, 7); // different iters
    }

    #[test]
    fn snapshot_roundtrip_restores_bit_identical_measurements() {
        let cluster = ClusterSpec::a40_cluster(4, 4);
        let cost = CostBook::default();
        let cache = ProfileCache::new();
        let mut db = EventDb::new();
        let a = db.intern(comp("xfmr_fwd/h1024/mp2/b4s128", 1 << 30));
        let b = db.intern(Event::Comm(crate::events::CommEvent::AllReduce {
            bytes: 1 << 26,
            group: 16,
            link: crate::cluster::LinkClass::Inter,
        }));
        let pa = cache.get_or_profile(&db, a, &cluster, &cost, 0.02, 3, 7);
        let pb = cache.get_or_profile(&db, b, &cluster, &cost, 0.02, 3, 7);

        let text = cache.save_json(&cluster, &cost, 0.02, 3, 7).to_string();
        let snap = ProfileCache::load_json(&Json::parse(&text).unwrap()).unwrap();
        assert_eq!(snap.fingerprint, fingerprint(&cluster, &cost, 0.02, 3, 7));
        assert_eq!(snap.keys.len(), 2);
        assert!(snap.keys.contains(&db.get(a).key()));

        // restored lookups are hits and bit-identical to the originals
        let ra = snap.cache.get_or_profile(&db, a, &cluster, &cost, 0.02, 3, 7);
        let rb = snap.cache.get_or_profile(&db, b, &cluster, &cost, 0.02, 3, 7);
        assert_eq!(ra, pa);
        assert_eq!(rb, pb);
        let s = snap.cache.stats(3);
        assert_eq!((s.hits, s.misses), (2, 0), "restored entries must hit");

        // saving the restored cache reproduces the file byte-for-byte
        let again = snap.cache.save_json(&cluster, &cost, 0.02, 3, 7).to_string();
        assert_eq!(again, text);
    }

    #[test]
    fn fingerprint_separates_cluster_cost_and_protocol() {
        let c1 = ClusterSpec::a40_cluster(4, 4);
        let c2 = ClusterSpec::a10_cluster(4, 4);
        let cost = CostBook::default();
        let base = fingerprint(&c1, &cost, 0.0, 1, 7);
        assert_eq!(base, fingerprint(&c1, &cost, 0.0, 1, 7));
        assert_ne!(base, fingerprint(&c2, &cost, 0.0, 1, 7));
        assert_ne!(base, fingerprint(&c1, &cost, 0.01, 1, 7));
        assert_ne!(base, fingerprint(&c1, &cost, 0.0, 2, 7));
        assert_ne!(base, fingerprint(&c1, &cost, 0.0, 1, 8));
        let mut tweaked = cost.clone();
        tweaked.base.scale = 1.01;
        assert_ne!(base, fingerprint(&c1, &tweaked, 0.0, 1, 7));
    }

    #[test]
    fn load_rejects_tampered_snapshots() {
        let cluster = ClusterSpec::a40_cluster(4, 4);
        let cost = CostBook::default();
        let cache = ProfileCache::new();
        let mut db = EventDb::new();
        let a = db.intern(comp("a", 1 << 28));
        cache.get_or_profile(&db, a, &cluster, &cost, 0.0, 1, 7);
        let good = cache.save_json(&cluster, &cost, 0.0, 1, 7).to_string();

        // flip the iters inside the protocol: fingerprint no longer matches
        let bad = good.replace("\"iters\":1", "\"iters\":2");
        assert!(ProfileCache::load_json(&Json::parse(&bad).unwrap()).is_err());
        // and plain non-snapshot JSON is refused up front
        assert!(ProfileCache::load_json(&Json::parse("{}").unwrap()).is_err());
    }

    #[test]
    fn device_kinds_never_share_cache_entries() {
        // ISSUE 4 invariant: the same shapes on different SKUs are
        // distinct keys with distinct measured costs
        let cluster = ClusterSpec::mixed_a40_a10(4, 4);
        let cost = CostBook::default();
        let cache = ProfileCache::new();
        let mut db = EventDb::new();
        let a = db.intern(comp_on("xfmr_fwd/h1024/mp1/b4s128", 1 << 30, "A40"));
        let b = db.intern(comp_on("xfmr_fwd/h1024/mp1/b4s128", 1 << 30, "A10"));
        let pa = cache.get_or_profile(&db, a, &cluster, &cost, 0.0, 1, 7);
        let pb = cache.get_or_profile(&db, b, &cluster, &cost, 0.0, 1, 7);
        let s = cache.stats(1);
        assert_eq!((s.hits, s.misses, s.unique_events), (0, 2, 2));
        assert!(pb.mean_us > pa.mean_us, "A10 must measure slower than A40");
    }

    #[test]
    fn fingerprint_ignores_placement_but_not_kinds() {
        use crate::cluster::Placement;
        let cost = CostBook::default();
        let mixed = ClusterSpec::mixed_a40_a10(4, 4);
        let base = fingerprint(&mixed, &cost, 0.0, 1, 7);
        // placement permutes ranks, not costs: same cache identity
        for p in [Placement::FastFirst, Placement::Interleaved] {
            assert_eq!(base, fingerprint(&mixed.with_placement(p), &cost, 0.0, 1, 7));
        }
        // but the kind tables and per-kind cost overrides are identity
        assert_ne!(
            base,
            fingerprint(&ClusterSpec::a40_cluster(4, 4), &cost, 0.0, 1, 7)
        );
        let mut slow = crate::cost::CostModel::default();
        slow.scale = 1.5;
        let tweaked = CostBook::default().with_kind("A10", slow);
        assert_ne!(base, fingerprint(&mixed, &tweaked, 0.0, 1, 7));
    }

    #[test]
    fn load_rejects_pre_heterogeneity_snapshot_versions() {
        let cluster = ClusterSpec::a40_cluster(4, 4);
        let cost = CostBook::default();
        let cache = ProfileCache::new();
        let mut db = EventDb::new();
        let a = db.intern(comp("a", 1 << 28));
        cache.get_or_profile(&db, a, &cluster, &cost, 0.0, 1, 7);
        let good = cache.save_json(&cluster, &cost, 0.0, 1, 7).to_string();
        assert!(good.contains("\"version\":2"), "{good}");

        let stale = good.replace("\"version\":2", "\"version\":1");
        let err = ProfileCache::load_json(&Json::parse(&stale).unwrap()).unwrap_err();
        assert!(
            err.to_string().contains("version 1 predates"),
            "want versioned error, got: {err}"
        );
        let future = good.replace("\"version\":2", "\"version\":9");
        let err = ProfileCache::load_json(&Json::parse(&future).unwrap()).unwrap_err();
        assert!(err.to_string().contains("unsupported snapshot version 9"));
    }

    #[test]
    fn lookup_log_stats_are_prior_relative() {
        let cluster = ClusterSpec::a40_cluster(4, 4);
        let cost = CostBook::default();
        let cache = ProfileCache::new();
        let log = LookupLog::default();
        // two "candidates" sharing one event
        for _ in 0..2 {
            let mut db = EventDb::new();
            db.intern(comp("shared", 1 << 28));
            db.intern(comp("shared", 1 << 28)); // interning dedups
            cache.profile_into_logged(&mut db, &cluster, &cost, 0.0, 2, 7, Some(&log));
        }
        let uses = log.into_uses(2);
        assert_eq!(uses.len(), 1);
        assert_eq!(uses[0].lookups, 2);

        let empty = stats_against(&uses, &HashSet::new());
        assert_eq!((empty.hits, empty.misses, empty.unique_events), (1, 1, 1));
        assert!(empty.gpu_seconds > 0.0);

        let prior: HashSet<String> = uses.iter().map(|u| u.key.clone()).collect();
        let warm = stats_against(&uses, &prior);
        assert_eq!((warm.hits, warm.misses), (2, 0));
        assert_eq!(warm.gpu_seconds, 0.0);
        assert_eq!(warm.hit_rate(), 1.0);
    }

    #[test]
    fn poisoned_entries_lock_is_recovered_not_fatal() {
        // ISSUE 6: a panic unwinding through a held entries guard poisons
        // the mutex; every cache operation must keep working afterwards
        // (the map is append-only, so recovery is safe).
        let cluster = ClusterSpec::a40_cluster(4, 4);
        let cost = CostBook::default();
        let cache = Arc::new(ProfileCache::new());
        let mut db = EventDb::new();
        let a = db.intern(comp("pre-poison", 1 << 28));
        let before = cache.get_or_profile(&db, a, &cluster, &cost, 0.0, 1, 7);

        let poisoner = Arc::clone(&cache);
        let panicked = std::thread::spawn(move || poisoner.panic_holding_entries_lock())
            .join()
            .is_err();
        assert!(panicked, "injection must actually panic");
        assert!(cache.entries.is_poisoned(), "lock must be genuinely poisoned");

        // reads, writes and snapshots all survive the poisoned state
        assert_eq!(cache.measured_len(), 1);
        let again = cache.get_or_profile(&db, a, &cluster, &cost, 0.0, 1, 7);
        assert_eq!(again, before);
        let b = db.intern(comp("post-poison", 1 << 29));
        cache.get_or_profile(&db, b, &cluster, &cost, 0.0, 1, 7);
        assert_eq!(cache.measured_len(), 2);
        let s = cache.stats(1);
        assert_eq!(s.unique_events, 2);
        let snap = cache.save_json(&cluster, &cost, 0.0, 1, 7).to_string();
        assert!(snap.contains("post-poison"));
    }

    #[test]
    fn poisoned_lookup_log_still_drains() {
        let cluster = ClusterSpec::a40_cluster(4, 4);
        let cost = CostBook::default();
        let cache = ProfileCache::new();
        let log = Arc::new(LookupLog::default());
        let mut db = EventDb::new();
        db.intern(comp("logged", 1 << 28));
        cache.profile_into_logged(&mut db, &cluster, &cost, 0.0, 1, 7, Some(&log));

        let poisoner = Arc::clone(&log);
        let _ = std::thread::spawn(move || {
            let _guard = poisoner.entries.lock().unwrap();
            panic!("poison the log");
        })
        .join();
        assert!(log.entries.is_poisoned());

        let log = Arc::into_inner(log).expect("sole owner");
        let uses = log.into_uses(1);
        assert_eq!(uses.len(), 1);
        assert_eq!(uses[0].lookups, 1);
    }

    #[test]
    fn concurrent_lookups_measure_each_event_once() {
        let cluster = ClusterSpec::a40_cluster(4, 4);
        let cost = CostBook::default();
        let cache = ProfileCache::new();
        let mut db = EventDb::new();
        for i in 0..6 {
            db.intern(comp(&format!("e{i}"), 1 << (20 + i)));
        }
        let db = &db;
        let cache = &cache;
        let cluster = &cluster;
        let cost = &cost;
        std::thread::scope(|s| {
            for _ in 0..4 {
                s.spawn(move || {
                    for id in db.ids() {
                        cache.get_or_profile(db, id, cluster, cost, 0.0, 1, 7);
                    }
                });
            }
        });
        let stats = cache.stats(1);
        assert_eq!(stats.misses, 6, "each unique event measured exactly once");
        assert_eq!(stats.hits, 4 * 6 - 6);
    }
}
