//! Shared, thread-safe profile cache for strategy sweeps.
//!
//! **Cache key.** An interned [`Event`] descriptor *is* the key. For
//! computation events the descriptor name encodes the model layer kind,
//! the tensor-MP shard shape (`.../mp{mp}/...`) and the micro-batch size
//! (`.../b{mbs}s{seq}`); for communication events the payload bytes, group
//! size and intra/inter link class are the identity (paper §4.1). Two
//! sweep candidates that shard a layer the same way therefore hash to the
//! same key and the second one reuses the first's measured cost instead of
//! re-running the profiling micro-program — the cross-candidate
//! generalization of the paper's §3.2 within-candidate dedup, and the
//! saving Table 3 accounts in GPU-seconds.
//!
//! **Determinism.** [`profile_single`] depends only on the descriptor and
//! the (jitter, iters, seed) protocol, never on arrival order, so a cache
//! hit returns bit-identical values to a fresh measurement. Each entry is
//! an `Arc<OnceLock<..>>`: when two workers race on the same un-profiled
//! event, exactly one runs the measurement and the other blocks on the
//! cell, which keeps the *unique-event* GPU-second accounting exact (no
//! double-billing) regardless of thread count.

use std::collections::HashMap;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, Mutex, OnceLock};

use crate::cluster::ClusterSpec;
use crate::cost::CostModel;
use crate::events::{Event, EventDb};
use crate::profile::{profile_single, ProfileReport, ProfiledEvent};

/// Shared cache of profiled event costs.
///
/// Entries are keyed by event descriptor only, so a cache is only valid
/// for **one** profiling protocol (jitter, iters, seed). The first lookup
/// pins the protocol; later lookups under a different one panic rather
/// than silently returning measurements taken under other settings.
#[derive(Debug, Default)]
pub struct ProfileCache {
    entries: Mutex<HashMap<Event, Arc<OnceLock<ProfiledEvent>>>>,
    /// (jitter_sigma bits, iters, seed) of the first lookup.
    protocol: OnceLock<(u64, usize, u64)>,
    hits: AtomicUsize,
    misses: AtomicUsize,
}

/// Deterministic summary of cache activity.
///
/// `misses` equals the number of unique events measured (each `OnceLock`
/// initializes exactly once) and `hits = lookups - misses`; both are
/// independent of thread interleaving, as is `gpu_seconds` (summed in
/// sorted key order).
#[derive(Debug, Clone, Default, PartialEq)]
pub struct CacheStats {
    pub hits: usize,
    pub misses: usize,
    pub unique_events: usize,
    /// GPU-seconds burned measuring the unique events (each once).
    pub gpu_seconds: f64,
    /// Unique events that needed ring-law extrapolation.
    pub extrapolated: usize,
}

impl CacheStats {
    /// Lookups served overall (hits + misses).
    pub fn lookups(&self) -> usize {
        self.hits + self.misses
    }

    /// Fraction of lookups served from cache, in [0, 1].
    pub fn hit_rate(&self) -> f64 {
        if self.lookups() == 0 {
            0.0
        } else {
            self.hits as f64 / self.lookups() as f64
        }
    }
}

impl ProfileCache {
    pub fn new() -> Self {
        Self::default()
    }

    /// Look up the cost of `db`'s event `id`, measuring it on a miss.
    ///
    /// Concurrent misses on the same event serialize on the entry's
    /// `OnceLock`; only the winner runs [`profile_single`].
    #[allow(clippy::too_many_arguments)]
    pub fn get_or_profile(
        &self,
        db: &EventDb,
        id: crate::events::EventId,
        cluster: &ClusterSpec,
        cost: &CostModel,
        jitter_sigma: f64,
        iters: usize,
        seed: u64,
    ) -> ProfiledEvent {
        let proto = (jitter_sigma.to_bits(), iters, seed);
        let pinned = *self.protocol.get_or_init(|| proto);
        assert_eq!(
            pinned, proto,
            "ProfileCache reused under a different profiling protocol \
             (jitter/iters/seed); use one cache per protocol"
        );
        let key = db.get(id).clone();
        let cell = {
            let mut map = self.entries.lock().unwrap();
            map.entry(key).or_default().clone()
        };
        let mut measured = false;
        let out = *cell.get_or_init(|| {
            measured = true;
            profile_single(db, id, cluster, cost, jitter_sigma, iters, seed)
        });
        if measured {
            self.misses.fetch_add(1, Ordering::Relaxed);
        } else {
            self.hits.fetch_add(1, Ordering::Relaxed);
        }
        out
    }

    /// Fill in every unprofiled event of `db` through the cache, returning
    /// how many lookups this candidate resolved from cache vs fresh.
    #[allow(clippy::too_many_arguments)]
    pub fn profile_into(
        &self,
        db: &mut EventDb,
        cluster: &ClusterSpec,
        cost: &CostModel,
        jitter_sigma: f64,
        iters: usize,
        seed: u64,
    ) -> usize {
        let ids = db.unprofiled();
        let n = ids.len();
        for id in ids {
            let p = self.get_or_profile(db, id, cluster, cost, jitter_sigma, iters, seed);
            db.set_elapsed(id, p.mean_us);
        }
        n
    }

    /// Snapshot of the cache's deterministic totals. `iters` must match
    /// the profiling protocol used to fill the cache (GPU-second scaling).
    pub fn stats(&self, iters: usize) -> CacheStats {
        let map = self.entries.lock().unwrap();
        // sort by event name so the f64 sum is bit-stable across runs
        // (HashMap iteration order is not)
        let mut profiled: Vec<(String, ProfiledEvent)> = map
            .iter()
            .filter_map(|(ev, cell)| cell.get().map(|p| (ev.name(), *p)))
            .collect();
        profiled.sort_by(|a, b| a.0.cmp(&b.0));
        let mut stats = CacheStats {
            hits: self.hits.load(Ordering::Relaxed),
            misses: self.misses.load(Ordering::Relaxed),
            unique_events: profiled.len(),
            ..CacheStats::default()
        };
        for (_, p) in &profiled {
            stats.gpu_seconds += p.gpu_seconds(iters);
            stats.extrapolated += usize::from(p.extrapolated);
        }
        stats
    }

    /// The cache's totals in legacy [`ProfileReport`] form (what
    /// `SearchReport::profile` carries).
    pub fn report(&self, iters: usize) -> ProfileReport {
        let s = self.stats(iters);
        ProfileReport {
            gpu_seconds: s.gpu_seconds,
            events_profiled: s.unique_events,
            extrapolated: s.extrapolated,
            cache_hits: s.hits,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cost::OpClass;
    use crate::events::CompEvent;

    fn comp(name: &str, flops: u64) -> Event {
        Event::Comp(CompEvent {
            name: name.into(),
            class: OpClass::Matmul,
            flops,
            bytes: flops / 64,
        })
    }

    #[test]
    fn second_lookup_hits_and_matches_fresh_measurement() {
        let cluster = ClusterSpec::a40_cluster(4, 4);
        let cost = CostModel::default();
        let cache = ProfileCache::new();

        let mut db1 = EventDb::new();
        let a1 = db1.intern(comp("xfmr_fwd/h1024/mp2/b4s128", 1 << 30));
        let fresh = profile_single(&db1, a1, &cluster, &cost, 0.0, 2, 7);
        let first = cache.get_or_profile(&db1, a1, &cluster, &cost, 0.0, 2, 7);
        assert_eq!(first.mean_us, fresh.mean_us);

        // a different db interning the same descriptor must hit
        let mut db2 = EventDb::new();
        let a2 = db2.intern(comp("xfmr_fwd/h1024/mp2/b4s128", 1 << 30));
        let second = cache.get_or_profile(&db2, a2, &cluster, &cost, 0.0, 2, 7);
        assert_eq!(second.mean_us, first.mean_us);

        let s = cache.stats(2);
        assert_eq!((s.hits, s.misses, s.unique_events), (1, 1, 1));
        assert!(s.gpu_seconds > 0.0);
    }

    #[test]
    fn distinct_shard_shapes_do_not_collide() {
        let cluster = ClusterSpec::a40_cluster(4, 4);
        let cost = CostModel::default();
        let cache = ProfileCache::new();
        let mut db = EventDb::new();
        let a = db.intern(comp("xfmr_fwd/h1024/mp1/b4s128", 1 << 30));
        let b = db.intern(comp("xfmr_fwd/h1024/mp2/b4s128", 1 << 29));
        cache.get_or_profile(&db, a, &cluster, &cost, 0.0, 1, 7);
        cache.get_or_profile(&db, b, &cluster, &cost, 0.0, 1, 7);
        let s = cache.stats(1);
        assert_eq!((s.hits, s.misses, s.unique_events), (0, 2, 2));
    }

    #[test]
    fn profile_into_fills_db_and_counts_lookups() {
        let cluster = ClusterSpec::a40_cluster(4, 4);
        let cost = CostModel::default();
        let cache = ProfileCache::new();
        let mut db = EventDb::new();
        let a = db.intern(comp("a", 1 << 28));
        let b = db.intern(comp("b", 1 << 29));
        let n = cache.profile_into(&mut db, &cluster, &cost, 0.0, 1, 7);
        assert_eq!(n, 2);
        assert!(db.is_profiled(a) && db.is_profiled(b));
        assert_eq!(cache.profile_into(&mut db, &cluster, &cost, 0.0, 1, 7), 0);
    }

    #[test]
    #[should_panic(expected = "different profiling protocol")]
    fn protocol_mismatch_is_rejected() {
        let cluster = ClusterSpec::a40_cluster(4, 4);
        let cost = CostModel::default();
        let cache = ProfileCache::new();
        let mut db = EventDb::new();
        let a = db.intern(comp("a", 1 << 28));
        cache.get_or_profile(&db, a, &cluster, &cost, 0.0, 1, 7);
        cache.get_or_profile(&db, a, &cluster, &cost, 0.0, 2, 7); // different iters
    }

    #[test]
    fn concurrent_lookups_measure_each_event_once() {
        let cluster = ClusterSpec::a40_cluster(4, 4);
        let cost = CostModel::default();
        let cache = ProfileCache::new();
        let mut db = EventDb::new();
        for i in 0..6 {
            db.intern(comp(&format!("e{i}"), 1 << (20 + i)));
        }
        let db = &db;
        let cache = &cache;
        let cluster = &cluster;
        let cost = &cost;
        std::thread::scope(|s| {
            for _ in 0..4 {
                s.spawn(move || {
                    for id in db.ids() {
                        cache.get_or_profile(db, id, cluster, cost, 0.0, 1, 7);
                    }
                });
            }
        });
        let stats = cache.stats(1);
        assert_eq!(stats.misses, 6, "each unique event measured exactly once");
        assert_eq!(stats.hits, 4 * 6 - 6);
    }
}
