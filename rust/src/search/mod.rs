//! Auto parallel-strategy search (paper §6): sweep the hybrid strategy
//! space with DistSim as the throughput oracle, at a fixed global batch
//! size, and rank strategies by predicted iterations/second.
//!
//! This is the paper's use-case: evaluating candidate deployments of
//! BERT-exLarge on 16 GPUs *without* touching the full cluster — profiling
//! happens on the 2-node slice, simulation is milliseconds per candidate.
//! The subsystem is built for *sweeps*, not single lookups:
//!
//! * [`SearchEngine`] evaluates candidates in parallel over a
//!   deterministic work queue (`std::thread::scope`; results are
//!   bit-identical for any worker count).
//! * [`ProfileCache`] shares profiled event costs across candidates.
//!   **Cache key:** the interned event descriptor itself, which encodes
//!   (model layer kind, tensor-MP shard shape, micro-batch size) for
//!   computation events and (bytes, group, intra/inter link) for
//!   communication events — so any two candidates that shard a layer the
//!   same way pay for its profiling once per sweep. This is the
//!   cross-candidate generalization of the paper's §3.2 event dedup, and
//!   Table 3 reports the saving in GPU-seconds.
//! * The sweep runs as a **staged candidate pipeline** (`pipeline`):
//!   candidate *sources* (strategy grid × schedule × micro-batch ×
//!   placement generators, including the [`pipeline::PlacementOptimizer`]
//!   searching `Placement::Table` permutations) feed a *pruner* with
//!   adaptive, epoch-scheduled re-pruning, which feeds the evaluator/
//!   cache layer. **Pruning bound:** `baseline::analytical` prices
//!   compute at peak FLOPs with ideal communication and zero overheads —
//!   placement-aware, each stage group at its own slowest member's SKU —
//!   so its batch time is a lower bound on the simulated batch time and
//!   `1e6 / analytical_us` an upper bound on throughput, per candidate
//!   placement. A candidate whose bound (inflated by a safety margin) is
//!   below the incumbent — re-published at fixed candidate-index epochs
//!   as better candidates land, so the pruned set stays bit-identical
//!   for any thread count — can never be the argmax and is skipped.
//! * [`SweepConfig::widened`] / [`SweepConfig::micro_batch_axis`] grow the
//!   space beyond the paper's power-of-two grid: every (mp, pp, dp)
//!   factoring [`Strategy::enumerate`] allows, and a micro-batch-size axis
//!   for pipelined candidates.
//! * [`SweepPlan`] (`plan`) splits *planning* from *execution*: the
//!   candidate space, canonical table pool, analytical bounds, memory
//!   verdicts and interned event set compile once, each tagged with the
//!   fingerprint of the inputs it reads, and a delta request rebuilds only
//!   the tagged components it touches ([`SweepPlan::launch`]). Plans feed
//!   the engine through [`SearchEngine::with_plan`] and never change
//!   sweep bytes — only cost.
//!
//! The legacy free functions ([`grid_search`], [`evaluate_candidate`])
//! remain as thin wrappers over the engine so the fig12/table2/table3
//! experiment drivers keep the paper's exact protocol.

pub mod cache;
pub mod engine;
pub mod pipeline;
pub mod plan;

pub use cache::{
    fingerprint, stats_against, CacheSnapshot, CacheStats, EventUse, LookupLog, ProfileCache,
    SNAPSHOT_VERSION,
};
pub use engine::{
    CandidateSpec, PlacementAttribution, RobustnessReport, ScheduleAttribution, SearchEngine,
    SweepCandidate, SweepConfig, SweepReport,
};
pub use pipeline::{
    enumerate_canonical_tables, CancelToken, CandidateSpace, PlacementOptimizer, PruneStats,
    NO_TABLE, PLACEMENT_EXHAUSTIVE_LIMIT,
};
pub use plan::{MemoryVerdicts, PlanEvents, PlanReuse, SweepPlan, TableMemo};

use crate::cluster::ClusterSpec;
use crate::config::RunConfig;
use crate::cost::CostModel;
use crate::distsim::DistSim;
use crate::engine::GroundTruth;
use crate::events::EventDb;
use crate::model::ModelSpec;
use crate::partition::partition;
use crate::profile::{profile_events, ProfileReport};
use crate::schedule;
use crate::strategy::Strategy;

/// One evaluated candidate.
#[derive(Debug, Clone)]
pub struct Candidate {
    pub strategy: Strategy,
    /// Predicted throughput, iterations/second (0 if unreachable).
    pub throughput: f64,
    /// Whether the model shard fits device memory (Fig. 12 draws
    /// unreachable configs as 0).
    pub reachable: bool,
    /// Micro-batches per replica used for this candidate.
    pub micro_batches: usize,
}

/// Search report: all candidates plus profiling-cost accounting (Table 3).
#[derive(Debug, Clone)]
pub struct SearchReport {
    pub candidates: Vec<Candidate>,
    pub profile: ProfileReport,
    /// Wall-clock spent by the sweep (profiling + simulation), seconds.
    pub simulate_seconds: f64,
}

impl SearchReport {
    fn reachable(&self) -> impl Iterator<Item = &Candidate> {
        self.candidates.iter().filter(|c| c.reachable)
    }

    /// Highest-throughput reachable candidate; `None` when nothing is
    /// deployable.
    pub fn best(&self) -> Option<&Candidate> {
        self.reachable()
            .max_by(|a, b| a.throughput.total_cmp(&b.throughput))
    }

    /// Runner-up over distinct strategies; `None` on empty or singleton
    /// reachable sets.
    pub fn second_best(&self) -> Option<&Candidate> {
        let best = self.best()?.strategy;
        self.reachable()
            .filter(|c| c.strategy != best)
            .max_by(|a, b| a.throughput.total_cmp(&b.throughput))
    }

    /// Lowest-throughput reachable candidate with non-zero throughput.
    pub fn worst(&self) -> Option<&Candidate> {
        self.reachable()
            .filter(|c| c.throughput > 0.0)
            .min_by(|a, b| a.throughput.total_cmp(&b.throughput))
    }

    /// Best/worst speedup — the paper's 7.37x headline. `None` when fewer
    /// than one reachable candidate exists.
    pub fn speedup(&self) -> Option<f64> {
        Some(self.best()?.throughput / self.worst()?.throughput)
    }
}

/// Enumerate the paper's §6 grid: sizes in {1, 2, 4, .., devices} per
/// axis, DP derived as devices / MP / PP.
pub fn grid(devices: usize) -> Vec<Strategy> {
    let mut axis = Vec::new();
    let mut v = 1;
    while v <= devices {
        axis.push(v);
        v *= 2;
    }
    let mut out = Vec::new();
    for &mp in &axis {
        for &pp in &axis {
            if mp * pp <= devices && devices % (mp * pp) == 0 {
                out.push(Strategy::new(mp, pp, devices / (mp * pp)));
            }
        }
    }
    out
}

/// The widened space: every (mp, pp, dp) factoring of the device count,
/// power of two or not (model-level validity — heads divisibility, pipeline
/// depth — is applied per candidate at evaluation time, where
/// `Strategy::is_valid_for` allows). Superset of [`grid`].
pub fn widened_grid(devices: usize) -> Vec<Strategy> {
    Strategy::enumerate(devices)
}

/// Evaluate one candidate with DistSim. Returns (throughput it/s,
/// reachable, micro_batches).
#[allow(clippy::too_many_arguments)]
pub fn evaluate_candidate(
    model: &ModelSpec,
    strategy: &Strategy,
    cluster: &ClusterSpec,
    cost: &CostModel,
    global_batch: usize,
    jitter_sigma: f64,
    profile_iters: usize,
    report: &mut ProfileReport,
) -> Candidate {
    // validity: heads divisibility, pipeline depth, batch divisibility
    if !strategy.is_valid_for(model.heads, model.num_transformer_layers(), strategy.world_size())
        || global_batch % strategy.dp != 0
    {
        return Candidate {
            strategy: *strategy,
            throughput: 0.0,
            reachable: false,
            micro_batches: 0,
        };
    }
    let per_replica = global_batch / strategy.dp;
    // micro-batch granularity: one sequence per micro-batch when
    // pipelining (maximizes overlap at fixed global batch), the whole
    // replica batch otherwise
    let (mbs, micro_batches) = if strategy.pp > 1 {
        (1, per_replica)
    } else {
        (per_replica, 1)
    };

    let part = partition(model, strategy, cluster, mbs);
    // memory reachability
    if !cluster.fits(part.max_params_per_rank()) {
        return Candidate {
            strategy: *strategy,
            throughput: 0.0,
            reachable: false,
            micro_batches,
        };
    }
    let sched = schedule::dapple(strategy.pp, micro_batches);
    let mut db = EventDb::new();
    crate::engine::build_programs(&part, &sched, cluster, &mut db);
    let book = crate::cost::CostBook::uniform(cost.clone());
    let r = profile_events(&mut db, cluster, &book, jitter_sigma, profile_iters, 7777);
    report.gpu_seconds += r.gpu_seconds;
    report.events_profiled += r.events_profiled;
    report.extrapolated += r.extrapolated;

    let ds = DistSim::new(&part, &sched, cluster);
    let batch_us = ds.predict_batch_time_us(&mut db);
    Candidate {
        strategy: *strategy,
        throughput: 1e6 / batch_us,
        reachable: true,
        micro_batches,
    }
}

/// Full grid search (paper §6 protocol), now served by the parallel
/// cache-aware [`SearchEngine`]: power-of-two grid, no pruning, profiled
/// costs shared across candidates. Values are bit-identical to the
/// historical serial per-candidate path (the cache returns the same
/// measurement a fresh profile would).
pub fn grid_search(
    model: &ModelSpec,
    cluster: &ClusterSpec,
    cost: &CostModel,
    global_batch: usize,
    jitter_sigma: f64,
    profile_iters: usize,
) -> SearchReport {
    let cfg = SweepConfig {
        global_batch,
        jitter_sigma,
        profile_iters,
        ..SweepConfig::default()
    };
    SearchEngine::new(model, cluster, cost, cfg)
        .sweep()
        .to_search_report()
}

/// Measure a candidate on the "real cluster" (ground-truth engine) — used
/// to verify the search result (Table 2). Legacy [`Candidate`]s carry no
/// micro-batch size, so this re-derives the default (seed) micro-batching;
/// for widened-sweep candidates use [`measure_actual_sweep`], which runs
/// the exact configuration the sweep simulated.
pub fn measure_actual(
    model_name: &str,
    cand: &Candidate,
    cluster: &ClusterSpec,
    global_batch: usize,
    iters: usize,
) -> anyhow::Result<f64> {
    let per_replica = global_batch / cand.strategy.dp;
    let (mbs, micro_batches) = if cand.strategy.pp > 1 {
        (1, per_replica)
    } else {
        (per_replica, 1)
    };
    measure_config(model_name, cand.strategy, mbs, micro_batches, cluster, iters)
}

/// Ground-truth a [`SweepCandidate`] with its *own* micro-batching and
/// pipeline schedule — the point the sweep actually simulated, not the
/// default derivation.
pub fn measure_actual_sweep(
    model_name: &str,
    cand: &SweepCandidate,
    cluster: &ClusterSpec,
    iters: usize,
) -> anyhow::Result<f64> {
    anyhow::ensure!(
        cand.micro_batch_size >= 1,
        "candidate {} was never deployable",
        cand.strategy
    );
    measure_schedule_config(
        model_name,
        cand.strategy,
        cand.micro_batch_size,
        cand.micro_batches,
        cand.schedule,
        cluster,
        iters,
    )
}

fn measure_config(
    model_name: &str,
    strategy: Strategy,
    micro_batch_size: usize,
    micro_batches: usize,
    cluster: &ClusterSpec,
    iters: usize,
) -> anyhow::Result<f64> {
    measure_schedule_config(
        model_name,
        strategy,
        micro_batch_size,
        micro_batches,
        schedule::SchedKind::Dapple,
        cluster,
        iters,
    )
}

fn measure_schedule_config(
    model_name: &str,
    strategy: Strategy,
    micro_batch_size: usize,
    micro_batches: usize,
    sched: schedule::SchedKind,
    cluster: &ClusterSpec,
    iters: usize,
) -> anyhow::Result<f64> {
    let mut cfg = RunConfig::new(model_name, strategy, cluster.clone());
    cfg.micro_batch_size = micro_batch_size;
    cfg.micro_batches = micro_batches;
    cfg.schedule = sched.name().to_string();
    let gt = GroundTruth::prepare(&cfg)?;
    Ok(1e6 / gt.mean_batch_time_us(iters))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::zoo;

    #[test]
    fn grid_of_16_has_15_entries() {
        // paper §6: "overall, there are 15 different hybrid parallelism
        // settings"
        assert_eq!(grid(16).len(), 15);
    }

    #[test]
    fn grid_covers_all_devices() {
        for s in grid(16) {
            assert_eq!(s.world_size(), 16);
        }
    }

    #[test]
    fn widened_grid_is_superset_with_non_pow2_splits() {
        // 16 devices factor only into powers of two, so the spaces agree
        assert_eq!(widened_grid(16).len(), grid(16).len());
        // 12 devices have non-power-of-two splits the pow2 grid misses
        let wide = widened_grid(12);
        assert!(wide.iter().any(|s| s.mp == 3));
        assert!(wide.len() > grid(12).len());
        for s in &wide {
            assert_eq!(s.world_size(), 12);
        }
    }

    #[test]
    fn search_finds_a_pipeline_heavy_winner_for_bert_exlarge() {
        // Fig. 12: the winner uses pipeline parallelism (2D8P in the
        // paper); pure 16-way MP is the worst by far.
        let model = zoo::bert_ex_large();
        let cluster = ClusterSpec::a10_cluster(4, 4);
        let rep = grid_search(&model, &cluster, &CostModel::default(), 16, 0.0, 1);
        assert_eq!(rep.candidates.len(), 15);
        let best = rep.best().expect("reachable candidates exist");
        assert!(best.strategy.pp >= 2, "winner {} should pipeline", best.strategy);
        let worst = rep.worst().expect("reachable candidates exist");
        assert_eq!(worst.strategy.mp, 16, "worst should be 16-way MP, got {}", worst.strategy);
        let speedup = rep.speedup().expect("speedup defined");
        assert!(
            (3.0..15.0).contains(&speedup),
            "speedup {speedup} out of the paper's order of magnitude"
        );
    }

    #[test]
    fn unreachable_candidates_marked() {
        // GPT-145B cannot fit mp*pp=1 shards on 16 A10s
        let model = zoo::gpt_145b();
        let cluster = ClusterSpec::a10_cluster(4, 4);
        let rep = grid_search(&model, &cluster, &CostModel::default(), 16, 0.0, 1);
        assert!(rep.candidates.iter().any(|c| !c.reachable));
        let dp16 = rep
            .candidates
            .iter()
            .find(|c| c.strategy.dp == 16)
            .unwrap();
        assert!(!dp16.reachable);
        assert_eq!(dp16.throughput, 0.0);
    }

    #[test]
    fn report_accessors_return_none_on_degenerate_sets() {
        let empty = SearchReport {
            candidates: vec![],
            profile: ProfileReport::default(),
            simulate_seconds: 0.0,
        };
        assert!(empty.best().is_none());
        assert!(empty.second_best().is_none());
        assert!(empty.worst().is_none());
        assert!(empty.speedup().is_none());

        let singleton = SearchReport {
            candidates: vec![Candidate {
                strategy: Strategy::new(1, 1, 1),
                throughput: 2.0,
                reachable: true,
                micro_batches: 1,
            }],
            profile: ProfileReport::default(),
            simulate_seconds: 0.0,
        };
        assert!(singleton.best().is_some());
        assert!(singleton.second_best().is_none(), "no distinct runner-up");
        assert_eq!(singleton.speedup(), Some(1.0));
    }
}

#[cfg(test)]
mod proptests {
    use super::*;
    use crate::testutil;

    #[test]
    fn prop_enumerations_cover_exactly_the_device_count() {
        testutil::check("grid-covers-devices", 120, |rng| {
            let devices = 1 + rng.below(96) as usize;
            for s in grid(devices) {
                assert_eq!(s.world_size(), devices, "pow2 grid @ {devices}");
            }
            let wide = widened_grid(devices);
            assert!(!wide.is_empty());
            for s in &wide {
                assert_eq!(s.mp * s.pp * s.dp, devices, "widened grid @ {devices}");
            }
            // the widened space subsumes the paper grid
            for s in grid(devices) {
                assert!(wide.contains(&s), "{s} missing from widened({devices})");
            }
        });
    }

    #[test]
    fn prop_report_accessors_never_panic() {
        // random candidate sets, including empty / all-unreachable /
        // singleton: accessors must return Option, never panic, and with
        // >= 2 reachable distinct strategies best+second_best+worst are all
        // Some.
        testutil::check("report-accessors-total", 300, |rng| {
            let n = rng.below(6) as usize;
            let mut candidates = Vec::new();
            for i in 0..n {
                let reachable = rng.below(2) == 0;
                candidates.push(Candidate {
                    strategy: Strategy::new(1 + i, 1, 1),
                    throughput: if reachable { 0.1 + rng.f64() } else { 0.0 },
                    reachable,
                    micro_batches: 1,
                });
            }
            let rep = SearchReport {
                candidates,
                profile: ProfileReport::default(),
                simulate_seconds: 0.0,
            };
            let reachable = rep.candidates.iter().filter(|c| c.reachable).count();
            assert_eq!(rep.best().is_some(), reachable >= 1);
            assert_eq!(rep.worst().is_some(), reachable >= 1);
            assert_eq!(rep.second_best().is_some(), reachable >= 2);
            if reachable >= 2 {
                let s = rep.speedup().unwrap();
                assert!(s >= 1.0, "best/worst ratio {s} < 1");
            }
        });
    }
}
