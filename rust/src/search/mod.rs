//! Auto parallel-strategy search (paper §6): grid-search the hybrid
//! strategy space with DistSim as the throughput oracle, at a fixed global
//! batch size, and rank strategies by predicted iterations/second.
//!
//! This is the paper's use-case: evaluating 15 candidate deployments of
//! BERT-exLarge on 16 GPUs *without* touching the full cluster — profiling
//! happens on the 2-node slice, simulation is milliseconds per candidate.

use crate::cluster::ClusterSpec;
use crate::config::RunConfig;
use crate::cost::CostModel;
use crate::distsim::DistSim;
use crate::engine::GroundTruth;
use crate::events::EventDb;
use crate::model::ModelSpec;
use crate::partition::partition;
use crate::profile::{profile_events, ProfileReport};
use crate::schedule;
use crate::strategy::Strategy;

/// One evaluated candidate.
#[derive(Debug, Clone)]
pub struct Candidate {
    pub strategy: Strategy,
    /// Predicted throughput, iterations/second (0 if unreachable).
    pub throughput: f64,
    /// Whether the model shard fits device memory (Fig. 12 draws
    /// unreachable configs as 0).
    pub reachable: bool,
    /// Micro-batches per replica used for this candidate.
    pub micro_batches: usize,
}

/// Search report: all candidates plus profiling-cost accounting (Table 3).
#[derive(Debug, Clone)]
pub struct SearchReport {
    pub candidates: Vec<Candidate>,
    pub profile: ProfileReport,
    /// Wall-clock spent in simulation (not profiling), seconds.
    pub simulate_seconds: f64,
}

impl SearchReport {
    pub fn best(&self) -> &Candidate {
        self.candidates
            .iter()
            .filter(|c| c.reachable)
            .max_by(|a, b| a.throughput.partial_cmp(&b.throughput).unwrap())
            .expect("no reachable candidate")
    }

    pub fn second_best(&self) -> &Candidate {
        let best = self.best().strategy;
        self.candidates
            .iter()
            .filter(|c| c.reachable && c.strategy != best)
            .max_by(|a, b| a.throughput.partial_cmp(&b.throughput).unwrap())
            .expect("fewer than two reachable candidates")
    }

    pub fn worst(&self) -> &Candidate {
        self.candidates
            .iter()
            .filter(|c| c.reachable && c.throughput > 0.0)
            .min_by(|a, b| a.throughput.partial_cmp(&b.throughput).unwrap())
            .expect("no reachable candidate")
    }

    /// Best/worst speedup — the paper's 7.37x headline.
    pub fn speedup(&self) -> f64 {
        self.best().throughput / self.worst().throughput
    }
}

/// Enumerate the paper's §6 grid: sizes in {1, 2, 4, .., devices} per
/// axis, DP derived as devices / MP / PP.
pub fn grid(devices: usize) -> Vec<Strategy> {
    let mut axis = Vec::new();
    let mut v = 1;
    while v <= devices {
        axis.push(v);
        v *= 2;
    }
    let mut out = Vec::new();
    for &mp in &axis {
        for &pp in &axis {
            if mp * pp <= devices && devices % (mp * pp) == 0 {
                out.push(Strategy::new(mp, pp, devices / (mp * pp)));
            }
        }
    }
    out
}

/// Evaluate one candidate with DistSim. Returns (throughput it/s,
/// reachable, micro_batches).
pub fn evaluate_candidate(
    model: &ModelSpec,
    strategy: &Strategy,
    cluster: &ClusterSpec,
    cost: &CostModel,
    global_batch: usize,
    jitter_sigma: f64,
    profile_iters: usize,
    report: &mut ProfileReport,
) -> Candidate {
    // validity: heads divisibility, pipeline depth, batch divisibility
    if !strategy.is_valid_for(model.heads, model.num_transformer_layers(), strategy.world_size())
        || global_batch % strategy.dp != 0
    {
        return Candidate {
            strategy: *strategy,
            throughput: 0.0,
            reachable: false,
            micro_batches: 0,
        };
    }
    let per_replica = global_batch / strategy.dp;
    // micro-batch granularity: one sequence per micro-batch when
    // pipelining (maximizes overlap at fixed global batch), the whole
    // replica batch otherwise
    let (mbs, micro_batches) = if strategy.pp > 1 {
        (1, per_replica)
    } else {
        (per_replica, 1)
    };

    let part = partition(model, strategy, cluster, mbs);
    // memory reachability
    if !cluster.fits(part.max_params_per_rank()) {
        return Candidate {
            strategy: *strategy,
            throughput: 0.0,
            reachable: false,
            micro_batches,
        };
    }
    let sched = schedule::dapple(strategy.pp, micro_batches);
    let mut db = EventDb::new();
    crate::engine::build_programs(&part, &sched, cluster, &mut db);
    let r = profile_events(&mut db, cluster, cost, jitter_sigma, profile_iters, 7777);
    report.gpu_seconds += r.gpu_seconds;
    report.events_profiled += r.events_profiled;
    report.extrapolated += r.extrapolated;

    let ds = DistSim::new(&part, &sched, cluster);
    let batch_us = ds.predict_batch_time_us(&mut db);
    Candidate {
        strategy: *strategy,
        throughput: 1e6 / batch_us,
        reachable: true,
        micro_batches,
    }
}

/// Full grid search (paper §6 protocol).
pub fn grid_search(
    model: &ModelSpec,
    cluster: &ClusterSpec,
    cost: &CostModel,
    global_batch: usize,
    jitter_sigma: f64,
    profile_iters: usize,
) -> SearchReport {
    let mut profile = ProfileReport::default();
    let t0 = std::time::Instant::now();
    let candidates: Vec<Candidate> = grid(cluster.total_devices())
        .iter()
        .map(|s| {
            evaluate_candidate(
                model,
                s,
                cluster,
                cost,
                global_batch,
                jitter_sigma,
                profile_iters,
                &mut profile,
            )
        })
        .collect();
    let simulate_seconds = t0.elapsed().as_secs_f64();
    SearchReport {
        candidates,
        profile,
        simulate_seconds,
    }
}

/// Measure a candidate on the "real cluster" (ground-truth engine) — used
/// to verify the search result (Table 2).
pub fn measure_actual(
    model_name: &str,
    cand: &Candidate,
    cluster: &ClusterSpec,
    global_batch: usize,
    iters: usize,
) -> anyhow::Result<f64> {
    let per_replica = global_batch / cand.strategy.dp;
    let (mbs, micro_batches) = if cand.strategy.pp > 1 {
        (1, per_replica)
    } else {
        (per_replica, 1)
    };
    let mut cfg = RunConfig::new(model_name, cand.strategy, cluster.clone());
    cfg.micro_batch_size = mbs;
    cfg.micro_batches = micro_batches;
    let gt = GroundTruth::prepare(&cfg)?;
    Ok(1e6 / gt.mean_batch_time_us(iters))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::zoo;

    #[test]
    fn grid_of_16_has_15_entries() {
        // paper §6: "overall, there are 15 different hybrid parallelism
        // settings"
        assert_eq!(grid(16).len(), 15);
    }

    #[test]
    fn grid_covers_all_devices() {
        for s in grid(16) {
            assert_eq!(s.world_size(), 16);
        }
    }

    #[test]
    fn search_finds_a_pipeline_heavy_winner_for_bert_exlarge() {
        // Fig. 12: the winner uses pipeline parallelism (2D8P in the
        // paper); pure 16-way MP is the worst by far.
        let model = zoo::bert_ex_large();
        let cluster = ClusterSpec::a10_cluster(4, 4);
        let rep = grid_search(&model, &cluster, &CostModel::default(), 16, 0.0, 1);
        assert_eq!(rep.candidates.len(), 15);
        let best = rep.best();
        assert!(best.strategy.pp >= 2, "winner {} should pipeline", best.strategy);
        let worst = rep.worst();
        assert_eq!(worst.strategy.mp, 16, "worst should be 16-way MP, got {}", worst.strategy);
        let speedup = rep.speedup();
        assert!(
            (3.0..15.0).contains(&speedup),
            "speedup {speedup} out of the paper's order of magnitude"
        );
    }

    #[test]
    fn unreachable_candidates_marked() {
        // GPT-145B cannot fit mp*pp=1 shards on 16 A10s
        let model = zoo::gpt_145b();
        let cluster = ClusterSpec::a10_cluster(4, 4);
        let rep = grid_search(&model, &cluster, &CostModel::default(), 16, 0.0, 1);
        assert!(rep.candidates.iter().any(|c| !c.reachable));
        let dp16 = rep
            .candidates
            .iter()
            .find(|c| c.strategy.dp == 16)
            .unwrap();
        assert!(!dp16.reachable);
        assert_eq!(dp16.throughput, 0.0);
    }
}
