//! The parallel, cache-aware strategy-sweep engine.
//!
//! Replaces the one-candidate-at-a-time free-function search: a
//! [`SearchEngine`] owns a [`ProfileCache`](super::ProfileCache) shared by
//! every candidate, evaluates candidates on a deterministic work queue
//! across `std::thread::scope` workers, optionally widens the strategy
//! space beyond the paper's power-of-two grid, and can prune candidates
//! that an analytical lower bound proves worse than an incumbent. Since
//! ISSUE 5 the sweep runs as a **staged pipeline**
//! ([`super::pipeline`]): candidate sources (including the placement
//! optimizer's `Placement::Table` generator) → epoch-scheduled adaptive
//! pruner → this evaluator/cache layer.
//!
//! On heterogeneous clusters the sweep gains a **placement axis**
//! ([`SweepConfig::placement_axis`]): every point is additionally
//! evaluated under the deterministic [`PlacementPolicy::AXIS`] overrides
//! (baseline, fast-SKUs-first, interleaved). Placement permutes ranks
//! onto devices without changing any profiled cost, so all placements of
//! a sweep share one cache and the thread-count bit-identity contract is
//! untouched; [`SweepReport::placement_attribution`] splits the win into
//! placement vs strategy, mirroring the schedule axis.
//!
//! **Determinism contract.** The [`SweepReport`]'s `candidates`, `profile`
//! and `cache` fields are bit-identical for any worker count: candidates
//! are indexed up front and results land by index; every profiled cost
//! depends only on the event descriptor + profiling protocol; cache
//! totals are summed in sorted-key order. Only `timing` carries wall-clock
//! (inherently non-deterministic) data. The indexed columnar [`Timeline`]
//! and the engine's `ExecScratch` reuse (ISSUE 2) change only where bytes
//! live, never a float operation or an RNG draw, so this contract holds
//! unchanged — `tests/search_engine.rs` pins it.
//!
//! [`Timeline`]: crate::timeline::Timeline

use std::borrow::Cow;
use std::collections::HashSet;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, Mutex, OnceLock};
use std::time::Instant;

use crate::baseline::analytical::analytical_batch_time_us;
use crate::cluster::{ClusterSpec, PlacementPolicy};
use crate::cost::{CostBook, CostModel};
use crate::distsim::DistSim;
use crate::events::EventDb;
use crate::memory::{self, Recompute};
use crate::model::ModelSpec;
use crate::partition::partition_opts;
use crate::profile::{profile_events, ProfileReport};
use crate::scenario::ScenarioSpec;
use crate::schedule::SchedKind;
use crate::strategy::Strategy;
use crate::telemetry::RequestTrace;

use super::cache::{stats_against, CacheStats, EventUse, LookupLog, ProfileCache};
use super::pipeline::{self, CancelToken, CandidateSpace, EpochPlan, PruneStats, NO_TABLE};
use super::plan::SweepPlan;

/// Sweep parameters. `Default` mirrors the seed's protocol (power-of-two
/// grid, DistSim profiling seed 7777, cache on, no pruning).
#[derive(Debug, Clone, PartialEq)]
pub struct SweepConfig {
    /// Global batch size (sequences) shared by every candidate.
    pub global_batch: usize,
    /// Multiplicative jitter sigma used while profiling events.
    pub jitter_sigma: f64,
    /// Iterations averaged per profiled event (paper: 100).
    pub profile_iters: usize,
    /// Profiling RNG seed (independent of the ground truth's).
    pub profile_seed: u64,
    /// Worker threads; 0 = `std::thread::available_parallelism()`.
    pub threads: usize,
    /// Widen beyond powers of two: every (mp, pp, dp) factoring of the
    /// device count (non-trivial only when the device count itself has
    /// non-power-of-two divisors).
    pub widened: bool,
    /// Explore the micro-batch-size axis for pipelined candidates instead
    /// of fixing one sequence per micro-batch.
    pub micro_batch_axis: bool,
    /// Enumerate every pipeline schedule ([`SchedKind::ALL`]) for pipelined
    /// candidates instead of fixing the seed protocol's Dapple.
    pub schedule_axis: bool,
    /// Evaluate every sweep point under each placement of
    /// [`PlacementPolicy::AXIS`] (baseline, fast-SKUs-first, interleaved).
    /// A no-op on homogeneous clusters, where every placement prices
    /// identically.
    pub placement_axis: bool,
    /// Run the placement *optimizer*: per strategy, search
    /// `Placement::Table` permutations (canonicalized and
    /// symmetry-reduced; exhaustive on small fleets, bound-guided beam
    /// beyond) and add the resulting table candidates to the space. A
    /// no-op on homogeneous clusters. See `search::pipeline`.
    pub placement_opt: bool,
    /// Beam width of the placement optimizer (max tables emitted per
    /// strategy when the symmetry-reduced space is too large to
    /// enumerate). Also the beam kept per rank while building tables.
    pub beam: usize,
    /// Adaptive re-pruning epochs: evaluation proceeds bound-descending,
    /// and after each of these fixed candidate-count epochs the incumbent
    /// re-prunes the remainder. 1 (the default) reproduces the historical
    /// single up-front incumbent. Only meaningful with `prune`.
    pub prune_epochs: usize,
    /// Evaluate at most this many sweep points (0 = unlimited). Truncation
    /// happens on the deterministic spec order, so a budgeted sweep is a
    /// prefix of the unbudgeted one.
    pub max_candidates: usize,
    /// Skip candidates whose analytical throughput upper bound cannot beat
    /// the incumbent (see [`SearchEngine::sweep`] for the bound).
    pub prune: bool,
    /// Safety margin on the pruning bound: a candidate is pruned only if
    /// `bound * (1 + prune_margin) < incumbent`. Guards against the
    /// analytical model's residual error; 0.10 by default.
    pub prune_margin: f64,
    /// Share profiled event costs across candidates. Off reproduces the
    /// seed's re-profile-per-candidate behaviour (the serial baseline the
    /// fig12 bench compares against).
    pub use_cache: bool,
    /// Unhappy-path scenario every candidate is additionally scored under
    /// (`scenario` module). Empty (the default) keeps the sweep nominal:
    /// no extra walks run and the report carries no robustness block, so
    /// nominal reports stay bit-identical to pre-scenario builds. Scenario
    /// scoring perturbs only the analytical re-walk, never a profiled
    /// cost, so scenario sweeps share the nominal cache fingerprint.
    pub scenario: ScenarioSpec,
    /// Enumerate the activation-recomputation axis: every point is
    /// additionally evaluated under `recompute: full` (re-run each
    /// layer's forward inside the backward, keeping only stage-boundary
    /// activations resident). Trades recomputed FLOPs for activation
    /// memory; the baseline `none` point always comes first, so axis-off
    /// sweeps are order-preserved prefixes.
    pub recompute_axis: bool,
    /// Enumerate the ZeRO optimizer-state sharding axis: every dp>1
    /// point is additionally evaluated under `zero_stage: 1` (Adam
    /// moments divided across the DP group, paid for with a gather
    /// folded into the DP collective). dp=1 points are not duplicated —
    /// stage 1 degenerates to stage 0 there.
    pub zero_axis: bool,
    /// Force per-rank memory accounting on (peak bytes priced for every
    /// candidate) even when no device declares a `capacity_bytes`.
    /// Accounting switches on implicitly whenever a capacity or a memory
    /// axis is present; off (the default) keeps every report
    /// byte-identical to pre-memory builds.
    pub memory: bool,
    /// Request-level flag (`sweep.trace: true`): ask the service to attach
    /// the opt-in request-lifecycle `trace` block to the response. The
    /// engine itself ignores it — stage spans are recorded through the
    /// [`RequestTrace`](crate::telemetry::RequestTrace) installed with
    /// [`SearchEngine::with_trace`], never through this flag — so sweep
    /// results are identical either way (DESIGN.md §9).
    pub trace: bool,
}

impl Default for SweepConfig {
    fn default() -> Self {
        SweepConfig {
            global_batch: 16,
            jitter_sigma: 0.0,
            profile_iters: 1,
            profile_seed: 7777,
            threads: 0,
            widened: false,
            micro_batch_axis: false,
            schedule_axis: false,
            placement_axis: false,
            placement_opt: false,
            beam: 4,
            prune_epochs: 1,
            max_candidates: 0,
            prune: false,
            prune_margin: 0.10,
            use_cache: true,
            scenario: ScenarioSpec::default(),
            recompute_axis: false,
            zero_axis: false,
            memory: false,
            trace: false,
        }
    }
}

/// One point of the sweep space: a strategy plus its micro-batching and
/// pipeline schedule.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct CandidateSpec {
    pub strategy: Strategy,
    /// Sequences per micro-batch (0 when dp does not divide the batch —
    /// evaluated as unreachable).
    pub micro_batch_size: usize,
    /// Micro-batches per replica per iteration.
    pub micro_batches: usize,
    /// Pipeline schedule this point runs (the seed protocol fixes Dapple).
    pub schedule: SchedKind,
    /// Rank→device placement this point deploys under (the cluster's own
    /// placement unless the placement axis enumerates overrides).
    pub placement: PlacementPolicy,
    /// Index into the sweep's table pool ([`SweepReport::tables`]) when
    /// `placement` is [`PlacementPolicy::Optimized`];
    /// [`pipeline::NO_TABLE`] otherwise.
    pub table: u32,
    /// Activation-recomputation policy this point trains under
    /// ([`Recompute::None`] outside the recompute axis).
    pub recompute: Recompute,
    /// ZeRO optimizer-state sharding stage, 0 or 1 (0 outside the zero
    /// axis).
    pub zero_stage: u8,
}

impl CandidateSpec {
    /// The seed protocol's micro-batching for a strategy: one sequence per
    /// micro-batch when pipelining, the whole replica batch otherwise,
    /// always on the Dapple schedule.
    pub fn default_for(strategy: Strategy, global_batch: usize) -> CandidateSpec {
        if global_batch % strategy.dp != 0 {
            return CandidateSpec {
                strategy,
                micro_batch_size: 0,
                micro_batches: 0,
                schedule: SchedKind::Dapple,
                placement: PlacementPolicy::Cluster,
                table: NO_TABLE,
                recompute: Recompute::None,
                zero_stage: 0,
            };
        }
        let per_replica = global_batch / strategy.dp;
        let (mbs, m) = if strategy.pp > 1 {
            (1, per_replica)
        } else {
            (per_replica, 1)
        };
        CandidateSpec {
            strategy,
            micro_batch_size: mbs,
            micro_batches: m,
            schedule: SchedKind::Dapple,
            placement: PlacementPolicy::Cluster,
            table: NO_TABLE,
            recompute: Recompute::None,
            zero_stage: 0,
        }
    }
}

/// One evaluated (or pruned) sweep point. Deterministic: no wall-clock.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct SweepCandidate {
    pub strategy: Strategy,
    pub micro_batch_size: usize,
    pub micro_batches: usize,
    /// Pipeline schedule the point was simulated under.
    pub schedule: SchedKind,
    /// Placement the point was simulated under.
    pub placement: PlacementPolicy,
    /// Index into [`SweepReport::tables`] for optimizer candidates
    /// ([`pipeline::NO_TABLE`] otherwise).
    pub table: u32,
    /// Activation-recomputation policy the point was simulated under.
    pub recompute: Recompute,
    /// ZeRO optimizer-state sharding stage the point was simulated under.
    pub zero_stage: u8,
    /// DistSim-predicted throughput, it/s (0 if unreachable or pruned).
    pub throughput: f64,
    /// Throughput under [`SweepConfig::scenario`], it/s. 0 when the sweep
    /// is nominal (empty scenario), the candidate was not evaluated, or
    /// the scenario's elastic resize leaves it undeployable
    /// (`dp + dp_delta < 1`).
    pub scenario_throughput: f64,
    /// Deployable: valid strategy and the shard fits device memory.
    pub reachable: bool,
    /// Skipped by the analytical-bound pruning pass (never simulated).
    pub pruned: bool,
    /// Analytical throughput upper bound, it/s (0 when not computed or
    /// not deployable).
    pub bound_throughput: f64,
    /// Worst-rank peak training-state residency, bytes (0 when memory
    /// accounting is off — see [`SearchEngine::memory_active`]).
    pub peak_bytes: u64,
    /// Every capacity-declaring rank holds this candidate's residency.
    /// `true` when accounting is off or no capacity is declared; `false`
    /// marks the memory stage's `oom` placeholders.
    pub fits: bool,
}

impl SweepCandidate {
    /// Did this candidate produce a usable throughput number? Memory-
    /// infeasible candidates never do — a fully-OOM space therefore ranks
    /// nothing and [`SweepReport::best`] returns `None`.
    pub fn evaluated(&self) -> bool {
        self.reachable && !self.pruned && self.fits && self.throughput > 0.0
    }

    /// Legacy [`super::Candidate`] view (pruned counts as not reachable,
    /// since no throughput was produced).
    pub fn to_candidate(&self) -> super::Candidate {
        super::Candidate {
            strategy: self.strategy,
            throughput: self.throughput,
            reachable: self.reachable && !self.pruned,
            micro_batches: self.micro_batches,
        }
    }
}

/// Wall-clock accounting — the only non-deterministic part of a report.
#[derive(Debug, Clone, Default)]
pub struct SweepTiming {
    /// Whole sweep (space construction + pruning + evaluation), seconds.
    pub total_seconds: f64,
    /// Per-candidate evaluation time, ms, index-aligned with
    /// `SweepReport::candidates` (0 for pruned candidates).
    pub per_candidate_ms: Vec<f64>,
}

/// Everything a sweep produced.
#[derive(Debug, Clone)]
pub struct SweepReport {
    pub candidates: Vec<SweepCandidate>,
    /// Aggregate profiling cost. With the cache on this counts every
    /// unique event once — the Table-3 dedup; without it, the sum over
    /// candidates.
    pub profile: ProfileReport,
    /// Cache accounting relative to the engine's prior (empty prior for a
    /// fresh cache: every unique event is this sweep's own miss).
    pub cache: CacheStats,
    /// This sweep's cache traffic in canonical key order — the raw
    /// material a what-if service re-accounts against *its* admission
    /// order (see `service`). Empty when the cache is off.
    pub event_uses: Vec<EventUse>,
    /// The placement optimizer's table pool; `SweepCandidate::table`
    /// indexes it. Empty unless [`SweepConfig::placement_opt`] ran.
    pub tables: Vec<Vec<usize>>,
    /// Pruning-layer accounting (the CLI's pruning block, the service's
    /// `pruning` response object).
    pub pruning: PruneStats,
    /// Robustness attribution of a scenario sweep; `None` on nominal
    /// sweeps (empty [`SweepConfig::scenario`]) or when nothing was
    /// evaluated.
    pub robustness: Option<RobustnessReport>,
    pub timing: SweepTiming,
    pub threads_used: usize,
}

/// Where a scenario sweep's robustness story lands: who wins nominally,
/// who wins under the scenario, what sticking with the nominal winner
/// would cost, and which degradation mechanism the slowdown comes from.
/// Deterministic (pure analytical re-walks), so it is covered by the
/// report's bit-identity contract.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct RobustnessReport {
    /// Index into `SweepReport::candidates` of the nominal winner.
    pub nominal_best: usize,
    /// Index of the best candidate under the scenario ("robust winner").
    pub scenario_best: usize,
    /// Robustness regret: `1 - scenario_tp(nominal) / scenario_tp(best)`
    /// — the throughput fraction lost by deploying the nominal winner
    /// into the unhappy path. 0 when the same candidate wins both; 1
    /// when the nominal winner is undeployable under the scenario.
    pub regret: f64,
    /// Nominal / scenario throughput of the robust winner (>= 1): how
    /// much the full scenario (degradation + restart + resize) costs it.
    pub scenario_slowdown: f64,
    /// Batch-time stretch of the robust winner with only the stragglers
    /// applied (1 when the spec has none).
    pub straggler_slowdown: f64,
    /// Batch-time stretch of the robust winner with only the link
    /// episodes applied (1 when the spec has none).
    pub link_slowdown: f64,
    /// Lost-work + restart cost charged to the batch, microseconds.
    pub restart_penalty_us: f64,
    /// Re-shard cost of the elastic resize (0 without one).
    pub reshard_us: f64,
    /// Episodes in the spec (straggler + link episodes + failures).
    pub episodes: usize,
}

/// Where a sweep's win came from (requires [`SweepConfig::schedule_axis`]
/// to be informative): the schedule axis's contribution on top of the best
/// default-schedule candidate, vs the spread the strategy axis alone
/// explains.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct ScheduleAttribution {
    /// Schedule of the overall winner.
    pub winning_schedule: SchedKind,
    /// Best overall / best Dapple candidate: >1 exactly when switching
    /// schedule beats every default-schedule deployment.
    pub schedule_speedup: f64,
    /// Best Dapple / worst Dapple: the spread strategy choice alone
    /// explains under the fixed default schedule.
    pub strategy_speedup: f64,
}

/// Where a placement-axis sweep's win came from (requires
/// [`SweepConfig::placement_axis`] to be informative): the placement
/// override's contribution on top of the best baseline-placement
/// candidate, vs the spread the strategy axis alone explains under the
/// baseline placement.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct PlacementAttribution {
    /// Placement of the overall winner.
    pub winning_placement: PlacementPolicy,
    /// Best overall / best baseline-placement candidate: >1 exactly when
    /// re-placing ranks beats every baseline deployment.
    pub placement_speedup: f64,
    /// Best baseline / worst baseline: the spread strategy choice alone
    /// explains under the cluster's own placement.
    pub strategy_speedup: f64,
}

/// First maximal-throughput candidate. Unlike `max_by` (which keeps the
/// *last* of equal maxima), ties resolve toward the earlier sweep point —
/// so a schedule-axis point that merely equals the default-schedule
/// candidate (degenerate micro-batchings produce bit-identical
/// simulations) never steals the win from it.
fn first_max<'r>(
    iter: impl Iterator<Item = &'r SweepCandidate>,
) -> Option<&'r SweepCandidate> {
    iter.fold(None, |best, c| match best {
        Some(b) if b.throughput.total_cmp(&c.throughput).is_ge() => Some(b),
        _ => Some(c),
    })
}

impl SweepReport {
    fn ranked(&self) -> impl Iterator<Item = &SweepCandidate> {
        self.candidates.iter().filter(|c| c.evaluated())
    }

    /// Highest-throughput evaluated candidate, if any (ties break toward
    /// the earlier sweep point).
    pub fn best(&self) -> Option<&SweepCandidate> {
        first_max(self.ranked())
    }

    /// Runner-up over distinct strategies, if at least two were evaluated.
    pub fn second_best(&self) -> Option<&SweepCandidate> {
        let best = self.best()?.strategy;
        first_max(self.ranked().filter(|c| c.strategy != best))
    }

    /// Lowest-throughput evaluated candidate, if any.
    pub fn worst(&self) -> Option<&SweepCandidate> {
        self.ranked()
            .min_by(|a, b| a.throughput.total_cmp(&b.throughput))
    }

    /// Best/worst ratio — the paper's 7.37x headline shape.
    pub fn speedup(&self) -> Option<f64> {
        Some(self.best()?.throughput / self.worst()?.throughput)
    }

    /// Highest-throughput evaluated candidate on one schedule, if any.
    pub fn best_for_schedule(&self, k: SchedKind) -> Option<&SweepCandidate> {
        first_max(self.ranked().filter(|c| c.schedule == k))
    }

    /// Attribute the sweep's win to the schedule axis vs the strategy
    /// axis. `None` when no Dapple candidate was evaluated (every sweep
    /// space includes the default schedule, so this only happens on empty
    /// or fully-unreachable spaces).
    pub fn schedule_attribution(&self) -> Option<ScheduleAttribution> {
        let best = self.best()?;
        let dapple_best = self.best_for_schedule(SchedKind::Dapple)?;
        let dapple_worst = self
            .ranked()
            .filter(|c| c.schedule == SchedKind::Dapple)
            .min_by(|a, b| a.throughput.total_cmp(&b.throughput))?;
        Some(ScheduleAttribution {
            winning_schedule: best.schedule,
            schedule_speedup: best.throughput / dapple_best.throughput,
            strategy_speedup: dapple_best.throughput / dapple_worst.throughput,
        })
    }

    /// Attribute the sweep's win to the placement axis vs the strategy
    /// axis. `None` when no baseline-placement candidate was evaluated
    /// (every sweep space includes [`PlacementPolicy::Cluster`], so this
    /// only happens on empty or fully-unreachable spaces).
    pub fn placement_attribution(&self) -> Option<PlacementAttribution> {
        let best = self.best()?;
        let base_best = first_max(
            self.ranked()
                .filter(|c| c.placement == PlacementPolicy::Cluster),
        )?;
        let base_worst = self
            .ranked()
            .filter(|c| c.placement == PlacementPolicy::Cluster)
            .min_by(|a, b| a.throughput.total_cmp(&b.throughput))?;
        Some(PlacementAttribution {
            winning_placement: best.placement,
            placement_speedup: best.throughput / base_best.throughput,
            strategy_speedup: base_best.throughput / base_worst.throughput,
        })
    }

    pub fn pruned_count(&self) -> usize {
        self.candidates.iter().filter(|c| c.pruned).count()
    }

    /// The winner's rank→device table, when the placement optimizer won.
    pub fn winning_table(&self) -> Option<&[usize]> {
        let best = self.best()?;
        if best.placement == PlacementPolicy::Optimized {
            self.tables.get(best.table as usize).map(Vec::as_slice)
        } else {
            None
        }
    }

    pub fn evaluated_count(&self) -> usize {
        self.candidates.iter().filter(|c| c.evaluated()).count()
    }

    /// Legacy view for the paper-protocol consumers (fig12/table2/table3).
    pub fn to_search_report(&self) -> super::SearchReport {
        super::SearchReport {
            candidates: self.candidates.iter().map(SweepCandidate::to_candidate).collect(),
            profile: self.profile.clone(),
            simulate_seconds: self.timing.total_seconds,
        }
    }
}

/// The sweep engine itself; see the module docs for the contract.
///
/// This is the single execution core behind every sweep surface: the
/// one-shot CLI (`distsim search`), the fig12/table2/table3 experiment
/// drivers, and the what-if service (`distsim serve`). The cache is
/// injectable ([`SearchEngine::with_cache`]) so long-lived callers can
/// share measurements across sweeps; `prior` names descriptors the caller
/// already paid for (a loaded snapshot), so the report charges this sweep
/// only for genuinely new measurements.
pub struct SearchEngine<'a> {
    model: &'a ModelSpec,
    cluster: &'a ClusterSpec,
    book: CostBook,
    cfg: SweepConfig,
    cache: Arc<ProfileCache>,
    prior: HashSet<String>,
    /// Cooperative cancellation flag ([`SearchEngine::with_cancel`]);
    /// default is a never-fired token, so plain sweeps are unaffected.
    cancel: CancelToken,
    /// Span recorder for the pipeline stages ([`SearchEngine::with_trace`]);
    /// default is the disabled no-op. Recording is strictly out-of-band:
    /// it never influences candidate results (DESIGN.md §9).
    trace: RequestTrace,
    /// The candidate space, built once per engine (the optimizer's table
    /// enumeration and bound-ranking are not free — `space()` memoizes).
    space: OnceLock<CandidateSpace>,
    /// Compiled plan feeding the staged pipeline
    /// ([`SearchEngine::with_plan`]): candidate space, bound vector,
    /// memory verdicts and interned event set come from the plan instead
    /// of being re-derived. Every component is — by the plan's dependency
    /// tagging — bit-identical to what this engine would recompute, so a
    /// planned sweep's report is byte-identical to a plan-less one.
    plan: Option<Arc<SweepPlan>>,
}

impl<'a> SearchEngine<'a> {
    pub fn new(
        model: &'a ModelSpec,
        cluster: &'a ClusterSpec,
        cost: &'a CostModel,
        cfg: SweepConfig,
    ) -> Self {
        Self::with_cache(model, cluster, cost, cfg, Arc::new(ProfileCache::new()))
    }

    /// Build an engine over a shared (possibly pre-warmed) cache. The
    /// cache's profiling protocol must match `cfg` — callers key shared
    /// caches by [`super::cache::fingerprint`] to guarantee it.
    pub fn with_cache(
        model: &'a ModelSpec,
        cluster: &'a ClusterSpec,
        cost: &'a CostModel,
        cfg: SweepConfig,
        cache: Arc<ProfileCache>,
    ) -> Self {
        Self::with_book(model, cluster, CostBook::uniform(cost.clone()), cfg, cache)
    }

    /// Build an engine pricing through a full per-device-kind cost
    /// registry (mixed-SKU fleets; the service's request path).
    pub fn with_book(
        model: &'a ModelSpec,
        cluster: &'a ClusterSpec,
        book: CostBook,
        cfg: SweepConfig,
        cache: Arc<ProfileCache>,
    ) -> Self {
        SearchEngine {
            model,
            cluster,
            book,
            cfg,
            cache,
            prior: HashSet::new(),
            cancel: CancelToken::default(),
            trace: RequestTrace::default(),
            space: OnceLock::new(),
            plan: None,
        }
    }

    /// The per-device-kind cost registry this engine prices with.
    pub fn book(&self) -> &CostBook {
        &self.book
    }

    /// The cluster a sweep point deploys on: the engine's cluster, with
    /// the candidate's placement override applied when the placement axis
    /// set one, or the optimizer's table resolved from `tables`. Profiled
    /// costs are placement-independent, so every placement shares the
    /// engine's cache (see [`super::cache::fingerprint`]).
    pub(super) fn cluster_for(
        &self,
        spec: &CandidateSpec,
        tables: &[Vec<usize>],
    ) -> Cow<'a, ClusterSpec> {
        if spec.table != NO_TABLE {
            let t = tables
                .get(spec.table as usize)
                .expect("candidate references its sweep's table pool");
            return Cow::Owned(
                self.cluster
                    .with_placement(crate::cluster::Placement::Table(t.clone())),
            );
        }
        match spec.placement.placement() {
            None => Cow::Borrowed(self.cluster),
            Some(p) => Cow::Owned(self.cluster.with_placement(p)),
        }
    }

    /// Declare descriptors as already measured (e.g. a loaded snapshot's
    /// keys): the report's cache stats count their lookups as hits and
    /// charge them no GPU-seconds.
    pub fn with_prior(mut self, prior: HashSet<String>) -> Self {
        self.prior = prior;
        self
    }

    /// Attach a cooperative [`CancelToken`]. The sweep checks it at
    /// candidate-evaluation boundaries — at every pruning-epoch head and
    /// before dispatching each candidate — and stops evaluating once it
    /// fires; candidates never evaluated come back as unreachable
    /// placeholders (`throughput 0`, `reachable false`). A cancelled
    /// sweep's report is *not* covered by the bit-identity contract
    /// (which boundary observes the flag is wall-clock), like
    /// deadline-bearing requests in the service.
    pub fn with_cancel(mut self, cancel: CancelToken) -> Self {
        self.cancel = cancel;
        self
    }

    /// Attach a [`RequestTrace`] recording the sweep's pipeline stages
    /// (`source`, `bound`, `prune_epoch`, one `evaluate` span per
    /// candidate batch). With the default disabled trace no clock is
    /// read; either way the sweep's results are bit-identical.
    pub fn with_trace(mut self, trace: RequestTrace) -> Self {
        self.trace = trace;
        self
    }

    /// Feed the sweep from a compiled [`SweepPlan`] (ISSUE 10): the
    /// candidate space, analytical bounds, memory verdicts and interned
    /// event set are taken from the plan instead of being re-derived.
    /// The caller is responsible for launching the plan against this
    /// engine's exact request first ([`SweepPlan::launch`]) — a
    /// mismatched plan's per-candidate components are ignored
    /// defensively, never half-applied.
    pub fn with_plan(mut self, plan: Arc<SweepPlan>) -> Self {
        self.plan = Some(plan);
        self
    }

    /// The model this engine sweeps (plan compilation reuses the
    /// engine's candidate-scoped helpers and needs the inputs back).
    pub fn model(&self) -> &'a ModelSpec {
        self.model
    }

    /// The shared profile cache (for persistence after the sweep).
    pub fn cache(&self) -> &Arc<ProfileCache> {
        &self.cache
    }

    pub fn config(&self) -> &SweepConfig {
        &self.cfg
    }

    /// The full candidate space (specs + optimizer table pool), built by
    /// the staged source pipeline — see [`pipeline::build_space`] for the
    /// deterministic order. A `max_candidates` budget truncates it, so a
    /// budgeted sweep is a prefix of the full one. Built once per engine
    /// and memoized (the config is fixed at construction).
    pub fn space(&self) -> &CandidateSpace {
        if let Some(plan) = &self.plan {
            return plan.space();
        }
        self.space
            .get_or_init(|| pipeline::build_space(self.model, self.cluster, &self.cfg))
    }

    /// The candidate specs alone (legacy accessor; optimizer candidates
    /// reference [`SearchEngine::space`]'s table pool).
    pub fn specs(&self) -> Vec<CandidateSpec> {
        self.space().specs.clone()
    }

    pub(super) fn valid(&self, spec: &CandidateSpec) -> bool {
        spec.micro_batch_size >= 1
            && spec.strategy.is_valid_for(
                self.model.heads,
                self.model.num_transformer_layers(),
                spec.strategy.world_size(),
            )
            && self.cfg.global_batch % spec.strategy.dp == 0
    }

    /// Analytical throughput upper bound for the pruning pass (it/s).
    ///
    /// `baseline::analytical` prices compute at peak FLOPs with ideal
    /// communication and no overheads — **placement-aware** since ISSUE 5
    /// (each stage group priced at its own slowest member's SKU through
    /// the candidate's placement) — so its batch time lower-bounds the
    /// simulated one and `1e6 / analytical_us` upper-bounds the simulated
    /// throughput, per candidate placement. 0.0 when the candidate is
    /// invalid or the shard does not fit (those are evaluated anyway —
    /// they are cheap).
    ///
    /// For an optimizer table candidate (as returned by
    /// [`SearchEngine::specs`]) the table resolves through this engine's
    /// own [`SearchEngine::space`]; `sweep` passes the pool directly.
    pub fn bound_throughput(&self, spec: &CandidateSpec) -> f64 {
        if spec.table == NO_TABLE {
            self.bound_with(spec, &[])
        } else {
            self.bound_with(spec, &self.space().tables)
        }
    }

    /// Is per-rank memory accounting live for this sweep? On when any
    /// device kind declares a [`capacity_bytes`]
    /// ([`ClusterSpec::has_capacity`]) or any memory flag/axis of the
    /// config asks for the numbers; off by default, keeping reports
    /// byte-identical to pre-memory builds.
    ///
    /// [`capacity_bytes`]: crate::cluster::DeviceSpec::capacity_bytes
    pub fn memory_active(&self) -> bool {
        self.cfg.memory
            || self.cfg.recompute_axis
            || self.cfg.zero_axis
            || self.cluster.has_capacity()
    }

    pub(super) fn bound_with(&self, spec: &CandidateSpec, tables: &[Vec<usize>]) -> f64 {
        if !self.valid(spec) {
            return 0.0;
        }
        let cluster = self.cluster_for(spec, tables);
        let part = partition_opts(
            self.model,
            &spec.strategy,
            &cluster,
            spec.micro_batch_size,
            spec.recompute,
            spec.zero_stage,
        );
        if !cluster.fits(part.max_params_per_rank()) {
            return 0.0;
        }
        let sched = spec.schedule.build(spec.strategy.pp, spec.micro_batches);
        let us = analytical_batch_time_us(self.model, &part, &sched, &cluster);
        if us > 0.0 {
            1e6 / us
        } else {
            0.0
        }
    }

    /// Fully evaluate one spec (partition → profile → hierarchical model).
    fn evaluate(
        &self,
        spec: &CandidateSpec,
        tables: &[Vec<usize>],
        log: Option<&LookupLog>,
    ) -> (SweepCandidate, ProfileReport) {
        let mut cand = SweepCandidate {
            strategy: spec.strategy,
            micro_batch_size: spec.micro_batch_size,
            micro_batches: spec.micro_batches,
            schedule: spec.schedule,
            placement: spec.placement,
            table: spec.table,
            recompute: spec.recompute,
            zero_stage: spec.zero_stage,
            throughput: 0.0,
            scenario_throughput: 0.0,
            reachable: false,
            pruned: false,
            bound_throughput: 0.0,
            peak_bytes: 0,
            fits: true,
        };
        if !self.valid(spec) {
            // match the legacy evaluate_candidate: invalid candidates
            // report no micro-batching at all
            cand.micro_batch_size = 0;
            cand.micro_batches = 0;
            return (cand, ProfileReport::default());
        }
        let cluster = self.cluster_for(spec, tables);
        let part = partition_opts(
            self.model,
            &spec.strategy,
            &cluster,
            spec.micro_batch_size,
            spec.recompute,
            spec.zero_stage,
        );
        if !cluster.fits(part.max_params_per_rank()) {
            return (cand, ProfileReport::default());
        }
        let sched = spec.schedule.build(spec.strategy.pp, spec.micro_batches);
        if self.memory_active() {
            let mem = memory::assess(&part, &sched, &cluster, spec.recompute, spec.zero_stage);
            cand.peak_bytes = mem.peak_bytes;
            cand.fits = mem.fits;
            if !mem.fits {
                // infeasible: never profiled, never simulated. The
                // sweep's memory stage prunes these before dispatch;
                // direct calls get the same free verdict.
                return (cand, ProfileReport::default());
            }
        }
        let mut db = EventDb::new();
        crate::engine::build_programs(&part, &sched, &cluster, &mut db);
        let profile = if self.cfg.use_cache {
            self.cache.profile_into_logged(
                &mut db,
                &cluster,
                &self.book,
                self.cfg.jitter_sigma,
                self.cfg.profile_iters,
                self.cfg.profile_seed,
                log,
            );
            // cost accounted once, deterministically, via the lookup log
            ProfileReport::default()
        } else {
            profile_events(
                &mut db,
                &cluster,
                &self.book,
                self.cfg.jitter_sigma,
                self.cfg.profile_iters,
                self.cfg.profile_seed,
            )
        };
        let ds = DistSim::new(&part, &sched, &cluster);
        let batch_us = ds.predict_batch_time_us(&mut db);
        cand.reachable = true;
        cand.throughput = 1e6 / batch_us;
        if !self.cfg.scenario.is_empty() {
            let (_, degraded_us) =
                ds.predict_batch_time_us_scenario(&mut db, &self.cfg.scenario);
            cand.scenario_throughput = self
                .cfg
                .scenario
                .compose_batch_us(degraded_us, spec.strategy.dp, self.cfg.global_batch)
                .map_or(0.0, |us| 1e6 / us);
        }
        (cand, profile)
    }

    /// Degraded analytical re-walk of one deployable spec under a
    /// (possibly masked) scenario: `(nominal_us, degraded_us)`. The cache
    /// is warm for any spec the sweep already evaluated, so this costs
    /// one event-interning pass plus the two walks — no new profiling.
    fn degraded_walk(
        &self,
        spec: &CandidateSpec,
        tables: &[Vec<usize>],
        scn: &ScenarioSpec,
    ) -> (f64, f64) {
        let cluster = self.cluster_for(spec, tables);
        let part = partition_opts(
            self.model,
            &spec.strategy,
            &cluster,
            spec.micro_batch_size,
            spec.recompute,
            spec.zero_stage,
        );
        let sched = spec.schedule.build(spec.strategy.pp, spec.micro_batches);
        let mut db = EventDb::new();
        crate::engine::build_programs(&part, &sched, &cluster, &mut db);
        if self.cfg.use_cache {
            self.cache.profile_into_logged(
                &mut db,
                &cluster,
                &self.book,
                self.cfg.jitter_sigma,
                self.cfg.profile_iters,
                self.cfg.profile_seed,
                None,
            );
        } else {
            profile_events(
                &mut db,
                &cluster,
                &self.book,
                self.cfg.jitter_sigma,
                self.cfg.profile_iters,
                self.cfg.profile_seed,
            );
        }
        let ds = DistSim::new(&part, &sched, &cluster);
        ds.predict_batch_time_us_scenario(&mut db, scn)
    }

    /// Build the robustness block of a scenario sweep: pick the nominal
    /// and scenario winners, compute the regret, and attribute the robust
    /// winner's slowdown to stragglers vs link episodes via masked
    /// re-walks. `None` when nothing was evaluated.
    fn robustness(
        &self,
        candidates: &[SweepCandidate],
        tables: &[Vec<usize>],
    ) -> Option<RobustnessReport> {
        let spec = &self.cfg.scenario;
        // first-max index folds, mirroring `first_max`'s tie-breaking
        let mut nominal_best: Option<usize> = None;
        let mut scenario_best: Option<usize> = None;
        for (i, c) in candidates.iter().enumerate() {
            if !c.evaluated() {
                continue;
            }
            if nominal_best.map_or(true, |b| {
                candidates[b].throughput.total_cmp(&c.throughput).is_lt()
            }) {
                nominal_best = Some(i);
            }
            if c.scenario_throughput > 0.0
                && scenario_best.map_or(true, |b| {
                    candidates[b]
                        .scenario_throughput
                        .total_cmp(&c.scenario_throughput)
                        .is_lt()
                })
            {
                scenario_best = Some(i);
            }
        }
        let nominal_best = nominal_best?;
        let scenario_best = scenario_best?;
        let w = &candidates[scenario_best];
        let wspec = CandidateSpec {
            strategy: w.strategy,
            micro_batch_size: w.micro_batch_size,
            micro_batches: w.micro_batches,
            schedule: w.schedule,
            placement: w.placement,
            table: w.table,
            recompute: w.recompute,
            zero_stage: w.zero_stage,
        };
        let masked_stretch = |scn: ScenarioSpec| -> f64 {
            if scn.is_empty() {
                return 1.0;
            }
            let (nominal, degraded) = self.degraded_walk(&wspec, tables, &scn);
            degraded / nominal
        };
        Some(RobustnessReport {
            nominal_best,
            scenario_best,
            regret: 1.0
                - candidates[nominal_best].scenario_throughput / w.scenario_throughput,
            scenario_slowdown: w.throughput / w.scenario_throughput,
            straggler_slowdown: masked_stretch(ScenarioSpec {
                stragglers: spec.stragglers.clone(),
                straggler_episodes: spec.straggler_episodes.clone(),
                ..ScenarioSpec::default()
            }),
            link_slowdown: masked_stretch(ScenarioSpec {
                link_episodes: spec.link_episodes.clone(),
                ..ScenarioSpec::default()
            }),
            restart_penalty_us: spec.restart_penalty_us(),
            reshard_us: spec.resize.as_ref().map_or(0.0, |r| r.reshard_us),
            episodes: spec.episode_count(),
        })
    }

    fn resolve_threads(&self, work: usize) -> usize {
        let n = if self.cfg.threads > 0 {
            self.cfg.threads
        } else {
            std::thread::available_parallelism()
                .map(|n| n.get())
                .unwrap_or(1)
        };
        n.max(1).min(work.max(1))
    }

    /// Run the sweep through the staged pipeline.
    ///
    /// Phases: (1) the candidate sources build the index-addressed space
    /// (strategies × schedules × micro-batchings × placements, plus the
    /// placement optimizer's table candidates); (2) if pruning, every
    /// candidate gets its placement-aware analytical bound, and the
    /// [`EpochPlan`] schedules evaluation bound-descending — the first
    /// epoch evaluates only the analytically-best candidate (the
    /// deterministic incumbent seed), and each later fixed-size epoch is
    /// evaluated on a shared atomic work queue, with the improved
    /// incumbent re-pruning the remainder at each epoch boundary; (3)
    /// results land by candidate index, so the report is bit-identical
    /// for any worker count.
    pub fn sweep(&self) -> SweepReport {
        let t0 = Instant::now();
        let source_span = self.trace.start("source");
        let space = self.space();
        drop(source_span);
        let specs = &space.specs;
        let tables = &space.tables;
        let n = specs.len();
        let mut candidates: Vec<Option<SweepCandidate>> = vec![None; n];
        let mut per_ms = vec![0.0f64; n];
        let mut reports: Vec<ProfileReport> = vec![ProfileReport::default(); n];
        let mut bounds = vec![0.0f64; n];
        let mut pruned = vec![false; n];
        let log = LookupLog::default();
        let mut stats = PruneStats {
            generated: n,
            ..PruneStats::default()
        };

        // stage 0 of the pipeline: memory-feasibility pruning. Free — no
        // profiling, no simulation, just every rank's closed-form
        // residency — so infeasible points never reach the bound pass or
        // the evaluator. Only explicit capacities can fail a rank, so a
        // capacity-less fleet walks this stage without pruning anything
        // (and skips it entirely unless a memory flag/axis asked for the
        // numbers). Runs independently of `cfg.prune`: feasibility is a
        // hard constraint, not a performance heuristic.
        let mut memory_pruned = vec![false; n];
        let mut peak_of = vec![0u64; n];
        if self.memory_active() {
            let _span = self.trace.start("memory");
            // a compiled plan carries the verdicts already (tagged by the
            // capacity inputs, so they are exactly what assess() would
            // return here); recompute only without one
            let verdicts = self.plan.as_ref().and_then(|p| p.memory_for(n));
            for (i, spec) in specs.iter().enumerate() {
                if !self.valid(spec) {
                    // invalid specs keep the evaluator's cheap
                    // unreachable path (micro-batching zeroed, etc.)
                    continue;
                }
                let (peak_bytes, fits) = match verdicts {
                    Some(v) => (v.peak_bytes[i], v.fits[i]),
                    None => {
                        let cluster = self.cluster_for(spec, tables);
                        let part = partition_opts(
                            self.model,
                            &spec.strategy,
                            &cluster,
                            spec.micro_batch_size,
                            spec.recompute,
                            spec.zero_stage,
                        );
                        let sched =
                            spec.schedule.build(spec.strategy.pp, spec.micro_batches);
                        let mem = memory::assess(
                            &part,
                            &sched,
                            &cluster,
                            spec.recompute,
                            spec.zero_stage,
                        );
                        (mem.peak_bytes, mem.fits)
                    }
                };
                peak_of[i] = peak_bytes;
                if !fits {
                    memory_pruned[i] = true;
                    pruned[i] = true;
                    stats.memory_pruned += 1;
                    candidates[i] = Some(SweepCandidate {
                        strategy: spec.strategy,
                        micro_batch_size: spec.micro_batch_size,
                        micro_batches: spec.micro_batches,
                        schedule: spec.schedule,
                        placement: spec.placement,
                        table: spec.table,
                        recompute: spec.recompute,
                        zero_stage: spec.zero_stage,
                        throughput: 0.0,
                        scenario_throughput: 0.0,
                        reachable: false,
                        pruned: true,
                        bound_throughput: 0.0,
                        peak_bytes,
                        fits: false,
                    });
                }
            }
        }

        if self.cfg.prune {
            let _span = self.trace.start("bound");
            // a compiled plan already holds the full bound vector (tagged
            // by model/cluster/axes + cost book — identical numbers)
            let plan_bounds = self.plan.as_ref().and_then(|p| p.bounds_for(n));
            for (i, spec) in specs.iter().enumerate() {
                if pruned[i] {
                    // memory-pruned: never scheduled, no bound needed
                    continue;
                }
                // optimizer candidates were already bounded during table
                // ranking — identical inputs, identical number
                bounds[i] = match (plan_bounds, space.seed_bounds[i]) {
                    (Some(pb), _) => pb[i],
                    (None, Some(b)) => b,
                    (None, None) => self.bound_with(spec, tables),
                };
            }
        }
        let mut plan = EpochPlan::new(&bounds, self.cfg.prune, self.cfg.prune_epochs);
        let threads = self.resolve_threads(n);
        let mut incumbent = 0.0f64;
        let mut epoch = 0usize;
        while !plan.exhausted() {
            // cancellation boundary: a fired token stops scheduling new
            // epochs; already-landed results stay valid
            if self.cancel.is_cancelled() {
                break;
            }
            // re-prune the not-yet-scheduled remainder against the
            // incumbent (epoch 1 = the historical single up-front pass;
            // later epochs are the adaptive re-pruning)
            if self.cfg.prune && incumbent > 0.0 {
                let _span = self.trace.start("prune_epoch");
                for &i in plan.remaining() {
                    if !pruned[i]
                        && bounds[i] > 0.0
                        && bounds[i] * (1.0 + self.cfg.prune_margin) < incumbent
                    {
                        pruned[i] = true;
                        candidates[i] = Some(SweepCandidate {
                            strategy: specs[i].strategy,
                            micro_batch_size: specs[i].micro_batch_size,
                            micro_batches: specs[i].micro_batches,
                            schedule: specs[i].schedule,
                            placement: specs[i].placement,
                            table: specs[i].table,
                            recompute: specs[i].recompute,
                            zero_stage: specs[i].zero_stage,
                            throughput: 0.0,
                            scenario_throughput: 0.0,
                            reachable: true,
                            pruned: true,
                            bound_throughput: bounds[i],
                            peak_bytes: peak_of[i],
                            fits: true,
                        });
                        if epoch <= 1 {
                            stats.bound_pruned += 1;
                        } else {
                            stats.epoch_repruned += 1;
                        }
                    }
                }
            }
            let chunk = plan.next_epoch(&pruned);
            epoch += 1;
            if chunk.is_empty() {
                continue;
            }
            let chunk_threads = threads.min(chunk.len()).max(1);
            let queue = AtomicUsize::new(0);
            let slots: Vec<Mutex<Option<(SweepCandidate, ProfileReport, f64)>>> =
                chunk.iter().map(|_| Mutex::new(None)).collect();
            {
                let _span = self.trace.start("evaluate");
                let chunk = &chunk;
                let queue = &queue;
                let slots = &slots;
                let bounds = &bounds;
                let log = &log;
                std::thread::scope(|scope| {
                    for _ in 0..chunk_threads {
                        scope.spawn(move || loop {
                            // per-candidate cancellation boundary: stop
                            // claiming work once the token fires (a
                            // started evaluation runs to completion)
                            if self.cancel.is_cancelled() {
                                break;
                            }
                            let k = queue.fetch_add(1, Ordering::Relaxed);
                            if k >= chunk.len() {
                                break;
                            }
                            let i = chunk[k];
                            let ti = Instant::now();
                            let (mut cand, rep) =
                                self.evaluate(&specs[i], tables, Some(log));
                            cand.bound_throughput = bounds[i];
                            let ms = ti.elapsed().as_secs_f64() * 1e3;
                            *slots[k].lock().unwrap() = Some((cand, rep, ms));
                        });
                    }
                });
            }
            // land results by index; fold the incumbent in chunk order (a
            // max — independent of the workers' interleaving). An empty
            // slot means the token fired before a worker claimed it — only
            // reachable on cancelled sweeps; the placeholder fill below
            // covers it.
            for (k, &i) in chunk.iter().enumerate() {
                let taken = slots[k].lock().unwrap().take();
                match taken {
                    Some((cand, rep, ms)) => {
                        incumbent = incumbent.max(cand.throughput);
                        candidates[i] = Some(cand);
                        reports[i] = rep;
                        per_ms[i] = ms;
                    }
                    None => debug_assert!(
                        self.cancel.is_cancelled(),
                        "worker left a slot empty without cancellation"
                    ),
                }
            }
        }
        // on a cancelled sweep the unclaimed candidates were neither pruned
        // nor evaluated; count only what actually ran (identical to
        // `n - pruned` when the token never fired)
        stats.evaluated = candidates.iter().filter(|c| c.is_some()).count()
            - stats.memory_pruned
            - stats.bound_pruned
            - stats.epoch_repruned;

        // aggregate profiling cost deterministically: the sweep's own
        // lookup log in sorted-key order, accounted against the prior —
        // a pure function of the candidate set, independent of thread
        // interleaving and of other sweeps sharing the cache
        let event_uses = log.into_uses(self.cfg.profile_iters);
        let cache_stats = stats_against(&event_uses, &self.prior);
        // gpu-seconds-avoided attribution: the memory stage sits at the
        // head of the pipeline, so events shared between a memory-pruned
        // and a bound-pruned candidate are credited to the memory stage;
        // the total over both stages is identical to the pre-memory
        // single-pass accounting.
        let mut counted: HashSet<String> =
            event_uses.iter().map(|u| u.key.clone()).collect();
        counted.extend(self.prior.iter().cloned());
        stats.memory_gpu_seconds_avoided =
            self.gpu_seconds_avoided(specs, tables, &memory_pruned, &mut counted);
        let bound_pruned_mask: Vec<bool> = pruned
            .iter()
            .zip(&memory_pruned)
            .map(|(&p, &m)| p && !m)
            .collect();
        stats.gpu_seconds_avoided = stats.memory_gpu_seconds_avoided
            + self.gpu_seconds_avoided(specs, tables, &bound_pruned_mask, &mut counted);
        let profile = if self.cfg.use_cache {
            ProfileReport {
                gpu_seconds: cache_stats.gpu_seconds,
                events_profiled: cache_stats.unique_events,
                extrapolated: cache_stats.extrapolated,
                cache_hits: cache_stats.hits,
            }
        } else {
            let mut total = ProfileReport::default();
            for r in &reports {
                total.gpu_seconds += r.gpu_seconds;
                total.events_profiled += r.events_profiled;
                total.extrapolated += r.extrapolated;
            }
            total
        };

        let candidates: Vec<SweepCandidate> = candidates
            .into_iter()
            .enumerate()
            .map(|(i, c)| {
                c.unwrap_or_else(|| {
                    // only reachable when the sweep was cancelled:
                    // an unevaluated spec comes back as a
                    // non-deployable placeholder
                    debug_assert!(self.cancel.is_cancelled());
                    SweepCandidate {
                        strategy: specs[i].strategy,
                        micro_batch_size: specs[i].micro_batch_size,
                        micro_batches: specs[i].micro_batches,
                        schedule: specs[i].schedule,
                        placement: specs[i].placement,
                        table: specs[i].table,
                        recompute: specs[i].recompute,
                        zero_stage: specs[i].zero_stage,
                        throughput: 0.0,
                        scenario_throughput: 0.0,
                        reachable: false,
                        pruned: false,
                        bound_throughput: bounds[i],
                        peak_bytes: peak_of[i],
                        fits: true,
                    }
                })
            })
            .collect();
        let robustness = if self.cfg.scenario.is_empty() {
            None
        } else {
            self.robustness(&candidates, tables)
        };

        SweepReport {
            candidates,
            profile,
            cache: cache_stats,
            event_uses,
            tables: space.tables.clone(),
            pruning: stats,
            robustness,
            timing: SweepTiming {
                total_seconds: t0.elapsed().as_secs_f64(),
                per_candidate_ms: per_ms,
            },
            threads_used: threads,
        }
    }

    /// Deterministic noise-free estimate of the profiling cost the pruned
    /// candidates would have added: every event only pruned candidates
    /// reference is priced once (like the cache dedup), via the same cost
    /// laws the profiler's micro-programs execute — never by actually
    /// running them, which would re-pay the cost pruning skipped. At
    /// `jitter_sigma = 0` this matches the measurement for computation,
    /// p2p and directly-profiled ring events; extrapolated rings use the
    /// hierarchical law on the target group (the §4.2 < 2% relation).
    ///
    /// Requires the cache path's [`LookupLog`] to know what the sweep
    /// already measured, so a cache-off sweep reports 0 (that mode exists
    /// only as the legacy per-candidate re-profiling baseline). Pruned
    /// candidates are always valid specs (bound-pruned ones carry a
    /// positive bound; memory-pruned ones were assessed, which only
    /// happens to valid specs), so their partitions are deployable by
    /// construction — only event *interning* runs here, no simulation.
    ///
    /// `counted` carries the already-paid-for descriptors across calls:
    /// the sweep's own measurements plus the prior (a warm snapshot's
    /// keys) on entry — pruning avoids nothing for events a hit would
    /// have served — and grows with each selected candidate's events, so
    /// calling once per pipeline stage attributes every shared event to
    /// the earliest stage.
    fn gpu_seconds_avoided(
        &self,
        specs: &[CandidateSpec],
        tables: &[Vec<usize>],
        select: &[bool],
        counted: &mut HashSet<String>,
    ) -> f64 {
        if !self.cfg.use_cache || !select.iter().any(|&p| p) {
            return 0.0;
        }
        let mut avoided = 0.0;
        // a compiled plan already interned every candidate's event
        // descriptors — identical keys, identical estimator inputs, so the
        // figure matches the cold path bit for bit
        if let Some(ev) = self.plan.as_ref().and_then(|p| p.events_for(specs.len())) {
            for (i, spec) in specs.iter().enumerate() {
                if !select[i] {
                    continue;
                }
                let cluster = self.cluster_for(spec, tables);
                for &id in &ev.per_candidate[i] {
                    if counted.insert(ev.keys[id as usize].clone()) {
                        avoided += estimate_event_gpu_seconds(
                            &ev.events[id as usize],
                            &cluster,
                            &self.book,
                            self.cfg.profile_iters,
                        );
                    }
                }
            }
            return avoided;
        }
        for (i, spec) in specs.iter().enumerate() {
            if !select[i] {
                continue;
            }
            let cluster = self.cluster_for(spec, tables);
            let part = partition_opts(
                self.model,
                &spec.strategy,
                &cluster,
                spec.micro_batch_size,
                spec.recompute,
                spec.zero_stage,
            );
            let sched = spec.schedule.build(spec.strategy.pp, spec.micro_batches);
            let mut db = EventDb::new();
            crate::engine::build_programs(&part, &sched, &cluster, &mut db);
            for id in db.ids() {
                if counted.insert(db.get(id).key()) {
                    avoided += estimate_event_gpu_seconds(
                        db.get(id),
                        &cluster,
                        &self.book,
                        self.cfg.profile_iters,
                    );
                }
            }
        }
        avoided
    }
}

/// The noise-free cost of measuring one event under the profiling
/// protocol (`mean_us x devices x iters`), from the same laws the
/// profiler's micro-programs execute: operator roofline for computation
/// events, the p2p law for transfers, and the (ring-capped, 2-node-slice)
/// all-reduce laws with the §4.2 extrapolation collapsing to the
/// hierarchical law on the target group. Mirrors `profile::profile_single`
/// without running the discrete-event engine.
fn estimate_event_gpu_seconds(
    event: &crate::events::Event,
    cluster: &ClusterSpec,
    book: &CostBook,
    iters: usize,
) -> f64 {
    use crate::comm;
    use crate::events::{CommEvent, Event};
    let (mean_us, devices): (f64, usize) = match event {
        Event::Comp(c) => match cluster.kind_by_name(&c.kind) {
            Some(spec) => (
                book.for_kind(&c.kind).op_latency_us(spec, c.class, c.flops, c.bytes),
                1,
            ),
            None => (0.0, 0),
        },
        Event::Comm(CommEvent::P2p { bytes, link }) => {
            (comm::p2p_time_us(cluster, *link, *bytes), 2)
        }
        Event::Comm(CommEvent::AllReduce { bytes, group, link }) => {
            let cap = match link {
                crate::cluster::LinkClass::Intra => cluster.gpus_per_node,
                crate::cluster::LinkClass::Inter => 2 * cluster.gpus_per_node,
            }
            .min(crate::profile::MAX_PROFILE_RING);
            let n = (*group).min(cap);
            let t = if n < *group {
                let target = comm::synthetic_group(cluster, *group, *link);
                comm::hierarchical_allreduce_time_us(cluster, &target, *bytes)
            } else {
                comm::allreduce_time_us(cluster, *link, n, *bytes)
            };
            (t, n)
        }
    };
    mean_us * 1e-6 * iters as f64 * devices as f64
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::zoo;

    fn engine_cfg(threads: usize, prune: bool, use_cache: bool) -> SweepConfig {
        SweepConfig {
            threads,
            prune,
            use_cache,
            ..SweepConfig::default()
        }
    }

    #[test]
    fn default_spec_matches_seed_protocol() {
        let s = CandidateSpec::default_for(Strategy::new(1, 4, 4), 16);
        assert_eq!((s.micro_batch_size, s.micro_batches), (1, 4));
        let s = CandidateSpec::default_for(Strategy::new(4, 1, 4), 16);
        assert_eq!((s.micro_batch_size, s.micro_batches), (4, 1));
        // dp does not divide the batch -> sentinel unreachable spec
        let s = CandidateSpec::default_for(Strategy::new(1, 1, 3), 16);
        assert_eq!(s.micro_batch_size, 0);
    }

    #[test]
    fn sweep_matches_legacy_grid_search_values() {
        let model = zoo::bert_ex_large();
        let cluster = ClusterSpec::a10_cluster(4, 4);
        let cost = CostModel::default();
        let eng = SearchEngine::new(&model, &cluster, &cost, engine_cfg(1, false, true));
        let rep = eng.sweep();
        assert_eq!(rep.candidates.len(), 15);
        // cache off must give identical throughputs (same per-event seeds)
        let eng2 = SearchEngine::new(&model, &cluster, &cost, engine_cfg(1, false, false));
        let rep2 = eng2.sweep();
        for (a, b) in rep.candidates.iter().zip(&rep2.candidates) {
            assert_eq!(a, b, "cache must not change values");
        }
        assert!(rep.cache.hits > 0, "15 candidates must share events");
        assert!(
            rep.profile.gpu_seconds < rep2.profile.gpu_seconds,
            "dedup must cut profiling cost"
        );
    }

    #[test]
    fn micro_batch_axis_adds_points_for_pipelined_strategies() {
        let model = zoo::bert_large();
        let cluster = ClusterSpec::a40_cluster(4, 4);
        let cost = CostModel::default();
        let cfg = SweepConfig {
            micro_batch_axis: true,
            ..SweepConfig::default()
        };
        let eng = SearchEngine::new(&model, &cluster, &cost, cfg);
        let specs = eng.specs();
        let base = SearchEngine::new(&model, &cluster, &cost, SweepConfig::default())
            .specs()
            .len();
        assert!(specs.len() > base);
        // every extra point still covers the device count and divides the
        // replica batch
        for s in &specs {
            assert_eq!(s.strategy.world_size(), 16);
            if s.micro_batch_size > 0 {
                assert_eq!(
                    s.micro_batch_size * s.micro_batches * s.strategy.dp,
                    16,
                    "{s:?}"
                );
            }
        }
    }

    #[test]
    fn schedule_axis_enumerates_gpipe_and_naive_points() {
        let model = zoo::bert_large();
        let cluster = ClusterSpec::a40_cluster(4, 4);
        let cost = CostModel::default();
        let cfg = SweepConfig {
            schedule_axis: true,
            ..SweepConfig::default()
        };
        let eng = SearchEngine::new(&model, &cluster, &cost, cfg);
        let specs = eng.specs();
        let base = SearchEngine::new(&model, &cluster, &cost, SweepConfig::default())
            .specs()
            .len();
        assert!(specs.len() > base);
        // every pipelined strategy grows gpipe + naive points; pp=1 ones
        // stay dapple-only (all schedules degenerate to the same thing)
        for s in &specs {
            if s.strategy.pp <= 1 {
                assert_eq!(s.schedule, SchedKind::Dapple, "{s:?}");
            }
            if s.schedule == SchedKind::Naive {
                assert_eq!(s.micro_batches, 1, "{s:?}");
            }
        }
        assert!(specs.iter().any(|s| s.schedule == SchedKind::GPipe));
        assert!(specs.iter().any(|s| s.schedule == SchedKind::Naive));
    }

    #[test]
    fn max_candidates_takes_a_prefix() {
        let model = zoo::bert_large();
        let cluster = ClusterSpec::a40_cluster(4, 4);
        let cost = CostModel::default();
        let full = SearchEngine::new(&model, &cluster, &cost, SweepConfig::default()).specs();
        let cfg = SweepConfig {
            max_candidates: 3,
            ..SweepConfig::default()
        };
        let capped = SearchEngine::new(&model, &cluster, &cost, cfg).specs();
        assert_eq!(capped.len(), 3);
        assert_eq!(capped[..], full[..3]);
    }

    #[test]
    fn scenario_sweep_scores_candidates_and_attributes_slowdown() {
        let model = zoo::bert_large();
        let cluster = ClusterSpec::a40_cluster(2, 2);
        let cost = CostModel::default();
        let mut cfg = engine_cfg(1, false, true);
        cfg.scenario.stragglers.push(crate::scenario::Straggler {
            device: 0,
            factor: 1.5,
        });
        let rep = SearchEngine::new(&model, &cluster, &cost, cfg).sweep();
        let rb = rep.robustness.expect("scenario sweep carries robustness");
        assert!(
            rb.straggler_slowdown > 1.0,
            "straggler must stretch the robust winner ({})",
            rb.straggler_slowdown
        );
        assert_eq!(rb.link_slowdown, 1.0, "no link episodes in the spec");
        assert!((0.0..=1.0).contains(&rb.regret), "regret {}", rb.regret);
        assert_eq!(rb.episodes, 0);
        for c in rep.candidates.iter().filter(|c| c.evaluated()) {
            assert!(
                c.scenario_throughput > 0.0 && c.scenario_throughput <= c.throughput,
                "{}: scenario {} vs nominal {}",
                c.strategy,
                c.scenario_throughput,
                c.throughput
            );
        }
        // nominal sweeps stay scenario-free
        let nominal = SearchEngine::new(&model, &cluster, &cost, engine_cfg(1, false, true))
            .sweep();
        assert!(nominal.robustness.is_none());
        assert!(nominal.candidates.iter().all(|c| c.scenario_throughput == 0.0));
    }

    #[test]
    fn memory_stage_prunes_infeasible_candidates_for_free() {
        let model = zoo::bert_large();
        // ~3 GB budget: dp-heavy replicas (~5.6 GB of fp32 state) OOM,
        // sharded candidates (~1.4 GB) fit
        let cap = 3_000_000_000u64;
        let cluster = ClusterSpec::a40_cluster(2, 2).with_uniform_capacity(cap);
        let cost = CostModel::default();
        let rep = SearchEngine::new(&model, &cluster, &cost, engine_cfg(1, false, true)).sweep();
        assert!(rep.pruning.memory_pruned >= 1, "{:?}", rep.pruning);
        let oom: Vec<_> = rep.candidates.iter().filter(|c| !c.fits).collect();
        assert_eq!(oom.len(), rep.pruning.memory_pruned);
        for c in &oom {
            assert!(!c.reachable && c.pruned, "{c:?}");
            assert_eq!(c.throughput, 0.0);
            assert!(c.peak_bytes > cap, "{c:?}");
        }
        let best = rep.best().expect("sharded candidates fit");
        assert!(best.fits && best.peak_bytes > 0 && best.peak_bytes <= cap);
        // pruning was free and is accounted
        assert!(rep.pruning.memory_gpu_seconds_avoided > 0.0);
        assert!(
            rep.pruning.gpu_seconds_avoided >= rep.pruning.memory_gpu_seconds_avoided
        );
        assert_eq!(
            rep.pruning.generated,
            rep.pruning.memory_pruned
                + rep.pruning.bound_pruned
                + rep.pruning.epoch_repruned
                + rep.pruning.evaluated
        );
        // bit-identity across worker counts with the memory stage active
        let rep4 = SearchEngine::new(&model, &cluster, &cost, engine_cfg(4, false, true)).sweep();
        assert_eq!(rep.candidates, rep4.candidates);
        assert_eq!(rep.pruning, rep4.pruning);
    }

    #[test]
    fn fully_oom_space_ranks_nothing() {
        let model = zoo::bert_large();
        // one byte of capacity: nothing fits anywhere
        let cluster = ClusterSpec::a40_cluster(2, 2).with_uniform_capacity(1);
        let cost = CostModel::default();
        let rep = SearchEngine::new(&model, &cluster, &cost, engine_cfg(1, false, true)).sweep();
        assert_eq!(rep.pruning.memory_pruned, rep.candidates.len());
        assert_eq!(rep.pruning.evaluated, 0);
        assert!(rep.best().is_none(), "a fully-OOM space has no winner");
        assert!(rep.second_best().is_none());
        assert!(rep.worst().is_none());
        assert!(rep.speedup().is_none());
        assert_eq!(rep.evaluated_count(), 0);
        // nothing was profiled: the whole space was pruned for free
        assert_eq!(rep.profile.gpu_seconds, 0.0);
        assert!(rep.event_uses.is_empty());
        for c in &rep.candidates {
            assert!(!c.fits && !c.reachable && c.pruned);
            assert!(c.peak_bytes > 0);
        }
    }

    #[test]
    fn memory_axes_change_nothing_until_capacities_bind() {
        // recompute/zero points are real sweep points: the axis-off
        // prefix keeps its values, recompute never beats its own baseline
        // on throughput (it strictly adds backward FLOPs), and zero-1
        // strictly cuts optimizer residency on dp>1 points
        let model = zoo::bert_large();
        let cluster = ClusterSpec::a40_cluster(2, 2);
        let cost = CostModel::default();
        let cfg = SweepConfig {
            recompute_axis: true,
            zero_axis: true,
            threads: 1,
            ..SweepConfig::default()
        };
        let rep = SearchEngine::new(&model, &cluster, &cost, cfg).sweep();
        assert!(rep.candidates.len() > 6);
        for c in rep.candidates.iter().filter(|c| c.evaluated()) {
            assert!(c.fits && c.peak_bytes > 0, "{c:?}");
            if c.recompute == Recompute::Full {
                let base = rep
                    .candidates
                    .iter()
                    .find(|b| {
                        b.strategy == c.strategy
                            && b.micro_batch_size == c.micro_batch_size
                            && b.schedule == c.schedule
                            && b.zero_stage == c.zero_stage
                            && b.recompute == Recompute::None
                    })
                    .expect("baseline point exists");
                assert!(
                    c.throughput <= base.throughput,
                    "recompute must not speed up {}: {} > {}",
                    c.strategy,
                    c.throughput,
                    base.throughput
                );
                assert!(c.peak_bytes < base.peak_bytes, "{}", c.strategy);
            }
        }
    }

    #[test]
    fn bound_is_above_simulated_throughput() {
        // the pruning premise: analytical throughput >= DistSim throughput
        let model = zoo::bert_large();
        let cluster = ClusterSpec::a40_cluster(4, 4);
        let cost = CostModel::default();
        let eng = SearchEngine::new(&model, &cluster, &cost, engine_cfg(1, false, true));
        for spec in eng.specs() {
            let bound = eng.bound_throughput(&spec);
            let (cand, _) = eng.evaluate(&spec, &[], None);
            if cand.evaluated() {
                assert!(
                    bound > cand.throughput,
                    "{}: bound {bound} <= simulated {}",
                    spec.strategy,
                    cand.throughput
                );
            }
        }
    }
}
