//! Hybrid parallelism strategy: the paper's "xM yP zD" notation (§5.1).
//!
//! `mp` = tensor model parallelism degree (intra-layer, Megatron-style),
//! `pp` = pipeline parallelism degree (layer-wise stages),
//! `dp` = data parallelism degree (model replicas).
//! Total devices = mp * pp * dp.
//!
//! Rank layout follows Megatron: MP ranks are contiguous (fastest-varying,
//! so an MP group sits inside one node whenever mp <= gpus/node), then PP,
//! then DP.

use std::fmt;

#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct Strategy {
    pub mp: usize,
    pub pp: usize,
    pub dp: usize,
}

/// A device's coordinates in the 3-D strategy grid.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct RankCoords {
    pub mp: usize,
    pub pp: usize,
    pub dp: usize,
}

impl Strategy {
    pub fn new(mp: usize, pp: usize, dp: usize) -> Self {
        assert!(mp >= 1 && pp >= 1 && dp >= 1, "degrees must be >= 1");
        Strategy { mp, pp, dp }
    }

    /// Parse the paper's notation: "2M4P1D" (case-insensitive, any order).
    pub fn parse(s: &str) -> anyhow::Result<Self> {
        let (mut mp, mut pp, mut dp) = (None, None, None);
        let mut num = String::new();
        for c in s.chars() {
            if c.is_ascii_digit() {
                num.push(c);
                continue;
            }
            let v: usize = num
                .parse()
                .map_err(|_| anyhow::anyhow!("bad strategy notation '{s}'"))?;
            num.clear();
            match c.to_ascii_uppercase() {
                'M' => mp = Some(v),
                'P' => pp = Some(v),
                'D' => dp = Some(v),
                _ => anyhow::bail!("bad strategy notation '{s}': unknown axis '{c}'"),
            }
        }
        if !num.is_empty() {
            anyhow::bail!("bad strategy notation '{s}': trailing number");
        }
        match (mp, pp, dp) {
            (Some(m), Some(p), Some(d)) => {
                anyhow::ensure!(m >= 1 && p >= 1 && d >= 1, "degrees must be >= 1");
                Ok(Strategy { mp: m, pp: p, dp: d })
            }
            _ => anyhow::bail!("bad strategy notation '{s}': need all of M, P, D"),
        }
    }

    /// Canonical paper notation, e.g. "2M4P1D".
    pub fn notation(&self) -> String {
        format!("{}M{}P{}D", self.mp, self.pp, self.dp)
    }

    pub fn world_size(&self) -> usize {
        self.mp * self.pp * self.dp
    }

    /// Grid coordinates of a global rank (Megatron order: MP fastest).
    pub fn coords(&self, rank: usize) -> RankCoords {
        assert!(rank < self.world_size(), "rank {rank} out of range");
        RankCoords {
            mp: rank % self.mp,
            pp: (rank / self.mp) % self.pp,
            dp: rank / (self.mp * self.pp),
        }
    }

    /// Inverse of [`coords`].
    pub fn rank_of(&self, c: RankCoords) -> usize {
        assert!(c.mp < self.mp && c.pp < self.pp && c.dp < self.dp);
        (c.dp * self.pp + c.pp) * self.mp + c.mp
    }

    /// The MP group (all tensor-parallel peers) containing `rank`.
    pub fn mp_group(&self, rank: usize) -> Vec<usize> {
        let c = self.coords(rank);
        (0..self.mp)
            .map(|m| self.rank_of(RankCoords { mp: m, ..c }))
            .collect()
    }

    /// The DP group (gradient all-reduce peers) containing `rank`.
    pub fn dp_group(&self, rank: usize) -> Vec<usize> {
        let c = self.coords(rank);
        (0..self.dp)
            .map(|d| self.rank_of(RankCoords { dp: d, ..c }))
            .collect()
    }

    /// The pipeline-stage peer on stage `pp` for `rank`'s (mp, dp) lane.
    pub fn pp_peer(&self, rank: usize, pp: usize) -> usize {
        let c = self.coords(rank);
        self.rank_of(RankCoords { pp, ..c })
    }

    /// Valid deployments of `total` devices: every (mp, pp, dp) factoring.
    pub fn enumerate(total: usize) -> Vec<Strategy> {
        let mut out = Vec::new();
        for mp in 1..=total {
            if total % mp != 0 {
                continue;
            }
            let rest = total / mp;
            for pp in 1..=rest {
                if rest % pp != 0 {
                    continue;
                }
                out.push(Strategy::new(mp, pp, rest / pp));
            }
        }
        out
    }

    /// Paper §6 search-space validity: MP must divide attention heads, PP
    /// must not exceed layer count, and degrees must cover all devices.
    pub fn is_valid_for(&self, heads: usize, layers: usize, devices: usize) -> bool {
        self.world_size() == devices
            && heads % self.mp == 0
            && self.pp <= layers
    }
}

impl fmt::Display for Strategy {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.notation())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_paper_notation() {
        let s = Strategy::parse("2M4P1D").unwrap();
        assert_eq!((s.mp, s.pp, s.dp), (2, 4, 1));
        let s = Strategy::parse("1m2p2d").unwrap();
        assert_eq!((s.mp, s.pp, s.dp), (1, 2, 2));
        // order-insensitive
        let s = Strategy::parse("4D2P1M").unwrap();
        assert_eq!((s.mp, s.pp, s.dp), (1, 2, 4));
    }

    #[test]
    fn parse_rejects_bad_input() {
        assert!(Strategy::parse("2M4P").is_err());
        assert!(Strategy::parse("xMyPzD").is_err());
        assert!(Strategy::parse("2M4P1D3").is_err());
        assert!(Strategy::parse("0M1P1D").is_err());
    }

    #[test]
    fn notation_roundtrip() {
        for s in Strategy::enumerate(16) {
            assert_eq!(Strategy::parse(&s.notation()).unwrap(), s);
        }
    }

    #[test]
    fn coords_roundtrip_all_ranks() {
        let s = Strategy::new(2, 4, 2);
        for r in 0..s.world_size() {
            assert_eq!(s.rank_of(s.coords(r)), r);
        }
    }

    #[test]
    fn megatron_rank_order_mp_fastest() {
        let s = Strategy::new(2, 2, 2);
        // rank 0,1 = MP pair of (pp0, dp0); rank 2,3 = (pp1, dp0) ...
        assert_eq!(s.mp_group(0), vec![0, 1]);
        assert_eq!(s.mp_group(3), vec![2, 3]);
        assert_eq!(s.dp_group(0), vec![0, 4]);
        assert_eq!(s.pp_peer(0, 1), 2);
    }

    #[test]
    fn enumerate_16_has_15_strategies() {
        // The paper (§6): 15 valid factorings of 16 devices over 3 axes
        // with sizes in {1,2,4,8,16}.
        assert_eq!(Strategy::enumerate(16).len(), 15);
    }

    #[test]
    fn groups_contain_self_and_are_disjoint_partitions() {
        let s = Strategy::new(2, 2, 4);
        let mut seen = vec![0usize; s.world_size()];
        for r in 0..s.world_size() {
            assert!(s.mp_group(r).contains(&r));
            assert!(s.dp_group(r).contains(&r));
        }
        // MP groups partition the world
        for r in 0..s.world_size() {
            for m in s.mp_group(r) {
                seen[m] += 1;
            }
        }
        // each rank appears exactly mp times (once per member's view)
        assert!(seen.iter().all(|&c| c == s.mp));
    }

    #[test]
    fn validity_rules() {
        // BERT-exLarge: 48 layers, 16 heads, 16 devices
        assert!(Strategy::new(2, 8, 1).is_valid_for(16, 48, 16));
        // MP=32 does not divide 16 heads
        assert!(!Strategy::new(32, 1, 1).is_valid_for(16, 48, 32));
        // wrong world size
        assert!(!Strategy::new(2, 8, 1).is_valid_for(16, 48, 32));
        // PP deeper than the layer count
        assert!(!Strategy::new(2, 64, 1).is_valid_for(16, 48, 128));
    }
}

#[cfg(test)]
mod proptests {
    use super::*;
    use crate::testutil;

    #[test]
    fn prop_coords_bijective_for_random_strategies() {
        testutil::check("coords-bijective", 200, |rng| {
            let mp = 1 << rng.below(4);
            let pp = 1 << rng.below(4);
            let dp = 1 << rng.below(3);
            let s = Strategy::new(mp, pp, dp);
            let mut seen = std::collections::HashSet::new();
            for r in 0..s.world_size() {
                let c = s.coords(r);
                assert_eq!(s.rank_of(c), r);
                assert!(seen.insert((c.mp, c.pp, c.dp)));
            }
        });
    }

    #[test]
    fn prop_notation_roundtrips() {
        testutil::check("notation-roundtrip", 200, |rng| {
            let s = Strategy::new(
                1 + rng.below(64) as usize,
                1 + rng.below(64) as usize,
                1 + rng.below(64) as usize,
            );
            assert_eq!(Strategy::parse(&s.notation()).unwrap(), s);
        });
    }
}
