//! Naive reference implementations of the [`Timeline`] queries and the
//! metrics built on them — the seed's filter/clone/sort semantics, kept
//! as an executable specification.
//!
//! The indexed columnar [`Timeline`] must yield **byte-identical** values
//! to these (same float operations in the same order), which the golden
//! suite in `tests/timeline_golden.rs` and the engine bench assert. Never
//! call these on a hot path; that is the point of them.

use std::collections::BTreeMap;

use crate::cluster::ClusterSpec;
use crate::memory::Recompute;
use crate::metrics::StageKey;
use crate::partition::Partition;
use crate::schedule::{Phase, PipelineSchedule};
use crate::timeline::{Span, SpanKind, Timeline};
use crate::util::{stats, TimeUs};

/// Earliest span start by full rescan.
pub fn start_us(t: &Timeline) -> TimeUs {
    if t.is_empty() {
        return 0.0;
    }
    t.spans()
        .iter()
        .map(|s| s.start)
        .fold(f64::INFINITY, f64::min)
}

/// Batch time by full rescan: last end minus first start.
pub fn batch_time_us(t: &Timeline) -> TimeUs {
    if t.is_empty() {
        return 0.0;
    }
    let end = t
        .spans()
        .iter()
        .map(|s| s.end)
        .fold(f64::NEG_INFINITY, f64::max);
    end - start_us(t)
}

/// One device's spans by filter + stable sort (the seed's query).
pub fn device_spans(t: &Timeline, device: usize) -> Vec<Span> {
    let mut v: Vec<Span> = t
        .spans()
        .iter()
        .copied()
        .filter(|s| s.device == device)
        .collect();
    v.sort_by(|a, b| a.start.partial_cmp(&b.start).unwrap());
    v
}

/// One device's computation spans, via [`device_spans`].
pub fn device_comp_spans(t: &Timeline, device: usize) -> Vec<Span> {
    device_spans(t, device)
        .into_iter()
        .filter(|s| s.tag.kind == SpanKind::Comp)
        .collect()
}

/// Busy time by rescan, summed in start order.
pub fn busy_us(t: &Timeline, device: usize) -> TimeUs {
    device_spans(t, device).iter().map(Span::dur).sum()
}

/// A whole-timeline shifted copy (the seed's `normalized()`), as bare
/// span lists per device.
fn normalized_comp_spans(t: &Timeline) -> Vec<Vec<Span>> {
    let t0 = start_us(t);
    (0..t.n_devices)
        .map(|d| {
            device_comp_spans(t, d)
                .into_iter()
                .map(|s| Span {
                    start: s.start - t0,
                    end: s.end - t0,
                    ..s
                })
                .collect()
        })
        .collect()
}

/// The seed's per-GPU activity error: normalize both timelines (clone +
/// shift), align compute spans by order, average |Δstart| and |Δend|.
pub fn per_gpu_activity_error_pct(pred: &Timeline, truth: &Timeline) -> Vec<f64> {
    assert_eq!(pred.n_devices, truth.n_devices, "device count mismatch");
    let p = normalized_comp_spans(pred);
    let t = normalized_comp_spans(truth);
    let bt = batch_time_us(truth);
    (0..truth.n_devices)
        .map(|d| {
            let (ps, ts) = (&p[d], &t[d]);
            assert_eq!(ps.len(), ts.len(), "device {d}: span count mismatch");
            if ts.is_empty() || bt == 0.0 {
                return 0.0;
            }
            let biases: Vec<f64> = ps
                .iter()
                .zip(ts)
                .flat_map(|(a, b)| [(a.start - b.start).abs(), (a.end - b.end).abs()])
                .collect();
            stats::mean(&biases) / bt * 100.0
        })
        .collect()
}

/// The seed's stage timestamps: normalized clone, then min-start /
/// max-end per (device, micro-batch, phase) over compute spans.
pub fn stage_timestamps(t: &Timeline) -> BTreeMap<StageKey, (f64, f64)> {
    let mut out: BTreeMap<StageKey, (f64, f64)> = BTreeMap::new();
    for (d, lane) in normalized_comp_spans(t).iter().enumerate() {
        for s in lane {
            let key = StageKey {
                device: d,
                mb: s.tag.mb,
                phase_fwd: s.tag.phase == Phase::Fwd,
            };
            let e = out.entry(key).or_insert((f64::INFINITY, f64::NEG_INFINITY));
            e.0 = e.0.min(s.start);
            e.1 = e.1.max(s.end);
        }
    }
    out
}

/// The seed's bubble ratio: idle gaps by rescan, over devices x batch time.
pub fn bubble_ratio(t: &Timeline) -> f64 {
    let bt = batch_time_us(t);
    if bt == 0.0 || t.n_devices == 0 {
        return 0.0;
    }
    let t0 = start_us(t);
    // exact max end by rescan — NOT t0 + bt, which round-trips through
    // two subtract/add roundings and can miss the true end by an ulp
    let t1 = t
        .spans()
        .iter()
        .map(|s| s.end)
        .fold(f64::NEG_INFINITY, f64::max);
    let mut idle: TimeUs = 0.0;
    for d in 0..t.n_devices {
        let mut cursor = t0;
        for s in device_spans(t, d) {
            if s.start - cursor > 0.0 {
                idle += s.start - cursor;
            }
            cursor = cursor.max(s.end);
        }
        if t1 - cursor > 0.0 {
            idle += t1 - cursor;
        }
    }
    idle / (bt * t.n_devices as f64)
}

/// In-flight activation high-water by prefix rescan: for every prefix of
/// the stage's task list, count micro-batches whose forward has run but
/// whose backward has not. A set-semantics reimplementation of
/// [`PipelineSchedule::max_in_flight`]'s running counter.
pub fn in_flight_by_rescan(sched: &PipelineSchedule, stage: usize) -> usize {
    let tasks = &sched.stage_tasks[stage];
    (0..=tasks.len())
        .map(|i| {
            let prefix = &tasks[..i];
            (0..sched.micro_batches)
                .filter(|&mb| {
                    let fwd = prefix
                        .iter()
                        .any(|t| t.mb == mb && t.phase == Phase::Fwd);
                    let bwd = prefix
                        .iter()
                        .any(|t| t.mb == mb && t.phase == Phase::Bwd);
                    fwd && !bwd
                })
                .count()
        })
        .max()
        .unwrap_or(0)
}

/// One rank's peak residency by literal DESIGN.md §10 arithmetic, summed
/// in u128 with no per-stage memoization — the memory model's executable
/// specification. [`crate::memory::assess`] must agree byte-for-byte;
/// `tests/memory_model.rs` asserts the differential.
pub fn rank_peak_bytes(
    part: &Partition,
    sched: &PipelineSchedule,
    rank: usize,
    recompute: Recompute,
    zero_stage: u8,
) -> u64 {
    let stage = part.strategy.coords(rank).pp;
    let params = part.stages[stage].params_per_rank as u128;
    let mut total: u128 = 0;
    total += params * 4; // weights, fp32
    total += params * 4; // gradients, fp32
    let opt = params * 8; // Adam moments
    let dp = part.strategy.dp as u128;
    total += if zero_stage >= 1 && dp > 1 {
        opt.div_ceil(dp)
    } else {
        opt
    };
    let act_mb = part.micro_batch_size as u128 * part.seq as u128 * part.hidden as u128 * 4;
    let resident = match recompute {
        Recompute::None => part.stages[stage].layers.len() as u128,
        Recompute::Full => 1,
    };
    total += act_mb * resident * in_flight_by_rescan(sched, stage) as u128;
    total as u64
}

/// Fleet feasibility by full per-rank rescan: `(fits, oom_ranks)` against
/// each rank's SKU capacity, ranks ascending.
pub fn memory_feasible(
    part: &Partition,
    sched: &PipelineSchedule,
    cluster: &ClusterSpec,
    recompute: Recompute,
    zero_stage: u8,
) -> (bool, Vec<usize>) {
    let mut oom = Vec::new();
    for rank in 0..part.strategy.world_size() {
        let bytes = rank_peak_bytes(part, sched, rank, recompute, zero_stage);
        if let Some(cap) = cluster.capacity_of_kind(cluster.kind_of_rank(rank)) {
            if bytes > cap {
                oom.push(rank);
            }
        }
    }
    (oom.is_empty(), oom)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::timeline::Tag;

    #[test]
    fn in_flight_rescan_matches_the_running_counter() {
        for pp in [1usize, 2, 4] {
            for m in [1usize, 2, 4, 8] {
                for sched in [crate::schedule::gpipe(pp, m), crate::schedule::dapple(pp, m)] {
                    for s in 0..pp {
                        assert_eq!(
                            in_flight_by_rescan(&sched, s),
                            sched.max_in_flight(s),
                            "{} pp={pp} m={m} stage={s}",
                            sched.name
                        );
                    }
                }
            }
        }
    }

    fn tl() -> Timeline {
        let mut t = Timeline::new(2);
        let tag = Tag {
            stage: 0,
            mb: 0,
            phase: Phase::Fwd,
            layer: 0,
            kind: SpanKind::Comp,
            idx: 0,
        };
        t.push(Span { device: 1, start: 20.0, end: 30.0, tag });
        t.push(Span { device: 0, start: 5.0, end: 10.0, tag });
        t.push(Span { device: 1, start: 10.0, end: 20.0, tag });
        t.finalize();
        t
    }

    #[test]
    fn naive_matches_indexed_on_a_hand_case() {
        let t = tl();
        assert_eq!(batch_time_us(&t), t.batch_time_us());
        assert_eq!(start_us(&t), t.start_us());
        for d in 0..t.n_devices {
            assert_eq!(device_spans(&t, d), t.device_spans(d).to_vec());
            assert_eq!(busy_us(&t, d), t.busy_us(d));
        }
        assert_eq!(stage_timestamps(&t), crate::metrics::stage_timestamps(&t));
        assert_eq!(bubble_ratio(&t), crate::timeline::analysis::bubble_ratio(&t));
    }
}
