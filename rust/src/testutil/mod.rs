//! Tiny property-testing harness (no `proptest` in the offline vendor
//! set): generate seeded random cases, shrink is traded for printing the
//! failing seed so cases replay deterministically.

pub mod naive;

use crate::util::Rng;

/// Run `f` on `cases` seeded RNG streams; panics with the failing seed.
pub fn check<F: Fn(&mut Rng)>(name: &str, cases: u64, f: F) {
    for seed in 0..cases {
        let mut rng = Rng::new(0xD15751A ^ seed);
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            f(&mut rng)
        }));
        if let Err(e) = result {
            eprintln!("property '{name}' failed at seed {seed}");
            std::panic::resume_unwind(e);
        }
    }
}

/// Pick one element of a slice.
pub fn pick<'a, T>(rng: &mut Rng, xs: &'a [T]) -> &'a T {
    &xs[rng.below(xs.len() as u64) as usize]
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn check_runs_all_cases() {
        let counter = std::cell::Cell::new(0u64);
        check("count", 25, |_| {
            counter.set(counter.get() + 1);
        });
        assert_eq!(counter.get(), 25);
    }

    #[test]
    #[should_panic]
    fn check_propagates_failures() {
        check("fail", 10, |rng| {
            assert!(rng.f64() < 2.0); // always true
            assert!(rng.f64() >= 0.5); // fails quickly for some seed
        });
    }

    #[test]
    fn pick_is_in_range() {
        let xs = [1, 2, 3];
        let mut rng = Rng::new(5);
        for _ in 0..100 {
            assert!(xs.contains(pick(&mut rng, &xs)));
        }
    }
}
