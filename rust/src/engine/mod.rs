//! Ground-truth cluster engine — the reproduction's stand-in for the
//! paper's real 16-GPU testbed (see DESIGN.md substitutions).
//!
//! [`GroundTruth`] wires the whole substrate together: model zoo →
//! partitioner → pipeline schedule → per-rank programs → discrete-event
//! execution with contention/jitter/skew. "Actually running" a strategy
//! means calling [`GroundTruth::run_iteration`]; the paper's "actual
//! profiling result" series in every figure comes from here.

pub mod des;
pub mod program;

pub use des::{
    execute, execute_with_base, execute_with_scratch, BaseCosts, EngineParams, ExecScratch,
};
pub use program::{build_programs, Instr, Program};

use std::sync::Arc;

use crate::config::RunConfig;
use crate::cost::{CostBook, CostModel};
use crate::events::EventDb;
use crate::model::ModelSpec;
use crate::partition::{partition, Partition};
use crate::scenario::ScenarioSpec;
use crate::schedule::{self, PipelineSchedule};
use crate::timeline::Timeline;
use crate::util::stats;

/// A fully-prepared ground-truth run of one configuration.
pub struct GroundTruth {
    pub cfg: RunConfig,
    pub model: ModelSpec,
    pub part: Partition,
    pub sched: PipelineSchedule,
    pub prog: Program,
    pub db: EventDb,
    /// Per-device-kind cost registry the run is priced under.
    pub book: CostBook,
    /// Noise-free per-instruction prices, computed once (§Perf).
    base: des::BaseCosts,
    /// Unhappy-path scenario every iteration runs under
    /// ([`GroundTruth::with_scenario`]; `None` = happy path).
    scenario: Option<Arc<ScenarioSpec>>,
}

impl GroundTruth {
    /// Prepare a run from a config (resolves the model by name, partitions
    /// it, builds the schedule and per-rank programs).
    pub fn prepare(cfg: &RunConfig) -> anyhow::Result<Self> {
        Self::prepare_with_cost(cfg, CostModel::default())
    }

    /// Prepare with one cost model for every device kind.
    pub fn prepare_with_cost(cfg: &RunConfig, cost: CostModel) -> anyhow::Result<Self> {
        Self::prepare_with_book(cfg, CostBook::uniform(cost))
    }

    /// Prepare with a full per-device-kind cost registry (mixed fleets).
    pub fn prepare_with_book(cfg: &RunConfig, book: CostBook) -> anyhow::Result<Self> {
        let model = crate::model::by_name(&cfg.model)
            .ok_or_else(|| anyhow::anyhow!("unknown model '{}'", cfg.model))?;
        anyhow::ensure!(
            cfg.strategy.world_size() <= cfg.cluster.total_devices(),
            "strategy {} needs {} devices, cluster has {}",
            cfg.strategy,
            cfg.strategy.world_size(),
            cfg.cluster.total_devices()
        );
        anyhow::ensure!(
            cfg.strategy.is_valid_for(
                model.heads,
                model.layers.len(),
                cfg.strategy.world_size()
            ),
            "strategy {} invalid for model {}",
            cfg.strategy,
            model.name
        );
        let part = partition(&model, &cfg.strategy, &cfg.cluster, cfg.micro_batch_size);
        let sched = schedule::by_name(&cfg.schedule, cfg.strategy.pp, cfg.micro_batches)?;
        sched.validate()?;
        let mut db = EventDb::new();
        let prog = build_programs(&part, &sched, &cfg.cluster, &mut db);
        let base = des::BaseCosts::compute(&prog, &db, &cfg.cluster, &book);
        Ok(GroundTruth {
            cfg: cfg.clone(),
            model,
            part,
            sched,
            prog,
            db,
            book,
            base,
            scenario: None,
        })
    }

    /// Run every iteration under an unhappy-path scenario (stragglers and
    /// link episodes perturb the executor; failures/resize are accounted
    /// analytically — see `scenario`). An empty spec is bit-identical to
    /// no scenario.
    pub fn with_scenario(mut self, scenario: Arc<ScenarioSpec>) -> Self {
        self.scenario = Some(scenario);
        self
    }

    fn params(&self, seed: u64) -> EngineParams {
        EngineParams {
            jitter_sigma: self.cfg.jitter_sigma,
            clock_skew_us: self.cfg.clock_skew_us,
            contention: true,
            seed,
            scenario: self.scenario.clone(),
        }
    }

    /// One iteration's timeline (seed-offset lets callers model
    /// iteration-to-iteration fluctuation).
    pub fn run_iteration(&self, iter: u64) -> Timeline {
        execute_with_base(
            &self.prog,
            &self.db,
            &self.cfg.cluster,
            &self.base,
            &self.params(self.cfg.seed.wrapping_add(iter)),
        )
    }

    /// One iteration reusing `scratch`'s engine buffers — bit-identical
    /// to [`GroundTruth::run_iteration`], without the per-call
    /// allocations. Hand the timeline back via [`ExecScratch::recycle`]
    /// when done with it to also reuse the span storage.
    pub fn run_iteration_with_scratch(
        &self,
        iter: u64,
        scratch: &mut ExecScratch,
    ) -> Timeline {
        execute_with_scratch(
            &self.prog,
            &self.db,
            &self.cfg.cluster,
            &self.base,
            &self.params(self.cfg.seed.wrapping_add(iter)),
            scratch,
        )
    }

    /// Batch time averaged over `iters` iterations — what "profile the
    /// real cluster for 100 iterations" yields in the paper. One scratch
    /// serves all iterations (zero per-iteration engine allocation).
    pub fn mean_batch_time_us(&self, iters: usize) -> f64 {
        let mut scratch = ExecScratch::new();
        let times: Vec<f64> = (0..iters)
            .map(|i| {
                let tl = self.run_iteration_with_scratch(i as u64, &mut scratch);
                let bt = tl.batch_time_us();
                scratch.recycle(tl);
                bt
            })
            .collect();
        stats::mean(&times)
    }

    /// Total GPU-seconds consumed by direct profiling: world * time.
    pub fn direct_profiling_gpu_seconds(&self, iters: usize) -> f64 {
        let t = self.mean_batch_time_us(iters);
        t * 1e-6 * iters as f64 * self.cfg.strategy.world_size() as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::ClusterSpec;
    use crate::strategy::Strategy;

    fn cfg(mp: usize, pp: usize, dp: usize) -> RunConfig {
        RunConfig::new(
            "bert-large",
            Strategy::new(mp, pp, dp),
            ClusterSpec::a40_cluster(4, 4),
        )
    }

    #[test]
    fn prepare_rejects_oversized_strategy() {
        let c = cfg(4, 4, 4); // 64 > 16 devices
        assert!(GroundTruth::prepare(&c).is_err());
    }

    #[test]
    fn prepare_rejects_unknown_model() {
        let mut c = cfg(1, 1, 1);
        c.model = "alexnet".into();
        assert!(GroundTruth::prepare(&c).is_err());
    }

    #[test]
    fn mean_batch_time_is_stable_across_iters() {
        let gt = GroundTruth::prepare(&cfg(2, 2, 2)).unwrap();
        let m1 = gt.mean_batch_time_us(5);
        let m2 = gt.mean_batch_time_us(5);
        assert_eq!(m1, m2); // deterministic seed schedule
        let single = gt.run_iteration(0).batch_time_us();
        assert!((single / m1 - 1.0).abs() < 0.1);
    }

    #[test]
    fn halving_per_replica_work_roughly_halves_batch_time() {
        // DP-only: batch time = per-replica compute + grad AR; doubling
        // the micro-batch count should roughly double the compute part.
        let mut a = cfg(1, 1, 4);
        a.micro_batches = 2;
        let mut b = cfg(1, 1, 4);
        b.micro_batches = 4;
        let ta = GroundTruth::prepare(&a).unwrap().mean_batch_time_us(3);
        let tb = GroundTruth::prepare(&b).unwrap().mean_batch_time_us(3);
        let ratio = tb / ta;
        assert!(
            (1.5..2.2).contains(&ratio),
            "2x micro-batches gave {ratio}x batch time"
        );
    }

    #[test]
    fn tensor_mp_over_pcie_is_expensive() {
        // The realism behind Fig. 12's worst case: on PCIe-class intra
        // links, 4-way tensor MP's per-layer all-reduces outweigh the
        // compute savings vs 4-way DP.
        let t_mp = GroundTruth::prepare(&cfg(4, 1, 1))
            .unwrap()
            .mean_batch_time_us(3);
        let t_dp = GroundTruth::prepare(&cfg(1, 1, 4))
            .unwrap()
            .mean_batch_time_us(3);
        assert!(
            t_mp > t_dp * 0.8,
            "MP {t_mp} should not dominate DP {t_dp} on PCIe"
        );
    }
}
