//! Per-rank instruction programs: what the "real cluster" executes.
//!
//! [`build_programs`] compiles a (partition, schedule, strategy, cluster)
//! quadruple into one sequential instruction stream per rank — exactly the
//! artifact a real framework would deploy. The ground-truth engine
//! (`engine::des`) then executes these with physical semantics (rendezvous
//! transfers, collective barriers, contention, jitter), while DistSim never
//! sees them: it re-derives the timeline hierarchically from events.

use crate::cluster::ClusterSpec;
use crate::events::{CommEvent, Event, EventDb, EventId};
use crate::partition::Partition;
use crate::schedule::{Phase, PipelineSchedule};
use crate::strategy::RankCoords;
use crate::timeline::{SpanKind, Tag};

/// One instruction in a rank's program.
#[derive(Debug, Clone)]
pub enum Instr {
    /// Run a computation event on this device.
    Comp { event: EventId, tag: Tag },
    /// Eager (buffered) send to a peer: enqueue and continue.
    Send { peer: usize, event: EventId, tag: Tag },
    /// Blocking receive from a peer: waits for the matching send, then for
    /// the transfer itself.
    Recv { peer: usize, event: EventId, tag: Tag },
    /// Blocking collective over a rank group.
    AllReduce {
        group: u32,
        event: EventId,
        tag: Tag,
    },
}

/// A whole cluster's programs plus the interned rank groups.
#[derive(Debug, Clone, Default)]
pub struct Program {
    /// `instrs[rank]` = that rank's sequential program.
    pub instrs: Vec<Vec<Instr>>,
    /// Interned collective groups (sorted rank lists).
    pub groups: Vec<Vec<usize>>,
}

impl Program {
    pub fn n_ranks(&self) -> usize {
        self.instrs.len()
    }

    fn intern_group(&mut self, ranks: Vec<usize>) -> u32 {
        if let Some(i) = self.groups.iter().position(|g| *g == ranks) {
            return i as u32;
        }
        self.groups.push(ranks);
        (self.groups.len() - 1) as u32
    }

    pub fn total_instrs(&self) -> usize {
        self.instrs.iter().map(Vec::len).sum()
    }
}

/// Build per-rank programs for one training iteration.
///
/// Program order per rank follows the pipeline schedule's stage order; for
/// every scheduled (mb, phase) task the rank:
///   Fwd: recv activation from prev stage → per-layer [comp, mp-AR*] →
///        send activation to next stage;
///   Bwd: recv grad from next stage → per-layer (reverse) [comp, mp-AR*] →
///        send grad to prev stage;
/// and at the end, if dp > 1, the gradient all-reduce over its DP group.
pub fn build_programs(
    part: &Partition,
    sched: &PipelineSchedule,
    cluster: &ClusterSpec,
    db: &mut EventDb,
) -> Program {
    let strategy = part.strategy;
    let world = strategy.world_size();
    assert_eq!(sched.pp(), strategy.pp, "schedule/strategy pp mismatch");

    let mut prog = Program {
        instrs: vec![Vec::new(); world],
        groups: Vec::new(),
    };

    // rank -> physical device (identity unless the cluster has a
    // non-linear placement); links and device kinds resolve through it
    let rank_dev = cluster.rank_to_device();
    let link = |a: usize, b: usize| cluster.link_class(rank_dev[a], rank_dev[b]);

    for rank in 0..world {
        let c = strategy.coords(rank);
        let stage = c.pp;
        let work = &part.stages[stage];
        // the SKU this rank runs on: computation events are re-stamped per
        // rank so mixed fleets intern (and profile) one event per kind
        let kind = cluster.kind_name(cluster.device_kind(rank_dev[rank]));
        let mut instrs = Vec::new();

        // interned ids used repeatedly. The MP all-reduce event carries
        // this rank's *own* group's link class, resolved through the
        // placement map — under a hand-crafted Placement::Table sibling
        // lanes can straddle nodes differently, and each must profile the
        // ring at the class it actually runs on (DESIGN.md §6).
        let (mp_group_id, mp_ar_event) = if strategy.mp > 1 {
            let group = strategy.mp_group(rank);
            let group_devs: Vec<usize> = group.iter().map(|&r| rank_dev[r]).collect();
            let mp_link = cluster.group_link_class(&group_devs);
            let ar = work.layers.iter().find_map(|lw| lw.mp_allreduce.as_ref());
            // one event template covers the stage: the partitioner gives
            // every layer the same all-reduce payload (stage-wide
            // act_bytes). Enforce that invariant rather than assume it.
            debug_assert!(
                work.layers
                    .iter()
                    .filter_map(|lw| lw.mp_allreduce.as_ref())
                    .all(|a| Some(a) == ar),
                "per-layer MP all-reduce templates diverged within a stage"
            );
            let ev = ar.map(|ar| match ar {
                CommEvent::AllReduce { bytes, group, .. } => {
                    db.intern(Event::Comm(CommEvent::AllReduce {
                        bytes: *bytes,
                        group: *group,
                        link: mp_link,
                    }))
                }
                other => db.intern(Event::Comm(other.clone())),
            });
            (Some(prog.intern_group(group)), ev)
        } else {
            (None, None)
        };

        for task in &sched.stage_tasks[stage] {
            let (mb, phase) = (task.mb, task.phase);
            match phase {
                Phase::Fwd => {
                    if stage > 0 {
                        let peer = strategy.rank_of(RankCoords { pp: stage - 1, ..c });
                        let bytes = part.stages[stage - 1].act_bytes;
                        let ev = db.intern(Event::Comm(CommEvent::P2p {
                            bytes,
                            link: link(peer, rank),
                        }));
                        instrs.push(Instr::Recv {
                            peer,
                            event: ev,
                            tag: Tag {
                                stage: stage as u32,
                                mb: mb as u32,
                                phase,
                                layer: u32::MAX,
                                kind: SpanKind::P2p,
                                idx: 0,
                            },
                        });
                    }
                    for lw in &work.layers {
                        instrs.push(Instr::Comp {
                            event: db.intern(Event::Comp(lw.fwd.for_kind(kind))),
                            tag: Tag::comp(stage, mb, phase, lw.layer_idx),
                        });
                        if let (Some(gid), Some(ev)) = (mp_group_id, mp_ar_event) {
                            for k in 0..lw.ar_count_fwd {
                                instrs.push(Instr::AllReduce {
                                    group: gid,
                                    event: ev,
                                    tag: Tag {
                                        stage: stage as u32,
                                        mb: mb as u32,
                                        phase,
                                        layer: lw.layer_idx as u32,
                                        kind: SpanKind::MpAllReduce,
                                        idx: k as u32,
                                    },
                                });
                            }
                        }
                    }
                    if stage + 1 < strategy.pp {
                        let peer = strategy.rank_of(RankCoords { pp: stage + 1, ..c });
                        let ev = db.intern(Event::Comm(CommEvent::P2p {
                            bytes: work.act_bytes,
                            link: link(rank, peer),
                        }));
                        instrs.push(Instr::Send {
                            peer,
                            event: ev,
                            tag: Tag {
                                stage: stage as u32,
                                mb: mb as u32,
                                phase,
                                layer: u32::MAX,
                                kind: SpanKind::P2p,
                                idx: 1,
                            },
                        });
                    }
                }
                Phase::Bwd => {
                    if stage + 1 < strategy.pp {
                        let peer = strategy.rank_of(RankCoords { pp: stage + 1, ..c });
                        let bytes = work.act_bytes;
                        let ev = db.intern(Event::Comm(CommEvent::P2p {
                            bytes,
                            link: link(peer, rank),
                        }));
                        instrs.push(Instr::Recv {
                            peer,
                            event: ev,
                            tag: Tag {
                                stage: stage as u32,
                                mb: mb as u32,
                                phase,
                                layer: u32::MAX,
                                kind: SpanKind::P2p,
                                idx: 0,
                            },
                        });
                    }
                    for lw in work.layers.iter().rev() {
                        instrs.push(Instr::Comp {
                            event: db.intern(Event::Comp(lw.bwd.for_kind(kind))),
                            tag: Tag::comp(stage, mb, phase, lw.layer_idx),
                        });
                        if let (Some(gid), Some(ev)) = (mp_group_id, mp_ar_event) {
                            for k in 0..lw.ar_count_bwd {
                                instrs.push(Instr::AllReduce {
                                    group: gid,
                                    event: ev,
                                    tag: Tag {
                                        stage: stage as u32,
                                        mb: mb as u32,
                                        phase,
                                        layer: lw.layer_idx as u32,
                                        kind: SpanKind::MpAllReduce,
                                        idx: k as u32,
                                    },
                                });
                            }
                        }
                    }
                    if stage > 0 {
                        let peer = strategy.rank_of(RankCoords { pp: stage - 1, ..c });
                        let bytes = part.stages[stage - 1].act_bytes;
                        let ev = db.intern(Event::Comm(CommEvent::P2p {
                            bytes,
                            link: link(rank, peer),
                        }));
                        instrs.push(Instr::Send {
                            peer,
                            event: ev,
                            tag: Tag {
                                stage: stage as u32,
                                mb: mb as u32,
                                phase,
                                layer: u32::MAX,
                                kind: SpanKind::P2p,
                                idx: 1,
                            },
                        });
                    }
                }
            }
        }

        // DP gradient all-reduce.
        if strategy.dp > 1 {
            let group = strategy.dp_group(rank);
            let group_devs: Vec<usize> = group.iter().map(|&r| rank_dev[r]).collect();
            let link = cluster.group_link_class(&group_devs);
            let ev = db.intern(Event::Comm(CommEvent::AllReduce {
                bytes: part.grad_bytes_per_rank[stage],
                group: strategy.dp,
                link,
            }));
            let gid = prog.intern_group(group);
            instrs.push(Instr::AllReduce {
                group: gid,
                event: ev,
                tag: Tag {
                    stage: stage as u32,
                    mb: 0,
                    phase: Phase::Bwd,
                    layer: u32::MAX,
                    kind: SpanKind::GradAllReduce,
                    idx: 0,
                },
            });
        }

        prog.instrs[rank] = instrs;
    }

    prog
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::zoo;
    use crate::partition::partition;
    use crate::schedule;
    use crate::strategy::Strategy;

    fn build(mp: usize, pp: usize, dp: usize, m: usize) -> (Program, EventDb) {
        let model = zoo::bert_large();
        let s = Strategy::new(mp, pp, dp);
        let c = ClusterSpec::a40_cluster(4, 4);
        let part = partition(&model, &s, &c, 4);
        let sched = schedule::dapple(pp, m);
        let mut db = EventDb::new();
        let prog = build_programs(&part, &sched, &c, &mut db);
        (prog, db)
    }

    #[test]
    fn sends_and_recvs_pair_up_globally() {
        let (prog, _) = build(2, 2, 2, 4);
        let mut sends = std::collections::HashMap::new();
        let mut recvs = std::collections::HashMap::new();
        for (r, instrs) in prog.instrs.iter().enumerate() {
            for i in instrs {
                match i {
                    Instr::Send { peer, .. } => {
                        *sends.entry((r, *peer)).or_insert(0) += 1;
                    }
                    Instr::Recv { peer, .. } => {
                        *recvs.entry((*peer, r)).or_insert(0) += 1;
                    }
                    _ => {}
                }
            }
        }
        assert_eq!(sends, recvs, "unmatched send/recv");
        assert!(!sends.is_empty());
    }

    #[test]
    fn allreduce_rounds_match_within_groups() {
        let (prog, _) = build(2, 2, 2, 4);
        // every member of a group must issue the same number of ARs on it
        let mut counts: std::collections::HashMap<(u32, usize), usize> =
            std::collections::HashMap::new();
        for (r, instrs) in prog.instrs.iter().enumerate() {
            for i in instrs {
                if let Instr::AllReduce { group, .. } = i {
                    *counts.entry((*group, r)).or_insert(0) += 1;
                }
            }
        }
        for (gid, members) in prog.groups.iter().enumerate() {
            let per: Vec<usize> = members
                .iter()
                .map(|&m| counts.get(&(gid as u32, m)).copied().unwrap_or(0))
                .collect();
            assert!(
                per.windows(2).all(|w| w[0] == w[1]),
                "group {gid} unbalanced: {per:?}"
            );
        }
    }

    #[test]
    fn dp_only_program_has_no_p2p() {
        let (prog, _) = build(1, 1, 4, 1);
        for instrs in &prog.instrs {
            assert!(!instrs
                .iter()
                .any(|i| matches!(i, Instr::Send { .. } | Instr::Recv { .. })));
            // but ends with the gradient all-reduce
            assert!(matches!(instrs.last(), Some(Instr::AllReduce { .. })));
        }
    }

    #[test]
    fn comp_counts_match_layers_times_microbatches() {
        let m = 4;
        let (prog, _) = build(1, 2, 1, m);
        let model = zoo::bert_large();
        let total_layers = model.layers.len();
        let comp_count: usize = prog
            .instrs
            .iter()
            .map(|is| {
                is.iter()
                    .filter(|i| matches!(i, Instr::Comp { .. }))
                    .count()
            })
            .sum();
        // each layer computed fwd + bwd per micro-batch on exactly 1 rank
        assert_eq!(comp_count, total_layers * 2 * m);
    }

    #[test]
    fn event_db_dedup_is_massive() {
        let (prog, db) = build(2, 4, 2, 8);
        // thousands of instructions, but only a handful of unique events
        assert!(prog.total_instrs() > 1000);
        assert!(db.len() < 30, "expected heavy dedup, got {}", db.len());
    }
}
