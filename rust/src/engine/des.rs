//! Discrete-event executor: runs per-rank programs with physical
//! semantics. This is the "real cluster" of the reproduction (see
//! DESIGN.md substitutions): eager-buffered sends, blocking receives,
//! collective barriers, link contention, kernel jitter and per-device
//! clock skew — the exact phenomena the paper attributes its residual
//! modeling errors to.

use std::cmp::Reverse;
use std::collections::{BinaryHeap, VecDeque};
use std::sync::Arc;

use super::program::{Instr, Program};
use crate::cluster::{ClusterSpec, LinkClass};
use crate::comm;
use crate::cost::CostBook;
use crate::events::{CommEvent, Event, EventDb};
use crate::scenario::ScenarioSpec;
use crate::timeline::{Span, Tag, Timeline};
use crate::util::{Rng, TimeUs};

/// Noise / fidelity knobs for the ground truth.
#[derive(Debug, Clone)]
pub struct EngineParams {
    /// Multiplicative compute-time jitter sigma (0 = deterministic).
    pub jitter_sigma: f64,
    /// Per-device clock skew sigma (us), applied to *recorded* timestamps
    /// (the paper reports timestamps in rank 0's clock).
    pub clock_skew_us: f64,
    /// Model link contention (concurrent transfers share bandwidth).
    pub contention: bool,
    pub seed: u64,
    /// Unhappy-path scenario (stragglers, link episodes — see
    /// `scenario`). `None` and `Some(empty)` are bit-identical to the
    /// pre-scenario engine: every adjustment is gated on a non-empty
    /// spec, including the scenario RNG forks. Failures and elastic
    /// resize are accounting events, composed analytically on top of the
    /// simulated batch time — the executor never mutates rank count.
    pub scenario: Option<Arc<ScenarioSpec>>,
}

impl Default for EngineParams {
    fn default() -> Self {
        EngineParams {
            jitter_sigma: 0.02,
            clock_skew_us: 20.0,
            contention: true,
            seed: 42,
            scenario: None,
        }
    }
}

struct RankState {
    pc: usize,
    clock: TimeUs,
    rng: Rng,
}

#[derive(Default)]
struct Channel {
    /// (post time, duration-relevant event) of sends not yet consumed.
    pending_sends: VecDeque<TimeUs>,
}

/// Transfer end-time ordered for the contention min-heaps. End times are
/// rank-local clocks, always finite and non-negative, so `total_cmp` is a
/// plain numeric order here.
#[derive(PartialEq)]
struct EndTime(TimeUs);

impl Eq for EndTime {}

impl PartialOrd for EndTime {
    fn partial_cmp(&self, other: &Self) -> Option<std::cmp::Ordering> {
        Some(self.cmp(other))
    }
}

impl Ord for EndTime {
    fn cmp(&self, other: &Self) -> std::cmp::Ordering {
        self.0.total_cmp(&other.0)
    }
}

/// Tracks concurrently-active transfers per link class for contention.
///
/// Min-heaps of end times with lazy expiry: `active(now)` pops every
/// transfer that ended at or before `now` (O(log k) amortized per
/// transfer, each entry popped once) instead of the seed's O(k)
/// retain-rescan on every call. The surviving set — entries with
/// `end > now` — is identical to what `retain` kept, so counts (and
/// therefore every contention factor and timeline) are bit-identical.
#[derive(Default)]
struct LinkLoad {
    intra: BinaryHeap<Reverse<EndTime>>,
    inter: BinaryHeap<Reverse<EndTime>>,
}

impl LinkLoad {
    fn lane(&mut self, class: LinkClass) -> &mut BinaryHeap<Reverse<EndTime>> {
        match class {
            LinkClass::Intra => &mut self.intra,
            LinkClass::Inter => &mut self.inter,
        }
    }

    fn active(&mut self, class: LinkClass, now: TimeUs) -> usize {
        let heap = self.lane(class);
        while matches!(heap.peek(), Some(Reverse(EndTime(end))) if *end <= now) {
            heap.pop();
        }
        heap.len()
    }

    fn register(&mut self, class: LinkClass, end: TimeUs) {
        self.lane(class).push(Reverse(EndTime(end)));
    }

    fn clear(&mut self) {
        self.intra.clear();
        self.inter.clear();
    }
}

/// Contention slowdown: each concurrent transfer on the same link class
/// costs 15% extra (an empirical stand-in for bandwidth sharing on a
/// PCIe/IB fabric; see DESIGN.md).
fn contention_factor(active: usize) -> f64 {
    1.0 + 0.15 * active as f64
}

/// Pre-priced base durations, one per instruction, computed once per
/// program and shared across iterations (§Perf: the logistic efficiency
/// curve and the collective laws are by far the hottest pure-compute in
/// the engine loop; re-pricing them every iteration cost ~40%).
///
/// Heterogeneity enters here: each rank's compute and launch overhead are
/// priced on *its* SKU (placement-resolved [`DeviceSpec`] + per-kind
/// [`CostBook`] model), so a mixed fleet's timeline has per-rank stage
/// latencies while the executor loop stays SKU-oblivious.
///
/// [`DeviceSpec`]: crate::cluster::DeviceSpec
#[derive(Debug, Clone)]
pub struct BaseCosts {
    /// `per_instr[rank][pc]` = noise-free duration of that instruction
    /// (for Send: the sender's launch overhead; for Recv: the wire time).
    pub per_instr: Vec<Vec<TimeUs>>,
}

impl BaseCosts {
    pub fn compute(
        prog: &Program,
        db: &EventDb,
        cluster: &ClusterSpec,
        book: &CostBook,
    ) -> BaseCosts {
        let rank_dev = cluster.rank_to_device();
        let per_instr = prog
            .instrs
            .iter()
            .enumerate()
            .map(|(rank, instrs)| {
                let spec = cluster.kind_spec(cluster.device_kind(rank_dev[rank]));
                let model = book.for_kind(&spec.name);
                instrs
                    .iter()
                    .map(|i| match i {
                        Instr::Comp { event, .. } => {
                            let Event::Comp(c) = db.get(*event) else {
                                panic!("comp instr references comm event")
                            };
                            model.op_latency_us(spec, c.class, c.flops, c.bytes)
                        }
                        Instr::Send { .. } => spec.launch_overhead_us,
                        Instr::Recv { event, .. } => {
                            let Event::Comm(CommEvent::P2p { bytes, link }) = db.get(*event)
                            else {
                                panic!("recv references non-p2p event")
                            };
                            comm::p2p_time_us(cluster, *link, *bytes)
                        }
                        Instr::AllReduce { group, event, .. } => {
                            let Event::Comm(CommEvent::AllReduce { bytes, .. }) = db.get(*event)
                            else {
                                panic!("allreduce references non-AR event")
                            };
                            let devices: Vec<usize> = prog.groups[*group as usize]
                                .iter()
                                .map(|&r| rank_dev[r])
                                .collect();
                            comm::hierarchical_allreduce_time_us(cluster, &devices, *bytes)
                        }
                    })
                    .collect()
            })
            .collect();
        BaseCosts { per_instr }
    }
}

/// Reusable engine state: every buffer [`execute_with_scratch`] needs,
/// allocated once per (program shape) and reused across iterations and
/// sweep candidates. After the first call with a given program, repeated
/// executions perform zero per-iteration heap allocation of engine state
/// (profiling loops run ~100 iterations per event, and a sweep runs
/// thousands of engine iterations — allocator churn was pure overhead;
/// see ISSUE 2 / §Perf).
#[derive(Default)]
pub struct ExecScratch {
    states: Vec<RankState>,
    skews: Vec<f64>,
    channels: Vec<Channel>,
    waiting_recv: Vec<Option<TimeUs>>,
    arrivals: Vec<Vec<(usize, TimeUs)>>,
    load: LinkLoad,
    runnable: VecDeque<usize>,
    blocked: Vec<bool>,
    /// Recycled output timeline (callers hand finished timelines back via
    /// [`ExecScratch::recycle`] so span buffers survive the iteration).
    spare: Option<Timeline>,
}

impl ExecScratch {
    pub fn new() -> Self {
        Self::default()
    }

    /// Hand a finished timeline back for reuse by the next execution.
    pub fn recycle(&mut self, timeline: Timeline) {
        self.spare = Some(timeline);
    }

    /// Size every buffer for an `n`-rank program with `n_groups`
    /// collective groups. Only (re)allocates when the shape grows.
    fn prepare(&mut self, n: usize, n_groups: usize) {
        if self.states.len() > n {
            self.states.truncate(n);
        }
        while self.states.len() < n {
            // placeholder rng; re-seeded per execution below
            self.states.push(RankState {
                pc: 0,
                clock: 0.0,
                rng: Rng::new(0),
            });
        }
        self.skews.clear();
        self.channels.resize_with(n * n, Channel::default);
        for c in &mut self.channels[..n * n] {
            c.pending_sends.clear();
        }
        self.waiting_recv.clear();
        self.waiting_recv.resize(n * n, None);
        self.arrivals.resize_with(n_groups, Vec::new);
        for a in &mut self.arrivals[..n_groups] {
            a.clear();
        }
        self.load.clear();
        self.runnable.clear();
        self.blocked.clear();
        self.blocked.resize(n, false);
    }
}

/// Execute one iteration of `prog`, returning the per-device timeline.
pub fn execute(
    prog: &Program,
    db: &EventDb,
    cluster: &ClusterSpec,
    book: &CostBook,
    params: &EngineParams,
) -> Timeline {
    let base = BaseCosts::compute(prog, db, cluster, book);
    execute_with_base(prog, db, cluster, &base, params)
}

/// Execute with pre-priced instruction costs (callers that run many
/// iterations compute [`BaseCosts`] once). Allocates fresh engine state;
/// the hot path is [`execute_with_scratch`].
pub fn execute_with_base(
    prog: &Program,
    db: &EventDb,
    cluster: &ClusterSpec,
    base: &BaseCosts,
    params: &EngineParams,
) -> Timeline {
    let mut scratch = ExecScratch::new();
    execute_with_scratch(prog, db, cluster, base, params, &mut scratch)
}

/// Execute reusing `scratch`'s buffers (zero per-iteration engine-state
/// allocation once warm). Bit-identical output to [`execute_with_base`]
/// for the same inputs — the scratch only recycles memory, never state.
pub fn execute_with_scratch(
    prog: &Program,
    db: &EventDb,
    cluster: &ClusterSpec,
    base: &BaseCosts,
    params: &EngineParams,
    scratch: &mut ExecScratch,
) -> Timeline {
    // every price — including per-rank (per-SKU) launch overheads — is
    // pre-resolved in `base`; the executor consults the topology only to
    // resolve a scenario's device factors and base link latencies.
    let n = prog.n_ranks();
    scratch.prepare(n, prog.groups.len());
    let mut master_rng = Rng::new(params.seed);
    {
        let mut r = master_rng.fork(0xC10C);
        scratch
            .skews
            .extend((0..n).map(|_| r.normal_ms(0.0, params.clock_skew_us)));
    }
    let skews = &scratch.skews[..];
    let skew0 = skews[0];

    let states = &mut scratch.states;
    for (r, st) in states.iter_mut().enumerate() {
        st.pc = 0;
        st.clock = 0.0;
        st.rng = master_rng.fork(r as u64 + 1);
    }
    let mut coll_rng = master_rng.fork(0xA11);

    // scenario state, all gated on a non-empty spec so the empty scenario
    // consumes no master draws and allocates nothing (bit-identity with
    // the pre-scenario engine). The per-rank scenario streams are forked
    // *after* every pre-existing fork, salted by (scenario, rank): the
    // scenario salt hashes the canonical spec JSON and each rank xors in
    // its index, so streams are distinct per rank and per scenario.
    let scn: Option<&ScenarioSpec> = params.scenario.as_deref().filter(|s| !s.is_empty());
    let rank_dev: Vec<usize> = if scn.is_some() {
        cluster.rank_to_device()
    } else {
        Vec::new()
    };
    let mut scn_rngs: Vec<Rng> = match scn {
        Some(spec) if spec.sigma > 0.0 => {
            let salt = spec.salt();
            (0..n).map(|r| master_rng.fork(salt ^ (r as u64 + 1))).collect()
        }
        _ => Vec::new(),
    };

    let mut timeline = scratch.spare.take().unwrap_or_default();
    timeline.reset(n);
    timeline.reserve(prog.total_instrs());
    // flat (src, dst) channel matrix — n is small (<= a few hundred ranks)
    // and flat indexing beats hashing in the hot loop (§Perf)
    let channels = &mut scratch.channels;
    // waiting receivers: [src * n + dst] -> recv post time (dst blocked)
    let waiting_recv = &mut scratch.waiting_recv;
    // collective arrivals: members block until the round completes, so at
    // most one round per group is in flight — a per-group vec suffices
    let arrivals = &mut scratch.arrivals;
    let load = &mut scratch.load;

    let runnable = &mut scratch.runnable;
    runnable.extend(0..n);
    let blocked = &mut scratch.blocked;
    let mut done = 0usize;

    let record =
        |timeline: &mut Timeline, device: usize, start: TimeUs, end: TimeUs, tag: Tag, skew: f64| {
            timeline.push(Span {
                device,
                start: start + skew,
                end: end + skew,
                tag,
            });
        };

    while let Some(r) = runnable.pop_front() {
        if blocked[r] {
            continue;
        }
        loop {
            let pc = states[r].pc;
            if pc >= prog.instrs[r].len() {
                done += 1;
                break;
            }
            match &prog.instrs[r][pc] {
                Instr::Comp { event: _, tag } => {
                    let mut dur =
                        base.per_instr[r][pc] * states[r].rng.jitter(params.jitter_sigma);
                    let start = states[r].clock;
                    if let Some(spec) = scn {
                        // straggler factors resolve at the span's start in
                        // unskewed simulated time (skew shifts recorded
                        // timestamps only, never this clock)
                        dur *= spec.comp_factor_at(rank_dev[r], start);
                        if spec.sigma > 0.0 {
                            dur *= scn_rngs[r].jitter(spec.sigma);
                        }
                    }
                    states[r].clock += dur;
                    record(&mut timeline, r, start, states[r].clock, *tag, skews[r] - skew0);
                    states[r].pc += 1;
                }
                Instr::Send { peer, event, tag } => {
                    let _ = (event, tag);
                    let peer = *peer;
                    // eager buffered send: pay this rank's (SKU's) launch
                    // overhead — pre-priced per instruction — and enqueue
                    states[r].clock += base.per_instr[r][pc];
                    channels[r * n + peer]
                        .pending_sends
                        .push_back(states[r].clock);
                    states[r].pc += 1;
                    // if the peer is already waiting on this channel,
                    // complete the transfer and wake it
                    if let Some(recv_post) = waiting_recv[r * n + peer].take() {
                        let send_post = channels[r * n + peer]
                            .pending_sends
                            .pop_front()
                            .unwrap();
                        let peer_pc = states[peer].pc;
                        let (recv_tag, ev) = match &prog.instrs[peer][peer_pc] {
                            Instr::Recv { event, tag, .. } => (*tag, *event),
                            other => panic!("peer not at recv: {other:?}"),
                        };
                        let Event::Comm(CommEvent::P2p { link, .. }) = db.get(ev) else {
                            panic!("recv references non-p2p event")
                        };
                        let start = send_post.max(recv_post);
                        let active = if params.contention { load.active(*link, start) } else { 0 };
                        let mut dur = base.per_instr[peer][peer_pc]
                            * contention_factor(active)
                            * coll_rng.jitter(params.jitter_sigma);
                        if let Some(spec) = scn {
                            dur = spec.link_dur_at(*link, start, dur, cluster.lat_us(*link));
                        }
                        if params.contention {
                            load.register(*link, start + dur);
                        }
                        states[peer].clock = start + dur;
                        states[peer].pc += 1;
                        let skew = skews[peer] - skew0;
                        record(&mut timeline, peer, start, start + dur, recv_tag, skew);
                        blocked[peer] = false;
                        runnable.push_back(peer);
                    }
                }
                Instr::Recv { peer, event, tag } => {
                    let peer = *peer;
                    let chan = &mut channels[peer * n + r];
                    if let Some(send_post) = chan.pending_sends.pop_front() {
                        let Event::Comm(CommEvent::P2p { link, .. }) = db.get(*event) else {
                            panic!("recv references non-p2p event")
                        };
                        let start = send_post.max(states[r].clock);
                        let active = if params.contention { load.active(*link, start) } else { 0 };
                        let mut dur = base.per_instr[r][pc]
                            * contention_factor(active)
                            * coll_rng.jitter(params.jitter_sigma);
                        if let Some(spec) = scn {
                            dur = spec.link_dur_at(*link, start, dur, cluster.lat_us(*link));
                        }
                        if params.contention {
                            load.register(*link, start + dur);
                        }
                        record(&mut timeline, r, start, start + dur, *tag, skews[r] - skew0);
                        states[r].clock = start + dur;
                        states[r].pc += 1;
                    } else {
                        waiting_recv[peer * n + r] = Some(states[r].clock);
                        blocked[r] = true;
                        break;
                    }
                }
                Instr::AllReduce { group, event, tag } => {
                    let gid = *group as usize;
                    arrivals[gid].push((r, states[r].clock));
                    let members = &prog.groups[gid];
                    if arrivals[gid].len() == members.len() {
                        // barrier complete: price the ring
                        let start = arrivals[gid]
                            .iter()
                            .map(|&(_, t)| t)
                            .fold(f64::NEG_INFINITY, f64::max);
                        // NOTE: ring all-reduces run on disjoint device
                        // sets (each group's ring uses its own members'
                        // links), so unlike p2p they do not contend with
                        // each other in this fabric model; they only see
                        // jitter. See DESIGN.md.
                        let mut dur =
                            base.per_instr[r][pc] * coll_rng.jitter(params.jitter_sigma);
                        if let Some(spec) = scn {
                            let Event::Comm(CommEvent::AllReduce { link, .. }) = db.get(*event)
                            else {
                                panic!("allreduce references non-AR event")
                            };
                            dur = spec.link_dur_at(*link, start, dur, cluster.lat_us(*link));
                        }
                        // drain in place (not mem::take) so the arrival
                        // buffer's allocation survives for the next round
                        for k in 0..arrivals[gid].len() {
                            let (m, _) = arrivals[gid][k];
                            states[m].clock = start + dur;
                            states[m].pc += 1;
                            record(&mut timeline, m, start, start + dur, *tag, skews[m] - skew0);
                            if m != r {
                                blocked[m] = false;
                                runnable.push_back(m);
                            }
                        }
                        arrivals[gid].clear();
                        // r continues in this loop
                    } else {
                        blocked[r] = true;
                        break;
                    }
                }
            }
        }
    }

    assert_eq!(
        done, n,
        "deadlock: {} of {} ranks finished (schedule/program bug)",
        done, n
    );
    timeline.finalize();
    timeline
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::engine::program::build_programs;
    use crate::model::zoo;
    use crate::partition::partition;
    use crate::schedule;
    use crate::strategy::Strategy;

    fn run(
        mp: usize,
        pp: usize,
        dp: usize,
        m: usize,
        sched_name: &str,
        params: &EngineParams,
    ) -> Timeline {
        let model = zoo::bert_large();
        let s = Strategy::new(mp, pp, dp);
        let c = ClusterSpec::a40_cluster(4, 4);
        let part = partition(&model, &s, &c, 4);
        let sched = schedule::by_name(sched_name, pp, m).unwrap();
        let mut db = EventDb::new();
        let prog = build_programs(&part, &sched, &c, &mut db);
        execute(&prog, &db, &c, &CostBook::default(), params)
    }

    fn quiet() -> EngineParams {
        EngineParams {
            jitter_sigma: 0.0,
            clock_skew_us: 0.0,
            contention: false,
            seed: 1,
            scenario: None,
        }
    }

    #[test]
    fn executes_all_hybrid_shapes_without_deadlock() {
        for (mp, pp, dp, m) in [
            (1, 1, 1, 1),
            (1, 1, 4, 1),
            (4, 1, 1, 2),
            (1, 4, 1, 4),
            (2, 2, 2, 4),
            (2, 4, 2, 8),
            (4, 2, 2, 4),
        ] {
            for sched in ["gpipe", "dapple"] {
                let t = run(mp, pp, dp, m, sched, &quiet());
                assert!(t.batch_time_us() > 0.0, "{mp}M{pp}P{dp}D {sched}");
            }
        }
    }

    #[test]
    fn deterministic_given_seed() {
        let a = run(2, 2, 2, 4, "dapple", &EngineParams::default());
        let b = run(2, 2, 2, 4, "dapple", &EngineParams::default());
        assert_eq!(a.len(), b.len());
        for (x, y) in a.spans().iter().zip(b.spans()) {
            assert_eq!(x.start, y.start);
            assert_eq!(x.end, y.end);
        }
    }

    #[test]
    fn scratch_reuse_is_bit_identical_to_fresh_state() {
        let model = zoo::bert_large();
        let s = Strategy::new(2, 2, 2);
        let c = ClusterSpec::a40_cluster(4, 4);
        let part = partition(&model, &s, &c, 4);
        let sched = schedule::by_name("dapple", 2, 4).unwrap();
        let mut db = EventDb::new();
        let prog = build_programs(&part, &sched, &c, &mut db);
        let base = BaseCosts::compute(&prog, &db, &c, &CostBook::default());
        let mut scratch = ExecScratch::new();
        for seed in [1u64, 2, 3] {
            let params = EngineParams { seed, ..EngineParams::default() };
            let fresh = execute_with_base(&prog, &db, &c, &base, &params);
            let reused = execute_with_scratch(&prog, &db, &c, &base, &params, &mut scratch);
            assert_eq!(fresh.len(), reused.len(), "seed {seed}");
            for (x, y) in fresh.spans().iter().zip(reused.spans()) {
                assert_eq!(x, y, "seed {seed}");
            }
            scratch.recycle(reused);
        }
    }

    #[test]
    fn different_seeds_fluctuate() {
        let a = run(2, 2, 2, 4, "dapple", &EngineParams { seed: 1, ..EngineParams::default() });
        let b = run(2, 2, 2, 4, "dapple", &EngineParams { seed: 2, ..EngineParams::default() });
        assert_ne!(a.batch_time_us(), b.batch_time_us());
        // but within a few percent of each other
        let rel = (a.batch_time_us() - b.batch_time_us()).abs() / a.batch_time_us();
        assert!(rel < 0.10, "fluctuation {rel} implausibly large");
    }

    #[test]
    fn spans_on_one_device_do_not_overlap() {
        let t = run(2, 2, 2, 4, "dapple", &quiet());
        for d in 0..t.n_devices {
            let spans = t.device_spans(d);
            for w in spans.windows(2) {
                assert!(
                    w[1].start >= w[0].end - 1e-6,
                    "device {d} overlap: {:?} then {:?}",
                    w[0],
                    w[1]
                );
            }
        }
    }

    #[test]
    fn gpipe_has_more_bubble_than_dapple_at_depth() {
        // Dapple exists to shrink bubbles; the physics must reflect that
        // at equal micro-batch count (bubble fraction; GPipe and 1F1B have
        // equal critical path in the ideal case but Dapple's steady state
        // interleaves, helping under jitter/comm overlap).
        let g = run(1, 4, 1, 8, "gpipe", &quiet());
        let d = run(1, 4, 1, 8, "dapple", &quiet());
        let gb = crate::timeline::analysis::bubble_ratio(&g);
        let db_ = crate::timeline::analysis::bubble_ratio(&d);
        assert!(db_ <= gb + 0.02, "gpipe {gb} vs dapple {db_}");
    }

    #[test]
    fn pipeline_bubble_shrinks_with_more_microbatches() {
        let few = run(1, 4, 1, 4, "dapple", &quiet());
        let many = run(1, 4, 1, 16, "dapple", &quiet());
        let bf = crate::timeline::analysis::bubble_ratio(&few);
        let bm = crate::timeline::analysis::bubble_ratio(&many);
        assert!(bm < bf, "bubble should shrink: {bf} -> {bm}");
    }

    #[test]
    fn dp_scaling_does_not_change_per_replica_compute_time() {
        // pure DP: batch time ~= single-replica time + grad AR
        let solo = run(1, 1, 1, 1, "gpipe", &quiet());
        let dp4 = run(1, 1, 4, 1, "gpipe", &quiet());
        assert!(dp4.batch_time_us() > solo.batch_time_us());
        // compute part identical: compare busy time of device 0 minus AR
        let solo_busy = solo.busy_us(0);
        let dp_comp: f64 = dp4
            .device_comp_spans(0)
            .iter()
            .map(|s| s.dur())
            .sum();
        assert!((dp_comp / solo_busy - 1.0).abs() < 1e-9);
    }

    #[test]
    fn empty_scenario_is_bit_identical_to_none() {
        let without = run(2, 2, 2, 4, "dapple", &EngineParams::default());
        let with = run(
            2,
            2,
            2,
            4,
            "dapple",
            &EngineParams {
                scenario: Some(Arc::new(ScenarioSpec::default())),
                ..EngineParams::default()
            },
        );
        assert_eq!(without.len(), with.len());
        for (a, b) in without.spans().iter().zip(with.spans()) {
            assert_eq!(a, b);
        }
    }

    #[test]
    fn persistent_straggler_slows_the_batch() {
        use crate::scenario::Straggler;
        let nominal = run(2, 2, 2, 4, "dapple", &quiet());
        let spec = ScenarioSpec {
            stragglers: vec![Straggler { device: 0, factor: 1.5 }],
            ..ScenarioSpec::default()
        };
        let slow = run(
            2,
            2,
            2,
            4,
            "dapple",
            &EngineParams { scenario: Some(Arc::new(spec)), ..quiet() },
        );
        assert!(slow.batch_time_us() > nominal.batch_time_us());
    }

    #[test]
    fn clock_skew_shifts_recorded_timestamps_only() {
        let no_skew = run(1, 2, 1, 2, "gpipe", &quiet());
        let skewed = run(
            1,
            2,
            1,
            2,
            "gpipe",
            &EngineParams {
                jitter_sigma: 0.0,
                clock_skew_us: 50.0,
                contention: false,
                seed: 9,
                scenario: None,
            },
        );
        // rank 0 spans unshifted relative to each other; other devices
        // shift rigidly — span durations must be identical
        for (a, b) in no_skew.spans().iter().zip(skewed.spans()) {
            assert!((a.dur() - b.dur()).abs() < 1e-9);
        }
    }
}

#[cfg(test)]
mod proptests {
    use super::*;
    use crate::engine::program::build_programs;
    use crate::model::zoo;
    use crate::partition::partition;
    use crate::schedule;
    use crate::strategy::Strategy;
    use crate::testutil;

    #[test]
    fn prop_random_hybrid_configs_never_deadlock() {
        testutil::check("no-deadlock", 40, |rng| {
            let mp = 1 << rng.below(3); // 1,2,4
            let pp = 1 << rng.below(3);
            let dp = 1 << rng.below(2);
            let m = 1 + rng.below(8) as usize;
            let sched_name = *testutil::pick(rng, &["gpipe", "dapple"]);
            let model = zoo::bert_large();
            let s = Strategy::new(mp, pp, dp);
            let c = ClusterSpec::a40_cluster(8, 4);
            let part = partition(&model, &s, &c, 2);
            let sched = schedule::by_name(sched_name, pp, m).unwrap();
            let mut db = EventDb::new();
            let prog = build_programs(&part, &sched, &c, &mut db);
            let tl = execute(
                &prog,
                &db,
                &c,
                &CostBook::default(),
                &EngineParams {
                    jitter_sigma: rng.f64() * 0.1,
                    clock_skew_us: rng.f64() * 50.0,
                    contention: rng.f64() < 0.5,
                    seed: rng.next_u64(),
                    scenario: None,
                },
            );
            assert!(tl.batch_time_us() > 0.0);
            // per-device spans never overlap, whatever the config
            for d in 0..tl.n_devices {
                let spans = tl.device_spans(d);
                for w in spans.windows(2) {
                    assert!(w[1].start >= w[0].end - 1e-6, "{s} overlap on {d}");
                }
            }
        });
    }
}
