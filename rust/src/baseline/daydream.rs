//! A Daydream/dPRO-style simulator (paper §2.4): profiled per-operator
//! times replayed under the **highly-sequential assumption** — when a
//! device finishes an operator it immediately launches the next one in its
//! own trace; the only cross-device dependency modeled is the data-parallel
//! gradient all-reduce.
//!
//! For pure data parallelism this is exactly right (and matches DistSim).
//! For pipeline/model parallelism it is structurally wrong: it cannot
//! express waiting for another stage's activation or an MP barrier, so it
//! predicts compute-packed timelines with no bubbles. The `ablate-hierarchy`
//! experiment quantifies that failure, motivating the paper's hierarchical
//! modeling.

use crate::cluster::ClusterSpec;
use crate::events::EventDb;
use crate::partition::Partition;
use crate::schedule::PipelineSchedule;
use crate::timeline::{Span, Timeline};

/// Replay every rank's operator list back-to-back (no inter-device waits
/// except the final gradient all-reduce).
pub fn daydream_predict(
    part: &Partition,
    sched: &PipelineSchedule,
    cluster: &ClusterSpec,
    db: &mut EventDb,
) -> Timeline {
    let strategy = part.strategy;
    let prog = crate::engine::build_programs(part, sched, cluster, db);
    let mut timeline = Timeline::new(strategy.world_size());

    // sequential replay per rank, ignoring send/recv/barrier semantics
    let mut finish = vec![0.0f64; strategy.world_size()];
    for (rank, instrs) in prog.instrs.iter().enumerate() {
        let mut cur = 0.0f64;
        for instr in instrs {
            match instr {
                crate::engine::Instr::Comp { event, tag } => {
                    let dur = db.elapsed(*event);
                    timeline.push(Span {
                        device: rank,
                        start: cur,
                        end: cur + dur,
                        tag: *tag,
                    });
                    cur += dur;
                }
                crate::engine::Instr::Recv { event, tag, .. } => {
                    // sequential assumption: the data is already there;
                    // only the wire time is replayed
                    let dur = db.elapsed(*event);
                    timeline.push(Span {
                        device: rank,
                        start: cur,
                        end: cur + dur,
                        tag: *tag,
                    });
                    cur += dur;
                }
                crate::engine::Instr::Send { .. } => {
                    cur += cluster.device.launch_overhead_us;
                }
                crate::engine::Instr::AllReduce { event, tag, .. } => {
                    // replay MP all-reduces inline; the DP gradient AR is
                    // the one synchronization Daydream models
                    let dur = db.elapsed(*event);
                    timeline.push(Span {
                        device: rank,
                        start: cur,
                        end: cur + dur,
                        tag: *tag,
                    });
                    cur += dur;
                }
            }
        }
        finish[rank] = cur;
    }
    let _ = finish;
    timeline.finalize();
    timeline
}

/// Daydream's batch-time estimate.
pub fn daydream_batch_time_us(
    part: &Partition,
    sched: &PipelineSchedule,
    cluster: &ClusterSpec,
    db: &mut EventDb,
) -> f64 {
    daydream_predict(part, sched, cluster, db).batch_time_us()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cost::CostBook;
    use crate::engine::GroundTruth;
    use crate::model::zoo;
    use crate::partition::partition;
    use crate::profile::profile_events;
    use crate::schedule;
    use crate::strategy::Strategy;

    fn setup(
        mp: usize,
        pp: usize,
        dp: usize,
        m: usize,
    ) -> (Partition, PipelineSchedule, ClusterSpec, EventDb) {
        let model = zoo::bert_large();
        let s = Strategy::new(mp, pp, dp);
        let c = ClusterSpec::a40_cluster(4, 4);
        let part = partition(&model, &s, &c, 4);
        let sched = schedule::dapple(pp, m);
        let mut db = EventDb::new();
        crate::engine::build_programs(&part, &sched, &c, &mut db);
        profile_events(&mut db, &c, &CostBook::default(), 0.0, 1, 5);
        (part, sched, c, db)
    }

    #[test]
    fn accurate_for_pure_data_parallelism() {
        // §2.4: the sequential assumption holds for DP
        let (part, sched, c, mut db) = setup(1, 1, 4, 1);
        let est = daydream_batch_time_us(&part, &sched, &c, &mut db);
        let cfg = crate::config::RunConfig {
            jitter_sigma: 0.0,
            clock_skew_us: 0.0,
            micro_batches: 1,
            ..crate::config::RunConfig::new("bert-large", Strategy::new(1, 1, 4), c)
        };
        let gt = GroundTruth::prepare(&cfg).unwrap();
        let actual = gt.run_iteration(0).batch_time_us();
        let err = crate::util::rel_err_pct(est, actual);
        assert!(err < 5.0, "daydream DP error {err}%");
    }

    #[test]
    fn misses_pipeline_bubbles_badly() {
        // §2.4: for PP it underestimates because it cannot express waiting
        let (part, sched, c, mut db) = setup(1, 4, 1, 4);
        let est = daydream_batch_time_us(&part, &sched, &c, &mut db);
        let cfg = crate::config::RunConfig {
            jitter_sigma: 0.0,
            clock_skew_us: 0.0,
            micro_batches: 4,
            ..crate::config::RunConfig::new("bert-large", Strategy::new(1, 4, 1), c)
        };
        let gt = GroundTruth::prepare(&cfg).unwrap();
        let actual = gt.run_iteration(0).batch_time_us();
        assert!(
            est < actual * 0.8,
            "daydream should badly underestimate PP: est {est} vs actual {actual}"
        );
    }
}
