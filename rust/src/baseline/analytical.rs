//! The analytical (heuristic) performance model of paper §2.3: computation
//! time = operation count / peak FLOPS, communication time = bytes /
//! bandwidth. No launch overheads, no efficiency curve, no contention, no
//! pipeline-bubble modeling beyond ideal dependency math.
//!
//! Intentionally optimistic — its gap to the ground truth is Fig. 3.

use crate::cluster::ClusterSpec;
use crate::cost::CostModel;
use crate::engine::GroundTruth;
use crate::model::ModelSpec;
use crate::partition::Partition;
use crate::schedule::PipelineSchedule;
use crate::strategy::{RankCoords, Strategy};
use crate::util::TimeUs;

/// Analytical iteration-time estimate for a configuration.
///
/// Ideal pipeline model: batch = (M + PP - 1) slots of the per-stage
/// fwd+bwd time (perfect overlap, zero queuing), plus ideal comm terms.
///
/// **Placement-aware (ISSUE 5).** On a heterogeneous fleet the estimate
/// prices each (stage, DP-replica) MP group at the *slowest* SKU among
/// its own members, resolved through the cluster's placement map, and
/// takes the max over replicas. That member's peak-rate ideal is a lower
/// bound on its simulated time, and the per-layer all-reduce barriers
/// make the group wait for it, so the estimate stays a true lower bound
/// on the simulated batch time *per candidate placement* — which is what
/// lets the search engine prune `Placement::Table` candidates soundly
/// (an all-A10 table is bounded at A10 speed, not fleet-fastest speed;
/// proof sketch in DESIGN.md §7). On a homogeneous fleet every group has
/// one kind and the estimate reduces to the pre-placement-aware model.
pub fn analytical_batch_time_us(
    model: &ModelSpec,
    part: &Partition,
    sched: &PipelineSchedule,
    cluster: &ClusterSpec,
) -> TimeUs {
    let cm = CostModel::default(); // only used for its analytical method
    let strategy = part.strategy;
    let m = sched.micro_batches as f64;
    let pp = strategy.pp as f64;
    let rank_dev = cluster.rank_to_device();

    // ideal ring all-reduce time (bytes / bw, no latency)
    let ring = |members: &[usize], bytes: f64| {
        let n = members.len() as f64;
        let link = cluster.group_link_class(members);
        2.0 * (n - 1.0) / n * bytes / (cluster.bw_gbs(link) * 1e3)
    };

    // per-replica ideal pipeline: (M + PP - 1) x the slowest stage slot,
    // where a slot is that (stage, replica) group's compute (priced at
    // the slowest member's SKU) plus its MP all-reduces (priced at the
    // group's own link class); the batch waits for every replica
    let pipeline = (0..strategy.dp)
        .map(|d| {
            let slot_max = (0..strategy.pp)
                .map(|s| {
                    let members: Vec<usize> = (0..strategy.mp)
                        .map(|mp| {
                            rank_dev[strategy.rank_of(RankCoords { mp, pp: s, dp: d })]
                        })
                        .collect();
                    // slowest member's ideal gates the barrier-stepped slot
                    let compute = members
                        .iter()
                        .map(|&dev| {
                            let spec = cluster.kind_spec(cluster.device_kind(dev));
                            part.stages[s]
                                .layers
                                .iter()
                                .map(|lw| {
                                    cm.analytical_latency_us(spec, lw.fwd.flops, lw.fwd.bytes)
                                        + cm.analytical_latency_us(
                                            spec, lw.bwd.flops, lw.bwd.bytes,
                                        )
                                })
                                .sum::<f64>()
                        })
                        .fold(0.0, f64::max);
                    let mp_comm: f64 = if strategy.mp > 1 {
                        part.stages[s]
                            .layers
                            .iter()
                            .map(|lw| {
                                let n = (lw.ar_count_fwd + lw.ar_count_bwd) as f64;
                                match &lw.mp_allreduce {
                                    Some(crate::events::CommEvent::AllReduce {
                                        bytes, ..
                                    }) => n * ring(&members, *bytes as f64),
                                    _ => 0.0,
                                }
                            })
                            .sum()
                    } else {
                        0.0
                    };
                    compute + mp_comm
                })
                .fold(0.0, f64::max);
            (m + pp - 1.0) * slot_max
        })
        .fold(0.0, f64::max);

    // activation transfers on the critical path: PP-1 hops
    let p2p: f64 = (0..strategy.pp.saturating_sub(1))
        .map(|s| {
            let bytes = part.stages[s].act_bytes as f64;
            let link = cluster.link_class(0, 1); // optimistic: intra
            bytes / (cluster.bw_gbs(link) * 1e3)
        })
        .sum::<f64>()
        * 2.0; // fwd + bwd

    // DP gradient all-reduce, ideal ring: the slowest lane's group gates
    // the stage barrier, each lane priced at its own group's link class
    let dp_comm = if strategy.dp > 1 {
        (0..strategy.pp)
            .map(|s| {
                let bytes = part.grad_bytes_per_rank[s] as f64;
                (0..strategy.mp)
                    .map(|mp| {
                        let members: Vec<usize> = strategy
                            .dp_group(strategy.rank_of(RankCoords { mp, pp: s, dp: 0 }))
                            .iter()
                            .map(|&r| rank_dev[r])
                            .collect();
                        ring(&members, bytes)
                    })
                    .fold(0.0, f64::max)
            })
            .fold(0.0, f64::max)
    } else {
        0.0
    };

    let _ = model;
    pipeline + p2p + dp_comm
}

/// Convenience: analytical estimate straight from a prepared ground truth.
pub fn analytical_from_gt(gt: &GroundTruth) -> TimeUs {
    analytical_batch_time_us(&gt.model, &gt.part, &gt.sched, &gt.cfg.cluster)
}

/// The analytical model's error against the ground truth, in percent
/// (the Fig. 3 bar for one strategy).
pub fn analytical_error_pct(gt: &GroundTruth, iters: usize) -> f64 {
    let actual = gt.mean_batch_time_us(iters);
    let est = analytical_from_gt(gt);
    crate::util::rel_err_pct(est, actual)
}

/// Used by Fig. 3's sanity tests.
pub fn strategy_of(mp: usize, pp: usize, dp: usize) -> Strategy {
    Strategy::new(mp, pp, dp)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::RunConfig;

    fn gt(mp: usize, pp: usize, dp: usize) -> GroundTruth {
        let cfg = RunConfig::new(
            "bert-large",
            Strategy::new(mp, pp, dp),
            ClusterSpec::a40_cluster(4, 4),
        );
        GroundTruth::prepare(&cfg).unwrap()
    }

    #[test]
    fn analytical_underestimates_ground_truth() {
        // the heuristic is optimistic by construction
        for (mp, pp, dp) in [(1, 1, 4), (2, 2, 2), (1, 4, 1)] {
            let g = gt(mp, pp, dp);
            let est = analytical_from_gt(&g);
            let actual = g.mean_batch_time_us(3);
            assert!(
                est < actual,
                "{mp}M{pp}P{dp}D: est {est} >= actual {actual}"
            );
        }
    }

    #[test]
    fn analytical_bound_is_placement_aware_on_mixed_fleets() {
        use crate::cluster::Placement;
        use crate::model::zoo;
        use crate::partition::partition;
        // 1M4P1D on a 2x4 mixed fleet (node 0 = A40, node 1 = A10): a
        // table packing the pipeline onto A10s must estimate strictly
        // slower than one packing it onto A40s — the tightened bound sees
        // each candidate's own placement, not the fleet's fastest SKU
        let model = zoo::bert_large();
        let s = Strategy::new(1, 4, 1);
        let sched = crate::schedule::dapple(4, 8);
        let est_on = |placement: Placement| {
            let c = crate::cluster::ClusterSpec::mixed_a40_a10(2, 4)
                .with_placement(placement);
            let part = partition(&model, &s, &c, 1);
            analytical_batch_time_us(&model, &part, &sched, &c)
        };
        let on_a40 = est_on(Placement::Table(vec![0, 1, 2, 3, 4, 5, 6, 7]));
        let on_a10 = est_on(Placement::Table(vec![4, 5, 6, 7, 0, 1, 2, 3]));
        assert!(
            on_a10 > on_a40 * 1.05,
            "all-A10 table ({on_a10}) must bound slower than all-A40 ({on_a40})"
        );
        // fast-first packs the 4 stages onto the A40 node: same estimate
        // as the explicit all-A40 table
        assert_eq!(est_on(Placement::FastFirst), on_a40);
    }

    #[test]
    fn analytical_error_in_fig3_band() {
        // Fig. 3: up to 40.4% error, 26.1% average. Our substrate differs,
        // but the error must be "tens of percent", not single digits.
        let errs: Vec<f64> = [(1, 1, 4), (2, 2, 2), (2, 1, 2), (1, 2, 2)]
            .iter()
            .map(|&(mp, pp, dp)| analytical_error_pct(&gt(mp, pp, dp), 3))
            .collect();
        let avg = crate::util::stats::mean(&errs);
        assert!(
            (10.0..60.0).contains(&avg),
            "analytical avg error {avg}% not in the tens-of-percent band ({errs:?})"
        );
    }
}
