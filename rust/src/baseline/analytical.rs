//! The analytical (heuristic) performance model of paper §2.3: computation
//! time = operation count / peak FLOPS, communication time = bytes /
//! bandwidth. No launch overheads, no efficiency curve, no contention, no
//! pipeline-bubble modeling beyond ideal dependency math.
//!
//! Intentionally optimistic — its gap to the ground truth is Fig. 3.

use crate::cluster::ClusterSpec;
use crate::cost::CostModel;
use crate::engine::GroundTruth;
use crate::model::ModelSpec;
use crate::partition::Partition;
use crate::schedule::PipelineSchedule;
use crate::strategy::Strategy;
use crate::util::TimeUs;

/// Analytical iteration-time estimate for a configuration.
///
/// Ideal pipeline model: batch = (M + PP - 1) slots of the per-stage
/// fwd+bwd time (perfect overlap, zero queuing), plus ideal comm terms.
pub fn analytical_batch_time_us(
    model: &ModelSpec,
    part: &Partition,
    sched: &PipelineSchedule,
    cluster: &ClusterSpec,
) -> TimeUs {
    let cm = CostModel::default(); // only used for its analytical method
    let strategy = part.strategy;
    // heterogeneous fleets price at the *fastest* SKU present: the
    // heuristic stays optimistic for any placement, which keeps the
    // search engine's pruning bound a true throughput upper bound
    let dev = cluster.fastest_spec();
    let m = sched.micro_batches as f64;
    let pp = strategy.pp as f64;

    // per-stage per-microbatch compute (fwd + bwd) at peak rate
    let stage_time: Vec<f64> = (0..strategy.pp)
        .map(|s| {
            part.stages[s]
                .layers
                .iter()
                .map(|lw| {
                    cm.analytical_latency_us(dev, lw.fwd.flops, lw.fwd.bytes)
                        + cm.analytical_latency_us(dev, lw.bwd.flops, lw.bwd.bytes)
                })
                .sum()
        })
        .collect();
    let slowest = stage_time.iter().copied().fold(0.0, f64::max);

    // MP all-reduce ideal time per stage (bytes / bw, no latency)
    let mp_comm: f64 = if strategy.mp > 1 {
        let link = cluster.rank_group_link_class(&strategy.mp_group(0));
        let bw = cluster.bw_gbs(link) * 1e3;
        part.stages
            .iter()
            .map(|st| {
                st.layers
                    .iter()
                    .map(|lw| {
                        let n = (lw.ar_count_fwd + lw.ar_count_bwd) as f64;
                        match &lw.mp_allreduce {
                            Some(crate::events::CommEvent::AllReduce { bytes, .. }) => {
                                n * 2.0 * (strategy.mp as f64 - 1.0)
                                    / strategy.mp as f64
                                    * *bytes as f64
                                    / bw
                            }
                            _ => 0.0,
                        }
                    })
                    .sum::<f64>()
            })
            .fold(0.0, f64::max)
    } else {
        0.0
    };

    // ideal pipeline fill: (M + PP - 1) x slowest stage slot
    let pipeline = (m + pp - 1.0) * (slowest + mp_comm);

    // activation transfers on the critical path: PP-1 hops
    let p2p: f64 = (0..strategy.pp.saturating_sub(1))
        .map(|s| {
            let bytes = part.stages[s].act_bytes as f64;
            let link = cluster.link_class(0, 1); // optimistic: intra
            bytes / (cluster.bw_gbs(link) * 1e3)
        })
        .sum::<f64>()
        * 2.0; // fwd + bwd

    // DP gradient all-reduce, ideal ring
    let dp_comm = if strategy.dp > 1 {
        let bytes = part
            .grad_bytes_per_rank
            .iter()
            .copied()
            .max()
            .unwrap_or(0) as f64;
        let link = cluster.rank_group_link_class(&strategy.dp_group(0));
        2.0 * (strategy.dp as f64 - 1.0) / strategy.dp as f64 * bytes
            / (cluster.bw_gbs(link) * 1e3)
    } else {
        0.0
    };

    let _ = model;
    pipeline + p2p + dp_comm
}

/// Convenience: analytical estimate straight from a prepared ground truth.
pub fn analytical_from_gt(gt: &GroundTruth) -> TimeUs {
    analytical_batch_time_us(&gt.model, &gt.part, &gt.sched, &gt.cfg.cluster)
}

/// The analytical model's error against the ground truth, in percent
/// (the Fig. 3 bar for one strategy).
pub fn analytical_error_pct(gt: &GroundTruth, iters: usize) -> f64 {
    let actual = gt.mean_batch_time_us(iters);
    let est = analytical_from_gt(gt);
    crate::util::rel_err_pct(est, actual)
}

/// Used by Fig. 3's sanity tests.
pub fn strategy_of(mp: usize, pp: usize, dp: usize) -> Strategy {
    Strategy::new(mp, pp, dp)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::RunConfig;

    fn gt(mp: usize, pp: usize, dp: usize) -> GroundTruth {
        let cfg = RunConfig::new(
            "bert-large",
            Strategy::new(mp, pp, dp),
            ClusterSpec::a40_cluster(4, 4),
        );
        GroundTruth::prepare(&cfg).unwrap()
    }

    #[test]
    fn analytical_underestimates_ground_truth() {
        // the heuristic is optimistic by construction
        for (mp, pp, dp) in [(1, 1, 4), (2, 2, 2), (1, 4, 1)] {
            let g = gt(mp, pp, dp);
            let est = analytical_from_gt(&g);
            let actual = g.mean_batch_time_us(3);
            assert!(
                est < actual,
                "{mp}M{pp}P{dp}D: est {est} >= actual {actual}"
            );
        }
    }

    #[test]
    fn analytical_error_in_fig3_band() {
        // Fig. 3: up to 40.4% error, 26.1% average. Our substrate differs,
        // but the error must be "tens of percent", not single digits.
        let errs: Vec<f64> = [(1, 1, 4), (2, 2, 2), (2, 1, 2), (1, 2, 2)]
            .iter()
            .map(|&(mp, pp, dp)| analytical_error_pct(&gt(mp, pp, dp), 3))
            .collect();
        let avg = crate::util::stats::mean(&errs);
        assert!(
            (10.0..60.0).contains(&avg),
            "analytical avg error {avg}% not in the tens-of-percent band ({errs:?})"
        );
    }
}
