//! Baselines the paper compares against.
//!
//! * [`analytical`] — the DistIR/AccPar-style heuristic (§2.3): time =
//!   FLOPs / peak + bytes / bandwidth, no efficiency losses, no overheads.
//!   Reproduces Fig. 3's 26-40% errors.
//! * [`daydream`] — the Daydream/dPRO-style replayer (§2.4): profiled
//!   per-op times replayed under the "highly sequential" assumption, which
//!   is sound for pure data parallelism but cannot express pipeline
//!   interleaving or tensor-MP barriers.

pub mod analytical;
pub mod daydream;
