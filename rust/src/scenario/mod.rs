//! Unhappy-path scenario engine: deterministic faults, stragglers, link
//! degradation and elastic-resize what-ifs (ISSUE 7).
//!
//! A [`ScenarioSpec`] is a JSON-round-trippable description of everything
//! that can go wrong during a training run:
//!
//! * **persistent stragglers** — a per-device multiplicative compute
//!   slowdown for the whole run ("node 3 runs 20% slow" = factor 1.2);
//! * **straggler episodes** — the same slowdown over a simulated-time
//!   window only (thermal throttling, a noisy neighbour);
//! * **link-degradation episodes** — bandwidth / latency multipliers on
//!   one [`LinkClass`] over a time window (a flapping NIC, an oversubscribed
//!   spine);
//! * **device failures** — a crash at `at_us` with checkpoint/restart
//!   accounting (work since the last checkpoint is lost, the restart costs
//!   `restart_us`);
//! * **elastic DP resize** — drop or add data-parallel replicas mid-run,
//!   paying a re-shard cost and re-balancing the per-replica batch.
//!
//! **Determinism contract.** A scenario perturbs the simulation only
//! through (a) pure multiplicative factors resolved against *unskewed
//! simulated time* and (b) RNG forks salted by (scenario, rank) — see
//! [`ScenarioSpec::salt`]. The empty scenario is bit-identical to running
//! without one (every adjustment is gated on `!is_empty()`), and any
//! non-empty scenario is bit-identical for any thread or worker count:
//! the factors are pure functions, and the per-rank scenario RNG streams
//! are consumed in program order (the DES scheduler's wake order is
//! logical, not temporal). See DESIGN.md §8.
//!
//! **Time-window resolution.** An episode `[start_us, end_us)` applies to
//! a span iff the span *starts* inside the window, in unskewed simulated
//! time (clock skew shifts recorded timestamps only, never the simulation
//! clock — DESIGN.md §2). Spans are not split at window edges: the window
//! granularity is one kernel / one transfer, which is the resolution the
//! engine models anyway.
//!
//! **What the DES simulates vs what is accounted analytically.** Straggler
//! factors and link episodes perturb the discrete-event executor span by
//! span. Failures and elastic resize are *accounting* events: re-simulating
//! a world-size change mid-iteration would change the partition itself, so
//! they compose analytically on top of the degraded batch time
//! ([`ScenarioSpec::compose_batch_us`]) — lost work + restart cost appear
//! exactly once, and a resize rescales the per-replica load and pays the
//! re-shard cost once.

use crate::cluster::LinkClass;
use crate::config::Json;

/// A persistent per-device multiplicative compute slowdown.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Straggler {
    /// Physical device index (validated against the cluster at admission).
    pub device: usize,
    /// Compute-time multiplier (> 0; 1.2 = 20% slower).
    pub factor: f64,
}

/// A transient per-device compute slowdown over a simulated-time window.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct StragglerEpisode {
    pub device: usize,
    /// Compute-time multiplier while the episode is active (> 0).
    pub factor: f64,
    /// Window start, unskewed simulated µs (inclusive).
    pub start_us: f64,
    /// Window end, unskewed simulated µs (exclusive; > `start_us`).
    pub end_us: f64,
}

/// A link-class-wide degradation over a simulated-time window.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct LinkEpisode {
    /// Which fabric tier degrades (`"intra"` | `"inter"`).
    pub link: LinkClass,
    /// Multiplier on the bandwidth-proportional part of a transfer's time
    /// (> 0; 2.0 = half the bandwidth).
    pub bw_factor: f64,
    /// Multiplier on the link's base latency (> 0).
    pub lat_factor: f64,
    pub start_us: f64,
    pub end_us: f64,
}

/// A device crash with checkpoint/restart accounting.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Failure {
    pub device: usize,
    /// Crash time, µs into the run.
    pub at_us: f64,
    /// Checkpoint cadence, µs. Work since the last checkpoint is lost:
    /// `at_us % checkpoint_interval_us` — or all of `at_us` when 0 (no
    /// checkpointing at all).
    pub checkpoint_interval_us: f64,
    /// Cost to restart and rejoin, µs.
    pub restart_us: f64,
}

/// An elastic data-parallel resize: drop (`dp_delta < 0`) or add
/// (`dp_delta > 0`) replicas mid-run.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Resize {
    /// Replica-count change (non-zero).
    pub dp_delta: i64,
    /// One-time re-shard / re-materialization cost, µs.
    pub reshard_us: f64,
}

/// A full unhappy-path scenario. `Default` is the empty scenario, which
/// is bit-identical to running without one.
#[derive(Debug, Clone, PartialEq, Default)]
pub struct ScenarioSpec {
    pub stragglers: Vec<Straggler>,
    pub straggler_episodes: Vec<StragglerEpisode>,
    pub link_episodes: Vec<LinkEpisode>,
    pub failures: Vec<Failure>,
    pub resize: Option<Resize>,
    /// Extra per-rank multiplicative jitter sigma drawn from the
    /// (scenario, rank)-salted RNG forks (0 = none).
    pub sigma: f64,
}

/// Time-weighted effective degradation factors over a horizon — the
/// analytical counterpart of the span-by-span DES perturbation, so sweeps
/// stay cheap (`distsim::predict` runs one extra walk, not a simulation
/// per episode). Built by [`ScenarioSpec::degrade_over`].
#[derive(Debug, Clone, PartialEq)]
pub struct Degrade {
    /// Per-device effective compute multiplier.
    pub comp: Vec<f64>,
    /// Per-link-class effective bandwidth-time multiplier
    /// (index by [`link_idx`]).
    pub bw: [f64; 2],
    /// Per-link-class effective latency multiplier.
    pub lat: [f64; 2],
}

/// Dense index for a [`LinkClass`] (intra = 0, inter = 1).
pub fn link_idx(link: LinkClass) -> usize {
    match link {
        LinkClass::Intra => 0,
        LinkClass::Inter => 1,
    }
}

impl Degrade {
    /// Effective compute multiplier for a device (1.0 out of range).
    pub fn comp_factor(&self, device: usize) -> f64 {
        self.comp.get(device).copied().unwrap_or(1.0)
    }

    /// Degrade one transfer duration: the bandwidth-proportional part is
    /// multiplied, the extra latency is added on top.
    pub fn link_dur(&self, link: LinkClass, dur: f64, base_lat_us: f64) -> f64 {
        let i = link_idx(link);
        dur * self.bw[i] + (self.lat[i] - 1.0) * base_lat_us
    }

    /// Is this degrade a no-op (all factors exactly 1)?
    pub fn is_identity(&self) -> bool {
        self.comp.iter().all(|&f| f == 1.0)
            && self.bw == [1.0, 1.0]
            && self.lat == [1.0, 1.0]
    }
}

fn overlap_weight(start: f64, end: f64, horizon: f64) -> f64 {
    if horizon <= 0.0 {
        return 0.0;
    }
    let lo = start.max(0.0);
    let hi = end.min(horizon);
    ((hi - lo).max(0.0)) / horizon
}

impl ScenarioSpec {
    /// No perturbation at all: running with this spec is bit-identical to
    /// running without a scenario.
    pub fn is_empty(&self) -> bool {
        self.stragglers.is_empty()
            && self.straggler_episodes.is_empty()
            && self.link_episodes.is_empty()
            && self.failures.is_empty()
            && self.resize.is_none()
            && self.sigma == 0.0
    }

    /// Episodes this scenario carries (the service's `episodes_simulated`
    /// counter counts these per scenario request).
    pub fn episode_count(&self) -> usize {
        self.straggler_episodes.len() + self.link_episodes.len() + self.failures.len()
    }

    /// Deterministic salt for (scenario, rank) RNG forks: FNV-1a over the
    /// canonical JSON (sorted keys, shortest floats), so equal scenarios
    /// fork equal streams on every machine and any textual difference
    /// separates them.
    pub fn salt(&self) -> u64 {
        fnv1a64(self.to_json().to_string().as_bytes())
    }

    /// Persistent compute multiplier for a device (stragglers compose
    /// multiplicatively when several name the same device).
    pub fn comp_factor(&self, device: usize) -> f64 {
        self.stragglers
            .iter()
            .filter(|s| s.device == device)
            .fold(1.0, |f, s| f * s.factor)
    }

    /// Compute multiplier for a span starting at unskewed simulated time
    /// `t` on `device`: persistent stragglers times every episode whose
    /// window `[start_us, end_us)` contains `t`.
    pub fn comp_factor_at(&self, device: usize, t: f64) -> f64 {
        let mut f = self.comp_factor(device);
        for e in &self.straggler_episodes {
            if e.device == device && t >= e.start_us && t < e.end_us {
                f *= e.factor;
            }
        }
        f
    }

    /// Degrade one transfer duration for a span starting at `t`:
    /// `dur * bw_factor + (lat_factor - 1) * base_lat_us` over the
    /// episodes active on `link` at `t`.
    pub fn link_dur_at(&self, link: LinkClass, t: f64, dur: f64, base_lat_us: f64) -> f64 {
        let mut bw = 1.0;
        let mut lat = 1.0;
        for e in &self.link_episodes {
            if e.link == link && t >= e.start_us && t < e.end_us {
                bw *= e.bw_factor;
                lat *= e.lat_factor;
            }
        }
        dur * bw + (lat - 1.0) * base_lat_us
    }

    /// Time-weighted effective factors over `[0, horizon_us)` for
    /// `devices` devices: each episode contributes `(factor - 1)` scaled
    /// by its fractional overlap with the horizon, on top of persistent
    /// factors. With `horizon_us <= 0` only persistent factors apply.
    pub fn degrade_over(&self, devices: usize, horizon_us: f64) -> Degrade {
        let mut comp: Vec<f64> = (0..devices).map(|d| self.comp_factor(d)).collect();
        for e in &self.straggler_episodes {
            if e.device < devices {
                comp[e.device] *=
                    1.0 + (e.factor - 1.0) * overlap_weight(e.start_us, e.end_us, horizon_us);
            }
        }
        let mut bw = [1.0f64; 2];
        let mut lat = [1.0f64; 2];
        for e in &self.link_episodes {
            let i = link_idx(e.link);
            let w = overlap_weight(e.start_us, e.end_us, horizon_us);
            bw[i] *= 1.0 + (e.bw_factor - 1.0) * w;
            lat[i] *= 1.0 + (e.lat_factor - 1.0) * w;
        }
        Degrade { comp, bw, lat }
    }

    /// Total failure accounting: for each failure, the work lost since the
    /// last checkpoint plus the restart cost. Appears exactly once in a
    /// scenario batch time ([`ScenarioSpec::compose_batch_us`]).
    pub fn restart_penalty_us(&self) -> f64 {
        self.failures
            .iter()
            .map(|f| {
                let lost = if f.checkpoint_interval_us > 0.0 {
                    f.at_us % f.checkpoint_interval_us
                } else {
                    f.at_us
                };
                lost + f.restart_us
            })
            .sum()
    }

    /// Data-parallel width after the elastic resize; `None` when the
    /// resize drops the last replica (the candidate is unreachable under
    /// this scenario).
    pub fn resized_dp(&self, dp: usize) -> Option<usize> {
        match self.resize {
            None => Some(dp),
            Some(r) => {
                let new = dp as i64 + r.dp_delta;
                if new >= 1 {
                    Some(new as usize)
                } else {
                    None
                }
            }
        }
    }

    /// Per-replica load multiplier after the resize: the global batch is
    /// re-balanced over the surviving replicas, so each one carries
    /// `ceil(global_batch / new_dp)` sequences instead of
    /// `global_batch / dp`. 1.0 without a resize; `None` when unreachable.
    pub fn load_ratio(&self, dp: usize, global_batch: usize) -> Option<f64> {
        let new_dp = self.resized_dp(dp)?;
        if new_dp == dp {
            return Some(1.0);
        }
        let per_replica = global_batch as f64 / dp as f64;
        let new_per = (global_batch as f64 / new_dp as f64).ceil();
        Some(new_per / per_replica)
    }

    /// Compose the full scenario batch time from the degraded simulated
    /// batch time: rescale for the elastic resize's per-replica load, then
    /// add the one-time re-shard cost and the failure restart penalty.
    /// `None` when the resize makes the candidate unreachable.
    pub fn compose_batch_us(
        &self,
        degraded_us: f64,
        dp: usize,
        global_batch: usize,
    ) -> Option<f64> {
        let ratio = self.load_ratio(dp, global_batch)?;
        let reshard = self.resize.map_or(0.0, |r| r.reshard_us);
        Some(degraded_us * ratio + reshard + self.restart_penalty_us())
    }

    /// Every device index this scenario names is on the cluster.
    pub fn validate_devices(&self, devices: usize) -> anyhow::Result<()> {
        let check = |d: usize, what: &str| {
            if d >= devices {
                anyhow::bail!("scenario: {what} device {d} out of range (cluster has {devices})")
            }
            Ok(())
        };
        for s in &self.stragglers {
            check(s.device, "straggler")?;
        }
        for e in &self.straggler_episodes {
            check(e.device, "straggler episode")?;
        }
        for f in &self.failures {
            check(f.device, "failure")?;
        }
        Ok(())
    }

    // -- JSON --------------------------------------------------------------

    /// Canonical JSON: empty collections and defaults are omitted, so the
    /// empty scenario serializes to `{}` and [`ScenarioSpec::salt`] is a
    /// pure function of the semantic content.
    pub fn to_json(&self) -> Json {
        let mut pairs: Vec<(&str, Json)> = Vec::new();
        if !self.stragglers.is_empty() {
            pairs.push((
                "stragglers",
                Json::Arr(
                    self.stragglers
                        .iter()
                        .map(|s| {
                            Json::obj(vec![
                                ("device", Json::num(s.device as f64)),
                                ("factor", Json::num(s.factor)),
                            ])
                        })
                        .collect(),
                ),
            ));
        }
        if !self.straggler_episodes.is_empty() {
            pairs.push((
                "straggler_episodes",
                Json::Arr(
                    self.straggler_episodes
                        .iter()
                        .map(|e| {
                            Json::obj(vec![
                                ("device", Json::num(e.device as f64)),
                                ("factor", Json::num(e.factor)),
                                ("start_us", Json::num(e.start_us)),
                                ("end_us", Json::num(e.end_us)),
                            ])
                        })
                        .collect(),
                ),
            ));
        }
        if !self.link_episodes.is_empty() {
            pairs.push((
                "link_episodes",
                Json::Arr(
                    self.link_episodes
                        .iter()
                        .map(|e| {
                            Json::obj(vec![
                                ("link", Json::str(e.link.name())),
                                ("bw_factor", Json::num(e.bw_factor)),
                                ("lat_factor", Json::num(e.lat_factor)),
                                ("start_us", Json::num(e.start_us)),
                                ("end_us", Json::num(e.end_us)),
                            ])
                        })
                        .collect(),
                ),
            ));
        }
        if !self.failures.is_empty() {
            pairs.push((
                "failures",
                Json::Arr(
                    self.failures
                        .iter()
                        .map(|f| {
                            Json::obj(vec![
                                ("device", Json::num(f.device as f64)),
                                ("at_us", Json::num(f.at_us)),
                                (
                                    "checkpoint_interval_us",
                                    Json::num(f.checkpoint_interval_us),
                                ),
                                ("restart_us", Json::num(f.restart_us)),
                            ])
                        })
                        .collect(),
                ),
            ));
        }
        if let Some(r) = self.resize {
            pairs.push((
                "resize",
                Json::obj(vec![
                    ("dp_delta", Json::num(r.dp_delta as f64)),
                    ("reshard_us", Json::num(r.reshard_us)),
                ]),
            ));
        }
        if self.sigma != 0.0 {
            pairs.push(("sigma", Json::num(self.sigma)));
        }
        Json::obj(pairs)
    }

    /// Strict parse: unknown keys (at every level) and out-of-domain
    /// values are errors, so a typo'd what-if request fails loudly instead
    /// of silently simulating the happy path.
    pub fn from_json(j: &Json) -> anyhow::Result<ScenarioSpec> {
        let obj = j
            .as_obj()
            .ok_or_else(|| anyhow::anyhow!("scenario must be an object"))?;
        let mut spec = ScenarioSpec::default();
        for (k, v) in obj {
            match k.as_str() {
                "stragglers" => {
                    for e in arr(v, "stragglers")? {
                        let m = entry(e, "stragglers", &["device", "factor"])?;
                        spec.stragglers.push(Straggler {
                            device: usize_field(m, "stragglers", "device")?,
                            factor: pos_field(m, "stragglers", "factor")?,
                        });
                    }
                }
                "straggler_episodes" => {
                    for e in arr(v, "straggler_episodes")? {
                        let m = entry(
                            e,
                            "straggler_episodes",
                            &["device", "factor", "start_us", "end_us"],
                        )?;
                        let ep = StragglerEpisode {
                            device: usize_field(m, "straggler_episodes", "device")?,
                            factor: pos_field(m, "straggler_episodes", "factor")?,
                            start_us: nonneg_field(m, "straggler_episodes", "start_us")?,
                            end_us: nonneg_field(m, "straggler_episodes", "end_us")?,
                        };
                        window(ep.start_us, ep.end_us, "straggler_episodes")?;
                        spec.straggler_episodes.push(ep);
                    }
                }
                "link_episodes" => {
                    for e in arr(v, "link_episodes")? {
                        let m = entry(
                            e,
                            "link_episodes",
                            &["link", "bw_factor", "lat_factor", "start_us", "end_us"],
                        )?;
                        let name = m
                            .get("link")
                            .and_then(Json::as_str)
                            .ok_or_else(|| {
                                anyhow::anyhow!("scenario: link_episodes entry needs a 'link' string")
                            })?;
                        let ep = LinkEpisode {
                            link: LinkClass::parse(name).map_err(|_| {
                                anyhow::anyhow!(
                                    "scenario: unknown link class '{name}' (want intra|inter)"
                                )
                            })?,
                            bw_factor: pos_field(m, "link_episodes", "bw_factor")?,
                            lat_factor: pos_field(m, "link_episodes", "lat_factor")?,
                            start_us: nonneg_field(m, "link_episodes", "start_us")?,
                            end_us: nonneg_field(m, "link_episodes", "end_us")?,
                        };
                        window(ep.start_us, ep.end_us, "link_episodes")?;
                        spec.link_episodes.push(ep);
                    }
                }
                "failures" => {
                    for e in arr(v, "failures")? {
                        let m = entry(
                            e,
                            "failures",
                            &["device", "at_us", "checkpoint_interval_us", "restart_us"],
                        )?;
                        spec.failures.push(Failure {
                            device: usize_field(m, "failures", "device")?,
                            at_us: nonneg_field(m, "failures", "at_us")?,
                            checkpoint_interval_us: nonneg_field(
                                m,
                                "failures",
                                "checkpoint_interval_us",
                            )?,
                            restart_us: nonneg_field(m, "failures", "restart_us")?,
                        });
                    }
                }
                "resize" => {
                    let m = entry(v, "resize", &["dp_delta", "reshard_us"])?;
                    let delta = m
                        .get("dp_delta")
                        .and_then(Json::as_f64)
                        .ok_or_else(|| {
                            anyhow::anyhow!("scenario: resize needs a numeric 'dp_delta'")
                        })?;
                    if delta == 0.0 || delta.fract() != 0.0 {
                        anyhow::bail!("scenario: resize dp_delta must be a non-zero integer");
                    }
                    spec.resize = Some(Resize {
                        dp_delta: delta as i64,
                        reshard_us: nonneg_field(m, "resize", "reshard_us")?,
                    });
                }
                "sigma" => {
                    let s = v
                        .as_f64()
                        .ok_or_else(|| anyhow::anyhow!("scenario: sigma must be a number"))?;
                    if !(s >= 0.0) {
                        anyhow::bail!("scenario: sigma must be >= 0");
                    }
                    spec.sigma = s;
                }
                other => anyhow::bail!(
                    "scenario: unknown key '{other}' (want stragglers, straggler_episodes, \
                     link_episodes, failures, resize, sigma)"
                ),
            }
        }
        Ok(spec)
    }
}

fn arr<'j>(v: &'j Json, what: &str) -> anyhow::Result<&'j [Json]> {
    v.as_arr()
        .ok_or_else(|| anyhow::anyhow!("scenario: {what} must be an array"))
}

fn entry<'j>(
    v: &'j Json,
    what: &str,
    allowed: &[&str],
) -> anyhow::Result<&'j std::collections::BTreeMap<String, Json>> {
    let m = v
        .as_obj()
        .ok_or_else(|| anyhow::anyhow!("scenario: {what} entries must be objects"))?;
    for k in m.keys() {
        if !allowed.contains(&k.as_str()) {
            anyhow::bail!(
                "scenario: unknown key '{k}' in {what} entry (want {})",
                allowed.join(", ")
            );
        }
    }
    Ok(m)
}

fn num_field(
    m: &std::collections::BTreeMap<String, Json>,
    what: &str,
    key: &str,
) -> anyhow::Result<f64> {
    m.get(key)
        .and_then(Json::as_f64)
        .ok_or_else(|| anyhow::anyhow!("scenario: {what} entry needs a numeric '{key}'"))
}

fn usize_field(
    m: &std::collections::BTreeMap<String, Json>,
    what: &str,
    key: &str,
) -> anyhow::Result<usize> {
    let v = num_field(m, what, key)?;
    if v < 0.0 || v.fract() != 0.0 {
        anyhow::bail!("scenario: {what} '{key}' must be a non-negative integer");
    }
    Ok(v as usize)
}

fn pos_field(
    m: &std::collections::BTreeMap<String, Json>,
    what: &str,
    key: &str,
) -> anyhow::Result<f64> {
    let v = num_field(m, what, key)?;
    if !(v > 0.0) {
        anyhow::bail!("scenario: {what} '{key}' must be > 0");
    }
    Ok(v)
}

fn nonneg_field(
    m: &std::collections::BTreeMap<String, Json>,
    what: &str,
    key: &str,
) -> anyhow::Result<f64> {
    let v = num_field(m, what, key)?;
    if !(v >= 0.0) {
        anyhow::bail!("scenario: {what} '{key}' must be >= 0");
    }
    Ok(v)
}

fn window(start: f64, end: f64, what: &str) -> anyhow::Result<()> {
    if end <= start {
        anyhow::bail!("scenario: {what} window must have end_us > start_us");
    }
    Ok(())
}

/// FNV-1a, 64-bit — same construction the cache fingerprint uses; local
/// because the scenario salt must not depend on the cache module.
fn fnv1a64(bytes: &[u8]) -> u64 {
    let mut h: u64 = 0xCBF2_9CE4_8422_2325;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x0000_0100_0000_01B3);
    }
    h
}

#[cfg(test)]
mod tests {
    use super::*;

    fn demo() -> ScenarioSpec {
        ScenarioSpec {
            stragglers: vec![Straggler { device: 3, factor: 1.2 }],
            straggler_episodes: vec![StragglerEpisode {
                device: 1,
                factor: 2.0,
                start_us: 0.0,
                end_us: 500.0,
            }],
            link_episodes: vec![LinkEpisode {
                link: LinkClass::Inter,
                bw_factor: 2.0,
                lat_factor: 1.5,
                start_us: 100.0,
                end_us: 600.0,
            }],
            failures: vec![Failure {
                device: 0,
                at_us: 1700.0,
                checkpoint_interval_us: 500.0,
                restart_us: 300.0,
            }],
            resize: Some(Resize { dp_delta: -1, reshard_us: 250.0 }),
            sigma: 0.05,
        }
    }

    #[test]
    fn empty_spec_is_empty_and_serializes_to_braces() {
        let spec = ScenarioSpec::default();
        assert!(spec.is_empty());
        assert_eq!(spec.to_json().to_string(), "{}");
        assert_eq!(ScenarioSpec::from_json(&Json::parse("{}").unwrap()).unwrap(), spec);
    }

    #[test]
    fn full_spec_roundtrips_through_json() {
        let spec = demo();
        let text = spec.to_json().to_string();
        let back = ScenarioSpec::from_json(&Json::parse(&text).unwrap()).unwrap();
        assert_eq!(spec, back);
        // canonical: re-serialization is byte-identical
        assert_eq!(back.to_json().to_string(), text);
    }

    #[test]
    fn strict_parse_rejects_bad_input() {
        for bad in [
            r#"{"nope":1}"#,
            r#"{"stragglers":[{"device":0,"factor":1.2,"extra":1}]}"#,
            r#"{"stragglers":[{"device":0,"factor":0}]}"#,
            r#"{"stragglers":[{"device":-1,"factor":1.2}]}"#,
            r#"{"straggler_episodes":[{"device":0,"factor":2,"start_us":5,"end_us":5}]}"#,
            r#"{"link_episodes":[{"link":"warp","bw_factor":2,"lat_factor":1,"start_us":0,"end_us":1}]}"#,
            r#"{"resize":{"dp_delta":0,"reshard_us":0}}"#,
            r#"{"resize":{"dp_delta":1.5,"reshard_us":0}}"#,
            r#"{"sigma":-0.1}"#,
            r#"{"failures":[{"device":0,"at_us":-1,"checkpoint_interval_us":0,"restart_us":0}]}"#,
            r#"[1,2]"#,
        ] {
            let j = Json::parse(bad).unwrap();
            assert!(ScenarioSpec::from_json(&j).is_err(), "accepted: {bad}");
        }
    }

    #[test]
    fn salt_separates_scenarios_and_is_stable() {
        let a = demo();
        let mut b = demo();
        b.stragglers[0].factor = 1.3;
        assert_ne!(a.salt(), b.salt());
        assert_eq!(a.salt(), demo().salt());
    }

    #[test]
    fn factors_resolve_against_time_windows() {
        let spec = demo();
        // persistent straggler on device 3, always on
        assert!((spec.comp_factor_at(3, 1e9) - 1.2).abs() < 1e-12);
        // transient on device 1: active at 0, inactive at end (exclusive)
        assert_eq!(spec.comp_factor_at(1, 0.0), 2.0);
        assert_eq!(spec.comp_factor_at(1, 499.9), 2.0);
        assert_eq!(spec.comp_factor_at(1, 500.0), 1.0);
        // link episode: inside the window bw doubles + latency x1.5
        let d = spec.link_dur_at(LinkClass::Inter, 200.0, 10.0, 4.0);
        assert!((d - (10.0 * 2.0 + 0.5 * 4.0)).abs() < 1e-12);
        assert_eq!(spec.link_dur_at(LinkClass::Inter, 700.0, 10.0, 4.0), 10.0);
        assert_eq!(spec.link_dur_at(LinkClass::Intra, 200.0, 10.0, 4.0), 10.0);
    }

    #[test]
    fn degrade_over_weights_episodes_by_overlap() {
        let spec = demo();
        // horizon 1000: device-1 episode covers [0,500) = half the run
        let deg = spec.degrade_over(4, 1000.0);
        assert!((deg.comp_factor(1) - 1.5).abs() < 1e-12);
        assert!((deg.comp_factor(3) - 1.2).abs() < 1e-12);
        assert_eq!(deg.comp_factor(2), 1.0);
        // inter link: [100,600) = half the run, bw 1.5x, lat 1.25x
        assert!((deg.bw[link_idx(LinkClass::Inter)] - 1.5).abs() < 1e-12);
        assert!((deg.lat[link_idx(LinkClass::Inter)] - 1.25).abs() < 1e-12);
        assert_eq!(deg.bw[link_idx(LinkClass::Intra)], 1.0);
        assert!(!deg.is_identity());
        assert!(ScenarioSpec::default().degrade_over(4, 1000.0).is_identity());
    }

    #[test]
    fn restart_penalty_counts_lost_work_and_restart_once() {
        let spec = demo();
        // crash at 1700 with checkpoints every 500: 200 lost + 300 restart
        assert!((spec.restart_penalty_us() - 500.0).abs() < 1e-12);
        // no checkpointing: everything since the start is lost
        let no_ckpt = ScenarioSpec {
            failures: vec![Failure {
                device: 0,
                at_us: 1700.0,
                checkpoint_interval_us: 0.0,
                restart_us: 300.0,
            }],
            ..ScenarioSpec::default()
        };
        assert!((no_ckpt.restart_penalty_us() - 2000.0).abs() < 1e-12);
    }

    #[test]
    fn resize_rebalances_load_and_can_be_unreachable() {
        let spec = demo(); // dp_delta -1
        assert_eq!(spec.resized_dp(2), Some(1));
        assert_eq!(spec.resized_dp(1), None);
        // dp 2 -> 1 on batch 16: 8 -> 16 sequences per replica
        assert_eq!(spec.load_ratio(2, 16), Some(2.0));
        assert_eq!(spec.load_ratio(1, 16), None);
        // compose: degraded 1000us doubles, + reshard 250 + restart 500
        let total = spec.compose_batch_us(1000.0, 2, 16).unwrap();
        assert!((total - (2000.0 + 250.0 + 500.0)).abs() < 1e-9);
        assert_eq!(spec.compose_batch_us(1000.0, 1, 16), None);
        // empty scenario composes to the input
        assert_eq!(
            ScenarioSpec::default().compose_batch_us(1234.5, 4, 16),
            Some(1234.5)
        );
    }

    #[test]
    fn device_validation_checks_every_list() {
        let spec = demo();
        assert!(spec.validate_devices(4).is_ok());
        assert!(spec.validate_devices(3).is_err()); // straggler on device 3
    }
}
