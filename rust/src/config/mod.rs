//! Configuration layer: JSON substrate plus typed run configuration.
//!
//! A [`RunConfig`] fully describes one simulation: which model, which
//! hybrid strategy, the cluster, micro-batching and noise parameters. It
//! round-trips through JSON so experiment sweeps and the CLI share one
//! format.

pub mod json;

pub use json::Json;

use crate::cluster::ClusterSpec;
use crate::strategy::Strategy;

/// One simulation run, fully specified.
#[derive(Debug, Clone, PartialEq)]
pub struct RunConfig {
    /// Model zoo name, e.g. "bert-large".
    pub model: String,
    /// Hybrid strategy, e.g. 2M4P2D.
    pub strategy: Strategy,
    /// Number of micro-batches per global batch (pipeline granularity).
    pub micro_batches: usize,
    /// Per-device micro-batch size (sequences).
    pub micro_batch_size: usize,
    /// Pipeline schedule: "gpipe" | "dapple" | "naive".
    pub schedule: String,
    /// Cluster description.
    pub cluster: ClusterSpec,
    /// Ground-truth engine noise: multiplicative compute jitter sigma.
    pub jitter_sigma: f64,
    /// Ground-truth per-device clock skew sigma (us).
    pub clock_skew_us: f64,
    /// RNG seed for the ground-truth engine.
    pub seed: u64,
    /// Iterations to average when profiling events.
    pub profile_iters: usize,
}

impl RunConfig {
    pub fn new(model: &str, strategy: Strategy, cluster: ClusterSpec) -> Self {
        RunConfig {
            model: model.to_string(),
            strategy,
            micro_batches: 4,
            micro_batch_size: 4,
            schedule: "dapple".to_string(),
            cluster,
            jitter_sigma: 0.02,
            clock_skew_us: 20.0,
            seed: 42,
            profile_iters: 100,
        }
    }

    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("model", Json::str(&self.model)),
            ("strategy", Json::str(self.strategy.notation())),
            ("micro_batches", Json::num(self.micro_batches as f64)),
            (
                "micro_batch_size",
                Json::num(self.micro_batch_size as f64),
            ),
            ("schedule", Json::str(&self.schedule)),
            ("cluster", self.cluster.to_json()),
            ("jitter_sigma", Json::num(self.jitter_sigma)),
            ("clock_skew_us", Json::num(self.clock_skew_us)),
            ("seed", Json::num(self.seed as f64)),
            ("profile_iters", Json::num(self.profile_iters as f64)),
        ])
    }

    pub fn from_json(j: &Json) -> anyhow::Result<Self> {
        let get = |k: &str| {
            j.get(k)
                .ok_or_else(|| anyhow::anyhow!("config missing key '{k}'"))
        };
        Ok(RunConfig {
            model: get("model")?
                .as_str()
                .ok_or_else(|| anyhow::anyhow!("model must be a string"))?
                .to_string(),
            strategy: Strategy::parse(
                get("strategy")?
                    .as_str()
                    .ok_or_else(|| anyhow::anyhow!("strategy must be a string"))?,
            )?,
            micro_batches: get("micro_batches")?.as_usize().unwrap_or(4),
            micro_batch_size: get("micro_batch_size")?.as_usize().unwrap_or(4),
            schedule: get("schedule")?.as_str().unwrap_or("dapple").to_string(),
            cluster: ClusterSpec::from_json(get("cluster")?)?,
            jitter_sigma: get("jitter_sigma")?.as_f64().unwrap_or(0.02),
            clock_skew_us: get("clock_skew_us")?.as_f64().unwrap_or(20.0),
            seed: get("seed")?.as_u64().unwrap_or(42),
            profile_iters: get("profile_iters")?.as_usize().unwrap_or(100),
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::ClusterSpec;

    #[test]
    fn run_config_roundtrips_through_json() {
        let cfg = RunConfig::new(
            "bert-large",
            Strategy::new(2, 2, 4),
            ClusterSpec::a40_cluster(4, 4),
        );
        let j = cfg.to_json();
        let back = RunConfig::from_json(&Json::parse(&j.to_string()).unwrap()).unwrap();
        assert_eq!(cfg, back);
    }

    #[test]
    fn from_json_rejects_missing_keys() {
        let j = Json::parse(r#"{"model":"bert-large"}"#).unwrap();
        assert!(RunConfig::from_json(&j).is_err());
    }
}
