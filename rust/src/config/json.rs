//! Minimal JSON parser + writer.
//!
//! The offline vendor set has no `serde`, so DistSim carries its own JSON
//! substrate: enough of RFC 8259 to read the AOT `manifest.json`, write
//! Chrome traces and calibration files, and round-trip run configs.

use std::collections::BTreeMap;
use std::fmt;

#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

#[derive(Debug)]
pub struct JsonError {
    pub pos: usize,
    pub msg: String,
}

impl fmt::Display for JsonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json parse error at byte {}: {}", self.pos, self.msg)
    }
}

impl std::error::Error for JsonError {}

impl Json {
    pub fn parse(s: &str) -> Result<Json, JsonError> {
        let mut p = Parser {
            b: s.as_bytes(),
            pos: 0,
        };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.b.len() {
            return Err(p.err("trailing data"));
        }
        Ok(v)
    }

    // -- accessors ---------------------------------------------------------

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_u64(&self) -> Option<u64> {
        self.as_f64().map(|f| f as u64)
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|f| f as usize)
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Some(m),
            _ => None,
        }
    }

    // -- builders ----------------------------------------------------------

    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(
            pairs
                .into_iter()
                .map(|(k, v)| (k.to_string(), v))
                .collect(),
        )
    }

    pub fn str(s: impl Into<String>) -> Json {
        Json::Str(s.into())
    }

    pub fn num(n: f64) -> Json {
        Json::Num(n)
    }

    // -- files -------------------------------------------------------------

    /// Read and parse a JSON file (cache snapshots, configs), wrapping
    /// both I/O and parse failures with the path for one-line CLI errors.
    pub fn read_file(path: &std::path::Path) -> anyhow::Result<Json> {
        let text = std::fs::read_to_string(path)
            .map_err(|e| anyhow::anyhow!("cannot read {}: {e}", path.display()))?;
        Json::parse(&text).map_err(|e| anyhow::anyhow!("malformed JSON in {}: {e}", path.display()))
    }

    /// Write this document to a file with a trailing newline, atomically
    /// (temp file + rename): a crash mid-save must never leave a
    /// truncated document where a valid one stood.
    pub fn write_file(&self, path: &std::path::Path) -> anyhow::Result<()> {
        let tmp = path.with_extension("json.tmp");
        std::fs::write(&tmp, format!("{self}\n"))
            .map_err(|e| anyhow::anyhow!("cannot write {}: {e}", tmp.display()))?;
        std::fs::rename(&tmp, path)
            .map_err(|e| anyhow::anyhow!("cannot write {}: {e}", path.display()))
    }
}

impl fmt::Display for Json {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Json::Null => write!(f, "null"),
            Json::Bool(b) => write!(f, "{b}"),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 9e15 {
                    write!(f, "{}", *n as i64)
                } else {
                    write!(f, "{n}")
                }
            }
            Json::Str(s) => write_escaped(f, s),
            Json::Arr(v) => {
                write!(f, "[")?;
                for (i, x) in v.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write!(f, "{x}")?;
                }
                write!(f, "]")
            }
            Json::Obj(m) => {
                write!(f, "{{")?;
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write_escaped(f, k)?;
                    write!(f, ":{v}")?;
                }
                write!(f, "}}")
            }
        }
    }
}

fn write_escaped(f: &mut fmt::Formatter<'_>, s: &str) -> fmt::Result {
    write!(f, "\"")?;
    for c in s.chars() {
        match c {
            '"' => write!(f, "\\\"")?,
            '\\' => write!(f, "\\\\")?,
            '\n' => write!(f, "\\n")?,
            '\r' => write!(f, "\\r")?,
            '\t' => write!(f, "\\t")?,
            c if (c as u32) < 0x20 => write!(f, "\\u{:04x}", c as u32)?,
            c => write!(f, "{c}")?,
        }
    }
    write!(f, "\"")
}

struct Parser<'a> {
    b: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError {
            pos: self.pos,
            msg: msg.to_string(),
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.pos).copied()
    }

    fn bump(&mut self) -> Option<u8> {
        let c = self.peek();
        if c.is_some() {
            self.pos += 1;
        }
        c
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, c: u8) -> Result<(), JsonError> {
        if self.bump() == Some(c) {
            Ok(())
        } else {
            self.pos = self.pos.saturating_sub(1);
            Err(self.err(&format!("expected '{}'", c as char)))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        self.skip_ws();
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("unexpected character")),
        }
    }

    fn lit(&mut self, word: &str, v: Json) -> Result<Json, JsonError> {
        if self.b[self.pos..].starts_with(word.as_bytes()) {
            self.pos += word.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected '{word}'")))
        }
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.pos += 1;
        }
        if self.peek() == Some(b'.') {
            self.pos += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            self.pos += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.pos += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        let text = std::str::from_utf8(&self.b[start..self.pos]).unwrap();
        text.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| self.err("bad number"))
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.bump() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => return Ok(out),
                Some(b'\\') => match self.bump() {
                    Some(b'"') => out.push('"'),
                    Some(b'\\') => out.push('\\'),
                    Some(b'/') => out.push('/'),
                    Some(b'n') => out.push('\n'),
                    Some(b't') => out.push('\t'),
                    Some(b'r') => out.push('\r'),
                    Some(b'b') => out.push('\u{8}'),
                    Some(b'f') => out.push('\u{c}'),
                    Some(b'u') => {
                        let mut code = 0u32;
                        for _ in 0..4 {
                            let c = self.bump().ok_or_else(|| self.err("bad \\u"))?;
                            code = code * 16
                                + (c as char)
                                    .to_digit(16)
                                    .ok_or_else(|| self.err("bad hex"))?;
                        }
                        out.push(char::from_u32(code).unwrap_or('\u{fffd}'));
                    }
                    _ => return Err(self.err("bad escape")),
                },
                Some(c) if c < 0x80 => out.push(c as char),
                Some(c) => {
                    // multi-byte utf-8: copy the raw bytes
                    let len = utf8_len(c);
                    let start = self.pos - 1;
                    for _ in 1..len {
                        self.bump();
                    }
                    out.push_str(
                        std::str::from_utf8(&self.b[start..self.pos])
                            .map_err(|_| self.err("bad utf8"))?,
                    );
                }
            }
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.expect(b'[')?;
        let mut v = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(v));
        }
        loop {
            v.push(self.value()?);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b']') => return Ok(Json::Arr(v)),
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.expect(b'{')?;
        let mut m = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.skip_ws();
            let k = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            let v = self.value()?;
            m.insert(k, v);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b'}') => return Ok(Json::Obj(m)),
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }
}

fn utf8_len(first: u8) -> usize {
    match first {
        0xC0..=0xDF => 2,
        0xE0..=0xEF => 3,
        0xF0..=0xF7 => 4,
        _ => 1,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse(" true ").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("-3.5e2").unwrap(), Json::Num(-350.0));
        assert_eq!(
            Json::parse("\"a\\nb\"").unwrap(),
            Json::Str("a\nb".into())
        );
    }

    #[test]
    fn parse_nested() {
        let j = Json::parse(r#"{"a":[1,2,{"b":"x"}],"c":null}"#).unwrap();
        assert_eq!(
            j.get("a").unwrap().as_arr().unwrap()[2]
                .get("b")
                .unwrap()
                .as_str(),
            Some("x")
        );
        assert_eq!(j.get("c"), Some(&Json::Null));
    }

    #[test]
    fn roundtrip() {
        let src = r#"{"arr":[1,2.5,"s"],"b":false,"n":null,"o":{"k":3}}"#;
        let j = Json::parse(src).unwrap();
        let j2 = Json::parse(&j.to_string()).unwrap();
        assert_eq!(j, j2);
    }

    #[test]
    fn escapes_roundtrip() {
        let j = Json::Str("tab\t nl\n quote\" back\\ unicode\u{1f600}".into());
        let j2 = Json::parse(&j.to_string()).unwrap();
        assert_eq!(j, j2);
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("12 34").is_err());
        assert!(Json::parse("'single'").is_err());
    }

    #[test]
    fn file_roundtrip_and_errors_name_the_path() {
        let path = std::env::temp_dir().join(format!("distsim_json_{}.json", std::process::id()));
        let doc = Json::parse(r#"{"a":[1,2],"b":"x"}"#).unwrap();
        doc.write_file(&path).unwrap();
        assert_eq!(Json::read_file(&path).unwrap(), doc);
        std::fs::write(&path, "{nope").unwrap();
        let err = Json::read_file(&path).unwrap_err().to_string();
        assert!(err.contains("malformed"), "{err}");
        std::fs::remove_file(&path).unwrap();
        assert!(Json::read_file(&path)
            .unwrap_err()
            .to_string()
            .contains("cannot read"));
    }

    #[test]
    fn parses_real_manifest_shape() {
        let src = r#"{"artifacts":[{"name":"m","path":"m.hlo.txt","kind":"matmul","flops":1024,"args":[{"shape":[2,2],"dtype":"float32"}],"n":128}]}"#;
        let j = Json::parse(src).unwrap();
        let a = &j.get("artifacts").unwrap().as_arr().unwrap()[0];
        assert_eq!(a.get("flops").unwrap().as_u64(), Some(1024));
        assert_eq!(
            a.get("args").unwrap().as_arr().unwrap()[0]
                .get("shape")
                .unwrap()
                .as_arr()
                .unwrap()
                .len(),
            2
        );
    }
}

#[cfg(test)]
mod proptests {
    use super::*;
    use crate::testutil;
    use crate::util::Rng;

    fn random_json(rng: &mut Rng, depth: usize) -> Json {
        match if depth == 0 { rng.below(4) } else { rng.below(6) } {
            0 => Json::Null,
            1 => Json::Bool(rng.f64() < 0.5),
            2 => {
                // mix of integers and dyadic fractions (exact in f64)
                let v = (rng.below(2_000_001) as f64 - 1e6) / 64.0;
                Json::Num(v)
            }
            3 => {
                let n = rng.below(12) as usize;
                Json::Str(
                    (0..n)
                        .map(|_| {
                            let c = rng.below(96) as u8 + 32;
                            c as char
                        })
                        .collect(),
                )
            }
            4 => Json::Arr(
                (0..rng.below(5)).map(|_| random_json(rng, depth - 1)).collect(),
            ),
            _ => Json::Obj(
                (0..rng.below(5))
                    .map(|i| (format!("k{i}"), random_json(rng, depth - 1)))
                    .collect(),
            ),
        }
    }

    #[test]
    fn prop_roundtrip_random_documents() {
        testutil::check("json-roundtrip", 300, |rng| {
            let j = random_json(rng, 3);
            let parsed = Json::parse(&j.to_string())
                .unwrap_or_else(|e| panic!("failed on {j}: {e}"));
            assert_eq!(parsed, j);
        });
    }
}
