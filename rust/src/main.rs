//! DistSim CLI — the L3 leader entrypoint.
//!
//! Subcommands (hand-rolled parser: no `clap` in the offline vendor set):
//!
//! ```text
//! distsim simulate  --model bert-large --strategy 2M2P2D [--schedule dapple]
//!                   [--micro-batches 4] [--micro-batch-size 4] [--trace out.json]
//! distsim search    [--model bert-exlarge] [--global-batch 16] [--cache-file F]
//!                   [--placement-opt] [--beam N] [--prune] [--prune-epochs N]
//!                   [--scenario-file scenario.json]
//! distsim serve     --stdio | --port N  [--workers W] [--cache-dir DIR]
//!                   [--save-interval SECS] [--max-queue N]
//!                   [--log-level error|warn|info|debug] [--trace-dir DIR]
//! distsim ask       [--model M ...] [--scenario-file scenario.json]
//!                   | --file req.ndjson  [--connect HOST:PORT]
//! distsim calibrate [--artifacts DIR] [--iters 5] [--out calibration.json]
//! distsim exp       fig3|fig8|fig9|fig10|fig11|fig12|table2|table3|
//!                   ablate-allreduce|ablate-noise|ablate-hierarchy|all
//!                   [--fast]
//! distsim models    # list the model zoo
//! ```
//!
//! Failures print a one-line JSON error object on stderr (shared with the
//! what-if service's error path) and exit non-zero — no panics or
//! backtraces for malformed configs or request files.

use std::collections::HashMap;

use distsim::cluster::ClusterSpec;
use distsim::config::RunConfig;
use distsim::strategy::Strategy;

fn parse_flags(args: &[String]) -> (Vec<String>, HashMap<String, String>) {
    let mut pos = Vec::new();
    let mut flags = HashMap::new();
    let mut i = 0;
    while i < args.len() {
        if let Some(name) = args[i].strip_prefix("--") {
            if i + 1 < args.len() && !args[i + 1].starts_with("--") {
                flags.insert(name.to_string(), args[i + 1].clone());
                i += 2;
            } else {
                flags.insert(name.to_string(), "true".to_string());
                i += 1;
            }
        } else {
            pos.push(args[i].clone());
            i += 1;
        }
    }
    (pos, flags)
}

fn flag<'a>(flags: &'a HashMap<String, String>, name: &str, default: &'a str) -> &'a str {
    flags.get(name).map(String::as_str).unwrap_or(default)
}

fn usize_flag(flags: &HashMap<String, String>, name: &str, default: usize) -> usize {
    flags
        .get(name)
        .and_then(|v| v.parse().ok())
        .unwrap_or(default)
}

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    if args.is_empty() {
        print_help();
        std::process::exit(2);
    }
    let cmd = args[0].clone();
    let (pos, flags) = parse_flags(&args[1..]);
    let result = match cmd.as_str() {
        "simulate" => cmd_simulate(&flags),
        "search" => cmd_search(&flags),
        "serve" => cmd_serve(&flags),
        "ask" => cmd_ask(&flags),
        "calibrate" => cmd_calibrate(&flags),
        "exp" => cmd_exp(&pos, &flags),
        "models" => {
            for name in distsim::model::model_names() {
                let m = distsim::model::by_name(name).unwrap();
                println!(
                    "{name:14} {:3} layers  hidden {:6}  {:7.2} M params",
                    m.layers.len(),
                    m.hidden,
                    m.total_params() as f64 / 1e6
                );
            }
            Ok(())
        }
        "help" | "--help" | "-h" => {
            print_help();
            Ok(())
        }
        other => Err(anyhow::anyhow!("unknown command '{other}' (try 'distsim help')")),
    };
    if let Err(e) = result {
        // one parseable line, same shape as a service error response
        eprintln!("{}", distsim::service::cli_error_line(&e));
        std::process::exit(1);
    }
}

fn print_help() {
    println!(
        "DistSim — event-based performance model of hybrid distributed DNN training

USAGE:
  distsim simulate  --model M --strategy xMyPzD [--schedule gpipe|dapple|naive]
                    [--micro-batches N] [--micro-batch-size B]
                    [--gt] [--trace out.json] [--trace-actual out.json]
  distsim search    [--model bert-exlarge] [--global-batch 16] [--nodes 4]
                    [--gpus-per-node 4] [--device a10|a40|a100|a40-a10]
                    [--placement linear|fast-first|interleaved] [--threads N]
                    [--wide] [--mbs-axis] [--schedule-axis] [--placement-axis]
                    [--placement-opt] [--beam N] [--prune] [--prune-epochs N]
                    [--no-cache] [--max-candidates N] [--cache-file F]
                    [--scenario-file scenario.json]
                    [--memory] [--recompute-axis] [--zero-axis]
                    [--capacity-gib G] [--plan-cache]
                    # --placement-opt searches rank→device tables beyond
                    # the named placements; --prune-epochs N re-prunes
                    # against the incumbent every 1/N of the sweep;
                    # --scenario-file scores every candidate under an
                    # unhappy-path ScenarioSpec and prints the robust pick;
                    # --memory prices per-rank peak bytes for every
                    # candidate; --recompute-axis / --zero-axis add
                    # activation-recompute and ZeRO-1 points to the sweep;
                    # --capacity-gib caps every device SKU so infeasible
                    # candidates are pruned for free before profiling;
                    # --plan-cache compiles the sweep plan (candidate
                    # space, bounds, memory verdicts, event set) up front
                    # and feeds the engine from it — identical output,
                    # plus a plan accounting line (DESIGN.md §11)
  distsim serve     --stdio | --port N  [--workers W] [--cache-dir DIR]
                    [--save-interval SECS] [--max-queue N]
                    [--log-level error|warn|info|debug] [--trace-dir DIR]
                    # long-lived what-if daemon: one NDJSON request per
                    # line in, one response line out, each connection's
                    # responses in its own admission order;
                    # --save-interval additionally snapshots caches
                    # periodically (atomic tmp-file + rename);
                    # --max-queue bounds queued sweeps (default 1024),
                    # overflow answered with a structured `unavailable`;
                    # --log-level gates one-line JSON events on stderr
                    # (default info); --trace-dir writes one Chrome-trace
                    # file per completed sweep (see FORMATS.md §1.8)
  distsim ask       [--model M --global-batch B ...] | --file req.ndjson
                    [--connect HOST:PORT] [--timing] [--workers W]
                    [--cache-dir DIR] [--scenario-file scenario.json]
                    # self-test client: runs the request in-process, or
                    # sends it to a running daemon with --connect;
                    # --scenario-file attaches an unhappy-path scenario
                    # to the flag-built sweep request; a multi-line
                    # --file session shares one compiled-plan cache, so
                    # repeated request shapes skip re-planning (the
                    # trailing stats line, if any, reports the hits)
  distsim calibrate [--artifacts DIR] [--iters 5] [--out calibration.json]
  distsim exp       fig3|fig8|fig9|fig10|fig11|fig12|table2|table3|
                    ablate-allreduce|ablate-noise|ablate-hierarchy|ablate-schedule|all [--fast]
  distsim models"
    );
}

fn cluster_from_flags(flags: &HashMap<String, String>) -> anyhow::Result<ClusterSpec> {
    let nodes = usize_flag(flags, "nodes", 4);
    let gpn = usize_flag(flags, "gpus-per-node", 4);
    let mut cluster = match flag(flags, "device", "a40") {
        "a40" => ClusterSpec::a40_cluster(nodes, gpn),
        "a10" => ClusterSpec::a10_cluster(nodes, gpn),
        "a100" => ClusterSpec::a100_pod(nodes),
        // mixed-SKU fleet: A40 nodes + A10 nodes, alternating by node
        "a40-a10" => {
            anyhow::ensure!(
                nodes >= 2,
                "--device a40-a10 needs --nodes >= 2 (one node would be all-A40)"
            );
            ClusterSpec::mixed_a40_a10(nodes, gpn)
        }
        other => anyhow::bail!("unknown device '{other}' (a40|a10|a100|a40-a10)"),
    };
    if let Some(p) = flags.get("placement") {
        cluster.placement = distsim::cluster::Placement::parse(p)?;
    }
    // --capacity-gib: declare a uniform per-device memory capacity; the
    // sweep's memory stage only ever prunes against declared capacities
    if let Some(g) = flags.get("capacity-gib") {
        let gib: f64 = g
            .parse()
            .map_err(|_| anyhow::anyhow!("bad --capacity-gib '{g}'"))?;
        anyhow::ensure!(gib > 0.0, "--capacity-gib must be positive");
        cluster = cluster.with_uniform_capacity((gib * 1_073_741_824.0).round() as u64);
    }
    Ok(cluster)
}

fn cmd_simulate(flags: &HashMap<String, String>) -> anyhow::Result<()> {
    let model = flag(flags, "model", "bert-large");
    let strategy = Strategy::parse(flag(flags, "strategy", "2M2P2D"))?;
    let mut cfg = RunConfig::new(model, strategy, cluster_from_flags(flags)?);
    cfg.schedule = flag(flags, "schedule", "dapple").to_string();
    cfg.micro_batches = usize_flag(flags, "micro-batches", 4);
    cfg.micro_batch_size = usize_flag(flags, "micro-batch-size", 4);
    cfg.profile_iters = usize_flag(flags, "profile-iters", 100);

    let run = distsim::exp::eval_cfg(&cfg)?;
    let pred = run.predicted.batch_time_us();
    println!(
        "model {model}  strategy {strategy}  schedule {}  micro-batches {}x{}",
        cfg.schedule, cfg.micro_batches, cfg.micro_batch_size
    );
    println!(
        "DistSim predicted batch time: {}  ({:.3} it/s)",
        distsim::util::fmt_us(pred),
        1e6 / pred
    );
    println!(
        "profiled {} unique events in {:.2} gpu-s ({} extrapolated)",
        run.profile.events_profiled, run.profile.gpu_seconds, run.profile.extrapolated
    );
    let (umin, umean, umax) =
        distsim::timeline::analysis::utilization_summary(&run.predicted);
    println!("device utilization: min {umin:.2} mean {umean:.2} max {umax:.2}");
    println!(
        "pipeline bubble ratio: {:.3}",
        distsim::timeline::analysis::bubble_ratio(&run.predicted)
    );

    if flags.contains_key("gt") {
        let actual = run.gt.mean_batch_time_us(20);
        println!(
            "ground-truth batch time:      {}  (error {:.2}%)",
            distsim::util::fmt_us(actual),
            distsim::util::rel_err_pct(pred, actual)
        );
    }
    if let Some(path) = flags.get("trace") {
        distsim::timeline::chrome::write_chrome_trace(&run.predicted, path)?;
        println!("wrote predicted trace to {path}");
    }
    if let Some(path) = flags.get("trace-actual") {
        let actual = run.gt.run_iteration(0);
        distsim::timeline::chrome::write_chrome_trace(&actual, path)?;
        println!("wrote actual trace to {path}");
    }
    Ok(())
}

fn cmd_search(flags: &HashMap<String, String>) -> anyhow::Result<()> {
    let model_name = flag(flags, "model", "bert-exlarge");
    let model = distsim::model::by_name(model_name)
        .ok_or_else(|| anyhow::anyhow!("unknown model '{model_name}'"))?;
    let mut dflags = flags.clone();
    dflags.entry("device".to_string()).or_insert("a10".to_string());
    let cluster = cluster_from_flags(&dflags)?;
    // --scenario-file: load an unhappy-path spec and score every sweep
    // candidate under it; device indices must exist on this cluster
    let scenario = match flags.get("scenario-file") {
        Some(path) => {
            let json = distsim::config::Json::read_file(std::path::Path::new(path))?;
            let spec = distsim::scenario::ScenarioSpec::from_json(&json)?;
            spec.validate_devices(cluster.total_devices())?;
            spec
        }
        None => distsim::scenario::ScenarioSpec::default(),
    };
    let cfg = distsim::search::SweepConfig {
        scenario,
        global_batch: usize_flag(flags, "global-batch", 16),
        jitter_sigma: 0.02,
        profile_iters: usize_flag(flags, "profile-iters", 100),
        threads: usize_flag(flags, "threads", 0),
        widened: flags.contains_key("wide"),
        micro_batch_axis: flags.contains_key("mbs-axis"),
        schedule_axis: flags.contains_key("schedule-axis"),
        placement_axis: flags.contains_key("placement-axis"),
        placement_opt: flags.contains_key("placement-opt"),
        beam: usize_flag(flags, "beam", 4),
        prune_epochs: usize_flag(flags, "prune-epochs", 1),
        max_candidates: usize_flag(flags, "max-candidates", 0),
        prune: flags.contains_key("prune"),
        memory: flags.contains_key("memory"),
        recompute_axis: flags.contains_key("recompute-axis"),
        zero_axis: flags.contains_key("zero-axis"),
        use_cache: !flags.contains_key("no-cache"),
        ..distsim::search::SweepConfig::default()
    };
    let cost = distsim::cost::CostModel::default();
    let book = distsim::cost::CostBook::uniform(cost.clone());

    // --cache-file: warm the sweep from a persisted snapshot when its
    // (cluster, cost, protocol) fingerprint matches, and save back after
    let cache_file = flags.get("cache-file").map(std::path::PathBuf::from);
    let fp = distsim::search::fingerprint(
        &cluster,
        &book,
        cfg.jitter_sigma,
        cfg.profile_iters,
        cfg.profile_seed,
    );
    let mut engine = distsim::search::SearchEngine::new(&model, &cluster, &cost, cfg.clone());
    // a snapshot for a *different* fingerprint still belongs to someone:
    // never overwrite it with this sweep's data
    let mut save_cache_file = true;
    if let Some(path) = cache_file.as_deref().filter(|p| p.exists()) {
        let json = distsim::config::Json::read_file(path)?;
        // only a *pre-current* snapshot version is ours to upgrade; a
        // future version or unrecognizable file belongs to someone else
        let upgradeable = matches!(
            json.get("version").and_then(distsim::config::Json::as_usize),
            Some(v) if v < distsim::search::SNAPSHOT_VERSION
        );
        match distsim::search::ProfileCache::load_json(&json) {
            Ok(snap) if snap.fingerprint == fp => {
                println!(
                    "cache file {}: loaded {} profiled events (fingerprint {fp})",
                    path.display(),
                    snap.keys.len()
                );
                engine = distsim::search::SearchEngine::with_cache(
                    &model,
                    &cluster,
                    &cost,
                    cfg.clone(),
                    std::sync::Arc::new(snap.cache),
                )
                .with_prior(snap.keys);
            }
            Ok(snap) => {
                save_cache_file = false;
                distsim::telemetry::Logger::default().warn(
                    "snapshot_ignored",
                    &[
                        (
                            "path",
                            distsim::config::Json::str(path.display().to_string()),
                        ),
                        ("found", distsim::config::Json::str(&snap.fingerprint)),
                        ("expected", distsim::config::Json::str(&fp)),
                    ],
                );
            }
            Err(e) => {
                // refuse to serve the snapshot (never silently price the
                // wrong SKU) and report the reason as one parseable line;
                // overwrite only genuinely-stale pre-current versions —
                // future-version or foreign files are left untouched
                save_cache_file = upgradeable;
                eprintln!("{}", distsim::service::cli_error_line(&e));
            }
        }
    }
    // --plan-cache: compile the sweep plan up front and feed the engine
    // from it. Output is byte-identical to a plan-less run (the plan's
    // components are exactly what the engine would recompute); a one-shot
    // CLI run gains the accounting line below, while the daemon reuses
    // plans across requests (DESIGN.md §11).
    if flags.contains_key("plan-cache") {
        let t0 = std::time::Instant::now();
        let plan = std::sync::Arc::new(distsim::search::SweepPlan::compile(
            &model, &cluster, &book, &cfg,
        ));
        println!(
            "plan: compiled {} candidates, {} interned events in {:.1} ms (shape {:016x})",
            plan.candidate_count(),
            plan.event_count(),
            t0.elapsed().as_secs_f64() * 1e3,
            plan.shape()
        );
        engine = engine.with_plan(plan);
    }
    let report = engine.sweep();

    for (c, ms) in report.candidates.iter().zip(&report.timing.per_candidate_ms) {
        let status = if !c.fits {
            format!("oom (peak {:.2} GiB)", c.peak_bytes as f64 / 1_073_741_824.0)
        } else if c.pruned {
            format!("pruned (bound {:.3} it/s)", c.bound_throughput)
        } else if !c.reachable {
            "unreachable".to_string()
        } else {
            format!("{:.3} it/s", c.throughput)
        };
        println!(
            "{:10} {:7} {:11} mbs {:>2} x{:<3} {:>26}   [{:7.1} ms]",
            c.strategy.notation(),
            c.schedule.name(),
            c.placement.name(),
            c.micro_batch_size,
            c.micro_batches,
            status,
            ms
        );
    }
    let (best, worst) = (report.best(), report.worst());
    match (best, worst) {
        (Some(b), Some(w)) => println!(
            "\nbest {} ({:.3} it/s), worst {} ({:.3} it/s): {:.2}x speedup",
            b.strategy,
            b.throughput,
            w.strategy,
            w.throughput,
            report.speedup().unwrap_or(f64::NAN)
        ),
        _ => println!("\nno reachable candidate for this model/cluster"),
    }
    if let Some(rb) = &report.robustness {
        let nb = &report.candidates[rb.nominal_best];
        let sb = &report.candidates[rb.scenario_best];
        println!(
            "robustness: nominal best {} -> scenario best {} ({:.3} it/s under scenario); \
             regret {:.1}%",
            nb.strategy.notation(),
            sb.strategy.notation(),
            sb.scenario_throughput,
            rb.regret * 100.0
        );
        println!(
            "  scenario slowdown x{:.3} (stragglers x{:.3}, links x{:.3}); \
             restart penalty {:.0} us, reshard {:.0} us, {} episodes",
            rb.scenario_slowdown,
            rb.straggler_slowdown,
            rb.link_slowdown,
            rb.restart_penalty_us,
            rb.reshard_us,
            rb.episodes
        );
    }
    println!(
        "{} candidates: {} evaluated, {} pruned, on {} threads in {:.3} s",
        report.candidates.len(),
        report.evaluated_count(),
        report.pruned_count(),
        report.threads_used,
        report.timing.total_seconds
    );
    // pruning accounting, mirroring the Table-3 cache block: what the
    // staged pipeline generated, discarded by bound, re-discarded at
    // epoch boundaries, and what that avoided in profiling currency
    println!(
        "pruning: {} generated, {} bound-pruned, {} epoch-repruned, {} evaluated; \
         {:.2} gpu-s avoided",
        report.pruning.generated,
        report.pruning.bound_pruned,
        report.pruning.epoch_repruned,
        report.pruning.evaluated,
        report.pruning.gpu_seconds_avoided
    );
    // memory accounting block: only when the stage actually priced
    // something, so capacity-less runs print byte-identical output
    let memory_active = report.pruning.memory_pruned > 0
        || report.candidates.iter().any(|c| c.peak_bytes > 0);
    if memory_active {
        let peak = report
            .candidates
            .iter()
            .map(|c| c.peak_bytes)
            .max()
            .unwrap_or(0);
        println!(
            "memory: {} memory-pruned (oom), worst candidate peak {:.2} GiB/rank; \
             {:.2} gpu-s avoided by the memory stage",
            report.pruning.memory_pruned,
            peak as f64 / 1_073_741_824.0,
            report.pruning.memory_gpu_seconds_avoided
        );
    }
    println!(
        "profiling: {:.2} gpu-s over {} unique events; cache {} hits / {} misses ({:.0}% hit rate)",
        report.profile.gpu_seconds,
        report.profile.events_profiled,
        report.cache.hits,
        report.cache.misses,
        report.cache.hit_rate() * 100.0
    );
    if let Some(a) = report.schedule_attribution().filter(|_| cfg.schedule_axis) {
        println!(
            "schedule axis: winner runs {} ({:.2}x over best dapple); strategy alone spans {:.2}x",
            a.winning_schedule, a.schedule_speedup, a.strategy_speedup
        );
    }
    if let Some(a) = report
        .placement_attribution()
        .filter(|_| cfg.placement_axis || cfg.placement_opt)
    {
        println!(
            "placement axis: winner deploys {} ({:.2}x over best baseline placement); \
             strategy alone spans {:.2}x",
            a.winning_placement, a.placement_speedup, a.strategy_speedup
        );
    }
    if let Some(t) = report.winning_table() {
        println!(
            "placement optimizer: winning rank→device table {:?}",
            t
        );
    }
    if let Some(path) = cache_file.as_deref().filter(|_| save_cache_file) {
        engine
            .cache()
            .save_json(
                &cluster,
                &book,
                cfg.jitter_sigma,
                cfg.profile_iters,
                cfg.profile_seed,
            )
            .write_file(path)?;
        println!(
            "cache file {}: saved {} profiled events",
            path.display(),
            engine.cache().measured_len()
        );
    }
    Ok(())
}

fn cmd_serve(flags: &HashMap<String, String>) -> anyhow::Result<()> {
    use distsim::config::Json;
    use distsim::telemetry::{LogLevel, Logger};
    let log_level = match flags.get("log-level") {
        Some(v) => LogLevel::parse(v).map_err(|e| anyhow::anyhow!("bad --log-level: {e}"))?,
        None => LogLevel::default(),
    };
    let opts = distsim::service::ServeOpts {
        workers: usize_flag(flags, "workers", 0),
        cache_dir: flags.get("cache-dir").map(std::path::PathBuf::from),
        save_interval: flags
            .get("save-interval")
            .and_then(|v| v.parse::<u64>().ok())
            .filter(|&s| s > 0)
            .map(std::time::Duration::from_secs),
        // 0 = the default bound; sweeps past it shed with `unavailable`
        max_queue: usize_flag(flags, "max-queue", 0),
        log_level,
        trace_dir: flags.get("trace-dir").map(std::path::PathBuf::from),
        ..Default::default()
    };
    let log = Logger::new(log_level);
    let served = |summary: &distsim::service::ServeSummary| {
        log.info(
            "served",
            &[
                ("requests", Json::num(summary.requests as f64)),
                ("sweeps", Json::num(summary.sweeps as f64)),
                ("errors", Json::num(summary.errors as f64)),
                (
                    "snapshots_saved",
                    Json::num(summary.snapshots_saved as f64),
                ),
            ],
        );
    };
    if flags.contains_key("stdio") {
        let stdin = std::io::stdin();
        // Stdout (not its lock) crosses into the writer thread: locks are
        // per-write, and Stdout is Send where StdoutLock is not
        let summary = distsim::service::serve_ndjson(stdin.lock(), std::io::stdout(), &opts);
        served(&summary);
        return Ok(());
    }
    if let Some(port) = flags.get("port") {
        let port: u16 = port
            .parse()
            .map_err(|_| anyhow::anyhow!("bad --port '{port}'"))?;
        let listener = std::net::TcpListener::bind(("127.0.0.1", port))?;
        // with --port 0 the OS picks; always announce the bound address
        log.info(
            "listening",
            &[("addr", Json::str(listener.local_addr()?.to_string()))],
        );
        let summary = distsim::service::serve_tcp(listener, &opts)?;
        served(&summary);
        return Ok(());
    }
    anyhow::bail!("serve needs a transport: --stdio or --port N")
}

fn cmd_ask(flags: &HashMap<String, String>) -> anyhow::Result<()> {
    // assemble the request line: from a file ('-' = stdin), or from flags
    let request = if let Some(path) = flags.get("file") {
        if path == "-" {
            let mut s = String::new();
            std::io::Read::read_to_string(&mut std::io::stdin(), &mut s)?;
            s
        } else {
            std::fs::read_to_string(path)
                .map_err(|e| anyhow::anyhow!("cannot read request file '{path}': {e}"))?
        }
    } else {
        let mut dflags = flags.clone();
        dflags.entry("device".to_string()).or_insert("a10".to_string());
        let cluster = cluster_from_flags(&dflags)?;
        use distsim::config::Json;
        let mut sweep = vec![
            (
                "global_batch",
                Json::num(usize_flag(flags, "global-batch", 16) as f64),
            ),
            (
                "profile_iters",
                Json::num(usize_flag(flags, "profile-iters", 1) as f64),
            ),
            ("threads", Json::num(usize_flag(flags, "threads", 1) as f64)),
        ];
        for (name, key) in [
            ("wide", "widened"),
            ("mbs-axis", "micro_batch_axis"),
            ("schedule-axis", "schedule_axis"),
            ("placement-axis", "placement_axis"),
            ("placement-opt", "placement_opt"),
            ("prune", "prune"),
            ("memory", "memory"),
            ("recompute-axis", "recompute_axis"),
            ("zero-axis", "zero_axis"),
        ] {
            if flags.contains_key(name) {
                sweep.push((key, Json::Bool(true)));
            }
        }
        // clamp to >= 1 like `distsim search` does, so the two entry
        // points agree on the same inputs (the service rejects 0)
        for (name, key) in [("prune-epochs", "prune_epochs"), ("beam", "beam")] {
            if let Some(v) = flags.get(name).and_then(|v| v.parse::<usize>().ok()) {
                sweep.push((key, Json::num(v.max(1) as f64)));
            }
        }
        // --scenario-file: parse eagerly so a malformed spec fails here
        // as a CLI error, not as a daemon error response line
        if let Some(path) = flags.get("scenario-file") {
            let json = Json::read_file(std::path::Path::new(path))?;
            let spec = distsim::scenario::ScenarioSpec::from_json(&json)?;
            sweep.push(("scenario", spec.to_json()));
        }
        distsim::service::protocol::build_request_line(
            flag(flags, "id", "ask"),
            flag(flags, "model", "bert-exlarge"),
            &cluster,
            sweep,
            usize_flag(flags, "max-candidates", 0),
            flags.contains_key("timing"),
        )
    };

    if let Some(addr) = flags.get("connect") {
        // remote: one request line out, responses echoed until EOF
        use std::io::{BufRead, Write};
        let mut stream = std::net::TcpStream::connect(addr)
            .map_err(|e| anyhow::anyhow!("cannot connect to {addr}: {e}"))?;
        let n_requests = request.lines().filter(|l| !l.trim().is_empty()).count();
        for line in request.lines().filter(|l| !l.trim().is_empty()) {
            writeln!(stream, "{line}")?;
        }
        stream.flush()?;
        let reader = std::io::BufReader::new(stream.try_clone()?);
        for (i, line) in reader.lines().enumerate() {
            println!("{}", line?);
            if i + 1 >= n_requests {
                break;
            }
        }
        return Ok(());
    }

    // local: run the request(s) through the in-process service core
    let opts = distsim::service::ServeOpts {
        workers: usize_flag(flags, "workers", 0),
        cache_dir: flags.get("cache-dir").map(std::path::PathBuf::from),
        ..Default::default()
    };
    distsim::service::serve_ndjson(std::io::Cursor::new(request), std::io::stdout(), &opts);
    Ok(())
}

fn cmd_calibrate(flags: &HashMap<String, String>) -> anyhow::Result<()> {
    let dir = flags
        .get("artifacts")
        .map(std::path::PathBuf::from)
        .unwrap_or_else(distsim::runtime::artifacts_dir);
    let iters = usize_flag(flags, "iters", 5);
    println!("measuring AOT artifacts in {} (PJRT-CPU) ...", dir.display());
    let mut cal = distsim::profile::calibrate::measure_artifacts(&dir, iters)?;
    let host_tflops = cal.host_gflops / 1e3;
    distsim::profile::calibrate::fit_scale(
        &mut cal,
        &distsim::cost::CostModel::default(),
        host_tflops,
    );
    for p in &cal.points {
        println!(
            "  {:28} {:>12.1} us  {:>8.2} GFLOP/s",
            p.name,
            p.measured_us,
            p.flops as f64 / p.measured_us / 1e3
        );
    }
    println!("host peak observed: {:.2} GFLOP/s", cal.host_gflops);
    let out = flag(flags, "out", "calibration.json");
    cal.save(std::path::Path::new(out))?;
    println!("wrote {out}");
    Ok(())
}

fn cmd_exp(pos: &[String], flags: &HashMap<String, String>) -> anyhow::Result<()> {
    let which = pos.first().map(String::as_str).unwrap_or("all");
    let fast = flags.contains_key("fast");
    // iteration budgets: paper uses 100-iteration averages; --fast trims
    let (gt_iters, prof_iters, f10_runs) = if fast { (5, 10, 10) } else { (30, 100, 100) };

    let run_one = |name: &str| -> anyhow::Result<()> {
        match name {
            "fig3" => distsim::exp::fig3::print(&distsim::exp::fig3::run(gt_iters)?),
            "fig8" => distsim::exp::fig8::print(&distsim::exp::fig8::run(gt_iters, prof_iters)?),
            "fig9" => distsim::exp::fig9::print(&distsim::exp::fig9::run(prof_iters)?),
            "fig10" => {
                distsim::exp::fig10::print(&distsim::exp::fig10::run(f10_runs, prof_iters)?)
            }
            "fig11" => distsim::exp::fig11::print(&distsim::exp::fig11::run(prof_iters)?),
            "fig12" | "table2" => {
                distsim::exp::fig12::print(&distsim::exp::fig12::run(prof_iters, gt_iters)?)
            }
            "table3" => distsim::exp::table3::print(&distsim::exp::table3::run(prof_iters, 100)?),
            "ablate-allreduce" => {
                distsim::exp::ablate::print_allreduce(&distsim::exp::ablate::allreduce(prof_iters)?)
            }
            "ablate-noise" => {
                distsim::exp::ablate::print_noise(&distsim::exp::ablate::noise(gt_iters, prof_iters)?)
            }
            "ablate-hierarchy" => distsim::exp::ablate::print_hierarchy(
                &distsim::exp::ablate::hierarchy(gt_iters, prof_iters)?,
            ),
            "ablate-schedule" => distsim::exp::ablate::print_schedules(
                &distsim::exp::ablate::schedules(prof_iters)?,
            ),
            other => anyhow::bail!("unknown experiment '{other}'"),
        }
        Ok(())
    };

    if which == "all" {
        for name in [
            "fig3", "fig8", "fig9", "fig10", "fig11", "fig12", "table3",
            "ablate-allreduce", "ablate-noise", "ablate-hierarchy",
            "ablate-schedule",
        ] {
            run_one(name)?;
        }
        Ok(())
    } else {
        run_one(which)
    }
}
