//! DistSim CLI — the L3 leader entrypoint.
//!
//! Subcommands (hand-rolled parser: no `clap` in the offline vendor set):
//!
//! ```text
//! distsim simulate  --model bert-large --strategy 2M2P2D [--schedule dapple]
//!                   [--micro-batches 4] [--micro-batch-size 4] [--trace out.json]
//! distsim search    [--model bert-exlarge] [--global-batch 16]
//! distsim calibrate [--artifacts DIR] [--iters 5] [--out calibration.json]
//! distsim exp       fig3|fig8|fig9|fig10|fig11|fig12|table2|table3|
//!                   ablate-allreduce|ablate-noise|ablate-hierarchy|all
//!                   [--fast]
//! distsim models    # list the model zoo
//! ```

use std::collections::HashMap;

use distsim::cluster::ClusterSpec;
use distsim::config::RunConfig;
use distsim::strategy::Strategy;

fn parse_flags(args: &[String]) -> (Vec<String>, HashMap<String, String>) {
    let mut pos = Vec::new();
    let mut flags = HashMap::new();
    let mut i = 0;
    while i < args.len() {
        if let Some(name) = args[i].strip_prefix("--") {
            if i + 1 < args.len() && !args[i + 1].starts_with("--") {
                flags.insert(name.to_string(), args[i + 1].clone());
                i += 2;
            } else {
                flags.insert(name.to_string(), "true".to_string());
                i += 1;
            }
        } else {
            pos.push(args[i].clone());
            i += 1;
        }
    }
    (pos, flags)
}

fn flag<'a>(flags: &'a HashMap<String, String>, name: &str, default: &'a str) -> &'a str {
    flags.get(name).map(String::as_str).unwrap_or(default)
}

fn usize_flag(flags: &HashMap<String, String>, name: &str, default: usize) -> usize {
    flags
        .get(name)
        .and_then(|v| v.parse().ok())
        .unwrap_or(default)
}

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    if args.is_empty() {
        print_help();
        std::process::exit(2);
    }
    let cmd = args[0].clone();
    let (pos, flags) = parse_flags(&args[1..]);
    let result = match cmd.as_str() {
        "simulate" => cmd_simulate(&flags),
        "search" => cmd_search(&flags),
        "calibrate" => cmd_calibrate(&flags),
        "exp" => cmd_exp(&pos, &flags),
        "models" => {
            for name in distsim::model::model_names() {
                let m = distsim::model::by_name(name).unwrap();
                println!(
                    "{name:14} {:3} layers  hidden {:6}  {:7.2} M params",
                    m.layers.len(),
                    m.hidden,
                    m.total_params() as f64 / 1e6
                );
            }
            Ok(())
        }
        "help" | "--help" | "-h" => {
            print_help();
            Ok(())
        }
        other => Err(anyhow::anyhow!("unknown command '{other}' (try 'distsim help')")),
    };
    if let Err(e) = result {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn print_help() {
    println!(
        "DistSim — event-based performance model of hybrid distributed DNN training

USAGE:
  distsim simulate  --model M --strategy xMyPzD [--schedule gpipe|dapple|naive]
                    [--micro-batches N] [--micro-batch-size B]
                    [--gt] [--trace out.json] [--trace-actual out.json]
  distsim search    [--model bert-exlarge] [--global-batch 16] [--nodes 4]
                    [--gpus-per-node 4] [--device a10|a40|a100] [--threads N]
                    [--wide] [--mbs-axis] [--prune] [--no-cache]
  distsim calibrate [--artifacts DIR] [--iters 5] [--out calibration.json]
  distsim exp       fig3|fig8|fig9|fig10|fig11|fig12|table2|table3|
                    ablate-allreduce|ablate-noise|ablate-hierarchy|ablate-schedule|all [--fast]
  distsim models"
    );
}

fn cluster_from_flags(flags: &HashMap<String, String>) -> anyhow::Result<ClusterSpec> {
    let nodes = usize_flag(flags, "nodes", 4);
    let gpn = usize_flag(flags, "gpus-per-node", 4);
    Ok(match flag(flags, "device", "a40") {
        "a40" => ClusterSpec::a40_cluster(nodes, gpn),
        "a10" => ClusterSpec::a10_cluster(nodes, gpn),
        "a100" => ClusterSpec::a100_pod(nodes),
        other => anyhow::bail!("unknown device '{other}'"),
    })
}

fn cmd_simulate(flags: &HashMap<String, String>) -> anyhow::Result<()> {
    let model = flag(flags, "model", "bert-large");
    let strategy = Strategy::parse(flag(flags, "strategy", "2M2P2D"))?;
    let mut cfg = RunConfig::new(model, strategy, cluster_from_flags(flags)?);
    cfg.schedule = flag(flags, "schedule", "dapple").to_string();
    cfg.micro_batches = usize_flag(flags, "micro-batches", 4);
    cfg.micro_batch_size = usize_flag(flags, "micro-batch-size", 4);
    cfg.profile_iters = usize_flag(flags, "profile-iters", 100);

    let run = distsim::exp::eval_cfg(&cfg)?;
    let pred = run.predicted.batch_time_us();
    println!(
        "model {model}  strategy {strategy}  schedule {}  micro-batches {}x{}",
        cfg.schedule, cfg.micro_batches, cfg.micro_batch_size
    );
    println!(
        "DistSim predicted batch time: {}  ({:.3} it/s)",
        distsim::util::fmt_us(pred),
        1e6 / pred
    );
    println!(
        "profiled {} unique events in {:.2} gpu-s ({} extrapolated)",
        run.profile.events_profiled, run.profile.gpu_seconds, run.profile.extrapolated
    );
    let (umin, umean, umax) =
        distsim::timeline::analysis::utilization_summary(&run.predicted);
    println!("device utilization: min {umin:.2} mean {umean:.2} max {umax:.2}");
    println!(
        "pipeline bubble ratio: {:.3}",
        distsim::timeline::analysis::bubble_ratio(&run.predicted)
    );

    if flags.contains_key("gt") {
        let actual = run.gt.mean_batch_time_us(20);
        println!(
            "ground-truth batch time:      {}  (error {:.2}%)",
            distsim::util::fmt_us(actual),
            distsim::util::rel_err_pct(pred, actual)
        );
    }
    if let Some(path) = flags.get("trace") {
        distsim::timeline::chrome::write_chrome_trace(&run.predicted, path)?;
        println!("wrote predicted trace to {path}");
    }
    if let Some(path) = flags.get("trace-actual") {
        let actual = run.gt.run_iteration(0);
        distsim::timeline::chrome::write_chrome_trace(&actual, path)?;
        println!("wrote actual trace to {path}");
    }
    Ok(())
}

fn cmd_search(flags: &HashMap<String, String>) -> anyhow::Result<()> {
    let model_name = flag(flags, "model", "bert-exlarge");
    let model = distsim::model::by_name(model_name)
        .ok_or_else(|| anyhow::anyhow!("unknown model '{model_name}'"))?;
    let mut dflags = flags.clone();
    dflags.entry("device".to_string()).or_insert("a10".to_string());
    let cluster = cluster_from_flags(&dflags)?;
    let cfg = distsim::search::SweepConfig {
        global_batch: usize_flag(flags, "global-batch", 16),
        jitter_sigma: 0.02,
        profile_iters: usize_flag(flags, "profile-iters", 100),
        threads: usize_flag(flags, "threads", 0),
        widened: flags.contains_key("wide"),
        micro_batch_axis: flags.contains_key("mbs-axis"),
        prune: flags.contains_key("prune"),
        use_cache: !flags.contains_key("no-cache"),
        ..distsim::search::SweepConfig::default()
    };
    let cost = distsim::cost::CostModel::default();
    let engine = distsim::search::SearchEngine::new(&model, &cluster, &cost, cfg);
    let report = engine.sweep();

    for (c, ms) in report.candidates.iter().zip(&report.timing.per_candidate_ms) {
        let status = if c.pruned {
            format!("pruned (bound {:.3} it/s)", c.bound_throughput)
        } else if !c.reachable {
            "unreachable".to_string()
        } else {
            format!("{:.3} it/s", c.throughput)
        };
        println!(
            "{:10} mbs {:>2} x{:<3} {:>26}   [{:7.1} ms]",
            c.strategy.notation(),
            c.micro_batch_size,
            c.micro_batches,
            status,
            ms
        );
    }
    let (best, worst) = (report.best(), report.worst());
    match (best, worst) {
        (Some(b), Some(w)) => println!(
            "\nbest {} ({:.3} it/s), worst {} ({:.3} it/s): {:.2}x speedup",
            b.strategy,
            b.throughput,
            w.strategy,
            w.throughput,
            report.speedup().unwrap_or(f64::NAN)
        ),
        _ => println!("\nno reachable candidate for this model/cluster"),
    }
    println!(
        "{} candidates: {} evaluated, {} pruned, on {} threads in {:.3} s",
        report.candidates.len(),
        report.evaluated_count(),
        report.pruned_count(),
        report.threads_used,
        report.timing.total_seconds
    );
    println!(
        "profiling: {:.2} gpu-s over {} unique events; cache {} hits / {} misses ({:.0}% hit rate)",
        report.profile.gpu_seconds,
        report.profile.events_profiled,
        report.cache.hits,
        report.cache.misses,
        report.cache.hit_rate() * 100.0
    );
    Ok(())
}

fn cmd_calibrate(flags: &HashMap<String, String>) -> anyhow::Result<()> {
    let dir = flags
        .get("artifacts")
        .map(std::path::PathBuf::from)
        .unwrap_or_else(distsim::runtime::artifacts_dir);
    let iters = usize_flag(flags, "iters", 5);
    println!("measuring AOT artifacts in {} (PJRT-CPU) ...", dir.display());
    let mut cal = distsim::profile::calibrate::measure_artifacts(&dir, iters)?;
    let host_tflops = cal.host_gflops / 1e3;
    distsim::profile::calibrate::fit_scale(
        &mut cal,
        &distsim::cost::CostModel::default(),
        host_tflops,
    );
    for p in &cal.points {
        println!(
            "  {:28} {:>12.1} us  {:>8.2} GFLOP/s",
            p.name,
            p.measured_us,
            p.flops as f64 / p.measured_us / 1e3
        );
    }
    println!("host peak observed: {:.2} GFLOP/s", cal.host_gflops);
    let out = flag(flags, "out", "calibration.json");
    cal.save(std::path::Path::new(out))?;
    println!("wrote {out}");
    Ok(())
}

fn cmd_exp(pos: &[String], flags: &HashMap<String, String>) -> anyhow::Result<()> {
    let which = pos.first().map(String::as_str).unwrap_or("all");
    let fast = flags.contains_key("fast");
    // iteration budgets: paper uses 100-iteration averages; --fast trims
    let (gt_iters, prof_iters, f10_runs) = if fast { (5, 10, 10) } else { (30, 100, 100) };

    let run_one = |name: &str| -> anyhow::Result<()> {
        match name {
            "fig3" => distsim::exp::fig3::print(&distsim::exp::fig3::run(gt_iters)?),
            "fig8" => distsim::exp::fig8::print(&distsim::exp::fig8::run(gt_iters, prof_iters)?),
            "fig9" => distsim::exp::fig9::print(&distsim::exp::fig9::run(prof_iters)?),
            "fig10" => {
                distsim::exp::fig10::print(&distsim::exp::fig10::run(f10_runs, prof_iters)?)
            }
            "fig11" => distsim::exp::fig11::print(&distsim::exp::fig11::run(prof_iters)?),
            "fig12" | "table2" => {
                distsim::exp::fig12::print(&distsim::exp::fig12::run(prof_iters, gt_iters)?)
            }
            "table3" => distsim::exp::table3::print(&distsim::exp::table3::run(prof_iters, 100)?),
            "ablate-allreduce" => {
                distsim::exp::ablate::print_allreduce(&distsim::exp::ablate::allreduce(prof_iters)?)
            }
            "ablate-noise" => {
                distsim::exp::ablate::print_noise(&distsim::exp::ablate::noise(gt_iters, prof_iters)?)
            }
            "ablate-hierarchy" => distsim::exp::ablate::print_hierarchy(
                &distsim::exp::ablate::hierarchy(gt_iters, prof_iters)?,
            ),
            "ablate-schedule" => distsim::exp::ablate::print_schedules(
                &distsim::exp::ablate::schedules(prof_iters)?,
            ),
            other => anyhow::bail!("unknown experiment '{other}'"),
        }
        Ok(())
    };

    if which == "all" {
        for name in [
            "fig3", "fig8", "fig9", "fig10", "fig11", "fig12", "table3",
            "ablate-allreduce", "ablate-noise", "ablate-hierarchy",
            "ablate-schedule",
        ] {
            run_one(name)?;
        }
        Ok(())
    } else {
        run_one(which)
    }
}
