//! The long-lived what-if daemon: transports, worker pool, cache registry
//! and the per-connection in-order response writer.
//!
//! ## Architecture
//!
//! ```text
//!  stdin / TCP conns --> reader(s) --parse--> bounded job queue --> workers
//!          |  control ops (ping/stats/      |  (per-conn seq)        |
//!          |  shutdown/cancel) answered     |  full => structured    |  sweeps share
//!          |  inline by the reader          |  `unavailable` shed    |  per-fingerprint
//!          v                                v                        v  ProfileCaches
//!      done map ((conn, seq) -> outcome) <--------------------------+
//!          |
//!          v
//!      writer: per-connection pipelines — each connection's responses
//!      in *its own* admission order, cache stats re-accounted
//!      "as-if-serial" against a per-connection prior
//! ```
//!
//! **Per-connection ordering (ISSUE 6).** Responses are delivered in
//! per-connection admission order: connection C's k-th request gets C's
//! k-th response line, but one connection's slow sweep never delays
//! another connection's responses — a `ping` on an idle connection is
//! answered immediately while a neighbour's sweep runs (the daemon used
//! to deliver in *global* admission order, head-of-line blocking every
//! client behind the slowest). Control ops (`ping`, `stats`, `shutdown`,
//! `cancel`) are answered inline by the connection's reader without
//! entering the job queue, so they stay prompt even when the queue is
//! full.
//!
//! **Determinism.** Each request's deterministic payload (candidates,
//! throughputs) depends only on the request itself — profiled costs are
//! functions of (descriptor, cluster, cost, protocol), never of which
//! request measured them first. Cache hit/miss accounting *would* be racy
//! under sharing, so the writer recomputes it deterministically,
//! re-scoped **per connection** (DESIGN.md §4.2): request k of connection
//! C charges as misses exactly its unique events not in the union of the
//! loaded snapshot and C's *own* requests 0..k-1 — a pure function of
//! C's request sequence. Each connection's response stream is therefore
//! bit-identical for any worker count, any cross-connection
//! interleaving, and any traffic on other connections
//! (`tests/saturation.rs` pins 1-vs-4 workers byte-for-byte across ~100
//! connections). The conceptual global merge order is the writer's
//! `(connection, per-connection seq)` key order — deterministic, but no
//! response ever waits on another connection's progress, because no
//! response *depends* on another connection's requests. Three deliberate
//! exceptions opt out of the contract: the `stats` op (a diagnostic —
//! live cache occupancy at write time), `budget.deadline_ms` requests
//! (whether the deadline expired is wall-clock), and cancelled sweeps
//! (which candidate boundary observes the token is wall-clock). Requests
//! using none of those are never affected.
//!
//! **Fairness and backpressure.** Jobs start in global admission order
//! (FIFO queue) over the shared worker pool; responses are *delivered*
//! per connection as soon as that connection's turn comes. The admission
//! queue is bounded ([`ServeOpts::max_queue`], `--max-queue`, default
//! [`DEFAULT_MAX_QUEUE`]): a sweep that would overflow it is answered
//! immediately with a structured `unavailable` error (load shed) instead
//! of growing the queue without bound. A job racing with shutdown gets
//! the same `unavailable` kind — the request was well-formed; the daemon
//! just can't serve it. Deadlines (`budget.deadline_ms`) bound queue
//! wait only: an expired request is answered with a structured
//! `deadline` error before it starts, and a request that did start
//! always runs to completion — wall-clock never truncates a payload.
//!
//! **Cancellation.** `{"op":"cancel","target":ID}` aborts the same
//! connection's queued or running sweep whose request id is `ID`: a
//! queued job is yanked from the queue outright (its response is a
//! `cancelled` error, the cancel's own response reports
//! `"cancelled_queued"`); a running sweep's [`CancelToken`] fires and
//! the engine stops at the next candidate-evaluation boundary
//! (`"cancelling"`, and the sweep answers with a `cancelled` error when
//! it stops); anything else — finished, unknown, or submitted without an
//! id — is `"not_found"`. Cancellation is cooperative and best-effort:
//! a sweep that completes before its token is observed completes
//! normally from the engine's point of view, but its report is
//! discarded and a `cancelled` error is answered (cancel wins).
//!
//! **Crash-resilience.** A panicking sweep is caught (`catch_unwind`)
//! and answered as an `internal` error; mutexes it may have poisoned on
//! the way out are recovered ([`crate::search::cache::lock_recover`] —
//! every guarded structure here is append-only, so recovery is safe)
//! rather than killing every later locker and wedging the daemon.

use std::collections::{BTreeMap, HashMap, HashSet, VecDeque};
use std::io::{BufRead, BufReader, Write};
use std::net::{Shutdown as NetShutdown, TcpListener, TcpStream};
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::path::PathBuf;
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::time::{Duration, Instant};

use crate::cluster::ClusterSpec;
use crate::config::Json;
use crate::cost::CostBook;
use crate::model::ModelSpec;
use crate::search::cache::lock_recover;
use crate::search::{
    fingerprint, stats_against, CancelToken, ProfileCache, SearchEngine, SweepConfig, SweepPlan,
    SweepReport, TableMemo,
};

use crate::telemetry::{LogLevel, Logger, RequestTrace, ServiceMetrics};

use super::protocol::{self, ErrorKind, Request, ServiceError, SweepRequest};

/// Default admission-queue bound when [`ServeOpts::max_queue`] is 0:
/// generous enough that well-behaved clients never see it, small enough
/// that a runaway client sheds load instead of growing memory without
/// bound.
pub const DEFAULT_MAX_QUEUE: usize = 1024;

/// Daemon configuration (transport-independent).
#[derive(Debug, Clone, Default)]
pub struct ServeOpts {
    /// Concurrent sweep workers; 0 = `available_parallelism`.
    pub workers: usize,
    /// Directory for profile-cache snapshots (`cache-<fingerprint>.json`),
    /// loaded lazily per fingerprint and saved back on shutdown/EOF.
    pub cache_dir: Option<PathBuf>,
    /// Additionally persist cache snapshots every this often while the
    /// daemon runs (`distsim serve --save-interval <secs>`), so a crash
    /// or kill loses at most one interval's measurements. Writes are
    /// atomic (tmp file + rename), so a reader — or a crash mid-write —
    /// never observes a torn snapshot. No-op without a cache dir.
    pub save_interval: Option<Duration>,
    /// Bound on queued (admitted, not yet started) sweeps; a sweep that
    /// would overflow it is answered with a structured `unavailable`
    /// error instead (`--max-queue`). 0 means [`DEFAULT_MAX_QUEUE`].
    pub max_queue: usize,
    /// Severity threshold of the structured stderr logger
    /// (`--log-level`; default `info`). Events are one-line JSON objects
    /// with a stable schema — see [`crate::telemetry::log`].
    pub log_level: LogLevel,
    /// Write one Chrome-trace JSON file per completed sweep
    /// (`trace-conn<conn>-seq<seq>.json`) under this directory
    /// (`--trace-dir`). Implies lifecycle tracing for every sweep; the
    /// response payload is unaffected unless the request also sets
    /// `sweep.trace` (DESIGN.md §9).
    pub trace_dir: Option<PathBuf>,
    /// Test-only fault injection: a sweep whose request id equals this
    /// panics inside the worker while holding the profile-cache entries
    /// lock, exercising the poisoned-lock recovery path end to end. Not
    /// reachable from the CLI.
    #[doc(hidden)]
    pub panic_inject_id: Option<String>,
}

impl ServeOpts {
    /// The admission-queue bound actually enforced (0 → the default).
    pub fn effective_max_queue(&self) -> usize {
        if self.max_queue == 0 {
            DEFAULT_MAX_QUEUE
        } else {
            self.max_queue
        }
    }
}

/// What a daemon run did, for callers that want to report it.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct ServeSummary {
    pub requests: usize,
    pub sweeps: usize,
    pub errors: usize,
    /// Snapshots written on exit (0 without a cache dir).
    pub snapshots_saved: usize,
}

// ---------------------------------------------------------------------------
// cache registry

struct RegistryEntry {
    cache: Arc<ProfileCache>,
    /// Keys restored from the on-disk snapshot (the accounting prior).
    preloaded: Arc<HashSet<String>>,
    // identity needed to save the snapshot back
    cluster: ClusterSpec,
    cost: CostBook,
    protocol: (f64, usize, u64),
}

/// Compiled sweep plans shared daemon-wide, one slot per request-*shape*
/// fingerprint ([`SweepPlan::shape_fingerprint`]) — deltas that keep the
/// shape (cost-book edits, capacity caps, scenario salts) land on the
/// same slot so [`SweepPlan::launch`] can reuse the untouched components.
/// Always on and fully transparent to clients: the plan feeds the engine
/// the exact components the cold path would recompute, so sweep payloads
/// stay byte-identical; only the `stats`/`metrics` ops see the accounting.
///
/// Every [`PlanCache::resolve`] increments exactly one of the three
/// counters, so `compiles + hits + partial` equals the number of
/// plan-cached sweeps — the invariant the `stats` op's `plans` block and
/// the `plan_*_total` metric families both report.
#[derive(Default)]
pub struct PlanCache {
    /// Device-class-keyed canonical-table memo shared by every compile
    /// (the satellite hoist: one enumeration per fleet, not per request).
    tables: TableMemo,
    map: Mutex<HashMap<u64, Arc<SweepPlan>>>,
    /// Cold compiles (no plan for the shape yet).
    compiles: AtomicUsize,
    /// Full hits (every component reused, zero recomputation).
    hits: AtomicUsize,
    /// Partial reuses (same shape, at least one component rebuilt — or a
    /// scenario-only delta, which rebuilds nothing but is not a full hit).
    partial: AtomicUsize,
}

impl PlanCache {
    pub fn new() -> Self {
        Self::default()
    }

    /// The plan for a request, compiled/launched as needed. The
    /// `plan_compile_us` histogram is observed whenever any compilation
    /// ran — cold compiles and partial reuses, never full hits. The
    /// `plan_*_total` families are *not* incremented here: they are
    /// sampled from [`PlanCache::counters`] at metrics-exposition time,
    /// exactly like the scenario totals, so `stats` and `metrics` always
    /// reconcile.
    ///
    /// Compilation happens *outside* the map lock (the same invariant
    /// [`CacheRegistry::resolve`] documents for snapshot I/O), so two
    /// workers racing on a cold shape may both compile — the duplicate
    /// work is idempotent (identical components, identical response
    /// bytes); only the accounting split between `compiles` and `hits`
    /// depends on the interleaving, which is why the `stats` op is
    /// documented as diagnostic rather than deterministic.
    pub fn resolve(
        &self,
        model: &ModelSpec,
        cluster: &ClusterSpec,
        book: &CostBook,
        cfg: &SweepConfig,
        metrics: &ServiceMetrics,
    ) -> Arc<SweepPlan> {
        let shape = SweepPlan::shape_fingerprint(model, cluster, cfg);
        let existing = lock_recover(&self.map).get(&shape).cloned();
        match existing {
            Some(plan) => {
                let reuse = plan.reuse_against(model, cluster, book, cfg);
                if reuse.full_hit() {
                    self.hits.fetch_add(1, Ordering::Relaxed);
                    return plan;
                }
                let t0 = Instant::now();
                let (next, _) = plan.launch(model, cluster, book, cfg, Some(&self.tables));
                metrics
                    .plan_compile_us
                    .observe_us(t0.elapsed().as_micros() as u64);
                self.partial.fetch_add(1, Ordering::Relaxed);
                let next = Arc::new(next);
                lock_recover(&self.map).insert(shape, next.clone());
                next
            }
            None => {
                let t0 = Instant::now();
                let plan = Arc::new(SweepPlan::compile_memo(
                    model,
                    cluster,
                    book,
                    cfg,
                    Some(&self.tables),
                ));
                metrics
                    .plan_compile_us
                    .observe_us(t0.elapsed().as_micros() as u64);
                self.compiles.fetch_add(1, Ordering::Relaxed);
                lock_recover(&self.map).insert(shape, plan.clone());
                plan
            }
        }
    }

    /// `(compiles, full hits, partial reuses)` since startup.
    pub fn counters(&self) -> (usize, usize, usize) {
        (
            self.compiles.load(Ordering::Relaxed),
            self.hits.load(Ordering::Relaxed),
            self.partial.load(Ordering::Relaxed),
        )
    }

    /// Distinct request shapes currently holding a plan.
    pub fn len(&self) -> usize {
        lock_recover(&self.map).len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

/// Shared profile caches, one per (cluster, cost, protocol) fingerprint —
/// the daemon-lifetime generalization of a sweep's single cache.
#[derive(Default)]
pub struct CacheRegistry {
    dir: Option<PathBuf>,
    /// Structured logger for snapshot-load/save diagnostics.
    log: Logger,
    map: Mutex<HashMap<String, RegistryEntry>>,
    /// Compiled sweep plans, beside the profile caches (ISSUE 10): the
    /// profile cache shares *measurements* across sweeps, the plan cache
    /// shares *planning* across sweeps.
    plans: PlanCache,
    /// Scenario-bearing sweeps served since startup (the `stats` op's
    /// `scenario.sweeps` counter).
    scenario_sweeps: AtomicUsize,
    /// Episodes those sweeps' specs carried (`scenario.episodes`).
    scenario_episodes: AtomicUsize,
}

impl CacheRegistry {
    pub fn new(dir: Option<PathBuf>) -> Self {
        CacheRegistry {
            dir,
            log: Logger::default(),
            map: Mutex::new(HashMap::new()),
            plans: PlanCache::new(),
            scenario_sweeps: AtomicUsize::new(0),
            scenario_episodes: AtomicUsize::new(0),
        }
    }

    /// Route diagnostics through `log` (builder-style).
    pub fn with_log(mut self, log: Logger) -> Self {
        self.log = log;
        self
    }

    /// Count one scenario-bearing sweep and its spec's episodes.
    pub fn record_scenario(&self, episodes: usize) {
        self.scenario_sweeps.fetch_add(1, Ordering::Relaxed);
        self.scenario_episodes.fetch_add(episodes, Ordering::Relaxed);
    }

    /// The daemon-wide plan cache.
    pub fn plans(&self) -> &PlanCache {
        &self.plans
    }

    /// `(scenario sweeps served, episodes simulated)` since startup.
    pub fn scenario_counters(&self) -> (usize, usize) {
        (
            self.scenario_sweeps.load(Ordering::Relaxed),
            self.scenario_episodes.load(Ordering::Relaxed),
        )
    }

    fn snapshot_path(dir: &std::path::Path, fp: &str) -> PathBuf {
        dir.join(format!("cache-{fp}.json"))
    }

    /// The cache for a request's fingerprint, loading a matching snapshot
    /// from disk the first time the fingerprint is seen.
    ///
    /// Snapshot I/O happens *outside* the registry lock so a large load
    /// for one fingerprint never stalls workers resolving other (or
    /// already-resident) caches; if two workers race on a cold
    /// fingerprint, both load and the entry API keeps the first — the
    /// duplicate work is idempotent (same file, same values).
    fn resolve(
        &self,
        cluster: &ClusterSpec,
        cost: &CostBook,
        jitter: f64,
        iters: usize,
        seed: u64,
    ) -> (String, Arc<ProfileCache>, Arc<HashSet<String>>) {
        let fp = fingerprint(cluster, cost, jitter, iters, seed);
        if let Some(e) = lock_recover(&self.map).get(&fp) {
            return (fp, e.cache.clone(), e.preloaded.clone());
        }
        let loaded = self.dir.as_deref().and_then(|d| {
            let path = Self::snapshot_path(d, &fp);
            let text = std::fs::read_to_string(&path).ok()?;
            match Json::parse(&text)
                .map_err(anyhow::Error::from)
                .and_then(|j| ProfileCache::load_json(&j))
            {
                Ok(snap) if snap.fingerprint == fp => Some(snap),
                Ok(snap) => {
                    self.log.warn(
                        "snapshot_ignored",
                        &[
                            ("path", Json::str(path.display().to_string())),
                            ("found", Json::str(&snap.fingerprint)),
                            ("expected", Json::str(&fp)),
                        ],
                    );
                    None
                }
                Err(e) => {
                    self.log.warn(
                        "snapshot_ignored",
                        &[
                            ("path", Json::str(path.display().to_string())),
                            ("error", Json::str(e.to_string())),
                        ],
                    );
                    None
                }
            }
        });
        let fresh = match loaded {
            Some(snap) => RegistryEntry {
                cache: Arc::new(snap.cache),
                preloaded: Arc::new(snap.keys),
                cluster: snap.cluster,
                cost: snap.cost,
                protocol: snap.protocol,
            },
            None => RegistryEntry {
                cache: Arc::new(ProfileCache::new()),
                preloaded: Arc::new(HashSet::new()),
                cluster: cluster.clone(),
                cost: cost.clone(),
                protocol: (jitter, iters, seed),
            },
        };
        let mut map = lock_recover(&self.map);
        let entry = map.entry(fp.clone()).or_insert(fresh);
        let out = (entry.cache.clone(), entry.preloaded.clone());
        (fp, out.0, out.1)
    }

    /// (fingerprint, measured entries) per cache, sorted by fingerprint.
    pub fn summary(&self) -> Vec<(String, usize)> {
        let map = lock_recover(&self.map);
        let mut v: Vec<(String, usize)> = map
            .iter()
            .map(|(fp, e)| (fp.clone(), e.cache.measured_len()))
            .collect();
        v.sort();
        v
    }

    /// Persist every cache with at least one measurement. Returns how many
    /// snapshot files were written.
    ///
    /// Each snapshot is written to a `.tmp` sibling and atomically
    /// renamed into place, so a concurrent reader (or a crash mid-write)
    /// never observes a torn file — the invariant the periodic
    /// `--save-interval` saver relies on.
    pub fn save_all(&self) -> usize {
        let Some(dir) = self.dir.as_deref() else {
            return 0;
        };
        if let Err(e) = std::fs::create_dir_all(dir) {
            self.log.warn(
                "cache_dir_error",
                &[
                    ("path", Json::str(dir.display().to_string())),
                    ("error", Json::str(e.to_string())),
                ],
            );
            return 0;
        }
        // serialization and disk I/O happen OUTSIDE the registry lock —
        // the same invariant resolve() documents — so a periodic save
        // never stalls workers admitting requests
        type Entry = (String, Arc<ProfileCache>, ClusterSpec, CostBook, (f64, usize, u64));
        let entries: Vec<Entry> = {
            let map = lock_recover(&self.map);
            map.iter()
                .filter(|(_, e)| e.cache.measured_len() > 0)
                .map(|(fp, e)| {
                    (
                        fp.clone(),
                        e.cache.clone(),
                        e.cluster.clone(),
                        e.cost.clone(),
                        e.protocol,
                    )
                })
                .collect()
        };
        let mut saved = 0;
        for (fp, cache, cluster, cost, (jitter, iters, seed)) in entries {
            let json = cache.save_json(&cluster, &cost, jitter, iters, seed);
            let path = Self::snapshot_path(dir, &fp);
            let tmp = path.with_extension("json.tmp");
            let res = json
                .write_file(&tmp)
                .and_then(|()| {
                    std::fs::rename(&tmp, &path).map_err(|e| {
                        anyhow::anyhow!(
                            "cannot move snapshot into place at {}: {e}",
                            path.display()
                        )
                    })
                });
            match res {
                Ok(()) => {
                    saved += 1;
                    self.log.debug(
                        "snapshot_saved",
                        &[("path", Json::str(path.display().to_string()))],
                    );
                }
                Err(err) => {
                    std::fs::remove_file(&tmp).ok();
                    self.log.warn(
                        "snapshot_write_failed",
                        &[("error", Json::str(err.to_string()))],
                    );
                }
            }
        }
        saved
    }
}

/// The periodic snapshot saver: parks on a condvar with the configured
/// interval and calls [`CacheRegistry::save_all`] until stopped (final
/// shutdown saves happen separately, after the writer drains).
struct PeriodicSaver {
    stop: Mutex<bool>,
    cv: Condvar,
}

impl PeriodicSaver {
    fn new() -> Self {
        PeriodicSaver {
            stop: Mutex::new(false),
            cv: Condvar::new(),
        }
    }

    fn run(&self, registry: &CacheRegistry, interval: Duration) {
        let mut stopped = lock_recover(&self.stop);
        loop {
            let (guard, timeout) = self
                .cv
                .wait_timeout(stopped, interval)
                .unwrap_or_else(|e| e.into_inner());
            stopped = guard;
            if *stopped {
                return;
            }
            if timeout.timed_out() {
                drop(stopped);
                registry.save_all();
                stopped = lock_recover(&self.stop);
            }
        }
    }

    fn stop(&self) {
        *lock_recover(&self.stop) = true;
        self.cv.notify_all();
    }
}

// ---------------------------------------------------------------------------
// shared daemon state

enum Outcome {
    Sweep {
        report: Box<SweepReport>,
        fp: String,
        preloaded: Arc<HashSet<String>>,
        include_timing: bool,
        /// Attach the quantized `trace` block (`sweep.trace: true`).
        include_trace: bool,
        /// The job's lifecycle recorder (disabled unless requested or
        /// `--trace-dir` is set).
        trace: RequestTrace,
    },
    Error(ServiceError),
    Cancel {
        target: String,
        /// `"cancelled_queued"` | `"cancelling"` | `"not_found"` — see
        /// [`protocol::cancel_response`].
        outcome: &'static str,
    },
    Pong,
    Stats,
    /// Telemetry registry snapshot (both exposition forms), assembled by
    /// the writer at delivery time like `Stats`.
    Metrics,
    Shutdown,
}

struct Completed {
    id: Option<String>,
    conn: usize,
    outcome: Outcome,
}

struct Job {
    /// Per-connection admission index (the writer delivers `conn`'s
    /// responses in this order).
    seq: u64,
    conn: usize,
    req: Box<SweepRequest>,
    admitted_at: Instant,
    /// Fired by a `cancel` op targeting this job's id.
    cancel: CancelToken,
    /// Lifecycle span recorder; its epoch is the admission instant.
    trace: RequestTrace,
}

/// Cancellation handle for an admitted-but-unfinished sweep, kept in
/// [`Shared::active`] under `(conn, request id)`. The `seq` disambiguates
/// reused ids on one connection (last one wins; a stale completion only
/// unregisters its own seq).
#[derive(Clone)]
struct JobHandle {
    seq: u64,
    cancel: CancelToken,
}

#[derive(Default)]
struct DoneState {
    /// Finished outcomes awaiting delivery, keyed by `(conn, per-conn
    /// seq)` — the writer's deterministic merge order.
    ready: BTreeMap<(usize, u64), Completed>,
    /// Total requests admitted across all connections; the writer exits
    /// once it has emitted all of them after close.
    admitted: u64,
    closed: bool,
}

#[derive(Default)]
struct QueueState {
    jobs: VecDeque<Job>,
    closed: bool,
}

/// Per-connection liveness: undelivered responses + whether the reader
/// has exited (plus the connection's admission counter). Lets the TCP
/// transport reclaim a finished connection's socket as soon as its last
/// response goes out — without dropping queued responses for half-close
/// clients (write shut, still reading).
#[derive(Default)]
struct ConnLive {
    outstanding: usize,
    reader_done: bool,
    /// Next per-connection sequence number to assign.
    next_seq: u64,
}

struct Shared {
    queue: Mutex<QueueState>,
    queue_cv: Condvar,
    done: Mutex<DoneState>,
    done_cv: Condvar,
    conns_live: Mutex<HashMap<usize, ConnLive>>,
    /// Cancellation handles of admitted-but-unfinished sweeps that carry
    /// a request id ((conn, id) → handle); id-less sweeps are not
    /// addressable and never enter.
    active: Mutex<HashMap<(usize, String), JobHandle>>,
    /// Bound on `queue.jobs` ([`ServeOpts::effective_max_queue`]).
    max_queue: usize,
    /// Set when a shutdown op is admitted: transports stop reading.
    stopping: AtomicBool,
    /// The daemon's telemetry registry (the `metrics` op's source).
    metrics: ServiceMetrics,
}

impl Shared {
    fn new(max_queue: usize) -> Self {
        Shared {
            queue: Mutex::default(),
            queue_cv: Condvar::new(),
            done: Mutex::default(),
            done_cv: Condvar::new(),
            conns_live: Mutex::default(),
            active: Mutex::default(),
            max_queue,
            stopping: AtomicBool::new(false),
            metrics: ServiceMetrics::new(),
        }
    }

    /// Admit one request from `conn`, assigning its per-connection
    /// sequence number (the slot its response will be delivered in).
    fn admit(&self, conn: usize) -> u64 {
        let seq = {
            let mut map = lock_recover(&self.conns_live);
            let c = map.entry(conn).or_default();
            c.outstanding += 1;
            let seq = c.next_seq;
            c.next_seq += 1;
            seq
        };
        lock_recover(&self.done).admitted += 1;
        seq
    }

    /// One response delivered for `conn`; true when the connection is
    /// finished (reader gone, nothing left to deliver) and can be closed.
    fn response_delivered(&self, conn: usize) -> bool {
        let mut map = lock_recover(&self.conns_live);
        if let Some(c) = map.get_mut(&conn) {
            c.outstanding = c.outstanding.saturating_sub(1);
            if c.reader_done && c.outstanding == 0 {
                map.remove(&conn);
                return true;
            }
        }
        false
    }

    /// `conn`'s reader exited; true when nothing is pending and the
    /// connection can be closed right away.
    fn reader_finished(&self, conn: usize) -> bool {
        let mut map = lock_recover(&self.conns_live);
        let c = map.entry(conn).or_default();
        c.reader_done = true;
        if c.outstanding == 0 {
            map.remove(&conn);
            true
        } else {
            false
        }
    }

    fn complete(&self, conn: usize, seq: u64, c: Completed) {
        let mut done = lock_recover(&self.done);
        done.ready.insert((conn, seq), c);
        self.done_cv.notify_all();
    }

    /// Register a cancellation handle for an admitted sweep with an id.
    /// A duplicate id on one connection replaces the handle: the *last*
    /// job under an id is the cancellable one.
    fn register_active(&self, conn: usize, id: &Option<String>, handle: JobHandle) {
        if let Some(id) = id {
            lock_recover(&self.active).insert((conn, id.clone()), handle);
        }
    }

    /// Drop `(conn, id)`'s handle, but only if it still belongs to `seq`
    /// (a reused id may have re-registered a newer job).
    fn unregister_active(&self, conn: usize, id: &Option<String>, seq: u64) {
        if let Some(id) = id {
            let mut active = lock_recover(&self.active);
            let key = (conn, id.clone());
            if active.get(&key).map(|h| h.seq) == Some(seq) {
                active.remove(&key);
            }
        }
    }

    /// Cancel `conn`'s sweep with request id `target`. Returns the
    /// outcome word for [`protocol::cancel_response`].
    fn cancel_target(&self, conn: usize, target: &str) -> &'static str {
        let handle = lock_recover(&self.active)
            .get(&(conn, target.to_string()))
            .cloned();
        let Some(handle) = handle else {
            return "not_found";
        };
        // fire the token first: if the job is mid-sweep this is the
        // cooperative interrupt; if it is still queued the yank below
        // answers it without ever starting
        handle.cancel.cancel();
        let yanked = {
            let mut q = lock_recover(&self.queue);
            let yanked = q
                .jobs
                .iter()
                .position(|j| j.conn == conn && j.seq == handle.seq)
                .and_then(|pos| q.jobs.remove(pos));
            self.metrics.queue_depth.set(q.jobs.len() as u64);
            yanked
        };
        match yanked {
            Some(job) => {
                self.unregister_active(conn, &job.req.id, job.seq);
                self.complete(
                    conn,
                    job.seq,
                    Completed {
                        id: job.req.id.clone(),
                        conn,
                        outcome: Outcome::Error(ServiceError::new(
                            ErrorKind::Cancelled,
                            format!("sweep '{target}' cancelled while queued"),
                        )),
                    },
                );
                "cancelled_queued"
            }
            // not queued: either mid-sweep (the token interrupts it at
            // the next candidate boundary) or finishing right now (the
            // worker's post-sweep token check answers `cancelled`)
            None => "cancelling",
        }
    }

    /// Answer an admitted job that will never run with an `unavailable`
    /// error (queue full, or racing with shutdown).
    fn shed_job(&self, job: Job, err: ServiceError) {
        self.unregister_active(job.conn, &job.req.id, job.seq);
        self.complete(
            job.conn,
            job.seq,
            Completed {
                id: job.req.id.clone(),
                conn: job.conn,
                outcome: Outcome::Error(err),
            },
        );
    }

    fn enqueue(&self, job: Job) {
        let mut q = lock_recover(&self.queue);
        if q.closed {
            // raced with shutdown: answer rather than silently dropping.
            // `unavailable`, not `bad_request` — the request was fine.
            drop(q);
            self.metrics.shed_shutdown_total.inc();
            self.shed_job(
                job,
                ServiceError::new(ErrorKind::Unavailable, "daemon is shutting down"),
            );
            return;
        }
        if q.jobs.len() >= self.max_queue {
            // bounded admission: shed load with a structured error
            // instead of growing the queue without bound. `depth` and
            // `max_queue` travel as machine-readable error fields so
            // clients back off without parsing the message.
            let depth = q.jobs.len();
            drop(q);
            self.metrics.shed_queue_full_total.inc();
            self.shed_job(
                job,
                ServiceError::new(
                    ErrorKind::Unavailable,
                    format!(
                        "admission queue is full ({depth} sweeps queued, --max-queue {}); \
                         retry later",
                        self.max_queue
                    ),
                )
                .with_detail("depth", Json::num(depth as f64))
                .with_detail("max_queue", Json::num(self.max_queue as f64)),
            );
            return;
        }
        q.jobs.push_back(job);
        self.metrics.queue_depth.set(q.jobs.len() as u64);
        self.metrics.queue_high_water.record_max(q.jobs.len() as u64);
        self.queue_cv.notify_one();
    }

    /// No more requests will be admitted: wake everyone so they can drain.
    fn close(&self) {
        lock_recover(&self.queue).closed = true;
        self.queue_cv.notify_all();
        lock_recover(&self.done).closed = true;
        self.done_cv.notify_all();
    }
}

// ---------------------------------------------------------------------------
// roles: reader, worker, writer

/// Read NDJSON requests from one transport until EOF or a shutdown op.
/// Returns true when this reader saw the shutdown op.
///
/// A reader never *drops* a line it managed to read: during shutdown
/// (another connection's op), lines already in flight are still admitted
/// and answered — either normally (admitted before the queue closed) or
/// with a structured shutting-down error ([`Shared::enqueue`]'s backstop).
/// Termination comes from the transport: the TCP accept loop shuts down
/// every connection's read half, which EOFs this loop.
/// `trace_all` (from `--trace-dir`) enables lifecycle tracing on every
/// sweep, independent of the per-request `sweep.trace` flag.
fn read_requests<R: BufRead>(shared: &Shared, input: R, conn: usize, trace_all: bool) -> bool {
    for line in input.lines() {
        let line = match line {
            Ok(l) => l,
            Err(_) => break, // transport error == EOF
        };
        if line.trim().is_empty() {
            continue;
        }
        match protocol::parse_line(&line) {
            Err((id, err)) => {
                let seq = shared.admit(conn);
                shared.complete(
                    conn,
                    seq,
                    Completed {
                        id,
                        conn,
                        outcome: Outcome::Error(err),
                    },
                );
            }
            Ok(Request::Ping { id }) => {
                let seq = shared.admit(conn);
                shared.complete(
                    conn,
                    seq,
                    Completed {
                        id,
                        conn,
                        outcome: Outcome::Pong,
                    },
                );
            }
            Ok(Request::Stats { id }) => {
                let seq = shared.admit(conn);
                shared.complete(
                    conn,
                    seq,
                    Completed {
                        id,
                        conn,
                        outcome: Outcome::Stats,
                    },
                );
            }
            Ok(Request::Metrics { id }) => {
                // control op like stats: answered from the registry at
                // delivery time, never queued behind sweeps
                let seq = shared.admit(conn);
                shared.complete(
                    conn,
                    seq,
                    Completed {
                        id,
                        conn,
                        outcome: Outcome::Metrics,
                    },
                );
            }
            Ok(Request::Cancel { id, target }) => {
                // control op, answered inline: a cancel must work even
                // (especially) when the job queue is saturated. Per-conn
                // ordering puts the ack *after* the target's own response
                // — the target was admitted earlier on this connection.
                let seq = shared.admit(conn);
                let outcome = shared.cancel_target(conn, &target);
                shared.complete(
                    conn,
                    seq,
                    Completed {
                        id,
                        conn,
                        outcome: Outcome::Cancel { target, outcome },
                    },
                );
            }
            Ok(Request::Shutdown { id }) => {
                shared.stopping.store(true, Ordering::SeqCst);
                let seq = shared.admit(conn);
                shared.complete(
                    conn,
                    seq,
                    Completed {
                        id,
                        conn,
                        outcome: Outcome::Shutdown,
                    },
                );
                return true;
            }
            Ok(Request::Sweep(req)) => {
                let seq = shared.admit(conn);
                let cancel = CancelToken::new();
                shared.register_active(
                    conn,
                    &req.id,
                    JobHandle {
                        seq,
                        cancel: cancel.clone(),
                    },
                );
                let trace = if trace_all || req.sweep.trace {
                    // epoch = admission: the `queue` span starts here
                    RequestTrace::enabled()
                } else {
                    RequestTrace::disabled()
                };
                shared.enqueue(Job {
                    seq,
                    conn,
                    req,
                    admitted_at: Instant::now(),
                    cancel,
                    trace,
                });
            }
        }
    }
    false
}

/// Execute one admitted sweep job end to end.
fn run_job(
    registry: &CacheRegistry,
    metrics: &ServiceMetrics,
    job: Job,
    panic_inject: Option<&str>,
) -> (u64, Completed) {
    let req = &job.req;
    // wall-clock telemetry, strictly out-of-band (DESIGN.md §9)
    metrics
        .queue_wait_us
        .observe_us(job.admitted_at.elapsed().as_micros() as u64);
    job.trace.span_since_epoch("queue");
    let answer = |outcome: Outcome| {
        (
            job.seq,
            Completed {
                id: req.id.clone(),
                conn: job.conn,
                outcome,
            },
        )
    };
    if job.cancel.is_cancelled() {
        // the cancel landed between dequeue and here: never start
        return answer(Outcome::Error(ServiceError::new(
            ErrorKind::Cancelled,
            "sweep cancelled before it started",
        )));
    }
    if let Some(deadline) = job.req.deadline_ms {
        if job.admitted_at.elapsed() > Duration::from_millis(deadline) {
            return answer(Outcome::Error(ServiceError::new(
                ErrorKind::Deadline,
                format!("deadline of {deadline} ms expired before the sweep started"),
            )));
        }
    }
    let (fp, cache, preloaded) = registry.resolve(
        &req.cluster,
        &req.cost,
        req.sweep.jitter_sigma,
        req.sweep.profile_iters,
        req.sweep.profile_seed,
    );
    // counted at start-of-run, not admission: cancelled-in-queue and
    // expired-deadline requests never simulated anything
    if !req.sweep.scenario.is_empty() {
        registry.record_scenario(req.sweep.scenario.episode_count());
    }
    let inject = panic_inject.is_some() && panic_inject == req.id.as_deref();
    let sweep_started = Instant::now();
    let sweep_span = job.trace.start("sweep");
    let outcome = match catch_unwind(AssertUnwindSafe(|| {
        if inject {
            // test-only: blow up while holding the entries lock, leaving
            // it poisoned for every later request to recover from
            cache.panic_holding_entries_lock();
        }
        // compiled-plan resolve (ISSUE 10): a repeat of an earlier
        // request's shape reuses its candidate space, bounds, memory
        // verdicts and event set — transparently, since every component
        // is bit-identical to what the engine would recompute below
        let plan = registry.plans.resolve(
            &req.model,
            &req.cluster,
            &req.cost,
            &req.sweep,
            metrics,
        );
        // the snapshot's keys are the engine's prior: in-sweep accounting
        // (pruning.gpu_seconds_avoided) then agrees with the writer's
        // as-if-serial cache block that nothing a hit would have served
        // counts as avoided or spent. (The writer still substitutes its
        // own admission-order cache stats for the engine's.)
        SearchEngine::with_book(
            &req.model,
            &req.cluster,
            req.cost.clone(),
            req.sweep.clone(),
            cache,
        )
        .with_prior((*preloaded).clone())
        .with_cancel(job.cancel.clone())
        .with_trace(job.trace.clone())
        .with_plan(plan)
        .sweep()
    })) {
        // cancel wins a finish-line race: a report produced while (or
        // after) the token fired is discarded, so the client that
        // cancelled never has to parse a full sweep payload
        Ok(_) if job.cancel.is_cancelled() => Outcome::Error(ServiceError::new(
            ErrorKind::Cancelled,
            "sweep cancelled at a candidate boundary",
        )),
        Ok(report) => Outcome::Sweep {
            report: Box::new(report),
            fp,
            preloaded,
            include_timing: req.include_timing,
            include_trace: req.sweep.trace,
            trace: job.trace.clone(),
        },
        Err(panic) => {
            let msg = panic
                .downcast_ref::<&str>()
                .map(|s| s.to_string())
                .or_else(|| panic.downcast_ref::<String>().cloned())
                .unwrap_or_else(|| "sweep panicked".to_string());
            Outcome::Error(ServiceError::new(ErrorKind::Internal, msg))
        }
    };
    drop(sweep_span);
    metrics
        .sweep_duration_us
        .observe_us(sweep_started.elapsed().as_micros() as u64);
    answer(outcome)
}

fn worker_loop(shared: &Shared, registry: &CacheRegistry, panic_inject: Option<&str>) {
    loop {
        let job = {
            let mut q = lock_recover(&shared.queue);
            loop {
                if let Some(job) = q.jobs.pop_front() {
                    shared.metrics.queue_depth.set(q.jobs.len() as u64);
                    break job;
                }
                if q.closed {
                    return;
                }
                q = shared.queue_cv.wait(q).unwrap_or_else(|e| e.into_inner());
            }
        };
        let (seq, completed) = run_job(registry, &shared.metrics, job, panic_inject);
        // unregister BEFORE completing: once the response is deliverable
        // a cancel for this id must be not_found, never a dangling handle
        shared.unregister_active(completed.conn, &completed.id, seq);
        shared.complete(completed.conn, seq, completed);
    }
}

/// Emit responses in per-connection admission order, recomputing each
/// sweep's cache stats against its *connection's* as-if-serial prior
/// (loaded snapshot ∪ that connection's earlier sweeps — a pure function
/// of the connection's own request sequence, so its stream is
/// bit-identical for any worker count or cross-connection interleaving).
/// A response is emitted as soon as it is the next one *for its
/// connection*; the `(conn, seq)` key order of `ready` is the
/// deterministic global merge order, but nothing ever waits on another
/// connection's slow sweep. `emit` receives (conn, line); `on_conn_idle`
/// fires when a connection whose reader already exited has received its
/// last pending response (transport closes it there).
fn writer_loop(
    shared: &Shared,
    registry: &CacheRegistry,
    log: Logger,
    trace_dir: Option<&std::path::Path>,
    mut emit: impl FnMut(usize, &str),
    mut on_conn_idle: impl FnMut(usize),
) -> ServeSummary {
    let mut summary = ServeSummary::default();
    // per-(conn, fingerprint) as-if-serial prior
    let mut seen: HashMap<(usize, String), HashSet<String>> = HashMap::new();
    // next deliverable per-connection seq (absent == 0: nothing emitted yet)
    let mut cursors: HashMap<usize, u64> = HashMap::new();
    let mut emitted = 0u64;
    loop {
        let completed = {
            let mut done = lock_recover(&shared.done);
            loop {
                // any connection whose head-of-line response is ready?
                let key = done
                    .ready
                    .keys()
                    .copied()
                    .find(|&(conn, seq)| seq == cursors.get(&conn).copied().unwrap_or(0));
                if let Some(key) = key {
                    break done.ready.remove(&key).expect("key just found");
                }
                if done.closed && emitted >= done.admitted {
                    return summary;
                }
                done = shared.done_cv.wait(done).unwrap_or_else(|e| e.into_inner());
            }
        };
        summary.requests += 1;
        shared.metrics.requests_total.inc();
        let conn = completed.conn;
        let seq = cursors.get(&conn).copied().unwrap_or(0);
        let id = completed.id.as_deref();
        // a completed sweep's trace, kept past serialization so the
        // Chrome-trace file (if --trace-dir) includes the `write` span
        let mut sweep_trace: Option<RequestTrace> = None;
        let line = match completed.outcome {
            Outcome::Sweep {
                report,
                fp,
                preloaded,
                include_timing,
                include_trace,
                trace,
            } => {
                summary.sweeps += 1;
                let m = &shared.metrics;
                m.sweeps_total.inc();
                let prior = seen
                    .entry((conn, fp.clone()))
                    .or_insert_with(|| (*preloaded).clone());
                let stats = stats_against(&report.event_uses, prior);
                for u in &report.event_uses {
                    prior.insert(u.key.clone());
                }
                // deterministic counters, accumulated from the same
                // as-if-serial stats the response reports
                m.cache_hits_total.add(stats.hits as u64);
                m.cache_misses_total.add(stats.misses as u64);
                m.cache_gpu_seconds.add(stats.gpu_seconds);
                m.pruning_generated_total.add(report.pruning.generated as u64);
                m.pruning_memory_pruned_total
                    .add(report.pruning.memory_pruned as u64);
                m.pruning_bound_pruned_total
                    .add(report.pruning.bound_pruned as u64);
                m.pruning_epoch_repruned_total
                    .add(report.pruning.epoch_repruned as u64);
                m.pruning_evaluated_total.add(report.pruning.evaluated as u64);
                m.pruning_gpu_seconds_avoided
                    .add(report.pruning.gpu_seconds_avoided);
                // build the opt-in trace block BEFORE the write span: a
                // response cannot contain the span of its own
                // serialization (the Chrome file can, and does)
                let trace_block = if include_trace {
                    Some(trace.to_json())
                } else {
                    None
                };
                let write_span = trace.start("write");
                let line =
                    protocol::sweep_response(id, &fp, &report, &stats, include_timing, trace_block)
                        .to_string();
                drop(write_span);
                sweep_trace = Some(trace);
                line
            }
            Outcome::Error(err) => {
                summary.errors += 1;
                shared.metrics.error_counter(err.kind).inc();
                protocol::error_response(id, &err).to_string()
            }
            Outcome::Cancel { target, outcome } => {
                let m = &shared.metrics;
                match outcome {
                    "cancelled_queued" => m.cancel_cancelled_queued_total.inc(),
                    "cancelling" => m.cancel_cancelling_total.inc(),
                    _ => m.cancel_not_found_total.inc(),
                }
                protocol::cancel_response(id, &target, outcome).to_string()
            }
            Outcome::Pong => protocol::pong_response(id).to_string(),
            Outcome::Stats => {
                let (sweeps, episodes) = registry.scenario_counters();
                protocol::stats_response(
                    id,
                    &registry.summary(),
                    sweeps,
                    episodes,
                    registry.plans().counters(),
                )
                .to_string()
            }
            Outcome::Metrics => {
                // reconcile-by-construction: the scenario and cache-
                // occupancy families are sampled from the same registry
                // the `stats` op reads, at the same delivery point
                let m = &shared.metrics;
                let (sweeps, episodes) = registry.scenario_counters();
                m.scenario_sweeps_total.set(sweeps as u64);
                m.scenario_episodes_total.set(episodes as u64);
                let (compiles, hits, partial) = registry.plans().counters();
                m.plan_compiles_total.set(compiles as u64);
                m.plan_hits_total.set(hits as u64);
                m.plan_partial_reuse_total.set(partial as u64);
                let caches = registry.summary();
                m.caches.set(caches.len() as u64);
                m.cache_events
                    .set(caches.iter().map(|(_, n)| *n as u64).sum());
                protocol::metrics_response(id, m.export_json(), &m.export_prometheus())
                    .to_string()
            }
            Outcome::Shutdown => protocol::shutdown_response(id).to_string(),
        };
        emit(conn, &line);
        log.debug(
            "request_done",
            &[
                ("conn", Json::num(conn as f64)),
                ("seq", Json::num(seq as f64)),
            ],
        );
        if let (Some(dir), Some(trace)) = (trace_dir, &sweep_trace) {
            if trace.is_enabled() {
                let path = dir.join(format!("trace-conn{conn}-seq{seq}.json"));
                match std::fs::write(&path, trace.to_chrome_json(id.unwrap_or("anon"))) {
                    Ok(()) => shared.metrics.traces_written_total.inc(),
                    Err(e) => log.warn(
                        "trace_write_failed",
                        &[
                            ("path", Json::str(path.display().to_string())),
                            ("error", Json::str(e.to_string())),
                        ],
                    ),
                }
            }
        }
        *cursors.entry(conn).or_insert(0) += 1;
        emitted += 1;
        if shared.response_delivered(conn) {
            on_conn_idle(conn);
            // a finished conn id is never reused; drop its bookkeeping so
            // a long-lived daemon doesn't accrete per-conn state forever
            cursors.remove(&conn);
            seen.retain(|(c, _), _| *c != conn);
        }
    }
}

fn resolve_workers(n: usize) -> usize {
    if n > 0 {
        n
    } else {
        std::thread::available_parallelism()
            .map(|n| n.get())
            .unwrap_or(1)
    }
}

// ---------------------------------------------------------------------------
/// Resolve `--trace-dir`: create the directory up front so per-request
/// trace writes can't half-fail, and drop the feature (with a logged
/// warning) when creation fails — tracing must never take the daemon down.
fn prepare_trace_dir(opts: &ServeOpts, log: Logger) -> Option<PathBuf> {
    let dir = opts.trace_dir.clone()?;
    match std::fs::create_dir_all(&dir) {
        Ok(()) => Some(dir),
        Err(e) => {
            log.warn(
                "trace_write_failed",
                &[
                    ("path", Json::str(dir.display().to_string())),
                    ("error", Json::str(e.to_string())),
                ],
            );
            None
        }
    }
}

// transports

/// Serve one NDJSON stream (stdin/stdout, or any reader/writer pair — the
/// in-process entry point tests and `distsim ask` use). Returns after EOF
/// or a `shutdown` op, once every admitted request has been answered and
/// snapshots are saved.
pub fn serve_ndjson<R: BufRead, W: Write + Send>(
    input: R,
    output: W,
    opts: &ServeOpts,
) -> ServeSummary {
    let log = Logger::new(opts.log_level);
    let registry = CacheRegistry::new(opts.cache_dir.clone()).with_log(log);
    let shared = Shared::new(opts.effective_max_queue());
    let workers = resolve_workers(opts.workers);
    let saver = PeriodicSaver::new();
    let trace_dir = prepare_trace_dir(opts, log);
    let mut summary = ServeSummary::default();
    std::thread::scope(|scope| {
        for _ in 0..workers {
            scope.spawn(|| worker_loop(&shared, &registry, opts.panic_inject_id.as_deref()));
        }
        if let Some(interval) = opts.save_interval.filter(|_| opts.cache_dir.is_some()) {
            scope.spawn(|| saver.run(&registry, interval));
        }
        let writer = scope.spawn({
            let shared = &shared;
            let registry = &registry;
            let mut output = output;
            move || {
                writer_loop(
                    shared,
                    registry,
                    log,
                    trace_dir.as_deref(),
                    |_conn, line| {
                        // a broken pipe must not kill the drain: log and move on
                        if writeln!(output, "{line}").and_then(|()| output.flush()).is_err() {
                            log.warn(
                                "response_dropped",
                                &[("reason", Json::str("output closed"))],
                            );
                        }
                    },
                    |_conn| {}, // single stream: nothing to close per-conn
                )
            }
        });
        read_requests(&shared, input, 0, trace_dir.is_some());
        shared.close();
        summary = writer.join().expect("writer panicked");
        saver.stop();
    });
    summary.snapshots_saved = registry.save_all();
    summary
}

/// Split an accepted TCP stream into (write half, read half), or clean up
/// and return `None` when the clone failed — the client is answered with
/// one structured `unavailable` line and the socket is shut down, so a
/// clone failure never leaks a registered-but-unreadable connection (the
/// old code inserted the stream into the connection table *before*
/// checking the clone, stranding the fd until shutdown).
fn split_accepted(
    stream: TcpStream,
    read_half: std::io::Result<TcpStream>,
) -> Option<(TcpStream, TcpStream)> {
    match read_half {
        Ok(read_half) => Some((stream, read_half)),
        Err(e) => {
            let err = ServiceError::new(
                ErrorKind::Unavailable,
                format!("connection setup failed (cannot clone socket): {e}"),
            );
            let mut s = &stream;
            let line = protocol::error_response(None, &err).to_string();
            writeln!(s, "{line}").ok();
            stream.shutdown(NetShutdown::Both).ok();
            None
        }
    }
}

/// Serve TCP connections on `listener`. Each connection is an independent
/// NDJSON stream multiplexed onto the shared queue, worker pool and cache
/// registry; each connection's responses are delivered in its *own*
/// admission order, independent of other connections' progress. Returns
/// when any connection sends a `shutdown` op.
pub fn serve_tcp(listener: TcpListener, opts: &ServeOpts) -> anyhow::Result<ServeSummary> {
    let log = Logger::new(opts.log_level);
    let registry = CacheRegistry::new(opts.cache_dir.clone()).with_log(log);
    let shared = Shared::new(opts.effective_max_queue());
    let workers = resolve_workers(opts.workers);
    let saver = PeriodicSaver::new();
    let trace_dir = prepare_trace_dir(opts, log);
    listener.set_nonblocking(true)?;
    let conns: Mutex<HashMap<usize, TcpStream>> = Mutex::new(HashMap::new());
    let active_readers = AtomicUsize::new(0);
    let mut summary = ServeSummary::default();
    std::thread::scope(|scope| {
        for _ in 0..workers {
            scope.spawn(|| worker_loop(&shared, &registry, opts.panic_inject_id.as_deref()));
        }
        if let Some(interval) = opts.save_interval.filter(|_| opts.cache_dir.is_some()) {
            scope.spawn(|| saver.run(&registry, interval));
        }
        let writer = scope.spawn({
            let shared = &shared;
            let registry = &registry;
            let conns = &conns;
            move || {
                writer_loop(
                    shared,
                    registry,
                    log,
                    trace_dir.as_deref(),
                    |conn, line| {
                        let stream =
                            lock_recover(conns).get(&conn).and_then(|s| s.try_clone().ok());
                        match stream {
                            Some(mut s) => {
                                if writeln!(s, "{line}").is_err() {
                                    log.warn(
                                        "response_dropped",
                                        &[
                                            ("conn", Json::num(conn as f64)),
                                            ("reason", Json::str("connection closed")),
                                        ],
                                    );
                                }
                            }
                            None => log.warn(
                                "response_dropped",
                                &[
                                    ("conn", Json::num(conn as f64)),
                                    ("reason", Json::str("connection gone")),
                                ],
                            ),
                        }
                    },
                    // last pending response delivered after the reader left:
                    // drop the socket so finished clients don't leak fds
                    |conn| {
                        lock_recover(conns).remove(&conn);
                    },
                )
            }
        });
        let mut conn_id = 0usize;
        while !shared.stopping.load(Ordering::SeqCst) {
            match listener.accept() {
                Ok((stream, _addr)) => {
                    stream.set_nonblocking(false).ok();
                    let read_half = stream.try_clone();
                    // register only a connection we can actually serve:
                    // a failed clone is answered + closed by
                    // split_accepted, never inserted (fd-leak fix)
                    if let Some((write_half, read_half)) = split_accepted(stream, read_half) {
                        lock_recover(&conns).insert(conn_id, write_half);
                        let id = conn_id;
                        active_readers.fetch_add(1, Ordering::SeqCst);
                        let shared = &shared;
                        let active = &active_readers;
                        let conns = &conns;
                        let trace_all = trace_dir.is_some();
                        scope.spawn(move || {
                            read_requests(shared, BufReader::new(read_half), id, trace_all);
                            // nothing pending? close the socket now; else the
                            // writer closes it after the last response
                            if shared.reader_finished(id) {
                                lock_recover(conns).remove(&id);
                            }
                            active.fetch_sub(1, Ordering::SeqCst);
                        });
                    }
                    conn_id += 1;
                }
                Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                    std::thread::sleep(Duration::from_millis(50));
                }
                Err(e) => {
                    log.warn("accept_failed", &[("error", Json::str(e.to_string()))]);
                    std::thread::sleep(Duration::from_millis(50));
                }
            }
        }
        // unblock readers stuck in read_line, then wait for them to exit
        // before closing the queue (they may still be admitting requests)
        for (_, s) in lock_recover(&conns).iter() {
            s.shutdown(NetShutdown::Read).ok();
        }
        while active_readers.load(Ordering::SeqCst) > 0 {
            std::thread::sleep(Duration::from_millis(10));
        }
        shared.close();
        summary = writer.join().expect("writer panicked");
        saver.stop();
    });
    summary.snapshots_saved = registry.save_all();
    Ok(summary)
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::Read;

    #[test]
    fn effective_max_queue_defaults_when_zero() {
        let opts = ServeOpts::default();
        assert_eq!(opts.effective_max_queue(), DEFAULT_MAX_QUEUE);
        let opts = ServeOpts {
            max_queue: 3,
            ..Default::default()
        };
        assert_eq!(opts.effective_max_queue(), 3);
    }

    /// The fd-leak fix: a failed `try_clone` answers the client with one
    /// structured `unavailable` line, shuts the socket, and registers
    /// nothing (`split_accepted` returns None).
    #[test]
    fn failed_clone_is_answered_and_closed_not_registered() {
        let listener = TcpListener::bind("127.0.0.1:0").expect("bind");
        let addr = listener.local_addr().expect("addr");
        let client = std::thread::spawn(move || {
            let mut c = TcpStream::connect(addr).expect("connect");
            let mut text = String::new();
            c.read_to_string(&mut text).expect("read to EOF");
            text
        });
        let (stream, _) = listener.accept().expect("accept");
        let injected: std::io::Result<TcpStream> =
            Err(std::io::Error::new(
                std::io::ErrorKind::Other,
                "simulated clone failure",
            ));
        assert!(split_accepted(stream, injected).is_none());
        let text = client.join().expect("client thread");
        let json = Json::parse(text.trim()).expect("one well-formed response line");
        let err = json.get("error").expect("error object");
        assert_eq!(
            err.get("kind").and_then(Json::as_str),
            Some("unavailable"),
            "clone failure sheds with the unavailable kind: {text}"
        );
        let msg = err.get("message").and_then(Json::as_str).unwrap_or("");
        assert!(
            msg.contains("simulated clone failure"),
            "message carries the cause: {msg}"
        );
    }

    /// Cancelling an id that was never admitted reports `not_found` and
    /// completes nothing.
    #[test]
    fn cancel_unknown_target_is_not_found() {
        let shared = Shared::new(4);
        assert_eq!(shared.cancel_target(0, "nope"), "not_found");
        assert!(lock_recover(&shared.done).ready.is_empty());
    }

    /// Per-connection seqs are independent: each connection counts from 0.
    #[test]
    fn admission_seqs_are_per_connection() {
        let shared = Shared::new(4);
        assert_eq!(shared.admit(7), 0);
        assert_eq!(shared.admit(7), 1);
        assert_eq!(shared.admit(9), 0);
        assert_eq!(lock_recover(&shared.done).admitted, 3);
    }
}
