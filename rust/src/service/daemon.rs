//! The long-lived what-if daemon: transports, worker pool, cache registry
//! and the in-order response writer.
//!
//! ## Architecture
//!
//! ```text
//!  stdin / TCP conns --> reader(s) --parse--> job queue --> worker pool
//!                                     |  (seq-stamped)        |  sweeps share
//!                                     |                       |  per-fingerprint
//!                                     v                       v  ProfileCaches
//!                                 done map (seq -> outcome) <-+
//!                                     |
//!                                     v
//!                              writer: emits responses in admission
//!                              order, re-accounting cache stats
//!                              "as-if-serial"
//! ```
//!
//! **Determinism.** Each request's deterministic payload (candidates,
//! throughputs) depends only on the request itself — profiled costs are
//! functions of (descriptor, cluster, cost, protocol), never of which
//! request measured them first. Cache hit/miss accounting *would* be racy
//! under sharing, so the writer recomputes it deterministically: request
//! k's misses are the unique events of k not in the union of the loaded
//! snapshot and requests 0..k-1's events — exactly what serial execution
//! in admission order would report. Responses are therefore bit-identical
//! for any worker count and any execution interleaving ( `tests/service.rs`
//! pins 1-vs-4 workers byte-for-byte). Two deliberate exceptions opt out
//! of the contract: the `stats` op is a *diagnostic* — it reports live
//! cache occupancy at write time — and a request that sets
//! `budget.deadline_ms` trades determinism for a bounded queue wait
//! (whether it expired depends on wall-clock). Requests without a
//! deadline are never affected by either.
//!
//! **Fairness.** Jobs start in admission order (FIFO queue) and responses
//! are *delivered* in admission order; a slow early request delays later
//! responses (head-of-line) but never changes them. Deadlines
//! (`budget.deadline_ms`) bound queue wait only: an expired request is
//! answered with a structured `deadline` error before it starts, and a
//! request that did start always runs to completion — wall-clock never
//! truncates a payload.

use std::collections::{BTreeMap, HashMap, HashSet, VecDeque};
use std::io::{BufRead, BufReader, Write};
use std::net::{Shutdown as NetShutdown, TcpListener, TcpStream};
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::path::PathBuf;
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::time::{Duration, Instant};

use crate::cluster::ClusterSpec;
use crate::config::Json;
use crate::cost::CostBook;
use crate::search::{
    fingerprint, stats_against, ProfileCache, SearchEngine, SweepReport,
};

use super::protocol::{self, ErrorKind, Request, ServiceError, SweepRequest};

/// Daemon configuration (transport-independent).
#[derive(Debug, Clone, Default)]
pub struct ServeOpts {
    /// Concurrent sweep workers; 0 = `available_parallelism`.
    pub workers: usize,
    /// Directory for profile-cache snapshots (`cache-<fingerprint>.json`),
    /// loaded lazily per fingerprint and saved back on shutdown/EOF.
    pub cache_dir: Option<PathBuf>,
    /// Additionally persist cache snapshots every this often while the
    /// daemon runs (`distsim serve --save-interval <secs>`), so a crash
    /// or kill loses at most one interval's measurements. Writes are
    /// atomic (tmp file + rename), so a reader — or a crash mid-write —
    /// never observes a torn snapshot. No-op without a cache dir.
    pub save_interval: Option<Duration>,
}

/// What a daemon run did, for callers that want to report it.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct ServeSummary {
    pub requests: usize,
    pub sweeps: usize,
    pub errors: usize,
    /// Snapshots written on exit (0 without a cache dir).
    pub snapshots_saved: usize,
}

// ---------------------------------------------------------------------------
// cache registry

struct RegistryEntry {
    cache: Arc<ProfileCache>,
    /// Keys restored from the on-disk snapshot (the accounting prior).
    preloaded: Arc<HashSet<String>>,
    // identity needed to save the snapshot back
    cluster: ClusterSpec,
    cost: CostBook,
    protocol: (f64, usize, u64),
}

/// Shared profile caches, one per (cluster, cost, protocol) fingerprint —
/// the daemon-lifetime generalization of a sweep's single cache.
#[derive(Default)]
pub struct CacheRegistry {
    dir: Option<PathBuf>,
    map: Mutex<HashMap<String, RegistryEntry>>,
}

impl CacheRegistry {
    pub fn new(dir: Option<PathBuf>) -> Self {
        CacheRegistry {
            dir,
            map: Mutex::new(HashMap::new()),
        }
    }

    fn snapshot_path(dir: &std::path::Path, fp: &str) -> PathBuf {
        dir.join(format!("cache-{fp}.json"))
    }

    /// The cache for a request's fingerprint, loading a matching snapshot
    /// from disk the first time the fingerprint is seen.
    ///
    /// Snapshot I/O happens *outside* the registry lock so a large load
    /// for one fingerprint never stalls workers resolving other (or
    /// already-resident) caches; if two workers race on a cold
    /// fingerprint, both load and the entry API keeps the first — the
    /// duplicate work is idempotent (same file, same values).
    fn resolve(
        &self,
        cluster: &ClusterSpec,
        cost: &CostBook,
        jitter: f64,
        iters: usize,
        seed: u64,
    ) -> (String, Arc<ProfileCache>, Arc<HashSet<String>>) {
        let fp = fingerprint(cluster, cost, jitter, iters, seed);
        if let Some(e) = self.map.lock().unwrap().get(&fp) {
            return (fp, e.cache.clone(), e.preloaded.clone());
        }
        let loaded = self.dir.as_deref().and_then(|d| {
            let path = Self::snapshot_path(d, &fp);
            let text = std::fs::read_to_string(&path).ok()?;
            match Json::parse(&text)
                .map_err(anyhow::Error::from)
                .and_then(|j| ProfileCache::load_json(&j))
            {
                Ok(snap) if snap.fingerprint == fp => Some(snap),
                Ok(snap) => {
                    eprintln!(
                        "warning: ignoring snapshot {} (fingerprint {} != {})",
                        path.display(),
                        snap.fingerprint,
                        fp
                    );
                    None
                }
                Err(e) => {
                    eprintln!("warning: ignoring snapshot {}: {e}", path.display());
                    None
                }
            }
        });
        let fresh = match loaded {
            Some(snap) => RegistryEntry {
                cache: Arc::new(snap.cache),
                preloaded: Arc::new(snap.keys),
                cluster: snap.cluster,
                cost: snap.cost,
                protocol: snap.protocol,
            },
            None => RegistryEntry {
                cache: Arc::new(ProfileCache::new()),
                preloaded: Arc::new(HashSet::new()),
                cluster: cluster.clone(),
                cost: cost.clone(),
                protocol: (jitter, iters, seed),
            },
        };
        let mut map = self.map.lock().unwrap();
        let entry = map.entry(fp.clone()).or_insert(fresh);
        let out = (entry.cache.clone(), entry.preloaded.clone());
        (fp, out.0, out.1)
    }

    /// (fingerprint, measured entries) per cache, sorted by fingerprint.
    pub fn summary(&self) -> Vec<(String, usize)> {
        let map = self.map.lock().unwrap();
        let mut v: Vec<(String, usize)> = map
            .iter()
            .map(|(fp, e)| (fp.clone(), e.cache.measured_len()))
            .collect();
        v.sort();
        v
    }

    /// Persist every cache with at least one measurement. Returns how many
    /// snapshot files were written.
    ///
    /// Each snapshot is written to a `.tmp` sibling and atomically
    /// renamed into place, so a concurrent reader (or a crash mid-write)
    /// never observes a torn file — the invariant the periodic
    /// `--save-interval` saver relies on.
    pub fn save_all(&self) -> usize {
        let Some(dir) = self.dir.as_deref() else {
            return 0;
        };
        if let Err(e) = std::fs::create_dir_all(dir) {
            eprintln!("warning: cannot create cache dir {}: {e}", dir.display());
            return 0;
        }
        // serialization and disk I/O happen OUTSIDE the registry lock —
        // the same invariant resolve() documents — so a periodic save
        // never stalls workers admitting requests
        type Entry = (String, Arc<ProfileCache>, ClusterSpec, CostBook, (f64, usize, u64));
        let entries: Vec<Entry> = {
            let map = self.map.lock().unwrap();
            map.iter()
                .filter(|(_, e)| e.cache.measured_len() > 0)
                .map(|(fp, e)| {
                    (
                        fp.clone(),
                        e.cache.clone(),
                        e.cluster.clone(),
                        e.cost.clone(),
                        e.protocol,
                    )
                })
                .collect()
        };
        let mut saved = 0;
        for (fp, cache, cluster, cost, (jitter, iters, seed)) in entries {
            let json = cache.save_json(&cluster, &cost, jitter, iters, seed);
            let path = Self::snapshot_path(dir, &fp);
            let tmp = path.with_extension("json.tmp");
            let res = json
                .write_file(&tmp)
                .and_then(|()| {
                    std::fs::rename(&tmp, &path).map_err(|e| {
                        anyhow::anyhow!(
                            "cannot move snapshot into place at {}: {e}",
                            path.display()
                        )
                    })
                });
            match res {
                Ok(()) => saved += 1,
                Err(err) => {
                    std::fs::remove_file(&tmp).ok();
                    eprintln!("warning: {err}");
                }
            }
        }
        saved
    }
}

/// The periodic snapshot saver: parks on a condvar with the configured
/// interval and calls [`CacheRegistry::save_all`] until stopped (final
/// shutdown saves happen separately, after the writer drains).
struct PeriodicSaver {
    stop: Mutex<bool>,
    cv: Condvar,
}

impl PeriodicSaver {
    fn new() -> Self {
        PeriodicSaver {
            stop: Mutex::new(false),
            cv: Condvar::new(),
        }
    }

    fn run(&self, registry: &CacheRegistry, interval: Duration) {
        let mut stopped = self.stop.lock().unwrap();
        loop {
            let (guard, timeout) = self
                .cv
                .wait_timeout(stopped, interval)
                .expect("saver lock poisoned");
            stopped = guard;
            if *stopped {
                return;
            }
            if timeout.timed_out() {
                drop(stopped);
                registry.save_all();
                stopped = self.stop.lock().unwrap();
            }
        }
    }

    fn stop(&self) {
        *self.stop.lock().unwrap() = true;
        self.cv.notify_all();
    }
}

// ---------------------------------------------------------------------------
// shared daemon state

enum Outcome {
    Sweep {
        report: Box<SweepReport>,
        fp: String,
        preloaded: Arc<HashSet<String>>,
        include_timing: bool,
    },
    Error(ServiceError),
    Pong,
    Stats,
    Shutdown,
}

struct Completed {
    id: Option<String>,
    conn: usize,
    outcome: Outcome,
}

struct Job {
    seq: u64,
    conn: usize,
    req: Box<SweepRequest>,
    admitted_at: Instant,
}

#[derive(Default)]
struct DoneState {
    map: BTreeMap<u64, Completed>,
    /// Total requests admitted (sequence numbers 0..admitted are spoken
    /// for); the writer exits once it has emitted all of them after close.
    admitted: u64,
    closed: bool,
}

#[derive(Default)]
struct QueueState {
    jobs: VecDeque<Job>,
    closed: bool,
}

/// Per-connection liveness: undelivered responses + whether the reader
/// has exited. Lets the TCP transport reclaim a finished connection's
/// socket as soon as its last response goes out — without dropping queued
/// responses for half-close clients (write shut, still reading).
#[derive(Default)]
struct ConnLive {
    outstanding: usize,
    reader_done: bool,
}

#[derive(Default)]
struct Shared {
    queue: Mutex<QueueState>,
    queue_cv: Condvar,
    done: Mutex<DoneState>,
    done_cv: Condvar,
    conns_live: Mutex<HashMap<usize, ConnLive>>,
    /// Set when a shutdown op is admitted: transports stop reading.
    stopping: AtomicBool,
}

impl Shared {
    /// Admit one request from `conn`, assigning its global sequence number.
    fn admit(&self, conn: usize) -> u64 {
        let seq = {
            let mut done = self.done.lock().unwrap();
            let seq = done.admitted;
            done.admitted += 1;
            seq
        };
        self.conns_live
            .lock()
            .unwrap()
            .entry(conn)
            .or_default()
            .outstanding += 1;
        seq
    }

    /// One response delivered for `conn`; true when the connection is
    /// finished (reader gone, nothing left to deliver) and can be closed.
    fn response_delivered(&self, conn: usize) -> bool {
        let mut map = self.conns_live.lock().unwrap();
        if let Some(c) = map.get_mut(&conn) {
            c.outstanding = c.outstanding.saturating_sub(1);
            if c.reader_done && c.outstanding == 0 {
                map.remove(&conn);
                return true;
            }
        }
        false
    }

    /// `conn`'s reader exited; true when nothing is pending and the
    /// connection can be closed right away.
    fn reader_finished(&self, conn: usize) -> bool {
        let mut map = self.conns_live.lock().unwrap();
        let c = map.entry(conn).or_default();
        c.reader_done = true;
        if c.outstanding == 0 {
            map.remove(&conn);
            true
        } else {
            false
        }
    }

    fn complete(&self, seq: u64, c: Completed) {
        let mut done = self.done.lock().unwrap();
        done.map.insert(seq, c);
        self.done_cv.notify_all();
    }

    fn enqueue(&self, job: Job) {
        let mut q = self.queue.lock().unwrap();
        if q.closed {
            // raced with shutdown: answer rather than silently dropping
            let seq = job.seq;
            let c = Completed {
                id: job.req.id.clone(),
                conn: job.conn,
                outcome: Outcome::Error(ServiceError::new(
                    ErrorKind::BadRequest,
                    "daemon is shutting down",
                )),
            };
            drop(q);
            self.complete(seq, c);
            return;
        }
        q.jobs.push_back(job);
        self.queue_cv.notify_one();
    }

    /// No more requests will be admitted: wake everyone so they can drain.
    fn close(&self) {
        self.queue.lock().unwrap().closed = true;
        self.queue_cv.notify_all();
        self.done.lock().unwrap().closed = true;
        self.done_cv.notify_all();
    }
}

// ---------------------------------------------------------------------------
// roles: reader, worker, writer

/// Read NDJSON requests from one transport until EOF or a shutdown op.
/// Returns true when this reader saw the shutdown op.
///
/// A reader never *drops* a line it managed to read: during shutdown
/// (another connection's op), lines already in flight are still admitted
/// and answered — either normally (admitted before the queue closed) or
/// with a structured shutting-down error ([`Shared::enqueue`]'s backstop).
/// Termination comes from the transport: the TCP accept loop shuts down
/// every connection's read half, which EOFs this loop.
fn read_requests<R: BufRead>(shared: &Shared, input: R, conn: usize) -> bool {
    for line in input.lines() {
        let line = match line {
            Ok(l) => l,
            Err(_) => break, // transport error == EOF
        };
        if line.trim().is_empty() {
            continue;
        }
        match protocol::parse_line(&line) {
            Err((id, err)) => {
                let seq = shared.admit(conn);
                shared.complete(
                    seq,
                    Completed {
                        id,
                        conn,
                        outcome: Outcome::Error(err),
                    },
                );
            }
            Ok(Request::Ping { id }) => {
                let seq = shared.admit(conn);
                shared.complete(
                    seq,
                    Completed {
                        id,
                        conn,
                        outcome: Outcome::Pong,
                    },
                );
            }
            Ok(Request::Stats { id }) => {
                let seq = shared.admit(conn);
                shared.complete(
                    seq,
                    Completed {
                        id,
                        conn,
                        outcome: Outcome::Stats,
                    },
                );
            }
            Ok(Request::Shutdown { id }) => {
                shared.stopping.store(true, Ordering::SeqCst);
                let seq = shared.admit(conn);
                shared.complete(
                    seq,
                    Completed {
                        id,
                        conn,
                        outcome: Outcome::Shutdown,
                    },
                );
                return true;
            }
            Ok(Request::Sweep(req)) => {
                let seq = shared.admit(conn);
                shared.enqueue(Job {
                    seq,
                    conn,
                    req,
                    admitted_at: Instant::now(),
                });
            }
        }
    }
    false
}

/// Execute one admitted sweep job end to end.
fn run_job(registry: &CacheRegistry, job: Job) -> (u64, Completed) {
    let req = &job.req;
    if let Some(deadline) = job.req.deadline_ms {
        if job.admitted_at.elapsed() > Duration::from_millis(deadline) {
            return (
                job.seq,
                Completed {
                    id: req.id.clone(),
                    conn: job.conn,
                    outcome: Outcome::Error(ServiceError::new(
                        ErrorKind::Deadline,
                        format!("deadline of {deadline} ms expired before the sweep started"),
                    )),
                },
            );
        }
    }
    let (fp, cache, preloaded) = registry.resolve(
        &req.cluster,
        &req.cost,
        req.sweep.jitter_sigma,
        req.sweep.profile_iters,
        req.sweep.profile_seed,
    );
    let outcome = match catch_unwind(AssertUnwindSafe(|| {
        // the snapshot's keys are the engine's prior: in-sweep accounting
        // (pruning.gpu_seconds_avoided) then agrees with the writer's
        // as-if-serial cache block that nothing a hit would have served
        // counts as avoided or spent. (The writer still substitutes its
        // own admission-order cache stats for the engine's.)
        SearchEngine::with_book(
            &req.model,
            &req.cluster,
            req.cost.clone(),
            req.sweep.clone(),
            cache,
        )
        .with_prior((*preloaded).clone())
        .sweep()
    })) {
        Ok(report) => Outcome::Sweep {
            report: Box::new(report),
            fp,
            preloaded,
            include_timing: req.include_timing,
        },
        Err(panic) => {
            let msg = panic
                .downcast_ref::<&str>()
                .map(|s| s.to_string())
                .or_else(|| panic.downcast_ref::<String>().cloned())
                .unwrap_or_else(|| "sweep panicked".to_string());
            Outcome::Error(ServiceError::new(ErrorKind::Internal, msg))
        }
    };
    (
        job.seq,
        Completed {
            id: req.id.clone(),
            conn: job.conn,
            outcome,
        },
    )
}

fn worker_loop(shared: &Shared, registry: &CacheRegistry) {
    loop {
        let job = {
            let mut q = shared.queue.lock().unwrap();
            loop {
                if let Some(job) = q.jobs.pop_front() {
                    break job;
                }
                if q.closed {
                    return;
                }
                q = shared.queue_cv.wait(q).unwrap();
            }
        };
        let (seq, completed) = run_job(registry, job);
        shared.complete(seq, completed);
    }
}

/// Emit responses in admission order, recomputing per-request cache stats
/// against the as-if-serial prior. `emit` receives (conn, line);
/// `on_conn_idle` fires when a connection whose reader already exited has
/// received its last pending response (transport closes it there).
fn writer_loop(
    shared: &Shared,
    registry: &CacheRegistry,
    mut emit: impl FnMut(usize, &str),
    mut on_conn_idle: impl FnMut(usize),
) -> ServeSummary {
    let mut summary = ServeSummary::default();
    let mut seen: HashMap<String, HashSet<String>> = HashMap::new();
    let mut next = 0u64;
    loop {
        let completed = {
            let mut done = shared.done.lock().unwrap();
            loop {
                if let Some(c) = done.map.remove(&next) {
                    break c;
                }
                if done.closed && next >= done.admitted {
                    return summary;
                }
                done = shared.done_cv.wait(done).unwrap();
            }
        };
        summary.requests += 1;
        let id = completed.id.as_deref();
        let line = match completed.outcome {
            Outcome::Sweep {
                report,
                fp,
                preloaded,
                include_timing,
            } => {
                summary.sweeps += 1;
                let prior = seen
                    .entry(fp.clone())
                    .or_insert_with(|| (*preloaded).clone());
                let stats = stats_against(&report.event_uses, prior);
                for u in &report.event_uses {
                    prior.insert(u.key.clone());
                }
                protocol::sweep_response(id, &fp, &report, &stats, include_timing).to_string()
            }
            Outcome::Error(err) => {
                summary.errors += 1;
                protocol::error_response(id, &err).to_string()
            }
            Outcome::Pong => protocol::pong_response(id).to_string(),
            Outcome::Stats => protocol::stats_response(id, &registry.summary()).to_string(),
            Outcome::Shutdown => protocol::shutdown_response(id).to_string(),
        };
        emit(completed.conn, &line);
        if shared.response_delivered(completed.conn) {
            on_conn_idle(completed.conn);
        }
        next += 1;
    }
}

fn resolve_workers(n: usize) -> usize {
    if n > 0 {
        n
    } else {
        std::thread::available_parallelism()
            .map(|n| n.get())
            .unwrap_or(1)
    }
}

// ---------------------------------------------------------------------------
// transports

/// Serve one NDJSON stream (stdin/stdout, or any reader/writer pair — the
/// in-process entry point tests and `distsim ask` use). Returns after EOF
/// or a `shutdown` op, once every admitted request has been answered and
/// snapshots are saved.
pub fn serve_ndjson<R: BufRead, W: Write + Send>(
    input: R,
    output: W,
    opts: &ServeOpts,
) -> ServeSummary {
    let registry = CacheRegistry::new(opts.cache_dir.clone());
    let shared = Shared::default();
    let workers = resolve_workers(opts.workers);
    let saver = PeriodicSaver::new();
    let mut summary = ServeSummary::default();
    std::thread::scope(|scope| {
        for _ in 0..workers {
            scope.spawn(|| worker_loop(&shared, &registry));
        }
        if let Some(interval) = opts.save_interval.filter(|_| opts.cache_dir.is_some()) {
            scope.spawn(|| saver.run(&registry, interval));
        }
        let writer = scope.spawn({
            let shared = &shared;
            let registry = &registry;
            let mut output = output;
            move || {
                writer_loop(
                    shared,
                    registry,
                    |_conn, line| {
                        // a broken pipe must not kill the drain: log and move on
                        if writeln!(output, "{line}").and_then(|()| output.flush()).is_err() {
                            eprintln!("warning: response dropped (output closed)");
                        }
                    },
                    |_conn| {}, // single stream: nothing to close per-conn
                )
            }
        });
        read_requests(&shared, input, 0);
        shared.close();
        summary = writer.join().expect("writer panicked");
        saver.stop();
    });
    summary.snapshots_saved = registry.save_all();
    summary
}

/// Serve TCP connections on `listener`. Each connection is an independent
/// NDJSON stream multiplexed onto the shared queue, worker pool and cache
/// registry; responses are delivered in global admission order. Returns
/// when any connection sends a `shutdown` op.
pub fn serve_tcp(listener: TcpListener, opts: &ServeOpts) -> anyhow::Result<ServeSummary> {
    let registry = CacheRegistry::new(opts.cache_dir.clone());
    let shared = Shared::default();
    let workers = resolve_workers(opts.workers);
    let saver = PeriodicSaver::new();
    listener.set_nonblocking(true)?;
    let conns: Mutex<HashMap<usize, TcpStream>> = Mutex::new(HashMap::new());
    let active_readers = AtomicUsize::new(0);
    let mut summary = ServeSummary::default();
    std::thread::scope(|scope| {
        for _ in 0..workers {
            scope.spawn(|| worker_loop(&shared, &registry));
        }
        if let Some(interval) = opts.save_interval.filter(|_| opts.cache_dir.is_some()) {
            scope.spawn(|| saver.run(&registry, interval));
        }
        let writer = scope.spawn({
            let shared = &shared;
            let registry = &registry;
            let conns = &conns;
            move || {
                writer_loop(
                    shared,
                    registry,
                    |conn, line| {
                        let stream =
                            conns.lock().unwrap().get(&conn).and_then(|s| s.try_clone().ok());
                        match stream {
                            Some(mut s) => {
                                if writeln!(s, "{line}").is_err() {
                                    eprintln!(
                                        "warning: response dropped (connection {conn} closed)"
                                    );
                                }
                            }
                            None => {
                                eprintln!("warning: response dropped (connection {conn} gone)")
                            }
                        }
                    },
                    // last pending response delivered after the reader left:
                    // drop the socket so finished clients don't leak fds
                    |conn| {
                        conns.lock().unwrap().remove(&conn);
                    },
                )
            }
        });
        let mut conn_id = 0usize;
        while !shared.stopping.load(Ordering::SeqCst) {
            match listener.accept() {
                Ok((stream, _addr)) => {
                    stream.set_nonblocking(false).ok();
                    let read_half = stream.try_clone();
                    conns.lock().unwrap().insert(conn_id, stream);
                    if let Ok(read_half) = read_half {
                        let id = conn_id;
                        active_readers.fetch_add(1, Ordering::SeqCst);
                        let shared = &shared;
                        let active = &active_readers;
                        let conns = &conns;
                        scope.spawn(move || {
                            read_requests(shared, BufReader::new(read_half), id);
                            // nothing pending? close the socket now; else the
                            // writer closes it after the last response
                            if shared.reader_finished(id) {
                                conns.lock().unwrap().remove(&id);
                            }
                            active.fetch_sub(1, Ordering::SeqCst);
                        });
                    }
                    conn_id += 1;
                }
                Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                    std::thread::sleep(Duration::from_millis(50));
                }
                Err(e) => {
                    eprintln!("warning: accept failed: {e}");
                    std::thread::sleep(Duration::from_millis(50));
                }
            }
        }
        // unblock readers stuck in read_line, then wait for them to exit
        // before closing the queue (they may still be admitting requests)
        for (_, s) in conns.lock().unwrap().iter() {
            s.shutdown(NetShutdown::Read).ok();
        }
        while active_readers.load(Ordering::SeqCst) > 0 {
            std::thread::sleep(Duration::from_millis(10));
        }
        shared.close();
        summary = writer.join().expect("writer panicked");
        saver.stop();
    });
    summary.snapshots_saved = registry.save_all();
    Ok(summary)
}
