//! The what-if wire protocol: newline-delimited JSON requests and
//! responses, parsed with the crate's own [`Json`] substrate (no serde in
//! the offline vendor set).
//!
//! One request per line; one response line per request, delivered in
//! admission order. Malformed input produces a structured error *response*
//! — the daemon never hangs or dies on bad bytes (`tests/service.rs` pins
//! this).
//!
//! ## Request schema
//!
//! ```json
//! {"id": "r1", "op": "sweep",
//!  "model": "bert-exlarge",
//!  "cluster": {"preset": "a40-a10", "nodes": 4, "gpus_per_node": 4,
//!              "placement": "interleaved"},
//!  "cost": {"scale": 1.0, "per_kind": {"A10": {"eff_max": 0.55}}},
//!  "sweep": {"global_batch": 16, "profile_iters": 1, "threads": 1,
//!            "widened": false, "micro_batch_axis": false,
//!            "schedule_axis": false, "placement_axis": false,
//!            "placement_opt": false, "beam": 4,
//!            "recompute_axis": false, "zero_axis": false, "memory": false,
//!            "prune": false, "prune_epochs": 1,
//!            "scenario": {"stragglers": [{"device": 0, "factor": 1.5}]}},
//!  "budget": {"max_candidates": 100, "deadline_ms": 60000},
//!  "timing": false}
//! ```
//!
//! `op` is one of `sweep` (default), `cancel`, `ping`, `stats`,
//! `shutdown` ([`OPS`]). `cancel` carries a required `target` — the `id`
//! of an earlier sweep on the *same connection* to abort (drop it from
//! the queue, or cooperatively interrupt it if already running).
//! `cluster` is either a full [`ClusterSpec`] object or a
//! preset shorthand (`a40`/`a10`/`a100`/`a40-a10` — the last a mixed-SKU
//! fleet), optionally with a `placement` policy or table. `cost` is a
//! per-device-kind registry: base fields flat, `per_kind` mapping SKU
//! names to overrides. Omitted `sweep` fields take [`SweepConfig`]
//! defaults, except `threads`, which defaults to 1 inside the service
//! (request-level parallelism comes from the daemon's worker pool).
//! `sweep.scenario` is an unhappy-path [`ScenarioSpec`] object
//! (stragglers, link episodes, failures, elastic resize — docs/FORMATS.md
//! §Scenario); devices it names must exist on the request's cluster, its
//! presence adds per-candidate `scenario_throughput` and a `robustness`
//! result block, and an omitted or empty scenario leaves the response
//! byte-identical to a pre-scenario build.
//! `sweep.recompute_axis` / `sweep.zero_axis` / `sweep.memory` opt into
//! per-rank memory accounting (ISSUE 9): candidates gain
//! `peak_bytes`/`fits`/`recompute`/`zero_stage` fields, infeasible points
//! come back as `reason: "oom"` placeholders, and the `pruning` block
//! gains `memory_pruned`. A preset cluster can cap every SKU with
//! `capacity_bytes`; with no capacity and no memory flag the response is
//! byte-identical to a pre-memory build.
//! `timing: true` opts into wall-clock fields — by default responses carry
//! only deterministic data, so equal requests produce byte-equal response
//! lines.
//!
//! The full byte-level specification of every request/response field (and
//! of every other on-disk format the project writes) lives in
//! **docs/FORMATS.md**; a CI drift check keeps the op list there in sync
//! with this dispatcher.

use crate::cluster::{ClusterSpec, Placement};
use crate::config::Json;
use crate::cost::CostBook;
use crate::model::ModelSpec;
use crate::scenario::ScenarioSpec;
use crate::search::{CacheStats, SweepConfig, SweepReport};

/// Every op the request dispatcher accepts, in documentation order.
/// `docs/FORMATS.md` must describe each one (`tests/docs_drift.rs` pins
/// that), and [`parse_line`]'s dispatcher accepts exactly this set.
pub const OPS: [&str; 6] = ["sweep", "cancel", "ping", "stats", "metrics", "shutdown"];

/// What went wrong, coarsely — the machine-readable half of an error
/// response.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ErrorKind {
    /// The line was not valid JSON.
    BadJson,
    /// Valid JSON, but not a valid request (unknown op/model/cluster...).
    BadRequest,
    /// The request's deadline expired before a worker could start it.
    Deadline,
    /// The sweep itself failed (engine panic) — a daemon bug, not yours.
    Internal,
    /// CLI-level failure (config file, flags); shares the same error shape.
    Cli,
    /// The daemon could not admit a well-formed request: the bounded
    /// admission queue is full (load shed) or the daemon is shutting
    /// down. Retryable — nothing is wrong with the request itself.
    Unavailable,
    /// The sweep was aborted by a `cancel` op before completing.
    Cancelled,
}

impl ErrorKind {
    /// Every error kind a response can carry, in documentation order
    /// (`docs/FORMATS.md` must describe each one).
    pub const ALL: [ErrorKind; 7] = [
        ErrorKind::BadJson,
        ErrorKind::BadRequest,
        ErrorKind::Deadline,
        ErrorKind::Internal,
        ErrorKind::Cli,
        ErrorKind::Unavailable,
        ErrorKind::Cancelled,
    ];

    pub fn name(&self) -> &'static str {
        match self {
            ErrorKind::BadJson => "bad_json",
            ErrorKind::BadRequest => "bad_request",
            ErrorKind::Deadline => "deadline",
            ErrorKind::Internal => "internal",
            ErrorKind::Cli => "cli",
            ErrorKind::Unavailable => "unavailable",
            ErrorKind::Cancelled => "cancelled",
        }
    }
}

/// A structured service error; renders as one response line.
#[derive(Debug, Clone)]
pub struct ServiceError {
    pub kind: ErrorKind,
    pub message: String,
    /// Extra machine-readable fields merged into the `error` object —
    /// e.g. `depth`/`max_queue` on admission-queue sheds, so clients can
    /// back off without parsing the human message.
    pub detail: Vec<(&'static str, Json)>,
}

impl ServiceError {
    pub fn new(kind: ErrorKind, message: impl Into<String>) -> Self {
        ServiceError {
            kind,
            message: message.into(),
            detail: Vec::new(),
        }
    }

    /// Attach one structured detail field (builder-style).
    pub fn with_detail(mut self, key: &'static str, value: Json) -> Self {
        self.detail.push((key, value));
        self
    }
}

/// A fully validated sweep request, ready for a worker.
#[derive(Debug, Clone)]
pub struct SweepRequest {
    pub id: Option<String>,
    pub model_name: String,
    pub model: ModelSpec,
    pub cluster: ClusterSpec,
    /// Per-device-kind cost registry (a flat cost object parses as a
    /// uniform book; `per_kind` adds SKU overrides).
    pub cost: CostBook,
    pub sweep: SweepConfig,
    /// Reject the request if it cannot *start* within this budget. Never
    /// truncates a running sweep — payloads stay deterministic.
    pub deadline_ms: Option<u64>,
    /// Include wall-clock fields in the response.
    pub include_timing: bool,
}

/// One parsed request line.
#[derive(Debug, Clone)]
pub enum Request {
    Sweep(Box<SweepRequest>),
    /// Abort the queued/running sweep whose `id` equals `target` on the
    /// same connection. Answered inline by the reader (never queued).
    Cancel { id: Option<String>, target: String },
    Ping { id: Option<String> },
    Stats { id: Option<String> },
    /// Telemetry snapshot: the daemon's metric registry in structured-JSON
    /// and Prometheus text forms. Diagnostic like `stats` — outside the
    /// byte-identity contract.
    Metrics { id: Option<String> },
    Shutdown { id: Option<String> },
}

fn req_id(j: &Json) -> Option<String> {
    j.get("id").and_then(Json::as_str).map(str::to_string)
}

/// Build a cluster from either a preset shorthand or a full spec object.
/// Both forms accept an optional `placement` (policy name or rank→device
/// table); the `a40-a10` preset is the mixed-SKU fleet (A40 nodes and A10
/// nodes alternating).
pub fn cluster_from_json(j: &Json) -> anyhow::Result<ClusterSpec> {
    if let Some(preset) = j.get("preset").and_then(Json::as_str) {
        for k in ["nodes", "gpus_per_node"] {
            anyhow::ensure!(
                j.get(k).map(|v| v.as_f64().is_some()).unwrap_or(true),
                "cluster preset field '{k}' must be a number"
            );
        }
        let nodes = j.get("nodes").and_then(Json::as_usize).unwrap_or(4);
        let gpn = j.get("gpus_per_node").and_then(Json::as_usize);
        let mut cluster = match preset {
            "a40" => ClusterSpec::a40_cluster(nodes, gpn.unwrap_or(4)),
            "a10" => ClusterSpec::a10_cluster(nodes, gpn.unwrap_or(4)),
            "a100" => {
                // the a100 pod preset is 8 GPUs/node by definition; a
                // different request must be rejected, not silently resized
                anyhow::ensure!(
                    gpn.is_none() || gpn == Some(8),
                    "a100 preset has 8 gpus_per_node (got {})",
                    gpn.unwrap_or(0)
                );
                ClusterSpec::a100_pod(nodes)
            }
            "a40-a10" => {
                // one node would be all-A40: reject rather than silently
                // degrade a requested mixed fleet to a homogeneous one
                anyhow::ensure!(
                    nodes >= 2,
                    "a40-a10 mixed preset needs >= 2 nodes (got {nodes})"
                );
                ClusterSpec::mixed_a40_a10(nodes, gpn.unwrap_or(4))
            }
            other => {
                anyhow::bail!("unknown cluster preset '{other}' (a40|a10|a100|a40-a10)")
            }
        };
        if let Some(p) = j.get("placement") {
            cluster.placement = Placement::from_json(p)?;
            cluster.validate()?;
        }
        // uniform training-state budget for every SKU of the preset —
        // the shorthand's way to opt into memory-feasibility pruning
        // (full cluster objects set per-device `capacity_bytes` instead)
        if let Some(v) = j.get("capacity_bytes") {
            let f = v.as_f64().unwrap_or(-1.0);
            anyhow::ensure!(
                f > 0.0 && f.fract() == 0.0 && f <= (1u64 << 53) as f64,
                "capacity_bytes must be a positive integer byte count"
            );
            cluster = cluster.with_uniform_capacity(f as u64);
        }
        return Ok(cluster);
    }
    ClusterSpec::from_json(j)
}

/// Strict cost-model overrides: unlike [`CostBook::from_json`] (which is
/// lenient for hand-written calibration files), a *request's* `cost`
/// object must contain only known keys with numeric values — a typo'd or
/// mistyped override is a `bad_request`, never a silent fallback to the
/// default cost model. The base fields sit flat; `per_kind` maps SKU
/// names to objects of the same base fields.
fn cost_model_fields_strict(obj: &std::collections::BTreeMap<String, Json>) -> anyhow::Result<()> {
    const KNOWN: [&str; 5] = [
        "eff_max",
        "eff_min",
        "eff_knee_flops",
        "membw_frac",
        "scale",
    ];
    for (k, v) in obj {
        anyhow::ensure!(
            KNOWN.contains(&k.as_str()),
            "unknown cost field '{k}' (eff_max|eff_min|eff_knee_flops|membw_frac|scale)"
        );
        anyhow::ensure!(v.as_f64().is_some(), "cost field '{k}' must be a number");
    }
    Ok(())
}

fn cost_from_json_strict(j: &Json) -> anyhow::Result<CostBook> {
    let obj = j
        .as_obj()
        .ok_or_else(|| anyhow::anyhow!("'cost' must be an object"))?;
    let mut base = obj.clone();
    if let Some(per) = base.remove("per_kind") {
        let per = per
            .as_obj()
            .ok_or_else(|| anyhow::anyhow!("'cost.per_kind' must be an object"))?;
        for (kind, m) in per {
            let m = m.as_obj().ok_or_else(|| {
                anyhow::anyhow!("cost.per_kind['{kind}'] must be an object")
            })?;
            cost_model_fields_strict(m)
                .map_err(|e| anyhow::anyhow!("cost.per_kind['{kind}']: {e}"))?;
        }
    }
    cost_model_fields_strict(&base)?;
    Ok(CostBook::from_json(j))
}

fn sweep_config_from_json(j: Option<&Json>) -> anyhow::Result<SweepConfig> {
    // service default: one engine thread per request — parallelism across
    // requests comes from the daemon's worker pool
    let mut cfg = SweepConfig {
        threads: 1,
        ..SweepConfig::default()
    };
    let Some(j) = j else { return Ok(cfg) };
    let obj = j
        .as_obj()
        .ok_or_else(|| anyhow::anyhow!("'sweep' must be an object"))?;
    // strict keys AND value types: a typo'd axis name or a string-wrapped
    // number must be a bad_request, never a silently-default sweep (same
    // policy as the cost overrides)
    for (k, v) in obj {
        let ok = match k.as_str() {
            "global_batch" | "jitter_sigma" | "profile_iters" | "threads" | "prune_margin"
            | "max_candidates" | "prune_epochs" | "beam" => v.as_f64().is_some(),
            "widened" | "micro_batch_axis" | "schedule_axis" | "placement_axis"
            | "placement_opt" | "recompute_axis" | "zero_axis" | "memory" | "prune"
            | "use_cache" | "trace" => v.as_bool().is_some(),
            // seeds travel as numbers or string-wrapped u64s
            "profile_seed" => matches!(v, Json::Num(_)) || v.as_str().is_some(),
            // unhappy-path scenario: its own strict parser rejects
            // unknown/mistyped fields (see `scenario::ScenarioSpec`)
            "scenario" => v.as_obj().is_some(),
            other => anyhow::bail!(
                "unknown sweep field '{other}' (global_batch|jitter_sigma|profile_iters|\
                 profile_seed|threads|widened|micro_batch_axis|schedule_axis|\
                 placement_axis|placement_opt|recompute_axis|zero_axis|memory|beam|\
                 prune|prune_margin|prune_epochs|use_cache|max_candidates|scenario|trace)"
            ),
        };
        anyhow::ensure!(ok, "sweep field '{k}' has the wrong type");
    }
    if let Some(v) = j.get("global_batch").and_then(Json::as_usize) {
        anyhow::ensure!(v >= 1, "global_batch must be >= 1");
        cfg.global_batch = v;
    }
    if let Some(v) = j.get("jitter_sigma").and_then(Json::as_f64) {
        cfg.jitter_sigma = v;
    }
    if let Some(v) = j.get("profile_iters").and_then(Json::as_usize) {
        anyhow::ensure!(v >= 1, "profile_iters must be >= 1");
        cfg.profile_iters = v;
    }
    if let Some(v) = j.get("profile_seed") {
        // accept both a JSON number and a string-wrapped u64
        cfg.profile_seed = match v {
            Json::Str(s) => s
                .parse()
                .map_err(|_| anyhow::anyhow!("profile_seed is not a u64"))?,
            _ => v
                .as_u64()
                .ok_or_else(|| anyhow::anyhow!("profile_seed is not a u64"))?,
        };
    }
    if let Some(v) = j.get("threads").and_then(Json::as_usize) {
        cfg.threads = v;
    }
    if let Some(v) = j.get("widened").and_then(Json::as_bool) {
        cfg.widened = v;
    }
    if let Some(v) = j.get("micro_batch_axis").and_then(Json::as_bool) {
        cfg.micro_batch_axis = v;
    }
    if let Some(v) = j.get("schedule_axis").and_then(Json::as_bool) {
        cfg.schedule_axis = v;
    }
    if let Some(v) = j.get("placement_axis").and_then(Json::as_bool) {
        cfg.placement_axis = v;
    }
    if let Some(v) = j.get("placement_opt").and_then(Json::as_bool) {
        cfg.placement_opt = v;
    }
    if let Some(v) = j.get("recompute_axis").and_then(Json::as_bool) {
        cfg.recompute_axis = v;
    }
    if let Some(v) = j.get("zero_axis").and_then(Json::as_bool) {
        cfg.zero_axis = v;
    }
    if let Some(v) = j.get("memory").and_then(Json::as_bool) {
        cfg.memory = v;
    }
    if let Some(v) = j.get("beam").and_then(Json::as_usize) {
        anyhow::ensure!(v >= 1, "beam must be >= 1");
        cfg.beam = v;
    }
    if let Some(v) = j.get("prune_epochs").and_then(Json::as_usize) {
        anyhow::ensure!(v >= 1, "prune_epochs must be >= 1");
        cfg.prune_epochs = v;
    }
    if let Some(v) = j.get("prune").and_then(Json::as_bool) {
        cfg.prune = v;
    }
    if let Some(v) = j.get("prune_margin").and_then(Json::as_f64) {
        cfg.prune_margin = v;
    }
    if let Some(v) = j.get("use_cache").and_then(Json::as_bool) {
        cfg.use_cache = v;
    }
    if let Some(v) = j.get("max_candidates").and_then(Json::as_usize) {
        cfg.max_candidates = v;
    }
    if let Some(v) = j.get("scenario") {
        cfg.scenario = ScenarioSpec::from_json(v)?;
    }
    if let Some(v) = j.get("trace").and_then(Json::as_bool) {
        cfg.trace = v;
    }
    Ok(cfg)
}

/// Parse one request line. On failure, returns the request id when the
/// line at least parsed as JSON, so the error response can still be
/// correlated.
pub fn parse_line(line: &str) -> Result<Request, (Option<String>, ServiceError)> {
    let j = Json::parse(line)
        .map_err(|e| (None, ServiceError::new(ErrorKind::BadJson, e.to_string())))?;
    let id = req_id(&j);
    let err_id = id.clone();
    let bad = move |msg: String| (err_id.clone(), ServiceError::new(ErrorKind::BadRequest, msg));
    let Some(obj) = j.as_obj() else {
        return Err(bad("request must be a JSON object".into()));
    };
    for k in obj.keys() {
        if ![
            "id", "op", "model", "cluster", "cost", "sweep", "budget", "timing", "target",
        ]
        .contains(&k.as_str())
        {
            return Err(bad(format!(
                "unknown request field '{k}' \
                 (id|op|model|cluster|cost|sweep|budget|timing|target)"
            )));
        }
    }
    if let Some(v) = j.get("id") {
        if v.as_str().is_none() {
            return Err(bad("'id' must be a string".into()));
        }
    }
    if let Some(v) = j.get("timing") {
        if v.as_bool().is_none() {
            return Err(bad("'timing' must be a boolean".into()));
        }
    }
    let op = j.get("op").and_then(Json::as_str).unwrap_or("sweep");
    if op != "cancel" && j.get("target").is_some() {
        return Err(bad(format!("'target' is only valid on op 'cancel' (got '{op}')")));
    }
    match op {
        "cancel" => {
            let target = j
                .get("target")
                .ok_or_else(|| bad("cancel request missing 'target' (the sweep id to abort)".into()))?
                .as_str()
                .ok_or_else(|| bad("'target' must be a string".into()))?
                .to_string();
            Ok(Request::Cancel { id, target })
        }
        "ping" => Ok(Request::Ping { id }),
        "stats" => Ok(Request::Stats { id }),
        "metrics" => Ok(Request::Metrics { id }),
        "shutdown" => Ok(Request::Shutdown { id }),
        "sweep" => {
            let model_name = j
                .get("model")
                .and_then(Json::as_str)
                .ok_or_else(|| bad("sweep request missing 'model'".into()))?
                .to_string();
            let model = crate::model::by_name(&model_name)
                .ok_or_else(|| bad(format!("unknown model '{model_name}'")))?;
            let cluster = cluster_from_json(
                j.get("cluster")
                    .ok_or_else(|| bad("sweep request missing 'cluster'".into()))?,
            )
            .map_err(|e| bad(e.to_string()))?;
            let cost = match j.get("cost") {
                Some(c) => cost_from_json_strict(c).map_err(|e| bad(e.to_string()))?,
                None => CostBook::default(),
            };
            let mut sweep =
                sweep_config_from_json(j.get("sweep")).map_err(|e| bad(e.to_string()))?;
            // a scenario naming a device the cluster doesn't have is a
            // bad_request at admission, not a silent no-op episode
            sweep
                .scenario
                .validate_devices(cluster.total_devices())
                .map_err(|e| bad(e.to_string()))?;
            let mut deadline_ms = None;
            if let Some(b) = j.get("budget") {
                let obj = b
                    .as_obj()
                    .ok_or_else(|| bad("'budget' must be an object".into()))?;
                for (k, v) in obj {
                    if !["max_candidates", "deadline_ms"].contains(&k.as_str()) {
                        return Err(bad(format!(
                            "unknown budget field '{k}' (max_candidates|deadline_ms)"
                        )));
                    }
                    if v.as_f64().is_none() {
                        return Err(bad(format!("budget field '{k}' must be a number")));
                    }
                }
                if let Some(v) = b.get("max_candidates").and_then(Json::as_usize) {
                    sweep.max_candidates = v;
                }
                deadline_ms = b.get("deadline_ms").and_then(Json::as_u64);
            }
            Ok(Request::Sweep(Box::new(SweepRequest {
                id,
                model_name,
                model,
                cluster,
                cost,
                sweep,
                deadline_ms,
                include_timing: j.get("timing").and_then(Json::as_bool).unwrap_or(false),
            })))
        }
        other => Err(bad(format!("unknown op '{other}' ({})", OPS.join("|")))),
    }
}

fn id_json(id: Option<&str>) -> Json {
    match id {
        Some(s) => Json::str(s),
        None => Json::Null,
    }
}

/// One-line error response.
pub fn error_response(id: Option<&str>, err: &ServiceError) -> Json {
    let mut fields = vec![
        ("kind", Json::str(err.kind.name())),
        ("message", Json::str(&err.message)),
    ];
    for (k, v) in &err.detail {
        fields.push((k, v.clone()));
    }
    Json::obj(vec![
        ("id", id_json(id)),
        ("ok", Json::Bool(false)),
        ("error", Json::obj(fields)),
    ])
}

/// The one-line JSON form of a CLI failure, shared with the service's
/// error path so scripts can parse `distsim` stderr uniformly.
pub fn cli_error_line(err: &anyhow::Error) -> String {
    error_response(
        None,
        &ServiceError::new(ErrorKind::Cli, format!("{err:#}")),
    )
    .to_string()
}

pub fn pong_response(id: Option<&str>) -> Json {
    Json::obj(vec![
        ("id", id_json(id)),
        ("ok", Json::Bool(true)),
        ("result", Json::obj(vec![("op", Json::str("ping"))])),
    ])
}

/// Response to a `cancel` op. `outcome` is one of:
///
/// * `"cancelled_queued"` — the target was still queued and was dropped
///   outright (the target's own response line is a `cancelled` error);
/// * `"cancelling"` — the target is mid-sweep; its token fired and it
///   will stop at the next candidate boundary (its response line is a
///   `cancelled` error when it does);
/// * `"not_found"` — no queued or running sweep with that id exists on
///   this connection (already finished, never existed, or sent without
///   an id — cancellation requires the target to be addressable).
pub fn cancel_response(id: Option<&str>, target: &str, outcome: &str) -> Json {
    Json::obj(vec![
        ("id", id_json(id)),
        ("ok", Json::Bool(true)),
        (
            "result",
            Json::obj(vec![
                ("op", Json::str("cancel")),
                ("target", Json::str(target)),
                ("outcome", Json::str(outcome)),
            ]),
        ),
    ])
}

pub fn shutdown_response(id: Option<&str>) -> Json {
    Json::obj(vec![
        ("id", id_json(id)),
        ("ok", Json::Bool(true)),
        ("result", Json::obj(vec![("op", Json::str("shutdown"))])),
    ])
}

/// Per-fingerprint cache occupancy plus scenario-sweep counters for the
/// `stats` op. `scenario_sweeps` counts scenario-bearing sweep requests
/// served since startup; `scenario_episodes` the episodes those requests'
/// specs carried (both monotone across the daemon's lifetime). `plans`
/// is the plan cache's `(compiles, full hits, partial reuses)` trio —
/// also monotone, and every plan-cached sweep increments exactly one.
pub fn stats_response(
    id: Option<&str>,
    caches: &[(String, usize)],
    scenario_sweeps: usize,
    scenario_episodes: usize,
    plans: (usize, usize, usize),
) -> Json {
    let (plan_compiles, plan_hits, plan_partial) = plans;
    Json::obj(vec![
        ("id", id_json(id)),
        ("ok", Json::Bool(true)),
        (
            "result",
            Json::obj(vec![
                ("op", Json::str("stats")),
                (
                    "caches",
                    Json::Arr(
                        caches
                            .iter()
                            .map(|(fp, n)| {
                                Json::obj(vec![
                                    ("fingerprint", Json::str(fp)),
                                    ("events", Json::num(*n as f64)),
                                ])
                            })
                            .collect(),
                    ),
                ),
                (
                    "scenario",
                    Json::obj(vec![
                        ("sweeps", Json::num(scenario_sweeps as f64)),
                        ("episodes", Json::num(scenario_episodes as f64)),
                    ]),
                ),
                // plan-cache accounting (ISSUE 10): every plan-cached
                // sweep lands in exactly one of the three buckets, so
                // compiles + hits + partial == plan-cached sweeps served
                (
                    "plans",
                    Json::obj(vec![
                        ("compiles", Json::num(plan_compiles as f64)),
                        ("hits", Json::num(plan_hits as f64)),
                        ("partial", Json::num(plan_partial as f64)),
                    ]),
                ),
            ]),
        ),
    ])
}

/// Serialize a `metrics` response: the telemetry registry's snapshot in
/// both exposition forms. `metrics` is [`ServiceMetrics::export_json`]
/// output, `prometheus` the text form. Diagnostic like `stats`: the
/// histograms are wall-clock, so the payload is outside the byte-identity
/// contract (DESIGN.md §9) — hence the explicit `deterministic: false`.
///
/// [`ServiceMetrics::export_json`]: crate::telemetry::ServiceMetrics::export_json
pub fn metrics_response(id: Option<&str>, metrics: Json, prometheus: &str) -> Json {
    Json::obj(vec![
        ("id", id_json(id)),
        ("ok", Json::Bool(true)),
        (
            "result",
            Json::obj(vec![
                ("op", Json::str("metrics")),
                ("deterministic", Json::Bool(false)),
                ("metrics", metrics),
                ("prometheus", Json::str(prometheus)),
            ]),
        ),
    ])
}

fn cache_stats_json(s: &CacheStats) -> Json {
    Json::obj(vec![
        ("hits", Json::num(s.hits as f64)),
        ("misses", Json::num(s.misses as f64)),
        ("unique_events", Json::num(s.unique_events as f64)),
        ("gpu_seconds", Json::num(s.gpu_seconds)),
        ("extrapolated", Json::num(s.extrapolated as f64)),
        ("hit_rate", Json::num(s.hit_rate())),
    ])
}

/// Serialize a sweep's outcome. `cache` is the accounting to report —
/// the daemon substitutes its admission-order stats for the engine's
/// prior-relative ones; one-shot callers pass `report.cache`.
pub fn sweep_response(
    id: Option<&str>,
    fingerprint: &str,
    report: &SweepReport,
    cache: &CacheStats,
    include_timing: bool,
    trace: Option<Json>,
) -> Json {
    let table_json = |idx: u32| {
        report
            .tables
            .get(idx as usize)
            .map(|t| Json::Arr(t.iter().map(|&d| Json::num(d as f64)).collect()))
    };
    // memory accounting ran iff some candidate carries a peak (every
    // valid candidate does once the stage runs — weights are never 0) or
    // the stage pruned something; derived from the report itself so the
    // gate is deterministic and needs no side-channel. Off ⇒ responses
    // stay byte-identical to pre-memory builds.
    let memory = report.pruning.memory_pruned > 0
        || report.candidates.iter().any(|c| c.peak_bytes > 0);
    let candidates: Vec<Json> = report
        .candidates
        .iter()
        .map(|c| {
            let mut fields = vec![
                ("strategy", Json::str(c.strategy.notation())),
                ("schedule", Json::str(c.schedule.name())),
                ("placement", Json::str(c.placement.name())),
                ("micro_batch_size", Json::num(c.micro_batch_size as f64)),
                ("micro_batches", Json::num(c.micro_batches as f64)),
                ("throughput", Json::num(c.throughput)),
                ("reachable", Json::Bool(c.reachable)),
                ("pruned", Json::Bool(c.pruned)),
                ("bound_throughput", Json::num(c.bound_throughput)),
            ];
            if report.robustness.is_some() {
                fields.push(("scenario_throughput", Json::num(c.scenario_throughput)));
            }
            if memory {
                fields.push(("recompute", Json::str(c.recompute.name())));
                fields.push(("zero_stage", Json::num(c.zero_stage as f64)));
                fields.push(("peak_bytes", Json::num(c.peak_bytes as f64)));
                fields.push(("fits", Json::Bool(c.fits)));
                if !c.fits {
                    // the memory stage's free placeholder verdict
                    fields.push(("reason", Json::str("oom")));
                }
            }
            if let Some(t) = table_json(c.table) {
                fields.push(("table", t));
            }
            Json::obj(fields)
        })
        .collect();
    let mut result = vec![
        ("op", Json::str("sweep")),
        ("fingerprint", Json::str(fingerprint)),
        ("candidates", Json::Arr(candidates)),
        (
            "evaluated",
            Json::num(report.evaluated_count() as f64),
        ),
        ("pruned", Json::num(report.pruned_count() as f64)),
        (
            "pruning",
            Json::obj({
                let mut fields = vec![
                    ("generated", Json::num(report.pruning.generated as f64)),
                    (
                        "bound_pruned",
                        Json::num(report.pruning.bound_pruned as f64),
                    ),
                    (
                        "epoch_repruned",
                        Json::num(report.pruning.epoch_repruned as f64),
                    ),
                    ("evaluated", Json::num(report.pruning.evaluated as f64)),
                    (
                        "gpu_seconds_avoided",
                        Json::num(report.pruning.gpu_seconds_avoided),
                    ),
                ];
                if memory {
                    fields.push((
                        "memory_pruned",
                        Json::num(report.pruning.memory_pruned as f64),
                    ));
                    fields.push((
                        "memory_gpu_seconds_avoided",
                        Json::num(report.pruning.memory_gpu_seconds_avoided),
                    ));
                }
                fields
            }),
        ),
        ("cache", cache_stats_json(cache)),
    ];
    if let Some(b) = report.best() {
        let mut fields = vec![
            ("strategy", Json::str(b.strategy.notation())),
            ("schedule", Json::str(b.schedule.name())),
            ("placement", Json::str(b.placement.name())),
            ("throughput", Json::num(b.throughput)),
        ];
        if memory {
            fields.push(("peak_bytes", Json::num(b.peak_bytes as f64)));
        }
        if let Some(t) = table_json(b.table) {
            fields.push(("table", t));
        }
        result.push(("best", Json::obj(fields)));
    }
    if let Some(w) = report.worst() {
        result.push((
            "worst",
            Json::obj(vec![
                ("strategy", Json::str(w.strategy.notation())),
                ("schedule", Json::str(w.schedule.name())),
                ("placement", Json::str(w.placement.name())),
                ("throughput", Json::num(w.throughput)),
            ]),
        ));
    }
    if let Some(s) = report.speedup() {
        result.push(("speedup", Json::num(s)));
    }
    if let Some(a) = report.schedule_attribution() {
        result.push((
            "schedule_attribution",
            Json::obj(vec![
                ("winning_schedule", Json::str(a.winning_schedule.name())),
                ("schedule_speedup", Json::num(a.schedule_speedup)),
                ("strategy_speedup", Json::num(a.strategy_speedup)),
            ]),
        ));
    }
    if let Some(a) = report.placement_attribution() {
        result.push((
            "placement_attribution",
            Json::obj(vec![
                ("winning_placement", Json::str(a.winning_placement.name())),
                ("placement_speedup", Json::num(a.placement_speedup)),
                ("strategy_speedup", Json::num(a.strategy_speedup)),
            ]),
        ));
    }
    if let Some(rb) = &report.robustness {
        let notation = |i: usize| report.candidates[i].strategy.notation();
        result.push((
            "robustness",
            Json::obj(vec![
                ("nominal_best", Json::str(notation(rb.nominal_best))),
                ("scenario_best", Json::str(notation(rb.scenario_best))),
                (
                    "scenario_best_throughput",
                    Json::num(report.candidates[rb.scenario_best].scenario_throughput),
                ),
                ("regret", Json::num(rb.regret)),
                ("scenario_slowdown", Json::num(rb.scenario_slowdown)),
                ("straggler_slowdown", Json::num(rb.straggler_slowdown)),
                ("link_slowdown", Json::num(rb.link_slowdown)),
                ("restart_penalty_us", Json::num(rb.restart_penalty_us)),
                ("reshard_us", Json::num(rb.reshard_us)),
                ("episodes", Json::num(rb.episodes as f64)),
            ]),
        ));
    }
    if include_timing {
        result.push((
            "timing",
            Json::obj(vec![
                ("total_seconds", Json::num(report.timing.total_seconds)),
                ("threads_used", Json::num(report.threads_used as f64)),
            ]),
        ));
    }
    // opt-in (`sweep.trace: true`) request-lifecycle block — wall-clock,
    // quantized, explicitly non-deterministic; absent by default so the
    // payload stays byte-identical (DESIGN.md §9)
    if let Some(t) = trace {
        result.push(("trace", t));
    }
    Json::obj(vec![
        ("id", id_json(id)),
        ("ok", Json::Bool(true)),
        ("result", Json::Obj(result.into_iter().map(|(k, v)| (k.to_string(), v)).collect())),
    ])
}

/// Build a sweep-request line from CLI-style parts (`distsim ask`).
pub fn build_request_line(
    id: &str,
    model: &str,
    cluster: &ClusterSpec,
    sweep_overrides: Vec<(&str, Json)>,
    max_candidates: usize,
    timing: bool,
) -> String {
    let mut req = vec![
        ("id", Json::str(id)),
        ("op", Json::str("sweep")),
        ("model", Json::str(model)),
        ("cluster", cluster.to_json()),
        ("sweep", Json::obj(sweep_overrides)),
    ];
    if max_candidates > 0 {
        req.push((
            "budget",
            Json::obj(vec![("max_candidates", Json::num(max_candidates as f64))]),
        ));
    }
    if timing {
        req.push(("timing", Json::Bool(true)));
    }
    Json::obj(req).to_string()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_minimal_sweep_request() {
        let line = r#"{"id":"r1","model":"bert-large","cluster":{"preset":"a40","nodes":2,"gpus_per_node":4},"sweep":{"global_batch":8}}"#;
        match parse_line(line).unwrap() {
            Request::Sweep(req) => {
                assert_eq!(req.id.as_deref(), Some("r1"));
                assert_eq!(req.model.name, "bert-large");
                assert_eq!(req.cluster.total_devices(), 8);
                assert_eq!(req.sweep.global_batch, 8);
                assert_eq!(req.sweep.threads, 1, "service default is 1 thread");
                assert!(!req.include_timing);
            }
            other => panic!("expected sweep, got {other:?}"),
        }
    }

    #[test]
    fn parse_control_ops() {
        assert!(matches!(
            parse_line(r#"{"op":"ping"}"#).unwrap(),
            Request::Ping { id: None }
        ));
        assert!(matches!(
            parse_line(r#"{"op":"shutdown","id":"x"}"#).unwrap(),
            Request::Shutdown { id: Some(_) }
        ));
        assert!(matches!(
            parse_line(r#"{"op":"stats"}"#).unwrap(),
            Request::Stats { id: None }
        ));
    }

    #[test]
    fn parse_cancel_op() {
        match parse_line(r#"{"id":"c1","op":"cancel","target":"r7"}"#).unwrap() {
            Request::Cancel { id, target } => {
                assert_eq!(id.as_deref(), Some("c1"));
                assert_eq!(target, "r7");
            }
            other => panic!("expected cancel, got {other:?}"),
        }
        // target is required, must be a string, and is cancel-only
        let (_, e) = parse_line(r#"{"op":"cancel"}"#).unwrap_err();
        assert_eq!(e.kind, ErrorKind::BadRequest);
        assert!(e.message.contains("target"));
        let (_, e) = parse_line(r#"{"op":"cancel","target":7}"#).unwrap_err();
        assert_eq!(e.kind, ErrorKind::BadRequest);
        let (_, e) = parse_line(r#"{"op":"ping","target":"r7"}"#).unwrap_err();
        assert_eq!(e.kind, ErrorKind::BadRequest);
        assert!(e.message.contains("only valid on op 'cancel'"));
        let (_, e) = parse_line(
            r#"{"model":"bert-large","cluster":{"preset":"a40"},"target":"r7"}"#,
        )
        .unwrap_err();
        assert_eq!(e.kind, ErrorKind::BadRequest);
    }

    #[test]
    fn cancel_response_shape() {
        let j = cancel_response(Some("c1"), "r7", "cancelled_queued");
        let line = j.to_string();
        let back = Json::parse(&line).unwrap();
        assert_eq!(back.get("ok").and_then(Json::as_bool), Some(true));
        let r = back.get("result").unwrap();
        assert_eq!(r.get("op").and_then(Json::as_str), Some("cancel"));
        assert_eq!(r.get("target").and_then(Json::as_str), Some("r7"));
        assert_eq!(r.get("outcome").and_then(Json::as_str), Some("cancelled_queued"));
    }

    #[test]
    fn bad_lines_map_to_structured_errors() {
        let (id, e) = parse_line("{not json").unwrap_err();
        assert_eq!((id, e.kind), (None, ErrorKind::BadJson));

        let (id, e) = parse_line(r#"{"id":"q","op":"frobnicate"}"#).unwrap_err();
        assert_eq!(id.as_deref(), Some("q"));
        assert_eq!(e.kind, ErrorKind::BadRequest);

        let (_, e) = parse_line(r#"{"model":"nope","cluster":{"preset":"a40"}}"#).unwrap_err();
        assert_eq!(e.kind, ErrorKind::BadRequest);
        assert!(e.message.contains("nope"));

        let (_, e) = parse_line(r#"{"model":"bert-large"}"#).unwrap_err();
        assert!(e.message.contains("cluster"));
    }

    #[test]
    fn strict_sweep_and_budget_keys() {
        // a typo'd axis name must not silently run the default sweep
        for body in [
            r#""sweep":{"mbs_axis":true}"#,
            r#""sweep":{"schedual_axis":true}"#,
            r#""sweep":{"global_batch":"32"}"#,
            r#""sweep":{"prune":"true"}"#,
            r#""budget":{"deadline":5}"#,
            r#""budget":{"deadline_ms":"100"}"#,
            r#""budget":7"#,
            r#""cluster2":0"#,
        ] {
            let line =
                format!(r#"{{"model":"bert-large","cluster":{{"preset":"a40"}},{body}}}"#);
            let (_, e) = parse_line(&line).unwrap_err();
            assert_eq!(e.kind, ErrorKind::BadRequest, "{body}");
        }
    }

    #[test]
    fn strict_cost_and_preset_validation() {
        // typo'd / mistyped cost overrides are rejected, not defaulted
        for cost in [
            r#"{"scail":2.0}"#,
            r#"{"scale":"2.0"}"#,
            r#"[1]"#,
            r#"{"per_kind":{"A10":{"scail":2.0}}}"#,
            r#"{"per_kind":{"A10":{"scale":"2.0"}}}"#,
            r#"{"per_kind":[1]}"#,
        ] {
            let line = format!(
                r#"{{"model":"bert-large","cluster":{{"preset":"a40"}},"cost":{cost}}}"#
            );
            let (_, e) = parse_line(&line).unwrap_err();
            assert_eq!(e.kind, ErrorKind::BadRequest, "{cost}");
        }
        // a valid override parses
        let line = r#"{"model":"bert-large","cluster":{"preset":"a40"},"cost":{"scale":2.0}}"#;
        match parse_line(line).unwrap() {
            Request::Sweep(req) => assert_eq!(req.cost.base.scale, 2.0),
            other => panic!("expected sweep, got {other:?}"),
        }
        // the a100 pod is 8 GPUs/node: a mismatched request is an error
        assert!(cluster_from_json(
            &Json::parse(r#"{"preset":"a100","nodes":2,"gpus_per_node":4}"#).unwrap()
        )
        .is_err());
        let pod = cluster_from_json(
            &Json::parse(r#"{"preset":"a100","nodes":2,"gpus_per_node":8}"#).unwrap(),
        )
        .unwrap();
        assert_eq!(pod.total_devices(), 16);
    }

    #[test]
    fn scenario_parses_and_is_validated_against_the_cluster() {
        let line = r#"{"model":"bert-large","cluster":{"preset":"a40","nodes":2,"gpus_per_node":4},"sweep":{"scenario":{"stragglers":[{"device":3,"factor":1.5}],"resize":{"dp_delta":-1,"reshard_us":250}}}}"#;
        match parse_line(line).unwrap() {
            Request::Sweep(req) => {
                assert_eq!(req.sweep.scenario.stragglers.len(), 1);
                assert_eq!(req.sweep.scenario.resize.unwrap().dp_delta, -1);
            }
            other => panic!("expected sweep, got {other:?}"),
        }
        // device 9 is off an 8-GPU cluster: bad_request, not a no-op
        let line = r#"{"model":"bert-large","cluster":{"preset":"a40","nodes":2,"gpus_per_node":4},"sweep":{"scenario":{"stragglers":[{"device":9,"factor":1.5}]}}}"#;
        let (_, e) = parse_line(line).unwrap_err();
        assert_eq!(e.kind, ErrorKind::BadRequest);
        assert!(e.message.contains("out of range"), "{}", e.message);
        // typo'd scenario fields are rejected by the strict spec parser
        for scn in [
            r#"{"straglers":[]}"#,
            r#"{"stragglers":[{"device":0,"factor":"x"}]}"#,
            r#"[1]"#,
        ] {
            let line = format!(
                r#"{{"model":"bert-large","cluster":{{"preset":"a40"}},"sweep":{{"scenario":{scn}}}}}"#
            );
            let (_, e) = parse_line(&line).unwrap_err();
            assert_eq!(e.kind, ErrorKind::BadRequest, "{scn}");
        }
    }

    #[test]
    fn memory_sweep_keys_parse_strictly() {
        let line = r#"{"model":"bert-large","cluster":{"preset":"a40"},"sweep":{"recompute_axis":true,"zero_axis":true,"memory":true}}"#;
        match parse_line(line).unwrap() {
            Request::Sweep(req) => {
                assert!(req.sweep.recompute_axis);
                assert!(req.sweep.zero_axis);
                assert!(req.sweep.memory);
            }
            other => panic!("expected sweep, got {other:?}"),
        }
        // the axes are booleans like every other axis flag
        for body in [
            r#""sweep":{"recompute_axis":1}"#,
            r#""sweep":{"zero_axis":"yes"}"#,
            r#""sweep":{"memory":0}"#,
        ] {
            let line =
                format!(r#"{{"model":"bert-large","cluster":{{"preset":"a40"}},{body}}}"#);
            let (_, e) = parse_line(&line).unwrap_err();
            assert_eq!(e.kind, ErrorKind::BadRequest, "{body}");
        }
    }

    #[test]
    fn preset_capacity_bytes_caps_every_sku() {
        let c = cluster_from_json(
            &Json::parse(r#"{"preset":"a40","nodes":2,"gpus_per_node":4,"capacity_bytes":3000000000}"#)
                .unwrap(),
        )
        .unwrap();
        assert!(c.has_capacity());
        // mistyped capacities are rejected, never silently cast
        for cap in ["\"48GiB\"", "0", "-5", "1.5"] {
            let j = Json::parse(&format!(r#"{{"preset":"a40","capacity_bytes":{cap}}}"#))
                .unwrap();
            assert!(cluster_from_json(&j).is_err(), "{cap}");
        }
    }

    #[test]
    fn budget_overrides_max_candidates() {
        let line = r#"{"model":"bert-large","cluster":{"preset":"a40"},"budget":{"max_candidates":3,"deadline_ms":500}}"#;
        match parse_line(line).unwrap() {
            Request::Sweep(req) => {
                assert_eq!(req.sweep.max_candidates, 3);
                assert_eq!(req.deadline_ms, Some(500));
            }
            other => panic!("expected sweep, got {other:?}"),
        }
    }

    #[test]
    fn error_response_is_one_parseable_line() {
        let e = ServiceError::new(ErrorKind::BadJson, "expected ',' or '}'\nat byte 3");
        let line = error_response(Some("r9"), &e).to_string();
        assert!(!line.contains('\n'), "must stay one line: {line}");
        let j = Json::parse(&line).unwrap();
        assert_eq!(j.get("ok").and_then(Json::as_bool), Some(false));
        assert_eq!(
            j.get("error").unwrap().get("kind").and_then(Json::as_str),
            Some("bad_json")
        );
    }

    #[test]
    fn parse_metrics_op_and_sweep_trace_flag() {
        assert!(matches!(
            parse_line(r#"{"op":"metrics"}"#).unwrap(),
            Request::Metrics { id: None }
        ));
        assert!(matches!(
            parse_line(r#"{"id":"m1","op":"metrics"}"#).unwrap(),
            Request::Metrics { id: Some(_) }
        ));
        let line = r#"{"model":"bert-large","cluster":{"preset":"a40"},"sweep":{"trace":true}}"#;
        match parse_line(line).unwrap() {
            Request::Sweep(req) => assert!(req.sweep.trace),
            other => panic!("expected sweep, got {other:?}"),
        }
        // trace must be a bool, like every other sweep flag
        let line = r#"{"model":"bert-large","cluster":{"preset":"a40"},"sweep":{"trace":"yes"}}"#;
        let (_, e) = parse_line(line).unwrap_err();
        assert_eq!(e.kind, ErrorKind::BadRequest);
    }

    #[test]
    fn error_detail_fields_land_in_the_error_object() {
        let e = ServiceError::new(ErrorKind::Unavailable, "admission queue is full")
            .with_detail("depth", Json::num(32.0))
            .with_detail("max_queue", Json::num(32.0));
        let j = Json::parse(&error_response(Some("r1"), &e).to_string()).unwrap();
        let err = j.get("error").unwrap();
        assert_eq!(err.get("kind").and_then(Json::as_str), Some("unavailable"));
        assert_eq!(err.get("depth").and_then(Json::as_u64), Some(32));
        assert_eq!(err.get("max_queue").and_then(Json::as_u64), Some(32));
    }

    #[test]
    fn metrics_response_carries_both_exposition_forms() {
        let m = crate::telemetry::ServiceMetrics::new();
        m.requests_total.inc();
        let line =
            metrics_response(Some("m1"), m.export_json(), &m.export_prometheus()).to_string();
        assert!(!line.contains('\n'), "must stay one line: {line}");
        let j = Json::parse(&line).unwrap();
        let r = j.get("result").unwrap();
        assert_eq!(r.get("op").and_then(Json::as_str), Some("metrics"));
        assert_eq!(r.get("deterministic").and_then(Json::as_bool), Some(false));
        assert!(r.get("metrics").unwrap().get("counters").is_some());
        let prom = r.get("prometheus").and_then(Json::as_str).unwrap();
        assert!(prom.contains("distsim_requests_total 1"));
    }

    #[test]
    fn cli_error_line_parses() {
        let e = anyhow::anyhow!("unknown command 'frobnicate'");
        let j = Json::parse(&cli_error_line(&e)).unwrap();
        assert_eq!(
            j.get("error").unwrap().get("kind").and_then(Json::as_str),
            Some("cli")
        );
        assert!(j
            .get("error")
            .unwrap()
            .get("message")
            .and_then(Json::as_str)
            .unwrap()
            .contains("unknown command"));
    }
}
