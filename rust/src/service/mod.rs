//! The what-if sweep service (ROADMAP: "Async sweep service").
//!
//! The paper's headline use-case treats DistSim as a cheap throughput
//! oracle: ask it many "what if I deployed this model, on this cluster,
//! with that strategy space?" questions instead of renting the cluster
//! (§6's 7.37× result; Proteus and DistIR frame the same capability as a
//! query-serving *system*). This module turns the one-shot
//! [`SearchEngine`](crate::search::SearchEngine) sweep into exactly that: a
//! long-lived daemon answering concurrent sweep requests over
//! newline-delimited JSON, with every request sharing the profile-cache
//! measurements of everything the daemon — in this run or any previous one
//! — has already priced.
//!
//! Three pieces:
//!
//! * [`protocol`] — the NDJSON request/response schema, parsed with the
//!   crate's own [`Json`](crate::config::Json); malformed input maps to
//!   structured error responses, shared with the CLI's error path.
//! * [`daemon`] — transports (stdio, TCP), the bounded admission queue
//!   (`--max-queue`, overflow shed with structured `unavailable` errors),
//!   the worker pool with cooperative sweep cancellation (`cancel` op),
//!   the per-fingerprint [`CacheRegistry`] with disk-persistent
//!   snapshots, the per-shape [`PlanCache`] of compiled sweep plans
//!   (the profile cache shares *measurements*, the plan cache shares
//!   *planning* — candidate spaces, bounds, memory verdicts, event
//!   sets — with delta-aware invalidation; DESIGN.md §11), and the
//!   per-connection in-order writer that keeps each
//!   connection's response stream deterministic without cross-connection
//!   head-of-line blocking (see the module docs for the determinism,
//!   fairness and cancellation contracts).
//! * `distsim serve` / `distsim ask` — the CLI entry points (`main.rs`);
//!   `ask` doubles as an in-process self-test client.
//!
//! The daemon observes itself through [`crate::telemetry`]: a `metrics`
//! op exposes the registry in structured-JSON and Prometheus text forms,
//! `sweep.trace: true` returns a quantized per-request lifecycle trace,
//! `--trace-dir` writes Chrome-trace files of the daemon's own request
//! handling, and `--log-level` gates one-line JSON log events on stderr.
//! All of it is out-of-band (DESIGN.md §9): deterministic sweep payloads
//! are byte-identical whether telemetry is on or off.
//!
//! The engine stays the single execution core: the daemon builds the same
//! [`SearchEngine`](crate::search::SearchEngine) the CLI does, injecting a
//! shared cache via `with_cache` — there is no service-only sweep fork.

pub mod daemon;
pub mod protocol;

pub use daemon::{
    serve_ndjson, serve_tcp, CacheRegistry, PlanCache, ServeOpts, ServeSummary, DEFAULT_MAX_QUEUE,
};
pub use protocol::{cli_error_line, ErrorKind, Request, ServiceError, SweepRequest};
