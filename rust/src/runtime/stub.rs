//! Offline stand-in for the PJRT backend (the default build): the `xla`
//! bindings are unavailable without network access, so creating the
//! runtime reports a clear error instead of failing to link. Callers that
//! degrade gracefully (`profile::calibrate`, the integration tests'
//! artifact self-skip) keep working; only actually *executing* an HLO
//! artifact requires `--features pjrt` plus the `xla` dependency.

use anyhow::Result;

use super::ArtifactSpec;

const UNAVAILABLE: &str =
    "PJRT runtime unavailable: built without the `pjrt` feature (needs the `xla` bindings)";

/// Placeholder for the compiled-executable handle.
pub struct LoadedExecutable {
    pub spec: ArtifactSpec,
}

/// Placeholder runtime; [`Runtime::cpu`] always errors.
pub struct Runtime {
    _private: (),
}

impl Runtime {
    pub fn cpu() -> Result<Runtime> {
        anyhow::bail!(UNAVAILABLE)
    }

    pub fn platform(&self) -> String {
        "unavailable".to_string()
    }

    pub fn load(&self, spec: &ArtifactSpec) -> Result<LoadedExecutable> {
        let _ = spec;
        anyhow::bail!(UNAVAILABLE)
    }
}

impl LoadedExecutable {
    pub fn run_once_us(&self) -> Result<f64> {
        anyhow::bail!(UNAVAILABLE)
    }

    pub fn bench_us(&self, iters: usize) -> Result<f64> {
        let _ = iters;
        anyhow::bail!(UNAVAILABLE)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn cpu_errors_cleanly_without_pjrt() {
        let err = Runtime::cpu().unwrap_err();
        assert!(err.to_string().contains("pjrt"), "{err}");
    }
}
