//! Real PJRT backend (feature `pjrt`): compiles and executes the AOT HLO
//! artifacts through the `xla` bindings. Building this file requires
//! adding the `xla` crate to `[dependencies]` by hand — the offline
//! vendor set does not carry it, which is why the feature is off by
//! default and `stub.rs` stands in.

use anyhow::{Context, Result};

use super::ArtifactSpec;

/// A compiled, executable HLO module on the PJRT CPU client.
pub struct LoadedExecutable {
    pub spec: ArtifactSpec,
    exe: xla::PjRtLoadedExecutable,
    args: Vec<xla::Literal>,
}

/// PJRT-CPU runtime holding the client and loaded executables.
pub struct Runtime {
    client: xla::PjRtClient,
}

impl Runtime {
    pub fn cpu() -> Result<Runtime> {
        let client = xla::PjRtClient::cpu().map_err(|e| anyhow::anyhow!("{e:?}"))?;
        Ok(Runtime { client })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Compile one artifact (HLO text → executable) and pre-build zero
    /// literals for its arguments.
    pub fn load(&self, spec: &ArtifactSpec) -> Result<LoadedExecutable> {
        let proto = xla::HloModuleProto::from_text_file(
            spec.path
                .to_str()
                .context("non-utf8 artifact path")?,
        )
        .map_err(|e| anyhow::anyhow!("loading {}: {e:?}", spec.path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .map_err(|e| anyhow::anyhow!("compiling {}: {e:?}", spec.name))?;
        let args = spec
            .arg_shapes
            .iter()
            .map(|dims| {
                let n: usize = dims.iter().product();
                // small pseudo-random fill (timing is data-independent for
                // dense kernels; non-zero avoids denormal weirdness)
                let data: Vec<f32> = (0..n).map(|i| ((i % 13) as f32 - 6.0) * 0.01).collect();
                let lit = xla::Literal::vec1(&data);
                let dims_i64: Vec<i64> = dims.iter().map(|&d| d as i64).collect();
                lit.reshape(&dims_i64)
                    .map_err(|e| anyhow::anyhow!("reshape: {e:?}"))
            })
            .collect::<Result<Vec<_>>>()?;
        Ok(LoadedExecutable {
            spec: spec.clone(),
            exe,
            args,
        })
    }
}

impl LoadedExecutable {
    /// Execute once, synchronously, returning elapsed wall time (us).
    pub fn run_once_us(&self) -> Result<f64> {
        let t0 = std::time::Instant::now();
        let result = self
            .exe
            .execute::<xla::Literal>(&self.args)
            .map_err(|e| anyhow::anyhow!("execute {}: {e:?}", self.spec.name))?;
        // force completion
        let _lit = result[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow::anyhow!("sync: {e:?}"))?;
        Ok(t0.elapsed().as_secs_f64() * 1e6)
    }

    /// Median-of-`iters` timing after one warmup run.
    pub fn bench_us(&self, iters: usize) -> Result<f64> {
        self.run_once_us()?; // warmup (compile caches, allocator)
        let mut samples = Vec::with_capacity(iters);
        for _ in 0..iters {
            samples.push(self.run_once_us()?);
        }
        Ok(crate::util::stats::median(&samples))
    }
}
