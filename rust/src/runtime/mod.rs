//! PJRT runtime: load and execute the AOT HLO artifacts from Rust.
//!
//! This is the L3↔L2 bridge: `python/compile/aot.py` lowers the JAX/Pallas
//! event graphs to HLO *text* once at build time; this module compiles and
//! runs them on the PJRT CPU client so the profiler can time real compute
//! (`profile::calibrate`). Python never runs at simulation time.
//!
//! Everything here degrades gracefully: if `artifacts/` is absent the
//! simulator falls back to the analytic device model, so `cargo test`
//! works without a prior `make artifacts`.

use std::path::{Path, PathBuf};

use anyhow::{Context, Result};

use crate::config::Json;

/// One entry of `artifacts/manifest.json`.
#[derive(Debug, Clone)]
pub struct ArtifactSpec {
    pub name: String,
    pub path: PathBuf,
    pub kind: String,
    pub flops: u64,
    /// Argument shapes (row-major dims) — all f32 in this project.
    pub arg_shapes: Vec<Vec<usize>>,
}

/// Parsed manifest.
#[derive(Debug, Clone, Default)]
pub struct Manifest {
    pub artifacts: Vec<ArtifactSpec>,
}

impl Manifest {
    /// Load `manifest.json` from an artifacts directory.
    pub fn load(dir: &Path) -> Result<Manifest> {
        let text = std::fs::read_to_string(dir.join("manifest.json"))
            .with_context(|| format!("reading {}/manifest.json", dir.display()))?;
        let j = Json::parse(&text).context("parsing manifest.json")?;
        let arr = j
            .get("artifacts")
            .and_then(Json::as_arr)
            .context("manifest missing 'artifacts'")?;
        let mut artifacts = Vec::with_capacity(arr.len());
        for a in arr {
            let get_str = |k: &str| {
                a.get(k)
                    .and_then(Json::as_str)
                    .map(str::to_string)
                    .with_context(|| format!("artifact missing '{k}'"))
            };
            let arg_shapes = a
                .get("args")
                .and_then(Json::as_arr)
                .context("artifact missing args")?
                .iter()
                .map(|arg| {
                    arg.get("shape")
                        .and_then(Json::as_arr)
                        .map(|dims| {
                            dims.iter()
                                .filter_map(Json::as_usize)
                                .collect::<Vec<usize>>()
                        })
                        .context("arg missing shape")
                })
                .collect::<Result<Vec<_>>>()?;
            artifacts.push(ArtifactSpec {
                name: get_str("name")?,
                path: dir.join(get_str("path")?),
                kind: get_str("kind")?,
                flops: a.get("flops").and_then(Json::as_u64).unwrap_or(0),
                arg_shapes,
            });
        }
        Ok(Manifest { artifacts })
    }

    pub fn by_kind(&self, kind: &str) -> Vec<&ArtifactSpec> {
        self.artifacts.iter().filter(|a| a.kind == kind).collect()
    }

    pub fn by_name(&self, name: &str) -> Option<&ArtifactSpec> {
        self.artifacts.iter().find(|a| a.name == name)
    }
}

/// A compiled, executable HLO module on the PJRT CPU client.
pub struct LoadedExecutable {
    pub spec: ArtifactSpec,
    exe: xla::PjRtLoadedExecutable,
    args: Vec<xla::Literal>,
}

/// PJRT-CPU runtime holding the client and loaded executables.
pub struct Runtime {
    client: xla::PjRtClient,
}

impl Runtime {
    pub fn cpu() -> Result<Runtime> {
        let client = xla::PjRtClient::cpu().map_err(|e| anyhow::anyhow!("{e:?}"))?;
        Ok(Runtime { client })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Compile one artifact (HLO text → executable) and pre-build zero
    /// literals for its arguments.
    pub fn load(&self, spec: &ArtifactSpec) -> Result<LoadedExecutable> {
        let proto = xla::HloModuleProto::from_text_file(
            spec.path
                .to_str()
                .context("non-utf8 artifact path")?,
        )
        .map_err(|e| anyhow::anyhow!("loading {}: {e:?}", spec.path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .map_err(|e| anyhow::anyhow!("compiling {}: {e:?}", spec.name))?;
        let args = spec
            .arg_shapes
            .iter()
            .map(|dims| {
                let n: usize = dims.iter().product();
                // small pseudo-random fill (timing is data-independent for
                // dense kernels; non-zero avoids denormal weirdness)
                let data: Vec<f32> = (0..n).map(|i| ((i % 13) as f32 - 6.0) * 0.01).collect();
                let lit = xla::Literal::vec1(&data);
                let dims_i64: Vec<i64> = dims.iter().map(|&d| d as i64).collect();
                lit.reshape(&dims_i64)
                    .map_err(|e| anyhow::anyhow!("reshape: {e:?}"))
            })
            .collect::<Result<Vec<_>>>()?;
        Ok(LoadedExecutable {
            spec: spec.clone(),
            exe,
            args,
        })
    }
}

impl LoadedExecutable {
    /// Execute once, synchronously, returning elapsed wall time (us).
    pub fn run_once_us(&self) -> Result<f64> {
        let t0 = std::time::Instant::now();
        let result = self
            .exe
            .execute::<xla::Literal>(&self.args)
            .map_err(|e| anyhow::anyhow!("execute {}: {e:?}", self.spec.name))?;
        // force completion
        let _lit = result[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow::anyhow!("sync: {e:?}"))?;
        Ok(t0.elapsed().as_secs_f64() * 1e6)
    }

    /// Median-of-`iters` timing after one warmup run.
    pub fn bench_us(&self, iters: usize) -> Result<f64> {
        self.run_once_us()?; // warmup (compile caches, allocator)
        let mut samples = Vec::with_capacity(iters);
        for _ in 0..iters {
            samples.push(self.run_once_us()?);
        }
        Ok(crate::util::stats::median(&samples))
    }
}

/// Default artifacts directory: `$DISTSIM_ARTIFACTS` or `./artifacts`.
pub fn artifacts_dir() -> PathBuf {
    std::env::var("DISTSIM_ARTIFACTS")
        .map(PathBuf::from)
        .unwrap_or_else(|_| PathBuf::from("artifacts"))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn manifest_parses_minimal_example() {
        let dir = std::env::temp_dir().join("distsim_manifest_test");
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(
            dir.join("manifest.json"),
            r#"{"artifacts":[{"name":"m","path":"m.hlo.txt","kind":"matmul","flops":4194304,
                "args":[{"shape":[128,128],"dtype":"float32"},{"shape":[128,128],"dtype":"float32"}]}]}"#,
        )
        .unwrap();
        let m = Manifest::load(&dir).unwrap();
        assert_eq!(m.artifacts.len(), 1);
        assert_eq!(m.artifacts[0].arg_shapes[0], vec![128, 128]);
        assert_eq!(m.by_kind("matmul").len(), 1);
        assert!(m.by_name("nope").is_none());
    }

    #[test]
    fn manifest_load_fails_cleanly_when_absent() {
        let err = Manifest::load(Path::new("/nonexistent/dir")).unwrap_err();
        assert!(err.to_string().contains("manifest.json"));
    }

    // Full PJRT round-trip tests live in rust/tests/runtime_integration.rs
    // (they need `make artifacts` to have run).
}
