//! PJRT runtime: load and execute the AOT HLO artifacts from Rust.
//!
//! This is the L3↔L2 bridge: `python/compile/aot.py` lowers the JAX/Pallas
//! event graphs to HLO *text* once at build time; this module compiles and
//! runs them on the PJRT CPU client so the profiler can time real compute
//! (`profile::calibrate`). Python never runs at simulation time.
//!
//! Everything here degrades gracefully: if `artifacts/` is absent the
//! simulator falls back to the analytic device model, so `cargo test`
//! works without a prior `make artifacts`. The PJRT client itself needs
//! the `xla` bindings, which the offline vendor set does not carry, so the
//! executing backend is gated behind the `pjrt` cargo feature: without it,
//! [`Runtime::cpu`] returns an error (callers like `profile::calibrate`
//! and the `calibrate` CLI surface it cleanly) while manifest parsing and
//! the whole simulator keep working.

use std::path::{Path, PathBuf};

use anyhow::{Context, Result};

use crate::config::Json;

#[cfg(feature = "pjrt")]
mod pjrt;
#[cfg(feature = "pjrt")]
pub use pjrt::{LoadedExecutable, Runtime};

#[cfg(not(feature = "pjrt"))]
mod stub;
#[cfg(not(feature = "pjrt"))]
pub use stub::{LoadedExecutable, Runtime};

/// One entry of `artifacts/manifest.json`.
#[derive(Debug, Clone)]
pub struct ArtifactSpec {
    pub name: String,
    pub path: PathBuf,
    pub kind: String,
    pub flops: u64,
    /// Argument shapes (row-major dims) — all f32 in this project.
    pub arg_shapes: Vec<Vec<usize>>,
}

/// Parsed manifest.
#[derive(Debug, Clone, Default)]
pub struct Manifest {
    pub artifacts: Vec<ArtifactSpec>,
}

impl Manifest {
    /// Load `manifest.json` from an artifacts directory.
    pub fn load(dir: &Path) -> Result<Manifest> {
        let text = std::fs::read_to_string(dir.join("manifest.json"))
            .with_context(|| format!("reading {}/manifest.json", dir.display()))?;
        let j = Json::parse(&text).context("parsing manifest.json")?;
        let arr = j
            .get("artifacts")
            .and_then(Json::as_arr)
            .context("manifest missing 'artifacts'")?;
        let mut artifacts = Vec::with_capacity(arr.len());
        for a in arr {
            let get_str = |k: &str| {
                a.get(k)
                    .and_then(Json::as_str)
                    .map(str::to_string)
                    .with_context(|| format!("artifact missing '{k}'"))
            };
            let arg_shapes = a
                .get("args")
                .and_then(Json::as_arr)
                .context("artifact missing args")?
                .iter()
                .map(|arg| {
                    arg.get("shape")
                        .and_then(Json::as_arr)
                        .map(|dims| {
                            dims.iter()
                                .filter_map(Json::as_usize)
                                .collect::<Vec<usize>>()
                        })
                        .context("arg missing shape")
                })
                .collect::<Result<Vec<_>>>()?;
            artifacts.push(ArtifactSpec {
                name: get_str("name")?,
                path: dir.join(get_str("path")?),
                kind: get_str("kind")?,
                flops: a.get("flops").and_then(Json::as_u64).unwrap_or(0),
                arg_shapes,
            });
        }
        Ok(Manifest { artifacts })
    }

    pub fn by_kind(&self, kind: &str) -> Vec<&ArtifactSpec> {
        self.artifacts.iter().filter(|a| a.kind == kind).collect()
    }

    pub fn by_name(&self, name: &str) -> Option<&ArtifactSpec> {
        self.artifacts.iter().find(|a| a.name == name)
    }
}

/// Default artifacts directory: `$DISTSIM_ARTIFACTS` or `./artifacts`.
pub fn artifacts_dir() -> PathBuf {
    std::env::var("DISTSIM_ARTIFACTS")
        .map(PathBuf::from)
        .unwrap_or_else(|_| PathBuf::from("artifacts"))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn manifest_parses_minimal_example() {
        let dir = std::env::temp_dir().join("distsim_manifest_test");
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(
            dir.join("manifest.json"),
            r#"{"artifacts":[{"name":"m","path":"m.hlo.txt","kind":"matmul","flops":4194304,
                "args":[{"shape":[128,128],"dtype":"float32"},{"shape":[128,128],"dtype":"float32"}]}]}"#,
        )
        .unwrap();
        let m = Manifest::load(&dir).unwrap();
        assert_eq!(m.artifacts.len(), 1);
        assert_eq!(m.artifacts[0].arg_shapes[0], vec![128, 128]);
        assert_eq!(m.by_kind("matmul").len(), 1);
        assert!(m.by_name("nope").is_none());
    }

    #[test]
    fn manifest_load_fails_cleanly_when_absent() {
        let err = Manifest::load(Path::new("/nonexistent/dir")).unwrap_err();
        assert!(err.to_string().contains("manifest.json"));
    }

    // Full PJRT round-trip tests live in rust/tests/runtime_integration.rs
    // (they need `make artifacts` to have run).
}
