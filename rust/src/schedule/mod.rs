//! Pipeline-parallel schedules (paper §2.1.3, §4.3): the per-stage order
//! in which micro-batch forward/backward tasks execute.
//!
//! Implemented algorithms, as in the paper: **GPipe** (all forwards, then
//! all backwards) and **Dapple** (1F1B: a warmup of forwards, then strict
//! forward/backward alternation, then a backward cooldown), plus the
//! no-micro-batching **naive** pipeline for reference.
//!
//! The schedule fixes *order only*; timing comes from dependencies —
//! enforced physically by the ground-truth engine (send/recv rendezvous)
//! and analytically by DistSim's Algorithm-1 modeling. That split is what
//! makes heterogeneous fleets (ISSUE 4) free at this layer: a schedule is
//! valid regardless of which SKU each stage lands on, and stage latencies
//! that vary by device kind enter purely through the executors — per-rank
//! base costs in the engine, per-kind composed-event durations in the
//! model — never through the task order itself.

use std::fmt;

/// Training phase of a micro-batch at a stage.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Phase {
    Fwd,
    Bwd,
}

impl fmt::Display for Phase {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Phase::Fwd => write!(f, "F"),
            Phase::Bwd => write!(f, "B"),
        }
    }
}

/// One entry in a stage's execution order.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct StageTask {
    pub mb: usize,
    pub phase: Phase,
}

/// A complete pipeline schedule.
#[derive(Debug, Clone)]
pub struct PipelineSchedule {
    pub name: String,
    pub micro_batches: usize,
    /// `stage_tasks[s]` = execution order on stage `s`.
    pub stage_tasks: Vec<Vec<StageTask>>,
}

/// GPipe: F(0) .. F(M-1), then B(M-1) .. B(0) on every stage.
pub fn gpipe(pp: usize, micro_batches: usize) -> PipelineSchedule {
    let mut stage_tasks = Vec::with_capacity(pp);
    for _ in 0..pp {
        let mut tasks = Vec::with_capacity(2 * micro_batches);
        for m in 0..micro_batches {
            tasks.push(StageTask { mb: m, phase: Phase::Fwd });
        }
        for m in (0..micro_batches).rev() {
            tasks.push(StageTask { mb: m, phase: Phase::Bwd });
        }
        stage_tasks.push(tasks);
    }
    PipelineSchedule {
        name: "gpipe".into(),
        micro_batches,
        stage_tasks,
    }
}

/// Dapple / 1F1B: stage `s` runs `min(pp - s - 1, M)` warmup forwards,
/// then alternates one-forward-one-backward, then drains backwards.
/// Caps in-flight activations at `pp - s`, Dapple's memory advantage.
pub fn dapple(pp: usize, micro_batches: usize) -> PipelineSchedule {
    let m_total = micro_batches;
    let mut stage_tasks = Vec::with_capacity(pp);
    for s in 0..pp {
        let warmup = (pp - s - 1).min(m_total);
        let mut tasks = Vec::with_capacity(2 * m_total);
        for m in 0..warmup {
            tasks.push(StageTask { mb: m, phase: Phase::Fwd });
        }
        // steady state: F(warmup + i), B(i)
        for i in 0..m_total - warmup {
            tasks.push(StageTask { mb: warmup + i, phase: Phase::Fwd });
            tasks.push(StageTask { mb: i, phase: Phase::Bwd });
        }
        // cooldown: remaining backwards
        for m in m_total - warmup..m_total {
            tasks.push(StageTask { mb: m, phase: Phase::Bwd });
        }
        stage_tasks.push(tasks);
    }
    PipelineSchedule {
        name: "dapple".into(),
        micro_batches,
        stage_tasks,
    }
}

/// Naive pipeline: the whole batch flows as a single micro-batch.
pub fn naive(pp: usize) -> PipelineSchedule {
    let mut s = gpipe(pp, 1);
    s.name = "naive".into();
    s
}

/// The implemented schedule algorithms, as a value the strategy sweep can
/// enumerate as a search axis (paper §2.1.3; the sweep's third dimension
/// next to strategy and micro-batch size).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub enum SchedKind {
    Dapple,
    GPipe,
    Naive,
}

impl SchedKind {
    /// Every implemented schedule, in deterministic sweep order (the seed
    /// protocol's Dapple first).
    pub const ALL: [SchedKind; 3] = [SchedKind::Dapple, SchedKind::GPipe, SchedKind::Naive];

    /// Canonical CLI name.
    pub fn name(&self) -> &'static str {
        match self {
            SchedKind::Dapple => "dapple",
            SchedKind::GPipe => "gpipe",
            SchedKind::Naive => "naive",
        }
    }

    pub fn parse(name: &str) -> anyhow::Result<SchedKind> {
        match name.to_ascii_lowercase().as_str() {
            "gpipe" => Ok(SchedKind::GPipe),
            "dapple" | "1f1b" => Ok(SchedKind::Dapple),
            "naive" => Ok(SchedKind::Naive),
            other => anyhow::bail!("unknown schedule '{other}' (gpipe|dapple|naive)"),
        }
    }

    /// Build the schedule for a pipeline of depth `pp`. `micro_batches` is
    /// ignored by [`SchedKind::Naive`], which always runs one micro-batch.
    pub fn build(&self, pp: usize, micro_batches: usize) -> PipelineSchedule {
        match self {
            SchedKind::Dapple => dapple(pp, micro_batches),
            SchedKind::GPipe => gpipe(pp, micro_batches),
            SchedKind::Naive => naive(pp),
        }
    }
}

impl fmt::Display for SchedKind {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.name())
    }
}

/// Look up a schedule builder by CLI name.
pub fn by_name(name: &str, pp: usize, micro_batches: usize) -> anyhow::Result<PipelineSchedule> {
    Ok(SchedKind::parse(name)?.build(pp, micro_batches))
}

impl PipelineSchedule {
    pub fn pp(&self) -> usize {
        self.stage_tasks.len()
    }

    /// Sanity invariants every schedule must satisfy; used by tests and
    /// asserted (debug) before simulation.
    pub fn validate(&self) -> anyhow::Result<()> {
        for (s, tasks) in self.stage_tasks.iter().enumerate() {
            let m = self.micro_batches;
            anyhow::ensure!(
                tasks.len() == 2 * m,
                "stage {s}: {} tasks != 2*{m}",
                tasks.len()
            );
            let mut fwd_pos = vec![None; m];
            let mut bwd_pos = vec![None; m];
            for (i, t) in tasks.iter().enumerate() {
                let slot = match t.phase {
                    Phase::Fwd => &mut fwd_pos,
                    Phase::Bwd => &mut bwd_pos,
                };
                anyhow::ensure!(
                    slot[t.mb].replace(i).is_none(),
                    "stage {s}: duplicate {t:?}"
                );
            }
            for mb in 0..m {
                let (f, b) = (fwd_pos[mb].unwrap(), bwd_pos[mb].unwrap());
                anyhow::ensure!(f < b, "stage {s}: B({mb}) before F({mb})");
            }
        }
        Ok(())
    }

    /// Max number of micro-batches whose activations are alive at once on
    /// `stage` (forward done, backward not yet) — the memory high-water.
    pub fn max_in_flight(&self, stage: usize) -> usize {
        let mut alive = 0usize;
        let mut peak = 0usize;
        for t in &self.stage_tasks[stage] {
            match t.phase {
                Phase::Fwd => {
                    alive += 1;
                    peak = peak.max(alive);
                }
                Phase::Bwd => alive -= 1,
            }
        }
        peak
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn gpipe_valid_for_many_shapes() {
        for (pp, m) in [(1, 1), (2, 4), (4, 4), (8, 16), (4, 1)] {
            gpipe(pp, m).validate().unwrap();
        }
    }

    #[test]
    fn dapple_valid_for_many_shapes() {
        for (pp, m) in [(1, 1), (2, 4), (4, 4), (8, 16), (4, 2), (16, 4)] {
            dapple(pp, m).validate().unwrap();
        }
    }

    #[test]
    fn gpipe_order_all_f_then_all_b() {
        let s = gpipe(2, 3);
        let t = &s.stage_tasks[0];
        assert_eq!(
            t.iter().map(|x| (x.mb, x.phase)).collect::<Vec<_>>(),
            vec![
                (0, Phase::Fwd),
                (1, Phase::Fwd),
                (2, Phase::Fwd),
                (2, Phase::Bwd),
                (1, Phase::Bwd),
                (0, Phase::Bwd),
            ]
        );
    }

    #[test]
    fn dapple_last_stage_alternates_immediately() {
        let s = dapple(4, 4);
        let last = &s.stage_tasks[3];
        assert_eq!(last[0], StageTask { mb: 0, phase: Phase::Fwd });
        assert_eq!(last[1], StageTask { mb: 0, phase: Phase::Bwd });
    }

    #[test]
    fn dapple_caps_in_flight_memory() {
        let pp = 4;
        let m = 8;
        let g = gpipe(pp, m);
        let d = dapple(pp, m);
        // GPipe stage 0 holds all M activations; Dapple holds at most pp.
        assert_eq!(g.max_in_flight(0), m);
        assert_eq!(d.max_in_flight(0), pp);
        assert!(d.max_in_flight(pp - 1) <= 1 + 1);
    }

    #[test]
    fn dapple_equals_gpipe_for_pp1() {
        // no pipeline -> both degenerate to sequential F/B per micro-batch
        let d = dapple(1, 4);
        d.validate().unwrap();
        assert_eq!(d.max_in_flight(0), 1);
    }

    #[test]
    fn naive_is_single_microbatch() {
        let n = naive(4);
        n.validate().unwrap();
        assert_eq!(n.micro_batches, 1);
    }

    #[test]
    fn by_name_dispatch() {
        assert_eq!(by_name("gpipe", 2, 4).unwrap().name, "gpipe");
        assert_eq!(by_name("1F1B", 2, 4).unwrap().name, "dapple");
        assert!(by_name("chimera", 2, 4).is_err());
    }

    #[test]
    fn sched_kind_roundtrips_and_builds() {
        for k in SchedKind::ALL {
            assert_eq!(SchedKind::parse(k.name()).unwrap(), k);
            let s = k.build(4, 8);
            s.validate().unwrap();
            assert_eq!(s.name, k.name());
        }
        assert_eq!(SchedKind::Naive.build(4, 8).micro_batches, 1);
        assert!(SchedKind::parse("chimera").is_err());
    }
}

#[cfg(test)]
mod proptests {
    use super::*;
    use crate::testutil;

    #[test]
    fn prop_random_schedules_are_valid() {
        testutil::check("schedule-valid", 200, |rng| {
            let pp = 1 + rng.below(12) as usize;
            let m = 1 + rng.below(24) as usize;
            gpipe(pp, m).validate().unwrap();
            dapple(pp, m).validate().unwrap();
        });
    }

    #[test]
    fn prop_dapple_in_flight_never_exceeds_pipeline_depth() {
        testutil::check("dapple-memory", 200, |rng| {
            let pp = 1 + rng.below(12) as usize;
            let m = 1 + rng.below(24) as usize;
            let d = dapple(pp, m);
            for s in 0..pp {
                assert!(
                    d.max_in_flight(s) <= pp.min(m).max(1),
                    "pp={pp} m={m} stage {s}: in-flight {}",
                    d.max_in_flight(s)
                );
            }
        });
    }

    #[test]
    fn prop_gpipe_and_dapple_agree_on_task_multiset() {
        testutil::check("same-tasks", 100, |rng| {
            let pp = 1 + rng.below(8) as usize;
            let m = 1 + rng.below(16) as usize;
            let (g, d) = (gpipe(pp, m), dapple(pp, m));
            for s in 0..pp {
                let mut a: Vec<(usize, bool)> = g.stage_tasks[s]
                    .iter()
                    .map(|t| (t.mb, t.phase == Phase::Fwd))
                    .collect();
                let mut b: Vec<(usize, bool)> = d.stage_tasks[s]
                    .iter()
                    .map(|t| (t.mb, t.phase == Phase::Fwd))
                    .collect();
                a.sort();
                b.sort();
                assert_eq!(a, b);
            }
        });
    }
}
