//! Timeline analyses the paper motivates: device utilization summaries and
//! pipeline-bubble extraction (§5: "helps programmers to locate pipeline
//! bubbles and perform practical operations such as fault-tolerance during
//! bubbles").

use super::Timeline;
use crate::util::TimeUs;

/// An idle interval on a device between two activities.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Bubble {
    pub device: usize,
    pub start: TimeUs,
    pub end: TimeUs,
}

impl Bubble {
    pub fn dur(&self) -> TimeUs {
        self.end - self.start
    }
}

/// All idle gaps longer than `min_us` on every device, within the span of
/// the whole step (leading/trailing idle included).
pub fn bubbles(t: &Timeline, min_us: TimeUs) -> Vec<Bubble> {
    let mut out = Vec::new();
    if t.is_empty() {
        return out;
    }
    let t0 = t.start_us();
    let t1 = t.end_us();
    for d in 0..t.n_devices {
        let spans = t.device_spans(d);
        let mut cursor = t0;
        for s in spans {
            if s.start - cursor > min_us {
                out.push(Bubble {
                    device: d,
                    start: cursor,
                    end: s.start,
                });
            }
            cursor = cursor.max(s.end);
        }
        if t1 - cursor > min_us {
            out.push(Bubble {
                device: d,
                start: cursor,
                end: t1,
            });
        }
    }
    out
}

/// Fraction of total device-time lost to bubbles.
pub fn bubble_ratio(t: &Timeline) -> f64 {
    let bt = t.batch_time_us();
    if bt == 0.0 || t.n_devices == 0 {
        return 0.0;
    }
    let idle: TimeUs = bubbles(t, 0.0).iter().map(Bubble::dur).sum();
    idle / (bt * t.n_devices as f64)
}

/// Utilization summary across devices: (min, mean, max).
pub fn utilization_summary(t: &Timeline) -> (f64, f64, f64) {
    if t.n_devices == 0 {
        return (0.0, 0.0, 0.0);
    }
    let us: Vec<f64> = (0..t.n_devices).map(|d| t.utilization(d)).collect();
    (
        crate::util::stats::min(&us),
        crate::util::stats::mean(&us),
        crate::util::stats::max(&us),
    )
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::schedule::Phase;
    use crate::timeline::{Span, SpanKind, Tag};

    fn tl() -> Timeline {
        let mut t = Timeline::new(2);
        let tag = Tag {
            stage: 0,
            mb: 0,
            phase: Phase::Fwd,
            layer: 0,
            kind: SpanKind::Comp,
            idx: 0,
        };
        // device 0: busy [0,10] and [20,30]; device 1: busy [0,30]
        t.push(Span { device: 0, start: 0.0, end: 10.0, tag });
        t.push(Span { device: 0, start: 20.0, end: 30.0, tag });
        t.push(Span { device: 1, start: 0.0, end: 30.0, tag });
        t.finalize();
        t
    }

    #[test]
    fn finds_the_gap() {
        let bs = bubbles(&tl(), 1.0);
        assert_eq!(bs.len(), 1);
        assert_eq!(bs[0].device, 0);
        assert_eq!((bs[0].start, bs[0].end), (10.0, 20.0));
    }

    #[test]
    fn bubble_ratio_matches_hand_count() {
        // total device-time = 2 * 30 = 60; idle = 10 -> ratio 1/6
        assert!((bubble_ratio(&tl()) - 10.0 / 60.0).abs() < 1e-12);
    }

    #[test]
    fn utilization_summary_ordering() {
        let (lo, mid, hi) = utilization_summary(&tl());
        assert!(lo <= mid && mid <= hi);
        assert!((hi - 1.0).abs() < 1e-12);
        assert!((lo - 2.0 / 3.0).abs() < 1e-12);
    }

    #[test]
    fn min_us_filter_suppresses_small_gaps() {
        assert!(bubbles(&tl(), 15.0).is_empty());
    }
}
