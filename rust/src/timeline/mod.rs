//! Per-device activity timelines — DistSim's output artifact (§3.2: "a
//! detailed execution timeline ... when and which device will compute and
//! communicate for certain operators").
//!
//! Both the ground-truth engine and DistSim's hierarchical modeling emit
//! this same structure, so the metrics layer can align spans one-to-one
//! and compute the paper's three error families (batch time, per-GPU
//! activity, per-stage timestamps).
//!
//! The store is **build-then-finalize columnar**: producers [`push`]
//! spans in any order, then call [`Timeline::finalize`], which lays the
//! spans out device-major (a stable counting sort into a per-device
//! offset index) and caches the global start/end extremes and per-device
//! busy totals. After finalize, [`device_spans`]/[`device_comp_spans`]
//! are borrowed slices and [`batch_time_us`]/[`busy_us`]/[`utilization`]
//! are O(1) — a sweep compares hundreds of candidate timelines, so these
//! queries are the metric-side hot path (§Perf).
//!
//! [`push`]: Timeline::push
//! [`device_spans`]: Timeline::device_spans
//! [`device_comp_spans`]: Timeline::device_comp_spans
//! [`batch_time_us`]: Timeline::batch_time_us
//! [`busy_us`]: Timeline::busy_us
//! [`utilization`]: Timeline::utilization

pub mod analysis;
pub mod chrome;

use crate::schedule::Phase;
use crate::util::TimeUs;

/// What a span on a device's lane represents.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum SpanKind {
    /// A computation event (layer fwd/bwd, embedding, head).
    Comp,
    /// Receiving an inter-stage activation / gradient transfer.
    P2p,
    /// Tensor-MP partial-sum all-reduce inside a layer.
    MpAllReduce,
    /// Data-parallel gradient all-reduce at batch end.
    GradAllReduce,
}

/// Identity of a span within the training step — identical between the
/// ground truth and the model, so spans align by (device, tag, order).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct Tag {
    pub stage: u32,
    pub mb: u32,
    pub phase: Phase,
    /// Layer index within the model (u32::MAX when not layer-specific,
    /// e.g. the DP gradient all-reduce).
    pub layer: u32,
    pub kind: SpanKind,
    /// Disambiguator for repeated events inside one (stage, mb, phase,
    /// layer), e.g. the two Megatron MP all-reduces.
    pub idx: u32,
}

impl Tag {
    pub fn comp(stage: usize, mb: usize, phase: Phase, layer: usize) -> Tag {
        Tag {
            stage: stage as u32,
            mb: mb as u32,
            phase,
            layer: layer as u32,
            kind: SpanKind::Comp,
            idx: 0,
        }
    }

    pub fn label(&self) -> String {
        match self.kind {
            SpanKind::Comp => format!(
                "{}{} s{} L{}",
                self.phase, self.mb, self.stage, self.layer
            ),
            SpanKind::P2p => format!("p2p {}{} s{}", self.phase, self.mb, self.stage),
            SpanKind::MpAllReduce => format!(
                "mp-ar {}{} s{} L{}#{}",
                self.phase, self.mb, self.stage, self.layer, self.idx
            ),
            SpanKind::GradAllReduce => format!("grad-ar s{}", self.stage),
        }
    }
}

/// One activity interval on one device.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Span {
    pub device: usize,
    pub start: TimeUs,
    pub end: TimeUs,
    pub tag: Tag,
}

impl Span {
    pub fn dur(&self) -> TimeUs {
        self.end - self.start
    }
}

/// A complete step timeline over all devices.
///
/// Lifecycle: [`Timeline::new`] → [`Timeline::push`]* →
/// [`Timeline::finalize`] → queries. An empty timeline counts as
/// finalized; pushing marks it un-finalized again. Queries on an
/// un-finalized timeline panic rather than silently rescanning.
#[derive(Debug, Clone)]
pub struct Timeline {
    pub n_devices: usize,
    /// Device-major after finalize; insertion order before.
    spans: Vec<Span>,
    finalized: bool,
    /// `offsets[d]..offsets[d+1]` is device d's slice of `spans`.
    offsets: Vec<usize>,
    /// Computation spans only, device-major (the per-GPU activity metric
    /// aligns these; kept contiguous so the accessor is a borrowed slice).
    comp: Vec<Span>,
    comp_offsets: Vec<usize>,
    /// Per-device busy totals (sum of span durations).
    busy: Vec<TimeUs>,
    /// Global earliest start / latest end.
    t0: TimeUs,
    t1: TimeUs,
    /// Counting-sort staging buffer, recycled across finalizes.
    sort_buf: Vec<Span>,
}

impl Default for Timeline {
    fn default() -> Self {
        Timeline::new(0)
    }
}

impl Timeline {
    pub fn new(n_devices: usize) -> Self {
        Timeline {
            n_devices,
            spans: Vec::new(),
            finalized: true, // empty is trivially indexed
            offsets: Vec::new(),
            comp: Vec::new(),
            comp_offsets: Vec::new(),
            busy: Vec::new(),
            t0: 0.0,
            t1: 0.0,
            sort_buf: Vec::new(),
        }
    }

    /// A builder pre-sized for `cap` spans (producers know their
    /// instruction counts up front).
    pub fn with_capacity(n_devices: usize, cap: usize) -> Self {
        let mut t = Timeline::new(n_devices);
        t.spans.reserve(cap);
        t
    }

    /// Clear all contents for reuse, keeping every allocation (the
    /// engine's scratch path recycles timelines across iterations).
    pub fn reset(&mut self, n_devices: usize) {
        self.n_devices = n_devices;
        self.spans.clear();
        self.finalized = true;
        self.offsets.clear();
        self.comp.clear();
        self.comp_offsets.clear();
        self.busy.clear();
        self.t0 = 0.0;
        self.t1 = 0.0;
    }

    /// Reserve room for `additional` more spans.
    pub fn reserve(&mut self, additional: usize) {
        self.spans.reserve(additional);
    }

    pub fn push(&mut self, span: Span) {
        debug_assert!(span.end >= span.start, "negative span {span:?}");
        debug_assert!(span.device < self.n_devices);
        self.finalized = false;
        self.spans.push(span);
    }

    /// Index the spans: device-major layout, per-device start order,
    /// cached extremes and busy totals. Idempotent; O(S) when producers
    /// already emit per-device start-sorted spans (all of ours do —
    /// per-rank clocks are monotone), O(S log S) worst case.
    pub fn finalize(&mut self) {
        if self.finalized {
            return;
        }
        if self.spans.is_empty() {
            self.finalized = true;
            return;
        }
        let n = self.n_devices;
        self.offsets.clear();
        self.offsets.resize(n + 1, 0);
        for s in &self.spans {
            self.offsets[s.device + 1] += 1;
        }
        for d in 0..n {
            self.offsets[d + 1] += self.offsets[d];
        }
        // stable counting sort by device into the staging buffer
        // (preserves insertion order within a device, like the old
        // filter-then-stable-sort query path)
        self.comp_offsets.clear(); // reused as per-device write cursors
        self.comp_offsets.extend_from_slice(&self.offsets[..n]);
        self.sort_buf.clear();
        self.sort_buf.resize(self.spans.len(), self.spans[0]);
        for &s in &self.spans {
            let cursor = &mut self.comp_offsets[s.device];
            self.sort_buf[*cursor] = s;
            *cursor += 1;
        }
        std::mem::swap(&mut self.spans, &mut self.sort_buf);
        self.sort_buf.clear();

        // per-device: ensure start order, then one pass for the caches
        self.busy.clear();
        self.busy.resize(n, 0.0);
        self.comp.clear();
        self.comp_offsets.clear();
        self.comp_offsets.push(0);
        let mut t0 = f64::INFINITY;
        let mut t1 = f64::NEG_INFINITY;
        for d in 0..n {
            let (lo, hi) = (self.offsets[d], self.offsets[d + 1]);
            let lane = &mut self.spans[lo..hi];
            if lane.windows(2).any(|w| w[1].start < w[0].start) {
                lane.sort_by(|a, b| a.start.partial_cmp(&b.start).unwrap());
            }
            for s in &self.spans[lo..hi] {
                self.busy[d] += s.dur();
                t0 = t0.min(s.start);
                t1 = t1.max(s.end);
                if s.tag.kind == SpanKind::Comp {
                    self.comp.push(*s);
                }
            }
            self.comp_offsets.push(self.comp.len());
        }
        self.t0 = t0;
        self.t1 = t1;
        self.finalized = true;
    }

    #[inline]
    fn assert_finalized(&self) {
        assert!(
            self.finalized,
            "Timeline queried before finalize(); call finalize() after the last push"
        );
    }

    /// All spans, as raw storage: device-major after finalize, insertion
    /// order before. Deliberately exempt from the finalize contract —
    /// exporters (chrome traces) and the naive reference semantics read
    /// the bag of spans without needing the index.
    pub fn spans(&self) -> &[Span] {
        &self.spans
    }

    pub fn len(&self) -> usize {
        self.spans.len()
    }

    pub fn is_empty(&self) -> bool {
        self.spans.is_empty()
    }

    /// Earliest span start (the paper's global standard time origin).
    /// O(1) after finalize.
    pub fn start_us(&self) -> TimeUs {
        self.assert_finalized();
        if self.spans.is_empty() {
            0.0
        } else {
            self.t0
        }
    }

    /// Latest span end. O(1) after finalize.
    pub fn end_us(&self) -> TimeUs {
        self.assert_finalized();
        if self.spans.is_empty() {
            0.0
        } else {
            self.t1
        }
    }

    /// Iteration (batch) time: last end minus first start. O(1).
    pub fn batch_time_us(&self) -> TimeUs {
        self.assert_finalized();
        if self.spans.is_empty() {
            return 0.0;
        }
        self.t1 - self.t0
    }

    /// All spans of one device, in start order — a borrowed slice into
    /// the columnar store (no clone, no re-sort).
    pub fn device_spans(&self, device: usize) -> &[Span] {
        self.assert_finalized();
        if self.spans.is_empty() {
            return &[];
        }
        &self.spans[self.offsets[device]..self.offsets[device + 1]]
    }

    /// Compute spans of one device, in start order (the paper's per-GPU
    /// activity metric aligns these) — a borrowed slice.
    pub fn device_comp_spans(&self, device: usize) -> &[Span] {
        self.assert_finalized();
        if self.spans.is_empty() {
            return &[];
        }
        &self.comp[self.comp_offsets[device]..self.comp_offsets[device + 1]]
    }

    /// Busy time (sum of span durations) of a device. O(1).
    pub fn busy_us(&self, device: usize) -> TimeUs {
        self.assert_finalized();
        if self.spans.is_empty() {
            return 0.0;
        }
        self.busy[device]
    }

    /// Device utilization = busy / batch time. O(1).
    pub fn utilization(&self, device: usize) -> f64 {
        let bt = self.batch_time_us();
        if bt == 0.0 {
            return 0.0;
        }
        (self.busy_us(device) / bt).min(1.0)
    }

    /// Shift all spans so the earliest start is 0 (the paper aligns both
    /// timelines to the first stage's start before comparing). The
    /// metrics layer no longer needs this — it subtracts [`start_us`]
    /// in place — but exporters still align traces with it.
    ///
    /// [`start_us`]: Timeline::start_us
    pub fn normalized(&self) -> Timeline {
        self.assert_finalized();
        let mut t = self.clone();
        if t.spans.is_empty() {
            return t;
        }
        let t0 = t.t0;
        for s in &mut t.spans {
            s.start -= t0;
            s.end -= t0;
        }
        for s in &mut t.comp {
            s.start -= t0;
            s.end -= t0;
        }
        t.t1 -= t0;
        t.t0 = 0.0;
        // re-derive busy from the shifted spans: (end - t0) - (start - t0)
        // can differ from (end - start) at ulp level, and the cache must
        // stay coherent with what a rescan of the spans would yield
        for d in 0..t.n_devices {
            let (lo, hi) = (t.offsets[d], t.offsets[d + 1]);
            t.busy[d] = t.spans[lo..hi].iter().map(Span::dur).sum();
        }
        t
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn span(device: usize, start: f64, end: f64, kind: SpanKind) -> Span {
        Span {
            device,
            start,
            end,
            tag: Tag {
                stage: 0,
                mb: 0,
                phase: Phase::Fwd,
                layer: 0,
                kind,
                idx: 0,
            },
        }
    }

    #[test]
    fn batch_time_spans_extremes() {
        let mut t = Timeline::new(2);
        t.push(span(0, 10.0, 20.0, SpanKind::Comp));
        t.push(span(1, 5.0, 12.0, SpanKind::Comp));
        t.push(span(1, 30.0, 45.0, SpanKind::P2p));
        t.finalize();
        assert_eq!(t.batch_time_us(), 40.0);
        assert_eq!(t.start_us(), 5.0);
        assert_eq!(t.end_us(), 45.0);
    }

    #[test]
    fn device_spans_sorted_and_filtered() {
        let mut t = Timeline::new(2);
        t.push(span(0, 20.0, 25.0, SpanKind::Comp));
        t.push(span(0, 0.0, 5.0, SpanKind::Comp));
        t.push(span(0, 10.0, 15.0, SpanKind::P2p));
        t.push(span(1, 0.0, 1.0, SpanKind::Comp));
        t.finalize();
        let d0 = t.device_spans(0);
        assert_eq!(d0.len(), 3);
        assert!(d0.windows(2).all(|w| w[0].start <= w[1].start));
        assert_eq!(t.device_comp_spans(0).len(), 2);
    }

    #[test]
    fn device_ranges_partition_the_span_set() {
        let mut t = Timeline::new(3);
        t.push(span(2, 0.0, 1.0, SpanKind::Comp));
        t.push(span(0, 3.0, 4.0, SpanKind::P2p));
        t.push(span(2, 1.0, 2.0, SpanKind::Comp));
        t.finalize();
        let total: usize = (0..3).map(|d| t.device_spans(d).len()).sum();
        assert_eq!(total, t.len());
        for d in 0..3 {
            assert!(t.device_spans(d).iter().all(|s| s.device == d));
        }
        assert!(t.device_spans(1).is_empty());
    }

    #[test]
    fn utilization_bounded() {
        let mut t = Timeline::new(2);
        t.push(span(0, 0.0, 100.0, SpanKind::Comp));
        t.push(span(1, 0.0, 25.0, SpanKind::Comp));
        t.finalize();
        assert!((t.utilization(0) - 1.0).abs() < 1e-12);
        assert!((t.utilization(1) - 0.25).abs() < 1e-12);
    }

    #[test]
    fn normalized_starts_at_zero() {
        let mut t = Timeline::new(1);
        t.push(span(0, 100.0, 110.0, SpanKind::Comp));
        t.finalize();
        let n = t.normalized();
        assert_eq!(n.spans()[0].start, 0.0);
        assert_eq!(n.batch_time_us(), t.batch_time_us());
        assert_eq!(n.device_comp_spans(0)[0].start, 0.0);
    }

    #[test]
    fn empty_timeline_is_safe() {
        let t = Timeline::new(4);
        assert_eq!(t.batch_time_us(), 0.0);
        assert_eq!(t.utilization(0), 0.0);
        assert!(t.device_spans(2).is_empty());
    }

    #[test]
    #[should_panic(expected = "before finalize")]
    fn querying_unfinalized_timeline_panics() {
        let mut t = Timeline::new(1);
        t.push(span(0, 0.0, 1.0, SpanKind::Comp));
        let _ = t.batch_time_us();
    }

    #[test]
    fn push_after_finalize_definalizes() {
        let mut t = Timeline::new(1);
        t.push(span(0, 0.0, 1.0, SpanKind::Comp));
        t.finalize();
        t.push(span(0, 1.0, 3.0, SpanKind::Comp));
        t.finalize();
        assert_eq!(t.batch_time_us(), 3.0);
        assert_eq!(t.busy_us(0), 3.0);
    }

    #[test]
    fn reset_reuses_allocations() {
        let mut t = Timeline::new(2);
        t.push(span(0, 0.0, 1.0, SpanKind::Comp));
        t.finalize();
        t.reset(3);
        assert!(t.is_empty());
        assert_eq!(t.n_devices, 3);
        t.push(span(2, 5.0, 6.0, SpanKind::Comp));
        t.finalize();
        assert_eq!(t.batch_time_us(), 1.0);
        assert_eq!(t.device_spans(2).len(), 1);
    }
}
