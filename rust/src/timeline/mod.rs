//! Per-device activity timelines — DistSim's output artifact (§3.2: "a
//! detailed execution timeline ... when and which device will compute and
//! communicate for certain operators").
//!
//! Both the ground-truth engine and DistSim's hierarchical modeling emit
//! this same structure, so the metrics layer can align spans one-to-one
//! and compute the paper's three error families (batch time, per-GPU
//! activity, per-stage timestamps).

pub mod analysis;
pub mod chrome;

use crate::schedule::Phase;
use crate::util::TimeUs;

/// What a span on a device's lane represents.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum SpanKind {
    /// A computation event (layer fwd/bwd, embedding, head).
    Comp,
    /// Receiving an inter-stage activation / gradient transfer.
    P2p,
    /// Tensor-MP partial-sum all-reduce inside a layer.
    MpAllReduce,
    /// Data-parallel gradient all-reduce at batch end.
    GradAllReduce,
}

/// Identity of a span within the training step — identical between the
/// ground truth and the model, so spans align by (device, tag, order).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct Tag {
    pub stage: u32,
    pub mb: u32,
    pub phase: Phase,
    /// Layer index within the model (u32::MAX when not layer-specific,
    /// e.g. the DP gradient all-reduce).
    pub layer: u32,
    pub kind: SpanKind,
    /// Disambiguator for repeated events inside one (stage, mb, phase,
    /// layer), e.g. the two Megatron MP all-reduces.
    pub idx: u32,
}

impl Tag {
    pub fn comp(stage: usize, mb: usize, phase: Phase, layer: usize) -> Tag {
        Tag {
            stage: stage as u32,
            mb: mb as u32,
            phase,
            layer: layer as u32,
            kind: SpanKind::Comp,
            idx: 0,
        }
    }

    pub fn label(&self) -> String {
        match self.kind {
            SpanKind::Comp => format!(
                "{}{} s{} L{}",
                self.phase, self.mb, self.stage, self.layer
            ),
            SpanKind::P2p => format!("p2p {}{} s{}", self.phase, self.mb, self.stage),
            SpanKind::MpAllReduce => format!(
                "mp-ar {}{} s{} L{}#{}",
                self.phase, self.mb, self.stage, self.layer, self.idx
            ),
            SpanKind::GradAllReduce => format!("grad-ar s{}", self.stage),
        }
    }
}

/// One activity interval on one device.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Span {
    pub device: usize,
    pub start: TimeUs,
    pub end: TimeUs,
    pub tag: Tag,
}

impl Span {
    pub fn dur(&self) -> TimeUs {
        self.end - self.start
    }
}

/// A complete step timeline over all devices.
#[derive(Debug, Clone, Default)]
pub struct Timeline {
    pub n_devices: usize,
    pub spans: Vec<Span>,
}

impl Timeline {
    pub fn new(n_devices: usize) -> Self {
        Timeline {
            n_devices,
            spans: Vec::new(),
        }
    }

    pub fn push(&mut self, span: Span) {
        debug_assert!(span.end >= span.start, "negative span {span:?}");
        debug_assert!(span.device < self.n_devices);
        self.spans.push(span);
    }

    /// Iteration (batch) time: last end minus first start.
    pub fn batch_time_us(&self) -> TimeUs {
        if self.spans.is_empty() {
            return 0.0;
        }
        let start = self.spans.iter().map(|s| s.start).fold(f64::INFINITY, f64::min);
        let end = self
            .spans
            .iter()
            .map(|s| s.end)
            .fold(f64::NEG_INFINITY, f64::max);
        end - start
    }

    /// All spans of one device, in start order.
    pub fn device_spans(&self, device: usize) -> Vec<Span> {
        let mut v: Vec<Span> = self
            .spans
            .iter()
            .copied()
            .filter(|s| s.device == device)
            .collect();
        v.sort_by(|a, b| a.start.partial_cmp(&b.start).unwrap());
        v
    }

    /// Compute spans of one device, in start order (the paper's per-GPU
    /// activity metric aligns these).
    pub fn device_comp_spans(&self, device: usize) -> Vec<Span> {
        self.device_spans(device)
            .into_iter()
            .filter(|s| s.tag.kind == SpanKind::Comp)
            .collect()
    }

    /// Busy time (sum of span durations) of a device.
    pub fn busy_us(&self, device: usize) -> TimeUs {
        self.spans
            .iter()
            .filter(|s| s.device == device)
            .map(Span::dur)
            .sum()
    }

    /// Device utilization = busy / batch time.
    pub fn utilization(&self, device: usize) -> f64 {
        let bt = self.batch_time_us();
        if bt == 0.0 {
            return 0.0;
        }
        (self.busy_us(device) / bt).min(1.0)
    }

    /// Shift all spans so the earliest start is 0 (the paper aligns both
    /// timelines to the first stage's start before comparing).
    pub fn normalized(&self) -> Timeline {
        if self.spans.is_empty() {
            return self.clone();
        }
        let t0 = self.spans.iter().map(|s| s.start).fold(f64::INFINITY, f64::min);
        Timeline {
            n_devices: self.n_devices,
            spans: self
                .spans
                .iter()
                .map(|s| Span {
                    start: s.start - t0,
                    end: s.end - t0,
                    ..*s
                })
                .collect(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn span(device: usize, start: f64, end: f64, kind: SpanKind) -> Span {
        Span {
            device,
            start,
            end,
            tag: Tag {
                stage: 0,
                mb: 0,
                phase: Phase::Fwd,
                layer: 0,
                kind,
                idx: 0,
            },
        }
    }

    #[test]
    fn batch_time_spans_extremes() {
        let mut t = Timeline::new(2);
        t.push(span(0, 10.0, 20.0, SpanKind::Comp));
        t.push(span(1, 5.0, 12.0, SpanKind::Comp));
        t.push(span(1, 30.0, 45.0, SpanKind::P2p));
        assert_eq!(t.batch_time_us(), 40.0);
    }

    #[test]
    fn device_spans_sorted_and_filtered() {
        let mut t = Timeline::new(2);
        t.push(span(0, 20.0, 25.0, SpanKind::Comp));
        t.push(span(0, 0.0, 5.0, SpanKind::Comp));
        t.push(span(0, 10.0, 15.0, SpanKind::P2p));
        t.push(span(1, 0.0, 1.0, SpanKind::Comp));
        let d0 = t.device_spans(0);
        assert_eq!(d0.len(), 3);
        assert!(d0.windows(2).all(|w| w[0].start <= w[1].start));
        assert_eq!(t.device_comp_spans(0).len(), 2);
    }

    #[test]
    fn utilization_bounded() {
        let mut t = Timeline::new(2);
        t.push(span(0, 0.0, 100.0, SpanKind::Comp));
        t.push(span(1, 0.0, 25.0, SpanKind::Comp));
        assert!((t.utilization(0) - 1.0).abs() < 1e-12);
        assert!((t.utilization(1) - 0.25).abs() < 1e-12);
    }

    #[test]
    fn normalized_starts_at_zero() {
        let mut t = Timeline::new(1);
        t.push(span(0, 100.0, 110.0, SpanKind::Comp));
        let n = t.normalized();
        assert_eq!(n.spans[0].start, 0.0);
        assert_eq!(n.batch_time_us(), t.batch_time_us());
    }

    #[test]
    fn empty_timeline_is_safe() {
        let t = Timeline::new(4);
        assert_eq!(t.batch_time_us(), 0.0);
        assert_eq!(t.utilization(0), 0.0);
    }
}
