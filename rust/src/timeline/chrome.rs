//! Chrome-trace (about://tracing, Perfetto) export of a [`Timeline`].
//!
//! Each device becomes a tid under one pid; spans become complete ("X")
//! events. Load the emitted file in Perfetto to inspect pipeline bubbles
//! visually — the use the paper proposes for fault-tolerance scheduling.

use super::{SpanKind, Timeline};
use crate::config::Json;

/// Render a timeline as a Chrome-trace JSON string.
pub fn to_chrome_trace(t: &Timeline) -> String {
    let mut events = Vec::with_capacity(t.len() + t.n_devices);
    for d in 0..t.n_devices {
        events.push(Json::obj(vec![
            ("name", Json::str("thread_name")),
            ("ph", Json::str("M")),
            ("pid", Json::num(0.0)),
            ("tid", Json::num(d as f64)),
            (
                "args",
                Json::obj(vec![("name", Json::str(format!("GPU {d}")))]),
            ),
        ]));
    }
    for s in t.spans() {
        events.push(Json::obj(vec![
            ("name", Json::str(s.tag.label())),
            ("cat", Json::str(kind_category(s.tag.kind))),
            ("ph", Json::str("X")),
            ("ts", Json::num(s.start)),
            ("dur", Json::num(s.dur())),
            ("pid", Json::num(0.0)),
            ("tid", Json::num(s.device as f64)),
        ]));
    }
    Json::obj(vec![
        ("traceEvents", Json::Arr(events)),
        ("displayTimeUnit", Json::str("ms")),
    ])
    .to_string()
}

fn kind_category(kind: SpanKind) -> &'static str {
    match kind {
        SpanKind::Comp => "compute",
        SpanKind::P2p => "p2p",
        SpanKind::MpAllReduce => "mp-allreduce",
        SpanKind::GradAllReduce => "grad-allreduce",
    }
}

/// Write a timeline to a `.json` trace file.
pub fn write_chrome_trace(t: &Timeline, path: &str) -> anyhow::Result<()> {
    std::fs::write(path, to_chrome_trace(t))?;
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::schedule::Phase;
    use crate::timeline::{Span, Tag};

    #[test]
    fn trace_is_valid_json_with_all_spans() {
        let mut t = Timeline::new(2);
        for d in 0..2 {
            t.push(Span {
                device: d,
                start: d as f64 * 10.0,
                end: d as f64 * 10.0 + 5.0,
                tag: Tag::comp(0, 0, Phase::Fwd, 3),
            });
        }
        let s = to_chrome_trace(&t);
        let j = Json::parse(&s).unwrap();
        let events = j.get("traceEvents").unwrap().as_arr().unwrap();
        // 2 metadata + 2 spans
        assert_eq!(events.len(), 4);
        let x: Vec<_> = events
            .iter()
            .filter(|e| e.get("ph").unwrap().as_str() == Some("X"))
            .collect();
        assert_eq!(x.len(), 2);
        assert_eq!(x[0].get("cat").unwrap().as_str(), Some("compute"));
    }
}
