//! Chrome-trace (about://tracing, Perfetto) export of a [`Timeline`].
//!
//! Each device becomes a tid under one pid; spans become complete ("X")
//! events. Load the emitted file in Perfetto to inspect pipeline bubbles
//! visually — the use the paper proposes for fault-tolerance scheduling.

use super::{SpanKind, Timeline};
use crate::config::Json;
use crate::scenario::ScenarioSpec;

/// Render a timeline as a Chrome-trace JSON string.
pub fn to_chrome_trace(t: &Timeline) -> String {
    finish(trace_events(t))
}

/// Render a timeline with the scenario's episodes annotated on a
/// synthetic "scenario" track (tid = device count): straggler and link
/// episodes become complete ("X") events over their windows, device
/// failures become instant ("i") markers at their injection time. Lets
/// Perfetto show *why* a rank's lane stretched where it did.
pub fn to_chrome_trace_with_scenario(t: &Timeline, spec: &ScenarioSpec) -> String {
    let mut events = trace_events(t);
    let tid = t.n_devices as f64;
    let t0 = t.start_us();
    events.push(Json::obj(vec![
        ("name", Json::str("thread_name")),
        ("ph", Json::str("M")),
        ("pid", Json::num(0.0)),
        ("tid", Json::num(tid)),
        ("args", Json::obj(vec![("name", Json::str("scenario"))])),
    ]));
    let window = |name: String, start: f64, end: f64| {
        Json::obj(vec![
            ("name", Json::str(name)),
            ("cat", Json::str("episode")),
            ("ph", Json::str("X")),
            ("ts", Json::num(t0 + start)),
            ("dur", Json::num(end - start)),
            ("pid", Json::num(0.0)),
            ("tid", Json::num(tid)),
        ])
    };
    for e in &spec.straggler_episodes {
        events.push(window(
            format!("straggle dev{} x{}", e.device, e.factor),
            e.start_us,
            e.end_us,
        ));
    }
    for e in &spec.link_episodes {
        events.push(window(
            format!("degrade {} bw x{} lat x{}", e.link.name(), e.bw_factor, e.lat_factor),
            e.start_us,
            e.end_us,
        ));
    }
    for f in &spec.failures {
        events.push(Json::obj(vec![
            ("name", Json::str(format!("fail dev{}", f.device))),
            ("cat", Json::str("episode")),
            ("ph", Json::str("i")),
            ("ts", Json::num(t0 + f.at_us)),
            ("pid", Json::num(0.0)),
            ("tid", Json::num(tid)),
            ("s", Json::str("g")),
        ]));
    }
    finish(events)
}

fn trace_events(t: &Timeline) -> Vec<Json> {
    let mut events = Vec::with_capacity(t.len() + t.n_devices);
    for d in 0..t.n_devices {
        events.push(Json::obj(vec![
            ("name", Json::str("thread_name")),
            ("ph", Json::str("M")),
            ("pid", Json::num(0.0)),
            ("tid", Json::num(d as f64)),
            (
                "args",
                Json::obj(vec![("name", Json::str(format!("GPU {d}")))]),
            ),
        ]));
    }
    for s in t.spans() {
        events.push(Json::obj(vec![
            ("name", Json::str(s.tag.label())),
            ("cat", Json::str(kind_category(s.tag.kind))),
            ("ph", Json::str("X")),
            ("ts", Json::num(s.start)),
            ("dur", Json::num(s.dur())),
            ("pid", Json::num(0.0)),
            ("tid", Json::num(s.device as f64)),
        ]));
    }
    events
}

/// Wrap pre-built trace events (metadata + spans) in the Chrome-trace
/// envelope. Public so the service's self-tracing ([`crate::telemetry`])
/// emits files openable in the same viewer as the simulated timelines.
pub fn finish(events: Vec<Json>) -> String {
    Json::obj(vec![
        ("traceEvents", Json::Arr(events)),
        ("displayTimeUnit", Json::str("ms")),
    ])
    .to_string()
}

fn kind_category(kind: SpanKind) -> &'static str {
    match kind {
        SpanKind::Comp => "compute",
        SpanKind::P2p => "p2p",
        SpanKind::MpAllReduce => "mp-allreduce",
        SpanKind::GradAllReduce => "grad-allreduce",
    }
}

/// Write a timeline to a `.json` trace file.
pub fn write_chrome_trace(t: &Timeline, path: &str) -> anyhow::Result<()> {
    std::fs::write(path, to_chrome_trace(t))?;
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::schedule::Phase;
    use crate::timeline::{Span, Tag};

    #[test]
    fn trace_is_valid_json_with_all_spans() {
        let mut t = Timeline::new(2);
        for d in 0..2 {
            t.push(Span {
                device: d,
                start: d as f64 * 10.0,
                end: d as f64 * 10.0 + 5.0,
                tag: Tag::comp(0, 0, Phase::Fwd, 3),
            });
        }
        let s = to_chrome_trace(&t);
        let j = Json::parse(&s).unwrap();
        let events = j.get("traceEvents").unwrap().as_arr().unwrap();
        // 2 metadata + 2 spans
        assert_eq!(events.len(), 4);
        let x: Vec<_> = events
            .iter()
            .filter(|e| e.get("ph").unwrap().as_str() == Some("X"))
            .collect();
        assert_eq!(x.len(), 2);
        assert_eq!(x[0].get("cat").unwrap().as_str(), Some("compute"));
    }

    #[test]
    fn scenario_trace_adds_episode_track() {
        let mut t = Timeline::new(2);
        t.push(Span {
            device: 0,
            start: 0.0,
            end: 5.0,
            tag: Tag::comp(0, 0, Phase::Fwd, 3),
        });
        let mut spec = ScenarioSpec::default();
        spec.straggler_episodes.push(crate::scenario::StragglerEpisode {
            device: 1,
            factor: 2.0,
            start_us: 0.0,
            end_us: 100.0,
        });
        spec.failures.push(crate::scenario::Failure {
            device: 0,
            at_us: 50.0,
            checkpoint_interval_us: 25.0,
            restart_us: 10.0,
        });
        let s = to_chrome_trace_with_scenario(&t, &spec);
        let j = Json::parse(&s).unwrap();
        let events = j.get("traceEvents").unwrap().as_arr().unwrap();
        let episodes: Vec<_> = events
            .iter()
            .filter(|e| e.get("cat").and_then(|c| c.as_str()) == Some("episode"))
            .collect();
        assert_eq!(episodes.len(), 2);
        // all episode events live on the synthetic track past the GPUs
        assert!(episodes
            .iter()
            .all(|e| e.get("tid").unwrap().as_f64() == Some(2.0)));
        // empty scenario emits the same span set plus the track metadata
        let base = to_chrome_trace(&t);
        assert!(base.len() < s.len());
    }
}
