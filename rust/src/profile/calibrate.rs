//! Cost-model calibration from **measured** compute (the L1/L2 artifacts).
//!
//! The paper anchors event costs by profiling real kernels with CUPTI; we
//! anchor ours by executing the AOT-lowered JAX/Pallas transformer-layer
//! graphs on PJRT-CPU and fitting the cost model's efficiency curve to the
//! measured (FLOPs → latency) points. The resulting [`Calibration`] can be
//! saved/loaded as JSON and applied to any [`CostModel`].
//!
//! With no artifacts present, calibration is skipped and the analytic
//! defaults are used (documented in DESIGN.md).

use std::path::Path;

use anyhow::Result;

use crate::config::Json;
use crate::cost::CostModel;
use crate::runtime::{Manifest, Runtime};

/// A single measured point: one artifact's FLOPs and latency.
#[derive(Debug, Clone)]
pub struct MeasuredPoint {
    pub name: String,
    pub flops: u64,
    pub measured_us: f64,
}

/// Calibration result: measured points + the fitted scale.
#[derive(Debug, Clone, Default)]
pub struct Calibration {
    pub points: Vec<MeasuredPoint>,
    /// Host throughput implied by the biggest measured matmul (GFLOP/s) —
    /// recorded for the report; the simulator keeps modeling the *target*
    /// device and uses `scale` only for relative shape.
    pub host_gflops: f64,
    /// Fitted multiplier for `CostModel::scale` when simulating the host
    /// itself (used by self-validation tests, not the A40/A10 presets).
    pub scale: f64,
}

/// Execute every matmul + layer artifact and collect measured timings.
pub fn measure_artifacts(dir: &Path, iters: usize) -> Result<Calibration> {
    let manifest = Manifest::load(dir)?;
    let rt = Runtime::cpu()?;
    let mut points = Vec::new();
    for kind in ["matmul", "layer_fwd", "layer_bwd", "attention"] {
        for spec in manifest.by_kind(kind) {
            let exe = rt.load(spec)?;
            let us = exe.bench_us(iters)?;
            points.push(MeasuredPoint {
                name: spec.name.clone(),
                flops: spec.flops,
                measured_us: us,
            });
        }
    }
    anyhow::ensure!(!points.is_empty(), "no artifacts to calibrate from");
    let host_gflops = points
        .iter()
        .map(|p| p.flops as f64 / p.measured_us / 1e3)
        .fold(0.0, f64::max);
    Ok(Calibration {
        points,
        host_gflops,
        scale: 1.0,
    })
}

/// Fit `CostModel::scale` so the model's predictions match the measured
/// points in geometric mean (a one-parameter fit keeps A40/A10 *shape*
/// assumptions while anchoring absolute compute cost to reality).
pub fn fit_scale(cal: &mut Calibration, cost: &CostModel, host_peak_tflops: f64) {
    let dev = crate::cluster::DeviceSpec {
        name: "host-cpu".into(),
        peak_tflops: host_peak_tflops,
        mem_bw_gbs: 50.0,
        launch_overhead_us: 20.0,
        mem_gib: 16.0,
        capacity_bytes: None,
    };
    let ratios: Vec<f64> = cal
        .points
        .iter()
        .filter(|p| p.flops > 0)
        .map(|p| {
            let pred = cost.op_latency_us(
                &dev,
                crate::cost::OpClass::Matmul,
                p.flops,
                p.flops / 64,
            );
            p.measured_us / pred
        })
        .collect();
    cal.scale = crate::util::stats::geomean(&ratios);
}

impl Calibration {
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            (
                "points",
                Json::Arr(
                    self.points
                        .iter()
                        .map(|p| {
                            Json::obj(vec![
                                ("name", Json::str(&p.name)),
                                ("flops", Json::num(p.flops as f64)),
                                ("measured_us", Json::num(p.measured_us)),
                            ])
                        })
                        .collect(),
                ),
            ),
            ("host_gflops", Json::num(self.host_gflops)),
            ("scale", Json::num(self.scale)),
        ])
    }

    pub fn from_json(j: &Json) -> Result<Calibration> {
        let points = j
            .get("points")
            .and_then(Json::as_arr)
            .ok_or_else(|| anyhow::anyhow!("calibration missing points"))?
            .iter()
            .map(|p| {
                Ok(MeasuredPoint {
                    name: p
                        .get("name")
                        .and_then(Json::as_str)
                        .ok_or_else(|| anyhow::anyhow!("point missing name"))?
                        .to_string(),
                    flops: p.get("flops").and_then(Json::as_u64).unwrap_or(0),
                    measured_us: p
                        .get("measured_us")
                        .and_then(Json::as_f64)
                        .unwrap_or(0.0),
                })
            })
            .collect::<Result<Vec<_>>>()?;
        Ok(Calibration {
            points,
            host_gflops: j.get("host_gflops").and_then(Json::as_f64).unwrap_or(0.0),
            scale: j.get("scale").and_then(Json::as_f64).unwrap_or(1.0),
        })
    }

    pub fn save(&self, path: &Path) -> Result<()> {
        std::fs::write(path, self.to_json().to_string())?;
        Ok(())
    }

    pub fn load(path: &Path) -> Result<Calibration> {
        let text = std::fs::read_to_string(path)?;
        Calibration::from_json(&Json::parse(&text)?)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cal() -> Calibration {
        Calibration {
            points: vec![
                MeasuredPoint {
                    name: "matmul_128".into(),
                    flops: 2 * 128 * 128 * 128,
                    measured_us: 80.0,
                },
                MeasuredPoint {
                    name: "matmul_1024".into(),
                    flops: 2u64 * 1024 * 1024 * 1024,
                    measured_us: 30_000.0,
                },
            ],
            host_gflops: 70.0,
            scale: 1.0,
        }
    }

    #[test]
    fn json_roundtrip() {
        let c = cal();
        let j = Json::parse(&c.to_json().to_string()).unwrap();
        let back = Calibration::from_json(&j).unwrap();
        assert_eq!(back.points.len(), 2);
        assert_eq!(back.points[1].name, "matmul_1024");
        assert_eq!(back.host_gflops, 70.0);
    }

    #[test]
    fn fit_scale_produces_positive_finite_scale() {
        let mut c = cal();
        fit_scale(&mut c, &CostModel::default(), 0.07);
        assert!(c.scale.is_finite() && c.scale > 0.0);
    }

    #[test]
    fn save_load_roundtrip() {
        let c = cal();
        let path = std::env::temp_dir().join("distsim_cal_test.json");
        c.save(&path).unwrap();
        let back = Calibration::load(&path).unwrap();
        assert_eq!(back.points.len(), c.points.len());
    }
}
